//! Fig 3 — single-node throughput. Two parts:
//!  1. the analytic model for the paper's machines/topologies (regenerates
//!     the figure's bars);
//!  2. REAL measured throughput of the tiny AOT models on this CPU via the
//!     PJRT runtime (scoring + training), across minibatch sizes — the
//!     measured counterpart whose *shape* (flat vs MB; FP >> FP+BP) must
//!     match the paper's.

use std::time::Instant;

use pcl_dnn::analytic::compute_model;
use pcl_dnn::analytic::MachineSpec;
use pcl_dnn::data::ImageDataset;
use pcl_dnn::metrics::Table;
use pcl_dnn::models::zoo;
use pcl_dnn::runtime::{HostTensor, Runtime};

fn main() {
    println!("=== fig3_single_node ===");
    println!("\n# analytic model (E5-2698v3; paper: OF ~315 FP / ~90 FP+BP, VGG ~95 / ~30)");
    let m = MachineSpec::e5_2698v3();
    let mut t = Table::new(&["net", "mode", "MB16", "MB32", "MB64", "MB128", "MB256"]);
    for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
        for (mode, tr) in [("FP", false), ("FP+BP", true)] {
            let mut row = vec![net.name.clone(), mode.into()];
            row.extend(
                compute_model::fig3_row(&net, &m, tr).iter().map(|(_, v)| format!("{v:.0}")),
            );
            t.row(row);
        }
    }
    t.print();

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n(artifacts not built; skipping measured part)");
        return;
    }
    println!("\n# measured on this CPU (tiny models, PJRT runtime)");
    let mut rt = Runtime::new("artifacts").expect("runtime");
    let mut t = Table::new(&["model", "mode", "batch", "samples/s"]);
    for model in ["vgg_tiny", "overfeat_tiny"] {
        let params = rt.manifest().load_params(model).unwrap();
        // scoring
        let fwd = format!("{model}_fwd");
        let spec = rt.manifest().artifact(&fwd).unwrap().clone();
        let ds = ImageDataset::new(32, 3, 10, 0);
        let b = spec.batch;
        let batch = ds.batch(0, b);
        let data = vec![HostTensor::f32(vec![b, 32, 32, 3], batch.images)];
        rt.execute_with_params(&fwd, &params, &data).unwrap(); // warm
        let t0 = Instant::now();
        let iters = 12;
        for _ in 0..iters {
            rt.execute_with_params(&fwd, &params, &data).unwrap();
        }
        t.row(vec![
            model.into(),
            "FP".into(),
            b.to_string(),
            format!("{:.0}", (iters * b) as f64 / t0.elapsed().as_secs_f64()),
        ]);
        // training
        let tr = format!("{model}_train");
        let spec = rt.manifest().artifact(&tr).unwrap().clone();
        let b = spec.batch;
        let batch = ds.batch(0, b);
        let data = vec![
            HostTensor::f32(vec![b, 32, 32, 3], batch.images),
            HostTensor::i32(vec![b], batch.labels),
        ];
        rt.execute_with_params(&tr, &params, &data).unwrap();
        let t0 = Instant::now();
        for _ in 0..iters {
            rt.execute_with_params(&tr, &params, &data).unwrap();
        }
        t.row(vec![
            model.into(),
            "FP+BP".into(),
            b.to_string(),
            format!("{:.0}", (iters * b) as f64 / t0.elapsed().as_secs_f64()),
        ]);
    }
    t.print();
    println!("(expected shape: FP sustains ~2.5-4x FP+BP, matching the paper's ratio)");
}
