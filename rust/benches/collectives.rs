//! Collectives microbenchmarks: part-reduce / part-broadcast / allreduce
//! across engines (inline vs threaded), rank counts and message sizes,
//! plus the lock-free command queue and the comm-thread round trip.

use std::time::Duration;

use pcl_dnn::collectives::{inline, threaded};
use pcl_dnn::coordinator::{CommHandle, CommOp, CommRequest, CommandQueue};
use pcl_dnn::util::bench::{bench, black_box, header};

fn make(ranks: usize, len: usize) -> Vec<Vec<f32>> {
    (0..ranks).map(|r| (0..len).map(|i| (r * 31 + i) as f32).collect()).collect()
}

fn main() {
    println!("=== collectives ===");
    header();

    for (ranks, len) in [(4usize, 1 << 10), (4, 1 << 16), (4, 1 << 20), (8, 1 << 16)] {
        let label_len = if len >= 1 << 20 { format!("{}M", len >> 20) } else { format!("{}K", len >> 10) };
        let base = make(ranks, len);
        let mut bufs = base.clone();
        bench(
            &format!("inline allreduce r{ranks} x {label_len}"),
            Duration::from_millis(300),
            || {
                bufs.clone_from(&base);
                inline::allreduce(black_box(&mut bufs));
            },
        )
        .report();
        let mut bufs = base.clone();
        bench(
            &format!("threaded allreduce r{ranks} x {label_len}"),
            Duration::from_millis(300),
            || {
                bufs.clone_from(&base);
                threaded::allreduce(black_box(&mut bufs));
            },
        )
        .report();
        let mut bufs = base.clone();
        bench(
            &format!("inline part_reduce r{ranks} x {label_len}"),
            Duration::from_millis(200),
            || {
                bufs.clone_from(&base);
                inline::part_reduce(black_box(&mut bufs));
            },
        )
        .report();
    }

    // lock-free queue throughput (single-thread push+pop pairs)
    let q: CommandQueue<u64> = CommandQueue::new(1024);
    bench("command_queue push+pop", Duration::from_millis(200), || {
        q.push(black_box(7)).unwrap();
        black_box(q.pop());
    })
    .report();

    // comm-thread round trip (submit -> allreduce -> completion)
    let h = CommHandle::spawn(64);
    let payload = make(4, 1 << 12);
    bench("comm_thread round-trip r4 x 4K", Duration::from_millis(300), || {
        h.submit(CommRequest { id: 0, op: CommOp::AllReduce, bufs: payload.clone() }).unwrap();
        black_box(h.wait_one());
    })
    .report();

    // effective reduction bandwidth
    let len = 1 << 20;
    let base = make(4, len);
    let mut bufs = base.clone();
    let r = bench("allreduce bandwidth probe 4x4MB", Duration::from_millis(400), || {
        bufs.clone_from(&base);
        inline::allreduce(&mut bufs);
    });
    let bytes = 4.0 * (4 * len) as f64; // read+write both phases approx
    println!(
        "  -> effective allreduce throughput: {:.2} GB/s",
        bytes / (r.mean_ns / 1e9) / 1e9
    );
}
