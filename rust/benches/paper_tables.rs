//! Bench + regeneration of the paper's analytic tables: Table 1, the §2.2
//! blocking search, the §2.4 efficiency model and the §3.3 optimum. The
//! timed portion is the brute-force search itself (the paper ran it as a
//! standalone multithreaded program).

use std::time::Duration;

use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::analytic::{cache_blocking, comm_model, register_blocking, scaling};
use pcl_dnn::metrics::Table;
use pcl_dnn::models::zoo;
use pcl_dnn::models::Layer;
use pcl_dnn::util::bench::{bench, black_box, header};

fn main() {
    println!("=== paper_tables bench ===");
    header();

    let c5 = zoo::overfeat_c5_paper();
    let cfg = cache_blocking::SearchCfg::default();
    bench("cache_blocking_search(C5, 128KB)", Duration::from_millis(400), || {
        black_box(cache_blocking::search(&c5, &cfg));
    })
    .report();

    let tpu = cache_blocking::SearchCfg { budget: 8 << 20, simd: 128, double_buffer: true, max_mb: 8 };
    bench("cache_blocking_search(C5, 8MB VMEM)", Duration::from_millis(400), || {
        black_box(cache_blocking::search(&c5, &tpu));
    })
    .report();

    let net = zoo::vgg_a();
    let p = Platform::table1_fdr();
    bench("table1_row(vgg_a, FDR)", Duration::from_millis(300), || {
        black_box(scaling::table1_row(&net, &p, 256));
    })
    .report();

    let fc = Layer::fc("fc", 4096, 4096);
    bench("optimal_groups(fc4096, N=64)", Duration::from_millis(200), || {
        black_box(comm_model::optimal_groups(&fc, 256, 64, 1.0));
    })
    .report();

    bench("register_cycle_model", Duration::from_millis(100), || {
        black_box(register_blocking::cycle_model(12, 8, 3));
    })
    .report();

    // ---- regenerated table ----
    println!("\n# Table 1 (paper: 1336/336; OverFeat 3 (86)/2 (128); VGG-A 1 (256)/1 (256))");
    let platforms = [Platform::table1_ethernet(), Platform::table1_fdr()];
    let mut t = Table::new(&["", "Ethernet", "FDR"]);
    t.row(vec![
        "comp-to-comms".into(),
        format!("{:.0}", platforms[0].comp_to_comms()),
        format!("{:.0}", platforms[1].comp_to_comms()),
    ]);
    for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
        let cells: Vec<String> = platforms
            .iter()
            .map(|p| {
                let (mb, n) = scaling::table1_row(&net, p, 256);
                format!("{mb} ({n})")
            })
            .collect();
        t.row(vec![net.name.clone(), cells[0].clone(), cells[1].clone()]);
    }
    t.print();
}
