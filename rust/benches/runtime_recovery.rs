//! Checkpoint + recovery benchmarks (ISSUE 9): what fault tolerance
//! costs when nothing fails, and what a failure costs when one does.
//!
//! * **Checkpoint overhead** — mean synthetic coordinator step latency
//!   with the async double-buffered writer submitting every {1, 2, 8}
//!   steps vs checkpointing off. The writer snapshots on the training
//!   thread but serializes + fsyncs on its own; the overhead row is the
//!   paper-style "fault tolerance tax" per interval.
//! * **MTTR** — wall-clock `fault::recover` latency per policy
//!   (stall restore vs shrink/replan rebuild at N-1), workers ∈ {4, 8}.
//!   Replay cost is excluded (it is `replay_steps x step_ms`, both
//!   reported).
//!
//! Synthetic compute only (no PJRT artifacts needed) — runs everywhere,
//! including container CI. Emits `BENCH_runtime_recovery.json`; CI's
//! `recovery` job uploads it.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use pcl_dnn::checkpoint::CheckpointWriter;
use pcl_dnn::coordinator::{MicrobatchPlan, SgdConfig, SyncSgdCoordinator};
use pcl_dnn::models::zoo;
use pcl_dnn::trainer::fault::{self, RecoveryPlanner};
use pcl_dnn::util::json::Json;
use pcl_dnn::util::rng::Rng;

const WARMUP_STEPS: usize = 2;
const MEASURED_STEPS: usize = 8;

fn vgg_shapes() -> Vec<usize> {
    zoo::vgg_tiny()
        .layers
        .iter()
        .filter(|l| l.is_weighted())
        .map(|l| l.weight_elems() as usize)
        .collect()
}

fn make_coord(shapes: &[usize], workers: usize) -> SyncSgdCoordinator {
    let params: Vec<Vec<f32>> = shapes.iter().map(|&n| vec![0.01f32; n]).collect();
    let plan = MicrobatchPlan::new(workers * 4, workers, 2).unwrap();
    SyncSgdCoordinator::new("synthetic", params, plan, SgdConfig::default())
}

fn run_step(coord: &mut SyncSgdCoordinator) {
    let mut compute =
        |w: usize, starts: &[usize], acc: &mut [Vec<f32>]| -> anyhow::Result<(f64, u64)> {
            let mut rng = Rng::new((w as u64) * 7919 + 1);
            for buf in acc.iter_mut() {
                rng.fill_normal(buf, 0.1);
            }
            Ok((0.5, starts.len() as u64))
        };
    coord.step_with_compute(&mut compute).unwrap();
}

fn bench_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pcl-dnn-bench-rec-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Mean step latency at each checkpoint interval; interval 0 = writer
/// off, the baseline the overhead percentages are relative to.
fn checkpoint_overhead(rows: &mut Vec<Json>) {
    println!("\n--- checkpoint overhead (vgg_tiny shapes, 4 workers) ---");
    let shapes = vgg_shapes();
    let param_bytes: usize = shapes.iter().map(|n| n * 4).sum();
    let mut baseline_ms = 0.0f64;
    for interval in [0u64, 1, 2, 8] {
        let dir = bench_dir(&format!("ovh-{interval}"));
        let mut coord = make_coord(&shapes, 4);
        let mut writer = (interval > 0).then(|| CheckpointWriter::spawn(&dir).unwrap());
        let mut step_s = 0.0f64;
        for i in 0..WARMUP_STEPS + MEASURED_STEPS {
            let t0 = Instant::now();
            run_step(&mut coord);
            if interval > 0 && (i as u64 + 1) % interval == 0 {
                if let Some(w) = writer.as_mut() {
                    w.submit(coord.params.snapshot());
                }
            }
            if i >= WARMUP_STEPS {
                step_s += t0.elapsed().as_secs_f64();
            }
        }
        let step_ms = step_s / MEASURED_STEPS as f64 * 1e3;
        if interval == 0 {
            baseline_ms = step_ms;
        }
        let overhead_pct =
            if interval == 0 { 0.0 } else { (step_ms / baseline_ms - 1.0) * 100.0 };
        let (written, skipped) = writer
            .take()
            .map(|w| {
                let skipped = w.skipped();
                (w.shutdown(), skipped)
            })
            .unwrap_or((0, 0));
        println!(
            "  every {interval:>2}: step {step_ms:>7.3} ms ({overhead_pct:>+6.2}%) | \
             written {written}, coalesced {skipped}"
        );
        let mut row = BTreeMap::new();
        row.insert("section".to_string(), Json::Str("checkpoint_overhead".to_string()));
        row.insert("interval".to_string(), Json::Num(interval as f64));
        row.insert("step_ms".to_string(), Json::Num(step_ms));
        row.insert("overhead_pct".to_string(), Json::Num(overhead_pct));
        row.insert("param_bytes".to_string(), Json::Num(param_bytes as f64));
        row.insert("written".to_string(), Json::Num(written as f64));
        row.insert("coalesced".to_string(), Json::Num(skipped as f64));
        rows.push(Json::Obj(row));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Wall-clock `fault::recover` per policy: the restore / replan /
/// rebuild components of MTTR, minus replay (reported as step count).
fn mttr(rows: &mut Vec<Json>) {
    println!("\n--- recovery latency (MTTR minus replay) ---");
    let shapes = vgg_shapes();
    for policy in ["stall", "shrink", "replan"] {
        for workers in [4usize, 8] {
            let dir = bench_dir(&format!("mttr-{policy}-{workers}"));
            let mut coord = make_coord(&shapes, workers);
            // 6 committed steps, a durable checkpoint at step 4: stall
            // restores it (2 steps of replay debt), shrink/replan keep
            // the live state and rebuild at N-1
            let mut writer = CheckpointWriter::spawn(&dir).unwrap();
            for i in 0..6 {
                run_step(&mut coord);
                if i == 3 {
                    writer.submit(coord.params.snapshot());
                }
            }
            writer.flush(std::time::Duration::from_secs(10)).unwrap();
            writer.shutdown();
            let rp = RecoveryPlanner {
                policy: fault::policy_from_str(policy).unwrap(),
                checkpoint_dir: dir.clone(),
                initial: coord.params.snapshot(),
                plan_before: None,
                replan_to: None,
                micro: 2,
                global_mb: workers * 4,
                artifact: "synthetic".into(),
            };
            let mut topos = |_: Option<&pcl_dnn::plan::PartitionPlan>,
                             _: usize|
             -> Vec<Option<pcl_dnn::collectives::GroupTopology>> { Vec::new() };
            let t0 = Instant::now();
            let (next, meas) = fault::recover(coord, 6, workers - 1, 0.0, &rp, &mut topos)
                .unwrap_or_else(|e| panic!("{policy} x{workers}: {e:#}"));
            let total_ms = t0.elapsed().as_secs_f64() * 1e3;
            drop(next);
            println!(
                "  {policy:>6} x{workers}: {total_ms:>7.3} ms | restore {:>7.3} ms | \
                 replan {:>6.3} ms | rebuild {:>6.3} ms | replay debt {} steps",
                meas.restore_s * 1e3,
                meas.replan_s * 1e3,
                meas.redistribution_s * 1e3,
                meas.replay_steps,
            );
            let mut row = BTreeMap::new();
            row.insert("section".to_string(), Json::Str("mttr".to_string()));
            row.insert("policy".to_string(), Json::Str(policy.to_string()));
            row.insert("workers".to_string(), Json::Num(workers as f64));
            row.insert("workers_after".to_string(), Json::Num(meas.workers_after as f64));
            row.insert("total_ms".to_string(), Json::Num(total_ms));
            row.insert("restore_ms".to_string(), Json::Num(meas.restore_s * 1e3));
            row.insert("replan_ms".to_string(), Json::Num(meas.replan_s * 1e3));
            row.insert("rebuild_ms".to_string(), Json::Num(meas.redistribution_s * 1e3));
            row.insert("replay_steps".to_string(), Json::Num(meas.replay_steps as f64));
            rows.push(Json::Obj(row));
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

fn main() {
    println!("=== runtime_recovery ===");
    let mut rows: Vec<Json> = Vec::new();
    checkpoint_overhead(&mut rows);
    mttr(&mut rows);
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("runtime_recovery".to_string()));
    root.insert("rows".to_string(), Json::Arr(rows));
    std::fs::write("BENCH_runtime_recovery.json", format!("{}\n", Json::Obj(root).pretty()))
        .expect("write BENCH_runtime_recovery.json");
    println!("\nwrote BENCH_runtime_recovery.json");
}
