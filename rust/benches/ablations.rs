//! Ablation benches for the design choices DESIGN.md calls out:
//!   1. L1: Pallas blocked conv/matmul artifact vs native-XLA lowering
//!      (interpret-mode cost on the CPU backend);
//!   2. L3: SGD on the host (paper placement) vs in-graph SGD artifact;
//!   3. netsim: butterfly vs ring collective cost models across sizes;
//!   4. coordinator: hybrid-FC strategy vs pure data parallelism (sim).

use std::time::Duration;

use pcl_dnn::analytic::machine::FabricSpec;
use pcl_dnn::coordinator::{ParamStore, SgdConfig};
use pcl_dnn::experiment::{AnalyticBackend, Backend, ExperimentSpec};
use pcl_dnn::netsim::collective;
use pcl_dnn::runtime::{HostTensor, Runtime};
use pcl_dnn::util::bench::{bench, black_box, header};

fn main() {
    println!("=== ablations ===");
    header();

    // ---- 3. butterfly vs ring (no artifacts needed) ----
    let fdr = FabricSpec::fdr_infiniband();
    for (bytes, n) in [(1u64 << 12, 128u64), (64 << 20, 128), (64 << 20, 8)] {
        let ring = collective::ring_reduce_scatter_s(&fdr, bytes, n);
        let bfly = collective::butterfly_reduce_scatter_s(&fdr, bytes, n);
        println!(
            "  reduce-scatter model {:>8} B x {n:>3} nodes: ring {:.3} ms, butterfly {:.3} ms -> {}",
            bytes,
            ring * 1e3,
            bfly * 1e3,
            if bfly < ring { "butterfly" } else { "ring" }
        );
    }

    // ---- 4. hybrid vs data-parallel FCs (spec-driven, CD-DNN + VGG) ----
    for (model, platform, mb) in
        [("cddnn_full", "endeavor", 1024u64), ("vgg_a", "cori", 256)]
    {
        let spec = ExperimentSpec::of("ablation", model, platform, 16, mb);
        let mut data = spec.clone();
        data.parallelism.mode = "data".into();
        let hy = AnalyticBackend.run(&spec).unwrap().speedup.unwrap();
        let dp = AnalyticBackend.run(&data).unwrap().speedup.unwrap();
        println!("  {model} @16 nodes: hybrid {hy:.1}x vs pure-data {dp:.1}x");
    }

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("(artifacts not built; skipping artifact ablations)");
        return;
    }
    let mut rt = Runtime::new("artifacts").expect("runtime");

    // ---- 1. pallas vs native artifacts ----
    let x = HostTensor::f32(vec![8, 16, 16, 64], vec![0.1; 8 * 16 * 16 * 64]);
    let w = HostTensor::f32(vec![3, 3, 64, 128], vec![0.1; 3 * 3 * 64 * 128]);
    for name in ["conv_layer_native", "conv_layer_pallas"] {
        rt.execute(name, &[x.clone(), w.clone()]).unwrap();
        let rt_ref = &mut rt;
        bench(&format!("{name} (8x16x16x64 * 3x3x64x128)"), Duration::from_millis(400), || {
            black_box(rt_ref.execute(name, &[x.clone(), w.clone()]).unwrap());
        })
        .report();
    }
    let a = HostTensor::f32(vec![256, 512], vec![0.5; 256 * 512]);
    let b = HostTensor::f32(vec![512, 256], vec![0.5; 512 * 256]);
    for name in ["matmul_native", "matmul_pallas"] {
        rt.execute(name, &[a.clone(), b.clone()]).unwrap();
        let rt_ref = &mut rt;
        bench(&format!("{name} (256x512x256)"), Duration::from_millis(300), || {
            black_box(rt_ref.execute(name, &[a.clone(), b.clone()]).unwrap());
        })
        .report();
    }
    println!("  (interpret-mode pallas lowers to loop-heavy HLO: the gap vs native on CPU is");
    println!("   expected; real-TPU perf is estimated analytically — `repro analyze kernel-blocking`)");

    // ---- 2. host SGD vs in-graph SGD ----
    let params = rt.manifest().load_params("vgg_tiny").unwrap();
    let grads: Vec<Vec<f32>> = params.iter().map(|p| vec![0.01; p.len()]).collect();
    let mut store = ParamStore::new(params.clone(), SgdConfig::default());
    bench("host SGD apply_all (vgg_tiny, 117K params)", Duration::from_millis(200), || {
        store.apply_all(black_box(&grads), 1.0).unwrap();
    })
    .report();
    let spec = rt.manifest().artifact("vgg_tiny_sgd").unwrap().clone();
    let mut inputs: Vec<HostTensor> = Vec::new();
    for (i, p) in params.iter().enumerate() {
        inputs.push(HostTensor::f32(spec.inputs[i].shape.clone(), p.clone()));
    }
    for (i, g) in grads.iter().enumerate() {
        inputs.push(HostTensor::f32(spec.inputs[params.len() + i].shape.clone(), g.clone()));
    }
    inputs.push(HostTensor::scalar_f32(0.01));
    rt.execute("vgg_tiny_sgd", &inputs).unwrap();
    {
        let rt_ref = &mut rt;
        bench("in-graph SGD artifact (vgg_tiny)", Duration::from_millis(300), || {
            black_box(rt_ref.execute("vgg_tiny_sgd", &inputs).unwrap());
        })
        .report();
    }
    println!("  (host SGD avoids 2x param literal copies per step — why §3.4 puts SGD on L3)");
}
