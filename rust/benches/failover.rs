//! Failover frontier bench — the replan-vs-stall-vs-shrink tradeoff
//! across the paper's three networks and a node-count sweep, measured
//! on the full-cluster simulator. Emits `BENCH_failover.json`:
//! one row per (network, nodes, policy) with the measured disruption,
//! the itemized replan/redistribution charges and the post-failure
//! efficiency at the surviving node count — the cross-PR trajectory for
//! the recovery model.

use std::collections::BTreeMap;

use pcl_dnn::experiment::{Backend, ExperimentSpec, FleetSimBackend, RecoveryReport};
use pcl_dnn::util::json::Json;

fn main() {
    println!("=== failover ===");
    let networks: &[(&str, &str, u64)] = &[
        ("vgg_a", "cori", 512),
        ("overfeat_fast", "aws", 256),
        ("cddnn_full", "endeavor", 1024),
    ];
    let nodes_grid: &[u64] = &[8, 16, 32];
    let policies: &[&str] = &["stall", "replan", "shrink"];

    let mut root = BTreeMap::new();
    for &(model, platform, mb) in networks {
        println!("\n# {model} on {platform}, MB={mb} (fail_at=1, fail_node=0)");
        let mut rows: Vec<Json> = Vec::new();
        for &nodes in nodes_grid {
            for &policy in policies {
                let mut spec = ExperimentSpec::of(
                    &format!("failover_{model}_{nodes}_{policy}"),
                    model,
                    platform,
                    nodes,
                    mb,
                );
                spec.cluster.fail_at = Some(1);
                spec.cluster.fail_node = 0;
                spec.cluster.recovery = policy.into();
                spec.parallelism.iterations = 5;
                let rep = FleetSimBackend.run(&spec).expect("failover spec runs");
                let rec = RecoveryReport::from_json(&rep.recovery)
                    .expect("failure spec reports recovery");
                println!(
                    "  x{nodes:>3} {policy:>6}: stall {:>7.3} s | replan {:>6.3} s | \
                     redist {:>6.3} s | post eff {:>5.1}% ({} nodes, {} tasks)",
                    rec.stall_s,
                    rec.replan_s,
                    rec.redistribution_s,
                    100.0 * rec.post_efficiency,
                    rec.nodes_after,
                    rep.tasks
                );
                let mut row = BTreeMap::new();
                row.insert("nodes".to_string(), Json::Num(nodes as f64));
                row.insert("nodes_after".to_string(), Json::Num(rec.nodes_after as f64));
                row.insert("policy".to_string(), Json::Str(policy.to_string()));
                row.insert("post_efficiency".to_string(), Json::Num(rec.post_efficiency));
                row.insert(
                    "post_iteration_s".to_string(),
                    Json::Num(rec.post_iteration_s),
                );
                row.insert(
                    "post_samples_per_s".to_string(),
                    Json::Num(rec.post_samples_per_s),
                );
                row.insert(
                    "redistribution_s".to_string(),
                    Json::Num(rec.redistribution_s),
                );
                row.insert("replan_s".to_string(), Json::Num(rec.replan_s));
                row.insert("stall_s".to_string(), Json::Num(rec.stall_s));
                row.insert("tasks".to_string(), Json::Num(rep.tasks as f64));
                rows.push(Json::Obj(row));
            }
        }
        root.insert(model.to_string(), Json::Arr(rows));
    }
    std::fs::write(
        "BENCH_failover.json",
        format!("{}\n", Json::Obj(root).pretty()),
    )
    .unwrap();
    println!("\nwrote BENCH_failover.json");
}
