//! Fig 4 — VGG-A strong scaling on (simulated) Cori, MB 256 and 512.
//! Regenerates the figure's two curves through the spec-driven
//! experiment API and times both backends on the same spec.

use std::time::Duration;

use pcl_dnn::experiment::{
    curve_table, registry, run_sweep, AnalyticBackend, Backend, ExperimentSpec, FleetSimBackend,
    MinibatchSpec,
};
use pcl_dnn::netsim::collective::Choice;
use pcl_dnn::plan::{planner, PlanCache};
use pcl_dnn::util::bench::{bench, black_box, header};

fn main() {
    println!("=== fig4_vgg_scaling ===");
    let spec = ExperimentSpec::fig4(); // VGG-A x128 on Cori, MB=512

    header();
    bench("AnalyticBackend::run(fig4, 128 nodes)", Duration::from_millis(500), || {
        black_box(AnalyticBackend.run(&spec).unwrap());
    })
    .report();

    for mb in [256u64, 512] {
        println!("\n# VGG-A on Cori, MB={mb} (paper: 90x @128 for MB=512 / 2510 img/s; 82% @64 for MB=256)");
        let mut s = spec.clone();
        s.minibatch = MinibatchSpec { global: mb };
        let curve = run_sweep(&AnalyticBackend, &s, &[1, 2, 4, 8, 16, 32, 64, 128]).unwrap();
        curve_table(&curve).print();
    }

    // full-cluster vs analytic cross-check on the SAME spec (clean
    // homogeneous switched fabric: the two backends must agree)
    println!("\n# cross-backend check, VGG-A x16, MB=256, clean fabric");
    let mut clean = spec.clone();
    clean.cluster.nodes = 16;
    clean.cluster.congestion = Some(0.0);
    clean.minibatch = MinibatchSpec { global: 256 };
    bench("FleetSimBackend::run(fig4, 16 nodes)", Duration::from_millis(800), || {
        black_box(FleetSimBackend.run(&clean).unwrap());
    })
    .report();
    let full = FleetSimBackend.run(&clean).unwrap();
    let rep = AnalyticBackend.run(&clean).unwrap();
    println!(
        "netsim {:.2} ms vs analytic {:.2} ms ({:+.2}%, {} tasks)",
        full.iteration_s * 1e3,
        rep.iteration_s * 1e3,
        100.0 * (full.iteration_s - rep.iteration_s) / rep.iteration_s,
        full.tasks
    );

    // cross-PR bench trajectory: planner-chosen vs fixed-recipe vs
    // pure-data efficiency per node count
    let net = registry::model("vgg_a").unwrap();
    let platform = registry::platform("cori").unwrap();
    let cache = PlanCache::new(PlanCache::default_dir());
    let rows = [8u64, 16, 32, 64, 128]
        .iter()
        .map(|&n| planner::bench_row(&net, &platform, 512, n, Choice::Auto, 3, Some(&cache)))
        .collect();
    planner::merge_bench_plan("BENCH_plan.json", "fig4_vgg_a", rows).unwrap();
    println!("\nwrote BENCH_plan.json (fig4_vgg_a: auto vs fixed vs data efficiency)");
}
