//! Fig 4 — VGG-A strong scaling on (simulated) Cori, MB 256 and 512.
//! Regenerates the figure's two curves and times the simulator itself.

use std::time::Duration;

use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::metrics::Table;
use pcl_dnn::models::zoo;
use pcl_dnn::netsim::cluster::{
    scaling_curve, simulate_training, simulate_training_fleet, SimConfig,
};
use pcl_dnn::netsim::FleetConfig;
use pcl_dnn::util::bench::{bench, black_box, header};

fn main() {
    println!("=== fig4_vgg_scaling ===");
    let p = Platform::cori();
    let net = zoo::vgg_a();

    header();
    bench("simulate_training(vgg_a, 128 nodes)", Duration::from_millis(500), || {
        black_box(simulate_training(
            &net,
            &p,
            &SimConfig { nodes: 128, minibatch: 512, ..Default::default() },
        ));
    })
    .report();

    for mb in [256u64, 512] {
        println!("\n# VGG-A on Cori, MB={mb} (paper: 90x @128 for MB=512 / 2510 img/s; 82% @64 for MB=256)");
        let nodes = [1u64, 2, 4, 8, 16, 32, 64, 128];
        let curve = scaling_curve(&net, &p, mb, &nodes, true);
        let mut t = Table::new(&["nodes", "img/s", "speedup", "efficiency"]);
        for pt in &curve {
            t.row(vec![
                pt.nodes.to_string(),
                format!("{:.0}", pt.images_per_s),
                format!("{:.1}x", pt.speedup),
                format!("{:.0}%", 100.0 * pt.efficiency),
            ]);
        }
        t.print();
    }

    // full-cluster vs analytic cross-check (homogeneous, contention-free
    // fabric: the two fidelities must agree)
    println!("\n# full-cluster cross-check, VGG-A x16, MB=256, clean fabric");
    let mut clean = Platform::cori();
    clean.fabric.congestion_per_doubling = 0.0;
    let cfg = SimConfig { nodes: 16, minibatch: 256, ..Default::default() };
    bench("simulate_training_fleet(vgg_a, 16 nodes)", Duration::from_millis(800), || {
        black_box(simulate_training_fleet(
            &net,
            &clean,
            &cfg,
            &FleetConfig::homogeneous(16),
        ));
    })
    .report();
    let full = simulate_training_fleet(&net, &clean, &cfg, &FleetConfig::homogeneous(16));
    let rep = simulate_training(&net, &clean, &cfg);
    println!(
        "full {:.2} ms vs analytic {:.2} ms ({:+.2}%, {} tasks)",
        full.iteration_s * 1e3,
        rep.iteration_s * 1e3,
        100.0 * (full.iteration_s - rep.iteration_s) / rep.iteration_s,
        full.tasks
    );
}
