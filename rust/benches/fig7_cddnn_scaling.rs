//! Fig 7 — CD-DNN (429 -> 7x2048 -> 9304 senones) scaling on (simulated)
//! Endeavor FDR cluster, MB=1024 frames, through the spec-driven
//! experiment API. Paper: 4600 f/s on one node, ~13K @4 nodes, 29.5K
//! @16 (6.4x). The FC-dominated DNN is the hardest scaling case
//! (highest comm-to-compute) — hybrid parallelism is what keeps it
//! scaling at all (ablation below).

use std::time::Duration;

use pcl_dnn::experiment::{
    registry, run_sweep, AnalyticBackend, Backend, ExperimentSpec, FleetSimBackend,
};
use pcl_dnn::metrics::Table;
use pcl_dnn::netsim::collective::Choice;
use pcl_dnn::plan::{planner, PlanCache};
use pcl_dnn::util::bench::{bench, black_box, header};

fn main() {
    println!("=== fig7_cddnn_scaling ===");
    let spec = ExperimentSpec::fig7(); // CD-DNN x16 on Endeavor, MB=1024
    header();
    bench("AnalyticBackend::run(fig7, 16 nodes)", Duration::from_millis(400), || {
        black_box(AnalyticBackend.run(&spec).unwrap());
    })
    .report();

    let nodes = [1u64, 2, 4, 8, 16];
    println!("\n# CD-DNN on Endeavor, MB=1024 (hybrid FCs vs pure data parallelism)");
    let mut ablation = spec.clone();
    ablation.parallelism.mode = "data".into();
    let hybrid = run_sweep(&AnalyticBackend, &spec, &nodes).unwrap();
    let data = run_sweep(&AnalyticBackend, &ablation, &nodes).unwrap();
    let mut t = Table::new(&["nodes", "hybrid f/s", "speedup", "pure-data f/s", "speedup"]);
    for (h, d) in hybrid.iter().zip(&data) {
        t.row(vec![
            h.nodes.to_string(),
            format!("{:.0}", h.samples_per_s),
            format!("{:.1}x", h.speedup.unwrap_or(f64::NAN)),
            format!("{:.0}", d.samples_per_s),
            format!("{:.1}x", d.speedup.unwrap_or(f64::NAN)),
        ]);
    }
    t.print();
    println!("\n(paper's shape: DNN scales far worse than the CNNs; hybrid > pure data parallel)");

    // full-cluster: straggler + heterogeneous-fleet sensitivity of the
    // comm-bound ASR workload — all spec overrides, netsim backend
    println!("\n# netsim backend: CD-DNN x16, straggler skew and hetero generations");
    bench("FleetSimBackend::run(fig7, 16 nodes)", Duration::from_millis(800), || {
        black_box(FleetSimBackend.run(&spec).unwrap());
    })
    .report();
    let base = FleetSimBackend.run(&spec).unwrap();
    let mut t = Table::new(&["fleet", "iter ms", "f/s", "vs homogeneous"]);
    t.row(vec![
        "homogeneous".into(),
        format!("{:.1}", base.iteration_s * 1e3),
        format!("{:.0}", base.samples_per_s),
        "1.00x".into(),
    ]);
    for (label, skew, hetero) in [
        ("skew 0.25", 0.25, false),
        ("skew 0.50", 0.50, false),
        ("hetero (odd nodes 1.3x)", 0.0, true),
        ("hetero + skew 0.25", 0.25, true),
    ] {
        let mut s = spec.clone();
        s.cluster.straggler_skew = skew;
        s.cluster.hetero = hetero;
        let r = FleetSimBackend.run(&s).unwrap();
        t.row(vec![
            label.into(),
            format!("{:.1}", r.iteration_s * 1e3),
            format!("{:.0}", r.samples_per_s),
            format!("{:.2}x", r.iteration_s / base.iteration_s),
        ]);
    }
    t.print();

    // cross-PR bench trajectory: planner vs fixed recipe vs pure data —
    // the CD-DNN is where the gap is widest (FC-dominated, §5.4)
    let cache = PlanCache::new(PlanCache::default_dir());
    let net = registry::model("cddnn_full").unwrap();
    let platform = registry::platform("endeavor").unwrap();
    let rows = [2u64, 4, 8, 16]
        .iter()
        .map(|&n| planner::bench_row(&net, &platform, 1024, n, Choice::Auto, 3, Some(&cache)))
        .collect();
    planner::merge_bench_plan("BENCH_plan.json", "fig7_cddnn", rows).unwrap();
    println!("\nwrote BENCH_plan.json (fig7_cddnn)");
}
