//! Fig 7 — CD-DNN (429 -> 7x2048 -> 9304 senones) scaling on (simulated)
//! Endeavor FDR cluster, MB=1024 frames. Paper: 4600 f/s on one node,
//! ~13K @4 nodes, 29.5K @16 (6.4x). The FC-dominated DNN is the hardest
//! scaling case (highest comm-to-compute) — hybrid parallelism is what
//! keeps it scaling at all (ablation below).

use std::time::Duration;

use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::metrics::Table;
use pcl_dnn::models::zoo;
use pcl_dnn::netsim::cluster::{
    scaling_curve, simulate_training, simulate_training_fleet, SimConfig,
};
use pcl_dnn::netsim::FleetConfig;
use pcl_dnn::util::bench::{bench, black_box, header};

fn main() {
    println!("=== fig7_cddnn_scaling ===");
    let p = Platform::endeavor();
    let net = zoo::cddnn_full();
    header();
    bench("simulate_training(cddnn, 16 nodes)", Duration::from_millis(400), || {
        black_box(simulate_training(
            &net,
            &p,
            &SimConfig { nodes: 16, minibatch: 1024, ..Default::default() },
        ));
    })
    .report();

    let nodes = [1u64, 2, 4, 8, 16];
    println!("\n# CD-DNN on Endeavor, MB=1024 (hybrid FCs)");
    let hybrid = scaling_curve(&net, &p, 1024, &nodes, true);
    let data = scaling_curve(&net, &p, 1024, &nodes, false);
    let mut t = Table::new(&["nodes", "hybrid f/s", "speedup", "pure-data f/s", "speedup"]);
    for (h, d) in hybrid.iter().zip(&data) {
        t.row(vec![
            h.nodes.to_string(),
            format!("{:.0}", h.images_per_s),
            format!("{:.1}x", h.speedup),
            format!("{:.0}", d.images_per_s),
            format!("{:.1}x", d.speedup),
        ]);
    }
    t.print();
    println!("\n(paper's shape: DNN scales far worse than the CNNs; hybrid > pure data parallel)");

    // full-cluster: straggler + heterogeneous-fleet sensitivity of the
    // comm-bound ASR workload
    println!("\n# full-cluster: CD-DNN x16, straggler skew and hetero generations");
    let cfg = SimConfig { nodes: 16, minibatch: 1024, ..Default::default() };
    bench("simulate_training_fleet(cddnn, 16 nodes)", Duration::from_millis(800), || {
        black_box(simulate_training_fleet(
            &net,
            &p,
            &cfg,
            &FleetConfig { nodes: 16, ..Default::default() },
        ));
    })
    .report();
    let base = simulate_training_fleet(&net, &p, &cfg, &FleetConfig { nodes: 16, ..Default::default() });
    let mut t = Table::new(&["fleet", "iter ms", "f/s", "vs homogeneous"]);
    t.row(vec![
        "homogeneous".into(),
        format!("{:.1}", base.iteration_s * 1e3),
        format!("{:.0}", base.images_per_s),
        "1.00x".into(),
    ]);
    for (label, skew, hetero) in [
        ("skew 0.25", 0.25, false),
        ("skew 0.50", 0.50, false),
        ("hetero (odd nodes 1.3x)", 0.0, true),
        ("hetero + skew 0.25", 0.25, true),
    ] {
        let r = simulate_training_fleet(
            &net,
            &p,
            &cfg,
            &FleetConfig { nodes: 16, straggler_skew: skew, hetero, ..Default::default() },
        );
        t.row(vec![
            label.into(),
            format!("{:.1}", r.iteration_s * 1e3),
            format!("{:.0}", r.images_per_s),
            format!("{:.2}x", r.iteration_s / base.iteration_s),
        ]);
    }
    t.print();
}
