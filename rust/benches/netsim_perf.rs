//! Netsim engine perf harness — the cross-PR trajectory for the
//! discrete-event fast path. Emits `BENCH_netsim_perf.json`:
//!
//! * fig4 fleet DAGs at n ∈ {32, 64, 128}: build wall-ms, run wall-ms,
//!   tasks simulated/sec for the indexed engine AND for the retained
//!   reference scheduler on the *same* built DAG (so the speedup column
//!   is apples-to-apples within one run);
//! * a netsim node sweep evaluated serially vs in parallel
//!   (`run_sweep_serial` vs `run_sweep`), wall-ms each;
//! * steady-state template rows: full simulation vs the periodic fast
//!   path end to end at fig4@64 x iterations ∈ {4, 16, 64} and
//!   auto@{32, 64, 128} x 16, with per-row bit-identity asserted.
//!
//! The fast path must stay bit-identical to the reference (asserted here
//! on the n=32 DAG as a smoke check; `tests/engine_oracle.rs` is the
//! real property suite), so this file is pure measurement.

use std::collections::BTreeMap;
use std::time::Instant;

use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::experiment::{run_sweep, run_sweep_serial, ExperimentSpec, FleetSimBackend};
use pcl_dnn::models::zoo;
use pcl_dnn::netsim::cluster::{
    build_training_fleet, build_training_fleet_full, simulate_training_fleet, summarize_fleet,
    SimConfig,
};
use pcl_dnn::netsim::{collective, reference, FleetConfig, SimPath};
use pcl_dnn::plan::PartitionPlan;
use pcl_dnn::util::json::Json;

fn ms(d: std::time::Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

fn main() {
    println!("=== netsim_perf ===");
    // clean fabric: same setting the fleet-vs-analytic validations use
    let mut platform = Platform::cori();
    platform.fabric.congestion_per_doubling = 0.0;
    let net = zoo::vgg_a();

    let mut fig4_rows: Vec<Json> = Vec::new();
    // auto (butterfly-dominated, ~100k tasks) at each size, plus the
    // ring-pinned ablation of the 128-node point — the O(N^2)-message
    // DAG (>1M tasks) where the reference full-scan is at its worst
    let points: &[(u64, collective::Choice)] = &[
        (32, collective::Choice::Auto),
        (64, collective::Choice::Auto),
        (128, collective::Choice::Auto),
        (128, collective::Choice::Ring),
    ];
    for &(nodes, choice) in points {
        let cfg = SimConfig {
            nodes,
            minibatch: 512,
            iterations: 3,
            plan: PartitionPlan::paper_recipe(&net, nodes, 512, 1.0),
            collective: choice,
            degraded_plan: None,
            ..Default::default()
        };
        let fleet = FleetConfig::homogeneous(nodes as usize);

        let t0 = Instant::now();
        let dag = build_training_fleet(&net, &platform, &cfg, &fleet).unwrap();
        let build = t0.elapsed();
        let tasks = dag.eng.len();

        let t0 = Instant::now();
        let fast = dag.eng.run();
        let run = t0.elapsed();

        let t0 = Instant::now();
        let oracle = reference::run(&dag.eng);
        let ref_run = t0.elapsed();
        assert_eq!(fast, oracle, "fig4@{nodes}: fast path diverged from reference");

        let tasks_per_s = tasks as f64 / run.as_secs_f64().max(1e-9);
        let ref_tasks_per_s = tasks as f64 / ref_run.as_secs_f64().max(1e-9);
        let tag = match choice {
            collective::Choice::Ring => "ring",
            collective::Choice::Butterfly => "butterfly",
            collective::Choice::Auto => "auto",
        };
        println!(
            "fig4@{nodes:>3} ({tag:>4}): {tasks:>8} tasks | build {:>8.2} ms | run {:>8.2} ms \
             ({:.2}M tasks/s) | reference {:>9.2} ms ({:.2}M tasks/s) | speedup {:.1}x",
            ms(build),
            ms(run),
            tasks_per_s / 1e6,
            ms(ref_run),
            ref_tasks_per_s / 1e6,
            tasks_per_s / ref_tasks_per_s
        );
        let mut row = BTreeMap::new();
        row.insert("build_ms".to_string(), Json::Num(ms(build)));
        row.insert("collective".to_string(), Json::Str(tag.to_string()));
        row.insert("nodes".to_string(), Json::Num(nodes as f64));
        row.insert("ref_run_ms".to_string(), Json::Num(ms(ref_run)));
        row.insert("ref_tasks_per_s".to_string(), Json::Num(ref_tasks_per_s));
        row.insert("run_ms".to_string(), Json::Num(ms(run)));
        row.insert(
            "speedup_vs_reference".to_string(),
            Json::Num(tasks_per_s / ref_tasks_per_s),
        );
        row.insert("tasks".to_string(), Json::Num(tasks as f64));
        row.insert("tasks_per_s".to_string(), Json::Num(tasks_per_s));
        fig4_rows.push(Json::Obj(row));
    }

    // steady-state template fast path vs full simulation, end to end:
    // the full column is the pre-template cost (legacy loop build +
    // event-by-event run over every iteration), the fast column is the
    // routed entry point (template build + 4-iteration periodic probe +
    // closed-form extrapolation). Each row asserts bit-identical results
    // before timing is trusted — this doubles as the CI divergence gate
    // for the fig4@32 smoke point. iterations=4 is below the probe
    // window, so that row legitimately routes full (speedup ~1x); the
    // 16 and 64 rows show wall-clock growing sublinearly in iterations.
    let mut template_rows: Vec<Json> = Vec::new();
    let template_points: &[(u64, usize)] =
        &[(64, 4), (64, 16), (64, 64), (32, 16), (128, 16)];
    for &(nodes, iterations) in template_points {
        let cfg = SimConfig {
            nodes,
            minibatch: 512,
            iterations,
            plan: PartitionPlan::paper_recipe(&net, nodes, 512, 1.0),
            collective: collective::Choice::Auto,
            degraded_plan: None,
            ..Default::default()
        };
        let fleet = FleetConfig::homogeneous(nodes as usize);

        let t0 = Instant::now();
        let dag = build_training_fleet_full(&net, &platform, &cfg, &fleet).unwrap();
        let sched = dag.eng.run();
        let full_r = summarize_fleet(&dag, &sched);
        let full_ms = ms(t0.elapsed());

        let t0 = Instant::now();
        let fast_r = simulate_training_fleet(&net, &platform, &cfg, &fleet).unwrap();
        let fast_ms = ms(t0.elapsed());

        // CI runs this bench with REPRO_NETSIM_PATH=full as the
        // template-off ablation; the routing assert only applies when
        // the knob leaves the router free to choose
        let forced_full =
            matches!(std::env::var("REPRO_NETSIM_PATH"), Ok(ref v) if v == "full");
        if iterations > 4 && !forced_full {
            assert_eq!(
                fast_r.sim_path,
                SimPath::Periodic,
                "fig4@{nodes} x{iterations}: clean fabric must route periodic"
            );
        }
        let mut fast_norm = fast_r.clone();
        fast_norm.sim_path = full_r.sim_path;
        fast_norm.warmup_tasks = full_r.warmup_tasks;
        assert_eq!(
            fast_norm, full_r,
            "fig4@{nodes} x{iterations}: fast path diverged from full simulation"
        );

        let speedup = full_ms / fast_ms.max(1e-9);
        println!(
            "template fig4@{nodes:>3} x{iterations:>2} it ({}): full {full_ms:>8.2} ms | \
             fast {fast_ms:>8.2} ms | speedup {speedup:.1}x | {} tasks",
            fast_r.sim_path.name(),
            fast_r.tasks
        );
        let mut row = BTreeMap::new();
        row.insert("fast_ms".to_string(), Json::Num(fast_ms));
        row.insert("full_ms".to_string(), Json::Num(full_ms));
        row.insert("iterations".to_string(), Json::Num(iterations as f64));
        row.insert("nodes".to_string(), Json::Num(nodes as f64));
        row.insert(
            "sim_path".to_string(),
            Json::Str(fast_r.sim_path.name().to_string()),
        );
        row.insert("speedup".to_string(), Json::Num(speedup));
        row.insert("tasks".to_string(), Json::Num(fast_r.tasks as f64));
        template_rows.push(Json::Obj(row));
    }

    // sweep parallelism: same spec list through the serial and the
    // scoped-thread paths (results are bit-identical; only wall differs)
    let sweep_nodes: Vec<u64> = vec![2, 4, 8, 16, 32];
    let mut spec = ExperimentSpec::of("netsim_perf_sweep", "vgg_a", "cori", 2, 256);
    spec.cluster.congestion = Some(0.0);
    spec.parallelism.iterations = 3;

    let t0 = Instant::now();
    let serial = run_sweep_serial(&FleetSimBackend, &spec, &sweep_nodes).unwrap();
    let serial_ms = ms(t0.elapsed());
    let t0 = Instant::now();
    let parallel = run_sweep(&FleetSimBackend, &spec, &sweep_nodes).unwrap();
    let parallel_ms = ms(t0.elapsed());
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "parallel sweep must be bit-identical to serial"
        );
    }
    println!(
        "sweep x{:?}: serial {serial_ms:.1} ms | parallel {parallel_ms:.1} ms ({:.2}x)",
        sweep_nodes,
        serial_ms / parallel_ms.max(1e-9)
    );

    let mut sweep = BTreeMap::new();
    sweep.insert(
        "nodes".to_string(),
        Json::Arr(sweep_nodes.iter().map(|&n| Json::Num(n as f64)).collect()),
    );
    sweep.insert("parallel_ms".to_string(), Json::Num(parallel_ms));
    sweep.insert("serial_ms".to_string(), Json::Num(serial_ms));
    sweep.insert(
        "speedup".to_string(),
        Json::Num(serial_ms / parallel_ms.max(1e-9)),
    );

    let mut root = BTreeMap::new();
    root.insert("fig4".to_string(), Json::Arr(fig4_rows));
    root.insert("sweep".to_string(), Json::Obj(sweep));
    root.insert("template".to_string(), Json::Arr(template_rows));
    std::fs::write(
        "BENCH_netsim_perf.json",
        format!("{}\n", Json::Obj(root).pretty()),
    )
    .unwrap();
    println!("\nwrote BENCH_netsim_perf.json");
}
