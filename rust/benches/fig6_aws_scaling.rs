//! Fig 6 — OverFeat + VGG-A scaling on (simulated) AWS EC2 10GbE with
//! SR-IOV, MB=256. Paper @16 nodes: OverFeat 1027 img/s (11.9x), VGG-A
//! 397 img/s (14.2x); VGG scales better thanks to higher flops/byte.

use std::time::Duration;

use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::metrics::Table;
use pcl_dnn::models::zoo;
use pcl_dnn::netsim::cluster::{scaling_curve, simulate_training, SimConfig};
use pcl_dnn::util::bench::{bench, black_box, header};

fn main() {
    println!("=== fig6_aws_scaling ===");
    let p = Platform::aws();
    header();
    bench("simulate_training(overfeat, 16 aws nodes)", Duration::from_millis(400), || {
        black_box(simulate_training(
            &zoo::overfeat_fast(),
            &p,
            &SimConfig { nodes: 16, minibatch: 256, ..Default::default() },
        ));
    })
    .report();

    let nodes = [1u64, 2, 4, 8, 16];
    for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
        println!("\n# {} on AWS, MB=256", net.name);
        let curve = scaling_curve(&net, &p, 256, &nodes, true);
        let mut t = Table::new(&["nodes", "img/s", "speedup"]);
        for pt in &curve {
            t.row(vec![
                pt.nodes.to_string(),
                format!("{:.0}", pt.images_per_s),
                format!("{:.1}x", pt.speedup),
            ]);
        }
        t.print();
    }
    let of = scaling_curve(&zoo::overfeat_fast(), &p, 256, &[16], true)[0].speedup;
    let vg = scaling_curve(&zoo::vgg_a(), &p, 256, &[16], true)[0].speedup;
    println!("\n@16 nodes: OverFeat {of:.1}x vs VGG-A {vg:.1}x — VGG wins, as in the paper");
}
