//! Fig 6 — OverFeat + VGG-A scaling on (simulated) AWS EC2 10GbE with
//! SR-IOV, MB=256. Paper @16 nodes: OverFeat 1027 img/s (11.9x), VGG-A
//! 397 img/s (14.2x); VGG scales better thanks to higher flops/byte.

use std::time::Duration;

use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::metrics::Table;
use pcl_dnn::models::zoo;
use pcl_dnn::netsim::cluster::{
    scaling_curve, simulate_training, simulate_training_fleet, SimConfig,
};
use pcl_dnn::netsim::{FleetConfig, Topology};
use pcl_dnn::util::bench::{bench, black_box, header};

fn main() {
    println!("=== fig6_aws_scaling ===");
    let p = Platform::aws();
    header();
    bench("simulate_training(overfeat, 16 aws nodes)", Duration::from_millis(400), || {
        black_box(simulate_training(
            &zoo::overfeat_fast(),
            &p,
            &SimConfig { nodes: 16, minibatch: 256, ..Default::default() },
        ));
    })
    .report();

    let nodes = [1u64, 2, 4, 8, 16];
    for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
        println!("\n# {} on AWS, MB=256", net.name);
        let curve = scaling_curve(&net, &p, 256, &nodes, true);
        let mut t = Table::new(&["nodes", "img/s", "speedup"]);
        for pt in &curve {
            t.row(vec![
                pt.nodes.to_string(),
                format!("{:.0}", pt.images_per_s),
                format!("{:.1}x", pt.speedup),
            ]);
        }
        t.print();
    }
    let of = scaling_curve(&zoo::overfeat_fast(), &p, 256, &[16], true)[0].speedup;
    let vg = scaling_curve(&zoo::vgg_a(), &p, 256, &[16], true)[0].speedup;
    println!("\n@16 nodes: OverFeat {of:.1}x vs VGG-A {vg:.1}x — VGG wins, as in the paper");

    // full-cluster: oversubscribed Ethernet contention (what §6's cloud
    // results hide inside their efficiency numbers)
    println!("\n# full-cluster: OverFeat x16, flat switch vs oversubscribed fat-tree core");
    let cfg = SimConfig { nodes: 16, minibatch: 256, ..Default::default() };
    bench("simulate_training_fleet(overfeat, 16 aws nodes)", Duration::from_millis(800), || {
        black_box(simulate_training_fleet(
            &zoo::overfeat_fast(),
            &p,
            &cfg,
            &FleetConfig { nodes: 16, ..Default::default() },
        ));
    })
    .report();
    let flat = simulate_training_fleet(
        &zoo::overfeat_fast(),
        &p,
        &cfg,
        &FleetConfig { nodes: 16, topology: Topology::FlatSwitch, ..Default::default() },
    );
    let mut t = Table::new(&["core", "iter ms", "img/s", "vs flat"]);
    t.row(vec![
        "flat switch".into(),
        format!("{:.1}", flat.iteration_s * 1e3),
        format!("{:.0}", flat.images_per_s),
        "1.00x".into(),
    ]);
    for oversub in [2.0, 4.0, 8.0] {
        let r = simulate_training_fleet(
            &zoo::overfeat_fast(),
            &p,
            &cfg,
            &FleetConfig {
                nodes: 16,
                topology: Topology::FatTree { radix: 8, oversub },
                ..Default::default()
            },
        );
        t.row(vec![
            format!("fat-tree {oversub}:1"),
            format!("{:.1}", r.iteration_s * 1e3),
            format!("{:.0}", r.images_per_s),
            format!("{:.2}x", r.iteration_s / flat.iteration_s),
        ]);
    }
    t.print();
}
