//! Fig 6 — OverFeat + VGG-A scaling on (simulated) AWS EC2 10GbE with
//! SR-IOV, MB=256, through the spec-driven experiment API. Paper @16
//! nodes: OverFeat 1027 img/s (11.9x), VGG-A 397 img/s (14.2x); VGG
//! scales better thanks to higher flops/byte.

use std::time::Duration;

use pcl_dnn::experiment::{
    curve_table, registry, run_sweep, AnalyticBackend, Backend, ExperimentSpec, FleetSimBackend,
};
use pcl_dnn::metrics::Table;
use pcl_dnn::netsim::collective::Choice;
use pcl_dnn::plan::{planner, PlanCache};
use pcl_dnn::util::bench::{bench, black_box, header};

fn main() {
    println!("=== fig6_aws_scaling ===");
    let overfeat = ExperimentSpec::fig6_overfeat();
    let vgg = ExperimentSpec::fig6_vgg();

    header();
    bench("AnalyticBackend::run(fig6_overfeat, 16 nodes)", Duration::from_millis(400), || {
        black_box(AnalyticBackend.run(&overfeat).unwrap());
    })
    .report();

    let nodes = [1u64, 2, 4, 8, 16];
    let mut at16 = Vec::new();
    for spec in [&overfeat, &vgg] {
        println!("\n# {} on AWS, MB=256", spec.model.name());
        let curve = run_sweep(&AnalyticBackend, spec, &nodes).unwrap();
        at16.push(curve.last().unwrap().speedup.unwrap_or(f64::NAN));
        curve_table(&curve).print();
    }
    println!(
        "\n@16 nodes: OverFeat {:.1}x vs VGG-A {:.1}x — VGG wins, as in the paper",
        at16[0], at16[1]
    );

    // full-cluster: oversubscribed Ethernet contention (what §6's cloud
    // results hide inside their efficiency numbers) — same spec, netsim
    // backend, topology overridden point-wise
    println!("\n# netsim backend: OverFeat x16, flat switch vs oversubscribed fat-tree core");
    bench("FleetSimBackend::run(fig6_overfeat, 16 nodes)", Duration::from_millis(800), || {
        black_box(FleetSimBackend.run(&overfeat).unwrap());
    })
    .report();
    let mut flat_spec = overfeat.clone();
    flat_spec.cluster.topology = "flat".into();
    let flat = FleetSimBackend.run(&flat_spec).unwrap();
    let mut t = Table::new(&["core", "iter ms", "img/s", "vs flat"]);
    t.row(vec![
        "flat switch".into(),
        format!("{:.1}", flat.iteration_s * 1e3),
        format!("{:.0}", flat.samples_per_s),
        "1.00x".into(),
    ]);
    for oversub in [2.0, 4.0, 8.0] {
        let mut s = overfeat.clone();
        s.cluster.topology = "fattree".into();
        s.cluster.radix = 8;
        s.cluster.oversub = oversub;
        let r = FleetSimBackend.run(&s).unwrap();
        t.row(vec![
            format!("fat-tree {oversub}:1"),
            format!("{:.1}", r.iteration_s * 1e3),
            format!("{:.0}", r.samples_per_s),
            format!("{:.2}x", r.iteration_s / flat.iteration_s),
        ]);
    }
    t.print();

    // cross-PR bench trajectory: planner vs fixed recipe vs pure data
    let cache = PlanCache::new(PlanCache::default_dir());
    let platform = registry::platform("aws").unwrap();
    for (key, model) in [("fig6_overfeat", "overfeat_fast"), ("fig6_vgg", "vgg_a")] {
        let net = registry::model(model).unwrap();
        let rows = [2u64, 4, 8, 16]
            .iter()
            .map(|&n| planner::bench_row(&net, &platform, 256, n, Choice::Auto, 3, Some(&cache)))
            .collect();
        planner::merge_bench_plan("BENCH_plan.json", key, rows).unwrap();
    }
    println!("\nwrote BENCH_plan.json (fig6_overfeat + fig6_vgg)");
}
