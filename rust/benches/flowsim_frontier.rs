//! Flow-level scaling frontier — the sweep only the flowsim tier can
//! afford. Emits `BENCH_flowsim_frontier.json`:
//!
//! * every full-size paper network (VGG-A/Cori, OverFeat-FAST/AWS,
//!   CD-DNN/Endeavor) at n ∈ {256, 512, 1024, 4096} — past the edge of
//!   the paper's own measurements (Fig 4 stops at 128) and past what
//!   per-message netsim can expand at all (its per-node minibatch floor
//!   stops at n = MB);
//! * per point: steady-state iteration ms, samples/s, efficiency vs the
//!   1-node baseline, flow-graph size, and build+run wall-ms — the
//!   "seconds, not minutes" claim is a measured column, not prose.
//!
//! Efficiency sanity is asserted loosely here (monotone non-increasing
//! within each model's sweep, within [0, 1.01]); the tight ≤5% pin
//! against netsim lives in `tests/fleet_sim.rs` where netsim can run.

use std::collections::BTreeMap;
use std::time::Instant;

use pcl_dnn::experiment::{Backend, ExperimentSpec, FlowSimBackend};
use pcl_dnn::util::json::Json;

fn main() {
    println!("=== flowsim_frontier ===");
    // clean fabric: the setting under which the tier is validated
    // against analytic/netsim, so frontier numbers stay comparable
    let models: &[(&str, &str, u64)] = &[
        ("vgg_a", "cori", 512),
        ("overfeat_fast", "aws", 256),
        ("cddnn_full", "endeavor", 1024),
    ];
    let node_counts: &[u64] = &[256, 512, 1024, 4096];

    let mut rows: Vec<Json> = Vec::new();
    for &(model, platform, mb) in models {
        let mut prev_eff = f64::INFINITY;
        for &nodes in node_counts {
            let mut spec = ExperimentSpec::of(
                &format!("frontier_{model}_{nodes}"),
                model,
                platform,
                nodes,
                mb,
            );
            spec.cluster.congestion = Some(0.0);
            spec.parallelism.iterations = 3;

            let t0 = Instant::now();
            let rep = FlowSimBackend.run(&spec).unwrap();
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

            let eff = rep.efficiency.unwrap();
            assert!(
                eff > 0.0 && eff <= 1.01,
                "{model}@{nodes}: efficiency {eff} out of range"
            );
            // loose: plan shapes shift between node counts, so allow
            // small local wobble while catching gross inversions
            assert!(
                eff <= prev_eff * 1.05,
                "{model}@{nodes}: efficiency {eff} rose above {prev_eff} as nodes grew"
            );
            prev_eff = eff;

            println!(
                "{model:>13}@{nodes:>4}: iter {:>9.3} ms | {:>10.0} samples/s | \
                 eff {:>5.1}% | {:>8} flows | wall {:>8.1} ms",
                rep.iteration_s * 1e3,
                rep.samples_per_s,
                100.0 * eff,
                rep.tasks,
                wall_ms
            );
            let mut row = BTreeMap::new();
            row.insert("efficiency".to_string(), Json::Num(eff));
            row.insert("iteration_s".to_string(), Json::Num(rep.iteration_s));
            row.insert("model".to_string(), Json::Str(model.to_string()));
            row.insert("nodes".to_string(), Json::Num(nodes as f64));
            row.insert("platform".to_string(), Json::Str(platform.to_string()));
            row.insert("samples_per_s".to_string(), Json::Num(rep.samples_per_s));
            row.insert("tasks".to_string(), Json::Num(rep.tasks as f64));
            row.insert("wall_ms".to_string(), Json::Num(wall_ms));
            rows.push(Json::Obj(row));
        }
    }

    let mut root = BTreeMap::new();
    root.insert("frontier".to_string(), Json::Arr(rows));
    std::fs::write(
        "BENCH_flowsim_frontier.json",
        format!("{}\n", Json::Obj(root).pretty()),
    )
    .unwrap();
    println!("\nwrote BENCH_flowsim_frontier.json");
}
