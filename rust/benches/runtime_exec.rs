//! PJRT runtime microbenchmarks: artifact execute latency, host<->literal
//! conversion overhead, end-to-end coordinator step latency. These are the
//! L3 hot-path numbers the §Perf pass optimizes.

use std::time::Duration;

use pcl_dnn::coordinator::{MicrobatchPlan, SgdConfig, SyncSgdCoordinator};
use pcl_dnn::data::ImageDataset;
use pcl_dnn::runtime::{HostTensor, Runtime};
use pcl_dnn::util::bench::{bench, black_box, header};
use pcl_dnn::util::rng::Rng;

fn main() {
    println!("=== runtime_exec ===");
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("(artifacts not built; skipping)");
        return;
    }
    let mut rt = Runtime::new("artifacts").expect("runtime");
    header();

    // literal conversion overhead
    let mut rng = Rng::new(0);
    let mut big = vec![0.0f32; 1 << 20];
    rng.fill_normal(&mut big, 1.0);
    let t = HostTensor::f32(vec![1 << 20], big);
    bench("to_literal 4MB f32", Duration::from_millis(200), || {
        black_box(t.to_literal().unwrap());
    })
    .report();
    let lit = t.to_literal().unwrap();
    bench("from_literal 4MB f32", Duration::from_millis(200), || {
        black_box(HostTensor::from_literal(&lit).unwrap());
    })
    .report();

    // artifact execute latency (small kernels)
    let x = HostTensor::f32(vec![256, 512], vec![0.5; 256 * 512]);
    let w = HostTensor::f32(vec![512, 256], vec![0.25; 512 * 256]);
    for name in ["matmul_native", "matmul_pallas"] {
        rt.execute(name, &[x.clone(), w.clone()]).unwrap(); // compile+warm
        let mut rt_ref = &mut rt;
        bench(&format!("execute {name} 256x512x256"), Duration::from_millis(300), || {
            black_box(rt_ref.execute(name, &[x.clone(), w.clone()]).unwrap());
        })
        .report();
    }

    // train-step execute (vgg_tiny micro-batch)
    let params = rt.manifest().load_params("vgg_tiny").unwrap();
    let spec = rt.manifest().artifact("vgg_tiny_train").unwrap().clone();
    let b = spec.batch;
    let ds = ImageDataset::new(32, 3, 10, 0);
    let batch = ds.batch(0, b);
    let data = vec![
        HostTensor::f32(vec![b, 32, 32, 3], batch.images),
        HostTensor::i32(vec![b], batch.labels),
    ];
    rt.execute_with_params("vgg_tiny_train", &params, &data).unwrap();
    {
        let rt_ref = &mut rt;
        bench("execute vgg_tiny_train (micro=4)", Duration::from_millis(500), || {
            black_box(rt_ref.execute_with_params("vgg_tiny_train", &params, &data).unwrap());
        })
        .report();
    }

    // full coordinator step (compute + queue + reduce + sgd)
    let plan = MicrobatchPlan::new(16, 2, b).unwrap();
    let mut coord = SyncSgdCoordinator::new(
        "vgg_tiny_train",
        params.clone(),
        plan,
        SgdConfig::default(),
    );
    let data2 = data.clone();
    {
        let rt_ref = &mut rt;
        bench("coordinator step (2 workers, MB=16)", Duration::from_millis(800), || {
            black_box(coord.step(rt_ref, &mut |_, _, _| data2.clone()).unwrap());
        })
        .report();
    }
    println!("\nmean PJRT execute latency since start: {:.2} ms", rt.mean_exec_ms());
}
