//! PJRT runtime microbenchmarks + the streaming-overlap ablation
//! (ISSUE 8): end-to-end coordinator step latency with the overlapped
//! exchange on vs off (`REPRO_RUNTIME_OVERLAP=off` path), across model
//! families and worker counts. The ablation drives `step_with_compute`
//! with synthetic gradients shaped by the real model descriptors, so it
//! runs everywhere — PJRT sections below stay gated on built artifacts.
//!
//! Emits `BENCH_runtime_perf.json` (always): per-row step latency,
//! samples/s, comm_wait/overlap/busy breakdown, keyed by model x
//! workers x overlap. CI's `runtime-perf` job uploads it.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use pcl_dnn::coordinator::{MicrobatchPlan, SgdConfig, StepStats, SyncSgdCoordinator};
use pcl_dnn::data::ImageDataset;
use pcl_dnn::models::zoo;
use pcl_dnn::runtime::{HostTensor, Runtime};
use pcl_dnn::util::bench::{bench, black_box, header};
use pcl_dnn::util::json::Json;
use pcl_dnn::util::rng::Rng;

const WARMUP_STEPS: usize = 2;
const MEASURED_STEPS: usize = 6;

/// Mean step wall time + per-step mean stats over MEASURED_STEPS
/// synthetic steps.
fn run_synthetic(shapes: &[usize], workers: usize, overlap: bool) -> (f64, StepStats) {
    let params: Vec<Vec<f32>> = shapes.iter().map(|&n| vec![0.01f32; n]).collect();
    let plan = MicrobatchPlan::new(workers * 4, workers, 2).unwrap();
    let mut coord = SyncSgdCoordinator::new("synthetic", params, plan, SgdConfig::default());
    coord.set_overlap(overlap);
    // Per-worker compute: RNG-fill every gradient tensor. Deterministic,
    // artifact-free, and heavy enough (transcendentals per element) that
    // the comm thread's folds can hide underneath it.
    let mut compute =
        |w: usize, starts: &[usize], acc: &mut [Vec<f32>]| -> anyhow::Result<(f64, u64)> {
            let mut rng = Rng::new((w as u64) * 7919 + 1);
            for buf in acc.iter_mut() {
                rng.fill_normal(buf, 0.1);
            }
            Ok((0.5, starts.len() as u64))
        };
    let mut step_s = 0.0f64;
    let mut agg = StepStats::default();
    for i in 0..WARMUP_STEPS + MEASURED_STEPS {
        let t0 = Instant::now();
        let stats = coord.step_with_compute(&mut compute).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        if i < WARMUP_STEPS {
            continue;
        }
        step_s += dt;
        agg.compute_s += stats.compute_s;
        agg.comm_wait_s += stats.comm_wait_s;
        agg.comm_busy_s += stats.comm_busy_s;
        agg.overlap_s += stats.overlap_s;
        agg.update_s += stats.update_s;
    }
    let n = MEASURED_STEPS as f64;
    agg.compute_s /= n;
    agg.comm_wait_s /= n;
    agg.comm_busy_s /= n;
    agg.overlap_s /= n;
    agg.update_s /= n;
    (step_s / n, agg)
}

fn ablation_row(
    model: &str,
    mode: &str,
    workers: usize,
    overlap: bool,
    step_s: f64,
    s: &StepStats,
) -> Json {
    let mut row = BTreeMap::new();
    row.insert("model".to_string(), Json::Str(model.to_string()));
    row.insert("mode".to_string(), Json::Str(mode.to_string()));
    row.insert("workers".to_string(), Json::Num(workers as f64));
    row.insert("overlap".to_string(), Json::Bool(overlap));
    row.insert("step_ms".to_string(), Json::Num(step_s * 1e3));
    row.insert("samples_per_s".to_string(), Json::Num(workers as f64 * 4.0 / step_s));
    row.insert("compute_ms".to_string(), Json::Num(s.compute_s * 1e3));
    row.insert("comm_wait_ms".to_string(), Json::Num(s.comm_wait_s * 1e3));
    row.insert("comm_busy_ms".to_string(), Json::Num(s.comm_busy_s * 1e3));
    row.insert("overlap_ms".to_string(), Json::Num(s.overlap_s * 1e3));
    row.insert("update_ms".to_string(), Json::Num(s.update_s * 1e3));
    Json::Obj(row)
}

/// The overlap on/off ablation over model families x worker counts.
/// Checks the ISSUE 8 acceptance bar: comm_wait strictly lower with
/// overlap on at workers >= 4 (retried once to ride out scheduler
/// noise on shared CI runners).
fn synthetic_ablation(rows: &mut Vec<Json>) {
    println!("\n--- streaming-overlap ablation (synthetic compute) ---");
    let families: Vec<(String, Vec<usize>)> = [
        zoo::vgg_tiny(),
        zoo::cddnn_tiny(),
        zoo::gpt_descriptor("gpt_micro", 128, 2, 256),
    ]
    .into_iter()
    .map(|net| {
        let shapes: Vec<usize> = net
            .layers
            .iter()
            .filter(|l| l.is_weighted())
            .map(|l| l.weight_elems() as usize)
            .collect();
        (net.name.clone(), shapes)
    })
    .collect();
    for (model, shapes) in &families {
        for workers in [2usize, 4, 8] {
            let mut on = run_synthetic(shapes, workers, true);
            let mut off = run_synthetic(shapes, workers, false);
            if workers >= 4 && on.1.comm_wait_s >= off.1.comm_wait_s {
                on = run_synthetic(shapes, workers, true);
                off = run_synthetic(shapes, workers, false);
            }
            let (on_step, on) = on;
            let (off_step, off) = off;
            println!(
                "  {model:>10} x{workers}: step {:>7.3} -> {:>7.3} ms | wait {:>7.3} -> {:>7.3} ms | overlap {:>6.3} ms",
                off_step * 1e3,
                on_step * 1e3,
                off.comm_wait_s * 1e3,
                on.comm_wait_s * 1e3,
                on.overlap_s * 1e3,
            );
            if workers >= 4 {
                assert!(
                    on.comm_wait_s < off.comm_wait_s,
                    "{model} x{workers}: overlap-on comm_wait {:.6}s not below off {:.6}s",
                    on.comm_wait_s,
                    off.comm_wait_s
                );
            }
            rows.push(ablation_row(model, "synthetic", workers, true, on_step, &on));
            rows.push(ablation_row(model, "synthetic", workers, false, off_step, &off));
        }
    }
}

/// PJRT microbenches + a real-artifact overlap ablation (gated on built
/// artifacts — the container CI runs synthetic-only).
fn pjrt_benches(rows: &mut Vec<Json>) {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("(artifacts not built; skipping PJRT sections)");
        return;
    }
    let mut rt = Runtime::new("artifacts").expect("runtime");
    header();

    // literal conversion overhead
    let mut rng = Rng::new(0);
    let mut big = vec![0.0f32; 1 << 20];
    rng.fill_normal(&mut big, 1.0);
    let t = HostTensor::f32(vec![1 << 20], big);
    bench("to_literal 4MB f32", Duration::from_millis(200), || {
        black_box(t.to_literal().unwrap());
    })
    .report();
    let lit = t.to_literal().unwrap();
    bench("from_literal 4MB f32", Duration::from_millis(200), || {
        black_box(HostTensor::from_literal(&lit).unwrap());
    })
    .report();

    // artifact execute latency (small kernels)
    let x = HostTensor::f32(vec![256, 512], vec![0.5; 256 * 512]);
    let w = HostTensor::f32(vec![512, 256], vec![0.25; 512 * 256]);
    for name in ["matmul_native", "matmul_pallas"] {
        rt.execute(name, &[x.clone(), w.clone()]).unwrap(); // compile+warm
        let rt_ref = &mut rt;
        bench(&format!("execute {name} 256x512x256"), Duration::from_millis(300), || {
            black_box(rt_ref.execute(name, &[x.clone(), w.clone()]).unwrap());
        })
        .report();
    }

    // train-step execute (vgg_tiny micro-batch)
    let params = rt.manifest().load_params("vgg_tiny").unwrap();
    let spec = rt.manifest().artifact("vgg_tiny_train").unwrap().clone();
    let b = spec.batch;
    let ds = ImageDataset::new(32, 3, 10, 0);
    let batch = ds.batch(0, b);
    let data = vec![
        HostTensor::f32(vec![b, 32, 32, 3], batch.images),
        HostTensor::i32(vec![b], batch.labels),
    ];
    rt.execute_with_params("vgg_tiny_train", &params, &data).unwrap();
    {
        let rt_ref = &mut rt;
        bench("execute vgg_tiny_train (micro=4)", Duration::from_millis(500), || {
            black_box(rt_ref.execute_with_params("vgg_tiny_train", &params, &data).unwrap());
        })
        .report();
    }

    // full coordinator step ablation (compute + queue + reduce + sgd)
    println!("\n--- coordinator step ablation (PJRT compute) ---");
    for overlap in [true, false] {
        let plan = MicrobatchPlan::new(16, 2, b).unwrap();
        let mut coord =
            SyncSgdCoordinator::new("vgg_tiny_train", params.clone(), plan, SgdConfig::default());
        coord.set_overlap(overlap);
        let data2 = data.clone();
        let rt_ref = &mut rt;
        let mut step_s = 0.0f64;
        let mut agg = StepStats::default();
        for i in 0..WARMUP_STEPS + MEASURED_STEPS {
            let t0 = Instant::now();
            let stats = coord.step(rt_ref, &mut |_, _, _| data2.clone()).unwrap();
            if i < WARMUP_STEPS {
                continue;
            }
            step_s += t0.elapsed().as_secs_f64();
            agg.comm_wait_s += stats.comm_wait_s;
            agg.comm_busy_s += stats.comm_busy_s;
            agg.overlap_s += stats.overlap_s;
            agg.compute_s += stats.compute_s;
            agg.update_s += stats.update_s;
        }
        let n = MEASURED_STEPS as f64;
        step_s /= n;
        agg.comm_wait_s /= n;
        agg.comm_busy_s /= n;
        agg.overlap_s /= n;
        agg.compute_s /= n;
        agg.update_s /= n;
        println!(
            "  vgg_tiny x2 overlap={overlap}: step {:.3} ms | wait {:.3} ms | overlap {:.3} ms",
            step_s * 1e3,
            agg.comm_wait_s * 1e3,
            agg.overlap_s * 1e3
        );
        rows.push(ablation_row("vgg_tiny", "pjrt", 2, overlap, step_s, &agg));
    }
    println!("\nmean PJRT execute latency since start: {:.2} ms", rt.mean_exec_ms());
}

fn main() {
    println!("=== runtime_exec ===");
    let mut rows: Vec<Json> = Vec::new();
    synthetic_ablation(&mut rows);
    pjrt_benches(&mut rows);
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("runtime_perf".to_string()));
    root.insert("rows".to_string(), Json::Arr(rows));
    std::fs::write("BENCH_runtime_perf.json", format!("{}\n", Json::Obj(root).pretty()))
        .expect("write BENCH_runtime_perf.json");
    println!("\nwrote BENCH_runtime_perf.json");
}
