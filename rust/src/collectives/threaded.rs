//! Multi-threaded engine: one OS thread per rank, same owner-computes
//! algorithm and reduction order as [`super::inline`], so results are
//! bitwise identical. Phases are separated by a barrier, mirroring the
//! step structure a real multi-node reduce-scatter/allgather would have.
//!
//! Safety model: within a phase every thread writes only its own shard
//! rows (disjoint index ranges) and reads regions no thread writes in
//! that phase; phases are separated by `std::sync::Barrier`.

use std::sync::Barrier;

use super::topology::shard_range;

/// Shared-pointer wrapper so scoped threads can address the rank buffers.
/// Disjointness of writes is guaranteed by the shard layout.
struct SharedBufs {
    ptrs: Vec<*mut f32>,
    len: usize,
}
unsafe impl Sync for SharedBufs {}

impl SharedBufs {
    fn new(bufs: &mut [Vec<f32>]) -> Self {
        let len = bufs.first().map_or(0, |b| b.len());
        assert!(bufs.iter().all(|b| b.len() == len), "ragged buffers");
        SharedBufs { ptrs: bufs.iter_mut().map(|b| b.as_mut_ptr()).collect(), len }
    }

    /// Read element `i` of rank `q`'s buffer.
    ///
    /// # Safety
    /// Caller must ensure no concurrent writer of `(q, i)` in this phase.
    unsafe fn get(&self, q: usize, i: usize) -> f32 {
        *self.ptrs[q].add(i)
    }

    /// Write element `i` of rank `q`'s buffer.
    ///
    /// # Safety
    /// Caller must ensure exclusive access to `(q, i)` in this phase.
    unsafe fn set(&self, q: usize, i: usize, v: f32) {
        *self.ptrs[q].add(i) = v;
    }
}

/// Threaded part-reduce: rank threads reduce their own shard in the fixed
/// left-to-right order.
pub fn part_reduce(bufs: &mut [Vec<f32>]) {
    run(bufs, true, false);
}

/// Threaded part-broadcast.
pub fn part_broadcast(bufs: &mut [Vec<f32>]) {
    run(bufs, false, true);
}

/// Threaded allreduce (reduce phase, barrier, broadcast phase).
pub fn allreduce(bufs: &mut [Vec<f32>]) {
    run(bufs, true, true);
}

fn run(bufs: &mut [Vec<f32>], reduce: bool, broadcast: bool) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let shared = SharedBufs::new(bufs);
    let len = shared.len;
    let barrier = Barrier::new(n);
    std::thread::scope(|scope| {
        for r in 0..n {
            let shared = &shared;
            let barrier = &barrier;
            scope.spawn(move || {
                let range = shard_range(r, n, len);
                if reduce {
                    // Phase 1: every thread writes ONLY its own shard range
                    // of its OWN buffer; reads of other buffers hit index
                    // ranges nobody writes in this phase... except other
                    // owners writing their own shard of their own buffer —
                    // which this thread never reads (it reads shard r of
                    // all buffers; thread q writes shard q of buffer q).
                    // shard r of buffer q (q != r) is read-only everywhere.
                    // shard r of buffer r is written by this thread only.
                    for i in range.clone() {
                        let mut acc = unsafe { shared.get(0, i) };
                        for q in 1..n {
                            acc += unsafe { shared.get(q, i) };
                        }
                        unsafe { shared.set(r, i, acc) };
                    }
                }
                // Wait: hazard between thread r reading buf[r][shard r]
                // (phase 1 result) and thread q writing buf[r][shard q]
                // (phase 2) is WAW/RAW-free only across the barrier.
                barrier.wait();
                if broadcast {
                    // Phase 2: thread r writes shard r into ALL buffers;
                    // ranges are disjoint across threads.
                    for q in 0..n {
                        if q == r {
                            continue;
                        }
                        for i in range.clone() {
                            let v = unsafe { shared.get(r, i) };
                            unsafe { shared.set(q, i, v) };
                        }
                    }
                }
            });
        }
    });
}

// Aliasing argument, phase 1: thread r reads shard r of every buffer and
// writes shard r of buffer r; thread q writes shard q of buffer q. Shards
// are disjoint index ranges, so no location is concurrently written and
// read. Phase 2: thread r writes shard r of all buffers and reads shard r
// of buffer r — again disjoint across threads. `super::tests` verifies
// bitwise equality with the single-threaded engine.

/// Minimum elements per chunk before [`fold_into`] spawns threads; below
/// ~1 MiB of f32 the adds finish faster than a thread starts.
const FOLD_CHUNK_MIN: usize = 1 << 18;

/// Streaming-reduction fold: `acc[i] += contrib[i]`. The comm thread runs
/// this once per (worker, tensor) in the overlapped exchange; large
/// tensors are chunked across threads. Every element is a single
/// independent add, so the result is bit-identical to the serial loop
/// for any chunking — chunk boundaries never re-associate the sum.
pub fn fold_into(acc: &mut [f32], contrib: &[f32]) {
    assert_eq!(acc.len(), contrib.len(), "fold_into: ragged buffers");
    let len = acc.len();
    let threads = if len >= 2 * FOLD_CHUNK_MIN {
        std::thread::available_parallelism().map_or(1, |n| n.get()).min(len / FOLD_CHUNK_MIN)
    } else {
        1
    };
    if threads <= 1 {
        for (a, &v) in acc.iter_mut().zip(contrib) {
            *a += v;
        }
        return;
    }
    std::thread::scope(|scope| {
        let mut acc_rest = acc;
        let mut contrib_rest = contrib;
        for t in 0..threads {
            let n = shard_range(t, threads, len).len();
            let (a, ar) = acc_rest.split_at_mut(n);
            let (c, cr) = contrib_rest.split_at(n);
            acc_rest = ar;
            contrib_rest = cr;
            scope.spawn(move || {
                for (x, &v) in a.iter_mut().zip(c) {
                    *x += v;
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threaded_allreduce_correct_sums() {
        let mut bufs: Vec<Vec<f32>> =
            (0..8).map(|r| (0..257).map(|i| (r + i) as f32).collect()).collect();
        let want: Vec<f32> =
            (0..257).map(|i| (0..8).map(|r| (r + i) as f32).sum()).collect();
        allreduce(&mut bufs);
        for b in &bufs {
            assert_eq!(b, &want);
        }
    }

    #[test]
    fn two_ranks_small_buffer() {
        let mut bufs = vec![vec![1.0f32], vec![2.0f32]];
        allreduce(&mut bufs);
        assert_eq!(bufs, vec![vec![3.0], vec![3.0]]);
    }

    #[test]
    fn fold_into_matches_serial_bitwise_across_threshold() {
        // sizes straddling the chunking threshold, odd lengths included;
        // chunked and serial folds must agree bit-for-bit
        for len in [1usize, 7, 1000, FOLD_CHUNK_MIN - 1, 2 * FOLD_CHUNK_MIN + 13] {
            let acc0: Vec<f32> = (0..len).map(|i| (i % 89) as f32 * 0.37 - 3.0).collect();
            let contrib: Vec<f32> = (0..len).map(|i| (i % 97) as f32 * -0.51 + 1.0).collect();
            let mut want = acc0.clone();
            for (a, &v) in want.iter_mut().zip(&contrib) {
                *a += v;
            }
            let mut got = acc0;
            fold_into(&mut got, &contrib);
            let eq = got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(eq, "len={len}");
        }
    }

    #[test]
    fn folding_in_rank_order_equals_part_reduce_scan() {
        // rank-ordered fold_into chain == inline part_reduce's
        // left-to-right element scan (the streaming-exchange determinism
        // anchor: leader.rs relies on exactly this identity)
        let bufs: Vec<Vec<f32>> = (0..5)
            .map(|r| (0..611).map(|i| ((r * 13 + i * 7) % 101) as f32 * 0.3 - 9.0).collect())
            .collect();
        let mut inline_bufs = bufs.clone();
        crate::collectives::inline::allreduce(&mut inline_bufs);
        let mut acc = bufs[0].clone();
        for b in &bufs[1..] {
            fold_into(&mut acc, b);
        }
        let eq = acc.iter().zip(&inline_bufs[0]).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(eq, "fold chain diverged from allreduce");
    }

    #[test]
    fn reduce_only_leaves_other_shards_untouched() {
        let mut bufs = vec![vec![1.0f32; 4], vec![10.0; 4]];
        part_reduce(&mut bufs);
        // rank 0 owns [0,2), rank 1 owns [2,4)
        assert_eq!(bufs[0][..2], [11.0, 11.0]);
        assert_eq!(bufs[0][2..], [1.0, 1.0]); // untouched
        assert_eq!(bufs[1][2..], [11.0, 11.0]);
    }
}
