//! Single-threaded reference engine for part-reduce / part-broadcast.
//!
//! Used on the training hot path (the coordinator's comm thread calls
//! these). The reduction order is a fixed left-to-right scan over ranks,
//! shared with the [`super::threaded`] engine, so results are bitwise
//! engine-independent.

use super::topology::shard_range;

/// part-reduce (§3.4, `MPI_Reduce_scatter`): after the call, rank `r`'s
/// buffer holds the full sum over ranks on its own shard; other regions
/// of each buffer are unspecified (they keep their pre-call content).
pub fn part_reduce(bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    debug_assert!(bufs.iter().all(|b| b.len() == len), "ragged buffers");
    for r in 0..n {
        let range = shard_range(r, n, len);
        // owner-computes: acc = buf[0] + buf[1] + ... (fixed order)
        for i in range {
            let mut acc = bufs[0][i];
            for q in 1..n {
                acc += bufs[q][i];
            }
            bufs[r][i] = acc;
        }
    }
}

/// part-broadcast (§3.4, `MPI_Allgather`): every rank's owned shard is
/// copied to all other ranks; afterwards all buffers are identical.
pub fn part_broadcast(bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    for r in 0..n {
        let range = shard_range(r, n, len);
        if range.is_empty() {
            continue;
        }
        let (owner, rest) = split_one(bufs, r);
        for (q, buf) in rest {
            debug_assert_ne!(q, r);
            buf[range.clone()].copy_from_slice(&owner[range.clone()]);
        }
    }
}

/// allreduce = part-reduce then part-broadcast (the data-parallel gradient
/// exchange around the SGD update).
pub fn allreduce(bufs: &mut [Vec<f32>]) {
    part_reduce(bufs);
    part_broadcast(bufs);
}

/// Borrow buffer `r` immutably and all others mutably.
fn split_one(bufs: &mut [Vec<f32>], r: usize) -> (&Vec<f32>, Vec<(usize, &mut Vec<f32>)>) {
    let (left, midright) = bufs.split_at_mut(r);
    let (mid, right) = midright.split_at_mut(1);
    let owner = &mid[0];
    let mut rest: Vec<(usize, &mut Vec<f32>)> = Vec::with_capacity(bufs_len_hint(left, right));
    for (i, b) in left.iter_mut().enumerate() {
        rest.push((i, b));
    }
    for (i, b) in right.iter_mut().enumerate() {
        rest.push((r + 1 + i, b));
    }
    (owner, rest)
}

fn bufs_len_hint(a: &[Vec<f32>], b: &[Vec<f32>]) -> usize {
    a.len() + b.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn part_reduce_owner_shards_hold_sums() {
        let mut bufs = vec![vec![1.0f32; 10], vec![2.0; 10], vec![4.0; 10]];
        part_reduce(&mut bufs);
        for r in 0..3 {
            for i in shard_range(r, 3, 10) {
                assert_eq!(bufs[r][i], 7.0);
            }
        }
    }

    #[test]
    fn broadcast_makes_buffers_identical() {
        let mut bufs: Vec<Vec<f32>> =
            (0..4).map(|r| (0..17).map(|i| (r * 17 + i) as f32).collect()).collect();
        part_reduce(&mut bufs);
        part_broadcast(&mut bufs);
        for r in 1..4 {
            assert_eq!(bufs[0], bufs[r]);
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let mut bufs = vec![vec![3.0f32, -1.0, 2.5]];
        let orig = bufs.clone();
        allreduce(&mut bufs);
        assert_eq!(bufs, orig);
    }

    #[test]
    fn fixed_order_association() {
        // The sum must be computed as ((b0 + b1) + b2) exactly.
        let vals = [1.0e8f32, 1.0, -1.0e8];
        let mut bufs: Vec<Vec<f32>> = vals.iter().map(|&v| vec![v]).collect();
        part_reduce(&mut bufs);
        let expect = ((vals[0] + vals[1]) + vals[2]) as f32;
        assert_eq!(bufs[0][0], expect);
    }

    #[test]
    fn handles_len_smaller_than_ranks() {
        let mut bufs: Vec<Vec<f32>> = (0..5).map(|r| vec![r as f32, 1.0]).collect();
        allreduce(&mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![10.0, 5.0]);
        }
    }
}
