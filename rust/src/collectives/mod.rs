//! Real (in-process) implementations of the paper's communication
//! primitives (§3.4): **part-reduce** (reduce-scatter) and
//! **part-broadcast** (allgather), plus allreduce compositions.
//!
//! These run over shared-memory "ranks" — the in-process stand-in for MPI
//! ranks (DESIGN.md hardware substitutions). Two engines produce
//! *bit-identical* results:
//!
//! * [`inline`] — single-threaded recursive-halving/doubling, used on the
//!   training path (deterministic, allocation-light);
//! * [`threaded`] — the same butterfly executed by one OS thread per rank
//!   with barrier rounds, used by the collectives bench and to validate
//!   that the algorithm parallelizes. Also home of [`fold_into`], the
//!   chunked `acc += contrib` the comm thread uses for the streaming
//!   (rank-ordered) gradient exchange.
//!
//! Determinism matters: synchronous SGD's "distributed = serial" claim
//! (Fig 5) requires a reduction order that does not depend on thread
//! scheduling. Both engines reduce each owned shard by a fixed
//! left-to-right scan over rank order (owner-computes direct
//! reduce-scatter — the natural algorithm over shared memory; the
//! butterfly/ring step structure only changes *cost*, which is what the
//! netsim α-β models account for on the simulated wire).

pub mod inline;
pub mod threaded;
pub mod topology;

pub use inline::{allreduce, part_broadcast, part_reduce};
pub use threaded::fold_into;
pub use topology::{shard_range, GroupTopology};

#[cfg(test)]
mod tests {
    use super::*;

    fn make_bufs(ranks: usize, len: usize) -> Vec<Vec<f32>> {
        (0..ranks)
            .map(|r| (0..len).map(|i| ((r * 31 + i * 7) % 97) as f32 * 0.5 - 10.0).collect())
            .collect()
    }

    fn expected_sum(bufs: &[Vec<f32>]) -> Vec<f32> {
        let len = bufs[0].len();
        (0..len).map(|i| bufs.iter().map(|b| b[i]).sum()).collect()
    }

    #[test]
    fn inline_and_threaded_engines_agree_bitwise() {
        for ranks in [2usize, 4, 8] {
            for len in [8usize, 64, 1000] {
                let mut a = make_bufs(ranks, len);
                let mut b = a.clone();
                inline::allreduce(&mut a);
                threaded::allreduce(&mut b);
                assert_eq!(a, b, "ranks={ranks} len={len}");
            }
        }
    }

    #[test]
    fn allreduce_sums_across_ranks() {
        let mut bufs = make_bufs(4, 100);
        let want = expected_sum(&bufs);
        inline::allreduce(&mut bufs);
        for (r, b) in bufs.iter().enumerate() {
            for (i, (&got, &w)) in b.iter().zip(want.iter()).enumerate() {
                assert!((got - w).abs() <= 1e-4 * w.abs().max(1.0), "rank {r} idx {i}");
            }
        }
    }

    #[test]
    fn part_reduce_then_broadcast_equals_allreduce() {
        let mut a = make_bufs(8, 123);
        let mut b = a.clone();
        inline::allreduce(&mut a);
        inline::part_reduce(&mut b);
        inline::part_broadcast(&mut b);
        assert_eq!(a, b);
    }
}
