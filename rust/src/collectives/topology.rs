//! Shard layout and hybrid group topology (paper §3.3).


use std::ops::Range;

/// Contiguous balanced shard of `len` elements owned by `rank` of `n`.
/// The first `len % n` ranks take one extra element.
pub fn shard_range(rank: usize, n: usize, len: usize) -> Range<usize> {
    assert!(rank < n, "rank {rank} out of {n}");
    let base = len / n;
    let rem = len % n;
    let start = rank * base + rank.min(rem);
    let extra = usize::from(rank < rem);
    start..start + base + extra
}

/// Hybrid parallelism topology: `nodes` workers arranged as `groups`
/// data-parallel replicas of `nodes/groups`-way model-parallel groups.
/// Workers within a group hold disjoint feature shards; corresponding
/// ranks across groups hold replicas (§3.3: "nodes within a group follow
/// a model-parallelism regime while corresponding nodes across node
/// groups follow a data-parallelism regime").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupTopology {
    pub nodes: usize,
    pub groups: usize,
}

impl GroupTopology {
    pub fn new(nodes: usize, groups: usize) -> Self {
        assert!(groups >= 1 && groups <= nodes, "G={groups} N={nodes}");
        assert_eq!(nodes % groups, 0, "G={groups} must divide N={nodes}");
        GroupTopology { nodes, groups }
    }

    /// Pure data parallelism = N groups of 1.
    pub fn data_parallel(nodes: usize) -> Self {
        Self::new(nodes, nodes)
    }

    /// Pure model parallelism = 1 group of N.
    pub fn model_parallel(nodes: usize) -> Self {
        Self::new(nodes, 1)
    }

    pub fn group_size(&self) -> usize {
        self.nodes / self.groups
    }

    /// Which model-parallel group a worker belongs to.
    pub fn group_of(&self, worker: usize) -> usize {
        assert!(worker < self.nodes);
        worker / self.group_size()
    }

    /// Rank of a worker within its model-parallel group.
    pub fn rank_in_group(&self, worker: usize) -> usize {
        worker % self.group_size()
    }

    /// Workers in a model-parallel group (they exchange activations).
    pub fn group_members(&self, group: usize) -> Vec<usize> {
        assert!(group < self.groups);
        let gs = self.group_size();
        (group * gs..(group + 1) * gs).collect()
    }

    /// Workers with the same in-group rank across groups (they exchange
    /// gradients data-parallel-wise for their shared feature shard).
    pub fn replica_set(&self, rank: usize) -> Vec<usize> {
        assert!(rank < self.group_size());
        (0..self.groups).map(|g| g * self.group_size() + rank).collect()
    }

    /// Global minibatch range computed by `group` (data-parallel split).
    pub fn minibatch_shard(&self, group: usize, minibatch: usize) -> Range<usize> {
        shard_range(group, self.groups, minibatch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_partition_exactly() {
        for n in 1..12usize {
            for len in [0usize, 1, 7, 64, 1001] {
                let mut total = 0;
                let mut next = 0;
                for r in 0..n {
                    let s = shard_range(r, n, len);
                    assert_eq!(s.start, next, "contiguous");
                    total += s.len();
                    next = s.end;
                }
                assert_eq!(total, len);
                assert_eq!(next, len);
            }
        }
    }

    #[test]
    fn shard_sizes_balanced() {
        for r in 0..5 {
            let s = shard_range(r, 5, 13);
            assert!(s.len() == 2 || s.len() == 3);
        }
    }

    #[test]
    fn groups_partition_workers() {
        let t = GroupTopology::new(16, 4);
        assert_eq!(t.group_size(), 4);
        let mut seen = vec![false; 16];
        for g in 0..4 {
            for w in t.group_members(g) {
                assert_eq!(t.group_of(w), g);
                assert!(!seen[w]);
                seen[w] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn replica_sets_cross_groups() {
        let t = GroupTopology::new(8, 4); // groups of 2
        assert_eq!(t.replica_set(0), vec![0, 2, 4, 6]);
        assert_eq!(t.replica_set(1), vec![1, 3, 5, 7]);
    }

    #[test]
    fn degenerate_topologies() {
        let dp = GroupTopology::data_parallel(8);
        assert_eq!(dp.group_size(), 1);
        let mp = GroupTopology::model_parallel(8);
        assert_eq!(mp.groups, 1);
        assert_eq!(mp.group_size(), 8);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn invalid_group_count_panics() {
        GroupTopology::new(8, 3);
    }

    #[test]
    fn minibatch_shards_cover() {
        let t = GroupTopology::new(8, 4);
        let total: usize = (0..4).map(|g| t.minibatch_shard(g, 256).len()).sum();
        assert_eq!(total, 256);
    }
}
