//! # pcl-dnn — Distributed Deep Learning Using Synchronous Stochastic Gradient Descent
//!
//! Reproduction of Das et al. (Intel PCL, 2016). The crate is the Layer-3
//! coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (blocked conv / block-SGEMM), authored in
//!   `python/compile/kernels/`, correctness-checked against pure-jnp refs.
//! * **L2** — JAX model zoo + train-step functions in `python/compile/`,
//!   AOT-lowered once to HLO text artifacts (`make artifacts`).
//! * **L3** — this crate: synchronous-SGD coordination (hybrid data/model
//!   parallel groups, part-reduce / part-broadcast collectives, a
//!   dedicated communication thread with a lock-free command queue, a
//!   dedicated data-handling thread), plus every substrate the paper's
//!   evaluation needs: an analytic balance-equation engine (paper §2-3), a
//!   discrete-event cluster/network simulator, and a PJRT runtime that
//!   executes the AOT artifacts. Python is never on the training path.
//!   The three substrates sit behind one declarative interface — the
//!   [`experiment`] module's `ExperimentSpec` / `Backend` /
//!   `ScalingReport` triple — so any experiment point runs on any
//!   substrate and the results compare in one schema.
//!
//! See `DESIGN.md` for the per-experiment index (Table 1, Figs 3-7) and
//! `EXPERIMENTS.md` for measured results.

pub mod analytic;
pub mod util;
pub mod checkpoint;
pub mod collectives;
pub mod coordinator;
pub mod data;
pub mod experiment;
pub mod flowsim;
pub mod metrics;
pub mod models;
pub mod netsim;
pub mod plan;
pub mod runtime;
pub mod trainer;

/// Crate-wide result type (anyhow).
pub type Result<T> = anyhow::Result<T>;
