//! `repro` — CLI for the PCL-DNN reproduction.
//!
//! ```text
//! repro info                          artifact/model inventory + platform
//! repro analyze table1                Table 1 (data-parallel scaling limits)
//! repro analyze cache-blocking        §2.2 brute-force B/F search
//! repro analyze register-blocking     §2.4 LS/FMA efficiency model
//! repro analyze hybrid                §3.3 hybrid-parallel optimum
//! repro analyze fig3                  Fig 3 single-node throughput model
//! repro analyze kernel-blocking       L1 Pallas tile VMEM/MXU estimates
//! repro simulate fig4|fig6|fig7       cluster-simulated scaling figures
//! repro simulate sweep --net vgg_a --platform cori --minibatch 256 ...
//! repro simulate full --nodes 16 --topology fattree --oversub 4 \
//!     --straggler-skew 0.3 --hetero --fail-at 2    full-cluster simulator
//! repro simulate stragglers --skews 0,0.2,0.5,1    straggler-skew sweep
//! repro simulate contention --oversubs 1,2,4,8     fat-tree core sweep
//! repro train --model vgg_tiny --workers 4 --minibatch 16 --steps 100
//! repro score --model vgg_tiny --batches 20
//! ```

use anyhow::{bail, Context, Result};

use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::analytic::{cache_blocking, comm_model, compute_model, register_blocking, scaling};
use pcl_dnn::metrics::Table;
use pcl_dnn::models::zoo;
use pcl_dnn::models::NetDescriptor;
use pcl_dnn::netsim::cluster::{
    scaling_curve, simulate_training, simulate_training_fleet, SimConfig,
};
use pcl_dnn::netsim::{FleetConfig, Topology};
use pcl_dnn::runtime::Runtime;
use pcl_dnn::trainer::{self, TrainConfig};
use pcl_dnn::util::cli::Opts;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn net_by_name(name: &str) -> Result<NetDescriptor> {
    Ok(match name {
        "vgg_a" => zoo::vgg_a(),
        "overfeat_fast" => zoo::overfeat_fast(),
        "cddnn_full" => zoo::cddnn_full(),
        "vgg_tiny" => zoo::vgg_tiny(),
        "overfeat_tiny" => zoo::overfeat_tiny(),
        "cddnn_tiny" => zoo::cddnn_tiny(),
        "gpt_mini" => zoo::gpt_descriptor("gpt_mini", 384, 6, 128),
        "gpt_large" => zoo::gpt_descriptor("gpt_large", 768, 12, 4096),
        _ => bail!("unknown network {name:?}"),
    })
}

fn platform_by_name(name: &str) -> Result<Platform> {
    Ok(match name {
        "cori" => Platform::cori(),
        "aws" => Platform::aws(),
        "endeavor" => Platform::endeavor(),
        "table1_ethernet" => Platform::table1_ethernet(),
        "table1_fdr" => Platform::table1_fdr(),
        _ => bail!("unknown platform {name:?} (cori|aws|endeavor|table1_ethernet|table1_fdr)"),
    })
}

fn run() -> Result<()> {
    let opts = Opts::from_env()?;
    match opts.pos(0) {
        Some("info") => info(&opts),
        Some("analyze") => analyze(&opts),
        Some("simulate") => simulate(&opts),
        Some("train") => train(&opts),
        Some("score") => score(&opts),
        _ => {
            eprintln!(
                "usage: repro <info|analyze|simulate|train|score> ... (see README quickstart)"
            );
            Ok(())
        }
    }
}

fn info(opts: &Opts) -> Result<()> {
    let dir = opts.str_or(
        "artifacts",
        pcl_dnn::runtime::default_artifacts_dir().to_str().unwrap_or("artifacts"),
    );
    let rt = Runtime::new(&dir).context("artifacts not built? run `make artifacts`")?;
    println!("platform: {}", rt.platform());
    println!("artifacts dir: {dir}");
    let mut t = Table::new(&["artifact", "kind", "model", "batch", "inputs", "outputs"]);
    for (name, a) in &rt.manifest().artifacts {
        t.row(vec![
            name.clone(),
            a.kind.clone(),
            a.model.clone().unwrap_or_default(),
            a.batch.to_string(),
            a.inputs.len().to_string(),
            a.outputs.len().to_string(),
        ]);
    }
    t.print();
    let mut t = Table::new(&["model", "params", "elements"]);
    for (name, m) in &rt.manifest().models {
        t.row(vec![name.clone(), m.params.len().to_string(), m.n_elements.to_string()]);
    }
    t.print();
    Ok(())
}

fn analyze(opts: &Opts) -> Result<()> {
    match opts.pos(1) {
        Some("table1") => {
            println!("# Table 1 — Theoretical scaling of data parallelism");
            println!("(paper: comp-to-comms 1336 / 336; OverFeat 3 (86) / 2 (128); VGG-A 1 (256) / 1 (256))\n");
            let platforms = [
                ("2s9c E5-2666v3 + 10GbE", Platform::table1_ethernet()),
                ("2s16c E5-2698v3 + FDR", Platform::table1_fdr()),
            ];
            let mut t = Table::new(&["", platforms[0].0, platforms[1].0]);
            t.row(vec![
                "Comp-to-comms (FLOPs/byte)".into(),
                format!("{:.0}", platforms[0].1.comp_to_comms()),
                format!("{:.0}", platforms[1].1.comp_to_comms()),
            ]);
            for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
                let cells: Vec<String> = platforms
                    .iter()
                    .map(|(_, p)| {
                        let (mb, n) = scaling::table1_row(&net, p, 256);
                        format!("{mb} ({n})")
                    })
                    .collect();
                t.row(vec![net.name.clone(), cells[0].clone(), cells[1].clone()]);
            }
            t.print();
            println!("\nconv-trunk comp/comm ratios (paper: OverFeat 208, VGG-A 1456):");
            for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
                println!("  {}: {:.0}", net.name, net.conv_comp_comm_ratio(1));
            }
            Ok(())
        }
        Some("cache-blocking") => {
            let budget = opts.parse_or("budget", 128 * 1024u64)?;
            let simd = opts.parse_or("simd", 8u64)?;
            let mb = opts.parse_or("mb", 1u64)?;
            let net = net_by_name(&opts.str_or("net", "overfeat_fast"))?;
            let cfg = cache_blocking::SearchCfg { budget, simd, double_buffer: true, max_mb: mb };
            println!(
                "# §2.2 cache-blocking search — budget {} KB, SIMD {simd}, max mb {mb}",
                budget / 1024
            );
            let mut t = Table::new(&[
                "layer",
                "B/F (row)",
                "B/F (best)",
                "blocking (mb,ofm,oh,ow,ifm,kh,kw)",
                "bytes",
            ]);
            for l in net.layers.iter().filter(|l| l.is_conv()) {
                let row_bf = compute_model::bf_ratio_row(l).unwrap();
                match cache_blocking::search(l, &cfg) {
                    Some(b) => t.row(vec![
                        l.name.clone(),
                        format!("{row_bf:.3}"),
                        format!("{:.4}", b.bf),
                        format!(
                            "({},{},{},{},{},{},{})",
                            b.mb_b, b.ofm_b, b.oh_b, b.ow_b, b.ifm_b, b.kh_b, b.kw_b
                        ),
                        b.bytes.to_string(),
                    ]),
                    None => t.row(vec![
                        l.name.clone(),
                        format!("{row_bf:.3}"),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]),
                }
            }
            t.print();
            Ok(())
        }
        Some("register-blocking") => {
            println!("# §2.4 register-blocking efficiency (Haswell: 2 VFMA/cyc, latency 5)");
            println!(
                "RB bounds: {} <= RB <= {}\n",
                register_blocking::min_rb(),
                register_blocking::max_rb()
            );
            let m = register_blocking::cycle_model(12, 8, 3);
            println!(
                "fwd C5 example (RB=1x12, SW=8, 3 taps): loads {:.0}cyc stores {:.0}cyc FMA {:.0}cyc -> efficiency {:.1}% (paper: 88%)\n",
                m.load_cycles,
                m.store_cycles,
                m.fma_cycles,
                100.0 * m.efficiency
            );
            let mut t = Table::new(&["kernel", "naive 2-D eff", "strategy", "strategy eff"]);
            for k in [3u64, 5, 7, 11] {
                let (desc, _, _) = register_blocking::weight_grad_strategy(k);
                t.row(vec![
                    format!("{k}x{k}"),
                    format!("{:.0}%", 100.0 * register_blocking::weight_grad_naive_efficiency(k)),
                    desc.to_string(),
                    format!(
                        "{:.0}%",
                        100.0 * register_blocking::weight_grad_strategy_efficiency(k)
                    ),
                ]);
            }
            t.print();
            Ok(())
        }
        Some("hybrid") => {
            let minibatch = opts.parse_or("minibatch", 256u64)?;
            let n = opts.parse_or("nodes", 64u64)?;
            let ofm = opts.parse_or("ofm", 4096u64)?;
            let ifm = opts.parse_or("ifm", 4096u64)?;
            let layer = pcl_dnn::models::Layer::fc("fc", ifm, ofm);
            println!("# §3.3 hybrid parallelism — FC {ifm}x{ofm}, MB={minibatch}, N={n}");
            println!(
                "continuous optimum G* = sqrt(N*MB/ofm) = {:.2}",
                comm_model::optimal_groups_continuous(ofm, minibatch, n)
            );
            let mut t = Table::new(&["G", "bytes/node (overlap=0)", "bytes/node (overlap=1)"]);
            for g in (1..=n).filter(|g| n % g == 0) {
                t.row(vec![
                    g.to_string(),
                    format!("{:.0}", comm_model::hybrid_bytes(&layer, minibatch, n, g, 0.0)),
                    format!("{:.0}", comm_model::hybrid_bytes(&layer, minibatch, n, g, 1.0)),
                ]);
            }
            t.print();
            for overlap in [0.0, 1.0] {
                println!(
                    "best G (overlap={overlap}): {}",
                    comm_model::optimal_groups(&layer, minibatch, n, overlap)
                );
            }
            Ok(())
        }
        Some("fig3") => {
            println!("# Fig 3 — single-node throughput model (E5-2698v3)");
            println!("(paper: OverFeat ~315 FP / ~90 FP+BP; VGG-A ~95 FP / ~30 FP+BP)\n");
            let m = pcl_dnn::analytic::MachineSpec::e5_2698v3();
            let mut t = Table::new(&["net", "mode", "MB16", "MB32", "MB64", "MB128", "MB256"]);
            for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
                for (mode, training) in [("FP", false), ("FP+BP", true)] {
                    let row = compute_model::fig3_row(&net, &m, training);
                    let mut cells = vec![net.name.clone(), mode.into()];
                    cells.extend(row.iter().map(|(_, v)| format!("{v:.0}")));
                    t.row(cells);
                }
            }
            t.print();
            Ok(())
        }
        Some("kernel-blocking") => {
            println!("# L1 Pallas kernel tile analysis (TPU estimates; interpret=True on CPU)");
            let budget = opts.parse_or("vmem", 8u64 << 20)?;
            let cfg =
                cache_blocking::SearchCfg { budget, simd: 128, double_buffer: true, max_mb: 8 };
            let net = net_by_name(&opts.str_or("net", "overfeat_fast"))?;
            let mut t = Table::new(&[
                "layer",
                "tile (mb,ofm,oh,ow,ifm)",
                "VMEM KB",
                "HBM B/F",
                "MXU util",
            ]);
            for l in net.layers.iter().filter(|l| l.is_conv()) {
                if let Some(b) = cache_blocking::search(l, &cfg) {
                    let mxu = register_blocking::mxu_utilization(
                        b.mb_b * b.oh_b * b.ow_b,
                        b.ofm_b,
                        b.ifm_b * b.kh_b * b.kw_b,
                    );
                    t.row(vec![
                        l.name.clone(),
                        format!("({},{},{},{},{})", b.mb_b, b.ofm_b, b.oh_b, b.ow_b, b.ifm_b),
                        format!("{}", b.bytes / 1024),
                        format!("{:.4}", b.bf),
                        format!("{:.0}%", 100.0 * mxu),
                    ]);
                }
            }
            t.print();
            Ok(())
        }
        other => bail!("unknown analyze target {other:?}"),
    }
}

fn simulate(opts: &Opts) -> Result<()> {
    let figure = opts.pos(1).unwrap_or("sweep");
    match figure {
        "fig4" => {
            println!("# Fig 4 — VGG-A scaling on Cori (simulated)");
            println!("(paper: 90x @128 nodes MB=512 / 2510 img/s; 82% eff @64 nodes MB=256)\n");
            let p = Platform::cori();
            for mb in [256u64, 512] {
                let nodes = [1u64, 2, 4, 8, 16, 32, 64, 128];
                let curve = scaling_curve(&zoo::vgg_a(), &p, mb, &nodes, true);
                let mut t = Table::new(&["nodes", "img/s", "speedup", "efficiency"]);
                for pt in &curve {
                    t.row(vec![
                        pt.nodes.to_string(),
                        format!("{:.0}", pt.images_per_s),
                        format!("{:.1}x", pt.speedup),
                        format!("{:.0}%", 100.0 * pt.efficiency),
                    ]);
                }
                println!("minibatch {mb}:");
                t.print();
                println!();
            }
            Ok(())
        }
        "fig6" => {
            println!("# Fig 6 — OverFeat & VGG-A on AWS EC2, MB=256 (simulated)");
            println!("(paper @16 nodes: OverFeat 1027 img/s = 11.9x; VGG-A 397 img/s = 14.2x)\n");
            let p = Platform::aws();
            let nodes = [1u64, 2, 4, 8, 16];
            for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
                let curve = scaling_curve(&net, &p, 256, &nodes, true);
                let mut t = Table::new(&["nodes", "img/s", "speedup"]);
                for pt in &curve {
                    t.row(vec![
                        pt.nodes.to_string(),
                        format!("{:.0}", pt.images_per_s),
                        format!("{:.1}x", pt.speedup),
                    ]);
                }
                println!("{}:", net.name);
                t.print();
                println!();
            }
            Ok(())
        }
        "fig7" => {
            println!("# Fig 7 — CD-DNN scaling on Endeavor, MB=1024 frames (simulated)");
            println!("(paper: 4600 f/s @1 node; ~13K @4; 29.5K @16 = 6.4x)\n");
            let p = Platform::endeavor();
            let nodes = [1u64, 2, 4, 8, 16];
            let curve = scaling_curve(&zoo::cddnn_full(), &p, 1024, &nodes, true);
            let mut t = Table::new(&["nodes", "frames/s", "speedup", "efficiency"]);
            for pt in &curve {
                t.row(vec![
                    pt.nodes.to_string(),
                    format!("{:.0}", pt.images_per_s),
                    format!("{:.1}x", pt.speedup),
                    format!("{:.0}%", 100.0 * pt.efficiency),
                ]);
            }
            t.print();
            println!("\nablation — pure data parallelism (no hybrid FCs):");
            let curve = scaling_curve(&zoo::cddnn_full(), &p, 1024, &nodes, false);
            let mut t = Table::new(&["nodes", "frames/s", "speedup"]);
            for pt in &curve {
                t.row(vec![
                    pt.nodes.to_string(),
                    format!("{:.0}", pt.images_per_s),
                    format!("{:.1}x", pt.speedup),
                ]);
            }
            t.print();
            Ok(())
        }
        "sweep" => {
            let net = net_by_name(&opts.str_or("net", "vgg_a"))?;
            let platform = platform_by_name(&opts.str_or("platform", "cori"))?;
            let minibatch = opts.parse_or("minibatch", 256u64)?;
            let max_nodes = opts.parse_or("nodes", 128u64)?;
            let hybrid = !opts.bool_flag("no-hybrid");
            let mut nodes = vec![];
            let mut n = 1u64;
            while n <= max_nodes {
                nodes.push(n);
                n *= 2;
            }
            println!(
                "# sweep — {} on {} ({}), MB={minibatch}, hybrid={hybrid}",
                net.name, platform.machine.name, platform.fabric.name
            );
            let curve = scaling_curve(&net, &platform, minibatch, &nodes, hybrid);
            let mut t = Table::new(&["nodes", "samples/s", "speedup", "efficiency", "iter ms"]);
            for (pt, &n) in curve.iter().zip(&nodes) {
                let r = simulate_training(
                    &net,
                    &platform,
                    &SimConfig { nodes: n, minibatch, hybrid_fc: hybrid, ..Default::default() },
                );
                t.row(vec![
                    pt.nodes.to_string(),
                    format!("{:.0}", pt.images_per_s),
                    format!("{:.1}x", pt.speedup),
                    format!("{:.0}%", 100.0 * pt.efficiency),
                    format!("{:.1}", r.iteration_s * 1e3),
                ]);
            }
            t.print();
            Ok(())
        }
        "full" => simulate_full(opts),
        "stragglers" => simulate_stragglers(opts),
        "contention" => simulate_contention(opts),
        other => bail!("unknown figure {other:?} (fig4|fig6|fig7|sweep|full|stragglers|contention)"),
    }
}

fn topology_from(opts: &Opts) -> Result<Topology> {
    let radix = opts.parse_or("radix", 8usize)?;
    let oversub = opts.parse_or("oversub", 2.0f64)?;
    match opts.str_or("topology", "switched").as_str() {
        "switched" => Ok(Topology::FullySwitched),
        "flat" => Ok(Topology::FlatSwitch),
        "fattree" | "fat-tree" => Ok(Topology::FatTree { radix, oversub }),
        other => bail!("unknown topology {other:?} (switched|flat|fattree)"),
    }
}

fn fleet_from(opts: &Opts, nodes: usize) -> Result<FleetConfig> {
    Ok(FleetConfig {
        nodes,
        topology: topology_from(opts)?,
        straggler_skew: opts.parse_or("straggler-skew", 0.0f64)?,
        hetero: opts.bool_flag("hetero"),
        fail_at: opts
            .str_opt("fail-at")
            .map(str::parse::<usize>)
            .transpose()
            .map_err(|e| anyhow::anyhow!("--fail-at: {e}"))?,
        fail_node: opts.parse_or("fail-node", 0usize)?,
        recovery_s: opts.parse_or("recovery", 5.0f64)?,
    })
}

fn parse_list<T: std::str::FromStr>(s: &str, flag: &str) -> Result<Vec<T>> {
    s.split(',')
        .filter(|p| !p.is_empty())
        .map(|p| p.trim().parse::<T>().map_err(|_| anyhow::anyhow!("--{flag}: bad entry {p:?}")))
        .collect()
}

/// One full-cluster simulation with an analytic cross-check.
fn simulate_full(opts: &Opts) -> Result<()> {
    let net = net_by_name(&opts.str_or("net", "vgg_a"))?;
    let platform = platform_by_name(&opts.str_or("platform", "cori"))?;
    let nodes = opts.parse_or("nodes", 16u64)?;
    let minibatch = opts.parse_or("minibatch", 256u64)?;
    let cfg = SimConfig {
        nodes,
        minibatch,
        hybrid_fc: !opts.bool_flag("no-hybrid"),
        iterations: opts.parse_or("iterations", 4usize)?,
        ..Default::default()
    };
    let fleet = fleet_from(opts, nodes as usize)?;
    println!(
        "# full-cluster simulation — {} x{nodes} on {} ({}), MB={minibatch}, topology={}",
        net.name,
        platform.machine.name,
        platform.fabric.name,
        fleet.topology.tag()
    );
    let full = simulate_training_fleet(&net, &platform, &cfg, &fleet);
    // the α-β cross-check strips congestion_per_doubling: that term is the
    // representative model's empirical stand-in for the contention the
    // full simulator models explicitly per link
    let mut stripped = platform.clone();
    stripped.fabric.congestion_per_doubling = 0.0;
    let rep = simulate_training(&net, &stripped, &cfg);
    let mut t = Table::new(&["", "iter ms", "samples/s", "mean util", "min util"]);
    t.row(vec![
        "full-cluster".into(),
        format!("{:.2}", full.iteration_s * 1e3),
        format!("{:.0}", full.images_per_s),
        format!("{:.0}%", 100.0 * full.mean_compute_utilization),
        format!("{:.0}%", 100.0 * full.min_compute_utilization),
    ]);
    t.row(vec![
        "analytic, no congestion term".into(),
        format!("{:.2}", rep.iteration_s * 1e3),
        format!("{:.0}", rep.images_per_s),
        format!("{:.0}%", 100.0 * rep.compute_utilization),
        "-".into(),
    ]);
    t.print();
    println!(
        "{} simulated tasks; full vs α-β delta {:+.1}% (expect ~0 on a homogeneous switched fabric)",
        full.tasks,
        100.0 * (full.iteration_s - rep.iteration_s) / rep.iteration_s
    );
    Ok(())
}

/// Straggler-skew sweep: the scenario a representative-node model cannot
/// express — synchronous SGD at the slowest node's pace.
fn simulate_stragglers(opts: &Opts) -> Result<()> {
    let net = net_by_name(&opts.str_or("net", "vgg_a"))?;
    let platform = platform_by_name(&opts.str_or("platform", "cori"))?;
    let nodes = opts.parse_or("nodes", 16u64)?;
    let minibatch = opts.parse_or("minibatch", 256u64)?;
    let skews: Vec<f64> = parse_list(&opts.str_or("skews", "0,0.1,0.25,0.5,1.0"), "skews")?;
    let cfg = SimConfig {
        nodes,
        minibatch,
        hybrid_fc: !opts.bool_flag("no-hybrid"),
        ..Default::default()
    };
    println!(
        "# straggler sweep — {} x{nodes} on {} ({}), MB={minibatch}",
        net.name, platform.machine.name, platform.fabric.name
    );
    let mut t = Table::new(&["skew", "iter ms", "samples/s", "slowdown", "min util"]);
    let mut base = 0.0;
    for &skew in &skews {
        let fleet = FleetConfig {
            nodes: nodes as usize,
            topology: topology_from(opts)?,
            straggler_skew: skew,
            hetero: opts.bool_flag("hetero"),
            ..Default::default()
        };
        let r = simulate_training_fleet(&net, &platform, &cfg, &fleet);
        if base == 0.0 {
            base = r.iteration_s;
        }
        t.row(vec![
            format!("{skew:.2}"),
            format!("{:.2}", r.iteration_s * 1e3),
            format!("{:.0}", r.images_per_s),
            format!("{:.2}x", r.iteration_s / base),
            format!("{:.0}%", 100.0 * r.min_compute_utilization),
        ]);
    }
    t.print();
    Ok(())
}

/// Oversubscribed-core contention sweep on a fat-tree fabric.
fn simulate_contention(opts: &Opts) -> Result<()> {
    let net = net_by_name(&opts.str_or("net", "cddnn_full"))?;
    let platform = platform_by_name(&opts.str_or("platform", "aws"))?;
    let nodes = opts.parse_or("nodes", 16u64)?;
    let minibatch = opts.parse_or("minibatch", 1024u64)?;
    let radix = opts.parse_or("radix", (nodes as usize / 2).max(2))?;
    let oversubs: Vec<f64> = parse_list(&opts.str_or("oversubs", "1,2,4,8"), "oversubs")?;
    let cfg = SimConfig {
        nodes,
        minibatch,
        hybrid_fc: !opts.bool_flag("no-hybrid"),
        ..Default::default()
    };
    println!(
        "# contention sweep — {} x{nodes} on {} ({}), MB={minibatch}, leaf radix {radix}",
        net.name, platform.machine.name, platform.fabric.name
    );
    let flat = simulate_training_fleet(
        &net,
        &platform,
        &cfg,
        &FleetConfig {
            nodes: nodes as usize,
            topology: Topology::FlatSwitch,
            ..Default::default()
        },
    );
    let mut t = Table::new(&["core", "iter ms", "samples/s", "vs flat"]);
    t.row(vec![
        "flat switch".into(),
        format!("{:.2}", flat.iteration_s * 1e3),
        format!("{:.0}", flat.images_per_s),
        "1.00x".into(),
    ]);
    for &oversub in &oversubs {
        let fleet = FleetConfig {
            nodes: nodes as usize,
            topology: Topology::FatTree { radix, oversub },
            ..Default::default()
        };
        let r = simulate_training_fleet(&net, &platform, &cfg, &fleet);
        t.row(vec![
            format!("fat-tree {oversub}:1"),
            format!("{:.2}", r.iteration_s * 1e3),
            format!("{:.0}", r.images_per_s),
            format!("{:.2}x", r.iteration_s / flat.iteration_s),
        ]);
    }
    t.print();
    Ok(())
}

fn train(opts: &Opts) -> Result<()> {
    let dir = opts.str_or(
        "artifacts",
        pcl_dnn::runtime::default_artifacts_dir().to_str().unwrap_or("artifacts"),
    );
    let mut rt = Runtime::new(&dir)?;
    let cfg = TrainConfig {
        model: opts.str_or("model", "vgg_tiny"),
        workers: opts.parse_or("workers", 1usize)?,
        global_mb: opts.parse_or("minibatch", 16usize)?,
        steps: opts.parse_or("steps", 50u64)?,
        lr: opts.parse_or("lr", 0.01f32)?,
        momentum: opts.parse_or("momentum", 0.0f32)?,
        seed: opts.parse_or("seed", 0u64)?,
        log_every: opts.parse_or("log-every", 10u64)?,
        eval_every: opts.parse_or("eval-every", 0u64)?,
        optimizer: opts.str_or("optimizer", "sgd"),
    };
    let outcome = trainer::train(&mut rt, &cfg)?;
    println!(
        "done: {} steps, final loss {:.4}, mean {:.1} samples/s",
        cfg.steps,
        outcome.history.final_loss().unwrap_or(f64::NAN),
        outcome.history.mean_throughput()
    );
    if let Some(path) = opts.str_opt("csv") {
        outcome.history.save_csv(path)?;
        println!("loss curve written to {path}");
    }
    Ok(())
}

fn score(opts: &Opts) -> Result<()> {
    let dir = opts.str_or(
        "artifacts",
        pcl_dnn::runtime::default_artifacts_dir().to_str().unwrap_or("artifacts"),
    );
    let mut rt = Runtime::new(&dir)?;
    let model = opts.str_or("model", "vgg_tiny");
    let batches = opts.parse_or("batches", 20u64)?;
    let tput = trainer::score_throughput(&mut rt, &model, batches, 0)?;
    println!("{model}: {tput:.1} samples/s scoring throughput ({batches} batches)");
    Ok(())
}
