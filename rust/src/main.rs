//! `repro` — spec-first CLI for the PCL-DNN reproduction.
//!
//! ```text
//! repro run --spec specs/fig4.json                 one spec, one backend
//! repro run --spec specs/fig4.json --backend netsim --set nodes=64,minibatch=256
//! repro run --spec specs/fig6_vgg.json --sweep-nodes 1,2,4,8,16 --out BENCH_fig6.json
//! repro plan --spec specs/fig4.json --set nodes=64 [--validate netsim]
//! repro failover --spec specs/fig4.json --policies stall,replan,shrink
//! repro syncsweep --skews 0,0.2,0.4 --out BENCH_sync_modes.json
//! repro schema                                     ScalingReport field list
//! repro info                                       artifact/model inventory + platform
//! repro analyze table1|cache-blocking|register-blocking|hybrid|fig3|kernel-blocking
//! ```
//!
//! Experiments are described by `ExperimentSpec` JSON files (see
//! `specs/` and DESIGN.md) and run on any backend: `analytic` (balance
//! equations), `netsim` (full-cluster discrete-event simulation) or
//! `runtime` (PJRT execution). The pre-spec subcommands are kept as
//! compatibility aliases that build the equivalent spec and print a
//! deprecation note:
//!
//! ```text
//! repro simulate fig4|fig6|fig7|sweep|full|stragglers|contention ...
//! repro train --model vgg_tiny --workers 4 --minibatch 16 --steps 100
//! repro score --model vgg_tiny --batches 20
//! ```

use anyhow::{bail, Context, Result};

use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::analytic::{cache_blocking, comm_model, compute_model, register_blocking, scaling};
use pcl_dnn::experiment::{
    backend_by_name, registry, resolved_platform, run_runtime, run_sweep, AnalyticBackend,
    Backend, ExecutionSpec, ExperimentSpec, FleetSimBackend, FlowSimBackend, MinibatchSpec,
    ModelSpec, ScalingReport,
};
use pcl_dnn::metrics::Table;
use pcl_dnn::models::zoo;
use pcl_dnn::plan::{apply_pins, planner, strategy_name, CacheOutcome, PartitionPlan, PlanCache};
use pcl_dnn::runtime::Runtime;
use pcl_dnn::trainer;
use pcl_dnn::util::cli::Opts;
use pcl_dnn::util::json::Json;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let opts = Opts::from_env()?;
    match opts.pos(0) {
        Some("run") => run_spec(&opts),
        Some("plan") => plan_cmd(&opts),
        Some("failover") => failover(&opts),
        Some("syncsweep") => syncsweep(&opts),
        Some("schema") => {
            for key in pcl_dnn::experiment::report::SCHEMA_KEYS {
                println!("{key}");
            }
            Ok(())
        }
        Some("info") => info(&opts),
        Some("analyze") => analyze(&opts),
        Some("simulate") => simulate(&opts),
        Some("train") => train(&opts),
        Some("score") => score(&opts),
        _ => {
            eprintln!(
                "usage: repro <run|plan|failover|syncsweep|schema|info|analyze|simulate|train|score> \
                 ... (see README quickstart; `run --spec specs/<figure>.json` is the main entry)"
            );
            Ok(())
        }
    }
}

fn default_artifacts(opts: &Opts) -> String {
    opts.str_or(
        "artifacts",
        pcl_dnn::runtime::default_artifacts_dir().to_str().unwrap_or("artifacts"),
    )
}

fn parse_list<T: std::str::FromStr>(s: &str, flag: &str) -> Result<Vec<T>> {
    s.split(',')
        .filter(|p| !p.is_empty())
        .map(|p| p.trim().parse::<T>().map_err(|_| anyhow::anyhow!("--{flag}: bad entry {p:?}")))
        .collect()
}

fn deprecated(old: &str, spec_form: &str) {
    eprintln!("note: `repro {old}` is a compatibility alias; prefer `repro {spec_form}`");
}

// ---------------------------------------------------------------------
// spec-first entry points
// ---------------------------------------------------------------------

fn report_table(reports: &[ScalingReport]) {
    let mut t = Table::new(&[
        "backend", "nodes", "iter ms", "samples/s", "speedup", "efficiency", "mean util",
        "min util",
    ]);
    for r in reports {
        t.row(vec![
            r.backend.clone(),
            r.nodes.to_string(),
            format!("{:.2}", r.iteration_s * 1e3),
            format!("{:.0}", r.samples_per_s),
            r.speedup.map(|s| format!("{s:.1}x")).unwrap_or_else(|| "-".into()),
            r.efficiency.map(|e| format!("{:.0}%", 100.0 * e)).unwrap_or_else(|| "-".into()),
            format!("{:.0}%", 100.0 * r.mean_compute_utilization),
            format!("{:.0}%", 100.0 * r.min_compute_utilization),
        ]);
    }
    t.print();
}

/// `repro run --spec <file> [--backend b] [--set k=v,...]
/// [--sweep-nodes 1,2,4] [--json] [--out file] [--check]`
fn run_spec(opts: &Opts) -> Result<()> {
    let path = opts
        .str_opt("spec")
        .context("--spec <file> is required (committed figures live in specs/)")?;
    let mut spec = ExperimentSpec::load(path)?;
    if let Some(sets) = opts.str_opt("set") {
        spec.apply_set(sets)?;
    }
    // the spec's execution.fidelity picks the default tier; --backend
    // overrides it point-wise
    let backend = backend_by_name(&opts.str_or("backend", &spec.execution.fidelity))?;
    let reports = match opts.str_opt("sweep-nodes") {
        Some(list) => run_sweep(backend.as_ref(), &spec, &parse_list::<u64>(list, "sweep-nodes")?)?,
        None => vec![backend.run(&spec)?],
    };
    println!(
        "# {} — {} on {} ({} backend)",
        spec.name,
        spec.model.name(),
        spec.platform,
        backend.name()
    );
    report_table(&reports);
    let json = Json::Arr(reports.iter().map(|r| r.to_json()).collect());
    if opts.bool_flag("check") {
        for r in &reports {
            let round = Json::parse(&r.to_json().to_string())?;
            ScalingReport::check_schema(&round)?;
            ScalingReport::from_json(&round)?;
        }
        println!("schema check OK ({} report(s))", reports.len());
    }
    if opts.bool_flag("json") {
        println!("{json}");
    }
    if let Some(out) = opts.str_opt("out") {
        std::fs::write(out, format!("{}\n", json.pretty()))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `repro plan --spec <file> [--set k=v,...] [--nodes 8,16,64]
/// [--validate netsim|flowsim] [--json] [--out file] [--no-cache]
/// [--check-golden specs/plans/<fig>.json] [--write-golden file]`
///
/// Derives the paper-style optimal design point for the spec's network:
/// per-layer candidate costs (data / model / hybrid at the §3.3 optimal
/// group count), the chosen `PartitionPlan`, and its analytic cost vs
/// the fixed recipe and pure data parallelism. `--validate flowsim`
/// replays the chosen plan on the flow-level simulator (clean fabric)
/// and fails if it disagrees with the analytic cost by more than 5%;
/// `--validate netsim` runs that flow-level pre-filter first, then the
/// full per-message fleet simulation under the same 5% gate.
///
/// Searches are reused content-addressed from `artifacts/plans/` (see
/// `plan::cache`; `--no-cache` bypasses both read and write), and a
/// multi-point `--nodes` list is searched in parallel.
fn plan_cmd(opts: &Opts) -> Result<()> {
    let path = opts
        .str_opt("spec")
        .context("--spec <file> is required (committed figures live in specs/)")?;
    let mut spec = ExperimentSpec::load(path)?;
    if let Some(sets) = opts.str_opt("set") {
        spec.apply_set(sets)?;
    }
    let node_list: Vec<u64> = match opts.str_opt("nodes") {
        Some(list) => parse_list(list, "nodes")?,
        None => vec![spec.cluster.nodes],
    };
    if node_list.iter().any(|&n| n == 0) {
        bail!("--nodes entries must be >= 1");
    }
    if node_list.len() > 1
        && (opts.str_opt("check-golden").is_some() || opts.str_opt("write-golden").is_some())
    {
        bail!(
            "--check-golden/--write-golden work on a single design point (a golden plan is \
             derived for one node count); drop --nodes or pass one value"
        );
    }
    let net = spec.model.resolve()?;
    let platform = resolved_platform(&spec)?;
    let collective = registry::collective(&spec.collective)?;
    let cache = if opts.bool_flag("no-cache") {
        None
    } else {
        Some(PlanCache::new(PlanCache::default_dir()))
    };
    let input_at = |n: u64| planner::PlannerInput {
        net: &net,
        platform: &platform,
        nodes: n,
        minibatch: spec.minibatch.global,
        overlap: spec.parallelism.overlap,
        collective,
        iterations: spec.parallelism.iterations.max(2),
    };
    // every design point is an independent pure search: fan the --nodes
    // list out across threads (cache files are per-key, so concurrent
    // writes never collide)
    let searches: Vec<(planner::PlanSearch, Option<CacheOutcome>)> =
        pcl_dnn::util::par::parallel_map(&node_list, |&n| {
            let input = input_at(n);
            match &cache {
                Some(c) => {
                    let (s, o) = c.plan_cached(spec.model.name(), &input);
                    (s, Some(o))
                }
                None => (planner::plan(&input), None),
            }
        });
    let mut out_doc: Vec<Json> = Vec::new();
    for (&n, (search, outcome)) in node_list.iter().zip(&searches) {
        let input = input_at(n);
        match outcome {
            Some(o) => println!("plan cache: {}", o.describe()),
            None => println!("plan cache: off (--no-cache)"),
        }
        // explicit spec pins still win over the searched plan
        let chosen = apply_pins(&search.plan, &spec.plan, &net)?;
        println!(
            "# design point — {} x{n} on {}, MB={}",
            spec.model.name(),
            spec.platform,
            spec.minibatch.global
        );
        let ms = |c: Option<f64>| c.map(|v| format!("{:.3}", v * 1e3)).unwrap_or_else(|| "-".into());
        let mut t = Table::new(&["layer", "data ms", "model ms", "hybrid ms", "G*", "chosen"]);
        for d in &search.decisions {
            let gstar = d
                .candidates
                .iter()
                .find_map(|c| match c.strategy {
                    comm_model::Strategy::Hybrid { groups } => Some(groups.to_string()),
                    _ => None,
                })
                .unwrap_or_else(|| "-".into());
            t.row(vec![
                d.layer.clone(),
                ms(d.cost_of("data")),
                ms(d.cost_of("model")),
                ms(d.cost_of("hybrid")),
                gstar,
                strategy_name(chosen.strategy_for(&d.layer)).to_string(),
            ]);
        }
        t.print();
        println!("\nchosen plan:");
        chosen.table().print();
        let chosen_s = planner::plan_cost_s(&input, &chosen);
        println!(
            "analytic: auto {:.2} ms/iter vs fixed recipe {:.2} ms vs pure data {:.2} ms \
             ({:+.1}% vs recipe)",
            chosen_s * 1e3,
            search.recipe_iteration_s * 1e3,
            search.data_iteration_s * 1e3,
            100.0 * (chosen_s - search.recipe_iteration_s) / search.recipe_iteration_s
        );
        if let Some(backend) = opts.str_opt("validate") {
            if backend != "netsim" && backend != "flowsim" {
                bail!("--validate {backend}: netsim and flowsim are supported");
            }
            let mut vspec = spec.clone();
            vspec.cluster.nodes = n;
            // clean fabric & fleet: the cross-check compares plan costs,
            // so strip the α-β congestion fudge (the simulators model
            // contention explicitly) AND the fleet imperfections the
            // analytic model cannot express (stragglers/hetero/failures)
            vspec.cluster.congestion = Some(0.0);
            vspec.cluster.straggler_skew = 0.0;
            vspec.cluster.hetero = false;
            vspec.cluster.fail_at = None;
            // the exact-layer pins fully determine the plan; "data" mode
            // keeps the backends from re-running the planner search just
            // to have every layer overwritten by the pins
            vspec.parallelism.mode = "data".into();
            vspec.plan = chosen.as_pins();
            let rep = AnalyticBackend.run(&vspec)?;
            // flow-level check first: it resolves in seconds even at
            // counts where per-message netsim takes minutes, so it is
            // both the cheap pre-filter for --validate netsim and the
            // whole check for --validate flowsim
            let flow = FlowSimBackend.run(&vspec)?;
            let fdelta = (flow.iteration_s - rep.iteration_s) / rep.iteration_s;
            println!(
                "flowsim validation: {:.2} ms vs analytic {:.2} ms ({:+.1}%, {} flows)",
                flow.iteration_s * 1e3,
                rep.iteration_s * 1e3,
                100.0 * fdelta,
                flow.tasks
            );
            if fdelta.abs() > 0.05 {
                bail!(
                    "flowsim disagrees with the analytic cost by {:.1}% (> 5%)",
                    100.0 * fdelta.abs()
                );
            }
            if backend == "netsim" {
                let full = FleetSimBackend.run(&vspec)?;
                let delta = (full.iteration_s - rep.iteration_s) / rep.iteration_s;
                println!(
                    "netsim validation: {:.2} ms vs analytic {:.2} ms ({:+.1}%, {} tasks)",
                    full.iteration_s * 1e3,
                    rep.iteration_s * 1e3,
                    100.0 * delta,
                    full.tasks
                );
                if delta.abs() > 0.05 {
                    bail!(
                        "netsim disagrees with the analytic cost by {:.1}% (> 5%)",
                        100.0 * delta.abs()
                    );
                }
            }
        }
        if let Some(golden_path) = opts.str_opt("check-golden") {
            let golden = PartitionPlan::load(golden_path)?;
            if golden.nodes != n {
                bail!(
                    "golden plan {golden_path} was derived for {} nodes, checking {n}",
                    golden.nodes
                );
            }
            if golden.minibatch != spec.minibatch.global {
                bail!(
                    "golden plan {golden_path} was derived for minibatch {}, checking {}",
                    golden.minibatch,
                    spec.minibatch.global
                );
            }
            golden.validate(&net)?;
            let golden_s = planner::plan_cost_s(&input, &golden);
            if chosen_s > golden_s * 1.005 {
                bail!(
                    "plan regression vs {golden_path}: auto plan prices {:.3} ms/iter, \
                     golden {:.3} ms/iter",
                    chosen_s * 1e3,
                    golden_s * 1e3
                );
            }
            if chosen.assignments != golden.assignments {
                println!(
                    "note: auto plan differs structurally from {golden_path} but is not worse; \
                     regenerate with --write-golden to refresh"
                );
            } else {
                println!("golden check OK ({golden_path})");
            }
        }
        if let Some(out) = opts.str_opt("write-golden") {
            std::fs::write(out, format!("{}\n", chosen.to_json().pretty()))?;
            println!("wrote {out}");
        }
        out_doc.push(chosen.to_json());
        println!();
    }
    let json = if out_doc.len() == 1 { out_doc.remove(0) } else { Json::Arr(out_doc) };
    if opts.bool_flag("json") {
        println!("{json}");
    }
    if let Some(out) = opts.str_opt("out") {
        std::fs::write(out, format!("{}\n", json.pretty()))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `repro failover --spec <file> [--set k=v,...]
/// [--policies stall,replan,shrink] [--backend netsim] [--no-cross-check]
/// [--json] [--out file]`
///
/// Sweep the failure-recovery policies over one spec: for each policy
/// the spec runs with `cluster.recovery` overridden, and the report's
/// recovery section (disruption seconds, itemized replan/redistribution
/// charges, post-failure efficiency at the surviving node count) is
/// tabulated. A spec without a failure event gets a default one
/// injected (`fail_at = 1`, `fail_node` as committed) so the committed
/// figure specs sweep as-is. Unless `--no-cross-check`, each netsim row
/// is paired with the analytic backend's α-β pricing of the same
/// policy and the post-failure-efficiency delta is printed.
fn failover(opts: &Opts) -> Result<()> {
    let path = opts
        .str_opt("spec")
        .context("--spec <file> is required (committed figures live in specs/)")?;
    let mut spec = ExperimentSpec::load(path)?;
    if let Some(sets) = opts.str_opt("set") {
        spec.apply_set(sets)?;
    }
    if spec.cluster.fail_at.is_none() {
        spec.cluster.fail_at = Some(1);
        println!(
            "note: spec has no failure event; injecting fail_at=1 (fail_node {})",
            spec.cluster.fail_node
        );
    }
    // a clean post-failure steady window needs the transition iteration
    // plus a warm-up iteration before the last-minus-previous window
    let min_iters = spec.cluster.fail_at.unwrap_or(0).saturating_add(3);
    if spec.parallelism.iterations < min_iters {
        println!(
            "note: raising parallelism.iterations {} -> {min_iters} for a clean \
             post-failure steady window",
            spec.parallelism.iterations
        );
        spec.parallelism.iterations = min_iters;
    }
    let policies: Vec<String> = opts
        .str_or("policies", "stall,replan,shrink")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().to_string())
        .collect();
    for p in &policies {
        registry::recovery_policy(p)?;
    }
    let backend = backend_by_name(&opts.str_or("backend", "netsim"))?;
    // the cross-check ladder: runtime rows (live fault injection) check
    // against netsim's scheduled prediction, netsim rows against the
    // analytic α-β pricing
    let cross = if opts.bool_flag("no-cross-check") {
        None
    } else {
        match backend.name() {
            "netsim" => Some("analytic"),
            "runtime" => Some("netsim"),
            _ => None,
        }
    };
    println!(
        "# failover — {} x{} on {}, MB={}, fail_at={} fail_node={} (backend {})",
        spec.model.name(),
        spec.cluster.nodes,
        spec.platform,
        spec.minibatch.global,
        spec.cluster.fail_at.unwrap_or(0),
        spec.cluster.fail_node,
        backend.name(),
    );
    let mut cols = vec![
        "policy", "nodes after", "stall s", "replan s", "redist s", "post iter ms",
        "post samples/s", "post eff",
    ];
    let delta_col = cross.map(|r| format!("{r} eff Δ"));
    if let Some(c) = &delta_col {
        cols.push(c.as_str());
    }
    let mut t = Table::new(&cols);
    let mut rows: Vec<Json> = Vec::new();
    let mut best: Option<(String, f64)> = None;
    for policy in &policies {
        let mut s = spec.clone();
        s.cluster.recovery = policy.clone();
        let rep = backend.run(&s)?;
        let rec = pcl_dnn::experiment::RecoveryReport::from_json(&rep.recovery)
            .context("backend report carries no recovery section")?;
        let mut row = vec![
            rec.policy.clone(),
            rec.nodes_after.to_string(),
            format!("{:.3}", rec.stall_s),
            format!("{:.3}", rec.replan_s),
            format!("{:.3}", rec.redistribution_s),
            format!("{:.2}", rec.post_iteration_s * 1e3),
            format!("{:.0}", rec.post_samples_per_s),
            format!("{:.1}%", 100.0 * rec.post_efficiency),
        ];
        let mut doc = match rec.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        doc.insert("backend".to_string(), Json::Str(rep.backend.clone()));
        if let Some(refname) = cross {
            let reference = backend_by_name(refname)?.run(&s)?;
            let arec =
                pcl_dnn::experiment::RecoveryReport::from_json(&reference.recovery)?;
            let delta = (rec.post_efficiency - arec.post_efficiency)
                / arec.post_efficiency.max(1e-9);
            row.push(format!("{:+.1}%", 100.0 * delta));
            doc.insert(
                format!("{refname}_post_efficiency"),
                Json::Num(arec.post_efficiency),
            );
        }
        t.row(row);
        let improves = match &best {
            Some((_, e)) => rec.post_efficiency > *e,
            None => true,
        };
        if improves {
            best = Some((rec.policy.clone(), rec.post_efficiency));
        }
        rows.push(Json::Obj(doc));
    }
    t.print();
    if let Some((policy, eff)) = best {
        println!("best post-failure efficiency: {policy} ({:.1}%)", 100.0 * eff);
    }
    let mut root = std::collections::BTreeMap::new();
    root.insert("policies".to_string(), Json::Arr(rows));
    root.insert("spec".to_string(), Json::Str(spec.name.clone()));
    let json = Json::Obj(root);
    if opts.bool_flag("json") {
        println!("{json}");
    }
    if let Some(out) = opts.str_opt("out") {
        std::fs::write(out, format!("{}\n", json.pretty()))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `repro syncsweep [--spec <file>] [--set k=v,...]
/// [--modes bsp,ssp{2},async-ps] [--skews 0,0.2,0.4] [--nodes 8]
/// [--json] [--out BENCH_sync_modes.json]`
///
/// The sync-vs-async throughput frontier: every synchronization mode
/// runs on the netsim backend at every straggler skew, tabulating
/// iteration time, aggregate throughput, and the speedup over the BSP
/// row at the same skew. The async-ps point at skew 0 is cross-checked
/// against the analytic α-β parameter-server pricing on a clean fabric
/// (the two substrates share the push/pull formula, so they must agree
/// within 10%).
fn syncsweep(opts: &Opts) -> Result<()> {
    let mut spec = match opts.str_opt("spec") {
        Some(path) => ExperimentSpec::load(path)?,
        None => {
            let mut s = ExperimentSpec::of(
                "syncsweep",
                &opts.str_or("net", "vgg_a"),
                &opts.str_or("platform", "cori"),
                opts.parse_or("nodes", 8u64)?,
                opts.parse_or("minibatch", 256u64)?,
            );
            s.parallelism.mode = "data".into();
            s
        }
    };
    if let Some(sets) = opts.str_opt("set") {
        spec.apply_set(sets)?;
    }
    // drift-bounded timelines need a pure data-parallel plan and no
    // failure event (the non-bsp builders reject both)
    spec.parallelism.mode = "data".into();
    spec.cluster.fail_at = None;
    // enough iterations for per-node drift to reach steady state
    if spec.parallelism.iterations < 4 {
        spec.parallelism.iterations = 4;
    }
    let modes: Vec<String> = opts
        .str_or("modes", "bsp,ssp{2},async-ps")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().to_string())
        .collect();
    for m in &modes {
        registry::sync_mode(m)?;
    }
    let skews: Vec<f64> = parse_list(&opts.str_or("skews", "0,0.2,0.4"), "skews")?;
    println!(
        "# syncsweep — {} x{} on {}, MB={} (netsim backend)",
        spec.model.name(),
        spec.cluster.nodes,
        spec.platform,
        spec.minibatch.global
    );
    let mut t = Table::new(&["sync", "skew", "iter ms", "samples/s", "vs bsp"]);
    let mut rows: Vec<Json> = Vec::new();
    for &skew in &skews {
        let mut bsp_iter: Option<f64> = None;
        for mode in &modes {
            let mut s = spec.clone();
            s.parallelism.sync = mode.clone();
            s.cluster.straggler_skew = skew;
            let r = FleetSimBackend.run(&s)?;
            if registry::sync_mode(mode)?.is_bsp() {
                bsp_iter = Some(r.iteration_s);
            }
            t.row(vec![
                mode.clone(),
                format!("{skew:.2}"),
                format!("{:.2}", r.iteration_s * 1e3),
                format!("{:.0}", r.samples_per_s),
                bsp_iter
                    .map(|b| format!("{:.2}x", b / r.iteration_s))
                    .unwrap_or_else(|| "—".into()),
            ]);
            let mut doc = std::collections::BTreeMap::new();
            doc.insert("backend".to_string(), Json::Str(r.backend.clone()));
            doc.insert("iteration_s".to_string(), Json::Num(r.iteration_s));
            doc.insert("samples_per_s".to_string(), Json::Num(r.samples_per_s));
            doc.insert("skew".to_string(), Json::Num(skew));
            doc.insert("sync".to_string(), Json::Str(mode.clone()));
            doc.insert(
                "vs_bsp".to_string(),
                match bsp_iter {
                    Some(b) => Json::Num(b / r.iteration_s),
                    None => Json::Null,
                },
            );
            rows.push(Json::Obj(doc));
        }
    }
    t.print();
    // clean-fabric agreement gate: netsim's per-message PS exchange vs
    // the analytic α-β closed form, on the async-ps mode where the
    // collective is fully replaced
    let mut c = spec.clone();
    c.parallelism.sync = "async-ps".into();
    c.cluster.straggler_skew = 0.0;
    c.cluster.hetero = false;
    c.cluster.congestion = Some(0.0);
    let sim = FleetSimBackend.run(&c)?;
    let ana = AnalyticBackend.run(&c)?;
    let delta = (sim.iteration_s - ana.iteration_s) / ana.iteration_s;
    println!(
        "async-ps cross-check (clean fabric): netsim {:.2} ms vs analytic {:.2} ms ({:+.1}%)",
        sim.iteration_s * 1e3,
        ana.iteration_s * 1e3,
        100.0 * delta
    );
    if delta.abs() > 0.10 {
        bail!(
            "netsim disagrees with the analytic parameter-server pricing by {:.1}% (> 10%)",
            100.0 * delta.abs()
        );
    }
    let mut check = std::collections::BTreeMap::new();
    check.insert("analytic_iteration_s".to_string(), Json::Num(ana.iteration_s));
    check.insert("delta".to_string(), Json::Num(delta));
    check.insert("netsim_iteration_s".to_string(), Json::Num(sim.iteration_s));
    let mut root = std::collections::BTreeMap::new();
    root.insert("cross_check".to_string(), Json::Obj(check));
    root.insert("rows".to_string(), Json::Arr(rows));
    root.insert("spec".to_string(), Json::Str(spec.name.clone()));
    let json = Json::Obj(root);
    if opts.bool_flag("json") {
        println!("{json}");
    }
    if let Some(out) = opts.str_opt("out") {
        std::fs::write(out, format!("{}\n", json.pretty()))?;
        println!("wrote {out}");
    }
    Ok(())
}

// ---------------------------------------------------------------------
// inventory + analytic tables (not experiments; spec-less by design)
// ---------------------------------------------------------------------

fn info(opts: &Opts) -> Result<()> {
    let dir = default_artifacts(opts);
    let rt = Runtime::new(&dir).context("artifacts not built? run `make artifacts`")?;
    println!("platform: {}", rt.platform());
    println!("artifacts dir: {dir}");
    let mut t = Table::new(&["artifact", "kind", "model", "batch", "inputs", "outputs"]);
    for (name, a) in &rt.manifest().artifacts {
        t.row(vec![
            name.clone(),
            a.kind.clone(),
            a.model.clone().unwrap_or_default(),
            a.batch.to_string(),
            a.inputs.len().to_string(),
            a.outputs.len().to_string(),
        ]);
    }
    t.print();
    let mut t = Table::new(&["model", "params", "elements"]);
    for (name, m) in &rt.manifest().models {
        t.row(vec![name.clone(), m.params.len().to_string(), m.n_elements.to_string()]);
    }
    t.print();
    println!("\nregistered zoo models: {}", registry::model_names().join(", "));
    println!("registered platforms:  {}", registry::platform_names().join(", "));
    Ok(())
}

fn analyze(opts: &Opts) -> Result<()> {
    match opts.pos(1) {
        Some("table1") => {
            println!("# Table 1 — Theoretical scaling of data parallelism");
            println!("(paper: comp-to-comms 1336 / 336; OverFeat 3 (86) / 2 (128); VGG-A 1 (256) / 1 (256))\n");
            let platforms = [
                ("2s9c E5-2666v3 + 10GbE", Platform::table1_ethernet()),
                ("2s16c E5-2698v3 + FDR", Platform::table1_fdr()),
            ];
            let mut t = Table::new(&["", platforms[0].0, platforms[1].0]);
            t.row(vec![
                "Comp-to-comms (FLOPs/byte)".into(),
                format!("{:.0}", platforms[0].1.comp_to_comms()),
                format!("{:.0}", platforms[1].1.comp_to_comms()),
            ]);
            for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
                let cells: Vec<String> = platforms
                    .iter()
                    .map(|(_, p)| {
                        let (mb, n) = scaling::table1_row(&net, p, 256);
                        format!("{mb} ({n})")
                    })
                    .collect();
                t.row(vec![net.name.clone(), cells[0].clone(), cells[1].clone()]);
            }
            t.print();
            println!("\nconv-trunk comp/comm ratios (paper: OverFeat 208, VGG-A 1456):");
            for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
                println!("  {}: {:.0}", net.name, net.conv_comp_comm_ratio(1));
            }
            Ok(())
        }
        Some("cache-blocking") => {
            let budget = opts.parse_or("budget", 128 * 1024u64)?;
            let simd = opts.parse_or("simd", 8u64)?;
            let mb = opts.parse_or("mb", 1u64)?;
            let net = registry::model(&opts.str_or("net", "overfeat_fast"))?;
            let cfg = cache_blocking::SearchCfg { budget, simd, double_buffer: true, max_mb: mb };
            println!(
                "# §2.2 cache-blocking search — budget {} KB, SIMD {simd}, max mb {mb}",
                budget / 1024
            );
            let mut t = Table::new(&[
                "layer",
                "B/F (row)",
                "B/F (best)",
                "blocking (mb,ofm,oh,ow,ifm,kh,kw)",
                "bytes",
            ]);
            for l in net.layers.iter().filter(|l| l.is_conv()) {
                let row_bf = compute_model::bf_ratio_row(l).unwrap();
                match cache_blocking::search(l, &cfg) {
                    Some(b) => t.row(vec![
                        l.name.clone(),
                        format!("{row_bf:.3}"),
                        format!("{:.4}", b.bf),
                        format!(
                            "({},{},{},{},{},{},{})",
                            b.mb_b, b.ofm_b, b.oh_b, b.ow_b, b.ifm_b, b.kh_b, b.kw_b
                        ),
                        b.bytes.to_string(),
                    ]),
                    None => t.row(vec![
                        l.name.clone(),
                        format!("{row_bf:.3}"),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]),
                }
            }
            t.print();
            Ok(())
        }
        Some("register-blocking") => {
            println!("# §2.4 register-blocking efficiency (Haswell: 2 VFMA/cyc, latency 5)");
            println!(
                "RB bounds: {} <= RB <= {}\n",
                register_blocking::min_rb(),
                register_blocking::max_rb()
            );
            let m = register_blocking::cycle_model(12, 8, 3);
            println!(
                "fwd C5 example (RB=1x12, SW=8, 3 taps): loads {:.0}cyc stores {:.0}cyc FMA {:.0}cyc -> efficiency {:.1}% (paper: 88%)\n",
                m.load_cycles,
                m.store_cycles,
                m.fma_cycles,
                100.0 * m.efficiency
            );
            let mut t = Table::new(&["kernel", "naive 2-D eff", "strategy", "strategy eff"]);
            for k in [3u64, 5, 7, 11] {
                let (desc, _, _) = register_blocking::weight_grad_strategy(k);
                t.row(vec![
                    format!("{k}x{k}"),
                    format!("{:.0}%", 100.0 * register_blocking::weight_grad_naive_efficiency(k)),
                    desc.to_string(),
                    format!(
                        "{:.0}%",
                        100.0 * register_blocking::weight_grad_strategy_efficiency(k)
                    ),
                ]);
            }
            t.print();
            Ok(())
        }
        Some("hybrid") => {
            let minibatch = opts.parse_or("minibatch", 256u64)?;
            let n = opts.parse_or("nodes", 64u64)?;
            let ofm = opts.parse_or("ofm", 4096u64)?;
            let ifm = opts.parse_or("ifm", 4096u64)?;
            let layer = pcl_dnn::models::Layer::fc("fc", ifm, ofm);
            println!("# §3.3 hybrid parallelism — FC {ifm}x{ofm}, MB={minibatch}, N={n}");
            println!(
                "continuous optimum G* = sqrt(N*MB/ofm) = {:.2}",
                comm_model::optimal_groups_continuous(ofm, minibatch, n)
            );
            let mut t = Table::new(&["G", "bytes/node (overlap=0)", "bytes/node (overlap=1)"]);
            for g in (1..=n).filter(|g| n % g == 0) {
                t.row(vec![
                    g.to_string(),
                    format!("{:.0}", comm_model::hybrid_bytes(&layer, minibatch, n, g, 0.0)),
                    format!("{:.0}", comm_model::hybrid_bytes(&layer, minibatch, n, g, 1.0)),
                ]);
            }
            t.print();
            for overlap in [0.0, 1.0] {
                println!(
                    "best G (overlap={overlap}): {}",
                    comm_model::optimal_groups(&layer, minibatch, n, overlap)
                );
            }
            Ok(())
        }
        Some("fig3") => {
            println!("# Fig 3 — single-node throughput model (E5-2698v3)");
            println!("(paper: OverFeat ~315 FP / ~90 FP+BP; VGG-A ~95 FP / ~30 FP+BP)\n");
            let m = pcl_dnn::analytic::MachineSpec::e5_2698v3();
            let mut t = Table::new(&["net", "mode", "MB16", "MB32", "MB64", "MB128", "MB256"]);
            for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
                for (mode, training) in [("FP", false), ("FP+BP", true)] {
                    let row = compute_model::fig3_row(&net, &m, training);
                    let mut cells = vec![net.name.clone(), mode.into()];
                    cells.extend(row.iter().map(|(_, v)| format!("{v:.0}")));
                    t.row(cells);
                }
            }
            t.print();
            Ok(())
        }
        Some("kernel-blocking") => {
            println!("# L1 Pallas kernel tile analysis (TPU estimates; interpret=True on CPU)");
            let budget = opts.parse_or("vmem", 8u64 << 20)?;
            let cfg =
                cache_blocking::SearchCfg { budget, simd: 128, double_buffer: true, max_mb: 8 };
            let net = registry::model(&opts.str_or("net", "overfeat_fast"))?;
            let mut t = Table::new(&[
                "layer",
                "tile (mb,ofm,oh,ow,ifm)",
                "VMEM KB",
                "HBM B/F",
                "MXU util",
            ]);
            for l in net.layers.iter().filter(|l| l.is_conv()) {
                if let Some(b) = cache_blocking::search(l, &cfg) {
                    let mxu = register_blocking::mxu_utilization(
                        b.mb_b * b.oh_b * b.ow_b,
                        b.ofm_b,
                        b.ifm_b * b.kh_b * b.kw_b,
                    );
                    t.row(vec![
                        l.name.clone(),
                        format!("({},{},{},{},{})", b.mb_b, b.ofm_b, b.oh_b, b.ow_b, b.ifm_b),
                        format!("{}", b.bytes / 1024),
                        format!("{:.4}", b.bf),
                        format!("{:.0}%", 100.0 * mxu),
                    ]);
                }
            }
            t.print();
            Ok(())
        }
        other => bail!("unknown analyze target {other:?}"),
    }
}

// ---------------------------------------------------------------------
// compatibility aliases — thin spec builders over the same backends
// ---------------------------------------------------------------------

/// Spec built from the shared `simulate` flags (`--net`, `--platform`,
/// `--minibatch`, `--no-hybrid`, topology/fleet knobs).
fn spec_from_flags(opts: &Opts, net: &str, platform: &str, minibatch: u64) -> Result<ExperimentSpec> {
    let mut spec = ExperimentSpec::of(
        "cli",
        &opts.str_or("net", net),
        &opts.str_or("platform", platform),
        opts.parse_or("nodes", 16u64)?,
        opts.parse_or("minibatch", minibatch)?,
    );
    if opts.bool_flag("no-hybrid") {
        spec.parallelism.mode = "data".into();
    }
    spec.parallelism.iterations = opts.parse_or("iterations", spec.parallelism.iterations)?;
    spec.collective = opts.str_or("collective", "auto");
    // validated by registry::topology when the backend runs (it also
    // accepts the fat-tree alias and lists the inventory on a typo)
    spec.cluster.topology = opts.str_or("topology", "switched");
    spec.cluster.radix = opts.parse_or("radix", 8usize)?;
    spec.cluster.oversub = opts.parse_or("oversub", 2.0f64)?;
    spec.cluster.straggler_skew = opts.parse_or("straggler-skew", 0.0f64)?;
    spec.cluster.hetero = opts.bool_flag("hetero");
    spec.cluster.fail_at = opts
        .str_opt("fail-at")
        .map(str::parse::<usize>)
        .transpose()
        .map_err(|e| anyhow::anyhow!("--fail-at: {e}"))?;
    spec.cluster.fail_node = opts.parse_or("fail-node", 0usize)?;
    spec.cluster.recovery_s = opts.parse_or("recovery", 5.0f64)?;
    Ok(spec)
}

fn print_curve(title: &str, reports: &[ScalingReport]) {
    println!("{title}");
    report_table(reports);
    println!();
}

fn simulate(opts: &Opts) -> Result<()> {
    let figure = opts.pos(1).unwrap_or("sweep");
    match figure {
        "fig4" => {
            deprecated("simulate fig4", "run --spec specs/fig4.json --sweep-nodes 1,2,...,128");
            println!("# Fig 4 — VGG-A scaling on Cori (simulated)");
            println!("(paper: 90x @128 nodes MB=512 / 2510 img/s; 82% eff @64 nodes MB=256)\n");
            let nodes = [1u64, 2, 4, 8, 16, 32, 64, 128];
            for mb in [256u64, 512] {
                let mut spec = ExperimentSpec::fig4();
                spec.minibatch = MinibatchSpec { global: mb };
                let curve = run_sweep(&AnalyticBackend, &spec, &nodes)?;
                print_curve(&format!("minibatch {mb}:"), &curve);
            }
            Ok(())
        }
        "fig6" => {
            deprecated("simulate fig6", "run --spec specs/fig6_overfeat.json (and fig6_vgg.json)");
            println!("# Fig 6 — OverFeat & VGG-A on AWS EC2, MB=256 (simulated)");
            println!("(paper @16 nodes: OverFeat 1027 img/s = 11.9x; VGG-A 397 img/s = 14.2x)\n");
            let nodes = [1u64, 2, 4, 8, 16];
            for spec in [ExperimentSpec::fig6_overfeat(), ExperimentSpec::fig6_vgg()] {
                let curve = run_sweep(&AnalyticBackend, &spec, &nodes)?;
                print_curve(&format!("{}:", spec.model.name()), &curve);
            }
            Ok(())
        }
        "fig7" => {
            deprecated("simulate fig7", "run --spec specs/fig7.json --sweep-nodes 1,2,4,8,16");
            println!("# Fig 7 — CD-DNN scaling on Endeavor, MB=1024 frames (simulated)");
            println!("(paper: 4600 f/s @1 node; ~13K @4; 29.5K @16 = 6.4x)\n");
            let nodes = [1u64, 2, 4, 8, 16];
            let spec = ExperimentSpec::fig7();
            let curve = run_sweep(&AnalyticBackend, &spec, &nodes)?;
            print_curve("hybrid FCs (paper recipe):", &curve);
            let mut ablation = spec.clone();
            ablation.parallelism.mode = "data".into();
            let curve = run_sweep(&AnalyticBackend, &ablation, &nodes)?;
            print_curve("ablation — pure data parallelism (no hybrid FCs):", &curve);
            Ok(())
        }
        "sweep" => {
            deprecated("simulate sweep", "run --spec <file> --sweep-nodes 1,2,4,...");
            let spec = spec_from_flags(opts, "vgg_a", "cori", 256)?;
            let max_nodes = opts.parse_or("nodes", 128u64)?;
            let mut nodes = vec![];
            let mut n = 1u64;
            while n <= max_nodes {
                nodes.push(n);
                n *= 2;
            }
            println!(
                "# sweep — {} on {}, MB={}, mode={}",
                spec.model.name(),
                spec.platform,
                spec.minibatch.global,
                spec.parallelism.mode
            );
            let curve = run_sweep(&AnalyticBackend, &spec, &nodes)?;
            report_table(&curve);
            Ok(())
        }
        "full" => {
            deprecated(
                "simulate full",
                "run --spec <file> --backend netsim (plus --backend analytic --set congestion=0 \
                 for the cross-check)",
            );
            let spec = spec_from_flags(opts, "vgg_a", "cori", 256)?;
            println!(
                "# full-cluster simulation — {} x{} on {}, MB={}, topology={}",
                spec.model.name(),
                spec.cluster.nodes,
                spec.platform,
                spec.minibatch.global,
                spec.cluster.topology
            );
            let full = FleetSimBackend.run(&spec)?;
            // the α-β cross-check strips congestion_per_doubling: that term
            // is the representative model's empirical stand-in for the
            // contention the full simulator models explicitly per link
            let mut clean = spec.clone();
            clean.cluster.congestion = Some(0.0);
            let rep = AnalyticBackend.run(&clean)?;
            report_table(&[full.clone(), rep.clone()]);
            println!(
                "{} simulated tasks; full vs α-β delta {:+.1}% (expect ~0 on a homogeneous \
                 switched fabric)",
                full.tasks,
                100.0 * (full.iteration_s - rep.iteration_s) / rep.iteration_s
            );
            Ok(())
        }
        "stragglers" => {
            deprecated(
                "simulate stragglers",
                "run --spec <file> --backend netsim --set straggler_skew=<s>",
            );
            let spec = spec_from_flags(opts, "vgg_a", "cori", 256)?;
            let skews: Vec<f64> = parse_list(&opts.str_or("skews", "0,0.1,0.25,0.5,1.0"), "skews")?;
            println!(
                "# straggler sweep — {} x{} on {}, MB={}",
                spec.model.name(),
                spec.cluster.nodes,
                spec.platform,
                spec.minibatch.global
            );
            let mut t = Table::new(&["skew", "iter ms", "samples/s", "slowdown", "min util"]);
            let mut base = 0.0;
            for &skew in &skews {
                let mut s = spec.clone();
                s.cluster.straggler_skew = skew;
                let r = FleetSimBackend.run(&s)?;
                if base == 0.0 {
                    base = r.iteration_s;
                }
                t.row(vec![
                    format!("{skew:.2}"),
                    format!("{:.2}", r.iteration_s * 1e3),
                    format!("{:.0}", r.samples_per_s),
                    format!("{:.2}x", r.iteration_s / base),
                    format!("{:.0}%", 100.0 * r.min_compute_utilization),
                ]);
            }
            t.print();
            Ok(())
        }
        "contention" => {
            deprecated(
                "simulate contention",
                "run --spec <file> --backend netsim --set topology=fattree,oversub=<x>",
            );
            let mut spec = spec_from_flags(opts, "cddnn_full", "aws", 1024)?;
            spec.cluster.radix =
                opts.parse_or("radix", (spec.cluster.nodes as usize / 2).max(2))?;
            let oversubs: Vec<f64> = parse_list(&opts.str_or("oversubs", "1,2,4,8"), "oversubs")?;
            println!(
                "# contention sweep — {} x{} on {}, MB={}, leaf radix {}",
                spec.model.name(),
                spec.cluster.nodes,
                spec.platform,
                spec.minibatch.global,
                spec.cluster.radix
            );
            let mut flat_spec = spec.clone();
            flat_spec.cluster.topology = "flat".into();
            let flat = FleetSimBackend.run(&flat_spec)?;
            let mut t = Table::new(&["core", "iter ms", "samples/s", "vs flat"]);
            t.row(vec![
                "flat switch".into(),
                format!("{:.2}", flat.iteration_s * 1e3),
                format!("{:.0}", flat.samples_per_s),
                "1.00x".into(),
            ]);
            for &oversub in &oversubs {
                let mut s = spec.clone();
                s.cluster.topology = "fattree".into();
                s.cluster.oversub = oversub;
                let r = FleetSimBackend.run(&s)?;
                t.row(vec![
                    format!("fat-tree {oversub}:1"),
                    format!("{:.2}", r.iteration_s * 1e3),
                    format!("{:.0}", r.samples_per_s),
                    format!("{:.2}x", r.iteration_s / flat.iteration_s),
                ]);
            }
            t.print();
            Ok(())
        }
        other => bail!("unknown figure {other:?} (fig4|fig6|fig7|sweep|full|stragglers|contention)"),
    }
}

fn train(opts: &Opts) -> Result<()> {
    deprecated(
        "train",
        "run --spec <file> --backend runtime (execution.{workers,steps,lr,...} in the spec)",
    );
    let spec = ExperimentSpec {
        name: "train".into(),
        model: ModelSpec::Zoo(opts.str_or("model", "vgg_tiny")),
        minibatch: MinibatchSpec { global: opts.parse_or("minibatch", 16u64)? },
        execution: ExecutionSpec {
            fidelity: "runtime".into(),
            model: None,
            workers: Some(opts.parse_or("workers", 1usize)?),
            steps: opts.parse_or("steps", 50u64)?,
            lr: opts.parse_or("lr", 0.01f64)?,
            momentum: opts.parse_or("momentum", 0.0f64)?,
            seed: opts.parse_or("seed", 0u64)?,
            log_every: opts.parse_or("log-every", 10u64)?,
            eval_every: opts.parse_or("eval-every", 0u64)?,
            optimizer: opts.str_or("optimizer", "sgd"),
            prefetch: opts.parse_or("prefetch", 8usize)?,
            checkpoint: match opts.parse_or("checkpoint", 0u64)? {
                0 => None,
                n => Some(n),
            },
            artifacts: default_artifacts(opts),
        },
        ..Default::default()
    };
    let (report, outcome) = run_runtime(&spec)?;
    println!(
        "done: {} steps, final loss {:.4}, mean {:.1} samples/s",
        spec.execution.steps,
        outcome.history.final_loss().unwrap_or(f64::NAN),
        outcome.history.mean_throughput()
    );
    report_table(&[report]);
    if let Some(path) = opts.str_opt("csv") {
        outcome.history.save_csv(path)?;
        println!("loss curve written to {path}");
    }
    Ok(())
}

fn score(opts: &Opts) -> Result<()> {
    let dir = default_artifacts(opts);
    let mut rt = Runtime::new(&dir)?;
    let model = opts.str_or("model", "vgg_tiny");
    let batches = opts.parse_or("batches", 20u64)?;
    let tput = trainer::score_throughput(&mut rt, &model, batches, 0)?;
    println!("{model}: {tput:.1} samples/s scoring throughput ({batches} batches)");
    Ok(())
}
