//! The topology zoo.
//!
//! Full-size descriptors follow the published architectures the paper
//! evaluates (VGG-A / Simonyan & Zisserman 2014; OverFeat-FAST / Sermanet
//! et al. 2013; CD-DNN / Seide et al. 2011). Tiny descriptors mirror the
//! runnable AOT models defined in `python/compile/models/` exactly.

use super::layers::{Layer, NetDescriptor};

/// VGG-A (VGG-11), 224x224x3 input, ImageNet-1k head.
pub fn vgg_a() -> NetDescriptor {
    NetDescriptor::new(
        "vgg_a",
        vec![
            Layer::conv("conv1", 3, 64, 3, 1, 226, 224),
            Layer::pool("pool1", 64, 112),
            Layer::conv("conv2", 64, 128, 3, 1, 114, 112),
            Layer::pool("pool2", 128, 56),
            Layer::conv("conv3_1", 128, 256, 3, 1, 58, 56),
            Layer::conv("conv3_2", 256, 256, 3, 1, 58, 56),
            Layer::pool("pool3", 256, 28),
            Layer::conv("conv4_1", 256, 512, 3, 1, 30, 28),
            Layer::conv("conv4_2", 512, 512, 3, 1, 30, 28),
            Layer::pool("pool4", 512, 14),
            Layer::conv("conv5_1", 512, 512, 3, 1, 16, 14),
            Layer::conv("conv5_2", 512, 512, 3, 1, 16, 14),
            Layer::pool("pool5", 512, 7),
            Layer::fc("fc6", 25088, 4096),
            Layer::fc("fc7", 4096, 4096),
            Layer::fc("fc8", 4096, 1000),
        ],
    )
}

/// OverFeat-FAST, 231x231x3 input. Layer C5 (paper §2.2's running example:
/// 12x12 output, 3x3 kernel, 512 ifm, 1024 ofm) appears here under its
/// paper-quoted shape.
pub fn overfeat_fast() -> NetDescriptor {
    NetDescriptor::new(
        "overfeat_fast",
        vec![
            Layer::conv("c1", 3, 96, 11, 4, 231, 56),
            Layer::pool("pool1", 96, 28),
            Layer::conv("c2", 96, 256, 5, 1, 28, 24),
            Layer::pool("pool2", 256, 12),
            Layer::conv("c3", 256, 512, 3, 1, 14, 12),
            Layer::conv("c4", 512, 1024, 3, 1, 14, 12),
            Layer::conv("c5", 1024, 1024, 3, 1, 14, 12),
            Layer::pool("pool5", 1024, 6),
            Layer::fc("fc6", 36864, 3072),
            Layer::fc("fc7", 3072, 4096),
            Layer::fc("fc8", 4096, 1000),
        ],
    )
}

/// The §2.2 running-example conv layer: "12*12 output, 3*3 kernel, 512
/// input feature maps and 1024 output feature maps (such as C5 in
/// OverFeat-FAST)".
pub fn overfeat_c5_paper() -> Layer {
    Layer::conv("c5_paper", 512, 1024, 3, 1, 14, 12)
}

/// CD-DNN acoustic model (paper §5.4): 429 -> 7 x 2048 -> 9304 senones.
pub fn cddnn_full() -> NetDescriptor {
    let mut layers = vec![Layer::fc("h0", 429, 2048)];
    for i in 1..7 {
        layers.push(Layer::fc(&format!("h{i}"), 2048, 2048));
    }
    layers.push(Layer::fc("senone", 2048, 9304));
    NetDescriptor::new("cddnn_full", layers)
}

/// Runnable tiny VGG-A (mirrors `python/compile/models/cnn.py::VGG_TINY`).
pub fn vgg_tiny() -> NetDescriptor {
    NetDescriptor::new(
        "vgg_tiny",
        vec![
            Layer::conv("conv0", 3, 8, 3, 1, 34, 32),
            Layer::pool("pool0", 8, 16),
            Layer::conv("conv1", 8, 16, 3, 1, 18, 16),
            Layer::pool("pool1", 16, 8),
            Layer::conv("conv2", 16, 32, 3, 1, 10, 8),
            Layer::conv("conv3", 32, 32, 3, 1, 10, 8),
            Layer::pool("pool3", 32, 4),
            Layer::conv("conv4", 32, 64, 3, 1, 6, 4),
            Layer::conv("conv5", 64, 64, 3, 1, 6, 4),
            Layer::pool("pool5", 64, 2),
            Layer::conv("conv6", 64, 64, 3, 1, 4, 2),
            Layer::conv("conv7", 64, 64, 3, 1, 4, 2),
            Layer::pool("pool7", 64, 1),
            Layer::fc("fc0", 64, 128),
            Layer::fc("fc1", 128, 64),
            Layer::fc("head", 64, 10),
        ],
    )
}

/// Runnable tiny OverFeat (mirrors `OVERFEAT_TINY` in python).
pub fn overfeat_tiny() -> NetDescriptor {
    NetDescriptor::new(
        "overfeat_tiny",
        vec![
            Layer::conv("c0", 3, 16, 5, 2, 32, 14),
            Layer::pool("pool0", 16, 7),
            Layer::conv("c1", 16, 32, 3, 1, 7, 5),
            Layer::conv("c2", 32, 64, 3, 1, 7, 5),
            Layer::conv("c3", 64, 64, 3, 1, 7, 5),
            Layer::fc("fc0", 1600, 192),
            Layer::fc("fc1", 192, 96),
            Layer::fc("head", 96, 10),
        ],
    )
}

/// Runnable tiny CD-DNN (mirrors `CDDNN_TINY` in python).
pub fn cddnn_tiny() -> NetDescriptor {
    let mut layers = vec![Layer::fc("h0", 429, 256)];
    for i in 1..7 {
        layers.push(Layer::fc(&format!("h{i}"), 256, 256));
    }
    layers.push(Layer::fc("senone", 256, 128));
    NetDescriptor::new("cddnn_tiny", layers)
}

/// Transformer block stack expressed as FC layers over tokens — lets the
/// analytic engine and simulator reason about the e2e LM workload with the
/// same machinery as the paper's DNN (attention matmuls included as FCs;
/// the softmax/elementwise parts are negligible at these scales).
pub fn gpt_descriptor(name: &str, d_model: u64, n_layers: u64, vocab: u64) -> NetDescriptor {
    let mut layers = Vec::new();
    for i in 0..n_layers {
        layers.push(Layer::fc(&format!("b{i}.qkv"), d_model, 3 * d_model));
        // two attention applications (QK^T and PV) ~ d_head * seq each;
        // modeled as a d->d FC per token pair of matmuls:
        layers.push(Layer::fc(&format!("b{i}.att"), d_model, d_model));
        layers.push(Layer::fc(&format!("b{i}.proj"), d_model, d_model));
        layers.push(Layer::fc(&format!("b{i}.mlp1"), d_model, 4 * d_model));
        layers.push(Layer::fc(&format!("b{i}.mlp2"), 4 * d_model, d_model));
    }
    layers.push(Layer::fc("lm_head", d_model, vocab));
    NetDescriptor::new(name, layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg_a_forward_flops_match_paper_footnote() {
        // Paper footnote 1: "VGG-A needs 33.6 GFlops per image" (training).
        // Our training accounting (3x fwd, first conv 2x) should land in
        // the same ballpark; fwd alone is ~15.2 GFLOP.
        let net = vgg_a();
        let fwd = net.fwd_flops_per_image() as f64 / 1e9;
        assert!((14.0..17.0).contains(&fwd), "fwd GFLOP {fwd}");
        let train = net.train_flops_per_image() as f64 / 1e9;
        assert!((30.0..50.0).contains(&train), "train GFLOP {train}");
    }

    #[test]
    fn comp_comm_ratios_match_paper_s31() {
        // §3.1: "The algorithmic computation-to-communication ratio [of]
        // convolutional layers of OverFeat-FAST and VGG-A are 208 and 1456"
        // (units: FLOPs per byte at MB_node=1, overlap=1).
        let of = overfeat_fast().conv_comp_comm_ratio(1);
        let vg = vgg_a().conv_comp_comm_ratio(1);
        assert!((150.0..280.0).contains(&of), "overfeat ratio {of}");
        assert!((1100.0..1800.0).contains(&vg), "vgg ratio {vg}");
        // VGG-A's ratio is ~7x OverFeat's — the fact Fig 6 leans on.
        assert!(vg / of > 4.0);
    }

    #[test]
    fn cddnn_dims() {
        let net = cddnn_full();
        assert_eq!(net.layers.len(), 8);
        // ~45M params: 429*2048 + 6*2048^2 + 2048*9304
        let w = net.weight_elems();
        assert!((40_000_000..50_000_000).contains(&w), "{w}");
    }

    #[test]
    fn vgg_weight_bytes_are_imagenet_scale() {
        // VGG-A has ~133M params (FC-dominated).
        let w = vgg_a().weight_elems();
        assert!((125_000_000..140_000_000).contains(&w), "{w}");
    }

    #[test]
    fn overfeat_c5_paper_shape() {
        let c5 = overfeat_c5_paper();
        assert_eq!(c5.weight_elems(), 512 * 1024 * 9);
        assert_eq!(c5.out_elems(), 1024 * 144);
    }
}
