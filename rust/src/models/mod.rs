//! Network topology descriptors.
//!
//! The analytic engine (paper §2-3) and the cluster simulator work from
//! *layer descriptors* — shapes only, no weights. The zoo carries both the
//! paper's full-size topologies (VGG-A, OverFeat-FAST, CD-DNN: used for
//! Table 1 and Figs 3/4/6/7) and the scaled-down runnable variants that
//! match the AOT artifacts built by `python/compile/`.

pub mod layers;
pub mod zoo;

pub use layers::{Layer, LayerKind, NetDescriptor};
pub use zoo::{
    cddnn_full, cddnn_tiny, gpt_descriptor, overfeat_fast, overfeat_tiny, vgg_a, vgg_tiny,
};
