//! Layer descriptors and their compute/footprint accounting (paper §2.1).
//!
//! Every compute layer is the 2k+3-nested loop of Algorithm 1; a
//! fully-connected layer is the `k_h = k_w = out_h = out_w = 1` special
//! case. All FLOP counts follow the paper's convention: one
//! multiply-accumulate = 2 FLOPs, and training = fwd + bprop + wt-grad =
//! 3x the forward FLOPs (the first layer skips bprop, handled by
//! [`NetDescriptor::train_flops_per_image`]).



/// Bytes per element; the paper (and our artifacts) are FP32 throughout.
pub const SIZE_DATA: u64 = 4;

/// One layer of a network topology.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// 2-D convolution: `ifm -> ofm` feature maps, `k x k` kernel.
    Conv {
        ifm: u64,
        ofm: u64,
        k: u64,
        stride: u64,
        /// Output spatial size (post-convolution).
        out_h: u64,
        out_w: u64,
        /// Input spatial size (pre-convolution, post-padding).
        in_h: u64,
        in_w: u64,
    },
    /// Fully-connected: `in_dim -> out_dim`.
    Fc { in_dim: u64, out_dim: u64 },
    /// Max-pooling (no weights; negligible compute, tracked for shapes).
    Pool { ch: u64, out_h: u64, out_w: u64, window: u64 },
}

#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
}

impl Layer {
    pub fn conv(
        name: &str,
        ifm: u64,
        ofm: u64,
        k: u64,
        stride: u64,
        in_hw: u64,
        out_hw: u64,
    ) -> Self {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Conv {
                ifm,
                ofm,
                k,
                stride,
                out_h: out_hw,
                out_w: out_hw,
                in_h: in_hw,
                in_w: in_hw,
            },
        }
    }

    pub fn fc(name: &str, in_dim: u64, out_dim: u64) -> Self {
        Layer { name: name.to_string(), kind: LayerKind::Fc { in_dim, out_dim } }
    }

    pub fn pool(name: &str, ch: u64, out_hw: u64) -> Self {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Pool { ch, out_h: out_hw, out_w: out_hw, window: 2 },
        }
    }

    /// Forward FLOPs for ONE image (2 * MACs, paper §3.1).
    pub fn fwd_flops(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { ifm, ofm, k, out_h, out_w, .. } => {
                2 * ifm * ofm * k * k * out_h * out_w
            }
            LayerKind::Fc { in_dim, out_dim } => 2 * in_dim * out_dim,
            LayerKind::Pool { ch, out_h, out_w, window } => ch * out_h * out_w * window * window,
        }
    }

    /// Weight (= weight-gradient) element count.
    pub fn weight_elems(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { ifm, ofm, k, .. } => ifm * ofm * k * k,
            LayerKind::Fc { in_dim, out_dim } => in_dim * out_dim,
            LayerKind::Pool { .. } => 0,
        }
    }

    pub fn weight_bytes(&self) -> u64 {
        SIZE_DATA * self.weight_elems()
    }

    /// Output activation elements for ONE image.
    pub fn out_elems(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { ofm, out_h, out_w, .. } => ofm * out_h * out_w,
            LayerKind::Fc { out_dim, .. } => out_dim,
            LayerKind::Pool { ch, out_h, out_w, .. } => ch * out_h * out_w,
        }
    }

    /// Input activation elements for ONE image.
    pub fn in_elems(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { ifm, in_h, in_w, .. } => ifm * in_h * in_w,
            LayerKind::Fc { in_dim, .. } => in_dim,
            LayerKind::Pool { ch, out_h, out_w, window } => ch * out_h * out_w * window * window,
        }
    }

    pub fn is_weighted(&self) -> bool {
        self.weight_elems() > 0
    }

    pub fn is_conv(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { .. })
    }

    pub fn is_fc(&self) -> bool {
        matches!(self.kind, LayerKind::Fc { .. })
    }
}

/// A full network topology (ordered input -> output).
#[derive(Debug, Clone, PartialEq)]
pub struct NetDescriptor {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl NetDescriptor {
    pub fn new(name: &str, layers: Vec<Layer>) -> Self {
        NetDescriptor { name: name.to_string(), layers }
    }

    /// Forward (scoring) FLOPs per image.
    pub fn fwd_flops_per_image(&self) -> u64 {
        self.layers.iter().map(|l| l.fwd_flops()).sum()
    }

    /// Training FLOPs per image: fwd + bprop + wt-grad = 3x fwd, except the
    /// first weighted layer which skips bprop (paper §3.1: "the first layer
    /// need not perform backpropagation").
    pub fn train_flops_per_image(&self) -> u64 {
        let mut total = 0;
        let mut first_weighted = true;
        for l in &self.layers {
            if !l.is_weighted() {
                total += l.fwd_flops(); // pool fwd only
                continue;
            }
            let f = l.fwd_flops();
            total += if first_weighted { 2 * f } else { 3 * f };
            first_weighted = false;
        }
        total
    }

    /// Total weight (model) bytes.
    pub fn weight_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_bytes()).sum()
    }

    pub fn weight_elems(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_elems()).sum()
    }

    /// The convolutional trunk (data-parallel regime in the paper's recipe).
    pub fn conv_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.is_conv())
    }

    /// FC head (model/hybrid-parallel regime).
    pub fn fc_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.is_fc())
    }

    /// Aggregate *algorithmic* compute-to-communication ratio of the conv
    /// trunk under data parallelism (paper §3.1 quotes 208 for
    /// OverFeat-FAST and 1456 for VGG-A): FLOPs per node-byte communicated,
    /// with overlap=1 send/recv overlap.
    pub fn conv_comp_comm_ratio(&self, minibatch_per_node: u64) -> f64 {
        let comp: u64 = self
            .conv_layers()
            .map(|l| 3 * l.fwd_flops() * minibatch_per_node)
            .sum();
        let comm: u64 = self.conv_layers().map(|l| l.weight_bytes()).sum();
        comp as f64 / comm as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_is_conv_special_case() {
        // A 1x1 conv on a 1x1 map with ifm=in, ofm=out must equal the FC.
        let conv = Layer::conv("c", 512, 1024, 1, 1, 1, 1);
        let fc = Layer::fc("f", 512, 1024);
        assert_eq!(conv.fwd_flops(), fc.fwd_flops());
        assert_eq!(conv.weight_elems(), fc.weight_elems());
    }

    #[test]
    fn train_flops_are_3x_fwd_minus_first_layer_bprop() {
        let net = NetDescriptor::new(
            "t",
            vec![
                Layer::conv("c1", 3, 8, 3, 1, 32, 32),
                Layer::conv("c2", 8, 8, 3, 1, 32, 32),
            ],
        );
        let f1 = net.layers[0].fwd_flops();
        let f2 = net.layers[1].fwd_flops();
        assert_eq!(net.train_flops_per_image(), 2 * f1 + 3 * f2);
    }

    #[test]
    fn pool_has_no_weights() {
        let p = Layer::pool("p", 64, 16);
        assert_eq!(p.weight_elems(), 0);
        assert!(!p.is_weighted());
    }
}
