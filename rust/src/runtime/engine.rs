//! The PJRT execution engine: one CPU client, one compiled executable per
//! artifact, literal-based I/O with shape checking against the manifest.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::HostTensor;

/// Runtime = PJRT client + compiled artifact cache + manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// cumulative (executions, nanoseconds) for profiling
    pub exec_count: std::cell::Cell<u64>,
    pub exec_ns: std::cell::Cell<u64>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest (artifacts are
    /// compiled lazily on first use; see [`Runtime::preload`]).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime {
            client,
            manifest,
            executables: HashMap::new(),
            exec_count: std::cell::Cell::new(0),
            exec_ns: std::cell::Cell::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
    }

    /// Compile an artifact now (no-op if cached). Returns compile seconds.
    pub fn preload(&mut self, name: &str) -> Result<f64> {
        if self.executables.contains_key(name) {
            return Ok(0.0);
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.dir.join(&spec.hlo_file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of artifact {name:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Execute an artifact. Inputs are validated against the manifest ABI;
    /// outputs come back as host tensors in manifest order.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.preload(name)?;
        let spec = self.manifest.artifact(name)?;
        validate_inputs(spec, inputs)?;
        let exe = self.executables.get(name).expect("preloaded above");

        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact {name:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {name:?}"))?;
        self.exec_count.set(self.exec_count.get() + 1);
        self.exec_ns
            .set(self.exec_ns.get() + t0.elapsed().as_nanos() as u64);

        // aot.py lowers with return_tuple=True: the single output literal
        // is a tuple wrapping all declared outputs.
        let parts = out_lit.to_tuple().context("decomposing output tuple")?;
        anyhow::ensure!(
            parts.len() == spec.outputs.len(),
            "artifact {name:?}: got {} outputs, manifest says {}",
            parts.len(),
            spec.outputs.len()
        );
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Convenience for the ubiquitous (params..., data...) calling form.
    pub fn execute_with_params(
        &mut self,
        name: &str,
        params: &[Vec<f32>],
        data: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let lits = self.params_to_literals(name, params)?;
        self.execute_with_param_literals(name, &lits, data)
    }

    /// Pre-convert a parameter set to XLA literals for `name`'s ABI.
    /// Parameters change once per optimizer step but are executed
    /// `microbatches x workers` times — converting once per step removes
    /// the dominant host-side copy from the training hot path (§Perf).
    pub fn params_to_literals(
        &self,
        name: &str,
        params: &[Vec<f32>],
    ) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.artifact(name)?;
        anyhow::ensure!(
            params.len() == spec.n_params,
            "artifact {name:?} wants {} params, got {}",
            spec.n_params,
            params.len()
        );
        params
            .iter()
            .zip(&spec.inputs)
            .map(|(p, s)| {
                anyhow::ensure!(p.len() == s.elems(), "param {} length mismatch", s.name);
                let dims: Vec<i64> = s.shape.iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(p).reshape(&dims)?)
            })
            .collect()
    }

    /// Execute with cached parameter literals + fresh data tensors.
    pub fn execute_with_param_literals(
        &mut self,
        name: &str,
        param_lits: &[xla::Literal],
        data: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        self.preload(name)?;
        let spec = self.manifest.artifact(name)?;
        anyhow::ensure!(
            param_lits.len() + data.len() == spec.inputs.len(),
            "artifact {name:?}: {} params + {} data != {} inputs",
            param_lits.len(),
            data.len(),
            spec.inputs.len()
        );
        // validate the data tail against the manifest
        for (t, s) in data.iter().zip(&spec.inputs[param_lits.len()..]) {
            anyhow::ensure!(
                t.shape() == s.shape.as_slice(),
                "artifact {name:?} input {}: shape {:?} != {:?}",
                s.name,
                t.shape(),
                s.shape
            );
            anyhow::ensure!(
                t.dtype() == s.dtype,
                "artifact {name:?} input {}: dtype mismatch",
                s.name
            );
        }
        let data_lits: Vec<xla::Literal> =
            data.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(spec.inputs.len());
        args.extend(param_lits.iter());
        args.extend(data_lits.iter());

        let exe = self.executables.get(name).expect("preloaded above");
        let t0 = Instant::now();
        let result = exe
            .execute::<&xla::Literal>(&args)
            .with_context(|| format!("executing artifact {name:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {name:?}"))?;
        self.exec_count.set(self.exec_count.get() + 1);
        self.exec_ns.set(self.exec_ns.get() + t0.elapsed().as_nanos() as u64);
        let parts = out_lit.to_tuple().context("decomposing output tuple")?;
        anyhow::ensure!(
            parts.len() == spec.outputs.len(),
            "artifact {name:?}: got {} outputs, manifest says {}",
            parts.len(),
            spec.outputs.len()
        );
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Like [`Runtime::execute_with_param_literals`] but hands back the
    /// raw output literals without materializing host tensors — the
    /// training hot path reads gradients out of these with
    /// `copy_raw_to` into reused accumulation buffers, avoiding one
    /// full-gradient-set allocation+copy per microbatch (§Perf).
    pub fn execute_raw(
        &mut self,
        name: &str,
        param_lits: &[xla::Literal],
        data: &[HostTensor],
    ) -> Result<Vec<xla::Literal>> {
        self.preload(name)?;
        let spec = self.manifest.artifact(name)?;
        anyhow::ensure!(
            param_lits.len() + data.len() == spec.inputs.len(),
            "artifact {name:?}: {} params + {} data != {} inputs",
            param_lits.len(),
            data.len(),
            spec.inputs.len()
        );
        let data_lits: Vec<xla::Literal> =
            data.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(spec.inputs.len());
        args.extend(param_lits.iter());
        args.extend(data_lits.iter());
        let exe = self.executables.get(name).expect("preloaded above");
        let t0 = Instant::now();
        let result = exe
            .execute::<&xla::Literal>(&args)
            .with_context(|| format!("executing artifact {name:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {name:?}"))?;
        self.exec_count.set(self.exec_count.get() + 1);
        self.exec_ns.set(self.exec_ns.get() + t0.elapsed().as_nanos() as u64);
        out_lit.to_tuple().context("decomposing output tuple")
    }

    /// Mean execution latency since startup (profiling hook).
    pub fn mean_exec_ms(&self) -> f64 {
        let n = self.exec_count.get();
        if n == 0 {
            0.0
        } else {
            self.exec_ns.get() as f64 / n as f64 / 1e6
        }
    }
}

fn validate_inputs(spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "artifact {:?}: got {} inputs, manifest says {}",
            spec.name,
            inputs.len(),
            spec.inputs.len()
        );
    }
    for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
        if t.shape() != s.shape.as_slice() {
            bail!(
                "artifact {:?} input {i} ({}): shape {:?} != manifest {:?}",
                spec.name,
                s.name,
                t.shape(),
                s.shape
            );
        }
        if t.dtype() != s.dtype {
            bail!("artifact {:?} input {i} ({}): dtype mismatch", spec.name, s.name);
        }
    }
    Ok(())
}
