//! Host-side tensors and their conversion to/from XLA literals.

use anyhow::{bail, Result};

/// Element type of the artifact ABI (the AOT models use f32 + i32 only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }
}

/// A host tensor: shape + typed storage.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape, data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Scalar extraction (loss values).
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("not a scalar: {} elements", d.len());
        }
        Ok(d[0])
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Convert from an XLA literal (copies).
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            ty => bail!("unsupported literal element type {ty:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(vec![4], vec![1, -2, 3, -4]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_f32(3.25);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.scalar().unwrap(), 3.25);
        assert_eq!(back.shape(), &[] as &[usize]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }
}
