//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them once on the CPU PJRT client, and
//! execute them from the training hot path. Python is never involved at
//! runtime — the rust binary is self-contained once `artifacts/` exists.
//!
//! Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

mod engine;
mod manifest;
mod tensor;

pub use engine::Runtime;
pub use manifest::{default_artifacts_dir, ArtifactSpec, IoSpec, Manifest, ModelSpec};
pub use tensor::{Dtype, HostTensor};
