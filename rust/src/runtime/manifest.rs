//! Typed view of `artifacts/manifest.json` — the ABI contract between the
//! python AOT pipeline and this runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

use super::tensor::Dtype;

/// One named input/output of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_file: String,
    pub kind: String,
    pub model: Option<String>,
    pub batch: usize,
    pub n_params: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// One model: parameter layout + init-params file.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub params_file: String,
    pub params: Vec<(String, Vec<usize>)>,
    pub n_elements: usize,
    pub config: BTreeMap<String, Json>,
}

impl ModelSpec {
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        self.params.iter().map(|(_, s)| s.clone()).collect()
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelSpec>,
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.get("name")?.as_str()?.to_string(),
        shape: j.get("shape")?.as_usize_vec()?,
        dtype: Dtype::parse(j.get("dtype")?.as_str()?)?,
    })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        anyhow::ensure!(j.get("version")?.as_usize()? == 1, "unknown manifest version");

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.get("artifacts")?.as_obj()? {
            let inputs: Vec<IoSpec> =
                a.get("inputs")?.as_arr()?.iter().map(parse_io).collect::<Result<_>>()?;
            let outputs: Vec<IoSpec> =
                a.get("outputs")?.as_arr()?.iter().map(parse_io).collect::<Result<_>>()?;
            let model = match a.get("model")? {
                Json::Str(s) => Some(s.clone()),
                _ => None,
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    hlo_file: a.get("hlo")?.as_str()?.to_string(),
                    kind: a.get("kind")?.as_str()?.to_string(),
                    model,
                    batch: a.get("batch")?.as_usize()?,
                    n_params: a.get("n_params")?.as_usize()?,
                    inputs,
                    outputs,
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, m) in j.get("models")?.as_obj()? {
            let params: Vec<(String, Vec<usize>)> = m
                .get("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok((p.get("name")?.as_str()?.to_string(), p.get("shape")?.as_usize_vec()?))
                })
                .collect::<Result<_>>()?;
            models.insert(
                name.clone(),
                ModelSpec {
                    name: name.clone(),
                    params_file: m.get("params_file")?.as_str()?.to_string(),
                    params,
                    n_elements: m.get("n_elements")?.as_usize()?,
                    config: m.get("config")?.as_obj()?.clone(),
                },
            );
        }
        Ok(Manifest { dir, artifacts, models })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }

    /// Load a model's seed-0 initial parameters from its params.bin
    /// (little-endian f32, spec order).
    pub fn load_params(&self, model: &str) -> Result<Vec<Vec<f32>>> {
        let spec = self.model(model)?;
        let path = self.dir.join(&spec.params_file);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        anyhow::ensure!(
            bytes.len() == 4 * spec.n_elements,
            "params.bin size {} != 4 * {}",
            bytes.len(),
            spec.n_elements
        );
        let mut out = Vec::with_capacity(spec.params.len());
        let mut off = 0usize;
        for (_, shape) in &spec.params {
            let n: usize = shape.iter().product();
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[off + 4 * i..off + 4 * i + 4];
                v.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += 4 * n;
            out.push(v);
        }
        Ok(out)
    }
}

/// Default artifacts dir: `$PCL_DNN_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("PCL_DNN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
