//! Brute-force cache-blocking search (paper §2.2).
//!
//! "We write a multithreaded program to perform a brute-force state space
//! search over all values of loop iterators in order to find the minimum
//! B/F ratio for different 2-D convolutional layers, given a limit on the
//! cache size." — this module is that program (std::thread-parallel), with the
//! same constraint set:
//!
//! * block tensors: output `b1 = (mb_b, ofm_b, oh_b, ow_b)`, weights
//!   `b2 = (ifm_b, ofm_b, kh_b, kw_b)` (shared `ofm_b`), input block
//!   derived as `(mb_b, ifm_b, oh_b*s + kh_b - 1, ow_b*s + kw_b - 1)`;
//! * `BS < Size_cache` with double-buffering headroom;
//! * one dimension (`ofm_b`) constrained to a multiple of the SIMD width.
//!
//! The same search answers the TPU question when run with a VMEM-sized
//! budget (see DESIGN.md §Hardware-Adaptation).




use crate::models::{Layer, LayerKind};
use crate::models::layers::SIZE_DATA;

/// A candidate blocking and its figures of merit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Blocking {
    pub mb_b: u64,
    pub ofm_b: u64,
    pub oh_b: u64,
    pub ow_b: u64,
    pub ifm_b: u64,
    pub kh_b: u64,
    pub kw_b: u64,
    /// Working-set bytes (BS in the paper).
    pub bytes: u64,
    /// FLOPs computed per block residency (CPB).
    pub flops: u64,
    /// bytes / FLOPs — the minimized objective.
    pub bf: f64,
}

/// Search configuration.
#[derive(Debug, Clone, Copy)]
pub struct SearchCfg {
    /// Cache (or VMEM) budget in bytes.
    pub budget: u64,
    /// SIMD width the ofm block must be a multiple of (8 = AVX2; use 128
    /// for the TPU lane dimension).
    pub simd: u64,
    /// Reserve half the budget for double buffering (paper: "with due
    /// consideration for double buffering").
    pub double_buffer: bool,
    /// Largest minibatch block to consider.
    pub max_mb: u64,
}

impl Default for SearchCfg {
    fn default() -> Self {
        SearchCfg { budget: 128 * 1024, simd: 8, double_buffer: true, max_mb: 1 }
    }
}

fn divisors(n: u64) -> Vec<u64> {
    (1..=n).filter(|d| n % d == 0).collect()
}

fn simd_multiples(n: u64, simd: u64) -> Vec<u64> {
    if n < simd {
        return vec![n];
    }
    divisors(n).into_iter().filter(|d| d % simd == 0).collect()
}

/// Evaluate one candidate (returns None if over budget).
fn eval(
    cfg: &SearchCfg,
    stride: u64,
    b: (u64, u64, u64, u64, u64, u64, u64),
) -> Option<Blocking> {
    let (mb_b, ofm_b, oh_b, ow_b, ifm_b, kh_b, kw_b) = b;
    let out_block = mb_b * ofm_b * oh_b * ow_b;
    let wt_block = ifm_b * ofm_b * kh_b * kw_b;
    let in_block = mb_b * ifm_b * (oh_b * stride + kh_b - 1) * (ow_b * stride + kw_b - 1);
    let mut bytes = SIZE_DATA * (out_block + wt_block + in_block);
    if cfg.double_buffer {
        bytes *= 2;
    }
    if bytes > cfg.budget {
        return None;
    }
    let flops = 2 * mb_b * ofm_b * oh_b * ow_b * ifm_b * kh_b * kw_b;
    // Traffic per block residency: each resident tensor is read once from
    // DRAM per residency (the §2.2 numerator).
    let traffic = SIZE_DATA * (out_block + wt_block + in_block);
    Some(Blocking {
        mb_b,
        ofm_b,
        oh_b,
        ow_b,
        ifm_b,
        kh_b,
        kw_b,
        bytes,
        flops,
        bf: traffic as f64 / flops as f64,
    })
}

/// Exhaustive minimum-B/F search for one conv layer.
pub fn search(layer: &Layer, cfg: &SearchCfg) -> Option<Blocking> {
    let LayerKind::Conv { ifm, ofm, k, stride, out_h, out_w, .. } = layer.kind else {
        return None;
    };
    let ofm_bs = simd_multiples(ofm, cfg.simd);
    let oh_bs = divisors(out_h);
    let ow_bs = divisors(out_w);
    let ifm_bs = divisors(ifm);
    let kh_bs = divisors(k);
    let kw_bs = divisors(k);
    let mb_bs: Vec<u64> = (1..=cfg.max_mb).collect();

    // Multithreaded state-space search (the paper wrote "a multithreaded
    // program"; we shard the (mb_b, ofm_b) plane across OS threads).
    let mut outer: Vec<(u64, u64)> = Vec::new();
    for &mb_b in &mb_bs {
        for &ofm_b in &ofm_bs {
            outer.push((mb_b, ofm_b));
        }
    }
    let n_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let chunk = outer.len().div_ceil(n_threads.max(1)).max(1);
    let best = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for work in outer.chunks(chunk) {
            let (oh_bs, ow_bs, ifm_bs, kh_bs, kw_bs) =
                (&oh_bs, &ow_bs, &ifm_bs, &kh_bs, &kw_bs);
            handles.push(scope.spawn(move || {
                let mut local: Option<Blocking> = None;
                for &(mb_b, ofm_b) in work {
                    for &oh_b in oh_bs {
                        for &ow_b in ow_bs {
                            for &ifm_b in ifm_bs {
                                for &kh_b in kh_bs {
                                    for &kw_b in kw_bs {
                                        if let Some(c) = eval(
                                            cfg,
                                            stride,
                                            (mb_b, ofm_b, oh_b, ow_b, ifm_b, kh_b, kw_b),
                                        ) {
                                            if local
                                                .map(|b| c.bf < b.bf)
                                                .unwrap_or(true)
                                            {
                                                local = Some(c);
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
                local
            }));
        }
        handles
            .into_iter()
            .filter_map(|h| h.join().expect("search thread panicked"))
            .min_by(|a, b| a.bf.total_cmp(&b.bf))
    });
    best
}

/// §2.2 headline: run the search over every conv layer of a network and
/// report (layer name, best blocking).
pub fn search_network(
    layers: &[Layer],
    cfg: &SearchCfg,
) -> Vec<(String, Option<Blocking>)> {
    layers
        .iter()
        .filter(|l| l.is_conv())
        .map(|l| (l.name.clone(), search(l, cfg)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{overfeat_c5_paper, overfeat_fast, vgg_a};

    #[test]
    fn c5_at_128kb_meets_paper_bound() {
        // §2.2: "with 128 KB of cache per thread ... a B/F ratio of <=0.04
        // can be maintained for most convolutional layers even for a
        // minibatch size of 1".
        let b = search(&overfeat_c5_paper(), &SearchCfg::default()).unwrap();
        assert!(b.bf <= 0.04, "bf={}", b.bf);
        assert!(b.bytes <= 128 * 1024);
        assert_eq!(b.ofm_b % 8, 0, "SIMD constraint");
    }

    #[test]
    fn most_conv_layers_meet_004_at_mb1() {
        let cfg = SearchCfg::default();
        for net in [overfeat_fast(), vgg_a()] {
            let results = search_network(&net.layers, &cfg);
            let ok = results
                .iter()
                .filter(|(_, b)| b.map(|b| b.bf <= 0.04).unwrap_or(false))
                .count();
            // "most" layers: all but the stem convs with tiny ifm counts.
            assert!(ok * 3 >= results.len() * 2, "{}: {ok}/{}", net.name, results.len());
        }
    }

    #[test]
    fn bigger_budget_never_hurts() {
        let small = search(&overfeat_c5_paper(), &SearchCfg::default()).unwrap();
        let big = search(
            &overfeat_c5_paper(),
            &SearchCfg { budget: 1024 * 1024, ..SearchCfg::default() },
        )
        .unwrap();
        assert!(big.bf <= small.bf);
    }

    #[test]
    fn vmem_budget_tpu_variant_runs() {
        // The TPU variant of the same search (DESIGN.md §Hardware-Adaptation):
        // 128-wide lane dim, 8 MB VMEM budget.
        let cfg = SearchCfg { budget: 8 << 20, simd: 128, max_mb: 4, double_buffer: true };
        let b = search(&overfeat_c5_paper(), &cfg).unwrap();
        assert!(b.bf < 0.01, "bf={}", b.bf);
        assert_eq!(b.ofm_b % 128, 0);
    }

    #[test]
    fn blocking_respects_budget_invariant() {
        let cfg = SearchCfg::default();
        for net in [overfeat_fast()] {
            for (_, b) in search_network(&net.layers, &cfg) {
                if let Some(b) = b {
                    assert!(b.bytes <= cfg.budget);
                }
            }
        }
    }
}
