//! Machine and fabric constants for the paper's testbeds (§5).
//!
//! Peak FLOP/s is derived the way the paper derives it:
//! `cores x AVX2-freq x SIMD-width(8 f32) x 2 FMA-ports x 2 FLOPs/FMA`.
//! The Table 1 "comp-to-comms" column pins the constants: 2s9c E5-2666v3 +
//! 10 GbE gives 1670 GF / 1.25 GB/s = 1336 FLOPs/byte, and 2s16c E5-2698v3
//! + FDR gives 2355 GF / 7 GB/s = 336 — exactly the paper's numbers.



use crate::models::Layer;

/// CPU node description.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineSpec {
    pub name: String,
    pub sockets: u64,
    pub cores_per_socket: u64,
    /// Sustained AVX frequency in GHz (what the FMA units actually run at).
    pub freq_ghz: f64,
    /// f32 lanes per vector (8 for AVX2).
    pub simd_width: u64,
    /// FMA issue ports per core (2 on Haswell).
    pub fma_per_cycle: u64,
    /// Achieved fraction of peak for convolutional layers (paper: "90%").
    pub conv_efficiency: f64,
    /// Achieved fraction of peak for fully-connected layers (paper: "70%").
    pub fc_efficiency: f64,
    /// Per-thread cache budget in bytes for blocking (paper §2.2: 128 KB).
    pub cache_per_thread: u64,
    /// Memory bandwidth GB/s (per node) — for B/F feasibility checks.
    pub mem_bw_gbps: f64,
    /// Whole-framework efficiency on top of per-kernel efficiency:
    /// non-GEMM ops (pool/ReLU/softmax), layout transforms, and the data
    /// layer. Calibrated so the Fig 3 model lands on the paper's measured
    /// single-node throughputs (VGG-A ~30 img/s train, ~95 score).
    pub framework_efficiency: f64,
    /// Fixed per-layer-pass overhead (thread fork/join + barrier across
    /// 32-64 threads, command submission). Amortized over the minibatch —
    /// the §2.5 "load imbalance" penalty Fig 3 shows for small MB.
    pub per_pass_overhead_s: f64,
}

impl MachineSpec {
    /// Peak single-precision GFLOP/s of the whole node.
    pub fn peak_gflops(&self) -> f64 {
        let cores = (self.sockets * self.cores_per_socket) as f64;
        cores * self.freq_ghz * self.simd_width as f64 * self.fma_per_cycle as f64 * 2.0
    }

    pub fn threads(&self) -> u64 {
        self.sockets * self.cores_per_socket
    }

    /// Achieved GFLOP/s for a given layer kind (paper's measured 90%/70%).
    pub fn achieved_gflops(&self, layer: &Layer) -> f64 {
        let eff = if layer.is_conv() { self.conv_efficiency } else { self.fc_efficiency };
        self.peak_gflops() * eff
    }

    /// System bytes-per-FLOP ratio (§2.2 quotes "typically < 0.08").
    pub fn system_bf_ratio(&self) -> f64 {
        self.mem_bw_gbps / self.peak_gflops()
    }

    /// Dual-socket 16-core Xeon E5-2698v3 (Cori phase I node).
    pub fn e5_2698v3() -> Self {
        MachineSpec {
            name: "2s16c E5-2698v3".into(),
            sockets: 2,
            cores_per_socket: 16,
            freq_ghz: 2.3,
            simd_width: 8,
            fma_per_cycle: 2,
            conv_efficiency: 0.90,
            fc_efficiency: 0.70,
            cache_per_thread: 128 * 1024,
            mem_bw_gbps: 136.0, // 4ch DDR4-2133 x 2 sockets
            framework_efficiency: 0.67,
            per_pass_overhead_s: 3.0e-4,
        }
    }

    /// Dual-socket 9-core Xeon E5-2666v3 @2.9 GHz (AWS c4.8xlarge).
    pub fn e5_2666v3() -> Self {
        MachineSpec {
            name: "2s9c E5-2666v3".into(),
            sockets: 2,
            cores_per_socket: 9,
            freq_ghz: 2.9,
            simd_width: 8,
            fma_per_cycle: 2,
            conv_efficiency: 0.90,
            fc_efficiency: 0.70,
            cache_per_thread: 128 * 1024,
            mem_bw_gbps: 118.0,
            framework_efficiency: 0.67,
            per_pass_overhead_s: 3.0e-4,
        }
    }

    /// Dual-socket 14-core Xeon E5-2697v3 (Intel Endeavor; paper: "1.7
    /// TFLOPS/s SP peak" — 28 cores x ~1.9 GHz AVX x 32).
    pub fn e5_2697v3() -> Self {
        MachineSpec {
            name: "2s14c E5-2697v3".into(),
            sockets: 2,
            cores_per_socket: 14,
            freq_ghz: 1.9,
            simd_width: 8,
            fma_per_cycle: 2,
            conv_efficiency: 0.90,
            fc_efficiency: 0.70,
            cache_per_thread: 128 * 1024,
            mem_bw_gbps: 136.0,
            // ASR FC stacks are pure block-SGEMM: almost no non-GEMM work
            // (paper: 4600 f/s = ~74% of peak on this machine).
            framework_efficiency: 0.95,
            per_pass_overhead_s: 1.0e-4,
        }
    }
}

/// Interconnect description: the α-β model plus a virtualization factor
/// for multi-tenant clouds (§5.3: EC2 network is virtualized and slower).
#[derive(Debug, Clone, PartialEq)]
pub struct FabricSpec {
    pub name: String,
    /// Per-message latency (α), seconds.
    pub latency_s: f64,
    /// Per-node unidirectional injection bandwidth (β), bytes/s.
    pub bw_bytes_per_s: f64,
    /// Links are full-duplex: with send/recv overlap the effective
    /// exchange bandwidth doubles (paper's overlap=1 assumption).
    pub full_duplex: bool,
    /// Software/virtualization multiplier on achieved bandwidth (1.0 =
    /// bare metal; EC2 with SR-IOV + dedicated interrupt core ~0.8).
    pub sw_efficiency: f64,
    /// Per-collective software latency — the paper's §3.2 `SWlat` term
    /// (MPI progress, command-queue handoff, rendezvous).
    pub sw_latency_s: f64,
    /// Fractional bandwidth loss per doubling of collective participants
    /// (global-collective contention + OS jitter/stragglers; calibrated
    /// against the paper's measured Fig 4 / Fig 6 / Fig 7 efficiencies).
    pub congestion_per_doubling: f64,
}

impl FabricSpec {
    /// Effective bandwidth for an overlapped exchange.
    pub fn effective_bw(&self) -> f64 {
        let duplex = if self.full_duplex { 2.0 } else { 1.0 };
        self.bw_bytes_per_s * duplex * self.sw_efficiency
    }

    /// Effective bandwidth seen by an `n`-participant collective.
    pub fn effective_bw_n(&self, n: u64) -> f64 {
        if n <= 1 {
            return self.effective_bw();
        }
        let doublings = (n as f64).log2();
        self.effective_bw() / (1.0 + self.congestion_per_doubling * doublings)
    }

    /// Time to push `bytes` through the NIC once (single message).
    pub fn point_to_point_s(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / (self.bw_bytes_per_s * self.sw_efficiency)
    }

    /// Cray Aries dragonfly (Cori phase I).
    pub fn aries() -> Self {
        FabricSpec {
            name: "Cray Aries".into(),
            latency_s: 1.5e-6,
            bw_bytes_per_s: 8.0e9,
            full_duplex: true,
            sw_efficiency: 0.9,
            sw_latency_s: 5.0e-5,
            congestion_per_doubling: 0.65,
        }
    }

    /// 56 Gb/s FDR InfiniBand.
    pub fn fdr_infiniband() -> Self {
        FabricSpec {
            name: "56Gbps FDR".into(),
            latency_s: 1.0e-6,
            bw_bytes_per_s: 7.0e9,
            full_duplex: true,
            sw_efficiency: 0.9,
            sw_latency_s: 1.5e-4,
            congestion_per_doubling: 0.45,
        }
    }

    /// Bare 10 Gigabit Ethernet.
    pub fn ethernet_10g() -> Self {
        FabricSpec {
            name: "10Gbps Ethernet".into(),
            latency_s: 2.0e-5,
            bw_bytes_per_s: 1.25e9,
            full_duplex: true,
            sw_efficiency: 0.9,
            sw_latency_s: 1.0e-4,
            congestion_per_doubling: 0.30,
        }
    }

    /// AWS EC2 10 GbE with SR-IOV ("enhanced networking") and a core
    /// dedicated to NIC interrupts — the paper's §5.3 configuration. The
    /// 30-40% interrupt-steering gain is already folded into sw_efficiency
    /// relative to the un-tuned virtualized baseline.
    pub fn aws_10g_sriov() -> Self {
        FabricSpec {
            name: "AWS 10GbE (SR-IOV)".into(),
            latency_s: 5.0e-5,
            bw_bytes_per_s: 1.25e9,
            full_duplex: true,
            sw_efficiency: 0.70,
            sw_latency_s: 2.0e-4,
            congestion_per_doubling: 0.20,
        }
    }
}

/// A named (machine, fabric) pair — the paper's evaluation platforms.
#[derive(Debug, Clone)]
pub struct Platform {
    pub machine: MachineSpec,
    pub fabric: FabricSpec,
}

impl Platform {
    /// NERSC Cori phase I (Fig 4/5).
    pub fn cori() -> Self {
        Platform { machine: MachineSpec::e5_2698v3(), fabric: FabricSpec::aries() }
    }

    /// AWS EC2 c4.8xlarge cluster (Fig 6).
    pub fn aws() -> Self {
        Platform { machine: MachineSpec::e5_2666v3(), fabric: FabricSpec::aws_10g_sriov() }
    }

    /// Intel Endeavor (Fig 7).
    pub fn endeavor() -> Self {
        Platform { machine: MachineSpec::e5_2697v3(), fabric: FabricSpec::fdr_infiniband() }
    }

    /// Table 1, column 1: 2s9c E5-2666v3 + bare 10 GbE.
    pub fn table1_ethernet() -> Self {
        Platform { machine: MachineSpec::e5_2666v3(), fabric: FabricSpec::ethernet_10g() }
    }

    /// Table 1, column 2: 2s16c E5-2698v3 + FDR.
    pub fn table1_fdr() -> Self {
        Platform { machine: MachineSpec::e5_2698v3(), fabric: FabricSpec::fdr_infiniband() }
    }

    /// The paper's comp-to-comms metric: peak FLOPs per wire byte.
    pub fn comp_to_comms(&self) -> f64 {
        self.machine.peak_gflops() * 1e9 / self.fabric.bw_bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_flops_match_paper_derivations() {
        // E5-2697v3: paper quotes 1.7 TFLOPS/s SP peak.
        let p = MachineSpec::e5_2697v3().peak_gflops();
        assert!((1600.0..1800.0).contains(&p), "{p}");
        // E5-2698v3: 32 cores x 2.3 x 32 = 2355 GF.
        let p = MachineSpec::e5_2698v3().peak_gflops();
        assert!((2300.0..2400.0).contains(&p), "{p}");
    }

    #[test]
    fn table1_comp_to_comms_row() {
        // Table 1: 1336 (Ethernet platform) and 336 (FDR platform).
        let eth = Platform::table1_ethernet().comp_to_comms();
        let fdr = Platform::table1_fdr().comp_to_comms();
        assert!((eth - 1336.0).abs() < 15.0, "{eth}");
        assert!((fdr - 336.0).abs() < 5.0, "{fdr}");
    }

    #[test]
    fn system_bf_below_paper_bound() {
        // §2.2: "typically the system B/F ratio is less than 0.08".
        for m in [MachineSpec::e5_2698v3(), MachineSpec::e5_2666v3()] {
            assert!(m.system_bf_ratio() < 0.08, "{}", m.name);
        }
    }

    #[test]
    fn duplex_doubles_effective_bw() {
        let f = FabricSpec::fdr_infiniband();
        assert!(f.effective_bw() > f.bw_bytes_per_s);
    }
}
