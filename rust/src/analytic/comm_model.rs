//! Communication volume models for data, model, and hybrid parallelism
//! (paper §3.1-3.3), including the closed-form optimal hybrid group count.



use crate::analytic::machine::FabricSpec;
use crate::models::{Layer, LayerKind};
use crate::models::layers::SIZE_DATA;

/// Parallelization strategy for one layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    /// Partition the minibatch; exchange weight gradients (§3.1).
    Data,
    /// Partition the feature maps; exchange activations (§3.2).
    Model,
    /// G data-parallel groups of N/G model-parallel nodes (§3.3).
    Hybrid { groups: u64 },
}

/// Per-iteration *per-node* communication volume (bytes) under data
/// parallelism: send partial weight gradients, receive updated weights.
/// `overlap` in [0,1] is the send/recv overlap the software achieves.
pub fn data_parallel_bytes(layer: &Layer, overlap: f64) -> f64 {
    SIZE_DATA as f64 * layer.weight_elems() as f64 * (2.0 - overlap)
}

/// §3.1 headline ratio: algorithmic compute-to-communication of a
/// data-parallel conv layer = `1.5 * out_w * out_h * MB_node` — independent
/// of kernel size, feature counts and stride.
pub fn data_parallel_comp_comm(layer: &Layer, mb_node: u64) -> Option<f64> {
    match layer.kind {
        LayerKind::Conv { out_h, out_w, .. } => Some(1.5 * (out_w * out_h * mb_node) as f64),
        LayerKind::Fc { .. } => Some(1.5 * mb_node as f64),
        _ => None,
    }
}

/// Per-iteration total communication volume (bytes) under model
/// parallelism for the forward+backward passes (§3.2): activations of the
/// full minibatch cross the group twice.
pub fn model_parallel_bytes(layer: &Layer, minibatch: u64) -> f64 {
    2.0 * SIZE_DATA as f64 * (layer.in_elems() * minibatch) as f64
}

/// §3.2 decision rule: is model parallelism preferable to data parallelism
/// for this layer? `ofm * k_w * k_h * (2 - overlap) > in_w * in_h * minibatch`.
pub fn model_beats_data(layer: &Layer, minibatch: u64, overlap: f64) -> bool {
    match layer.kind {
        LayerKind::Conv { ofm, k, in_h, in_w, .. } => {
            (ofm * k * k) as f64 * (2.0 - overlap) > (in_h * in_w * minibatch) as f64
        }
        LayerKind::Fc { out_dim, .. } => {
            // k_w = k_h = in_w = in_h = 1: "whenever ofm > minibatch model
            // parallelism is better" (overlap=1).
            out_dim as f64 * (2.0 - overlap) > minibatch as f64
        }
        _ => false,
    }
}

/// §3.3 hybrid volume per node group structure: G groups of N/G nodes.
/// Returns total per-node bytes per iteration.
pub fn hybrid_bytes(layer: &Layer, minibatch: u64, n: u64, g: u64, overlap: f64) -> f64 {
    assert!(g >= 1 && g <= n && n % g == 0, "G={g} must divide N={n}");
    let mb_group = minibatch as f64 / g as f64;
    if g == 1 {
        // pure model parallelism
        return 2.0 * SIZE_DATA as f64 * layer.in_elems() as f64 * minibatch as f64;
    }
    let comms_model = 2.0 * SIZE_DATA as f64 * layer.in_elems() as f64 * mb_group;
    let comms_data =
        SIZE_DATA as f64 * layer.weight_elems() as f64 * (2.0 - overlap) * g as f64 / n as f64;
    comms_model + comms_data
}

/// Closed-form §3.3 optimum for an FC layer (overlap=0 case the paper
/// differentiates): `G* = sqrt(N * minibatch / ofm)`, compared against the
/// boundary G=1 and clamped to divisors of N.
pub fn optimal_groups(layer: &Layer, minibatch: u64, n: u64, overlap: f64) -> u64 {
    let ofm = match layer.kind {
        LayerKind::Fc { out_dim, .. } => out_dim,
        LayerKind::Conv { ofm, .. } => ofm,
        _ => return n,
    };
    let g_star = ((n * minibatch) as f64 / ofm as f64).sqrt();
    // candidate divisors of N around G*, plus the G=1 boundary
    let mut best = (1u64, hybrid_bytes(layer, minibatch, n, 1, overlap));
    for g in (1..=n).filter(|g| n % g == 0) {
        let bytes = hybrid_bytes(layer, minibatch, n, g, overlap);
        if bytes < best.1 {
            best = (g, bytes);
        }
    }
    let _ = g_star; // continuous optimum; the discrete scan is authoritative
    best.0
}

/// Continuous §3.3 optimum (for reporting/tests against the paper's G=3
/// worked example).
pub fn optimal_groups_continuous(ofm: u64, minibatch: u64, n: u64) -> f64 {
    ((n * minibatch) as f64 / ofm as f64).sqrt()
}

/// α-β cost of one sharded parameter-server exchange for a layer's
/// gradients under ssp / async-ps sync modes: each node *pushes* its
/// gradient shard-wise to N servers (co-located one per node) and
/// *pulls* the refreshed weights back. With the shard layout each
/// direction moves `bytes * (N-1)/N` off-node, pipelined across shards,
/// so the α term is one push hop plus one pull hop — no log(N) rounds,
/// no ring convoy. This is strictly cheaper than either collective
/// schedule, which is exactly why relaxed-sync modes win under skew.
/// Both the netsim fleet builder and the analytic cross-check price PS
/// traffic with this same closed form (no fabric contention is modeled
/// for PS flows), which is what keeps the two substrates within the
/// clean-fabric agreement bound.
pub fn ps_exchange_s(fabric: &FabricSpec, weight_bytes: u64, nodes: u64) -> f64 {
    if nodes <= 1 || weight_bytes == 0 {
        return 0.0;
    }
    let off_node = weight_bytes as f64 * (nodes - 1) as f64 / nodes as f64;
    2.0 * (fabric.latency_s + fabric.sw_latency_s) + 2.0 * off_node / fabric.effective_bw()
}

/// Pick the best strategy for a layer (the paper's recipe: data-parallel
/// convs, hybrid FCs with G chosen by the §3.3 optimum).
pub fn best_strategy(layer: &Layer, minibatch: u64, n: u64, overlap: f64) -> Strategy {
    if layer.is_conv() || !layer.is_weighted() {
        return Strategy::Data;
    }
    if !model_beats_data(layer, minibatch, overlap) {
        return Strategy::Data;
    }
    let g = optimal_groups(layer, minibatch, n, overlap);
    if g == n {
        Strategy::Data
    } else if g == 1 {
        Strategy::Model
    } else {
        Strategy::Hybrid { groups: g }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::overfeat_c5_paper;

    fn fc4096() -> Layer {
        Layer::fc("fc", 4096, 4096)
    }

    #[test]
    fn comp_comm_independent_of_kernel_and_features() {
        // §3.1: ratio depends only on output map size and MB/node.
        let a = Layer::conv("a", 64, 128, 3, 1, 14, 12);
        let b = Layer::conv("b", 512, 1024, 5, 1, 16, 12);
        assert_eq!(
            data_parallel_comp_comm(&a, 4),
            data_parallel_comp_comm(&b, 4)
        );
    }

    #[test]
    fn paper_g3_worked_example() {
        // §3.3 worked example: ofm=4096, minibatch=256, N=64. The paper
        // states G=3 and volume 8*ifm*213; the formula it derives actually
        // gives G* = sqrt(64*256/4096) = 2 and volume 8*ifm*(256/G +
        // 4096*G/64) = 8*ifm*256 at G=2 (a tie with G=1 at overlap=0 —
        // the paper's 213 appears to mix G=3 and G=2 terms). We assert
        // the *derivation*: the continuous optimum, and that with
        // overlap=1 (the paper's own software achieves overlap) hybrid
        // strictly beats pure model parallelism.
        let g_cont = optimal_groups_continuous(4096, 256, 64);
        assert!((g_cont - 2.0).abs() < 1e-9, "{g_cont}");
        let layer = fc4096();
        // overlap=0: boundary tie — the scan must not pick anything worse.
        let g0 = optimal_groups(&layer, 256, 64, 0.0);
        assert!(
            hybrid_bytes(&layer, 256, 64, g0, 0.0)
                <= hybrid_bytes(&layer, 256, 64, 1, 0.0) + 1.0
        );
        // overlap=1: hybrid strictly wins, as §3.3 concludes.
        let g1 = optimal_groups(&layer, 256, 64, 1.0);
        assert!((2..=4).contains(&g1), "G={g1}");
        let hybrid = hybrid_bytes(&layer, 256, 64, g1, 1.0);
        let pure_model = hybrid_bytes(&layer, 256, 64, 1, 1.0);
        assert!(hybrid < pure_model, "{hybrid} !< {pure_model}");
        let ratio = hybrid / pure_model;
        assert!((0.5..0.95).contains(&ratio), "{ratio}");
    }

    #[test]
    fn fc_prefers_model_when_ofm_exceeds_minibatch() {
        // §3.2: "whenever ofm > minibatch model parallelism is better ...
        // unless we have large minibatches (> 5000) as in ASR networks".
        assert!(model_beats_data(&fc4096(), 256, 1.0));
        assert!(!model_beats_data(&fc4096(), 5120, 1.0));
    }

    #[test]
    fn conv_prefers_data_parallelism() {
        // §3.2: convs have in_w*in_h*minibatch >> ofm*k*k.
        let c5 = overfeat_c5_paper();
        assert!(!model_beats_data(&c5, 64, 1.0));
        assert_eq!(best_strategy(&c5, 64, 64, 1.0), Strategy::Data);
    }

    #[test]
    fn large_kernel_small_minibatch_flips_to_model() {
        // §3.2: "only for a large kernel size and small minibatch does
        // model parallelism become better" for convs.
        let big_k = Layer::conv("c", 512, 1024, 11, 1, 14, 4);
        assert!(model_beats_data(&big_k, 1, 1.0));
    }

    #[test]
    fn hybrid_bytes_matches_paper_arithmetic() {
        // Paper: comm volume 8*ifm*(minibatch/G + ofm*G/N) at overlap=0.
        let l = fc4096();
        for g in [2u64, 4, 8] {
            let got = hybrid_bytes(&l, 256, 64, g, 0.0);
            let want = 8.0 * 4096.0 * (256.0 / g as f64 + 4096.0 * g as f64 / 64.0);
            assert!((got - want).abs() / want < 1e-9, "g={g}: {got} vs {want}");
        }
    }

    #[test]
    fn strategy_for_fc_head_is_hybrid_or_model() {
        let s = best_strategy(&fc4096(), 256, 64, 1.0);
        assert!(matches!(s, Strategy::Hybrid { .. } | Strategy::Model), "{s:?}");
    }

    #[test]
    fn ps_exchange_alpha_beta_shape() {
        use crate::analytic::machine::Platform;
        let fabric = Platform::cori().fabric;
        // degenerate cases cost nothing
        assert_eq!(ps_exchange_s(&fabric, 0, 8), 0.0);
        assert_eq!(ps_exchange_s(&fabric, 1 << 20, 1), 0.0);
        // α term: two hops regardless of node count
        let alpha = 2.0 * (fabric.latency_s + fabric.sw_latency_s);
        let tiny = ps_exchange_s(&fabric, 8, 8);
        assert!((tiny - alpha).abs() / alpha < 0.01, "{tiny} vs {alpha}");
        // β term grows with (N-1)/N — monotone in N, bounded by 2B/bw
        let bytes = 64u64 << 20;
        let t8 = ps_exchange_s(&fabric, bytes, 8);
        let t64 = ps_exchange_s(&fabric, bytes, 64);
        assert!(t64 > t8, "{t64} !> {t8}");
        let cap = alpha + 2.0 * bytes as f64 / fabric.effective_bw();
        assert!(t64 < cap, "{t64} !< {cap}");
    }
}
