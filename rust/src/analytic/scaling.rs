//! Compute/communication overlap ("bubble") scaling estimator (paper §3.1)
//! and the Table 1 reproduction.
//!
//! The paper's schedule: weight-gradient of layer `i` is computed *before*
//! its backpropagation, and its gradient exchange overlaps all remaining
//! backward compute of layers `j < i` plus the next iteration's forward
//! compute up to layer `i`. The residual wait is the "bubble":
//!
//! ```text
//! ocomp_i  = sum_{j<i} comp_j + comp_i / 3
//! ocomms_i = sum_{j<=i} comms_j
//! bubble_i = ocomms_i / comms_sys - ocomp_i / comp_sys
//! ```
//!
//! Only `bubble_0` (the first layer: wt-grad -> fwd dependency) is
//! unavoidable. The estimator answers Table 1's two questions: the
//! smallest per-node minibatch at which the last conv layer's bubble
//! closes, and the node count a fixed global minibatch scales to.



use crate::models::NetDescriptor;

use super::comm_model;
use super::machine::Platform;

/// Per-layer entries of the §3.1 estimator.
#[derive(Debug, Clone)]
pub struct BubbleRow {
    pub layer: String,
    /// Training compute seconds for this layer at MB_node (all passes).
    pub comp_s: f64,
    /// Gradient-exchange seconds for this layer's weights.
    pub comms_s: f64,
    pub ocomp_s: f64,
    pub ocomms_s: f64,
    pub bubble_s: f64,
}

#[derive(Debug, Clone)]
pub struct BubbleReport {
    pub rows: Vec<BubbleRow>,
    /// Total per-iteration compute seconds (the useful work).
    pub total_comp_s: f64,
    /// Sum of positive bubbles (the exposed communication).
    pub exposed_s: f64,
    /// Estimated scaling efficiency at this MB_node.
    pub efficiency: f64,
}

/// Run the §3.1 estimator on the *data-parallel regime* (conv trunk) of a
/// network, for `mb_node` data points per node. Layers are traversed in
/// backward order (the order their gradients become available).
pub fn bubble_report(net: &NetDescriptor, platform: &Platform, mb_node: u64) -> BubbleReport {
    let m = &platform.machine;
    let fabric = &platform.fabric;
    let comp_sys = 1.0; // times below are already seconds
    let _ = comp_sys;

    // Weighted conv layers in backward order L_k .. L_0 (gradient
    // availability order); the paper indexes forward, we keep its
    // formulas with j ranging over already-finished backward work.
    let convs: Vec<_> = net.conv_layers().collect();
    let mut rows = Vec::new();
    // comp_j: per-layer training seconds; first (input) layer skips bprop.
    let comp: Vec<f64> = convs
        .iter()
        .enumerate()
        .map(|(i, l)| {
            super::compute_model::layer_train_time_s(l, m, mb_node, i == 0)
        })
        .collect();
    let comms: Vec<f64> = convs
        .iter()
        .map(|l| {
            let bytes = comm_model::data_parallel_bytes(l, 1.0);
            fabric.latency_s + bytes / fabric.effective_bw()
        })
        .collect();

    // Backward traversal: gradients appear for L_k first, L_0 last. The
    // exchange of L_i overlaps the backward compute of L_{i-1}..L_0 and
    // the next-iteration forward up to L_i — per the paper this is
    // sum_{j<i} comp_j + comp_i/3.
    let mut ocomms_acc = 0.0;
    for i in (0..convs.len()).rev() {
        let ocomp: f64 = comp[..i].iter().sum::<f64>() + comp[i] / 3.0;
        ocomms_acc += comms[i];
        // ocomms_i = sum_{j<=i backward} comms_j: every exchange issued at
        // or after this layer's wt-grad competes for the wire.
        let ocomms: f64 = comms[i..].iter().sum();
        let bubble = ocomms - ocomp;
        rows.push(BubbleRow {
            layer: convs[i].name.clone(),
            comp_s: comp[i],
            comms_s: comms[i],
            ocomp_s: ocomp,
            ocomms_s: ocomms,
            bubble_s: bubble,
        });
    }
    let _ = ocomms_acc;
    let total_comp: f64 = comp.iter().sum();
    // Exposed communication: the worst residual bubble (bubbles nest — the
    // binding constraint is the maximum, and L_0's bubble is unavoidable).
    let exposed = rows.iter().map(|r| r.bubble_s).fold(0.0_f64, f64::max);
    let efficiency = total_comp / (total_comp + exposed);
    BubbleReport { rows, total_comp_s: total_comp, exposed_s: exposed, efficiency }
}

/// Table 1: smallest MB_node such that the *last* conv layer's bubble
/// closes (`bubble_k < 0` — §3.1's feasibility test for full overlap).
pub fn min_points_per_node(net: &NetDescriptor, platform: &Platform) -> u64 {
    for mb in 1..=4096 {
        let rep = bubble_report(net, platform, mb);
        // rows[0] is the deepest conv layer L_k (backward order).
        if let Some(first) = rep.rows.first() {
            if first.bubble_s <= 0.0 {
                return mb;
            }
        }
    }
    4096
}

/// Table 1: nodes a `minibatch`-sized problem scales to (conv trunk).
pub fn max_nodes(net: &NetDescriptor, platform: &Platform, minibatch: u64) -> u64 {
    let min_mb = min_points_per_node(net, platform);
    minibatch / min_mb.max(1)
}

/// The §3.1 node-count bound:
/// `N <= minibatch * (comms_sys/comp_sys) * (ocomp_k / ocomms_k)` with
/// ocomp in FLOPs and ocomms in bytes at MB_node=1.
pub fn node_bound(net: &NetDescriptor, platform: &Platform, minibatch: u64) -> f64 {
    let rep = bubble_report(net, platform, 1);
    let Some(last) = rep.rows.first() else { return 1.0 };
    // ocomp_k/ocomms_k in seconds already embeds comp_sys and comms_sys.
    minibatch as f64 * (last.ocomp_s / last.ocomms_s)
}

/// One point of the Table 1 bottom rows: (min points/node, nodes for a
/// 256-minibatch problem).
pub fn table1_row(net: &NetDescriptor, platform: &Platform, minibatch: u64) -> (u64, u64) {
    let mb = min_points_per_node(net, platform);
    (mb, minibatch / mb.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{overfeat_fast, vgg_a};

    #[test]
    fn vgg_scales_further_than_overfeat() {
        // Table 1's qualitative content: VGG-A needs fewer points per node
        // than OverFeat-FAST on both platforms (1 vs 2-3 in the paper).
        for p in [Platform::table1_ethernet(), Platform::table1_fdr()] {
            let vgg = min_points_per_node(&vgg_a(), &p);
            let of = min_points_per_node(&overfeat_fast(), &p);
            assert!(vgg <= of, "{}: vgg={vgg} overfeat={of}", p.fabric.name);
        }
    }

    #[test]
    fn table1_vgg_needs_one_point_per_node() {
        // Paper: VGG-A row is "1 (256)" on both platforms.
        let (mb_eth, n_eth) = table1_row(&vgg_a(), &Platform::table1_ethernet(), 256);
        let (mb_fdr, n_fdr) = table1_row(&vgg_a(), &Platform::table1_fdr(), 256);
        assert!(mb_eth <= 2, "{mb_eth}");
        assert_eq!(mb_fdr, 1);
        assert!(n_eth >= 128);
        assert_eq!(n_fdr, 256);
    }

    #[test]
    fn table1_overfeat_band() {
        // Paper: OverFeat-FAST needs 3 points/node on Ethernet, 2 on FDR.
        // Our fabric constants differ slightly; assert the band.
        let (mb_eth, _) = table1_row(&overfeat_fast(), &Platform::table1_ethernet(), 256);
        let (mb_fdr, _) = table1_row(&overfeat_fast(), &Platform::table1_fdr(), 256);
        assert!((2..=6).contains(&mb_eth), "eth {mb_eth}");
        assert!((1..=3).contains(&mb_fdr), "fdr {mb_fdr}");
        assert!(mb_fdr <= mb_eth);
    }

    #[test]
    fn better_fabric_closes_bubbles() {
        let eth = bubble_report(&overfeat_fast(), &Platform::table1_ethernet(), 4);
        let fdr = bubble_report(&overfeat_fast(), &Platform::table1_fdr(), 4);
        assert!(fdr.exposed_s <= eth.exposed_s);
        assert!(fdr.efficiency >= eth.efficiency);
    }

    #[test]
    fn more_points_per_node_means_higher_efficiency() {
        let p = Platform::table1_ethernet();
        let lo = bubble_report(&overfeat_fast(), &p, 1).efficiency;
        let hi = bubble_report(&overfeat_fast(), &p, 64).efficiency;
        assert!(hi > lo, "{hi} !> {lo}");
        assert!(hi > 0.95);
    }

    #[test]
    fn efficiency_in_unit_range() {
        for p in [Platform::cori(), Platform::aws(), Platform::endeavor()] {
            for mb in [1u64, 4, 32] {
                let e = bubble_report(&vgg_a(), &p, mb).efficiency;
                assert!(e > 0.0 && e <= 1.0, "{} mb={mb}: {e}", p.fabric.name);
            }
        }
    }
}
