//! Register-blocking efficiency model (paper §2.4).
//!
//! Haswell core model: 2 loads/cycle, 2 VFMA/cycle (latency 5), 1
//! store/cycle, 16 vector registers. The paper requires the register block
//! `10 <= RB_h * RB_w <= 15` (>=10 to hide 5-cycle x 2-issue FMA latency,
//! <=15 to keep one register for the broadcast weight), and computes for
//! the inner loop of Algorithm 2:
//!
//! ```text
//! LS  = (RB + SW*KH*KW)/2 + RB      (loads dual-issued; stores 1/cycle)
//! FMA = (SW*KH*KW*RB)/2
//! ```
//!
//! Efficiency = FMA / (FMA + load-cycles), with the store stream hidden
//! under the FMA stream (the paper's 88% for OverFeat-FAST C5 with
//! RB=1x12, SW=8, one kernel row in flight confirms this interpretation).
//!
//! The TPU translation (`mxu_utilization`) reports the same "useful work /
//! issue slots" ratio for a systolic 128x128 MXU fed from VMEM tiles.



/// Haswell-class core constants.
pub const FMA_LATENCY: u64 = 5;
pub const FMA_PER_CYCLE: u64 = 2;
pub const LOADS_PER_CYCLE: u64 = 2;
pub const STORES_PER_CYCLE: u64 = 1;
pub const VECTOR_REGS: u64 = 16;

/// Minimum register-block size that hides FMA latency.
pub fn min_rb() -> u64 {
    FMA_LATENCY * FMA_PER_CYCLE // = 10
}

/// Maximum register-block size (one register reserved for the weight).
pub fn max_rb() -> u64 {
    VECTOR_REGS - 1 // = 15
}

/// Is `rb_h x rb_w` a legal block per §2.4?
pub fn rb_valid(rb_h: u64, rb_w: u64) -> bool {
    let rb = rb_h * rb_w;
    (min_rb()..=max_rb()).contains(&rb)
}

/// Cycle counts for the Algorithm 2 inner loop (lines 5-29).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CycleModel {
    pub rb: u64,
    pub sw: u64,
    /// kernel taps in flight: (kh_end-kh_start) * (kw_end-kw_start)
    pub taps: u64,
    pub load_cycles: f64,
    pub store_cycles: f64,
    pub fma_cycles: f64,
    pub efficiency: f64,
}

/// Forward/backward-propagation efficiency for a register block of `rb`
/// accumulators, SIMD width `sw`, processing `taps` kernel taps per
/// residency (one kernel row at a time for fwd-prop: taps = kw).
pub fn cycle_model(rb: u64, sw: u64, taps: u64) -> CycleModel {
    let loads = (rb + sw * taps) as f64 / LOADS_PER_CYCLE as f64;
    let stores = rb as f64 / STORES_PER_CYCLE as f64;
    let fma = (sw * taps * rb) as f64 / FMA_PER_CYCLE as f64;
    // Stores retire on port 4 in parallel with the FMA stream; the load
    // stream contends with operand delivery, so it is serialized against
    // FMA issue. This reproduces the paper's 88% for (rb=12, sw=8, taps=3).
    let eff = fma / (fma + loads);
    CycleModel { rb, sw, taps, load_cycles: loads, store_cycles: stores, fma_cycles: fma, efficiency: eff }
}

/// §2.4 weight-gradient register-blocking strategies, per kernel size:
/// returns (description, rb_elems, taps) — the tailored blockings from the
/// paper's bullet list.
pub fn weight_grad_strategy(k: u64) -> (&'static str, u64, u64) {
    match k {
        3 => ("one row (3 SIMD elems) of 4 consecutive kernels along ifm", 12, 3),
        5 => ("one row of 2 consecutive kernels along ifm", 10, 5),
        7 => ("one row of 2 consecutive kernels along ifm", 14, 7),
        11 => ("1-D block along kernel width", 11, 11),
        _ => ("one kernel row", 0, k),
    }
}

/// Peak weight-gradient efficiency for a kxk kernel with naive 2-D
/// blocking over the kernel itself (§2.4: "even two dimensional blocking
/// will only yield a theoretical peak efficiency of 75% for a 3x3
/// kernel"). A 3x3 kernel provides only 9 accumulators; hiding the
/// 10-deep FMA pipeline requires the next whole-row multiple (12), so
/// utilization caps at 9/12 = 75%. Kernels larger than the register file
/// block by whole rows that fit (<= 15 registers).
pub fn weight_grad_naive_efficiency(k: u64) -> f64 {
    let rb_full = k * k;
    if rb_full > max_rb() {
        // spill regime: block whole rows that fit the register file
        let rows = (max_rb() / k).max(1);
        let rb = rows * k;
        if rb >= min_rb() {
            return 1.0;
        }
        let need = min_rb().div_ceil(k) * k;
        return rb as f64 / need as f64;
    }
    let need = min_rb().div_ceil(k) * k; // next row multiple >= 10
    (rb_full as f64 / need as f64).min(1.0)
}

/// Efficiency with the §2.4 tailored strategy.
pub fn weight_grad_strategy_efficiency(k: u64) -> f64 {
    let (_, rb, taps) = weight_grad_strategy(k);
    if rb == 0 {
        return weight_grad_naive_efficiency(k);
    }
    cycle_model(rb, 8, taps).efficiency.min(1.0)
}

/// MXU-utilization estimate for the Pallas kernel tile (the TPU analogue
/// of the VFMA efficiency — DESIGN.md §Hardware-Adaptation). A (m x n)
/// output tile contracted over k on a 128x128 systolic array sustains
/// `min(m,128)*min(n,128)/128^2` of peak per wave; edge waves waste the
/// remainder.
pub fn mxu_utilization(tile_m: u64, tile_n: u64, tile_k: u64) -> f64 {
    let k = tile_k.max(1);
    let waves = (tile_m.div_ceil(128) * tile_n.div_ceil(128) * k.div_ceil(128)) as f64;
    let slots_per_wave = 128.0 * 128.0 * k.min(128) as f64;
    let useful = (tile_m * tile_n * k) as f64;
    (useful / (waves * slots_per_wave)).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_c5_fwd_efficiency_is_88pct() {
        // §2.4: RB_w=12, RB_h=1, SW=8, one 3-tap kernel row -> "88%".
        let m = cycle_model(12, 8, 3);
        assert!((m.efficiency - 0.88).abs() < 0.015, "{}", m.efficiency);
    }

    #[test]
    fn rb_bounds_match_paper() {
        assert_eq!(min_rb(), 10);
        assert_eq!(max_rb(), 15);
        assert!(rb_valid(1, 12));
        assert!(rb_valid(3, 4));
        assert!(!rb_valid(1, 9)); // too small to hide latency
        assert!(!rb_valid(4, 4)); // needs the weight register
    }

    #[test]
    fn ls_fma_counts_for_paper_example() {
        let m = cycle_model(12, 8, 3);
        // LS = (12 + 24)/2 + 12 = 30 split as loads 18 + stores 12;
        // FMA = 8*3*12/2 = 144.
        assert_eq!(m.load_cycles, 18.0);
        assert_eq!(m.store_cycles, 12.0);
        assert_eq!(m.fma_cycles, 144.0);
    }

    #[test]
    fn wtgrad_3x3_naive_caps_at_75pct() {
        // §2.4: "even two dimensional blocking will only yield a
        // theoretical peak efficiency of 75% for a 3x3 kernel".
        assert!((weight_grad_naive_efficiency(3) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn wtgrad_strategies_hide_fma_latency() {
        // Every §2.4 tailored strategy keeps 10..=15 accumulators in
        // flight (latency hidden, weight register spared) and clears 80%.
        for k in [3u64, 5, 7, 11] {
            let (_, rb, _) = weight_grad_strategy(k);
            assert!((min_rb()..=max_rb()).contains(&rb) || rb == 11, "k={k} rb={rb}");
            assert!(weight_grad_strategy_efficiency(k) > 0.80, "k={k}");
        }
        // and the 3x3 strategy strictly beats naive 2-D blocking
        assert!(weight_grad_strategy_efficiency(3) > weight_grad_naive_efficiency(3));
    }

    #[test]
    fn bigger_rb_is_more_efficient() {
        let lo = cycle_model(10, 8, 3).efficiency;
        let hi = cycle_model(15, 8, 3).efficiency;
        assert!(hi > lo);
    }

    #[test]
    fn mxu_utilization_full_tile_is_one() {
        assert!((mxu_utilization(128, 128, 128) - 1.0).abs() < 1e-9);
        // a 64-wide tile wastes half the array
        assert!((mxu_utilization(64, 128, 128) - 0.5).abs() < 1e-9);
        assert!(mxu_utilization(12, 16, 8) < 0.1);
    }
}
