//! Analytic balance-equation engine (paper §2-§3).
//!
//! The paper's methodology is to "systematically develop detailed system
//! balance equations, and solve them to obtain limits for performance".
//! This module is that methodology, executable:
//!
//! * [`machine`] — CPU + fabric constants for the paper's testbeds.
//! * [`compute_model`] — per-layer FLOPs/bytes/time and B/F ratios (§2.1-2.2).
//! * [`cache_blocking`] — the brute-force blocking state-space search (§2.2).
//! * [`register_blocking`] — the LS/FMA cycle-efficiency model (§2.4).
//! * [`comm_model`] — data/model/hybrid communication volumes and the
//!   optimal hybrid group count G* (§3.1-3.3).
//! * [`scaling`] — the compute/communication overlap ("bubble") scaling
//!   estimator and Table 1 (§3.1).

pub mod cache_blocking;
pub mod comm_model;
pub mod compute_model;
pub mod machine;
pub mod register_blocking;
pub mod scaling;

pub use machine::{FabricSpec, MachineSpec, Platform};
