//! Per-layer compute/bandwidth model (paper §2.1-2.2) and the single-node
//! throughput estimator behind Fig 3.

use crate::models::{Layer, LayerKind, NetDescriptor};
use crate::models::layers::SIZE_DATA;

use super::machine::MachineSpec;

/// B/F ratio when streaming one output row at a time (the paper's loop
/// over `i_3` with nothing cached):
/// `size * (out + in + k) / (2 * k * out)` per §2.2. For OverFeat-FAST C5
/// this evaluates to 0.54.
pub fn bf_ratio_row(layer: &Layer) -> Option<f64> {
    match layer.kind {
        LayerKind::Conv { k, stride, out_h, out_w, .. } => {
            let in_h = out_h * stride + k - 1;
            let in_w = out_w * stride + k - 1;
            let bytes = SIZE_DATA as f64 * (out_w * out_h + in_w * in_h + k * k) as f64;
            let flops = 2.0 * (k * k * out_w * out_h) as f64;
            Some(bytes / flops)
        }
        _ => None,
    }
}

/// B/F ratio when the whole working set fits in cache (one DRAM pass for
/// all 7 loops, §2.2). For C5 at small minibatch this is ~0.003-0.01.
pub fn bf_ratio_full(layer: &Layer, minibatch: u64) -> Option<f64> {
    match layer.kind {
        LayerKind::Conv { ifm, ofm, k, stride, out_h, out_w, .. } => {
            let in_h = out_h * stride + k - 1;
            let in_w = out_w * stride + k - 1;
            let mb = minibatch;
            let bytes = SIZE_DATA as f64
                * (mb * ofm * out_w * out_h + mb * ifm * in_w * in_h + ifm * ofm * k * k) as f64;
            let flops = 2.0 * (mb * ofm * ifm * k * k * out_w * out_h) as f64;
            Some(bytes / flops)
        }
        _ => None,
    }
}

/// Seconds to run one *training* pass (fwd+bprop+wtgrad) of `layer` for
/// `mb` images on `machine` at the paper's achieved efficiency.
pub fn layer_train_time_s(layer: &Layer, machine: &MachineSpec, mb: u64, skip_bprop: bool) -> f64 {
    let passes = if skip_bprop { 2.0 } else { 3.0 };
    let flops = passes * (layer.fwd_flops() * mb) as f64;
    flops / (machine.achieved_gflops(layer) * 1e9)
}

/// Seconds for one forward (scoring) pass.
pub fn layer_fwd_time_s(layer: &Layer, machine: &MachineSpec, mb: u64) -> f64 {
    (layer.fwd_flops() * mb) as f64 / (machine.achieved_gflops(layer) * 1e9)
}

/// Thread-level load balance for a layer (paper §2.5: jobs are one output
/// row of SW features; when `jobs < threads x integer`, the tail iteration
/// leaves threads idle — this is the "load imbalance" the paper blames for
/// OverFeat's low small-minibatch training throughput in Fig 3).
pub fn thread_utilization(layer: &Layer, machine: &MachineSpec, mb: u64) -> f64 {
    let threads = machine.threads();
    let jobs = match layer.kind {
        LayerKind::Conv { ofm, out_h, .. } => {
            mb * (ofm / machine.simd_width.min(ofm)).max(1) * out_h
        }
        LayerKind::Fc { out_dim, .. } => mb * (out_dim / machine.simd_width.min(out_dim)).max(1),
        LayerKind::Pool { ch, out_h, .. } => mb * ch * out_h,
    };
    if jobs == 0 {
        return 1.0;
    }
    let rounds = jobs.div_ceil(threads);
    jobs as f64 / (rounds * threads) as f64
}

/// Single-node throughput estimate in images (or frames) per second —
/// the model behind Fig 3, including the §2.5 load-imbalance effect.
pub fn single_node_throughput(
    net: &NetDescriptor,
    machine: &MachineSpec,
    minibatch: u64,
    training: bool,
) -> f64 {
    let mut total_s = 0.0;
    let mut first_weighted = true;
    for layer in &net.layers {
        let util = thread_utilization(layer, machine, minibatch).max(0.05);
        let (t, passes) = if training && layer.is_weighted() {
            let t = layer_train_time_s(layer, machine, minibatch, first_weighted);
            let p = if first_weighted { 2.0 } else { 3.0 };
            first_weighted = false;
            (t, p)
        } else {
            (layer_fwd_time_s(layer, machine, minibatch), 1.0)
        };
        // fixed fork/join + layout overhead per pass (§2.5 load imbalance)
        total_s += t / util + passes * machine.per_pass_overhead_s;
    }
    // whole-framework factor: non-GEMM ops, transforms, data layer
    machine.framework_efficiency * minibatch as f64 / total_s
}

/// A row of the Fig 3 table: throughput across minibatch sizes.
pub fn fig3_row(net: &NetDescriptor, machine: &MachineSpec, training: bool) -> Vec<(u64, f64)> {
    [16u64, 32, 64, 128, 256]
        .iter()
        .map(|&mb| (mb, single_node_throughput(net, machine, mb, training)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{overfeat_c5_paper, overfeat_fast, vgg_a};

    #[test]
    fn c5_row_bf_matches_paper() {
        // §2.2: "the B/F ratio is 0.54" for OverFeat-FAST C5 row-wise.
        let bf = bf_ratio_row(&overfeat_c5_paper()).unwrap();
        assert!((bf - 0.54).abs() < 0.01, "{bf}");
    }

    #[test]
    fn c5_full_cache_bf_matches_paper_order() {
        // §2.2: "the best achievable B/F ratio for C5 ... is 0.003" (all
        // data cached, minibatch amortizing the weight traffic).
        let bf = bf_ratio_full(&overfeat_c5_paper(), 8).unwrap();
        assert!((0.001..0.01).contains(&bf), "{bf}");
        // and it improves monotonically with minibatch
        let bf64 = bf_ratio_full(&overfeat_c5_paper(), 64).unwrap();
        assert!(bf64 < bf);
    }

    #[test]
    fn fc_has_no_row_bf() {
        let fc = Layer::fc("f", 128, 128);
        assert!(bf_ratio_row(&fc).is_none());
    }

    #[test]
    fn fig3_vgg_training_throughput_near_paper() {
        // Fig 3: VGG-A training ~30 img/s, scoring ~95 img/s on one
        // E5-2698v3 node. Analytic model should land within ~25%.
        let m = MachineSpec::e5_2698v3();
        let train = single_node_throughput(&vgg_a(), &m, 256, true);
        let score = single_node_throughput(&vgg_a(), &m, 256, false);
        assert!((22.0..45.0).contains(&train), "train {train}");
        assert!((70.0..140.0).contains(&score), "score {score}");
        assert!(score > 2.5 * train);
    }

    #[test]
    fn fig3_overfeat_small_minibatch_penalty() {
        // Fig 3: OverFeat training throughput at MB=16 is visibly below
        // MB=256; scoring shows no significant variation.
        let m = MachineSpec::e5_2698v3();
        let rows = fig3_row(&overfeat_fast(), &m, true);
        let t16 = rows[0].1;
        let t256 = rows[4].1;
        assert!(t16 < 0.97 * t256, "t16={t16} t256={t256}");
    }

    #[test]
    fn utilization_at_most_one() {
        let m = MachineSpec::e5_2698v3();
        for l in overfeat_fast().layers.iter() {
            for mb in [1, 16, 256] {
                let u = thread_utilization(l, &m, mb);
                assert!(u > 0.0 && u <= 1.0);
            }
        }
    }
}
