//! End-to-end trainer: glues the PJRT runtime, the synchronous-SGD
//! coordinator, and the data-handling thread into the paper's training
//! loop. Works for every model family in the zoo (CNN images, CD-DNN
//! frames, GPT tokens) by dispatching on the manifest's model config.

pub mod fault;

use anyhow::{bail, ensure, Context, Result};

use crate::analytic::comm_model::Strategy;
use crate::checkpoint::CheckpointWriter;
use crate::collectives::GroupTopology;
use crate::coordinator::{MicrobatchPlan, SgdConfig, StepResult, SyncSgdCoordinator};
use crate::data::{Corpus, FrameDataset, ImageDataset, Prefetcher};
use crate::metrics::{History, StepRecord};
use crate::plan::PartitionPlan;
use crate::runtime::{HostTensor, Runtime};

/// Training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// model name in the manifest (e.g. "vgg_tiny")
    pub model: String,
    pub workers: usize,
    pub global_mb: usize,
    pub steps: u64,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
    /// print a log line every N steps (0 = silent)
    pub log_every: u64,
    /// run the eval artifact every N steps (0 = never)
    pub eval_every: u64,
    /// "sgd" (paper default) or "adam" (e2e transformer driver)
    pub optimizer: String,
    /// data-thread prefetch queue depth (microbatches ready ahead of the
    /// coordinator; the paper's "continuous availability" requirement)
    pub prefetch: usize,
    /// Partition plan at worker granularity (`plan.nodes == workers`):
    /// tensors of model/hybrid layer groups take the plan's shard-owner
    /// exchange path in the coordinator. `None` = pure data parallelism.
    pub plan: Option<PartitionPlan>,
    /// write an async checkpoint every N steps (0 = off); driven by
    /// `execution.checkpoint` in the spec
    pub checkpoint_every: u64,
    /// checkpoint directory (`None` = `checkpoints/<model>`)
    pub checkpoint_dir: Option<String>,
    /// inject a deterministic worker death at this step (`cluster.fail_at`)
    pub fail_at: Option<u64>,
    /// which worker dies (`cluster.fail_node`)
    pub fail_worker: usize,
    /// recovery policy name: stall | shrink | replan (`cluster.recovery`)
    pub recovery: String,
    /// degraded plan for `replan` recovery (backend re-derives it at N-1;
    /// `None` falls back to `PartitionPlan::renormalize_for`)
    pub recovery_plan: Option<PartitionPlan>,
    /// sync mode name (`parallelism.sync`): "bsp" | "ssp{K}" | "async-ps".
    /// Non-bsp modes let a worker run up to K steps ahead of the slowest
    /// reduction fold (async-ps = unbounded, capped at `workers`).
    pub sync: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "vgg_tiny".into(),
            workers: 1,
            global_mb: 16,
            steps: 50,
            lr: 0.01,
            momentum: 0.0,
            seed: 0,
            log_every: 10,
            eval_every: 0,
            optimizer: "sgd".into(),
            prefetch: 8,
            plan: None,
            checkpoint_every: 0,
            checkpoint_dir: None,
            fail_at: None,
            fail_worker: 0,
            recovery: "stall".into(),
            recovery_plan: None,
            sync: "bsp".into(),
        }
    }
}

/// Exchange topology for one parameter tensor under the plan (`None` =
/// plain data-parallel allreduce on the comm thread). Hybrid shapes that
/// cannot map onto the worker count fall back to data parallelism — the
/// shared-memory runtime cannot leave a tensor unexchanged.
fn tensor_topology(
    plan: Option<&PartitionPlan>,
    param: &str,
    workers: usize,
) -> Option<GroupTopology> {
    if workers <= 1 {
        return None;
    }
    let group = plan?.assignment_for_param(param)?;
    match group.strategy {
        Strategy::Data => None,
        Strategy::Model => Some(GroupTopology::model_parallel(workers)),
        Strategy::Hybrid { groups } => {
            let groups = groups as usize;
            if groups >= 1 && groups < workers && workers % groups == 0 {
                Some(GroupTopology::new(workers, groups))
            } else {
                None
            }
        }
    }
}

/// What kind of data a model consumes.
enum Family {
    Cnn { image: usize, in_ch: usize, classes: usize },
    Cddnn { in_dim: usize, senones: usize },
    Gpt { vocab: usize, seq: usize },
}

fn family(rt: &Runtime, model: &str) -> Result<Family> {
    let spec = rt.manifest().model(model)?;
    let ty = spec.config.get("type").context("model config missing 'type'")?.as_str()?;
    let get = |k: &str| -> Result<usize> {
        spec.config.get(k).with_context(|| format!("config missing {k}"))?.as_usize()
    };
    Ok(match ty {
        "cnn" => Family::Cnn { image: get("image")?, in_ch: get("in_ch")?, classes: get("classes")? },
        "cddnn" => Family::Cddnn { in_dim: get("in_dim")?, senones: get("senones")? },
        "gpt" => Family::Gpt { vocab: get("vocab")?, seq: get("seq")? },
        _ => bail!("unknown model family {ty:?}"),
    })
}

/// A fully prepared microbatch: the non-parameter artifact inputs.
type Micro = Vec<HostTensor>;

/// Build the per-microbatch data generator for a model family, producing
/// items in the exact consumption order of the coordinator (worker-major
/// within a step, steps consecutive). Runs on the dedicated data thread.
/// `first_step` lets the trainer respawn the stream mid-run after a
/// recovery (checkpoint replay restarts from the restored step; a shrink
/// restarts at the failed step under the degraded plan).
fn spawn_data_thread(
    fam: &Family,
    micro: usize,
    plan: &MicrobatchPlan,
    first_step: u64,
    steps: u64,
    seed: u64,
    prefetch: usize,
) -> Prefetcher<Micro> {
    let total_micro = plan.total_micro() as u64;
    let global_mb = plan.global_mb as u64;
    // flatten plan starts in consumption order
    let starts: Vec<u64> =
        plan.per_worker.iter().flatten().map(|&s| s as u64).collect();
    let total_items = steps.saturating_sub(first_step).saturating_mul(total_micro);
    match fam {
        Family::Cnn { image, in_ch, classes } => {
            let ds = ImageDataset::new(*image, *in_ch, *classes, seed);
            let (image, in_ch) = (*image, *in_ch);
            Prefetcher::spawn(prefetch, total_items, move |i| {
                let step = first_step + i / total_micro;
                let start = step * global_mb + starts[(i % total_micro) as usize];
                let b = ds.batch(start, micro);
                vec![
                    HostTensor::f32(vec![micro, image, image, in_ch], b.images),
                    HostTensor::i32(vec![micro], b.labels),
                ]
            })
        }
        Family::Cddnn { in_dim, senones } => {
            let ds = FrameDataset::new(*in_dim, *senones, seed);
            let in_dim = *in_dim;
            Prefetcher::spawn(prefetch, total_items, move |i| {
                let step = first_step + i / total_micro;
                let start = step * global_mb + starts[(i % total_micro) as usize];
                let b = ds.batch(start, micro);
                vec![
                    HostTensor::f32(vec![micro, in_dim], b.images),
                    HostTensor::i32(vec![micro], b.labels),
                ]
            })
        }
        Family::Gpt { vocab, seq } => {
            let c = Corpus::new(*vocab, seed);
            let seq = *seq;
            Prefetcher::spawn(prefetch, total_items, move |i| {
                let step = first_step + i / total_micro;
                let start = step * global_mb + starts[(i % total_micro) as usize];
                let b = c.batch(start, micro, seq);
                vec![HostTensor::i32(vec![micro, seq], b.tokens)]
            })
        }
    }
}

/// Outcome of a training run.
pub struct TrainOutcome {
    pub history: History,
    pub evals: Vec<EvalRecord>,
    pub final_params: Vec<Vec<f32>>,
    /// measured fault recovery (only when `fail_at` fired)
    pub recovery: Option<fault::RecoveryMeasurement>,
}

/// Validation metrics (CNN eval artifacts return loss/top1/top5).
#[derive(Debug, Clone, Copy)]
pub struct EvalRecord {
    pub step: u64,
    pub loss: f64,
    pub top1: f64,
    pub top5: f64,
}

/// Train `cfg.model` for `cfg.steps` synchronous steps.
pub fn train(rt: &mut Runtime, cfg: &TrainConfig) -> Result<TrainOutcome> {
    let artifact = format!("{}_train", cfg.model);
    let spec = rt.manifest().artifact(&artifact)?.clone();
    let micro = spec.batch;
    let fam = family(rt, &cfg.model)?;
    let plan = MicrobatchPlan::new(cfg.global_mb, cfg.workers, micro).with_context(|| {
        format!("minibatch {} / workers {} / micro {micro}", cfg.global_mb, cfg.workers)
    })?;
    let params = rt.manifest().load_params(&cfg.model)?;
    let optimizer = match cfg.optimizer.as_str() {
        "sgd" => crate::coordinator::state::Optimizer::Sgd,
        "adam" => crate::coordinator::state::Optimizer::adam(),
        other => bail!("unknown optimizer {other:?} (sgd|adam)"),
    };
    let sgd = SgdConfig { lr: cfg.lr, momentum: cfg.momentum, weight_decay: 0.0, optimizer };
    // plan-directed exchange sharding: map each manifest parameter tensor
    // onto its layer group's topology (manifest params are named
    // `<layer>.<suffix>`, zoo layers `<layer>`); the names are kept so a
    // recovery can re-map them onto the degraded plan
    let param_names: Vec<String> = rt
        .manifest()
        .model(&cfg.model)?
        .params
        .iter()
        .map(|(name, _)| name.clone())
        .collect();
    let tensor_topos: Vec<Option<GroupTopology>> = param_names
        .iter()
        .map(|name| tensor_topology(cfg.plan.as_ref(), name, cfg.workers))
        .collect();
    let mut coord =
        SyncSgdCoordinator::with_plan(&artifact, params, plan.clone(), sgd, tensor_topos);
    // bounded-staleness window: how many gradient sets may wait parked
    // behind the in-flight reduction before the leader blocks (0 = BSP,
    // today's fully synchronous step)
    let staleness = match crate::experiment::registry::sync_mode(&cfg.sync)? {
        crate::netsim::SyncMode::Bsp => 0,
        crate::netsim::SyncMode::Ssp { staleness } => staleness,
        crate::netsim::SyncMode::AsyncPs => cfg.workers,
    };
    coord.set_staleness(staleness);

    // checkpoint + fault plumbing (both off by default)
    let ckpt_dir = std::path::PathBuf::from(
        cfg.checkpoint_dir.clone().unwrap_or_else(|| format!("checkpoints/{}", cfg.model)),
    );
    let mut writer = if cfg.checkpoint_every > 0 {
        Some(CheckpointWriter::spawn(&ckpt_dir)?)
    } else {
        None
    };
    let mut fault_armed: Option<fault::FaultSpec> = None;
    let mut planner: Option<fault::RecoveryPlanner> = None;
    if let Some(at) = cfg.fail_at {
        crate::experiment::spec::validate_fail_window(at, cfg.steps, "execution.steps")?;
        ensure!(
            cfg.fail_worker < cfg.workers,
            "fail_node {} out of range for {} workers",
            cfg.fail_worker,
            cfg.workers
        );
        let policy = fault::policy_from_str(&cfg.recovery)?;
        if policy != crate::netsim::RecoveryPolicy::Stall {
            ensure!(
                cfg.workers >= 2,
                "{} recovery cannot drop below one worker (workers = {})",
                cfg.recovery,
                cfg.workers
            );
        }
        fault_armed = Some(fault::FaultSpec { at_step: at, worker: cfg.fail_worker });
        planner = Some(fault::RecoveryPlanner {
            policy,
            checkpoint_dir: ckpt_dir.clone(),
            initial: coord.params.snapshot(),
            plan_before: cfg.plan.clone(),
            replan_to: cfg.recovery_plan.clone(),
            micro,
            global_mb: cfg.global_mb,
            artifact: artifact.clone(),
        });
    }

    let mut data =
        spawn_data_thread(&fam, micro, &plan, 0, cfg.steps, cfg.seed, cfg.prefetch.max(1));
    let compile_s = rt.preload(&artifact)?;
    if cfg.log_every > 0 {
        println!(
            "train {}: {} workers, MB={} (micro={}, {} exec/step), compile {:.2}s",
            cfg.model, cfg.workers, cfg.global_mb, micro, plan.total_micro(), compile_s
        );
    }

    let mut history = History::default();
    let mut evals = Vec::new();
    let mut stall_ns_prev = 0u64;
    let mut recovery: Option<fault::RecoveryMeasurement> = None;
    // pre/post-failure throughput accounting for the recovery report
    let (mut pre_wall_s, mut pre_samples) = (0.0f64, 0.0f64);
    let (mut post_wall_s, mut post_samples, mut post_steps) = (0.0f64, 0.0f64, 0u64);
    let mut step: u64 = 0;
    while step < cfg.steps {
        let kill = fault_armed.filter(|f| f.at_step == step).map(|f| f.worker);
        let t0 = std::time::Instant::now();
        let outcome = coord.step_outcome(
            rt,
            &mut |_w, _m, _start| data.next().expect("data thread ended early"),
            kill,
        )?;
        let dt = t0.elapsed().as_secs_f64();
        let stats = match outcome {
            StepResult::Done(stats) => stats,
            StepResult::Died { worker } => {
                fault_armed.take().ok_or_else(|| fault::unexpected_death(worker))?;
                let rp = planner.as_ref().expect("armed fault implies a planner");
                // make queued checkpoints durable before restoring from disk
                if let Some(w) = writer.as_ref() {
                    w.flush(std::time::Duration::from_secs(10))
                        .context("flushing checkpoints before recovery")?;
                }
                let mut topos_for = |p: Option<&PartitionPlan>, workers: usize| {
                    param_names.iter().map(|n| tensor_topology(p, n, workers)).collect()
                };
                let (next, meas) = fault::recover(coord, step, worker, dt, rp, &mut topos_for)?;
                coord = next;
                if cfg.log_every > 0 {
                    println!(
                        "  FAULT step {:>5}  worker {worker} died; {:?} recovery: resume step {} on {} workers ({} replayed)",
                        step, meas.policy, meas.resume_step, meas.workers_after, meas.replay_steps
                    );
                }
                // fresh data stream in the new plan's consumption order
                data = spawn_data_thread(
                    &fam, micro, &coord.plan, meas.resume_step, cfg.steps, cfg.seed,
                    cfg.prefetch.max(1),
                );
                stall_ns_prev = 0;
                step = meas.resume_step;
                recovery = Some(meas);
                continue;
            }
        };
        // this step's data-thread stall (the prefetcher counter is
        // cumulative; difference it per step)
        let stall_ns = data.stall_ns.get();
        let data_stall_us = (stall_ns - stall_ns_prev) as f64 / 1e3;
        stall_ns_prev = stall_ns;
        // a shrink/replan recovery changed the effective minibatch
        let step_mb = coord.plan.global_mb as f64;
        history.push(StepRecord {
            step,
            loss: stats.loss,
            images_per_s: step_mb / dt,
            compute_s: stats.compute_s,
            comm_wait_s: stats.comm_wait_s,
            overlap_s: stats.overlap_s,
            data_stall_us,
        });
        match recovery.as_mut() {
            None => {
                pre_wall_s += dt;
                pre_samples += step_mb;
            }
            // replayed steps are lost progress, not post-recovery throughput
            Some(m) if step < m.failed_step => m.replay_s += dt,
            Some(_) => {
                post_wall_s += dt;
                post_samples += step_mb;
                post_steps += 1;
            }
        }
        if cfg.checkpoint_every > 0 && (step + 1) % cfg.checkpoint_every == 0 {
            if let Some(w) = writer.as_mut() {
                // submit-and-forget: a still-busy writer skips the interval
                // rather than stalling the training loop
                w.submit(coord.params.snapshot());
            }
        }
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            println!(
                "  step {:>5}  loss {:.4}  {:>8.1} samples/s  (compute {:.0}ms, comm-wait {:.1}ms, overlap {:.1}ms, data-stall {:.0}us)",
                step,
                stats.loss,
                step_mb / dt,
                stats.compute_s * 1e3,
                stats.comm_wait_s * 1e3,
                stats.overlap_s * 1e3,
                data_stall_us,
            );
        }
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            if let Some(e) = evaluate(rt, &cfg.model, &coord.params.tensors, cfg.seed)? {
                evals.push(EvalRecord { step, ..e });
                if cfg.log_every > 0 {
                    if e.top1.is_nan() {
                        println!("  eval  step {:>5}  loss {:.4}", step, e.loss);
                    } else {
                        println!(
                            "  eval  step {:>5}  loss {:.4}  top1 {:.3}  top5 {:.3}",
                            step, e.loss, e.top1, e.top5
                        );
                    }
                }
            }
        }
        step += 1;
    }
    if let Some(m) = recovery.as_mut() {
        m.pre_samples_per_s = if pre_wall_s > 0.0 { pre_samples / pre_wall_s } else { 0.0 };
        m.post_samples_per_s = if post_wall_s > 0.0 { post_samples / post_wall_s } else { 0.0 };
        m.post_iteration_s =
            if post_steps > 0 { post_wall_s / post_steps as f64 } else { 0.0 };
    }
    if fault_armed.is_some() {
        bail!("fail_at {:?} never fired (steps = {})", cfg.fail_at, cfg.steps);
    }
    let final_params = coord.params.tensors.clone();
    coord.shutdown();
    if let Some(w) = writer.take() {
        w.shutdown();
    }
    Ok(TrainOutcome { history, evals, final_params, recovery })
}

/// Run the model's eval artifact on a held-out deterministic batch.
/// Returns None when the model has no eval artifact.
pub fn evaluate(
    rt: &mut Runtime,
    model: &str,
    params: &[Vec<f32>],
    seed: u64,
) -> Result<Option<EvalRecord>> {
    let name = format!("{model}_eval");
    if rt.manifest().artifacts.get(&name).is_none() {
        return Ok(None);
    }
    let spec = rt.manifest().artifact(&name)?.clone();
    let b = spec.batch;
    let fam = family(rt, model)?;
    // held-out data: SAME distribution (same seed/templates), but a
    // sample-index range training never reaches.
    const HELD_OUT: u64 = 1 << 40;
    let data: Vec<HostTensor> = match fam {
        Family::Cnn { image, in_ch, classes } => {
            let ds = ImageDataset::new(image, in_ch, classes, seed);
            let batch = ds.batch(HELD_OUT, b);
            vec![
                HostTensor::f32(vec![b, image, image, in_ch], batch.images),
                HostTensor::i32(vec![b], batch.labels),
            ]
        }
        Family::Cddnn { in_dim, senones } => {
            let ds = FrameDataset::new(in_dim, senones, seed);
            let batch = ds.batch(HELD_OUT, b);
            vec![
                HostTensor::f32(vec![b, in_dim], batch.images),
                HostTensor::i32(vec![b], batch.labels),
            ]
        }
        Family::Gpt { vocab, seq } => {
            let c = Corpus::new(vocab, seed);
            let batch = c.batch(HELD_OUT, b, seq);
            vec![HostTensor::i32(vec![b, seq], batch.tokens)]
        }
    };
    let out = rt.execute_with_params(&name, params, &data)?;
    let loss = out[0].scalar()? as f64;
    let (top1, top5) = if out.len() >= 3 {
        (out[1].scalar()? as f64, out[2].scalar()? as f64)
    } else {
        (f64::NAN, f64::NAN)
    };
    Ok(Some(EvalRecord { step: 0, loss, top1, top5 }))
}

/// Scoring (inference) throughput over the fwd artifact — the "FP" bars
/// of Fig 3, measured for real on the tiny models.
pub fn score_throughput(rt: &mut Runtime, model: &str, batches: u64, seed: u64) -> Result<f64> {
    let name = format!("{model}_fwd");
    let spec = rt.manifest().artifact(&name)?.clone();
    let b = spec.batch;
    let fam = family(rt, model)?;
    let params = rt.manifest().load_params(model)?;
    rt.preload(&name)?;
    let data: Vec<HostTensor> = match fam {
        Family::Cnn { image, in_ch, classes } => {
            let ds = ImageDataset::new(image, in_ch, classes, seed);
            let batch = ds.batch(0, b);
            vec![HostTensor::f32(vec![b, image, image, in_ch], batch.images)]
        }
        Family::Cddnn { in_dim, senones } => {
            let ds = FrameDataset::new(in_dim, senones, seed);
            let batch = ds.batch(0, b);
            vec![HostTensor::f32(vec![b, in_dim], batch.images)]
        }
        Family::Gpt { .. } => bail!("gpt models have no fwd artifact"),
    };
    let t0 = std::time::Instant::now();
    for _ in 0..batches {
        rt.execute_with_params(&name, &params, &data)?;
    }
    Ok((batches as usize * b) as f64 / t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn tensor_topology_maps_params_onto_plan_groups() {
        // the mapping that routes manifest param tensors onto the plan's
        // shard-owner exchange: vgg_tiny's FC head prefers model
        // parallelism at 4 workers / MB 16 (ofm > MB), the conv trunk and
        // classifier head stay data-parallel
        let net = zoo::vgg_tiny();
        let plan = PartitionPlan::paper_recipe(&net, 4, 16, 1.0);
        let topo = |p: &str| tensor_topology(Some(&plan), p, 4);
        for p in ["fc0.w", "fc0.b", "fc1.w"] {
            let t = topo(p).unwrap_or_else(|| panic!("{p} lost its plan topology"));
            assert_eq!(t.groups, 1, "{p}"); // model-parallel = 1 group of 4
        }
        for p in ["conv0.w", "conv3.b", "head.w"] {
            assert!(topo(p).is_none(), "{p} should take the plain allreduce");
        }
        // dotted transformer layer names resolve through the last segment
        let gpt = zoo::gpt_descriptor("g", 384, 1, 128);
        let mut per = Vec::new();
        for l in gpt.layers.iter().filter(|l| l.is_weighted()) {
            per.push((
                l.name.clone(),
                crate::analytic::comm_model::Strategy::Hybrid { groups: 2 },
                None,
                1.0,
            ));
        }
        let plan = PartitionPlan::from_assignments("pinned", 4, 16, &per);
        assert!(tensor_topology(Some(&plan), "b0.qkv.w", 4).is_some());
        // degenerate inputs fall back to the allreduce path
        assert!(tensor_topology(None, "fc0.w", 4).is_none());
        assert!(tensor_topology(Some(&plan), "b0.qkv.w", 1).is_none());
        assert!(tensor_topology(Some(&plan), "unknown.w", 4).is_none());
    }
}
