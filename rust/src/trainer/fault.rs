//! Live fault injection + recovery policies for the runtime trainer
//! (ISSUE 9): the in-process realization of what PR 5 *prices* in the
//! simulators. A worker death surfaces as [`StepResult::Died`] from the
//! coordinator's fault seam; [`recover`] then executes the spec's
//! `cluster.recovery` policy for real and measures the disruption:
//!
//! * **stall** — restart the dead worker, roll every survivor back to
//!   the last durable checkpoint ([`crate::checkpoint::restore`], or the
//!   step-0 snapshot when the failure lands before the first write), and
//!   replay. Restore is bit-exact, compute is deterministic, so the
//!   replayed trajectory equals the uninterrupted one bit-for-bit — the
//!   property `tests/recovery_tests.rs` pins across workers ×
//!   optimizers.
//! * **shrink** — continue at N-1 survivors on
//!   [`PartitionPlan::renormalize_for`] with the global minibatch
//!   respread ([`respread`]).
//! * **replan** — continue at N-1 on a re-derived plan (backend-supplied
//!   when the spec carries one; renormalization otherwise).
//!
//! Every phase is wall-clock timed into a [`RecoveryMeasurement`], which
//! the runtime backend maps onto the same `ScalingReport.recovery`
//! schema netsim and the analytic model fill — the three-way
//! cross-check `repro failover --backend runtime` closes.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::checkpoint;
use crate::collectives::GroupTopology;
use crate::coordinator::{
    MicrobatchPlan, ParamSnapshot, SyncSgdCoordinator,
};
use crate::netsim::RecoveryPolicy;
use crate::plan::PartitionPlan;

/// The deterministic killer's trigger: worker `worker` dies at global
/// step `at_step` (the step is aborted and recovered, mirroring netsim's
/// `fail_at`/`fail_node` semantics).
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    pub at_step: u64,
    pub worker: usize,
}

/// Everything the recovery path needs, fixed at training start.
pub struct RecoveryPlanner {
    pub policy: RecoveryPolicy,
    /// where the checkpoint writer publishes (stall restores from here)
    pub checkpoint_dir: PathBuf,
    /// step-0 state: the restore source when the failure lands before
    /// the first checkpoint hits disk
    pub initial: ParamSnapshot,
    pub plan_before: Option<PartitionPlan>,
    /// degraded plan for `replan` (backend re-derives it on the actual
    /// fabric); [`PartitionPlan::renormalize_for`] is the fallback
    pub replan_to: Option<PartitionPlan>,
    pub micro: usize,
    pub global_mb: usize,
    pub artifact: String,
}

/// Measured recovery outcome — the runtime analogue of netsim's
/// `RecoveryOutcome`, filled with wall-clock seconds instead of
/// simulated ones.
#[derive(Debug, Clone)]
pub struct RecoveryMeasurement {
    pub policy: RecoveryPolicy,
    pub failed_step: u64,
    pub dead_worker: usize,
    pub workers_before: usize,
    pub workers_after: usize,
    /// leader-side failure surfacing + in-flight fold drain
    pub detect_s: f64,
    /// checkpoint read + bit-exact state restore (stall only)
    pub restore_s: f64,
    /// steps replayed from the restored checkpoint (stall only)
    pub replay_steps: u64,
    /// wall seconds spent re-running replayed steps (trainer-accumulated)
    pub replay_s: f64,
    /// degraded-plan derivation (replan; renormalization under shrink)
    pub replan_s: f64,
    /// coordinator rebuild + minibatch respread at the new worker count
    pub redistribution_s: f64,
    pub plan_after: Option<PartitionPlan>,
    /// step the training loop resumes from (== checkpoint step under
    /// stall; == the failed step under shrink/replan)
    pub resume_step: u64,
    /// mean samples/s before the failure (trainer-filled)
    pub pre_samples_per_s: f64,
    /// mean samples/s after recovery completed (trainer-filled)
    pub post_samples_per_s: f64,
    /// mean wall seconds per step after recovery (trainer-filled)
    pub post_iteration_s: f64,
    /// samples/step dropped by the respread because the ABI-pinned
    /// microbatch no longer divides the global minibatch (shrink/replan
    /// only; 0 = the hyperparameter survived intact)
    pub residual_mb: usize,
}

impl RecoveryMeasurement {
    /// Total wall seconds of lost forward progress: detection + restore
    /// + replayed compute + replan + redistribution (zero where a phase
    /// does not apply to the policy).
    pub fn stall_s(&self) -> f64 {
        self.detect_s + self.restore_s + self.replay_s + self.replan_s + self.redistribution_s
    }
}

/// A respread minibatch plan plus the explicit record of any residual
/// the respread could not keep (`ScalingReport.recovery.residual_mb`).
#[derive(Debug, Clone)]
pub struct Respread {
    pub plan: MicrobatchPlan,
    /// samples/step the plan had to drop (0 = hyperparameter intact)
    pub residual_mb: usize,
}

/// Respread the global minibatch over the surviving workers *without
/// altering the hyperparameter*: the microbatch size is pinned by the
/// AOT artifact ABI, but the per-worker microbatch counts are not, so a
/// survivor count that no longer divides the total is handled by uneven
/// assignment ([`MicrobatchPlan::uneven`] — some survivors run one more
/// microbatch than others). The only residual left is when `micro`
/// itself stops dividing the global minibatch; those `global_mb % micro`
/// samples cannot be scheduled at all and are reported explicitly in
/// [`Respread::residual_mb`] rather than silently trimmed. Fails when
/// fewer microbatches remain than survivors (an idle survivor would fold
/// a stale gradient buffer). Deterministic; documented in DESIGN.md.
pub fn respread(global_mb: usize, workers: usize, micro: usize) -> Result<Respread> {
    ensure!(workers >= 1, "respread needs at least one survivor");
    ensure!(micro >= 1, "microbatch must be positive");
    let kept = (global_mb / micro) * micro;
    let residual_mb = global_mb - kept;
    let plan = MicrobatchPlan::uneven(kept, workers, micro)
        .with_context(|| format!("respreading MB {global_mb} over {workers} survivors"))?;
    Ok(Respread { plan, residual_mb })
}

/// Recover a coordinator whose worker `dead_worker` died during
/// `failed_step`. Consumes the old coordinator (its comm thread drains
/// on the handoff) and returns a healthy replacement plus the measured
/// disruption. `topos_for` maps a partition plan + worker count onto
/// per-tensor exchange topologies (the trainer's manifest-name mapping;
/// tests pass a stub).
pub fn recover(
    coord: SyncSgdCoordinator,
    failed_step: u64,
    dead_worker: usize,
    detect_s: f64,
    rp: &RecoveryPlanner,
    topos_for: &mut dyn FnMut(Option<&PartitionPlan>, usize) -> Vec<Option<GroupTopology>>,
) -> Result<(SyncSgdCoordinator, RecoveryMeasurement)> {
    let workers_before = coord.workers();
    let overlap = coord.overlap_enabled();
    let mut params = coord.into_params();
    let mut meas = RecoveryMeasurement {
        policy: rp.policy,
        failed_step,
        dead_worker,
        workers_before,
        workers_after: workers_before,
        detect_s,
        restore_s: 0.0,
        replay_steps: 0,
        replay_s: 0.0,
        replan_s: 0.0,
        redistribution_s: 0.0,
        plan_after: None,
        resume_step: failed_step,
        pre_samples_per_s: 0.0,
        post_samples_per_s: 0.0,
        post_iteration_s: 0.0,
        residual_mb: 0,
    };
    match rp.policy {
        RecoveryPolicy::Stall => {
            // restart the dead worker (logical workers restart for free
            // in-process; the state roll-back is the real cost) and roll
            // every survivor back to the last durable checkpoint
            let t0 = Instant::now();
            let snap = match checkpoint::restore(&rp.checkpoint_dir)
                .context("loading checkpoint for stall recovery")?
            {
                Some(s) => s,
                None => rp.initial.clone(),
            };
            ensure!(
                snap.step <= failed_step,
                "checkpoint step {} is past the failed step {failed_step}",
                snap.step
            );
            params.restore(&snap).context("restoring checkpoint state")?;
            meas.restore_s = t0.elapsed().as_secs_f64();
            meas.resume_step = snap.step;
            meas.replay_steps = failed_step - snap.step;
            meas.plan_after = rp.plan_before.clone();

            let t1 = Instant::now();
            let mb = MicrobatchPlan::new(rp.global_mb, workers_before, rp.micro)
                .context("rebuilding the microbatch plan after stall recovery")?;
            let topos = topos_for(rp.plan_before.as_ref(), workers_before);
            let mut next = SyncSgdCoordinator::with_store(&rp.artifact, params, mb, topos);
            next.set_overlap(overlap);
            meas.redistribution_s = t1.elapsed().as_secs_f64();
            Ok((next, meas))
        }
        RecoveryPolicy::Shrink | RecoveryPolicy::Replan => {
            ensure!(
                workers_before >= 2,
                "cannot drop below one worker: {workers_before} before the failure"
            );
            let n1 = workers_before - 1;
            meas.workers_after = n1;

            // degraded plan: replan prefers the backend's re-derived
            // plan, shrink renormalizes §3.3-style; both snap hybrid
            // group shapes onto divisors of N-1
            let t0 = Instant::now();
            meas.plan_after = match rp.policy {
                RecoveryPolicy::Replan => rp
                    .replan_to
                    .clone()
                    .or_else(|| rp.plan_before.as_ref().map(|p| p.renormalize_for(n1 as u64))),
                _ => rp.plan_before.as_ref().map(|p| p.renormalize_for(n1 as u64)),
            };
            meas.replan_s = t0.elapsed().as_secs_f64();

            // survivors keep the current state (the failed step never
            // committed); respread the minibatch and rebuild at N-1
            let t1 = Instant::now();
            let rs = respread(rp.global_mb, n1, rp.micro)?;
            meas.residual_mb = rs.residual_mb;
            let mb = rs.plan;
            let topos = topos_for(meas.plan_after.as_ref(), n1);
            let mut next = SyncSgdCoordinator::with_store(&rp.artifact, params, mb, topos);
            next.set_overlap(overlap);
            meas.redistribution_s = t1.elapsed().as_secs_f64();
            Ok((next, meas))
        }
    }
}

/// A worker died with no fault configured — a genuine panic in user
/// compute. Turned into a hard error by the trainer.
pub fn unexpected_death(worker: usize) -> anyhow::Error {
    anyhow::anyhow!("worker {worker} died with no injected fault configured (genuine panic)")
}

/// Parse a recovery policy name through the registry (single source of
/// truth for the inventory error).
pub fn policy_from_str(name: &str) -> Result<RecoveryPolicy> {
    crate::experiment::registry::recovery_policy(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respread_preserves_the_global_minibatch() {
        // 16 over 4→3 survivors at micro 2: previously trimmed to 12 —
        // a silent hyperparameter change. Now the 8 microbatches go
        // 3/3/2 and all 16 samples survive.
        let r = respread(16, 3, 2).unwrap();
        assert_eq!((r.plan.global_mb, r.plan.workers, r.plan.micro), (16, 3, 2));
        assert_eq!(r.residual_mb, 0);
        let counts: Vec<usize> = r.plan.per_worker.iter().map(Vec::len).collect();
        assert_eq!(counts, vec![3, 3, 2]);
        // divisible stays exact (and even)
        let r = respread(16, 2, 2).unwrap();
        assert_eq!((r.plan.global_mb, r.plan.workers, r.plan.micro), (16, 2, 2));
        assert_eq!(r.residual_mb, 0);
        // only a micro-indivisible global MB leaves a residual, and it
        // is reported, not silently dropped
        let r = respread(17, 3, 2).unwrap();
        assert_eq!(r.plan.global_mb, 16);
        assert_eq!(r.residual_mb, 1);
        // fewer microbatches than survivors: refuse rather than inflate
        // the minibatch (the old code grew 2 -> 6 here)
        assert!(respread(2, 3, 2).is_err());
        assert!(respread(16, 0, 2).is_err());
    }

    #[test]
    fn policy_names_resolve_through_the_registry() {
        assert_eq!(policy_from_str("stall").unwrap(), RecoveryPolicy::Stall);
        assert_eq!(policy_from_str("shrink").unwrap(), RecoveryPolicy::Shrink);
        assert_eq!(policy_from_str("replan").unwrap(), RecoveryPolicy::Replan);
        let err = policy_from_str("reboot").unwrap_err().to_string();
        assert!(err.contains("stall"), "inventory missing from {err:?}");
    }
}
