//! Parameter store + SGD optimizer state.
//!
//! The update lives on the coordinator, not in the AOT graph: §3.4 puts
//! SGD between part-reduce (gradient sums arrive) and part-broadcast
//! (updated weights leave). Plain SGD (optional momentum) is the paper's
//! setting — it changes no hyperparameters, so neither do we on the
//! paper's workloads; Adam is available for the e2e transformer driver.

use anyhow::{ensure, Result};

/// Optimizer selection. The paper trains with vanilla synchronous SGD
/// (its point is that NO optimizer/hyperparameter changes are needed to
/// scale); Adam is provided for the e2e transformer driver, where plain
/// SGD is a poor fit. Both run on the coordinator between part-reduce
/// and part-broadcast.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Optimizer {
    Sgd,
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

impl Optimizer {
    pub fn adam() -> Self {
        Optimizer::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// SGD/optimizer hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct SgdConfig {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub optimizer: Optimizer,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig { lr: 0.02, momentum: 0.0, weight_decay: 0.0, optimizer: Optimizer::Sgd }
    }
}

/// Bit-exact capture of the full training state: parameters plus every
/// piece of optimizer state the update reads (momentum velocity, Adam
/// moments, per-tensor update counts) and the step counter. Restoring a
/// snapshot and replaying the same gradients reproduces the
/// uninterrupted trajectory bit-for-bit — the determinism contract the
/// checkpoint layer (ISSUE 9 `stall` recovery) is built on.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSnapshot {
    pub step: u64,
    pub tensors: Vec<Vec<f32>>,
    pub velocity: Option<Vec<Vec<f32>>>,
    pub adam_m: Option<Vec<Vec<f32>>>,
    pub adam_v: Option<Vec<Vec<f32>>>,
    pub tensor_steps: Vec<u64>,
}

impl ParamSnapshot {
    pub fn n_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }
}

/// All model parameters as flat f32 tensors (manifest spec order).
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub tensors: Vec<Vec<f32>>,
    velocity: Option<Vec<Vec<f32>>>,
    /// Adam first/second-moment state, lazily allocated.
    adam_m: Option<Vec<Vec<f32>>>,
    adam_v: Option<Vec<Vec<f32>>>,
    /// per-tensor update counts (Adam bias correction is per update)
    tensor_steps: Vec<u64>,
    pub cfg: SgdConfig,
    /// monotone update counter (each tensor updated once per step)
    pub step: u64,
}

impl ParamStore {
    pub fn new(tensors: Vec<Vec<f32>>, cfg: SgdConfig) -> Self {
        let zeros = |ts: &Vec<Vec<f32>>| -> Vec<Vec<f32>> {
            ts.iter().map(|t| vec![0.0; t.len()]).collect()
        };
        let velocity = if cfg.momentum != 0.0 && cfg.optimizer == Optimizer::Sgd {
            Some(zeros(&tensors))
        } else {
            None
        };
        let (adam_m, adam_v) = if matches!(cfg.optimizer, Optimizer::Adam { .. }) {
            (Some(zeros(&tensors)), Some(zeros(&tensors)))
        } else {
            (None, None)
        };
        let n = tensors.len();
        ParamStore {
            tensors,
            velocity,
            adam_m,
            adam_v,
            tensor_steps: vec![0; n],
            cfg,
            step: 0,
        }
    }

    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    pub fn n_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Apply SGD to tensor `t` given its summed gradient over
    /// `grad_scale_inv` microbatches (grad := grad_sum / grad_scale_inv).
    pub fn apply_tensor(&mut self, t: usize, grad_sum: &[f32], grad_scale_inv: f32) -> Result<()> {
        ensure!(t < self.tensors.len(), "tensor index {t} out of range");
        let p = &mut self.tensors[t];
        ensure!(p.len() == grad_sum.len(), "grad len {} != param len {}", grad_sum.len(), p.len());
        let scale = 1.0 / grad_scale_inv;
        let lr = self.cfg.lr;
        let wd = self.cfg.weight_decay;
        if let Optimizer::Adam { beta1, beta2, eps } = self.cfg.optimizer {
            self.tensor_steps[t] += 1;
            let k = self.tensor_steps[t] as f32;
            let bc1 = 1.0 - beta1.powf(k);
            let bc2 = 1.0 - beta2.powf(k);
            let m = &mut self.adam_m.as_mut().expect("adam state")[t];
            let v = &mut self.adam_v.as_mut().expect("adam state")[t];
            for (((w, m), v), &gs) in p.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(grad_sum)
            {
                let g = gs * scale + wd * *w;
                *m = beta1 * *m + (1.0 - beta1) * g;
                *v = beta2 * *v + (1.0 - beta2) * g * g;
                let mh = *m / bc1;
                let vh = *v / bc2;
                *w -= lr * mh / (vh.sqrt() + eps);
            }
            return Ok(());
        }
        match &mut self.velocity {
            None => {
                for (w, &g) in p.iter_mut().zip(grad_sum) {
                    let g = g * scale + wd * *w;
                    *w -= lr * g;
                }
            }
            Some(vel) => {
                let m = self.cfg.momentum;
                for ((w, v), &g) in p.iter_mut().zip(&mut vel[t]).zip(grad_sum) {
                    let g = g * scale + wd * *w;
                    *v = m * *v + g;
                    *w -= lr * *v;
                }
            }
        }
        Ok(())
    }

    /// Apply a full gradient set (tensor order).
    pub fn apply_all(&mut self, grads: &[Vec<f32>], grad_scale_inv: f32) -> Result<()> {
        ensure!(grads.len() == self.tensors.len(), "gradient count mismatch");
        for t in 0..grads.len() {
            self.apply_tensor(t, &grads[t], grad_scale_inv)?;
        }
        self.step += 1;
        Ok(())
    }

    /// Capture the full training state (see [`ParamSnapshot`]).
    pub fn snapshot(&self) -> ParamSnapshot {
        ParamSnapshot {
            step: self.step,
            tensors: self.tensors.clone(),
            velocity: self.velocity.clone(),
            adam_m: self.adam_m.clone(),
            adam_v: self.adam_v.clone(),
            tensor_steps: self.tensor_steps.clone(),
        }
    }

    /// Restore a snapshot bit-identically. The snapshot must come from a
    /// store with the same tensor shapes and the same optimizer family
    /// (momentum/Adam state presence must match) — anything else is a
    /// config mismatch, not a resumable state.
    pub fn restore(&mut self, snap: &ParamSnapshot) -> Result<()> {
        ensure!(
            snap.tensors.len() == self.tensors.len(),
            "snapshot has {} tensors, store has {}",
            snap.tensors.len(),
            self.tensors.len()
        );
        for (t, (a, b)) in snap.tensors.iter().zip(&self.tensors).enumerate() {
            ensure!(
                a.len() == b.len(),
                "snapshot tensor {t} has {} elements, store has {}",
                a.len(),
                b.len()
            );
        }
        ensure!(
            snap.velocity.is_some() == self.velocity.is_some(),
            "snapshot momentum state ({}) does not match the store's optimizer config ({})",
            snap.velocity.is_some(),
            self.velocity.is_some()
        );
        ensure!(
            snap.adam_m.is_some() == self.adam_m.is_some()
                && snap.adam_v.is_some() == self.adam_v.is_some(),
            "snapshot Adam state does not match the store's optimizer config"
        );
        ensure!(
            snap.tensor_steps.len() == self.tensor_steps.len(),
            "snapshot tensor_steps length mismatch"
        );
        self.tensors = snap.tensors.clone();
        self.velocity = snap.velocity.clone();
        self.adam_m = snap.adam_m.clone();
        self.adam_v = snap.adam_v.clone();
        self.tensor_steps = snap.tensor_steps.clone();
        self.step = snap.step;
        Ok(())
    }

    /// L2 norm over all parameters (drift probe for tests).
    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_update() {
        let mut s = ParamStore::new(
            vec![vec![1.0, 2.0]],
            SgdConfig { lr: 0.5, momentum: 0.0, weight_decay: 0.0, optimizer: Optimizer::Sgd },
        );
        s.apply_all(&[vec![2.0, 4.0]], 2.0).unwrap(); // grads = [1, 2]
        assert_eq!(s.tensors[0], vec![0.5, 1.0]);
        assert_eq!(s.step, 1);
    }

    #[test]
    fn momentum_accumulates() {
        let cfg = SgdConfig { lr: 1.0, momentum: 0.5, ..SgdConfig::default() };
        let mut s = ParamStore::new(vec![vec![0.0]], cfg);
        s.apply_all(&[vec![1.0]], 1.0).unwrap(); // v=1, w=-1
        s.apply_all(&[vec![1.0]], 1.0).unwrap(); // v=1.5, w=-2.5
        assert!((s.tensors[0][0] + 2.5).abs() < 1e-6, "{}", s.tensors[0][0]);
    }

    #[test]
    fn weight_decay_shrinks() {
        let cfg = SgdConfig { lr: 0.1, weight_decay: 0.1, ..SgdConfig::default() };
        let mut s = ParamStore::new(vec![vec![10.0]], cfg);
        s.apply_all(&[vec![0.0]], 1.0).unwrap();
        assert!(s.tensors[0][0] < 10.0);
    }

    #[test]
    fn adam_first_step_moves_by_lr() {
        // bias-corrected Adam's first update is ~lr * sign(g)
        let cfg = SgdConfig { lr: 0.1, momentum: 0.0, weight_decay: 0.0,
                              optimizer: Optimizer::adam() };
        let mut s = ParamStore::new(vec![vec![1.0, -1.0]], cfg);
        s.apply_all(&[vec![3.0, -0.5]], 1.0).unwrap();
        assert!((s.tensors[0][0] - (1.0 - 0.1)).abs() < 1e-3, "{}", s.tensors[0][0]);
        assert!((s.tensors[0][1] - (-1.0 + 0.1)).abs() < 1e-3, "{}", s.tensors[0][1]);
    }

    #[test]
    fn adam_adapts_to_gradient_scale() {
        // constant gradient: per-step movement stays ~lr regardless of |g|
        let cfg = SgdConfig { lr: 0.01, momentum: 0.0, weight_decay: 0.0,
                              optimizer: Optimizer::adam() };
        for g in [1e-3f32, 1.0, 1e3] {
            let mut s = ParamStore::new(vec![vec![0.0]], cfg);
            for _ in 0..10 {
                s.apply_all(&[vec![g]], 1.0).unwrap();
            }
            let moved = -s.tensors[0][0];
            assert!((moved - 0.1).abs() < 0.02, "g={g}: moved {moved}");
        }
    }

    #[test]
    fn snapshot_restore_replays_bit_identically() {
        // the checkpoint determinism contract: restore + replay == never
        // interrupted, for every optimizer family
        let cfgs = [
            SgdConfig { lr: 0.05, ..SgdConfig::default() },
            SgdConfig { lr: 0.05, momentum: 0.9, weight_decay: 5e-4, ..SgdConfig::default() },
            SgdConfig { lr: 3e-3, optimizer: Optimizer::adam(), ..SgdConfig::default() },
        ];
        for cfg in cfgs {
            let init = vec![vec![0.7f32, -0.3, 1.1], vec![0.25f32; 5]];
            let grad_for = |k: u64| -> Vec<Vec<f32>> {
                vec![
                    (0..3).map(|i| (k as f32 + 1.0) * 0.1 - i as f32 * 0.03).collect(),
                    (0..5).map(|i| (i as f32 - k as f32) * 0.2).collect(),
                ]
            };
            let mut a = ParamStore::new(init.clone(), cfg);
            for k in 0..3 {
                a.apply_all(&grad_for(k), 2.0).unwrap();
            }
            let snap = a.snapshot();
            for k in 3..6 {
                a.apply_all(&grad_for(k), 2.0).unwrap();
            }
            let mut b = ParamStore::new(init, cfg);
            b.restore(&snap).unwrap();
            assert_eq!(b.step, 3);
            for k in 3..6 {
                b.apply_all(&grad_for(k), 2.0).unwrap();
            }
            assert_eq!(a.step, b.step);
            for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
                let eq = ta.iter().zip(tb).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(eq, "restore + replay diverged under {:?}", cfg.optimizer);
            }
        }
    }

    #[test]
    fn restore_rejects_mismatched_state() {
        let mut plain = ParamStore::new(vec![vec![0.0; 3]], SgdConfig::default());
        // wrong tensor shape
        let mut snap = plain.snapshot();
        snap.tensors[0].push(0.0);
        assert!(plain.restore(&snap).is_err());
        // optimizer-family mismatch (Adam snapshot into a plain store)
        let adam = ParamStore::new(
            vec![vec![0.0; 3]],
            SgdConfig { optimizer: Optimizer::adam(), ..SgdConfig::default() },
        );
        assert!(plain.restore(&adam.snapshot()).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut s = ParamStore::new(vec![vec![0.0; 3]], SgdConfig::default());
        assert!(s.apply_all(&[vec![0.0; 2]], 1.0).is_err());
        assert!(s.apply_all(&[vec![0.0; 3], vec![0.0]], 1.0).is_err());
    }
}
