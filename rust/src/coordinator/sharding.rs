//! Minibatch partitioning: global minibatch -> workers -> fixed-size
//! microbatches (the AOT artifacts have a fixed batch dimension, so
//! workers run `global_mb / (workers * micro)` sequential executions and
//! accumulate gradients locally before the collective — standard
//! gradient accumulation, semantics identical to one big batch).

use anyhow::{ensure, Result};

/// The per-step execution plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MicrobatchPlan {
    pub global_mb: usize,
    pub workers: usize,
    pub micro: usize,
    /// microbatch start offsets per worker, each of length micro
    pub per_worker: Vec<Vec<usize>>,
}

impl MicrobatchPlan {
    /// Build the plan; requires `workers * micro` to divide `global_mb`.
    pub fn new(global_mb: usize, workers: usize, micro: usize) -> Result<Self> {
        ensure!(workers >= 1 && micro >= 1, "degenerate plan");
        ensure!(
            global_mb % (workers * micro) == 0,
            "global minibatch {global_mb} not divisible by workers({workers}) x micro({micro})"
        );
        let per_w = global_mb / workers;
        let per_worker = (0..workers)
            .map(|w| (0..per_w / micro).map(|m| w * per_w + m * micro).collect())
            .collect();
        Ok(MicrobatchPlan { global_mb, workers, micro, per_worker })
    }

    /// Build a plan that tolerates worker counts not dividing the
    /// microbatch count by spreading the excess microbatches one-per-
    /// worker from the front — the recovery path's way of keeping the
    /// global minibatch (a hyperparameter) intact when survivors no
    /// longer divide it. Still requires `micro | global_mb`, and every
    /// worker must receive at least one microbatch (a worker with an
    /// empty slate would contribute a stale gradient buffer to the
    /// all-reduce). Identical layout to [`MicrobatchPlan::new`] whenever
    /// the division is exact.
    pub fn uneven(global_mb: usize, workers: usize, micro: usize) -> Result<Self> {
        ensure!(workers >= 1 && micro >= 1, "degenerate plan");
        ensure!(
            global_mb % micro == 0,
            "global minibatch {global_mb} not divisible by micro({micro})"
        );
        let total = global_mb / micro;
        ensure!(
            total >= workers,
            "global minibatch {global_mb} yields {total} microbatches of {micro} — fewer \
             than {workers} workers, so some worker would fold an empty (stale) gradient \
             into the all-reduce"
        );
        let (base, extra) = (total / workers, total % workers);
        let mut per_worker = Vec::with_capacity(workers);
        let mut off = 0;
        for w in 0..workers {
            let n = base + usize::from(w < extra);
            per_worker.push((0..n).map(|m| off + m * micro).collect());
            off += n * micro;
        }
        Ok(MicrobatchPlan { global_mb, workers, micro, per_worker })
    }

    /// Total microbatch executions per step.
    pub fn total_micro(&self) -> usize {
        self.global_mb / self.micro
    }

    /// Microbatches per worker.
    pub fn micro_per_worker(&self) -> usize {
        self.total_micro() / self.workers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_global_batch_exactly() {
        let p = MicrobatchPlan::new(16, 4, 2).unwrap();
        let mut starts: Vec<usize> = p.per_worker.iter().flatten().copied().collect();
        starts.sort_unstable();
        assert_eq!(starts, vec![0, 2, 4, 6, 8, 10, 12, 14]);
        assert_eq!(p.total_micro(), 8);
        assert_eq!(p.micro_per_worker(), 2);
    }

    #[test]
    fn single_worker_sees_all() {
        let p = MicrobatchPlan::new(16, 1, 4).unwrap();
        assert_eq!(p.per_worker[0], vec![0, 4, 8, 12]);
    }

    #[test]
    fn workers_get_disjoint_contiguous_ranges() {
        let p = MicrobatchPlan::new(32, 4, 4).unwrap();
        for (w, starts) in p.per_worker.iter().enumerate() {
            for s in starts {
                assert!(*s >= w * 8 && *s < (w + 1) * 8);
            }
        }
    }

    #[test]
    fn same_data_different_worker_counts() {
        // The union of sample indices is identical for any worker count —
        // the precondition for Fig 5 equivalence.
        let all = |workers| -> Vec<usize> {
            let p = MicrobatchPlan::new(16, workers, 2).unwrap();
            let mut v: Vec<usize> =
                p.per_worker.iter().flatten().flat_map(|&s| s..s + 2).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(all(1), all(2));
        assert_eq!(all(2), all(4));
        assert_eq!(all(4), all(8));
    }

    #[test]
    fn indivisible_rejected() {
        assert!(MicrobatchPlan::new(10, 4, 2).is_err());
        assert!(MicrobatchPlan::new(16, 3, 2).is_err());
    }

    #[test]
    fn uneven_matches_new_when_divisible() {
        for (mb, w, micro) in [(16, 4, 2), (16, 1, 4), (32, 4, 4), (16, 2, 2)] {
            assert_eq!(
                MicrobatchPlan::uneven(mb, w, micro).unwrap(),
                MicrobatchPlan::new(mb, w, micro).unwrap(),
                "{mb}/{w}/{micro}"
            );
        }
    }

    #[test]
    fn uneven_spreads_remainder_without_trimming() {
        // 16 samples over 3 survivors at micro 2: 8 microbatches split
        // 3/3/2 — the global minibatch stays 16, no samples dropped
        let p = MicrobatchPlan::uneven(16, 3, 2).unwrap();
        assert_eq!(p.global_mb, 16);
        let counts: Vec<usize> = p.per_worker.iter().map(Vec::len).collect();
        assert_eq!(counts, vec![3, 3, 2]);
        let mut starts: Vec<usize> = p.per_worker.iter().flatten().copied().collect();
        starts.sort_unstable();
        assert_eq!(starts, vec![0, 2, 4, 6, 8, 10, 12, 14]);
        // disjoint contiguous coverage of the whole batch
        let samples: Vec<usize> =
            starts.iter().flat_map(|&s| s..s + p.micro).collect();
        assert_eq!(samples, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn uneven_rejects_empty_workers_and_ragged_micro() {
        // fewer microbatches than workers → some worker gets nothing →
        // its recycled gradient buffer would poison the fold
        let e = MicrobatchPlan::uneven(2, 3, 2).unwrap_err().to_string();
        assert!(e.contains("fewer"), "{e}");
        // micro must still divide the global minibatch
        assert!(MicrobatchPlan::uneven(15, 3, 2).is_err());
        assert!(MicrobatchPlan::uneven(16, 0, 2).is_err());
    }
}
