//! Minibatch partitioning: global minibatch -> workers -> fixed-size
//! microbatches (the AOT artifacts have a fixed batch dimension, so
//! workers run `global_mb / (workers * micro)` sequential executions and
//! accumulate gradients locally before the collective — standard
//! gradient accumulation, semantics identical to one big batch).

use anyhow::{ensure, Result};

/// The per-step execution plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MicrobatchPlan {
    pub global_mb: usize,
    pub workers: usize,
    pub micro: usize,
    /// microbatch start offsets per worker, each of length micro
    pub per_worker: Vec<Vec<usize>>,
}

impl MicrobatchPlan {
    /// Build the plan; requires `workers * micro` to divide `global_mb`.
    pub fn new(global_mb: usize, workers: usize, micro: usize) -> Result<Self> {
        ensure!(workers >= 1 && micro >= 1, "degenerate plan");
        ensure!(
            global_mb % (workers * micro) == 0,
            "global minibatch {global_mb} not divisible by workers({workers}) x micro({micro})"
        );
        let per_w = global_mb / workers;
        let per_worker = (0..workers)
            .map(|w| (0..per_w / micro).map(|m| w * per_w + m * micro).collect())
            .collect();
        Ok(MicrobatchPlan { global_mb, workers, micro, per_worker })
    }

    /// Total microbatch executions per step.
    pub fn total_micro(&self) -> usize {
        self.global_mb / self.micro
    }

    /// Microbatches per worker.
    pub fn micro_per_worker(&self) -> usize {
        self.total_micro() / self.workers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_global_batch_exactly() {
        let p = MicrobatchPlan::new(16, 4, 2).unwrap();
        let mut starts: Vec<usize> = p.per_worker.iter().flatten().copied().collect();
        starts.sort_unstable();
        assert_eq!(starts, vec![0, 2, 4, 6, 8, 10, 12, 14]);
        assert_eq!(p.total_micro(), 8);
        assert_eq!(p.micro_per_worker(), 2);
    }

    #[test]
    fn single_worker_sees_all() {
        let p = MicrobatchPlan::new(16, 1, 4).unwrap();
        assert_eq!(p.per_worker[0], vec![0, 4, 8, 12]);
    }

    #[test]
    fn workers_get_disjoint_contiguous_ranges() {
        let p = MicrobatchPlan::new(32, 4, 4).unwrap();
        for (w, starts) in p.per_worker.iter().enumerate() {
            for s in starts {
                assert!(*s >= w * 8 && *s < (w + 1) * 8);
            }
        }
    }

    #[test]
    fn same_data_different_worker_counts() {
        // The union of sample indices is identical for any worker count —
        // the precondition for Fig 5 equivalence.
        let all = |workers| -> Vec<usize> {
            let p = MicrobatchPlan::new(16, workers, 2).unwrap();
            let mut v: Vec<usize> =
                p.per_worker.iter().flatten().flat_map(|&s| s..s + 2).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(all(1), all(2));
        assert_eq!(all(2), all(4));
        assert_eq!(all(4), all(8));
    }

    #[test]
    fn indivisible_rejected() {
        assert!(MicrobatchPlan::new(10, 4, 2).is_err());
        assert!(MicrobatchPlan::new(16, 3, 2).is_err());
    }
}
