//! The dedicated communication thread (paper §4): drains the lock-free
//! command queue, executes part-reduce / part-broadcast over worker
//! gradient buffers, and posts completions. The compute path's submit is
//! a single lock-free push ("submit-and-forget"); completion is consumed
//! whenever the coordinator actually needs the result, which is what
//! creates the §3.1 overlap window.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::collectives::{fold_into, inline};

use super::command_queue::CommandQueue;

/// What to run over the buffers (one buffer per worker/rank).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommOp {
    /// part-reduce: rank r owns the reduced shard r afterwards.
    PartReduce,
    /// part-broadcast: every rank sees every owned shard.
    PartBroadcast,
    /// both (the full gradient exchange).
    AllReduce,
    /// Streaming fold for the overlapped exchange: `bufs[0] += bufs[1]`
    /// (chunked [`fold_into`]); both buffers come back in the completion.
    /// `rank` is the contributing worker — carried for diagnostics; the
    /// reduction *order* is pinned by submission order, which the leader
    /// keeps in rank order so the running sum is the serial left-to-right
    /// scan `((b0+b1)+b2)+…` bit-for-bit.
    Reduce { rank: usize },
}

/// A queued communication command.
#[derive(Debug)]
pub struct CommRequest {
    pub id: u64,
    pub op: CommOp,
    /// One gradient buffer per worker; the collective runs across them.
    pub bufs: Vec<Vec<f32>>,
}

/// Completed command, same id, buffers after the collective.
pub struct CommCompletion {
    pub id: u64,
    pub bufs: Vec<Vec<f32>>,
}

/// Result of a bounded completion wait ([`CommHandle::wait_timeout`]).
/// Distinguishes "nothing yet" from "the thread is gone" so abort and
/// recovery paths can back off without blocking forever on a dead
/// channel.
pub enum WaitOutcome {
    Done(CommCompletion),
    TimedOut,
    /// The comm thread exited and the channel is drained.
    Disconnected,
}

/// Handle owning the comm thread.
pub struct CommHandle {
    queue: Arc<CommandQueue<CommRequest>>,
    completions: Receiver<CommCompletion>,
    stop: Arc<AtomicBool>,
    paused: Arc<AtomicBool>,
    busy_ns: Arc<AtomicU64>,
    handle: Option<JoinHandle<u64>>,
}

impl CommHandle {
    /// Spawn the dedicated comm thread with a queue of `depth` commands.
    pub fn spawn(depth: usize) -> CommHandle {
        Self::spawn_with(depth, false)
    }

    /// [`CommHandle::spawn`] but with the thread frozen from the first
    /// instruction (see [`CommHandle::set_paused`]) — the spawn-then-pause
    /// ordering would otherwise race one loop iteration. Test/bench hook.
    pub fn spawn_paused(depth: usize) -> CommHandle {
        Self::spawn_with(depth, true)
    }

    fn spawn_with(depth: usize, start_paused: bool) -> CommHandle {
        let queue = Arc::new(CommandQueue::<CommRequest>::new(depth));
        let stop = Arc::new(AtomicBool::new(false));
        let paused = Arc::new(AtomicBool::new(start_paused));
        let busy_ns = Arc::new(AtomicU64::new(0));
        let (tx, rx): (Sender<CommCompletion>, Receiver<CommCompletion>) = channel();
        let q = queue.clone();
        let s = stop.clone();
        let p = paused.clone();
        let busy = busy_ns.clone();
        let handle = std::thread::Builder::new()
            .name("pcl-dnn-comm".into())
            .spawn(move || {
                let mut processed = 0u64;
                loop {
                    // stop overrides pause so shutdown/drop can never
                    // hang on a frozen thread; it still drains the queue
                    if p.load(Ordering::Acquire) && !s.load(Ordering::Acquire) {
                        std::thread::yield_now();
                        continue;
                    }
                    match q.pop() {
                        Some(mut req) => {
                            let t0 = std::time::Instant::now();
                            match req.op {
                                CommOp::PartReduce => inline::part_reduce(&mut req.bufs),
                                CommOp::PartBroadcast => inline::part_broadcast(&mut req.bufs),
                                CommOp::AllReduce => inline::allreduce(&mut req.bufs),
                                CommOp::Reduce { .. } => {
                                    let (acc, contrib) = req.bufs.split_at_mut(1);
                                    fold_into(&mut acc[0], &contrib[0]);
                                }
                            }
                            busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                            processed += 1;
                            if tx.send(CommCompletion { id: req.id, bufs: req.bufs }).is_err() {
                                return processed;
                            }
                        }
                        None => {
                            if s.load(Ordering::Acquire) && q.is_empty() {
                                return processed;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
            })
            .expect("spawning comm thread");
        CommHandle { queue, completions: rx, stop, paused, busy_ns, handle: Some(handle) }
    }

    /// Submit-and-forget. Non-blocking; on a full queue the command is
    /// returned so the caller can decide (the paper's library applies
    /// backpressure the same way).
    pub fn submit(&self, req: CommRequest) -> Result<(), CommRequest> {
        self.queue.push(req).map_err(|e| e.0)
    }

    /// Blocking wait for the next completion (any order policy is the
    /// caller's business; completions arrive in execution order).
    pub fn wait_one(&self) -> Option<CommCompletion> {
        self.completions.recv().ok()
    }

    /// Non-blocking completion poll.
    pub fn try_complete(&self) -> Option<CommCompletion> {
        self.completions.try_recv().ok()
    }

    /// Bounded completion wait: the leader's abort/recovery paths (ISSUE
    /// 9) layer exponential backoff over this instead of parking forever
    /// in [`CommHandle::wait_one`] — a dead or wedged comm thread then
    /// surfaces as an error, not a hang.
    pub fn wait_timeout(&self, timeout: std::time::Duration) -> WaitOutcome {
        use std::sync::mpsc::RecvTimeoutError;
        match self.completions.recv_timeout(timeout) {
            Ok(done) => WaitOutcome::Done(done),
            Err(RecvTimeoutError::Timeout) => WaitOutcome::TimedOut,
            Err(RecvTimeoutError::Disconnected) => WaitOutcome::Disconnected,
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Cumulative nanoseconds the thread has spent executing collectives
    /// (monotonic). The leader differences this across a step to get
    /// comm busy time, and `busy − blocked-wait` is the measured overlap.
    pub fn busy_ns(&self) -> u64 {
        self.busy_ns.load(Ordering::Relaxed)
    }

    /// Test/bench hook: freeze (or resume) the comm thread *before* it
    /// pops the next command. While paused, submissions queue up — this
    /// is what makes the backpressure test deterministic instead of a
    /// race against the drain rate.
    pub fn set_paused(&self, paused: bool) {
        self.paused.store(paused, Ordering::Release);
    }

    /// Stop after draining; returns commands processed.
    pub fn shutdown(mut self) -> u64 {
        self.stop.store(true, Ordering::Release);
        self.handle.take().map(|h| h.join().unwrap_or(0)).unwrap_or(0)
    }
}

impl Drop for CommHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bufs(k: usize, len: usize) -> Vec<Vec<f32>> {
        (0..k).map(|r| (0..len).map(|i| (r + i) as f32).collect()).collect()
    }

    #[test]
    fn allreduce_through_thread_matches_inline() {
        let h = CommHandle::spawn(8);
        let mut expect = bufs(4, 100);
        inline::allreduce(&mut expect);
        h.submit(CommRequest { id: 7, op: CommOp::AllReduce, bufs: bufs(4, 100) }).unwrap();
        let done = h.wait_one().unwrap();
        assert_eq!(done.id, 7);
        assert_eq!(done.bufs, expect);
        assert_eq!(h.shutdown(), 1);
    }

    #[test]
    fn completions_in_submission_order() {
        let h = CommHandle::spawn(8);
        for id in 0..5 {
            h.submit(CommRequest { id, op: CommOp::AllReduce, bufs: bufs(2, 10) }).unwrap();
        }
        for id in 0..5 {
            assert_eq!(h.wait_one().unwrap().id, id);
        }
    }

    #[test]
    fn submit_is_nonblocking_on_full_queue() {
        // deterministic backpressure: freeze the comm thread, fill the
        // queue to capacity, and assert the overflowing submit comes back
        // intact instead of blocking or dropping.
        let h = CommHandle::spawn_paused(2); // capacity exactly 2
        let mut accepted = 0u64;
        let mut bounced = None;
        for id in 0..16u64 {
            match h.submit(CommRequest { id, op: CommOp::PartReduce, bufs: bufs(2, 64) }) {
                Ok(()) => accepted += 1,
                Err(back) => {
                    bounced = Some(back);
                    break;
                }
            }
        }
        let back = bounced.expect("queue never exerted backpressure");
        assert_eq!(accepted, 2, "queue accepted past its capacity");
        assert_eq!(back.id, 2, "wrong request handed back");
        assert_eq!(back.bufs, bufs(2, 64), "bounced request lost its buffers");
        // resume: everything accepted completes in order, nothing is lost
        h.set_paused(false);
        for id in 0..accepted {
            assert_eq!(h.wait_one().unwrap().id, id);
        }
        assert_eq!(h.shutdown(), accepted);
    }

    #[test]
    fn reduce_op_folds_acc_in_place_and_returns_both_buffers() {
        let h = CommHandle::spawn(8);
        let acc: Vec<f32> = (0..300).map(|i| i as f32 * 0.25).collect();
        let contrib: Vec<f32> = (0..300).map(|i| 100.0 - i as f32).collect();
        let want: Vec<f32> = acc.iter().zip(&contrib).map(|(a, c)| a + c).collect();
        h.submit(CommRequest {
            id: 3,
            op: CommOp::Reduce { rank: 1 },
            bufs: vec![acc, contrib.clone()],
        })
        .unwrap();
        let done = h.wait_one().unwrap();
        assert_eq!(done.id, 3);
        assert_eq!(done.bufs.len(), 2, "both buffers must come back for recycling");
        assert_eq!(done.bufs[0], want);
        assert_eq!(done.bufs[1], contrib, "contrib buffer must be unmodified");
        assert!(h.busy_ns() > 0, "busy accounting missed the fold");
        assert_eq!(h.shutdown(), 1);
    }

    #[test]
    fn chained_reduce_matches_allreduce_sum_bitwise() {
        // rank-ordered Reduce submissions == one AllReduce, bit-for-bit —
        // the determinism contract the streaming leader is built on
        let n = 6;
        let mut reference = bufs(n, 517);
        inline::allreduce(&mut reference);
        let h = CommHandle::spawn(8);
        let all = bufs(n, 517);
        let mut acc = all[0].clone();
        for (rank, contrib) in all.into_iter().enumerate().skip(1) {
            h.submit(CommRequest {
                id: rank as u64,
                op: CommOp::Reduce { rank },
                bufs: vec![acc, contrib],
            })
            .unwrap();
            let mut done = h.wait_one().unwrap();
            done.bufs.truncate(1);
            acc = done.bufs.pop().unwrap();
        }
        let eq = acc.iter().zip(&reference[0]).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(eq, "chained folds diverged from allreduce");
        assert_eq!(h.shutdown(), (n - 1) as u64);
    }

    #[test]
    fn shutdown_drains_pending() {
        let h = CommHandle::spawn(16);
        for id in 0..10 {
            h.submit(CommRequest { id, op: CommOp::PartReduce, bufs: bufs(2, 100) }).unwrap();
        }
        assert_eq!(h.shutdown(), 10);
    }

    #[test]
    fn worker_panic_mid_fold_neither_poisons_nor_hangs() {
        // ISSUE 9 hardening: a worker closure that panics while Reduce
        // folds are in flight must leave the comm thread healthy — the
        // leader drains deterministically and Drop cannot hang. The
        // panic happens leader-side (the thread never sees it); what it
        // must survive is the abandoned in-flight work.
        use std::panic::{catch_unwind, AssertUnwindSafe};
        use std::time::Duration;
        let h = CommHandle::spawn(8);
        for id in 0..4u64 {
            h.submit(CommRequest {
                id,
                op: CommOp::Reduce { rank: 1 },
                bufs: vec![vec![1.0f32; 512], vec![2.0f32; 512]],
            })
            .unwrap();
        }
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let died = catch_unwind(AssertUnwindSafe(|| -> () {
            panic!("worker died mid-fold");
        }));
        std::panic::set_hook(hook);
        assert!(died.is_err());
        // drain-or-abort: every in-flight op completes under a bounded
        // wait; nothing is lost, nothing blocks forever
        for id in 0..4u64 {
            match h.wait_timeout(Duration::from_secs(5)) {
                WaitOutcome::Done(done) => {
                    assert_eq!(done.id, id);
                    assert_eq!(done.bufs[0][0], 3.0);
                }
                WaitOutcome::TimedOut => panic!("fold {id} never completed"),
                WaitOutcome::Disconnected => panic!("comm thread died draining fold {id}"),
            }
        }
        assert_eq!(h.shutdown(), 4);
    }

    #[test]
    fn drop_with_inflight_ops_terminates_even_when_paused() {
        // stop-overrides-pause extended to the abort path: dropping the
        // handle with queued work AND the thread frozen must still
        // terminate (drain, then exit) instead of spinning on the pause
        // gate forever.
        let h = CommHandle::spawn_paused(8);
        for id in 0..5u64 {
            h.submit(CommRequest { id, op: CommOp::AllReduce, bufs: bufs(2, 64) }).unwrap();
        }
        drop(h); // must not hang
    }
}
