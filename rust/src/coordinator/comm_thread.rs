//! The dedicated communication thread (paper §4): drains the lock-free
//! command queue, executes part-reduce / part-broadcast over worker
//! gradient buffers, and posts completions. The compute path's submit is
//! a single lock-free push ("submit-and-forget"); completion is consumed
//! whenever the coordinator actually needs the result, which is what
//! creates the §3.1 overlap window.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::collectives::inline;

use super::command_queue::CommandQueue;

/// What to run over the buffers (one buffer per worker/rank).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommOp {
    /// part-reduce: rank r owns the reduced shard r afterwards.
    PartReduce,
    /// part-broadcast: every rank sees every owned shard.
    PartBroadcast,
    /// both (the full gradient exchange).
    AllReduce,
}

/// A queued communication command.
#[derive(Debug)]
pub struct CommRequest {
    pub id: u64,
    pub op: CommOp,
    /// One gradient buffer per worker; the collective runs across them.
    pub bufs: Vec<Vec<f32>>,
}

/// Completed command, same id, buffers after the collective.
pub struct CommCompletion {
    pub id: u64,
    pub bufs: Vec<Vec<f32>>,
}

/// Handle owning the comm thread.
pub struct CommHandle {
    queue: Arc<CommandQueue<CommRequest>>,
    completions: Receiver<CommCompletion>,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<u64>>,
}

impl CommHandle {
    /// Spawn the dedicated comm thread with a queue of `depth` commands.
    pub fn spawn(depth: usize) -> CommHandle {
        let queue = Arc::new(CommandQueue::<CommRequest>::new(depth));
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx): (Sender<CommCompletion>, Receiver<CommCompletion>) = channel();
        let q = queue.clone();
        let s = stop.clone();
        let handle = std::thread::Builder::new()
            .name("pcl-dnn-comm".into())
            .spawn(move || {
                let mut processed = 0u64;
                loop {
                    match q.pop() {
                        Some(mut req) => {
                            match req.op {
                                CommOp::PartReduce => inline::part_reduce(&mut req.bufs),
                                CommOp::PartBroadcast => inline::part_broadcast(&mut req.bufs),
                                CommOp::AllReduce => inline::allreduce(&mut req.bufs),
                            }
                            processed += 1;
                            if tx.send(CommCompletion { id: req.id, bufs: req.bufs }).is_err() {
                                return processed;
                            }
                        }
                        None => {
                            if s.load(Ordering::Acquire) && q.is_empty() {
                                return processed;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
            })
            .expect("spawning comm thread");
        CommHandle { queue, completions: rx, stop, handle: Some(handle) }
    }

    /// Submit-and-forget. Non-blocking; on a full queue the command is
    /// returned so the caller can decide (the paper's library applies
    /// backpressure the same way).
    pub fn submit(&self, req: CommRequest) -> Result<(), CommRequest> {
        self.queue.push(req).map_err(|e| e.0)
    }

    /// Blocking wait for the next completion (any order policy is the
    /// caller's business; completions arrive in execution order).
    pub fn wait_one(&self) -> Option<CommCompletion> {
        self.completions.recv().ok()
    }

    /// Non-blocking completion poll.
    pub fn try_complete(&self) -> Option<CommCompletion> {
        self.completions.try_recv().ok()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Stop after draining; returns commands processed.
    pub fn shutdown(mut self) -> u64 {
        self.stop.store(true, Ordering::Release);
        self.handle.take().map(|h| h.join().unwrap_or(0)).unwrap_or(0)
    }
}

impl Drop for CommHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bufs(k: usize, len: usize) -> Vec<Vec<f32>> {
        (0..k).map(|r| (0..len).map(|i| (r + i) as f32).collect()).collect()
    }

    #[test]
    fn allreduce_through_thread_matches_inline() {
        let h = CommHandle::spawn(8);
        let mut expect = bufs(4, 100);
        inline::allreduce(&mut expect);
        h.submit(CommRequest { id: 7, op: CommOp::AllReduce, bufs: bufs(4, 100) }).unwrap();
        let done = h.wait_one().unwrap();
        assert_eq!(done.id, 7);
        assert_eq!(done.bufs, expect);
        assert_eq!(h.shutdown(), 1);
    }

    #[test]
    fn completions_in_submission_order() {
        let h = CommHandle::spawn(8);
        for id in 0..5 {
            h.submit(CommRequest { id, op: CommOp::AllReduce, bufs: bufs(2, 10) }).unwrap();
        }
        for id in 0..5 {
            assert_eq!(h.wait_one().unwrap().id, id);
        }
    }

    #[test]
    fn submit_is_nonblocking_on_full_queue() {
        let h = CommHandle::spawn(2);
        // flood faster than the comm thread drains; eventually push fails
        // rather than blocking, handing the request back.
        let mut returned = 0;
        for id in 0..50_000u64 {
            if h.submit(CommRequest { id, op: CommOp::PartReduce, bufs: bufs(2, 2000) }).is_err() {
                returned += 1;
                break;
            }
        }
        // drain whatever completed; no hang
        while h.try_complete().is_some() {}
        let _ = returned; // may be 0 on a fast machine; the property is "no deadlock"
    }

    #[test]
    fn shutdown_drains_pending() {
        let h = CommHandle::spawn(16);
        for id in 0..10 {
            h.submit(CommRequest { id, op: CommOp::PartReduce, bufs: bufs(2, 100) }).unwrap();
        }
        assert_eq!(h.shutdown(), 10);
    }
}
