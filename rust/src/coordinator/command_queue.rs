//! Lock-free bounded MPMC command queue (paper §4: "a lock-free command
//! queue that enables the compute library to submit communication
//! commands in a non-blocking manner (i.e., submit-and-forget)").
//!
//! Vyukov bounded MPMC ring: each slot carries a sequence number;
//! producers and consumers claim slots with a single CAS each, no locks,
//! no spurious blocking. Push never waits — a full queue returns the
//! command to the caller (backpressure is explicit).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Returned when the ring is full; hands the value back.
#[derive(Debug, PartialEq, Eq)]
pub struct PushError<T>(pub T);

struct Slot<T> {
    seq: AtomicUsize,
    value: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded lock-free MPMC queue.
pub struct CommandQueue<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    head: AtomicUsize, // next pop position
    tail: AtomicUsize, // next push position
}

unsafe impl<T: Send> Send for CommandQueue<T> {}
unsafe impl<T: Send> Sync for CommandQueue<T> {}

impl<T> CommandQueue<T> {
    /// Capacity is rounded up to a power of two (>= 2).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Vec<Slot<T>> = (0..cap)
            .map(|i| Slot { seq: AtomicUsize::new(i), value: UnsafeCell::new(MaybeUninit::uninit()) })
            .collect();
        CommandQueue {
            slots: slots.into_boxed_slice(),
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Non-blocking push (the submit side of submit-and-forget).
    pub fn push(&self, value: T) -> Result<(), PushError<T>> {
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                // slot free at this lap: try to claim
                match self.tail.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.value.get()).write(value) };
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(actual) => pos = actual,
                }
            } else if diff < 0 {
                return Err(PushError(value)); // full
            } else {
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Non-blocking pop (the comm thread's drain side).
    pub fn pop(&self) -> Option<T> {
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - (pos + 1) as isize;
            if diff == 0 {
                match self.head.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let value = unsafe { (*slot.value.get()).assume_init_read() };
                        slot.seq.store(pos + self.mask + 1, Ordering::Release);
                        return Some(value);
                    }
                    Err(actual) => pos = actual,
                }
            } else if diff < 0 {
                return None; // empty
            } else {
                pos = self.head.load(Ordering::Relaxed);
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::SeqCst) == self.tail.load(Ordering::SeqCst)
    }

    pub fn len(&self) -> usize {
        self.tail
            .load(Ordering::SeqCst)
            .saturating_sub(self.head.load(Ordering::SeqCst))
    }
}

impl<T> Drop for CommandQueue<T> {
    fn drop(&mut self) {
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = CommandQueue::new(8);
        for i in 0..8 {
            q.push(i).unwrap();
        }
        assert_eq!(q.push(99), Err(PushError(99))); // full
        for i in 0..8 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn wraps_around() {
        let q = CommandQueue::new(4);
        for lap in 0..10 {
            for i in 0..4 {
                q.push(lap * 4 + i).unwrap();
            }
            for i in 0..4 {
                assert_eq!(q.pop(), Some(lap * 4 + i));
            }
        }
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q = Arc::new(CommandQueue::new(64));
        let producers = 4;
        let per = 2500u64;
        let mut handles = Vec::new();
        for p in 0..producers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    let v = p * per + i;
                    loop {
                        if q.push(v).is_ok() {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            }));
        }
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut seen = vec![false; (producers * per) as usize];
                let mut count = 0usize;
                while count < seen.len() {
                    if let Some(v) = q.pop() {
                        assert!(!seen[v as usize], "duplicate {v}");
                        seen[v as usize] = true;
                        count += 1;
                    } else {
                        std::thread::yield_now();
                    }
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        let seen = consumer.join().unwrap();
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn drop_releases_queued_values() {
        // Box values would leak if Drop didn't drain.
        let q = CommandQueue::new(8);
        q.push(Box::new(1u64)).unwrap();
        q.push(Box::new(2u64)).unwrap();
        drop(q); // miri/asan-clean by construction
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        assert_eq!(CommandQueue::<u8>::new(3).capacity(), 4);
        assert_eq!(CommandQueue::<u8>::new(8).capacity(), 8);
        assert_eq!(CommandQueue::<u8>::new(0).capacity(), 2);
    }
}
