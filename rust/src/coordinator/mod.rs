//! The synchronous-SGD coordinator — the paper's system contribution
//! (PCL-DNN §4), in-process:
//!
//! * [`command_queue`] — the lock-free command queue through which the
//!   compute path submits communication work to the **dedicated
//!   communication thread** without blocking (submit-and-forget,
//!   Vaidyanathan et al. 2015).
//! * [`comm_thread`] — that dedicated thread: drains the queue, runs
//!   part-reduce / part-broadcast over the worker gradient buffers, and
//!   posts completions.
//! * [`state`] — the parameter store + SGD optimizer (the update happens
//!   here, between part-reduce and part-broadcast, exactly where §3.4
//!   places it).
//! * [`sharding`] — minibatch partitioning across workers/microbatches.
//! * [`leader`] — the synchronous step loop tying workers, queue, and
//!   state together. Default pipeline is the **streaming overlapped
//!   exchange**: each worker's gradients are folded into a running
//!   rank-ordered sum on the comm thread while the next worker computes,
//!   bit-identical to the retained serial reference pipeline
//!   (`REPRO_RUNTIME_OVERLAP=off`).

pub mod command_queue;
pub mod comm_thread;
pub mod leader;
pub mod sharding;
pub mod state;

pub use command_queue::{CommandQueue, PushError};
pub use comm_thread::{CommHandle, CommOp, CommRequest};
pub use leader::{overlap_env_enabled, StepResult, StepStats, SyncSgdCoordinator, WorkerCompute};
pub use sharding::MicrobatchPlan;
pub use state::{ParamSnapshot, ParamStore, SgdConfig};
