//! The synchronous-SGD leader: drives K logical workers through one
//! minibatch step, exchanges gradients through the dedicated comm thread,
//! and applies SGD per tensor as reductions complete.
//!
//! Semantics (the paper's core claim): the K-worker execution is
//! *equivalent to the serial implementation* — same samples, same
//! averaged gradient, same update — so convergence is identical (Fig 5).
//! Workers here are logical ranks executing on the single PJRT CPU
//! client in turn; gradient exchange and SGD run on the comm thread and
//! overlap the remaining workers' compute via per-tensor pipelining
//! (submit-and-forget through the lock-free queue).

use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::collectives::{shard_range, GroupTopology};
use crate::runtime::{HostTensor, Runtime};

use super::comm_thread::{CommHandle, CommOp, CommRequest};
use super::sharding::MicrobatchPlan;
use super::state::{ParamStore, SgdConfig};

/// Per-step telemetry.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepStats {
    pub loss: f64,
    pub compute_s: f64,
    /// time the leader was blocked waiting on the comm thread
    pub comm_wait_s: f64,
    pub update_s: f64,
    pub executions: u64,
    /// tensors exchanged via a PartitionPlan shard-owner topology
    /// (model/hybrid layer groups) instead of the plain allreduce
    pub plan_sharded: u64,
}

/// Leader + worker pool + comm thread for one model.
pub struct SyncSgdCoordinator {
    pub params: ParamStore,
    pub plan: MicrobatchPlan,
    /// Per-tensor exchange topology from the `PartitionPlan`: `None` =
    /// plain allreduce on the comm thread; `Some` = the plan's
    /// model/hybrid group shape, executed as a shard-owner exchange.
    tensor_topos: Vec<Option<GroupTopology>>,
    comm: CommHandle,
    artifact: String,
}

impl SyncSgdCoordinator {
    /// `artifact` is a train-kind artifact; params must match its ABI.
    pub fn new(
        artifact: &str,
        params: Vec<Vec<f32>>,
        plan: MicrobatchPlan,
        sgd: SgdConfig,
    ) -> Self {
        Self::with_plan(artifact, params, plan, sgd, Vec::new())
    }

    /// [`SyncSgdCoordinator::new`] plus a per-tensor exchange topology
    /// (index-aligned with `params`; missing/`None` entries use the
    /// plain allreduce path).
    pub fn with_plan(
        artifact: &str,
        params: Vec<Vec<f32>>,
        plan: MicrobatchPlan,
        sgd: SgdConfig,
        tensor_topos: Vec<Option<GroupTopology>>,
    ) -> Self {
        let depth = (params.len() * 2).next_power_of_two();
        SyncSgdCoordinator {
            params: ParamStore::new(params, sgd),
            plan,
            tensor_topos,
            comm: CommHandle::spawn(depth),
            artifact: artifact.to_string(),
        }
    }

    pub fn workers(&self) -> usize {
        self.plan.workers
    }

    /// Run one synchronous step. `data_for(worker, micro_index,
    /// global_sample_start)` supplies the non-parameter inputs of one
    /// microbatch (e.g. images+labels).
    pub fn step(
        &mut self,
        rt: &mut Runtime,
        data_for: &mut dyn FnMut(usize, usize, usize) -> Vec<HostTensor>,
    ) -> Result<StepStats> {
        let n_tensors = self.params.n_tensors();
        let workers = self.plan.workers;
        let mut stats = StepStats::default();

        // -------- compute phase: every worker, every microbatch --------
        let t0 = Instant::now();
        // per-worker accumulated gradient sums, [worker][tensor]
        let mut grads: Vec<Vec<Vec<f32>>> = (0..workers)
            .map(|_| self.params.tensors.iter().map(|t| vec![0.0f32; t.len()]).collect())
            .collect();
        let mut loss_sum = 0.0f64;
        // params are constant within the step: convert to literals ONCE
        // and reuse across all workers x microbatches (§Perf: removes the
        // dominant host-side copy for large models).
        let param_lits = rt.params_to_literals(&self.artifact, &self.params.tensors)?;
        // reused gradient read buffer: copy_raw_to into scratch instead of
        // allocating a fresh Vec per gradient per microbatch (§Perf)
        let mut scratch: Vec<Vec<f32>> =
            self.params.tensors.iter().map(|t| vec![0.0f32; t.len()]).collect();
        for w in 0..workers {
            for (m, &start) in self.plan.per_worker[w].clone().iter().enumerate() {
                let data = data_for(w, m, start);
                let outs = rt
                    .execute_raw(&self.artifact, &param_lits, &data)
                    .with_context(|| format!("worker {w} micro {m}"))?;
                ensure!(outs.len() == 1 + n_tensors, "train artifact ABI mismatch");
                loss_sum += outs[0].get_first_element::<f32>()? as f64;
                for t in 0..n_tensors {
                    let s = &mut scratch[t];
                    outs[1 + t].copy_raw_to(s.as_mut_slice())?;
                    let acc = &mut grads[w][t];
                    for (a, &v) in acc.iter_mut().zip(s.iter()) {
                        *a += v;
                    }
                }
                stats.executions += 1;
            }
        }
        stats.compute_s = t0.elapsed().as_secs_f64();

        // -------- exchange + update phase: per-tensor pipelining --------
        // Regroup to per-tensor buffers and submit each tensor's exchange
        // the moment it is assembled; apply SGD as completions arrive.
        let total_micro = self.plan.total_micro() as f32;
        let t1 = Instant::now();
        let mut submitted = 0usize;
        let mut completed = 0usize;
        let mut update_s = 0.0f64;
        // move out per-tensor: iterate tensors, stealing each worker's buf
        for t in 0..n_tensors {
            let mut bufs: Vec<Vec<f32>> =
                grads.iter_mut().map(|per_w| std::mem::take(&mut per_w[t])).collect();
            // §3.3 shard-owner exchange for model/hybrid-assigned tensors,
            // inline over the shared-memory buffers: in-group rank r owns
            // shard r — its replica-set row reduces the shard, then the
            // group (conceptually) part-broadcasts it back. With unsharded
            // artifacts every worker contributes every shard, so the sum
            // is element-for-element the full allreduce — the plan shapes
            // ownership (and, on a real fabric, traffic), not the update.
            if let Some(topo) = self.tensor_topos.get(t).copied().flatten() {
                let tu = Instant::now();
                let len = bufs[0].len();
                let s = topo.group_size();
                let (first, rest) = bufs.split_first_mut().expect(">=1 worker");
                for r in 0..s {
                    let range = shard_range(r, s, len);
                    for w in rest.iter() {
                        for (a, &v) in first[range.clone()].iter_mut().zip(&w[range.clone()]) {
                            *a += v;
                        }
                    }
                }
                self.params.apply_tensor(t, first, total_micro)?;
                update_s += tu.elapsed().as_secs_f64();
                stats.plan_sharded += 1;
                continue;
            }
            let mut req =
                CommRequest { id: t as u64, op: CommOp::AllReduce, bufs };
            // submit-and-forget; drain completions opportunistically if
            // the queue is momentarily full (backpressure)
            loop {
                match self.comm.submit(req) {
                    Ok(()) => break,
                    Err(back) => {
                        req = back;
                        if let Some(done) = self.comm.try_complete() {
                            let tu = Instant::now();
                            self.params.apply_tensor(
                                done.id as usize,
                                &done.bufs[0],
                                total_micro,
                            )?;
                            update_s += tu.elapsed().as_secs_f64();
                            completed += 1;
                        } else {
                            std::thread::yield_now();
                        }
                    }
                }
            }
            submitted += 1;
            // opportunistic completion processing (keeps queue shallow)
            while let Some(done) = self.comm.try_complete() {
                let tu = Instant::now();
                self.params.apply_tensor(done.id as usize, &done.bufs[0], total_micro)?;
                update_s += tu.elapsed().as_secs_f64();
                completed += 1;
            }
        }
        // wait out the tail
        while completed < submitted {
            let done = self.comm.wait_one().context("comm thread died")?;
            let tu = Instant::now();
            self.params.apply_tensor(done.id as usize, &done.bufs[0], total_micro)?;
            update_s += tu.elapsed().as_secs_f64();
            completed += 1;
        }
        self.params.step += 1;
        stats.comm_wait_s = t1.elapsed().as_secs_f64() - update_s;
        stats.update_s = update_s;
        stats.loss = loss_sum / self.plan.total_micro() as f64;
        Ok(stats)
    }

    /// Tear down the comm thread; returns commands it processed.
    pub fn shutdown(self) -> u64 {
        self.comm.shutdown()
    }
}
