//! The synchronous-SGD leader: drives K logical workers through one
//! minibatch step, exchanges gradients through the dedicated comm thread,
//! and applies SGD per tensor as reductions complete.
//!
//! Semantics (the paper's core claim): the K-worker execution is
//! *equivalent to the serial implementation* — same samples, same
//! averaged gradient, same update — so convergence is identical (Fig 5).
//! Workers here are logical ranks executing on the single PJRT CPU
//! client in turn.
//!
//! Two exchange pipelines produce **bit-identical** updates:
//!
//! * **streaming** (default): as worker *w* finishes its microbatches,
//!   its per-tensor gradient sums are handed to the comm thread as
//!   [`CommOp::Reduce`] folds into a running sum, so the reduction of
//!   worker *w* overlaps the compute of worker *w+1* (§3.1/§4 overlap).
//!   Folds are submitted in rank order, so the running sum is the serial
//!   left-to-right scan `((b0+b1)+b2)+…` — the exact element order
//!   `inline::part_reduce` uses. Peak gradient memory is ~3 tensor sets
//!   (sums + in-flight contribution + the set being computed), constant
//!   in the worker count; SGD applies per tensor as final sums land.
//! * **reference** (`REPRO_RUNTIME_OVERLAP=off`): the retained serial
//!   baseline — all workers compute first into an O(workers × params)
//!   buffer, then the exchange runs. The bit-identity property suite
//!   (`tests/overlap_tests.rs`) pins streaming to this oracle.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::collectives::{shard_range, GroupTopology};
use crate::runtime::{HostTensor, Runtime};

use super::comm_thread::{CommCompletion, CommHandle, CommOp, CommRequest, WaitOutcome};
use super::sharding::MicrobatchPlan;
use super::state::{ParamStore, SgdConfig};

/// Backoff budget for normal-path completion waits: folds are ms-scale,
/// so a minute of silence means the comm thread is wedged — surface an
/// error instead of parking forever (ISSUE 9: detection enables
/// recovery).
const WAIT_BUDGET: Duration = Duration::from_secs(60);
/// Backoff budget for the in-flight drain after a worker death.
const ABORT_WAIT_BUDGET: Duration = Duration::from_secs(10);

/// Per-step telemetry.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepStats {
    pub loss: f64,
    pub compute_s: f64,
    /// time the leader was *blocked* on the comm thread (timed directly
    /// around the blocking waits — never negative by construction)
    pub comm_wait_s: f64,
    pub update_s: f64,
    /// comm-thread busy seconds hidden behind leader-side compute this
    /// step: `comm_busy_s − comm_wait_s`, clamped at 0
    pub overlap_s: f64,
    /// comm-thread busy seconds this step (collectives + folds)
    pub comm_busy_s: f64,
    pub executions: u64,
    /// tensors exchanged via a PartitionPlan shard-owner topology
    /// (model/hybrid layer groups) instead of the plain allreduce
    pub plan_sharded: u64,
}

impl StepStats {
    /// Fraction of comm-thread work hidden behind compute (0 when the
    /// comm thread did nothing, e.g. single-worker steps).
    pub fn overlap_frac(&self) -> f64 {
        if self.comm_busy_s > 0.0 {
            (self.overlap_s / self.comm_busy_s).clamp(0.0, 1.0)
        } else {
            0.0
        }
    }
}

/// Per-worker compute hook: fill `acc` (tensor-aligned buffers,
/// **overwritten**, not accumulated into) with worker `w`'s gradient
/// sums over its microbatches (`starts` lists their global sample
/// starts); returns `(loss_sum, executions)`. Factored out of the PJRT
/// path so the exchange pipeline is drivable without artifacts — the
/// bit-identity suite and the perf bench feed synthetic gradients
/// through the real comm thread.
pub type WorkerCompute<'a> = dyn FnMut(usize, &[usize], &mut [Vec<f32>]) -> Result<(f64, u64)> + 'a;

/// Outcome of a guarded step ([`SyncSgdCoordinator::step_with_compute_guarded`]):
/// either the step committed, or a worker died mid-step — the step was
/// aborted deterministically (in-flight folds drained, no parameter
/// touched, step counter unchanged) and the caller decides the recovery
/// policy.
#[derive(Debug)]
pub enum StepResult {
    Done(StepStats),
    Died { worker: usize },
}

/// Payload of the deterministic killer's injected panic.
struct InjectedFault;

/// Invoke one worker's compute under `catch_unwind`, with the ISSUE 9
/// deterministic killer spliced in front: when `kill` names this worker
/// it panics through the exact path a genuine worker fault would take.
/// Returns `None` when the worker died (injected or real panic);
/// `Some(Err)` stays an ordinary propagated error.
fn run_worker(
    compute: &mut WorkerCompute<'_>,
    w: usize,
    starts: &[usize],
    acc: &mut [Vec<f32>],
    kill: Option<usize>,
) -> Option<Result<(f64, u64)>> {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    if kill == Some(w) {
        // silence the default hook for the one panic we cause ourselves;
        // genuine panics below keep their backtrace
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let died = catch_unwind(AssertUnwindSafe(|| -> Result<(f64, u64)> {
            std::panic::panic_any(InjectedFault);
        }));
        std::panic::set_hook(hook);
        debug_assert!(died.is_err());
        return None;
    }
    catch_unwind(AssertUnwindSafe(|| compute(w, starts, acc))).ok()
}

/// `REPRO_RUNTIME_OVERLAP` parsing: unset/anything-else = streaming on,
/// `off`/`0`/`false`/`no` = serial reference pipeline.
pub fn overlap_env_enabled(value: Option<&str>) -> bool {
    match value {
        Some(v) => !matches!(v.to_ascii_lowercase().as_str(), "off" | "0" | "false" | "no"),
        None => true,
    }
}

/// Leader + worker pool + comm thread for one model.
pub struct SyncSgdCoordinator {
    pub params: ParamStore,
    pub plan: MicrobatchPlan,
    /// Per-tensor exchange topology from the `PartitionPlan`: `None` =
    /// plain allreduce on the comm thread; `Some` = the plan's
    /// model/hybrid group shape, executed as a shard-owner exchange.
    tensor_topos: Vec<Option<GroupTopology>>,
    comm: CommHandle,
    artifact: String,
    /// streaming overlapped exchange (default) vs serial reference
    overlap: bool,
    /// bounded-staleness window (`parallelism.sync`): how many computed
    /// gradient sets may wait parked behind the in-flight fold chain
    /// before the leader blocks. 0 = BSP (today's fully synchronous
    /// step); ssp{K} parks up to K sets; async-ps parks up to `workers`.
    /// Folds still run in rank order, so parameters are bit-identical
    /// across windows — the window only moves *when* the leader stalls.
    staleness: usize,
    /// recycled tensor-aligned gradient buffer sets; bounded, so peak
    /// gradient memory is constant in the worker count
    pool: Vec<Vec<Vec<f32>>>,
    /// how many sets [`Self::take_set`] ever allocated (the memory bound
    /// the overlap tests pin: ≤ 3 regardless of workers)
    sets_allocated: usize,
    /// reused literal read buffer for the PJRT compute closure
    read_scratch: Vec<Vec<f32>>,
}

impl SyncSgdCoordinator {
    /// `artifact` is a train-kind artifact; params must match its ABI.
    pub fn new(
        artifact: &str,
        params: Vec<Vec<f32>>,
        plan: MicrobatchPlan,
        sgd: SgdConfig,
    ) -> Self {
        Self::with_plan(artifact, params, plan, sgd, Vec::new())
    }

    /// [`SyncSgdCoordinator::new`] plus a per-tensor exchange topology
    /// (index-aligned with `params`; missing/`None` entries use the
    /// plain allreduce path).
    pub fn with_plan(
        artifact: &str,
        params: Vec<Vec<f32>>,
        plan: MicrobatchPlan,
        sgd: SgdConfig,
        tensor_topos: Vec<Option<GroupTopology>>,
    ) -> Self {
        Self::with_store(artifact, ParamStore::new(params, sgd), plan, tensor_topos)
    }

    /// [`SyncSgdCoordinator::with_plan`] but adopting an existing
    /// [`ParamStore`] — optimizer state (momentum velocity, Adam
    /// moments, step counters) carries over intact. The ISSUE 9 recovery
    /// paths rebuild the coordinator around surviving state with this.
    pub fn with_store(
        artifact: &str,
        store: ParamStore,
        plan: MicrobatchPlan,
        tensor_topos: Vec<Option<GroupTopology>>,
    ) -> Self {
        let depth = (store.n_tensors() * 2).next_power_of_two();
        let read_scratch = store.tensors.iter().map(|t| vec![0.0f32; t.len()]).collect();
        SyncSgdCoordinator {
            params: store,
            plan,
            tensor_topos,
            comm: CommHandle::spawn(depth),
            artifact: artifact.to_string(),
            overlap: overlap_env_enabled(
                std::env::var("REPRO_RUNTIME_OVERLAP").ok().as_deref(),
            ),
            staleness: 0,
            pool: Vec::new(),
            sets_allocated: 0,
            read_scratch,
        }
    }

    pub fn workers(&self) -> usize {
        self.plan.workers
    }

    /// Which exchange pipeline `step` runs (env-derived; see module docs).
    pub fn overlap_enabled(&self) -> bool {
        self.overlap
    }

    /// Pin the pipeline explicitly (tests/benches; overrides the env).
    pub fn set_overlap(&mut self, on: bool) {
        self.overlap = on;
    }

    /// Set the bounded-staleness window (0 = BSP, the default). Only the
    /// streaming pipeline consults it; the serial reference is BSP by
    /// construction.
    pub fn set_staleness(&mut self, window: usize) {
        self.staleness = window;
    }

    /// The active bounded-staleness window.
    pub fn staleness(&self) -> usize {
        self.staleness
    }

    /// Gradient-buffer sets this coordinator ever allocated — the peak-
    /// memory observable: stays ≤ 3 on the streaming path regardless of
    /// the worker count (vs `workers` sets on the reference path).
    pub fn grad_sets_allocated(&self) -> usize {
        self.sets_allocated
    }

    fn take_set(&mut self) -> Vec<Vec<f32>> {
        self.pool.pop().unwrap_or_else(|| {
            self.sets_allocated += 1;
            self.params.tensors.iter().map(|t| vec![0.0f32; t.len()]).collect()
        })
    }

    fn put_set(&mut self, set: Vec<Vec<f32>>) {
        if self.pool.len() < 4 {
            self.pool.push(set);
        }
    }

    /// Run one synchronous step. `data_for(worker, micro_index,
    /// global_sample_start)` supplies the non-parameter inputs of one
    /// microbatch (e.g. images+labels).
    pub fn step(
        &mut self,
        rt: &mut Runtime,
        data_for: &mut dyn FnMut(usize, usize, usize) -> Vec<HostTensor>,
    ) -> Result<StepStats> {
        match self.step_outcome(rt, data_for, None)? {
            StepResult::Done(stats) => Ok(stats),
            StepResult::Died { worker } => {
                bail!("worker {worker} panicked with no fault handler installed")
            }
        }
    }

    /// [`SyncSgdCoordinator::step`] with a fault seam: `kill` names a
    /// worker the deterministic killer takes down this step (`None` =
    /// healthy step). A dead worker aborts the step without touching
    /// parameters and returns [`StepResult::Died`] for the trainer's
    /// recovery policy to handle.
    pub fn step_outcome(
        &mut self,
        rt: &mut Runtime,
        data_for: &mut dyn FnMut(usize, usize, usize) -> Vec<HostTensor>,
        kill: Option<usize>,
    ) -> Result<StepResult> {
        let n_tensors = self.params.n_tensors();
        // params are constant within the step: convert to literals ONCE
        // and reuse across all workers x microbatches (§Perf: removes the
        // dominant host-side copy for large models).
        let param_lits = rt.params_to_literals(&self.artifact, &self.params.tensors)?;
        let artifact = self.artifact.clone();
        let mut read = std::mem::take(&mut self.read_scratch);
        let mut compute = |w: usize, starts: &[usize], acc: &mut [Vec<f32>]| -> Result<(f64, u64)> {
            let mut loss_sum = 0.0f64;
            let mut execs = 0u64;
            for (m, &start) in starts.iter().enumerate() {
                let data = data_for(w, m, start);
                let outs = rt
                    .execute_raw(&artifact, &param_lits, &data)
                    .with_context(|| format!("worker {w} micro {m}"))?;
                ensure!(outs.len() == 1 + n_tensors, "train artifact ABI mismatch");
                loss_sum += outs[0].get_first_element::<f32>()? as f64;
                for t in 0..n_tensors {
                    if m == 0 {
                        // first microbatch overwrites — no zeroing pass
                        outs[1 + t].copy_raw_to(acc[t].as_mut_slice())?;
                    } else {
                        outs[1 + t].copy_raw_to(read[t].as_mut_slice())?;
                        for (a, &v) in acc[t].iter_mut().zip(read[t].iter()) {
                            *a += v;
                        }
                    }
                }
                execs += 1;
            }
            Ok((loss_sum, execs))
        };
        let out = self.step_with_compute_guarded(&mut compute, kill);
        drop(compute);
        self.read_scratch = read;
        out
    }

    /// [`SyncSgdCoordinator::step`] with the per-worker compute supplied
    /// by the caller — the PJRT-free entry the property tests and the
    /// ablation bench drive.
    pub fn step_with_compute(&mut self, compute: &mut WorkerCompute<'_>) -> Result<StepStats> {
        match self.step_with_compute_guarded(compute, None)? {
            StepResult::Done(stats) => Ok(stats),
            StepResult::Died { worker } => {
                bail!("worker {worker} panicked with no fault handler installed")
            }
        }
    }

    /// [`SyncSgdCoordinator::step_with_compute`] with the fault seam
    /// exposed (see [`SyncSgdCoordinator::step_outcome`]). Both exchange
    /// pipelines share the guarantee: on a death the step aborts with
    /// in-flight folds drained, buffers recycled, and parameters + step
    /// counter untouched — the coordinator stays usable.
    pub fn step_with_compute_guarded(
        &mut self,
        compute: &mut WorkerCompute<'_>,
        kill: Option<usize>,
    ) -> Result<StepResult> {
        if self.overlap {
            self.step_streaming(compute, kill)
        } else {
            self.step_reference(compute, kill)
        }
    }

    /// Streaming overlapped exchange (see module docs): compute worker
    /// w+1 while the comm thread folds worker w into the running sums.
    fn step_streaming(
        &mut self,
        compute: &mut WorkerCompute<'_>,
        kill: Option<usize>,
    ) -> Result<StepResult> {
        let n_tensors = self.params.n_tensors();
        let workers = self.plan.workers;
        let total_micro = self.plan.total_micro() as f32;
        let busy0 = self.comm.busy_ns();
        let mut stats = StepStats::default();
        let mut loss_sum = 0.0f64;
        let mut wait_s = 0.0f64;
        let mut update_s = 0.0f64;

        // `sums[t]` is the rank-ordered running fold; it starts as worker
        // 0's buffers and cycles leader -> comm thread -> leader per
        // contributing worker. `reclaim` rebuilds the contributing
        // worker's set from completions for recycling. `parked` is the
        // bounded-staleness backlog: computed sets waiting for the fold
        // chain (ranks kept so folds stay in rank order — the
        // bit-identity invariant holds for every window).
        let mut sums: Vec<Vec<f32>> = Vec::new();
        let mut reclaim: Vec<Vec<f32>> = Vec::with_capacity(n_tensors);
        let mut parked: VecDeque<(usize, Vec<Vec<f32>>)> = VecDeque::new();
        let mut pending = 0usize;

        for w in 0..workers {
            let mut cur = self.take_set();
            let tc = Instant::now();
            let res = run_worker(compute, w, &self.plan.per_worker[w], &mut cur, kill);
            stats.compute_s += tc.elapsed().as_secs_f64();
            let (l, e) = match res {
                Some(r) => r?,
                None => {
                    // worker died: abort without touching params
                    self.put_set(cur);
                    self.abort_inflight(pending, sums, reclaim, parked)?;
                    return Ok(StepResult::Died { worker: w });
                }
            };
            loss_sum += l;
            stats.executions += e;
            if w == 0 {
                sums = cur;
                continue;
            }
            parked.push_back((w, cur));
            if w + 1 == workers {
                // the last set is submitted by the tail drain so its
                // completions are never retired, only applied
                break;
            }
            // Fold the parked backlog. Under BSP (window 0) worker w−1's
            // folds come home before worker w's are submitted — in the
            // steady state they finished during this worker's compute
            // (that is the overlap); blocked time here is true exposed
            // comm wait. Under ssp{K}/async-ps up to K sets may stay
            // parked while the next worker computes: the leader only
            // blocks once the backlog exceeds the staleness window.
            loop {
                while pending > 0 {
                    match self.comm.try_complete() {
                        Some(done) => {
                            retire(done, &mut sums, &mut reclaim);
                            pending -= 1;
                        }
                        None => break,
                    }
                }
                if pending == 0 {
                    if !reclaim.is_empty() {
                        self.put_set(std::mem::take(&mut reclaim));
                    }
                    match parked.pop_front() {
                        Some((rank, set)) => {
                            pending += set.len();
                            self.submit_fold(rank, set, &mut sums, &mut wait_s);
                        }
                        None => break,
                    }
                } else if parked.len() > self.staleness {
                    // backlog over the window: this wait is the exposed
                    // synchronization stall the sync axis trades away
                    let done = self.next_completion(&mut wait_s)?;
                    retire(done, &mut sums, &mut reclaim);
                    pending -= 1;
                } else {
                    // within the window: go compute the next worker
                    break;
                }
            }
        }

        // flush the remaining backlog (always holds at least the last
        // worker's set when workers > 1): each set waits out the previous
        // folds, then submits — still in rank order
        while let Some((rank, set)) = parked.pop_front() {
            while pending > 0 {
                let done = self.next_completion(&mut wait_s)?;
                retire(done, &mut sums, &mut reclaim);
                pending -= 1;
            }
            if !reclaim.is_empty() {
                self.put_set(std::mem::take(&mut reclaim));
            }
            pending += set.len();
            self.submit_fold(rank, set, &mut sums, &mut wait_s);
        }

        if workers == 1 {
            // degenerate: nothing to exchange; sums is worker 0's set
            let tu = Instant::now();
            for t in 0..n_tensors {
                self.params.apply_tensor(t, &sums[t], total_micro)?;
                if self.tensor_topos.get(t).copied().flatten().is_some() {
                    stats.plan_sharded += 1;
                }
            }
            update_s += tu.elapsed().as_secs_f64();
        } else {
            // tail: each completion finalizes one tensor's sum — apply
            // SGD immediately, pipelined against the remaining folds
            while pending > 0 {
                let done = self.next_completion(&mut wait_s)?;
                let t = done.id as usize;
                let mut bufs = done.bufs;
                debug_assert_eq!(bufs.len(), 2);
                let contrib = bufs.pop().expect("fold completion lost contrib");
                let sum = bufs.pop().expect("fold completion lost acc");
                let tu = Instant::now();
                self.params.apply_tensor(t, &sum, total_micro)?;
                update_s += tu.elapsed().as_secs_f64();
                if self.tensor_topos.get(t).copied().flatten().is_some() {
                    // the plan shapes ownership/traffic, not the update
                    // (see step_reference); count it the same way
                    stats.plan_sharded += 1;
                }
                sums[t] = sum;
                reclaim.push(contrib);
                pending -= 1;
            }
            if !reclaim.is_empty() {
                self.put_set(std::mem::take(&mut reclaim));
            }
        }
        self.put_set(sums);

        self.params.step += 1;
        stats.loss = loss_sum / total_micro as f64;
        stats.comm_wait_s = wait_s.max(0.0);
        stats.update_s = update_s;
        stats.comm_busy_s = (self.comm.busy_ns() - busy0) as f64 / 1e9;
        stats.overlap_s = (stats.comm_busy_s - stats.comm_wait_s).max(0.0);
        Ok(StepResult::Done(stats))
    }

    /// The retained serial reference pipeline (pre-streaming shape): all
    /// workers compute into an O(workers × params) buffer, then the
    /// exchange runs. Kept in-tree as the oracle for the bit-identity
    /// property suite and as the `REPRO_RUNTIME_OVERLAP=off` ablation
    /// baseline.
    fn step_reference(
        &mut self,
        compute: &mut WorkerCompute<'_>,
        kill: Option<usize>,
    ) -> Result<StepResult> {
        let n_tensors = self.params.n_tensors();
        let workers = self.plan.workers;
        let busy0 = self.comm.busy_ns();
        let mut stats = StepStats::default();

        // -------- compute phase: every worker, every microbatch --------
        let t0 = Instant::now();
        // per-worker accumulated gradient sums, [worker][tensor]
        let mut grads: Vec<Vec<Vec<f32>>> = (0..workers)
            .map(|_| self.params.tensors.iter().map(|t| vec![0.0f32; t.len()]).collect())
            .collect();
        let mut loss_sum = 0.0f64;
        for (w, acc) in grads.iter_mut().enumerate() {
            // nothing is submitted until every worker computed, so a
            // death here aborts with no in-flight work to drain
            match run_worker(compute, w, &self.plan.per_worker[w], acc, kill) {
                Some(r) => {
                    let (l, e) = r?;
                    loss_sum += l;
                    stats.executions += e;
                }
                None => return Ok(StepResult::Died { worker: w }),
            }
        }
        stats.compute_s = t0.elapsed().as_secs_f64();

        // -------- exchange + update phase: per-tensor pipelining --------
        // Regroup to per-tensor buffers and submit each tensor's exchange
        // the moment it is assembled; apply SGD as completions arrive.
        let total_micro = self.plan.total_micro() as f32;
        let mut submitted = 0usize;
        let mut completed = 0usize;
        let mut wait_s = 0.0f64;
        let mut update_s = 0.0f64;
        // move out per-tensor: iterate tensors, stealing each worker's buf
        for t in 0..n_tensors {
            let bufs: Vec<Vec<f32>> =
                grads.iter_mut().map(|per_w| std::mem::take(&mut per_w[t])).collect();
            // §3.3 shard-owner exchange for model/hybrid-assigned tensors,
            // inline over the shared-memory buffers: in-group rank r owns
            // shard r — its replica-set row reduces the shard, then the
            // group (conceptually) part-broadcasts it back. With unsharded
            // artifacts every worker contributes every shard, so the sum
            // is element-for-element the full allreduce — the plan shapes
            // ownership (and, on a real fabric, traffic), not the update.
            if let Some(topo) = self.tensor_topos.get(t).copied().flatten() {
                let tu = Instant::now();
                let mut bufs = bufs;
                let len = bufs[0].len();
                let s = topo.group_size();
                let (first, rest) = bufs.split_first_mut().expect(">=1 worker");
                for r in 0..s {
                    let range = shard_range(r, s, len);
                    for w in rest.iter() {
                        for (a, &v) in first[range.clone()].iter_mut().zip(&w[range.clone()]) {
                            *a += v;
                        }
                    }
                }
                self.params.apply_tensor(t, first, total_micro)?;
                update_s += tu.elapsed().as_secs_f64();
                stats.plan_sharded += 1;
                continue;
            }
            let mut req = CommRequest { id: t as u64, op: CommOp::AllReduce, bufs };
            // submit-and-forget; drain completions opportunistically if
            // the queue is momentarily full (backpressure)
            loop {
                match self.comm.submit(req) {
                    Ok(()) => break,
                    Err(back) => {
                        req = back;
                        if let Some(done) = self.comm.try_complete() {
                            let tu = Instant::now();
                            self.params.apply_tensor(
                                done.id as usize,
                                &done.bufs[0],
                                total_micro,
                            )?;
                            update_s += tu.elapsed().as_secs_f64();
                            completed += 1;
                        } else {
                            let ty = Instant::now();
                            std::thread::yield_now();
                            wait_s += ty.elapsed().as_secs_f64();
                        }
                    }
                }
            }
            submitted += 1;
            // opportunistic completion processing (keeps queue shallow)
            while let Some(done) = self.comm.try_complete() {
                let tu = Instant::now();
                self.params.apply_tensor(done.id as usize, &done.bufs[0], total_micro)?;
                update_s += tu.elapsed().as_secs_f64();
                completed += 1;
            }
        }
        // wait out the tail (blocked time is the exposed comm wait)
        while completed < submitted {
            let tw = Instant::now();
            let done = self.wait_completion_backoff(WAIT_BUDGET)?;
            wait_s += tw.elapsed().as_secs_f64();
            let tu = Instant::now();
            self.params.apply_tensor(done.id as usize, &done.bufs[0], total_micro)?;
            update_s += tu.elapsed().as_secs_f64();
            completed += 1;
        }
        self.params.step += 1;
        stats.loss = loss_sum / total_micro as f64;
        stats.comm_wait_s = wait_s.max(0.0);
        stats.update_s = update_s;
        stats.comm_busy_s = (self.comm.busy_ns() - busy0) as f64 / 1e9;
        stats.overlap_s = (stats.comm_busy_s - stats.comm_wait_s).max(0.0);
        Ok(StepResult::Done(stats))
    }

    /// Submit one worker's gradient set tensor-by-tensor, in rank order
    /// (the bit-identity invariant), cycling the running sums out to the
    /// comm thread. Callers must have drained `pending` to 0 first — the
    /// sums buffers travel with the requests.
    fn submit_fold(
        &mut self,
        rank: usize,
        set: Vec<Vec<f32>>,
        sums: &mut [Vec<f32>],
        wait_s: &mut f64,
    ) {
        for (t, contrib) in set.into_iter().enumerate() {
            let mut req = CommRequest {
                id: t as u64,
                op: CommOp::Reduce { rank },
                bufs: vec![std::mem::take(&mut sums[t]), contrib],
            };
            loop {
                match self.comm.submit(req) {
                    Ok(()) => break,
                    Err(back) => {
                        // Queue full: spin until the comm thread makes
                        // room (it drains independently; completions
                        // buffer in the unbounded channel). Cannot
                        // happen with the spawn depth of 2×n_tensors —
                        // at most n_tensors folds are ever in flight
                        // even with a parked backlog — but stay correct
                        // for any depth. Consuming completions here
                        // instead would let a last-worker fold bypass
                        // the applying tail drain.
                        req = back;
                        let ty = Instant::now();
                        std::thread::yield_now();
                        *wait_s += ty.elapsed().as_secs_f64();
                    }
                }
            }
        }
    }

    /// Next fold completion: poll first, then block (timing only the
    /// blocked portion — the comm_wait ≥ 0 invariant holds by shape).
    fn next_completion(&self, wait_s: &mut f64) -> Result<CommCompletion> {
        if let Some(done) = self.comm.try_complete() {
            return Ok(done);
        }
        let t0 = Instant::now();
        let done = self.wait_completion_backoff(WAIT_BUDGET)?;
        *wait_s += t0.elapsed().as_secs_f64();
        Ok(done)
    }

    /// Poll-then-wait with exponential backoff bounded by `budget` — the
    /// ISSUE 9 replacement for the unbounded `wait_one` park: a dead or
    /// wedged comm thread surfaces as a context-rich error instead of a
    /// hang, which is what makes detection (and thus recovery) possible.
    fn wait_completion_backoff(&self, budget: Duration) -> Result<CommCompletion> {
        if let Some(done) = self.comm.try_complete() {
            return Ok(done);
        }
        let mut slice = Duration::from_micros(500);
        let mut waited = Duration::ZERO;
        while waited < budget {
            match self.comm.wait_timeout(slice) {
                WaitOutcome::Done(done) => return Ok(done),
                WaitOutcome::Disconnected => bail!("comm thread died"),
                WaitOutcome::TimedOut => {
                    waited += slice;
                    slice = (slice * 2).min(Duration::from_millis(250));
                }
            }
        }
        bail!(
            "comm thread unresponsive: no completion within {:.1}s (bounded backoff exhausted)",
            budget.as_secs_f64()
        )
    }

    /// Deterministically drain in-flight folds after a worker death:
    /// every submitted-but-unretired completion is awaited under bounded
    /// backoff and its buffers recycled; parameters were never touched
    /// (the streaming apply happens only in the tail drain). Extends the
    /// comm thread's stop-overrides-pause shutdown guarantee to mid-step
    /// aborts.
    fn abort_inflight(
        &mut self,
        mut pending: usize,
        mut sums: Vec<Vec<f32>>,
        mut reclaim: Vec<Vec<f32>>,
        parked: VecDeque<(usize, Vec<Vec<f32>>)>,
    ) -> Result<()> {
        while pending > 0 {
            let done = self.wait_completion_backoff(ABORT_WAIT_BUDGET)?;
            retire(done, &mut sums, &mut reclaim);
            pending -= 1;
        }
        if !reclaim.is_empty() {
            self.put_set(reclaim);
        }
        if !sums.is_empty() {
            self.put_set(sums);
        }
        // parked bounded-staleness backlog: never submitted, recycle as-is
        for (_rank, set) in parked {
            self.put_set(set);
        }
        Ok(())
    }

    /// Tear down the comm thread; returns commands it processed.
    pub fn shutdown(self) -> u64 {
        self.comm.shutdown()
    }

    /// Tear down the comm thread and hand back the parameter store (with
    /// its full optimizer state) — the recovery paths carry it into a
    /// rebuilt coordinator at the surviving worker count.
    pub fn into_params(mut self) -> ParamStore {
        std::mem::replace(&mut self.params, ParamStore::new(Vec::new(), SgdConfig::default()))
    }
}

/// Store a mid-step fold completion back: the running sum returns to
/// `sums[t]`, the contribution buffer joins the set being reclaimed.
/// Completions arrive in submission order (single comm thread + FIFO
/// channel), so `reclaim` rebuilds tensor-ordered.
fn retire(done: CommCompletion, sums: &mut [Vec<f32>], reclaim: &mut Vec<Vec<f32>>) {
    let t = done.id as usize;
    let mut bufs = done.bufs;
    debug_assert_eq!(bufs.len(), 2);
    let contrib = bufs.pop().expect("fold completion lost contrib");
    sums[t] = bufs.pop().expect("fold completion lost acc");
    debug_assert_eq!(t, reclaim.len(), "fold completions out of submission order");
    reclaim.push(contrib);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_env_parsing() {
        assert!(overlap_env_enabled(None));
        assert!(overlap_env_enabled(Some("on")));
        assert!(overlap_env_enabled(Some("1")));
        assert!(overlap_env_enabled(Some("anything")));
        for v in ["off", "OFF", "0", "false", "False", "no"] {
            assert!(!overlap_env_enabled(Some(v)), "{v:?} should disable overlap");
        }
    }

    #[test]
    fn streaming_smoke_matches_reference_bitwise() {
        // tiny smoke here; the randomized grid lives in
        // tests/overlap_tests.rs
        let params = vec![vec![0.5f32; 7], vec![-0.25f32; 33]];
        let plan = MicrobatchPlan::new(8, 4, 2).unwrap();
        let mk = |overlap: bool| {
            let mut c =
                SyncSgdCoordinator::new("t", params.clone(), plan.clone(), SgdConfig::default());
            c.set_overlap(overlap);
            c
        };
        let mut compute = |w: usize, starts: &[usize], acc: &mut [Vec<f32>]| {
            for (t, buf) in acc.iter_mut().enumerate() {
                for (i, x) in buf.iter_mut().enumerate() {
                    *x = ((w * 31 + t * 7 + i) % 13) as f32 * 0.1 - 0.5;
                }
            }
            Ok((starts.len() as f64 * 0.25, starts.len() as u64))
        };
        let mut a = mk(true);
        let mut b = mk(false);
        for _ in 0..3 {
            let sa = a.step_with_compute(&mut compute).unwrap();
            let sb = b.step_with_compute(&mut compute).unwrap();
            assert_eq!(sa.loss.to_bits(), sb.loss.to_bits());
            assert!(sa.comm_wait_s >= 0.0 && sb.comm_wait_s >= 0.0);
        }
        for (ta, tb) in a.params.tensors.iter().zip(&b.params.tensors) {
            let eq = ta.iter().zip(tb).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(eq, "streaming diverged from reference");
        }
        let sets = a.grad_sets_allocated();
        assert!(sets <= 3, "streaming allocated {sets} sets");
    }

    #[test]
    fn injected_death_aborts_step_and_keeps_coordinator_usable() {
        // the fault seam: killing worker 2 mid-step must (a) return Died,
        // (b) leave params + step counter untouched, (c) drain in-flight
        // folds so the next healthy step is bit-identical to a run that
        // never saw the fault — under BOTH exchange pipelines.
        let params = vec![vec![0.5f32; 19], vec![-0.25f32; 64]];
        let plan = MicrobatchPlan::new(8, 4, 2).unwrap();
        let mut compute = |w: usize, starts: &[usize], acc: &mut [Vec<f32>]| {
            for (t, buf) in acc.iter_mut().enumerate() {
                for (i, x) in buf.iter_mut().enumerate() {
                    *x = ((w * 17 + t * 5 + i) % 11) as f32 * 0.1 - 0.4;
                }
            }
            Ok((starts.len() as f64 * 0.5, starts.len() as u64))
        };
        for overlap in [true, false] {
            let mk = || {
                let mut c = SyncSgdCoordinator::new(
                    "t",
                    params.clone(),
                    plan.clone(),
                    SgdConfig::default(),
                );
                c.set_overlap(overlap);
                c
            };
            let mut faulty = mk();
            let before = faulty.params.tensors.clone();
            match faulty.step_with_compute_guarded(&mut compute, Some(2)).unwrap() {
                StepResult::Died { worker } => assert_eq!(worker, 2),
                StepResult::Done(_) => panic!("killer never fired (overlap={overlap})"),
            }
            assert_eq!(faulty.params.step, 0, "aborted step must not commit");
            for (a, b) in faulty.params.tensors.iter().zip(&before) {
                assert!(
                    a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "aborted step touched parameters (overlap={overlap})"
                );
            }
            // the coordinator stays usable and bit-identical to a clean one
            let mut clean = mk();
            let sf = faulty.step_with_compute(&mut compute).unwrap();
            let sc = clean.step_with_compute(&mut compute).unwrap();
            assert_eq!(sf.loss.to_bits(), sc.loss.to_bits());
            for (a, b) in faulty.params.tensors.iter().zip(&clean.params.tensors) {
                assert!(
                    a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "post-abort step diverged (overlap={overlap})"
                );
            }
        }
    }

    #[test]
    fn into_params_carries_optimizer_state() {
        let cfg = SgdConfig { lr: 0.1, momentum: 0.9, ..SgdConfig::default() };
        let plan = MicrobatchPlan::new(4, 2, 2).unwrap();
        let mut c = SyncSgdCoordinator::new("t", vec![vec![1.0f32; 8]], plan.clone(), cfg);
        let mut compute = |_w: usize, starts: &[usize], acc: &mut [Vec<f32>]| {
            for buf in acc.iter_mut() {
                buf.fill(0.5);
            }
            Ok((0.0, starts.len() as u64))
        };
        c.step_with_compute(&mut compute).unwrap();
        let snap = c.params.snapshot();
        assert!(snap.velocity.is_some(), "momentum state expected");
        let store = c.into_params();
        let c2 = SyncSgdCoordinator::with_store("t", store, plan, Vec::new());
        assert_eq!(c2.params.step, 1);
        assert_eq!(c2.params.snapshot(), snap, "optimizer state lost in the handoff");
    }
}
