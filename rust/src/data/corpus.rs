//! Synthetic language corpus for the e2e transformer driver: a fixed
//! first-order Markov ("bigram") language with Zipf-like transition mass.
//! An LM that learns the transition matrix drives next-token
//! cross-entropy from ln(vocab) down toward the chain's conditional
//! entropy — giving the loss curve the e2e experiment records.

use crate::util::rng::Rng;

/// A batch of token windows, shape (n, seq) flattened row-major.
#[derive(Debug, Clone)]
pub struct TokenBatch {
    pub tokens: Vec<i32>,
    pub n: usize,
    pub seq: usize,
}

/// Deterministic Markov corpus over `vocab` tokens.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub vocab: usize,
    seed: u64,
    /// transition CDF rows: next-token sampling tables, vocab x fanout
    tables: Vec<Vec<u32>>,
}

/// Each token has `FANOUT` likely successors with Zipf-ish weights.
const FANOUT: usize = 8;

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 2);
        let base = Rng::new(seed);
        // Sampling table per token: 64 slots drawn from its successor set
        // with Zipf(1) weights -> sampling = uniform pick from the table.
        let tables = (0..vocab)
            .map(|t| {
                let mut r = base.fork(0xc0ff_ee00 + t as u64);
                let succ: Vec<u32> =
                    (0..FANOUT).map(|_| r.below(vocab as u64) as u32).collect();
                let mut table = Vec::with_capacity(64);
                // weight of successor rank k ~ 1/(k+1)
                let total: f64 = (0..FANOUT).map(|k| 1.0 / (k + 1) as f64).sum();
                for (k, &s) in succ.iter().enumerate() {
                    let share = (64.0 * (1.0 / (k + 1) as f64) / total).round() as usize;
                    for _ in 0..share.max(1) {
                        table.push(s);
                    }
                }
                table.truncate(64);
                while table.len() < 64 {
                    table.push(succ[0]);
                }
                table
            })
            .collect();
        Corpus { vocab, seed, tables }
    }

    /// Token `j` of the infinite stream for window `w` (streams are
    /// per-window chains so any (start,seq) window is O(seq) to make).
    fn window(&self, w: u64, seq: usize) -> Vec<i32> {
        let mut r = Rng::new(self.seed).fork(0xbeef_0000 ^ w);
        let mut tok = r.below(self.vocab as u64) as u32;
        let mut out = Vec::with_capacity(seq);
        out.push(tok as i32);
        for _ in 1..seq {
            let table = &self.tables[tok as usize];
            tok = table[r.below(table.len() as u64) as usize];
            out.push(tok as i32);
        }
        out
    }

    /// Materialize a batch of `n` windows starting at window id `start`.
    pub fn batch(&self, start: u64, n: usize, seq: usize) -> TokenBatch {
        let mut tokens = Vec::with_capacity(n * seq);
        for i in 0..n {
            tokens.extend(self.window(start + i as u64, seq));
        }
        TokenBatch { tokens, n, seq }
    }

    /// Conditional entropy of the chain in nats — the loss floor the LM
    /// trains toward (uniform over the sampling table's distribution).
    pub fn entropy_floor(&self) -> f64 {
        let mut h = 0.0;
        for table in &self.tables {
            // empirical distribution of the 64-slot table
            let mut counts = std::collections::HashMap::new();
            for &s in table {
                *counts.entry(s).or_insert(0usize) += 1;
            }
            let mut ht = 0.0;
            for (_, c) in counts {
                let p = c as f64 / table.len() as f64;
                ht -= p * p.ln();
            }
            h += ht;
        }
        h / self.tables.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_deterministic() {
        let c = Corpus::new(128, 5);
        assert_eq!(c.batch(10, 4, 32).tokens, c.batch(10, 4, 32).tokens);
    }

    #[test]
    fn tokens_in_vocab() {
        let c = Corpus::new(64, 1);
        let b = c.batch(0, 8, 50);
        assert!(b.tokens.iter().all(|&t| (0..64).contains(&t)));
        assert_eq!(b.tokens.len(), 8 * 50);
    }

    #[test]
    fn entropy_floor_well_below_uniform() {
        // The chain must be learnable: floor << ln(vocab).
        let c = Corpus::new(128, 7);
        let floor = c.entropy_floor();
        let uniform = (128f64).ln();
        assert!(floor < 0.6 * uniform, "floor {floor} uniform {uniform}");
        assert!(floor > 0.5, "{floor}"); // but not degenerate
    }

    #[test]
    fn bigram_structure_present() {
        // successor sets are small: count distinct successors of token 0
        let c = Corpus::new(128, 3);
        let mut succ = std::collections::HashSet::new();
        for w in 0..200u64 {
            let win = c.window(w, 20);
            for pair in win.windows(2) {
                if pair[0] == 0 {
                    succ.insert(pair[1]);
                }
            }
        }
        assert!(succ.len() <= FANOUT, "{}", succ.len());
    }
}
