//! Class-conditional synthetic image / ASR-frame datasets.
//!
//! Sample `i` is fully determined by `(seed, i)`: label = a deterministic
//! draw, data = the label's fixed template + per-sample noise. Learnable
//! (templates are separable), infinite, and identical for every worker and
//! run — which is what Fig 5's convergence-equivalence experiment needs.

use crate::util::rng::Rng;

/// A batch of images (NHWC flat) + labels.
#[derive(Debug, Clone)]
pub struct ImageBatch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
}

/// Deterministic synthetic image dataset.
#[derive(Debug, Clone)]
pub struct ImageDataset {
    pub image: usize,
    pub channels: usize,
    pub classes: usize,
    seed: u64,
    templates: Vec<Vec<f32>>,
    /// Noise amplitude relative to the unit-scale template.
    pub noise: f32,
}

impl ImageDataset {
    pub fn new(image: usize, channels: usize, classes: usize, seed: u64) -> Self {
        let elems = image * image * channels;
        let base = Rng::new(seed);
        let templates = (0..classes)
            .map(|c| {
                let mut rng = base.fork(0x7e3a_0000 + c as u64);
                let mut t = vec![0.0f32; elems];
                rng.fill_normal(&mut t, 1.0);
                t
            })
            .collect();
        ImageDataset { image, channels, classes, seed, templates, noise: 0.5 }
    }

    pub fn sample_elems(&self) -> usize {
        self.image * self.image * self.channels
    }

    /// Label of global sample `idx`.
    pub fn label(&self, idx: u64) -> i32 {
        let mut r = Rng::new(self.seed).fork(0x1abe_1000 ^ idx);
        r.below(self.classes as u64) as i32
    }

    /// Write sample `idx` into `out` (length = sample_elems).
    pub fn write_sample(&self, idx: u64, out: &mut [f32]) {
        let label = self.label(idx) as usize;
        let mut r = Rng::new(self.seed).fork(0x5a3f_2000 ^ idx);
        let t = &self.templates[label];
        for (o, &tv) in out.iter_mut().zip(t.iter()) {
            *o = tv + self.noise * r.normal();
        }
    }

    /// Materialize the batch of samples [start, start+n).
    pub fn batch(&self, start: u64, n: usize) -> ImageBatch {
        let elems = self.sample_elems();
        let mut images = vec![0.0f32; n * elems];
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let idx = start + i as u64;
            self.write_sample(idx, &mut images[i * elems..(i + 1) * elems]);
            labels.push(self.label(idx));
        }
        ImageBatch { images, labels, n }
    }
}

/// Synthetic ASR frame dataset (CD-DNN: 429-dim frames -> senone ids).
/// Same construction as images, 1-D feature vectors.
#[derive(Debug, Clone)]
pub struct FrameDataset {
    pub dim: usize,
    pub senones: usize,
    seed: u64,
    templates: Vec<Vec<f32>>,
    pub noise: f32,
}

impl FrameDataset {
    pub fn new(dim: usize, senones: usize, seed: u64) -> Self {
        let base = Rng::new(seed);
        let templates = (0..senones)
            .map(|c| {
                let mut rng = base.fork(0x0f4a_3000 + c as u64);
                let mut t = vec![0.0f32; dim];
                rng.fill_normal(&mut t, 1.0);
                t
            })
            .collect();
        FrameDataset { dim, senones, seed, templates, noise: 0.5 }
    }

    pub fn label(&self, idx: u64) -> i32 {
        let mut r = Rng::new(self.seed).fork(0x1abe_1000 ^ idx);
        r.below(self.senones as u64) as i32
    }

    pub fn batch(&self, start: u64, n: usize) -> ImageBatch {
        let mut frames = vec![0.0f32; n * self.dim];
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let idx = start + i as u64;
            let label = self.label(idx) as usize;
            let mut r = Rng::new(self.seed).fork(0x5a3f_2000 ^ idx);
            let t = &self.templates[label];
            for (o, &tv) in frames[i * self.dim..(i + 1) * self.dim].iter_mut().zip(t.iter()) {
                *o = tv + self.noise * r.normal();
            }
            labels.push(label as i32);
        }
        ImageBatch { images: frames, labels, n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_deterministic() {
        let d = ImageDataset::new(8, 3, 10, 42);
        let a = d.batch(100, 4);
        let b = d.batch(100, 4);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_indices_differ() {
        let d = ImageDataset::new(8, 3, 10, 42);
        let a = d.batch(0, 1);
        let b = d.batch(1, 1);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn labels_cover_classes() {
        let d = ImageDataset::new(4, 1, 10, 7);
        let mut seen = [false; 10];
        for i in 0..500 {
            seen[d.label(i) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn same_class_samples_correlate() {
        // Two samples of one class must be closer to each other than to a
        // different class's template (the dataset is learnable).
        let d = ImageDataset::new(8, 1, 4, 3);
        let mut by_class: Vec<Vec<u64>> = vec![Vec::new(); 4];
        for i in 0..200 {
            by_class[d.label(i) as usize].push(i);
        }
        let elems = d.sample_elems();
        let get = |idx: u64| {
            let mut v = vec![0.0; elems];
            d.write_sample(idx, &mut v);
            v
        };
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum()
        };
        let c0 = &by_class[0];
        let c1 = &by_class[1];
        assert!(c0.len() >= 2 && !c1.is_empty());
        let same = dist(&get(c0[0]), &get(c0[1]));
        let cross = dist(&get(c0[0]), &get(c1[0]));
        assert!(same < cross, "same {same} cross {cross}");
    }

    #[test]
    fn frames_have_right_dims() {
        let d = FrameDataset::new(429, 128, 1);
        let b = d.batch(0, 8);
        assert_eq!(b.images.len(), 8 * 429);
        assert!(b.labels.iter().all(|&l| (0..128).contains(&l)));
    }
}
