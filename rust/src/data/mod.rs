//! Data-handling module (paper §4): synthetic datasets with deterministic
//! generation, plus a prefetching loader that runs on a **dedicated
//! thread** so data preparation never competes with compute — the paper's
//! two design requirements for this module.
//!
//! Real corpora substitution (DESIGN.md): throughput and scaling depend on
//! tensor shapes, not pixel/token content, and convergence equivalence
//! only needs a learnable task — so images are class-conditional templates
//! plus noise, ASR frames are senone-conditional, and LM text comes from a
//! fixed synthetic bigram ("Markov") language.

mod corpus;
mod loader;
mod synthetic;

pub use corpus::{Corpus, TokenBatch};
pub use loader::Prefetcher;
pub use synthetic::{FrameDataset, ImageBatch, ImageDataset};
