//! Prefetching data loader on a dedicated thread (paper §4).
//!
//! "The data handling module executes on a dedicated hardware thread" and
//! "must ensure continuous availability of pre-processed data": a producer
//! thread fills a bounded channel ahead of the trainer; the trainer's
//! `next()` is a queue pop, never a generation stall (unless the producer
//! genuinely can't keep up, which the stats expose).

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;
use std::time::Instant;

/// Handle to a prefetch pipeline producing items of type `T`.
pub struct Prefetcher<T: Send + 'static> {
    rx: Option<Receiver<T>>,
    handle: Option<JoinHandle<()>>,
    /// consumer-side stall time (waiting on the producer), ns
    pub stall_ns: std::cell::Cell<u64>,
    pub fetched: std::cell::Cell<u64>,
}

impl<T: Send + 'static> Prefetcher<T> {
    /// Spawn the producer thread. `gen(i)` produces item `i`; `depth` is
    /// the prefetch queue capacity; `total` items are produced (use
    /// `u64::MAX` for endless streams).
    pub fn spawn(depth: usize, total: u64, mut gen: impl FnMut(u64) -> T + Send + 'static) -> Self {
        let (tx, rx) = sync_channel::<T>(depth.max(1));
        let handle = std::thread::Builder::new()
            .name("pcl-dnn-data".into())
            .spawn(move || {
                for i in 0..total {
                    let item = gen(i);
                    if tx.send(item).is_err() {
                        return; // consumer dropped
                    }
                }
            })
            .expect("spawning data thread");
        Prefetcher {
            rx: Some(rx),
            handle: Some(handle),
            stall_ns: std::cell::Cell::new(0),
            fetched: std::cell::Cell::new(0),
        }
    }

    /// Next item (None when the stream is exhausted).
    pub fn next(&self) -> Option<T> {
        let t0 = Instant::now();
        let item = self.rx.as_ref().and_then(|rx| rx.recv().ok());
        self.stall_ns.set(self.stall_ns.get() + t0.elapsed().as_nanos() as u64);
        if item.is_some() {
            self.fetched.set(self.fetched.get() + 1);
        }
        item
    }

    /// Mean consumer stall per fetched item, in microseconds — should be
    /// ~0 when the data thread keeps up (the paper's requirement).
    pub fn mean_stall_us(&self) -> f64 {
        let n = self.fetched.get();
        if n == 0 {
            0.0
        } else {
            self.stall_ns.get() as f64 / n as f64 / 1e3
        }
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        // Drop the receiver first: the producer's next send fails and the
        // thread exits, so join cannot hang.
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_in_order() {
        let p = Prefetcher::spawn(4, 10, |i| i * 2);
        let got: Vec<u64> = std::iter::from_fn(|| p.next()).collect();
        assert_eq!(got, (0..10).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        // producer can only run `depth+1` ahead of the consumer
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;
        let produced = Arc::new(AtomicU64::new(0));
        let p2 = produced.clone();
        let p = Prefetcher::spawn(2, 100, move |i| {
            p2.store(i + 1, Ordering::SeqCst);
            i
        });
        assert_eq!(p.next(), Some(0));
        std::thread::sleep(std::time::Duration::from_millis(30));
        let ahead = produced.load(Ordering::SeqCst);
        assert!(ahead <= 5, "producer ran {ahead} ahead");
    }

    #[test]
    fn endless_stream_and_drop() {
        let p = Prefetcher::spawn(2, u64::MAX, |i| i);
        assert_eq!(p.next(), Some(0));
        assert_eq!(p.next(), Some(1));
        drop(p); // must not hang
    }

    #[test]
    fn stall_accounting_runs() {
        let p = Prefetcher::spawn(2, 5, |i| i);
        while p.next().is_some() {}
        assert_eq!(p.fetched.get(), 5);
    }
}
