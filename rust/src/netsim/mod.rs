//! Discrete-event cluster simulator — the substrate standing in for the
//! paper's physical testbeds (Cori, AWS EC2, Endeavor; see DESIGN.md
//! "Hardware substitutions").
//!
//! * [`engine`] — a deterministic task-graph discrete-event engine with
//!   unary resources (a node's compute stream and its dedicated
//!   communication thread — the paper's §4 software architecture).
//! * [`collective`] — α-β cost models for the paper's two primitives,
//!   part-reduce (`MPI_Reduce_scatter`) and part-broadcast
//!   (`MPI_Allgather`), §3.4.
//! * [`cluster`] — builds the per-iteration task DAG for synchronous SGD
//!   (wt-grad before bprop, gradient exchange overlapped into remaining
//!   backward + next forward) and extracts steady-state iteration time.

pub mod cluster;
pub mod collective;
pub mod engine;

pub use cluster::{simulate_training, ScalingPoint, SimConfig, SimResult};
pub use engine::{Engine, Schedule, Task, TaskId};
