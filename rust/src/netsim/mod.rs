//! Discrete-event cluster simulator — the substrate standing in for the
//! paper's physical testbeds (Cori, AWS EC2, Endeavor; see DESIGN.md
//! "Hardware substitutions").
//!
//! * [`engine`] — a deterministic task-graph discrete-event engine with
//!   unary resources; tasks may occupy several resources at once, so a
//!   message holds its sender's NIC, its receiver's NIC and any shared
//!   fabric channel for its flight time.
//! * [`network`] — the topology layer: flat Ethernet switch,
//!   oversubscribed fat-tree, or fully-switched fabric, instantiated as
//!   first-class contended link resources.
//! * [`collective`] — the paper's two primitives, part-reduce
//!   (`MPI_Reduce_scatter`) and part-broadcast (`MPI_Allgather`), §3.4:
//!   α-β cost models plus ring / recursive-halving-doubling schedule
//!   builders that expand them into per-message task DAGs.
//! * [`fleet`] — N nodes × (compute, comm) streams with per-node speed
//!   skew (stragglers), heterogeneous generations, and failure/rejoin.
//! * [`cluster`] — the per-iteration synchronous-SGD DAG (wt-grad before
//!   bprop, gradient exchange overlapped into remaining backward + next
//!   forward) in two fidelities: the representative-node α-β model
//!   ([`cluster::simulate_training`], the analytic cross-check) and the
//!   full-cluster per-node model ([`cluster::simulate_training_fleet`]).
//!   Clean-fabric runs route through a steady-state periodic fast path
//!   (iteration templates + closed-form extrapolation, bit-identical to
//!   the full simulation; `SimPath` records which path ran).
//! * [`reference`] — the retained pre-optimization full-scan scheduler,
//!   the bit-identicality oracle for the engine's indexed fast path.

pub mod cluster;
pub mod collective;
pub mod engine;
pub mod fleet;
pub mod network;
pub mod reference;

pub use cluster::{
    simulate_training, simulate_training_fleet, simulate_training_fleet_full, FleetSimResult,
    RecoveryOutcome, ScalingPoint, SimConfig, SimPath, SimResult, SyncMode,
};
pub use collective::Choice;
pub use engine::{DepLists, Engine, Schedule, TaskId};
pub use fleet::{Fleet, FleetConfig, RecoveryPolicy};
pub use network::{Network, Topology};
