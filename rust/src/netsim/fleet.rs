//! Fleet instantiation: N nodes × (compute, comm) streams over a
//! [`Network`], with optional per-node speed skew (stragglers),
//! heterogeneous node generations, and failure/rejoin events.
//!
//! Engine resource layout: node `v` owns compute stream `2v` and comm
//! stream `2v+1` (the §4 dedicated communication thread); all network
//! link resources start at `2N` and are managed by [`Network`].

use crate::analytic::FabricSpec;

use super::network::{Network, Topology};

/// What the fleet does after `fail_node` dies (synchronous SGD makes a
/// failed node the worst-case straggler: every survivor waits at the
/// next gradient exchange, §4). The policy decides whether the fleet
/// waits for the node or reconfigures around it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryPolicy {
    /// Wait the full `recovery_s` (detection + restart + replay) for the
    /// node to rejoin, then resume at N with the original plan — the
    /// pre-recovery-aware behavior.
    #[default]
    Stall,
    /// Drop to N-1 survivors and re-derive the partition plan for the
    /// degraded node count (hybrid group shapes must divide N, so N-1
    /// generally invalidates the old plan); pays detection + replan
    /// coordination + weight redistribution before resuming.
    Replan,
    /// Drop to N-1 keeping the original plan, with hybrid group shapes
    /// re-normalized per the §3.3 degenerate-shape rule and the global
    /// minibatch respread over the survivors; pays detection + weight
    /// redistribution only.
    Shrink,
}

/// Shape of a simulated fleet.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub nodes: usize,
    pub topology: Topology,
    /// Linear straggler ramp: node `i`'s compute (and local SGD) runs
    /// `1 + skew * i/(N-1)` times slower than node 0. 0 = homogeneous.
    pub straggler_skew: f64,
    /// Heterogeneous fleet: every odd node is a 30% slower older
    /// generation (composes with the straggler ramp).
    pub hetero: bool,
    /// Fail `fail_node` at the start of this iteration; what happens
    /// next is `recovery`'s call.
    pub fail_at: Option<usize>,
    pub fail_node: usize,
    /// Stall's full detection + restart + replay window; the
    /// reconfiguring policies pay only the detection share of it
    /// (`cluster::DETECT_FRAC`).
    pub recovery_s: f64,
    pub recovery: RecoveryPolicy,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            nodes: 1,
            topology: Topology::FullySwitched,
            straggler_skew: 0.0,
            hetero: false,
            fail_at: None,
            fail_node: 0,
            recovery_s: 5.0,
            recovery: RecoveryPolicy::Stall,
        }
    }
}

impl FleetConfig {
    /// Homogeneous fleet of `nodes` on a fully-switched fabric — the
    /// configuration that must reproduce the α-β predictions.
    pub fn homogeneous(nodes: usize) -> Self {
        FleetConfig { nodes, ..Default::default() }
    }
}

/// An instantiated fleet: resource ids + per-node slowdown factors.
#[derive(Debug, Clone)]
pub struct Fleet {
    pub cfg: FleetConfig,
    pub net: Network,
    /// Per-node compute-time multiplier (>= 1.0 means slower).
    pub time_mult: Vec<f64>,
}

impl Fleet {
    pub fn new(cfg: &FleetConfig, fabric: &FabricSpec) -> Fleet {
        assert!(cfg.nodes >= 1, "fleet needs at least one node");
        assert!(cfg.straggler_skew >= 0.0, "straggler skew must be >= 0");
        let n = cfg.nodes;
        let net = Network::new(cfg.topology, n, fabric, 2 * n);
        let mut time_mult = vec![1.0; n];
        if n > 1 && cfg.straggler_skew > 0.0 {
            for (i, m) in time_mult.iter_mut().enumerate() {
                *m *= 1.0 + cfg.straggler_skew * i as f64 / (n - 1) as f64;
            }
        }
        if cfg.hetero {
            for m in time_mult.iter_mut().skip(1).step_by(2) {
                *m *= 1.3;
            }
        }
        Fleet { cfg: cfg.clone(), net, time_mult }
    }

    /// Serial compute pipeline of node `v`.
    pub fn compute_res(&self, v: usize) -> usize {
        debug_assert!(v < self.cfg.nodes);
        2 * v
    }

    /// Dedicated communication thread of node `v`.
    pub fn comm_res(&self, v: usize) -> usize {
        debug_assert!(v < self.cfg.nodes);
        2 * v + 1
    }

    /// Slowest node's time multiplier (the synchronous bottleneck).
    pub fn max_time_mult(&self) -> f64 {
        self.time_mult.iter().cloned().fold(1.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_fleet_has_unit_multipliers() {
        let f = Fleet::new(&FleetConfig::homogeneous(8), &FabricSpec::fdr_infiniband());
        assert!(f.time_mult.iter().all(|&m| m == 1.0));
        assert_eq!(f.max_time_mult(), 1.0);
    }

    #[test]
    fn straggler_ramp_is_linear_and_bounded() {
        let cfg = FleetConfig {
            nodes: 5,
            straggler_skew: 0.4,
            ..Default::default()
        };
        let f = Fleet::new(&cfg, &FabricSpec::fdr_infiniband());
        assert_eq!(f.time_mult[0], 1.0);
        assert!((f.time_mult[4] - 1.4).abs() < 1e-12);
        for w in f.time_mult.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn hetero_slows_odd_nodes() {
        let cfg = FleetConfig { nodes: 4, hetero: true, ..Default::default() };
        let f = Fleet::new(&cfg, &FabricSpec::fdr_infiniband());
        assert_eq!(f.time_mult, vec![1.0, 1.3, 1.0, 1.3]);
    }

    #[test]
    fn resource_ids_do_not_collide_with_network() {
        let cfg = FleetConfig::homogeneous(6);
        let f = Fleet::new(&cfg, &FabricSpec::ethernet_10g());
        for v in 0..6 {
            assert!(f.compute_res(v) < 12);
            assert!(f.comm_res(v) < 12);
            assert!(f.net.tx(v) >= 12);
            assert!(f.net.rx(v) >= 12);
        }
    }
}
