//! Per-iteration task DAGs for distributed synchronous SGD, simulated on
//! the discrete-event engine — the machinery behind Figs 4, 6 and 7.
//!
//! Two fidelities share the same per-layer compute/strategy model:
//!
//! * [`simulate_training`] — the **representative-node** model: all nodes
//!   are symmetric, so one node's two streams (compute pipeline +
//!   dedicated communication thread, §4) are simulated with collective
//!   durations taken from the α-β models over the full node count. Fast,
//!   and the analytic cross-check for the full simulator.
//! * [`simulate_training_fleet`] — the **full-cluster** model: every node
//!   of a [`Fleet`] gets its own compute and comm streams, collectives
//!   are expanded into per-message tasks over contended network links,
//!   and per-node speed skew / heterogeneous generations / failure events
//!   shape the schedule. This is the model that can express stragglers,
//!   link contention on oversubscribed fabrics, and rejoin stalls — the
//!   effects the paper's Ethernet/AWS results (§6) are dominated by.
//!   [`build_training_fleet`] exposes the built DAG so perf harnesses can
//!   time construction and execution separately (and replay the same DAG
//!   on the retained reference scheduler).
//!
//! Both encode the paper's §3.1 overlap structure:
//!
//! * forward L0..Lk, then backward Lk..L0 with **wt-grad before bprop**;
//! * the gradient exchange of layer i is submitted to the comm stream the
//!   moment wt-grad_i retires (submit-and-forget through the command
//!   queue) and overlaps all remaining backward work and the next
//!   iteration's forward work up to layer i;
//! * fwd_i of iteration t+1 depends on update_i (comm + SGD) of t;
//! * model/hybrid-parallel FC layers additionally exchange activations
//!   *inside* the fwd/bwd chains (not overlappable — §3.2's weakness).
//!
//! Steady-state iteration time is measured between consecutive iteration
//! boundaries after a warm-up iteration.
//!
//! DAG-construction hot path: per-member dependency lists and gate lists
//! live in two reusable [`DepLists`] arenas (no `Vec<Vec<TaskId>>` per
//! collective), command-queue tails are fixed-size [`Tail`] pairs, and
//! task labels are interned by the engine — so building a 128-node fig4
//! iteration allocates O(layers), not O(messages).
//!
//! Multi-iteration hot path: every clean iteration emits an identical
//! task block, so the builders walk the model zoo and the collective
//! expanders for the first two iterations only and instance the rest
//! from the trailing block ([`Engine::instance_tail_block`]); on top of
//! that, [`simulate_training_fleet`] detects a periodic steady-state
//! schedule from a [`PROBE_ITERATIONS`]-iteration probe and extrapolates
//! the remaining iterations in closed form — bit-identical to the full
//! simulation, with automatic fallback for the configurations that
//! genuinely need the full split DAG (stragglers, hetero generations,
//! failure/recovery timelines). See DESIGN.md "Steady-state fast path".

use anyhow::{bail, Result};

use crate::analytic::comm_model::{self, Strategy};
use crate::analytic::compute_model;
use crate::analytic::machine::Platform;
use crate::analytic::FabricSpec;
use crate::collectives::GroupTopology;
use crate::models::{Layer, NetDescriptor};
use crate::plan::{planner, PartitionPlan};

use super::collective::{self, CollectiveKind};
use super::engine::{self, DepLists, Engine, Schedule, TaskId};
use super::fleet::{Fleet, FleetConfig, RecoveryPolicy};
use super::network::ns;

const COMPUTE: usize = 0;
const COMM: usize = 1;

/// Synchronization discipline of the gradient exchange
/// (`ExperimentSpec.parallelism.sync`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SyncMode {
    /// Bulk-synchronous (the paper's contract): every node's iteration
    /// t+1 forward gates on every node's iteration-t update through the
    /// all-member gradient collective — the barrier *is* the collective.
    #[default]
    Bsp,
    /// Stale-synchronous parameter server: gradients move as per-node
    /// push/pull traffic and a node may run up to `staleness` iterations
    /// ahead of the slowest node.
    Ssp { staleness: usize },
    /// Fully asynchronous parameter server: push/pull traffic with no
    /// cross-node gating at all (unbounded drift).
    AsyncPs,
}

impl SyncMode {
    /// `Ssp { staleness: 0 }` *is* the barrier — waiting zero iterations
    /// behind the slowest node is exactly what bsp's collective enforces
    /// — so it normalizes to `Bsp` and stays bit-identical on every
    /// substrate instead of merely approximately equal.
    pub fn normalized(self) -> SyncMode {
        match self {
            SyncMode::Ssp { staleness: 0 } => SyncMode::Bsp,
            m => m,
        }
    }

    pub fn is_bsp(self) -> bool {
        self.normalized() == SyncMode::Bsp
    }

    /// Drift bound in iterations: `Some(0)` for bsp, `Some(K)` for ssp,
    /// `None` (unbounded) for async-ps.
    pub fn staleness(self) -> Option<usize> {
        match self.normalized() {
            SyncMode::Bsp => Some(0),
            SyncMode::Ssp { staleness } => Some(staleness),
            SyncMode::AsyncPs => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub nodes: u64,
    pub minibatch: u64,
    /// Iterations to simulate (>= 2; last-minus-previous is reported).
    /// (The comm-library send/recv overlap assumption lives in the
    /// plan's per-group `overlap` — it shapes strategy derivation, not
    /// the schedule itself.)
    pub iterations: usize,
    /// The per-layer-group parallelization plan both simulator fidelities
    /// execute. An empty plan (no assignments) is pure data parallelism.
    pub plan: PartitionPlan,
    /// Default collective-algorithm policy (`Auto` = cheaper of
    /// ring/butterfly per exchange, the tuned-library behavior;
    /// `Ring`/`Butterfly` pin it for ablations). Plan groups may override
    /// it per layer group; both the α-β cost models and the per-message
    /// schedule builders honor the same resolution.
    pub collective: collective::Choice,
    /// Plan the fleet executes after a `shrink`/`replan` failure event
    /// drops it to N-1 survivors. Backends supply the re-derived plan
    /// for `replan` (planner/recipe at the degraded node count, cached
    /// by degraded N); `None` falls back to re-normalizing `plan` per
    /// the §3.3 degenerate-shape rule. Ignored for `stall`.
    pub degraded_plan: Option<PartitionPlan>,
    /// Synchronization discipline: `Bsp` keeps today's collective
    /// barrier; `Ssp`/`AsyncPs` replace the gradient collectives with
    /// per-node parameter-server push/pull tasks and let nodes drift
    /// (bounded by the staleness window under ssp). Non-bsp modes
    /// require a pure data-parallel plan and no failure event.
    pub sync: SyncMode,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            nodes: 1,
            minibatch: 256,
            iterations: 4,
            plan: PartitionPlan::empty(1, 256),
            collective: collective::Choice::Auto,
            degraded_plan: None,
            sync: SyncMode::Bsp,
        }
    }
}

impl SimConfig {
    /// Pure data parallelism everywhere (the ablation baseline).
    pub fn data_parallel(nodes: u64, minibatch: u64) -> Self {
        SimConfig {
            nodes,
            minibatch,
            plan: PartitionPlan::empty(nodes, minibatch),
            ..Default::default()
        }
    }

    /// The paper's fixed recipe for `net` (§3.1–3.3): data-parallel conv
    /// trunk, per-layer best of data/model/hybrid on the FC head.
    pub fn recipe(net: &NetDescriptor, nodes: u64, minibatch: u64) -> Self {
        SimConfig {
            nodes,
            minibatch,
            plan: PartitionPlan::paper_recipe(net, nodes, minibatch, 1.0),
            ..Default::default()
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub nodes: u64,
    pub iteration_s: f64,
    pub images_per_s: f64,
    /// Fraction of the iteration the compute stream is busy.
    pub compute_utilization: f64,
}

/// One point of a scaling curve (Figs 4/6/7).
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub nodes: u64,
    pub images_per_s: f64,
    pub speedup: f64,
    pub efficiency: f64,
}

/// Which execution path produced a [`FleetSimResult`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimPath {
    /// Every iteration simulated event by event.
    Full,
    /// Steady-state fast path: a [`PROBE_ITERATIONS`]-iteration probe
    /// simulated in full, the remaining iterations extrapolated in
    /// closed form from the detected periodic schedule.
    Periodic,
}

impl SimPath {
    /// Wire name, as reported in `ScalingReport.sim_path`.
    pub fn name(self) -> &'static str {
        match self {
            SimPath::Full => "full",
            SimPath::Periodic => "periodic",
        }
    }
}

/// Steady-state output of the full-cluster simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSimResult {
    pub nodes: u64,
    pub iteration_s: f64,
    pub images_per_s: f64,
    /// Mean compute-stream utilization across nodes (steady iteration).
    pub mean_compute_utilization: f64,
    /// Utilization of the least-busy node — the one most starved by
    /// stragglers or contention.
    pub min_compute_utilization: f64,
    /// Tasks the simulated window covers (messages + compute + setup).
    /// On the periodic path this is the closed-form K-iteration count —
    /// identical to what the full simulation would have reported.
    pub tasks: usize,
    /// Which path produced this result (the ONLY field, together with
    /// `warmup_tasks`, on which the two paths may legitimately differ).
    pub sim_path: SimPath,
    /// Tasks actually simulated event by event: the whole DAG on the
    /// full path, the probe prefix on the periodic path.
    pub warmup_tasks: usize,
    /// Tasks one clean iteration emits (0 when a failure event split the
    /// DAG and iterations are not uniform).
    pub cycle_tasks: usize,
    /// Failure-recovery measurement (`Some` whenever a failure event
    /// fired inside the simulated window).
    pub recovery: Option<RecoveryOutcome>,
}

/// What a failure event cost and what the fleet resumed as — measured
/// from the executed schedule plus the charges baked into the DAG.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryOutcome {
    pub policy: RecoveryPolicy,
    /// Active nodes after the event (N for stall, N-1 otherwise).
    pub nodes_after: u64,
    /// Measured disruption: extra seconds the failure iteration took
    /// over the post-failure steady iteration.
    pub stall_s: f64,
    /// Charged replan-coordination seconds (`replan` only).
    pub replan_s: f64,
    /// Charged α-β weight-redistribution seconds (`shrink`/`replan`).
    pub redistribution_s: f64,
    /// Plan the survivors resumed on; `None` = the original plan
    /// (stall keeps the fleet intact).
    pub plan_after: Option<PartitionPlan>,
}

// ---------------------------------------------------------------------
// Failure-recovery cost model (shared by the fleet DAG builder and the
// analytic backend's α-β pricing of the same policies)
// ---------------------------------------------------------------------

/// Fraction of [`FleetConfig::recovery_s`] spent *detecting* a failure
/// (the survivors' timeout). Stall pays the full window — detection +
/// restart + replay of the dead node; the reconfiguring policies pay
/// only this detection share before shrinking/replanning around it.
pub const DETECT_FRAC: f64 = 0.2;

/// Fixed coordinator-side charge for running the plan search during a
/// `replan` recovery.
pub const REPLAN_SEARCH_S: f64 = 0.05;

/// Control-plane seconds to agree on and install a re-derived plan
/// across the degraded fleet: the coordinator's search charge plus a
/// log2-depth barrier + broadcast priced on the actual fabric.
pub fn replan_coordination_s(fabric: &FabricSpec, nodes_after: u64) -> f64 {
    let rounds = (nodes_after.max(2) as f64).log2().ceil() + 1.0;
    REPLAN_SEARCH_S + 2.0 * rounds * (fabric.latency_s + fabric.sw_latency_s)
}

/// Weight bytes that must move to re-establish sharding after losing
/// one of `nodes` equal owners: the dead node's 1/N share of the model.
pub fn redistribution_bytes(net: &NetDescriptor, nodes: u64) -> u64 {
    if nodes <= 1 {
        return 0;
    }
    net.weight_bytes() / nodes
}

/// α-β seconds to redistribute that share across the survivors (an
/// allgather over the degraded member set) — the closed-form twin of
/// the `redist` collective the fleet DAG expands onto the real links.
pub fn redistribution_s(
    fabric: &FabricSpec,
    choice: collective::Choice,
    net: &NetDescriptor,
    nodes_before: u64,
    nodes_after: u64,
) -> f64 {
    choice.allgather_s(fabric, redistribution_bytes(net, nodes_before), nodes_after)
}

/// Communication seconds for one layer's gradient/weight exchange under
/// its plan assignment (the canonical per-strategy α-β arithmetic lives
/// in `plan::planner`, shared with the design-point search).
fn grad_exchange_s(layer: &Layer, platform: &Platform, cfg: &SimConfig) -> f64 {
    if cfg.nodes <= 1 || !layer.is_weighted() {
        return 0.0;
    }
    if !cfg.sync.is_bsp() {
        // ssp/async: the layer's gradient moves as parameter-server
        // push/pull traffic instead of an all-member collective
        return comm_model::ps_exchange_s(&platform.fabric, layer.weight_bytes(), cfg.nodes);
    }
    planner::strategy_grad_s(
        strategy_for(layer, cfg),
        layer,
        &platform.fabric,
        choice_for(layer, cfg),
        cfg.nodes,
    )
}

/// Non-bsp sync modes price gradients as parameter-server push/pull,
/// which only shards data-parallel weights; model/hybrid layer groups
/// (and failure-recovery timelines) stay bsp-only. Checked by both
/// simulator fidelities so a direct API caller gets the same error the
/// spec layer raises.
fn check_sync_support(cfg: &SimConfig) -> Result<()> {
    if cfg.sync.is_bsp() {
        return Ok(());
    }
    if let Some(g) = cfg
        .plan
        .assignments
        .iter()
        .find(|g| !matches!(g.strategy, Strategy::Data))
    {
        bail!(
            "sync mode {:?} requires a pure data-parallel plan, but layer group {:?} \
             is assigned {:?} (set parallelism.mode = \"data\")",
            cfg.sync,
            g.name,
            g.strategy
        );
    }
    Ok(())
}

/// Activation exchange seconds (model/hybrid FC layers, fwd or bwd leg).
fn act_exchange_s(layer: &Layer, platform: &Platform, cfg: &SimConfig) -> f64 {
    planner::strategy_act_leg_s(
        strategy_for(layer, cfg),
        layer,
        &platform.fabric,
        choice_for(layer, cfg),
        cfg.nodes,
        cfg.minibatch,
    )
}

/// One compute pass of `layer` over `mb` data points, with the same
/// framework-efficiency and per-pass overhead terms as the Fig 3 model
/// (so 1-node simulated throughput anchors to the measured single-node
/// numbers) plus the §2.5 thread-utilization penalty, which bites at the
/// small per-node minibatches large clusters run at.
pub(crate) fn pass_time_s(layer: &Layer, m: &crate::analytic::MachineSpec, mb: f64) -> f64 {
    let util = compute_model::thread_utilization(layer, m, (mb.ceil() as u64).max(1)).max(0.05);
    let t = compute_model::layer_fwd_time_s(layer, m, 1) * mb / util;
    t / m.framework_efficiency + m.per_pass_overhead_s
}

/// A plan's assignment for a layer at an explicit member count — the
/// fleet builder's phase-aware lookup (after a shrink/replan failure the
/// member count and plan differ from `SimConfig`'s). Single-node and
/// weightless layers trivially run data-parallel: nothing is exchanged.
pub(crate) fn strategy_in(plan: &PartitionPlan, layer: &Layer, nodes: u64) -> Strategy {
    if !layer.is_weighted() || nodes <= 1 {
        return Strategy::Data;
    }
    plan.strategy_for(&layer.name)
}

/// Collective policy for a layer's exchanges under `plan`: the plan
/// group's pinned choice, falling back to the experiment-level default.
pub(crate) fn choice_in(
    plan: &PartitionPlan,
    layer: &Layer,
    default: collective::Choice,
) -> collective::Choice {
    plan.collective_for(&layer.name).unwrap_or(default)
}

/// Scatter per-member collective results (`done[j]` for member `j`)
/// into a global-node-indexed array.
fn scatter(out: &mut [TaskId], members: &[usize], done: &[TaskId]) {
    for (j, &v) in members.iter().enumerate() {
        out[v] = done[j];
    }
}

/// Map a `GroupTopology` member list (positions within the active
/// member set) onto global node ids.
fn to_global(members: &mut [usize], active: &[usize]) {
    for m in members.iter_mut() {
        *m = active[*m];
    }
}

/// The plan's assignment for a layer (single-node and weightless layers
/// trivially run data-parallel: there is nothing to exchange).
fn strategy_for(layer: &Layer, cfg: &SimConfig) -> Strategy {
    strategy_in(&cfg.plan, layer, cfg.nodes)
}

/// Collective policy for a layer's exchanges: the plan group's pinned
/// choice, falling back to the experiment-level default.
fn choice_for(layer: &Layer, cfg: &SimConfig) -> collective::Choice {
    choice_in(&cfg.plan, layer, cfg.collective)
}

/// Simulate `cfg.iterations` of synchronous SGD and return steady-state
/// timing for the representative node (the analytic α-β path).
pub fn simulate_training(
    net: &NetDescriptor,
    platform: &Platform,
    cfg: &SimConfig,
) -> Result<SimResult> {
    if cfg.iterations < 2 {
        bail!(
            "SimConfig.iterations is {} but must be >= 2: steady-state timing is the \
             last iteration boundary minus the previous one, so at least two \
             iterations must be simulated (set parallelism.iterations >= 2)",
            cfg.iterations
        );
    }
    check_sync_support(cfg)?;
    debug_assert!(
        cfg.plan.assignments.is_empty() || cfg.plan.nodes == cfg.nodes,
        "plan was derived for {} nodes but the simulation runs {}",
        cfg.plan.nodes,
        cfg.nodes
    );
    let m = &platform.machine;
    let mb_node = cfg.minibatch as f64 / cfg.nodes as f64;
    let layers = &net.layers;
    let k = layers.len();

    let mut eng = Engine::new();
    // update task of layer i from the previous iteration
    let mut prev_update: Vec<Option<TaskId>> = vec![None; k];

    // every iteration emits an identical task block (same labels,
    // durations, resources; only dependency contents differ — iteration
    // 0 has no previous updates to gate on), so the loop walks the model
    // only twice and the remaining iterations are instanced from the
    // trailing block
    for _ in 0..2 {
        // ---------------- forward ----------------
        let mut last_fwd: Option<TaskId> = None;
        for (i, l) in layers.iter().enumerate() {
            let mut deps: Vec<TaskId> = Vec::new();
            if let Some(p) = last_fwd {
                deps.push(p);
            }
            if let Some(u) = prev_update[i] {
                deps.push(u);
            }
            // model/hybrid layers gather remote activations before compute
            let act_s = act_exchange_s(l, platform, cfg);
            let fwd_dep = if act_s > 0.0 {
                let a = eng.add(&format!("act_fwd.{}", l.name), COMM, ns(act_s), &deps);
                vec![a]
            } else {
                deps
            };
            let eff_mb = per_layer_mb(l, cfg, mb_node);
            let t = pass_time_s(l, m, eff_mb);
            let id = eng.add(&format!("fwd.{}", l.name), COMPUTE, ns(t), &fwd_dep);
            last_fwd = Some(id);
        }

        // ---------------- backward (wt-grad before bprop) ----------------
        let mut chain = last_fwd.expect("non-empty net");
        let mut update_ids: Vec<Option<TaskId>> = vec![None; k];
        let first_weighted = layers.iter().position(|l| l.is_weighted()).unwrap_or(0);
        for i in (0..k).rev() {
            let l = &layers[i];
            if !l.is_weighted() {
                continue;
            }
            let eff_mb = per_layer_mb(l, cfg, mb_node);
            let per_pass = pass_time_s(l, m, eff_mb);
            // weight gradient first (enables early comm submission)
            let wg = eng.add(&format!("wtgrad.{}", l.name), COMPUTE, ns(per_pass), &[chain]);
            // submit-and-forget: gradient exchange on the comm stream
            let ex_s = grad_exchange_s(l, platform, cfg);
            let sgd_s = 2.0 * l.weight_elems() as f64 / (m.peak_gflops() * 1e9);
            let ex = if ex_s > 0.0 {
                eng.add(&format!("partreduce.{}", l.name), COMM, ns(ex_s), &[wg])
            } else {
                wg
            };
            let up = eng.add(&format!("sgd.{}", l.name), COMM, ns(sgd_s), &[ex]);
            update_ids[i] = Some(up);
            // backpropagation (skipped for the first weighted layer)
            if i != first_weighted {
                let act_s = act_exchange_s(l, platform, cfg);
                let bp = eng.add(&format!("bprop.{}", l.name), COMPUTE, ns(per_pass), &[wg]);
                chain = if act_s > 0.0 {
                    eng.add(&format!("act_bwd.{}", l.name), COMM, ns(act_s), &[bp])
                } else {
                    bp
                };
            } else {
                chain = wg;
            }
        }
        prev_update = update_ids;
    }
    // task-id range of iteration `it` is [it * stride, (it + 1) * stride)
    let stride = eng.len() / 2;
    if cfg.iterations > 2 {
        eng.instance_tail_block(stride, cfg.iterations - 2);
    }

    let sched = eng.run();
    // steady state: last iteration boundary minus the previous one, where
    // an iteration truly ends when its last update lands.
    let iter_finish = |it: usize| -> u64 {
        (it * stride..(it + 1) * stride).map(|id| sched.end_ns[id]).max().unwrap_or(0)
    };
    let t_last = iter_finish(cfg.iterations - 1);
    let t_prev = iter_finish(cfg.iterations - 2);
    let iter_s = (t_last - t_prev) as f64 / 1e9;

    // compute-stream utilization over the steady iteration
    let busy: u64 = (0..eng.len())
        .filter(|&id| {
            eng.resource(id) == COMPUTE
                && sched.start_ns[id] >= t_prev
                && sched.end_ns[id] <= t_last
        })
        .map(|id| eng.duration_ns(id))
        .sum();
    let util = busy as f64 / (t_last - t_prev).max(1) as f64;

    Ok(SimResult {
        nodes: cfg.nodes,
        iteration_s: iter_s,
        images_per_s: cfg.minibatch as f64 / iter_s,
        compute_utilization: util.min(1.0),
    })
}

/// Effective per-node data points for a layer under its strategy: data
/// parallel layers see MB/N; model/hybrid layers compute the full (group)
/// minibatch over a 1/(N/G) feature shard — same FLOPs per node.
fn per_layer_mb(layer: &Layer, cfg: &SimConfig, mb_node: f64) -> f64 {
    match strategy_for(layer, cfg) {
        Strategy::Data => mb_node,
        Strategy::Model => cfg.minibatch as f64 / cfg.nodes as f64,
        Strategy::Hybrid { .. } => cfg.minibatch as f64 / cfg.nodes as f64,
    }
}

// ---------------------------------------------------------------------
// Full-cluster simulation
// ---------------------------------------------------------------------

/// Command-queue tail of one node: the (at most two) tasks subsequent
/// collectives on that node's comm stream must chain behind. Replaces a
/// `Vec<TaskId>` per node per exchange.
#[derive(Debug, Clone, Copy, Default)]
struct Tail {
    a: Option<TaskId>,
    b: Option<TaskId>,
}

impl Tail {
    fn one(t: TaskId) -> Tail {
        Tail { a: Some(t), b: None }
    }

    fn pair(a: TaskId, b: Option<TaskId>) -> Tail {
        Tail { a: Some(a), b }
    }

    fn iter(self) -> impl Iterator<Item = TaskId> {
        self.a.into_iter().chain(self.b)
    }
}

/// A built full-cluster DAG plus the bookkeeping needed to summarize a
/// schedule: construction and execution are split so perf harnesses can
/// time them separately and replay the DAG on the reference scheduler.
#[derive(Debug)]
pub struct FleetDag {
    pub eng: Engine,
    /// Per-iteration candidate end tasks (the iteration is over when the
    /// last of them retires).
    iter_ends: Vec<Vec<TaskId>>,
    /// Recovery stalls: they occupy a compute stream but are idle time.
    fail_tasks: Vec<TaskId>,
    /// Failure event baked into the DAG (policy, split point, charges).
    recovery: Option<DagRecovery>,
    nodes: usize,
    minibatch: u64,
    iterations: usize,
    /// Tasks one iteration emits when every iteration is uniform (clean
    /// fabric — no failure split); 0 otherwise.
    cycle_tasks: usize,
    /// Synchronization discipline the DAG was built under (normalized).
    sync: SyncMode,
    /// `[iteration][node]` end task (the node's last gradient update of
    /// that iteration). Populated only under non-bsp sync, where
    /// throughput aggregates per-node rates instead of barrier spacing.
    node_iter_ends: Vec<Vec<TaskId>>,
}

/// A failure event as resolved by the DAG builder: where the simulation
/// split, what the survivors resumed on, and the charges the transition
/// tasks carry (recorded so reports can itemize them).
#[derive(Debug, Clone)]
struct DagRecovery {
    policy: RecoveryPolicy,
    fail_at: usize,
    fail_node: usize,
    nodes_after: usize,
    detect_s: f64,
    replan_s: f64,
    redistribution_s: f64,
    /// Resolved degraded plan (`None` for stall: the plan is unchanged).
    degraded_plan: Option<PartitionPlan>,
}

/// Shared context of the fleet DAG construction: the engine, the fleet
/// wiring, the per-node command-queue tails and the two reusable
/// dependency-list arenas (`gates` is indexed by global node id, `deps`
/// by collective-member position).
struct DagBuilder<'a> {
    eng: Engine,
    fleet: &'a Fleet,
    fabric: &'a FabricSpec,
    last_comm: Vec<Tail>,
    gates: DepLists,
    deps: DepLists,
    /// Reusable global-node-indexed id scratch (exchange SGD tasks).
    node_scratch: Vec<TaskId>,
    comm_scratch: Vec<usize>,
}

impl<'a> DagBuilder<'a> {
    fn new(fleet: &'a Fleet, fabric: &'a FabricSpec) -> DagBuilder<'a> {
        let n = fleet.cfg.nodes;
        DagBuilder {
            eng: Engine::new(),
            fleet,
            fabric,
            last_comm: vec![Tail::default(); n],
            gates: DepLists::new(),
            deps: DepLists::new(),
            node_scratch: vec![0; n],
            comm_scratch: Vec::with_capacity(n),
        }
    }

    /// Reset the gate arena to one single-dependency list per node:
    /// node `v` gates on `src[v]`.
    fn gates_single(&mut self, src: &[TaskId]) {
        self.gates.clear();
        for &t in src {
            self.gates.push(t);
            self.gates.finish_list();
        }
    }

    /// Build one collective over `members` (global node ids), gated per
    /// member on `self.gates.get(v)` plus the member's command-queue
    /// tail. Returns the per-member completion tasks.
    fn run_collective(
        &mut self,
        choice: collective::Choice,
        label: &str,
        members: &[usize],
        bytes: u64,
        kind: CollectiveKind,
    ) -> Vec<TaskId> {
        let algo = choice.algorithm(self.fabric, bytes, members.len() as u64);
        self.comm_scratch.clear();
        self.comm_scratch.extend(members.iter().map(|&v| self.fleet.comm_res(v)));
        self.deps.clear();
        for &v in members {
            for &d in self.gates.get(v) {
                self.deps.push(d);
            }
            for d in self.last_comm[v].iter() {
                self.deps.push(d);
            }
            self.deps.finish_list();
        }
        let built = collective::build_collective(
            &mut self.eng, &self.fleet.net, &self.comm_scratch, label, members, bytes,
            &self.deps, kind, algo,
        );
        for (j, &v) in members.iter().enumerate() {
            let extra = (built.done[j] != built.last_local[j]).then_some(built.done[j]);
            self.last_comm[v] = Tail::pair(built.last_local[j], extra);
        }
        built.done
    }

    /// RS -> strip SGD -> AG over one member set: the §3.4 gradient
    /// exchange as an explicit message schedule. `wg` is indexed by
    /// global node id. Returns the per-member update task (the one that
    /// releases the next iteration's forward pass).
    fn exchange_update(
        &mut self,
        choice: collective::Choice,
        label: &str,
        members: &[usize],
        bytes: u64,
        wg: &[TaskId],
        sgd_s: f64,
    ) -> Vec<TaskId> {
        self.gates_single(wg);
        let rs = self.run_collective(choice, label, members, bytes, CollectiveKind::ReduceScatter);
        let sgd_label = format!("{label}.sgd");
        let mut sgd_global = std::mem::take(&mut self.node_scratch);
        for (j, &v) in members.iter().enumerate() {
            let mut d: [TaskId; 3] = [0; 3];
            d[0] = rs[j];
            let mut len = 1;
            for t in self.last_comm[v].iter() {
                d[len] = t;
                len += 1;
            }
            let id = self.eng.add(
                &sgd_label,
                self.fleet.comm_res(v),
                ns(sgd_s * self.fleet.time_mult[v]),
                &d[..len],
            );
            self.last_comm[v] = Tail::one(id);
            sgd_global[v] = id;
        }
        self.gates_single(&sgd_global);
        self.node_scratch = sgd_global;
        self.run_collective(choice, label, members, bytes, CollectiveKind::Allgather)
    }
}

/// Build the full-cluster DAG for `cfg.iterations` of synchronous SGD:
/// every node of the fleet, with collectives expanded to per-message
/// tasks over contended links. `cfg.nodes` must equal `fleet_cfg.nodes`.
///
/// A failure event (`fleet_cfg.fail_at`) splits the build per the
/// fleet's [`RecoveryPolicy`]: `stall` keeps all N nodes and inserts the
/// classic detection + restart + replay stall on the dead node's compute
/// stream; `shrink`/`replan` drop the dead node at the split, insert the
/// detect → (replan) → redistribute transition on the survivors, and
/// continue the remaining iterations at N-1 on the degraded plan with
/// the global minibatch respread over the survivors.
///
/// Clean builds (no firing failure event) walk the model zoo and the
/// collective expanders for the first two iterations only and instance
/// the rest from the trailing block — bit-identical to the loop build
/// ([`build_training_fleet_full`] forces the loop; the equivalence is
/// asserted in `tests/engine_oracle.rs`).
pub fn build_training_fleet(
    net: &NetDescriptor,
    platform: &Platform,
    cfg: &SimConfig,
    fleet_cfg: &FleetConfig,
) -> Result<FleetDag> {
    build_fleet_dag(net, platform, cfg, fleet_cfg, true)
}

/// [`build_training_fleet`] with template instancing disabled: every
/// iteration is re-emitted through the builders. Retained as the
/// ground-truth construction path (and the honest baseline for the
/// template-vs-full rows in `benches/netsim_perf.rs`).
pub fn build_training_fleet_full(
    net: &NetDescriptor,
    platform: &Platform,
    cfg: &SimConfig,
    fleet_cfg: &FleetConfig,
) -> Result<FleetDag> {
    build_fleet_dag(net, platform, cfg, fleet_cfg, false)
}

fn build_fleet_dag(
    net: &NetDescriptor,
    platform: &Platform,
    cfg: &SimConfig,
    fleet_cfg: &FleetConfig,
    use_template: bool,
) -> Result<FleetDag> {
    if cfg.iterations < 2 {
        bail!(
            "SimConfig.iterations is {} but must be >= 2 for the fleet builder: \
             steady-state timing is the last iteration boundary minus the previous \
             one, so at least two iterations must be simulated (set \
             parallelism.iterations >= 2)",
            cfg.iterations
        );
    }
    check_sync_support(cfg)?;
    if !cfg.sync.is_bsp() && fleet_cfg.fail_at.filter(|&it| it < cfg.iterations).is_some() {
        bail!(
            "sync mode {:?} does not model failure recovery: the shrink/replan/stall \
             timelines assume the bsp barrier (drop cluster.fail_at or set \
             parallelism.sync = \"bsp\")",
            cfg.sync
        );
    }
    assert_eq!(
        cfg.nodes as usize, fleet_cfg.nodes,
        "SimConfig.nodes must match FleetConfig.nodes"
    );
    debug_assert!(
        cfg.plan.assignments.is_empty() || cfg.plan.nodes == cfg.nodes,
        "plan was derived for {} nodes but the fleet runs {}",
        cfg.plan.nodes,
        cfg.nodes
    );
    let m = &platform.machine;
    let fabric = &platform.fabric;
    let fleet = Fleet::new(fleet_cfg, fabric);
    let n = fleet_cfg.nodes;
    let layers = &net.layers;
    let k = layers.len();

    // failure-event resolution: an event outside the simulated window
    // never fires, and a 1-node fleet has no survivors to shrink onto,
    // so it degrades to stall
    let policy = if n <= 1 {
        RecoveryPolicy::Stall
    } else {
        fleet_cfg.recovery
    };
    let recovery: Option<DagRecovery> = fleet_cfg
        .fail_at
        .filter(|&it| it < cfg.iterations)
        .map(|fail_at| {
            let fail_node = fleet_cfg.fail_node.min(n - 1);
            let (nodes_after, degraded_plan) = match policy {
                RecoveryPolicy::Stall => (n, None),
                _ => {
                    let plan = match &cfg.degraded_plan {
                        Some(p) => p.clone(),
                        None => cfg.plan.renormalize_for(n as u64 - 1),
                    };
                    debug_assert!(
                        plan.assignments.is_empty() || plan.nodes == n as u64 - 1,
                        "degraded plan was derived for {} nodes but {} survive",
                        plan.nodes,
                        n - 1
                    );
                    (n - 1, Some(plan))
                }
            };
            let reconfigures = policy != RecoveryPolicy::Stall;
            DagRecovery {
                policy,
                fail_at,
                fail_node,
                nodes_after,
                detect_s: if reconfigures {
                    DETECT_FRAC * fleet_cfg.recovery_s
                } else {
                    0.0
                },
                replan_s: if policy == RecoveryPolicy::Replan {
                    replan_coordination_s(fabric, nodes_after as u64)
                } else {
                    0.0
                },
                redistribution_s: if reconfigures {
                    redistribution_s(fabric, cfg.collective, net, n as u64, nodes_after as u64)
                } else {
                    0.0
                },
                degraded_plan,
            }
        });

    let mut b = DagBuilder::new(&fleet, fabric);
    // [node][layer] update task of the previous iteration
    let mut prev_update: Vec<Vec<Option<TaskId>>> = vec![vec![None; k]; n];
    // per-iteration candidate end tasks
    let mut iter_ends: Vec<Vec<TaskId>> = Vec::with_capacity(cfg.iterations);
    // each node's backward-chain end of the previous iteration
    let mut prev_chain: Vec<Option<TaskId>> = vec![None; n];
    // recovery stalls occupy a compute stream but are idle time, not work
    let mut fail_tasks: Vec<TaskId> = Vec::new();

    // the member set and plan of the phase being built: all N nodes on
    // cfg.plan until a shrink/replan failure drops the fleet to the
    // survivors on the degraded plan (arrays stay indexed by global node
    // id throughout; dead slots simply stop being written or read)
    let mut active: Vec<usize> = (0..n).collect();
    let mut alive: Vec<bool> = vec![true; n];
    let mut plan: &PartitionPlan = &cfg.plan;
    let mut n_active: u64 = n as u64;

    // clean builds emit one identical task block per iteration (only the
    // dependency contents differ: iteration 0 has no previous updates),
    // so the expensive zoo/collective walk runs twice and the remaining
    // iterations are instanced from the trailing block; a failure event
    // makes iterations non-uniform and forces the full loop, and so do
    // the non-bsp modes (ssp's drift gates reach staleness+1 iterations
    // back, which the two-iteration template cannot represent)
    let template =
        use_template && recovery.is_none() && cfg.iterations > 2 && cfg.sync.is_bsp();
    let built_iterations = if template { 2 } else { cfg.iterations };
    // [iteration][node] end task, tracked only when nodes may drift
    let mut node_iter_ends: Vec<Vec<TaskId>> = Vec::new();

    for it in 0..built_iterations {
        let mut iter_tail: Vec<TaskId> = Vec::new();
        // per-node gate releasing this iteration's first forward pass
        // (stall rejoin, or the shrink/replan transition's last task)
        let mut resume_gate: Vec<Option<TaskId>> = vec![None; n];
        if let Some(rec) = recovery.as_ref().filter(|r| r.fail_at == it) {
            match rec.policy {
                RecoveryPolicy::Stall => {
                    // failure/rejoin: the failed node stalls for detection +
                    // restart + replay before its forward pass; the
                    // synchronous step waits. Gated on the node's previous
                    // iteration so the stall lands at the start of iteration
                    // `fail_at`, not at simulation time zero.
                    let v = rec.fail_node;
                    let deps: Vec<TaskId> = prev_chain[v].into_iter().collect();
                    let id = b.eng.add(
                        "fail",
                        fleet.compute_res(v),
                        ns(fleet_cfg.recovery_s),
                        &deps,
                    );
                    fail_tasks.push(id);
                    resume_gate[v] = Some(id);
                }
                RecoveryPolicy::Replan | RecoveryPolicy::Shrink => {
                    // detect → (replan) → redistribute → resume: the
                    // survivors time out on the dead node, agree on the
                    // degraded plan, then re-establish weight ownership
                    // over the actual fabric before the next iteration
                    alive[rec.fail_node] = false;
                    active.retain(|&v| v != rec.fail_node);
                    n_active = rec.nodes_after as u64;
                    plan = rec.degraded_plan.as_ref().expect("degraded plan");
                    let mut gate: Vec<TaskId> = vec![0; n];
                    for &v in &active {
                        let deps: Vec<TaskId> = prev_chain[v].into_iter().collect();
                        let d = b.eng.add(
                            "detect",
                            fleet.compute_res(v),
                            ns(rec.detect_s),
                            &deps,
                        );
                        fail_tasks.push(d);
                        gate[v] = if rec.replan_s > 0.0 {
                            let rp = b.eng.add(
                                "replan",
                                fleet.compute_res(v),
                                ns(rec.replan_s),
                                &[d],
                            );
                            fail_tasks.push(rp);
                            rp
                        } else {
                            d
                        };
                    }
                    let bytes = redistribution_bytes(net, n as u64);
                    if bytes > 0 && active.len() > 1 {
                        b.gates_single(&gate);
                        let done = b.run_collective(
                            cfg.collective, "redist", &active, bytes,
                            CollectiveKind::Allgather,
                        );
                        for (j, &v) in active.iter().enumerate() {
                            resume_gate[v] = Some(done[j]);
                        }
                    } else {
                        for &v in &active {
                            resume_gate[v] = Some(gate[v]);
                        }
                    }
                }
            }
        }
        // per-node data points: the global minibatch spread over the
        // currently-active member count (every strategy computes the
        // same per-node share; model/hybrid shard features, not samples)
        let mb_active = cfg.minibatch as f64 / n_active as f64;

        // ---------------- forward ----------------
        let mut last_fwd: Vec<Option<TaskId>> = vec![None; n];
        for (i, l) in layers.iter().enumerate() {
            let strat = strategy_in(plan, l, n_active);
            let choice = choice_in(plan, l, cfg.collective);
            b.gates.clear();
            for v in 0..n {
                if alive[v] {
                    if let Some(p) = last_fwd[v] {
                        b.gates.push(p);
                    }
                    if let Some(u) = prev_update[v][i] {
                        b.gates.push(u);
                    }
                    if i == 0 {
                        if let Some(s) = resume_gate[v] {
                            b.gates.push(s);
                        }
                        // ssp drift bound: node v may not start iteration
                        // `it` until every other node finished iteration
                        // `it - 1 - K` (async-ps pushes no gate at all;
                        // bsp's coupling is the collective itself)
                        if let SyncMode::Ssp { staleness } = cfg.sync.normalized() {
                            if let Some(lag_it) = it.checked_sub(1 + staleness) {
                                for u in 0..n {
                                    if u != v && alive[u] {
                                        b.gates.push(node_iter_ends[lag_it][u]);
                                    }
                                }
                            }
                        }
                    }
                }
                b.gates.finish_list();
            }
            // model/hybrid layers gather remote activations before compute
            let fwd_src: Option<Vec<TaskId>> = match strat {
                Strategy::Model if n_active > 1 => {
                    let bytes = 4 * l.in_elems() * cfg.minibatch;
                    let done = b.run_collective(
                        choice, &format!("af{i}"), &active, bytes,
                        CollectiveKind::Allgather,
                    );
                    let mut out: Vec<TaskId> = vec![0; n];
                    scatter(&mut out, &active, &done);
                    Some(out)
                }
                Strategy::Hybrid { groups } if n_active > 1 => {
                    let topo = GroupTopology::new(n_active as usize, groups as usize);
                    let bytes = 4 * l.in_elems() * (cfg.minibatch / groups);
                    let mut out: Vec<TaskId> = vec![0; n];
                    for g in 0..topo.groups {
                        let mut members = topo.group_members(g);
                        to_global(&mut members, &active);
                        let done = b.run_collective(
                            choice, &format!("af{i}.g{g}"), &members, bytes,
                            CollectiveKind::Allgather,
                        );
                        scatter(&mut out, &members, &done);
                    }
                    Some(out)
                }
                _ => None,
            };
            let base_t = pass_time_s(l, m, mb_active);
            let fwd_label = format!("f{i}");
            for &v in &active {
                let dur = ns(base_t * fleet.time_mult[v]);
                let id = match &fwd_src {
                    Some(done) => b.eng.add(&fwd_label, fleet.compute_res(v), dur, &[done[v]]),
                    None => b.eng.add(&fwd_label, fleet.compute_res(v), dur, b.gates.get(v)),
                };
                last_fwd[v] = Some(id);
            }
        }

        // ---------------- backward (wt-grad before bprop) ----------------
        let mut chain: Vec<TaskId> = vec![0; n];
        for &v in &active {
            chain[v] = last_fwd[v].expect("non-empty net");
        }
        let mut update_ids: Vec<Vec<Option<TaskId>>> = vec![vec![None; k]; n];
        let first_weighted = layers.iter().position(|l| l.is_weighted()).unwrap_or(0);
        for i in (0..k).rev() {
            let l = &layers[i];
            if !l.is_weighted() {
                continue;
            }
            let strat = strategy_in(plan, l, n_active);
            let choice = choice_in(plan, l, cfg.collective);
            let per_pass = pass_time_s(l, m, mb_active);
            // weight gradient first (enables early comm submission)
            let wg_label = format!("w{i}");
            let mut wg: Vec<TaskId> = vec![0; n];
            for &v in &active {
                wg[v] = b.eng.add(
                    &wg_label,
                    fleet.compute_res(v),
                    ns(per_pass * fleet.time_mult[v]),
                    &[chain[v]],
                );
            }
            let sgd_s = 2.0 * l.weight_elems() as f64 / (m.peak_gflops() * 1e9);
            let updates: Vec<TaskId> = if !cfg.sync.is_bsp() && n_active > 1 {
                // parameter-server push/pull on each node's own comm
                // stream: the α-β round trip to the sharded PS, then the
                // local apply. No cross-node coupling here — ssp's drift
                // bound gates the *forward* side instead.
                let ps_s = comm_model::ps_exchange_s(fabric, l.weight_bytes(), n_active);
                let ps_label = format!("ps{i}");
                let sgd_label = format!("sgd{i}");
                let mut out: Vec<TaskId> = vec![0; n];
                for &v in &active {
                    let mut d: [TaskId; 3] = [0; 3];
                    d[0] = wg[v];
                    let mut len = 1;
                    for t in b.last_comm[v].iter() {
                        d[len] = t;
                        len += 1;
                    }
                    let ps =
                        b.eng.add(&ps_label, fleet.comm_res(v), ns(ps_s), &d[..len]);
                    let id = b.eng.add(
                        &sgd_label,
                        fleet.comm_res(v),
                        ns(sgd_s * fleet.time_mult[v]),
                        &[ps],
                    );
                    b.last_comm[v] = Tail::one(id);
                    out[v] = id;
                }
                out
            } else {
                match strat {
                Strategy::Data if n_active > 1 => {
                    let done = b.exchange_update(
                        choice, &format!("x{i}"), &active, l.weight_bytes(), &wg, sgd_s,
                    );
                    let mut out: Vec<TaskId> = vec![0; n];
                    scatter(&mut out, &active, &done);
                    out
                }
                Strategy::Hybrid { groups } if n_active > 1 => {
                    // data-parallel exchange of the 1/(N/G) weight shard
                    // across each replica set
                    let topo = GroupTopology::new(n_active as usize, groups as usize);
                    let shard = l.weight_bytes() / topo.group_size() as u64;
                    let mut out: Vec<TaskId> = vec![0; n];
                    for r in 0..topo.group_size() {
                        let mut members = topo.replica_set(r);
                        to_global(&mut members, &active);
                        let done = b.exchange_update(
                            choice, &format!("x{i}.r{r}"), &members, shard, &wg, sgd_s,
                        );
                        scatter(&mut out, &members, &done);
                    }
                    out
                }
                _ => {
                    // no weight exchange (model parallel or single node):
                    // local SGD on the comm stream
                    let sgd_label = format!("sgd{i}");
                    let mut out: Vec<TaskId> = vec![0; n];
                    for &v in &active {
                        let mut d: [TaskId; 3] = [0; 3];
                        d[0] = wg[v];
                        let mut len = 1;
                        for t in b.last_comm[v].iter() {
                            d[len] = t;
                            len += 1;
                        }
                        let id = b.eng.add(
                            &sgd_label,
                            fleet.comm_res(v),
                            ns(sgd_s * fleet.time_mult[v]),
                            &d[..len],
                        );
                        b.last_comm[v] = Tail::one(id);
                        out[v] = id;
                    }
                    out
                }
                }
            };
            for &v in &active {
                update_ids[v][i] = Some(updates[v]);
                iter_tail.push(updates[v]);
            }
            // backpropagation (skipped for the first weighted layer)
            if i != first_weighted {
                let bp_label = format!("b{i}");
                let mut bp: Vec<TaskId> = vec![0; n];
                for &v in &active {
                    bp[v] = b.eng.add(
                        &bp_label,
                        fleet.compute_res(v),
                        ns(per_pass * fleet.time_mult[v]),
                        &[wg[v]],
                    );
                }
                // model/hybrid layers exchange activations on the way back
                chain = match strat {
                    Strategy::Model if n_active > 1 => {
                        let bytes = 4 * l.in_elems() * cfg.minibatch;
                        b.gates_single(&bp);
                        let done = b.run_collective(
                            choice, &format!("ab{i}"), &active, bytes,
                            CollectiveKind::Allgather,
                        );
                        let mut out: Vec<TaskId> = vec![0; n];
                        scatter(&mut out, &active, &done);
                        out
                    }
                    Strategy::Hybrid { groups } if n_active > 1 => {
                        let topo = GroupTopology::new(n_active as usize, groups as usize);
                        let bytes = 4 * l.in_elems() * (cfg.minibatch / groups);
                        let mut out: Vec<TaskId> = vec![0; n];
                        b.gates_single(&bp);
                        for g in 0..topo.groups {
                            let mut members = topo.group_members(g);
                            to_global(&mut members, &active);
                            let done = b.run_collective(
                                choice, &format!("ab{i}.g{g}"), &members, bytes,
                                CollectiveKind::Allgather,
                            );
                            scatter(&mut out, &members, &done);
                        }
                        out
                    }
                    _ => bp,
                };
            } else {
                chain = wg;
            }
        }
        if !cfg.sync.is_bsp() {
            // a node's iteration retires with its last update: the first
            // weighted layer is processed last on the backward walk and
            // its ps→sgd pair chains behind everything else on the
            // node's comm stream
            let ends: Vec<TaskId> = (0..n)
                .map(|v| update_ids[v][first_weighted].expect("weighted net"))
                .collect();
            node_iter_ends.push(ends);
        }
        prev_update = update_ids;
        for &v in &active {
            prev_chain[v] = Some(chain[v]);
            iter_tail.push(chain[v]);
        }
        iter_ends.push(iter_tail);
    }

    if template {
        let stride = b.eng.len() / 2;
        b.eng.instance_tail_block(stride, cfg.iterations - 2);
        // each instanced copy ends on the shifted images of iteration 1's
        // end tasks (the copies are exact shifted replicas)
        let template_ends = iter_ends[1].clone();
        for c in 1..=cfg.iterations - 2 {
            iter_ends.push(template_ends.iter().map(|&t| t + stride * c).collect());
        }
    }
    let cycle_tasks = if recovery.is_none() { b.eng.len() / cfg.iterations } else { 0 };

    Ok(FleetDag {
        eng: b.eng,
        iter_ends,
        fail_tasks,
        recovery,
        nodes: n,
        minibatch: cfg.minibatch,
        iterations: cfg.iterations,
        cycle_tasks,
        sync: cfg.sync.normalized(),
        node_iter_ends,
    })
}

/// Steady-state summary of one executed fleet schedule.
pub fn summarize_fleet(dag: &FleetDag, sched: &Schedule) -> FleetSimResult {
    let n = dag.nodes;
    let iter_finish = |it: usize| -> u64 {
        dag.iter_ends[it].iter().map(|&id| sched.end_ns[id]).max().unwrap_or(0)
    };
    let t_last = iter_finish(dag.iterations - 1);
    let t_prev = iter_finish(dag.iterations - 2);
    let iter_s = ((t_last - t_prev) as f64 / 1e9).max(1e-12);

    // a shrink/replan failure leaves the dead node idle for the rest of
    // the schedule: keep it out of the utilization statistics
    let lost: Option<usize> = dag
        .recovery
        .as_ref()
        .filter(|r| r.nodes_after < n)
        .map(|r| r.fail_node);
    // per-node compute utilization over the steady iteration (recovery
    // stalls hold the stream but are idle time, not work)
    let mut busy = vec![0u64; n];
    for id in 0..dag.eng.len() {
        let r = dag.eng.resource(id);
        if r < 2 * n
            && r % 2 == 0
            && sched.start_ns[id] >= t_prev
            && sched.end_ns[id] <= t_last
            // fail_tasks is sorted (ids are pushed in creation order), and
            // shrink/replan push O(N) transition tasks — keep the lookup
            // logarithmic, this loop runs over every simulated task
            && dag.fail_tasks.binary_search(&id).is_err()
        {
            busy[r / 2] += dag.eng.duration_ns(id);
        }
    }
    let window = (t_last - t_prev).max(1) as f64;
    let utils: Vec<f64> = busy
        .iter()
        .enumerate()
        .filter(|&(v, _)| Some(v) != lost)
        .map(|(_, &b)| (b as f64 / window).min(1.0))
        .collect();
    let mean = utils.iter().sum::<f64>() / utils.len().max(1) as f64;
    let min = utils.iter().cloned().fold(f64::INFINITY, f64::min);

    // measured failure disruption: the extra seconds the failure
    // iteration took over the post-failure steady iteration
    let recovery = dag.recovery.as_ref().map(|rec| {
        let before = if rec.fail_at > 0 {
            iter_finish(rec.fail_at - 1)
        } else {
            0
        };
        let failure_iter_s = (iter_finish(rec.fail_at).saturating_sub(before)) as f64 / 1e9;
        RecoveryOutcome {
            policy: rec.policy,
            nodes_after: rec.nodes_after as u64,
            stall_s: (failure_iter_s - iter_s).max(0.0),
            replan_s: rec.replan_s,
            redistribution_s: rec.redistribution_s,
            plan_after: rec.degraded_plan.clone(),
        }
    });

    // barrier-free modes: aggregate throughput is the sum of per-node
    // steady rates (each node feeds its MB/N share at its own pace, and
    // under async-ps the fast nodes genuinely run ahead), not the
    // fleet-wide boundary spacing a barrier would impose; iteration_s
    // is re-derived as the aggregate-equivalent spacing
    let (iter_s, images_per_s) = if !dag.sync.is_bsp() && dag.node_iter_ends.len() >= 2 {
        let mb_node = dag.minibatch as f64 / n as f64;
        let last = &dag.node_iter_ends[dag.iterations - 1];
        let prev = &dag.node_iter_ends[dag.iterations - 2];
        let rate: f64 = (0..n)
            .map(|v| {
                let t = sched.end_ns[last[v]].saturating_sub(sched.end_ns[prev[v]]) as f64
                    / 1e9;
                mb_node / t.max(1e-12)
            })
            .sum();
        (dag.minibatch as f64 / rate, rate)
    } else {
        (iter_s, dag.minibatch as f64 / iter_s)
    };

    FleetSimResult {
        nodes: n as u64,
        iteration_s: iter_s,
        images_per_s,
        mean_compute_utilization: mean,
        min_compute_utilization: min,
        tasks: dag.eng.len(),
        sim_path: SimPath::Full,
        warmup_tasks: dag.eng.len(),
        cycle_tasks: dag.cycle_tasks,
        recovery,
    }
}

/// Iterations the periodic fast path simulates in full before
/// extrapolating: one warm-up block, two steady blocks to detect the
/// period across, and a terminal block — so the probe's measurement
/// window (last iteration minus previous) has exactly the same
/// neighbor context (a mid block followed by a successor-less final
/// block) as the full run's, which is what makes the extrapolated
/// report bit-identical.
pub const PROBE_ITERATIONS: usize = 4;

/// Clean-fabric check for the periodic fast path. Stragglers, hetero
/// generations, firing failure events and the non-bsp sync modes (whose
/// drift gates reach past the probe's neighbor window) genuinely need
/// the full split DAG; `REPRO_NETSIM_PATH=full` forces the full path
/// for A/B gating.
fn periodic_eligible(cfg: &SimConfig, fleet_cfg: &FleetConfig) -> bool {
    let forced_full = matches!(std::env::var("REPRO_NETSIM_PATH"), Ok(v) if v == "full");
    !forced_full
        && cfg.sync.is_bsp()
        && cfg.iterations > PROBE_ITERATIONS
        && fleet_cfg.straggler_skew == 0.0
        && !fleet_cfg.hetero
        && fleet_cfg.fail_at.filter(|&it| it < cfg.iterations).is_none()
}

/// The steady-state fast path: build + run a [`PROBE_ITERATIONS`]
/// probe, verify the schedule is periodic, and extrapolate the
/// K-iteration report in closed form. Returns `Ok(None)` when the probe
/// does not prove periodicity (the caller falls back to the full
/// simulation).
fn simulate_fleet_periodic(
    net: &NetDescriptor,
    platform: &Platform,
    cfg: &SimConfig,
    fleet_cfg: &FleetConfig,
) -> Result<Option<FleetSimResult>> {
    let probe_cfg = SimConfig { iterations: PROBE_ITERATIONS, ..cfg.clone() };
    let dag = build_training_fleet(net, platform, &probe_cfg, fleet_cfg)?;
    let stride = dag.cycle_tasks;
    if stride == 0 || dag.eng.len() != stride * PROBE_ITERATIONS {
        return Ok(None);
    }
    let sched = dag.eng.run();
    let iter_finish = |it: usize| -> u64 {
        dag.iter_ends[it].iter().map(|&id| sched.end_ns[id]).max().unwrap_or(0)
    };
    // adjacency guard: a block may overlap its direct neighbors only
    // (block b+2 must not start before block b fully finished). This
    // bounds how far scheduling state propagates, so the probe's blocks
    // provably see the same context as the full run's.
    for bl in 0..PROBE_ITERATIONS - 2 {
        let fin = iter_finish(bl);
        let min_start = (stride * (bl + 2)..stride * (bl + 3))
            .map(|id| sched.start_ns[id])
            .min()
            .unwrap_or(0);
        if min_start < fin {
            return Ok(None);
        }
    }
    // the two mid blocks must repeat with one constant per-task shift
    if engine::periodic_shift(&sched, stride, stride, 2).is_none() {
        return Ok(None);
    }
    // the probe's steady window [finish(P-2), finish(P-1)] is a shifted
    // replica of the full run's [finish(K-2), finish(K-1)] — identical
    // iteration time, throughput and utilizations; only the task total
    // is scaled to the K iterations the caller asked for
    let mut r = summarize_fleet(&dag, &sched);
    r.sim_path = SimPath::Periodic;
    r.tasks = stride * cfg.iterations;
    Ok(Some(r))
}

/// Simulate `cfg.iterations` of synchronous SGD across every node of the
/// fleet, with collectives expanded to per-message tasks over contended
/// links. `cfg.nodes` must equal `fleet_cfg.nodes`.
///
/// Clean-fabric configurations route through the steady-state periodic
/// fast path (probe + closed-form extrapolation, bit-identical to the
/// full simulation — `sim_path` records which path ran); stragglers,
/// hetero generations, failure events, an undetected period or
/// `REPRO_NETSIM_PATH=full` all fall back to
/// [`simulate_training_fleet_full`].
pub fn simulate_training_fleet(
    net: &NetDescriptor,
    platform: &Platform,
    cfg: &SimConfig,
    fleet_cfg: &FleetConfig,
) -> Result<FleetSimResult> {
    if periodic_eligible(cfg, fleet_cfg) {
        if let Some(r) = simulate_fleet_periodic(net, platform, cfg, fleet_cfg)? {
            return Ok(r);
        }
    }
    simulate_training_fleet_full(net, platform, cfg, fleet_cfg)
}

/// Force the full event-by-event simulation of every iteration — the
/// ground truth the periodic fast path is verified against.
pub fn simulate_training_fleet_full(
    net: &NetDescriptor,
    platform: &Platform,
    cfg: &SimConfig,
    fleet_cfg: &FleetConfig,
) -> Result<FleetSimResult> {
    let dag = build_training_fleet(net, platform, cfg, fleet_cfg)?;
    let sched = dag.eng.run();
    Ok(summarize_fleet(&dag, &sched))
}

/// Sweep node counts and produce a scaling curve (speedup vs the 1-node
/// simulation of the same config). `plan_for(n)` supplies the partition
/// plan at each size — plans are node-count-specific because hybrid
/// group shapes change with N (use `PartitionPlan::paper_recipe` /
/// `PartitionPlan::data_parallel` closures for the classic curves).
pub fn scaling_curve(
    net: &NetDescriptor,
    platform: &Platform,
    minibatch: u64,
    nodes: &[u64],
    plan_for: impl Fn(u64) -> PartitionPlan,
) -> Result<Vec<ScalingPoint>> {
    let base = simulate_training(
        net,
        platform,
        &SimConfig { nodes: 1, minibatch, plan: plan_for(1), ..Default::default() },
    )?;
    let mut curve = Vec::with_capacity(nodes.len());
    for &n in nodes {
        let r = simulate_training(
            net,
            platform,
            &SimConfig { nodes: n, minibatch, plan: plan_for(n), ..Default::default() },
        )?;
        curve.push(ScalingPoint {
            nodes: n,
            images_per_s: r.images_per_s,
            speedup: r.images_per_s / base.images_per_s,
            efficiency: r.images_per_s / (base.images_per_s * n as f64),
        });
    }
    Ok(curve)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{cddnn_full, overfeat_fast, vgg_a};

    /// The paper-recipe plan closure for [`scaling_curve`].
    fn recipe_of(net: &NetDescriptor, mb: u64) -> impl Fn(u64) -> PartitionPlan + '_ {
        move |n| PartitionPlan::paper_recipe(net, n, mb, 1.0)
    }

    #[test]
    fn single_node_matches_compute_only() {
        let p = Platform::cori();
        let r = simulate_training(&vgg_a(), &p, &SimConfig::default()).unwrap();
        assert!(r.compute_utilization > 0.99, "{}", r.compute_utilization);
        // ~25-40 img/s on one node (Fig 3/4 anchor)
        assert!((20.0..50.0).contains(&r.images_per_s), "{}", r.images_per_s);
    }

    #[test]
    fn fig4_vgg_scaling_shape() {
        // Fig 4: VGG-A MB=512 reaches ~90x at 128 Cori nodes (70% eff);
        // MB=256 ~82% efficiency at 64 nodes.
        let p = Platform::cori();
        let net = vgg_a();
        let curve512 = scaling_curve(&net, &p, 512, &[128], recipe_of(&net, 512)).unwrap();
        assert!(
            (60.0..120.0).contains(&curve512[0].speedup),
            "128-node speedup {}",
            curve512[0].speedup
        );
        let curve256 = scaling_curve(&net, &p, 256, &[64], recipe_of(&net, 256)).unwrap();
        assert!(
            curve256[0].efficiency > 0.60,
            "64-node eff {}",
            curve256[0].efficiency
        );
    }

    #[test]
    fn scaling_is_monotone_in_nodes() {
        let p = Platform::cori();
        let net = vgg_a();
        let curve =
            scaling_curve(&net, &p, 256, &[2, 4, 8, 16, 32, 64], recipe_of(&net, 256)).unwrap();
        for w in curve.windows(2) {
            assert!(w[1].images_per_s >= w[0].images_per_s * 0.98);
        }
    }

    #[test]
    fn overfeat_scales_worse_than_vgg_on_ethernet() {
        // Fig 6's observation: VGG-A speedup (14.2x) > OverFeat (11.9x)
        // at 16 AWS nodes because of its higher flops-per-byte.
        let p = Platform::aws();
        let of_net = overfeat_fast();
        let vg_net = vgg_a();
        let of =
            scaling_curve(&of_net, &p, 256, &[16], recipe_of(&of_net, 256)).unwrap()[0].speedup;
        let vg =
            scaling_curve(&vg_net, &p, 256, &[16], recipe_of(&vg_net, 256)).unwrap()[0].speedup;
        assert!(vg > of, "vgg {vg} overfeat {of}");
        assert!((6.0..16.1).contains(&of), "{of}");
        assert!((10.0..16.1).contains(&vg), "{vg}");
    }

    #[test]
    fn cddnn_scales_least() {
        // Fig 7: CD-DNN reaches only ~6.5x on 16 nodes even on FDR.
        let p = Platform::endeavor();
        let dn_net = cddnn_full();
        let dn =
            scaling_curve(&dn_net, &p, 1024, &[16], recipe_of(&dn_net, 1024)).unwrap()[0].speedup;
        assert!((3.0..12.0).contains(&dn), "{dn}");
        let vg_net = vgg_a();
        let vg =
            scaling_curve(&vg_net, &p, 256, &[16], recipe_of(&vg_net, 256)).unwrap()[0].speedup;
        assert!(dn < vg);
    }

    #[test]
    fn recipe_plan_beats_pure_data_parallel_for_fc_nets() {
        // The §3.3 ablation: the hybrid recipe plan vs the all-data plan
        // for the FC-dominated CD-DNN.
        let p = Platform::endeavor();
        let net = cddnn_full();
        let hybrid = scaling_curve(&net, &p, 1024, &[16], recipe_of(&net, 1024)).unwrap()[0].speedup;
        let data = scaling_curve(&net, &p, 1024, &[16], |n| {
            PartitionPlan::data_parallel(&net, n, 1024)
        })
        .unwrap()[0]
            .speedup;
        assert!(hybrid > data, "hybrid {hybrid} !> data {data}");
    }

    #[test]
    fn per_group_collective_override_is_honored() {
        // pinning the collective on the FC group must change the α-β
        // exchange durations vs the (different) pinned alternative
        let p = Platform::endeavor();
        let net = cddnn_full();
        let mut iter_s = Vec::new();
        for pinned in [collective::Choice::Ring, collective::Choice::Butterfly] {
            let mut plan = PartitionPlan::paper_recipe(&net, 16, 1024, 1.0);
            for g in &mut plan.assignments {
                g.collective = Some(pinned);
            }
            let cfg = SimConfig { nodes: 16, minibatch: 1024, plan, ..Default::default() };
            iter_s.push(simulate_training(&net, &p, &cfg).unwrap().iteration_s);
        }
        assert_ne!(iter_s[0], iter_s[1], "ring vs butterfly made no difference");
    }

    #[test]
    fn fleet_single_node_matches_representative() {
        let p = Platform::cori();
        let cfg = SimConfig::default();
        let rep = simulate_training(&vgg_a(), &p, &cfg).unwrap();
        let full = simulate_training_fleet(
            &vgg_a(), &p, &cfg, &crate::netsim::FleetConfig::homogeneous(1),
        )
        .unwrap();
        let rel = (rep.iteration_s - full.iteration_s).abs() / rep.iteration_s;
        assert!(rel < 0.01, "rep {} vs full {}", rep.iteration_s, full.iteration_s);
    }

    #[test]
    fn fleet_sim_is_deterministic() {
        let p = Platform::aws();
        let cfg =
            SimConfig { iterations: 3, ..SimConfig::recipe(&overfeat_fast(), 4, 256) };
        let fc = crate::netsim::FleetConfig {
            nodes: 4,
            straggler_skew: 0.25,
            ..Default::default()
        };
        let a = simulate_training_fleet(&overfeat_fast(), &p, &cfg, &fc).unwrap();
        let b = simulate_training_fleet(&overfeat_fast(), &p, &cfg, &fc).unwrap();
        assert_eq!(a.iteration_s, b.iteration_s);
        assert_eq!(a.tasks, b.tasks);
    }

    #[test]
    fn shrink_drops_the_failed_node_and_resumes_at_n_minus_one() {
        let mut p = Platform::cori();
        p.fabric.congestion_per_doubling = 0.0;
        let net = vgg_a();
        let cfg = SimConfig { iterations: 5, ..SimConfig::data_parallel(4, 256) };
        let fc = crate::netsim::FleetConfig {
            nodes: 4,
            fail_at: Some(1),
            fail_node: 2,
            recovery_s: 3.0,
            recovery: RecoveryPolicy::Shrink,
            ..Default::default()
        };
        let r = simulate_training_fleet(&net, &p, &cfg, &fc).unwrap();
        let rec = r.recovery.expect("failure fired");
        assert_eq!(rec.nodes_after, 3);
        assert_eq!(rec.replan_s, 0.0);
        assert!(rec.redistribution_s > 0.0);
        assert!(rec.stall_s > 0.0, "transition must cost something");
        // post-failure steady state: 3 survivors each compute MB/3 — the
        // iteration is slower than the clean 4-node fleet but faster
        // than paying the whole minibatch on one node
        let clean = simulate_training_fleet(
            &net, &p, &cfg, &crate::netsim::FleetConfig::homogeneous(4),
        )
        .unwrap();
        assert!(r.iteration_s > clean.iteration_s * 1.1, "{} vs {}", r.iteration_s,
                clean.iteration_s);
        assert!(r.iteration_s < clean.iteration_s * 2.0);
        // the dead node is excluded from utilization, so survivors stay busy
        assert!(r.min_compute_utilization > 0.5, "{}", r.min_compute_utilization);
    }

    #[test]
    fn replan_charges_coordination_on_top_of_shrink() {
        let mut p = Platform::cori();
        p.fabric.congestion_per_doubling = 0.0;
        let net = vgg_a();
        let cfg = SimConfig { iterations: 5, ..SimConfig::recipe(&net, 4, 256) };
        let mk = |policy| {
            let fc = crate::netsim::FleetConfig {
                nodes: 4,
                fail_at: Some(1),
                fail_node: 0,
                recovery_s: 3.0,
                recovery: policy,
                ..Default::default()
            };
            simulate_training_fleet(&net, &p, &cfg, &fc).unwrap()
        };
        let shrink = mk(RecoveryPolicy::Shrink).recovery.unwrap();
        let replan = mk(RecoveryPolicy::Replan).recovery.unwrap();
        assert_eq!(shrink.replan_s, 0.0);
        assert!(replan.replan_s > 0.0);
        assert_eq!(shrink.redistribution_s, replan.redistribution_s);
        // both resumed on a plan valid at 3 nodes
        for rec in [&shrink, &replan] {
            let after = rec.plan_after.as_ref().expect("degraded plan recorded");
            assert_eq!(after.nodes, 3);
            after.validate(&net).unwrap();
        }
        let stall = mk(RecoveryPolicy::Stall).recovery.unwrap();
        assert_eq!(stall.nodes_after, 4);
        assert!(stall.plan_after.is_none());
        // stall pays the full recovery_s; the measured disruption is in
        // that ballpark (pipelining can hide a little of it)
        assert!(stall.stall_s > 2.0, "{}", stall.stall_s);
    }

    #[test]
    fn too_few_iterations_is_a_helpful_error_not_a_panic() {
        let p = Platform::cori();
        let cfg = SimConfig { iterations: 1, ..SimConfig::default() };
        let err = simulate_training(&vgg_a(), &p, &cfg).unwrap_err();
        assert!(format!("{err}").contains("at least two"), "{err}");
        let err = simulate_training_fleet(
            &vgg_a(), &p, &cfg, &crate::netsim::FleetConfig::homogeneous(1),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("parallelism.iterations"), "{err}");
    }

    #[test]
    fn fleet_dag_replays_identically_on_the_reference_engine() {
        // the fleet DAG is the real workload the oracle must agree on —
        // not just random graphs
        let p = Platform::aws();
        let cfg = SimConfig { iterations: 3, ..SimConfig::recipe(&overfeat_fast(), 4, 256) };
        let fc = crate::netsim::FleetConfig::homogeneous(4);
        let dag = build_training_fleet(&overfeat_fast(), &p, &cfg, &fc).unwrap();
        let fast = dag.eng.run();
        let oracle = crate::netsim::reference::run(&dag.eng);
        assert_eq!(fast, oracle);
    }
}
