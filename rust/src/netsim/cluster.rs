//! Per-iteration task DAG for distributed synchronous SGD, simulated on
//! the discrete-event engine — the machinery behind Figs 4, 6 and 7.
//!
//! Representative-node model: all nodes are symmetric in (hybrid) data
//! parallelism, so we simulate one node's two streams — its compute
//! pipeline and its dedicated communication thread (§4) — with collective
//! durations taken from the α-β models over the full node count. The
//! schedule encodes the paper's §3.1 overlap structure:
//!
//! * forward L0..Lk, then backward Lk..L0 with **wt-grad before bprop**;
//! * the gradient exchange of layer i is submitted to the comm stream the
//!   moment wt-grad_i retires (submit-and-forget through the command
//!   queue) and overlaps all remaining backward work and the next
//!   iteration's forward work up to layer i;
//! * fwd_i of iteration t+1 depends on update_i (comm + SGD) of t;
//! * model/hybrid-parallel FC layers additionally exchange activations
//!   *inside* the fwd/bwd chains (not overlappable — §3.2's weakness).
//!
//! Steady-state iteration time is measured between consecutive iteration
//! boundaries after a warm-up iteration.



use crate::analytic::comm_model::{self, Strategy};
use crate::analytic::compute_model;
use crate::analytic::machine::Platform;
use crate::models::{Layer, NetDescriptor};

use super::collective;
use super::engine::{Engine, TaskId};

const COMPUTE: usize = 0;
const COMM: usize = 1;

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub nodes: u64,
    pub minibatch: u64,
    /// Send/recv overlap achieved by the comm library (paper assumes 1).
    pub overlap: f64,
    /// Iterations to simulate (>= 3; last-minus-previous is reported).
    pub iterations: usize,
    /// Per-layer strategy selection: `true` = paper recipe (hybrid FCs),
    /// `false` = pure data parallelism everywhere (the ablation).
    pub hybrid_fc: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { nodes: 1, minibatch: 256, overlap: 1.0, iterations: 4, hybrid_fc: true }
    }
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub nodes: u64,
    pub iteration_s: f64,
    pub images_per_s: f64,
    /// Fraction of the iteration the compute stream is busy.
    pub compute_utilization: f64,
}

/// One point of a scaling curve (Figs 4/6/7).
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub nodes: u64,
    pub images_per_s: f64,
    pub speedup: f64,
    pub efficiency: f64,
}

fn ns(seconds: f64) -> u64 {
    (seconds * 1e9).round().max(0.0) as u64
}

/// Communication seconds for one layer's gradient/weight exchange under
/// its strategy.
fn grad_exchange_s(layer: &Layer, platform: &Platform, cfg: &SimConfig) -> f64 {
    let fabric = &platform.fabric;
    let n = cfg.nodes;
    if n <= 1 || !layer.is_weighted() {
        return 0.0;
    }
    match strategy_for(layer, cfg) {
        Strategy::Data => {
            collective::gradient_exchange_s(fabric, layer.weight_bytes(), n)
        }
        Strategy::Model => 0.0, // weights stay put; activations move instead
        Strategy::Hybrid { groups } => {
            // data-parallel exchange of the 1/G weight shard across groups
            let shard = layer.weight_bytes() / (n / groups).max(1);
            collective::gradient_exchange_s(fabric, shard, groups)
        }
    }
}

/// Activation exchange seconds (model/hybrid FC layers, fwd or bwd leg).
fn act_exchange_s(layer: &Layer, platform: &Platform, cfg: &SimConfig) -> f64 {
    let fabric = &platform.fabric;
    match strategy_for(layer, cfg) {
        Strategy::Data => 0.0,
        Strategy::Model => {
            let bytes = 4 * layer.in_elems() * cfg.minibatch;
            collective::allgather_s(fabric, bytes, cfg.nodes)
        }
        Strategy::Hybrid { groups } => {
            let group_nodes = (cfg.nodes / groups).max(1);
            let mb_group = cfg.minibatch / groups;
            let bytes = 4 * layer.in_elems() * mb_group;
            collective::allgather_s(fabric, bytes, group_nodes)
        }
    }
}

/// One compute pass of `layer` over `mb` data points, with the same
/// framework-efficiency and per-pass overhead terms as the Fig 3 model
/// (so 1-node simulated throughput anchors to the measured single-node
/// numbers) plus the §2.5 thread-utilization penalty, which bites at the
/// small per-node minibatches large clusters run at.
fn pass_time_s(layer: &Layer, m: &crate::analytic::MachineSpec, mb: f64) -> f64 {
    let util = compute_model::thread_utilization(layer, m, (mb.ceil() as u64).max(1)).max(0.05);
    let t = compute_model::layer_fwd_time_s(layer, m, 1) * mb / util;
    t / m.framework_efficiency + m.per_pass_overhead_s
}

fn strategy_for(layer: &Layer, cfg: &SimConfig) -> Strategy {
    if !cfg.hybrid_fc || layer.is_conv() || !layer.is_weighted() || cfg.nodes <= 1 {
        return Strategy::Data;
    }
    comm_model::best_strategy(layer, cfg.minibatch, cfg.nodes, cfg.overlap)
}

/// Simulate `cfg.iterations` of synchronous SGD and return steady-state
/// timing for the representative node.
pub fn simulate_training(net: &NetDescriptor, platform: &Platform, cfg: &SimConfig) -> SimResult {
    assert!(cfg.iterations >= 2);
    let m = &platform.machine;
    let mb_node = cfg.minibatch as f64 / cfg.nodes as f64;
    let layers = &net.layers;
    let k = layers.len();

    let mut eng = Engine::new();
    // update task of layer i from the previous iteration
    let mut prev_update: Vec<Option<TaskId>> = vec![None; k];
    let mut iter_end: Vec<TaskId> = Vec::new();

    for it in 0..cfg.iterations {
        // ---------------- forward ----------------
        let mut last_fwd: Option<TaskId> = None;
        let mut fwd_ids = Vec::with_capacity(k);
        for (i, l) in layers.iter().enumerate() {
            let mut deps: Vec<TaskId> = Vec::new();
            if let Some(p) = last_fwd {
                deps.push(p);
            }
            if let Some(u) = prev_update[i] {
                deps.push(u);
            }
            // model/hybrid layers gather remote activations before compute
            let act_s = act_exchange_s(l, platform, cfg);
            let fwd_dep = if act_s > 0.0 {
                let a = eng.add(
                    format!("it{it}.act_fwd.{}", l.name),
                    COMM,
                    ns(act_s),
                    &deps,
                );
                vec![a]
            } else {
                deps
            };
            let eff_mb = per_layer_mb(l, cfg, mb_node);
            let t = pass_time_s(l, m, eff_mb);
            let id = eng.add(format!("it{it}.fwd.{}", l.name), COMPUTE, ns(t), &fwd_dep);
            last_fwd = Some(id);
            fwd_ids.push(id);
        }

        // ---------------- backward (wt-grad before bprop) ----------------
        let mut chain = last_fwd.expect("non-empty net");
        let mut update_ids: Vec<Option<TaskId>> = vec![None; k];
        let first_weighted = layers.iter().position(|l| l.is_weighted()).unwrap_or(0);
        for i in (0..k).rev() {
            let l = &layers[i];
            if !l.is_weighted() {
                continue;
            }
            let eff_mb = per_layer_mb(l, cfg, mb_node);
            let per_pass = pass_time_s(l, m, eff_mb);
            // weight gradient first (enables early comm submission)
            let wg = eng.add(format!("it{it}.wtgrad.{}", l.name), COMPUTE, ns(per_pass), &[chain]);
            // submit-and-forget: gradient exchange on the comm stream
            let ex_s = grad_exchange_s(l, platform, cfg);
            let sgd_s = 2.0 * l.weight_elems() as f64 / (m.peak_gflops() * 1e9);
            let ex = if ex_s > 0.0 {
                eng.add(format!("it{it}.partreduce.{}", l.name), COMM, ns(ex_s), &[wg])
            } else {
                wg
            };
            let up = eng.add(format!("it{it}.sgd.{}", l.name), COMM, ns(sgd_s), &[ex]);
            update_ids[i] = Some(up);
            // backpropagation (skipped for the first weighted layer)
            if i != first_weighted {
                let act_s = act_exchange_s(l, platform, cfg);
                let bp = eng.add(format!("it{it}.bprop.{}", l.name), COMPUTE, ns(per_pass), &[wg]);
                chain = if act_s > 0.0 {
                    eng.add(format!("it{it}.act_bwd.{}", l.name), COMM, ns(act_s), &[bp])
                } else {
                    bp
                };
            } else {
                chain = wg;
            }
        }
        prev_update = update_ids;
        iter_end.push(chain);
    }

    let sched = eng.run();
    // steady state: last iteration boundary minus the previous one, where
    // an iteration truly ends when its last update lands.
    let iter_finish = |it: usize| -> u64 {
        let prefix = format!("it{it}.");
        (0..eng.len())
            .filter(|&id| eng.task(id).name.starts_with(&prefix))
            .map(|id| sched.end_ns[id])
            .max()
            .unwrap_or(0)
    };
    let t_last = iter_finish(cfg.iterations - 1);
    let t_prev = iter_finish(cfg.iterations - 2);
    let iter_s = (t_last - t_prev) as f64 / 1e9;

    // compute-stream utilization over the steady iteration
    let busy: u64 = (0..eng.len())
        .filter(|&id| {
            eng.task(id).resource == COMPUTE
                && sched.start_ns[id] >= t_prev
                && sched.end_ns[id] <= t_last
        })
        .map(|id| eng.task(id).duration_ns)
        .sum();
    let util = busy as f64 / (t_last - t_prev).max(1) as f64;

    SimResult {
        nodes: cfg.nodes,
        iteration_s: iter_s,
        images_per_s: cfg.minibatch as f64 / iter_s,
        compute_utilization: util.min(1.0),
    }
}

/// Effective per-node data points for a layer under its strategy: data
/// parallel layers see MB/N; model/hybrid layers compute the full (group)
/// minibatch over a 1/(N/G) feature shard — same FLOPs per node.
fn per_layer_mb(layer: &Layer, cfg: &SimConfig, mb_node: f64) -> f64 {
    match strategy_for(layer, cfg) {
        Strategy::Data => mb_node,
        Strategy::Model => cfg.minibatch as f64 / cfg.nodes as f64,
        Strategy::Hybrid { .. } => cfg.minibatch as f64 / cfg.nodes as f64,
    }
}

/// Sweep node counts and produce a scaling curve (speedup vs the 1-node
/// simulation of the same config).
pub fn scaling_curve(
    net: &NetDescriptor,
    platform: &Platform,
    minibatch: u64,
    nodes: &[u64],
    hybrid_fc: bool,
) -> Vec<ScalingPoint> {
    let base = simulate_training(
        net,
        platform,
        &SimConfig { nodes: 1, minibatch, hybrid_fc, ..Default::default() },
    );
    nodes
        .iter()
        .map(|&n| {
            let r = simulate_training(
                net,
                platform,
                &SimConfig { nodes: n, minibatch, hybrid_fc, ..Default::default() },
            );
            ScalingPoint {
                nodes: n,
                images_per_s: r.images_per_s,
                speedup: r.images_per_s / base.images_per_s,
                efficiency: r.images_per_s / (base.images_per_s * n as f64),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{cddnn_full, overfeat_fast, vgg_a};

    #[test]
    fn single_node_matches_compute_only() {
        let p = Platform::cori();
        let r = simulate_training(&vgg_a(), &p, &SimConfig::default());
        assert!(r.compute_utilization > 0.99, "{}", r.compute_utilization);
        // ~25-40 img/s on one node (Fig 3/4 anchor)
        assert!((20.0..50.0).contains(&r.images_per_s), "{}", r.images_per_s);
    }

    #[test]
    fn fig4_vgg_scaling_shape() {
        // Fig 4: VGG-A MB=512 reaches ~90x at 128 Cori nodes (70% eff);
        // MB=256 ~82% efficiency at 64 nodes.
        let p = Platform::cori();
        let curve512 = scaling_curve(&vgg_a(), &p, 512, &[128], true);
        assert!(
            (60.0..120.0).contains(&curve512[0].speedup),
            "128-node speedup {}",
            curve512[0].speedup
        );
        let curve256 = scaling_curve(&vgg_a(), &p, 256, &[64], true);
        assert!(
            curve256[0].efficiency > 0.60,
            "64-node eff {}",
            curve256[0].efficiency
        );
    }

    #[test]
    fn scaling_is_monotone_in_nodes() {
        let p = Platform::cori();
        let curve = scaling_curve(&vgg_a(), &p, 256, &[2, 4, 8, 16, 32, 64], true);
        for w in curve.windows(2) {
            assert!(w[1].images_per_s >= w[0].images_per_s * 0.98);
        }
    }

    #[test]
    fn overfeat_scales_worse_than_vgg_on_ethernet() {
        // Fig 6's observation: VGG-A speedup (14.2x) > OverFeat (11.9x)
        // at 16 AWS nodes because of its higher flops-per-byte.
        let p = Platform::aws();
        let of = scaling_curve(&overfeat_fast(), &p, 256, &[16], true)[0].speedup;
        let vg = scaling_curve(&vgg_a(), &p, 256, &[16], true)[0].speedup;
        assert!(vg > of, "vgg {vg} overfeat {of}");
        assert!((6.0..16.1).contains(&of), "{of}");
        assert!((10.0..16.1).contains(&vg), "{vg}");
    }

    #[test]
    fn cddnn_scales_least() {
        // Fig 7: CD-DNN reaches only ~6.5x on 16 nodes even on FDR.
        let p = Platform::endeavor();
        let dn = scaling_curve(&cddnn_full(), &p, 1024, &[16], true)[0].speedup;
        assert!((3.0..12.0).contains(&dn), "{dn}");
        let vg = scaling_curve(&vgg_a(), &p, 256, &[16], true)[0].speedup;
        assert!(dn < vg);
    }

    #[test]
    fn hybrid_fc_beats_pure_data_parallel_for_fc_nets() {
        // The §3.3 ablation: hybrid on vs off for the FC-dominated CD-DNN.
        let p = Platform::endeavor();
        let hybrid = scaling_curve(&cddnn_full(), &p, 1024, &[16], true)[0].speedup;
        let data = scaling_curve(&cddnn_full(), &p, 1024, &[16], false)[0].speedup;
        assert!(hybrid > data, "hybrid {hybrid} !> data {data}");
    }
}
