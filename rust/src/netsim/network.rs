//! Network topology layer: maps node pairs to contended link resources.
//!
//! Links are first-class unary resources of the discrete-event engine: a
//! message from `src` to `dst` occupies the sender's NIC injection port
//! (tx), the receiver's ejection port (rx), and — on an oversubscribed
//! fat-tree — one up-channel of the source leaf and one down-channel of
//! the destination leaf. Contention (many flows crossing an
//! oversubscribed core, incast into one receiver, a straggler's late
//! sends) then emerges from resource serialization instead of from the
//! analytic model's `congestion_per_doubling` fudge factor.
//!
//! Calibration note: per-direction link bandwidth is the fabric's
//! *overlapped-exchange* bandwidth ([`FabricSpec::effective_bw`]), the
//! same constant the α-β formulas in [`super::collective`] use. With tx
//! and rx as separate resources, a full-duplex send+recv pair overlaps
//! naturally, and on a homogeneous contention-free fabric the simulated
//! collectives converge to the closed-form α-β predictions exactly (the
//! validation test in `tests/fleet_sim.rs` asserts this within 5%).

use crate::analytic::FabricSpec;

/// Round seconds to engine nanoseconds.
pub fn ns(seconds: f64) -> u64 {
    (seconds * 1e9).round().max(0.0) as u64
}

/// Fabric wiring between the nodes of a fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Topology {
    /// All N nodes on one commodity Ethernet switch: non-blocking for
    /// unicast, but every message pays a store-and-forward hop (2α).
    FlatSwitch,
    /// Leaf-spine fat-tree with `radix` nodes per leaf switch and an
    /// oversubscribed core: each leaf exposes `radix / oversub`
    /// full-rate channels toward the spine. Intra-leaf traffic costs 2α,
    /// cross-leaf traffic 3α plus the shared channel.
    FatTree { radix: usize, oversub: f64 },
    /// Fully provisioned HPC fabric (Aries/FDR-class): per-node dedicated
    /// paths, contention only at the NICs, single-α messages.
    FullySwitched,
}

impl Topology {
    /// Short tag for labels and JSON output.
    pub fn tag(&self) -> String {
        match self {
            Topology::FlatSwitch => "flat".to_string(),
            Topology::FatTree { radix, oversub } => format!("fattree{radix}x{oversub}"),
            Topology::FullySwitched => "switched".to_string(),
        }
    }
}

/// Instantiated link resources for `nodes` endpoints of one fabric.
#[derive(Debug, Clone)]
pub struct Network {
    pub topology: Topology,
    pub nodes: usize,
    /// Effective per-direction bandwidth of a NIC port, bytes/s.
    pub nic_bw: f64,
    /// Per-message wire latency (α), seconds.
    pub latency_s: f64,
    /// Per-collective software setup latency (the §3.2 SWlat term).
    pub sw_latency_s: f64,
    /// First engine resource id owned by the network.
    base: usize,
    /// Fat-tree: number of leaves and full-rate channels per leaf.
    n_leaves: usize,
    channels_per_leaf: usize,
}

impl Network {
    /// Build the link resources for `nodes` endpoints, starting at engine
    /// resource id `base` (ids below `base` belong to the fleet's
    /// compute/comm streams).
    pub fn new(topology: Topology, nodes: usize, fabric: &FabricSpec, base: usize) -> Network {
        let (n_leaves, channels_per_leaf) = match topology {
            Topology::FatTree { radix, oversub } => {
                assert!(radix >= 1, "fat-tree radix must be >= 1");
                assert!(oversub >= 1.0, "oversubscription must be >= 1.0");
                let leaves = (nodes + radix - 1) / radix;
                let ch = ((radix as f64 / oversub).floor() as usize).max(1);
                (leaves, ch)
            }
            _ => (0, 0),
        };
        Network {
            topology,
            nodes,
            nic_bw: fabric.effective_bw(),
            latency_s: fabric.latency_s,
            sw_latency_s: fabric.sw_latency_s,
            base,
            n_leaves,
            channels_per_leaf,
        }
    }

    /// Total engine resources the network occupies (tx+rx per node, plus
    /// up+down channels per leaf on a fat-tree).
    pub fn n_resources(&self) -> usize {
        2 * self.nodes + 2 * self.n_leaves * self.channels_per_leaf
    }

    /// NIC injection port of node `v`.
    pub fn tx(&self, v: usize) -> usize {
        debug_assert!(v < self.nodes);
        self.base + 2 * v
    }

    /// NIC ejection port of node `v`.
    pub fn rx(&self, v: usize) -> usize {
        debug_assert!(v < self.nodes);
        self.base + 2 * v + 1
    }

    /// Leaf switch of node `v` (0 on single-switch topologies).
    pub fn leaf_of(&self, v: usize) -> usize {
        match self.topology {
            Topology::FatTree { radix, .. } => v / radix,
            _ => 0,
        }
    }

    /// Number of leaf switches (fat-tree only; 0 elsewhere).
    pub fn n_leaves(&self) -> usize {
        self.n_leaves
    }

    /// Full-rate core channels per leaf (fat-tree only; 0 elsewhere).
    pub fn channels_per_leaf(&self) -> usize {
        self.channels_per_leaf
    }

    /// Aggregate bisection bandwidth (bytes/s): capacity crossing an
    /// even split of the fleet. Single-switch fabrics are limited only
    /// by the NICs on one side; a fat-tree is additionally capped by
    /// the core channels crossing the leaf split, so oversubscription
    /// shows up as a proportional drop.
    pub fn bisection_bw(&self) -> f64 {
        let node_limited = (self.nodes / 2) as f64 * self.nic_bw;
        match self.topology {
            // a single-leaf "fat-tree" never crosses the core
            Topology::FatTree { .. } if self.n_leaves > 1 => {
                let core =
                    (self.n_leaves / 2) as f64 * self.channels_per_leaf as f64 * self.nic_bw;
                node_limited.min(core)
            }
            _ => node_limited,
        }
    }

    /// Up-channel `c` of leaf `l`.
    fn up_channel(&self, l: usize, c: usize) -> usize {
        self.base + 2 * self.nodes + 2 * l * self.channels_per_leaf + c
    }

    /// Down-channel `c` of leaf `l`.
    fn down_channel(&self, l: usize, c: usize) -> usize {
        self.base + 2 * self.nodes + (2 * l + 1) * self.channels_per_leaf + c
    }

    /// Link resources and end-to-end latency (seconds) of one message.
    /// Channel choice is deterministic (hash of endpoint), so schedules
    /// are bit-identical across runs.
    pub fn route(&self, src: usize, dst: usize) -> (Route, f64) {
        debug_assert!(src != dst, "self-message {src}->{dst}");
        match self.topology {
            Topology::FullySwitched => {
                (Route::two(self.tx(src), self.rx(dst)), self.latency_s)
            }
            Topology::FlatSwitch => {
                (Route::two(self.tx(src), self.rx(dst)), 2.0 * self.latency_s)
            }
            Topology::FatTree { .. } => {
                let (ls, ld) = (self.leaf_of(src), self.leaf_of(dst));
                if ls == ld {
                    (Route::two(self.tx(src), self.rx(dst)), 2.0 * self.latency_s)
                } else {
                    let up = self.up_channel(ls, src % self.channels_per_leaf);
                    let down = self.down_channel(ld, dst % self.channels_per_leaf);
                    (
                        Route::four(self.tx(src), self.rx(dst), up, down),
                        3.0 * self.latency_s,
                    )
                }
            }
        }
    }

    /// Resource set + duration (ns) for a `bytes`-sized message.
    pub fn message(&self, src: usize, dst: usize, bytes: f64) -> (Route, u64) {
        let (resources, lat) = self.route(src, dst);
        (resources, ns(lat + bytes / self.nic_bw))
    }
}

/// Fixed-capacity link set of one message (≤ 4 links on every topology) —
/// a stack value instead of a `Vec` per message, which matters when a
/// 128-node fig4 iteration expands to hundreds of thousands of them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Route {
    links: [usize; 4],
    len: u8,
}

impl Route {
    fn two(a: usize, b: usize) -> Route {
        Route { links: [a, b, 0, 0], len: 2 }
    }

    fn four(a: usize, b: usize, c: usize, d: usize) -> Route {
        Route { links: [a, b, c, d], len: 4 }
    }

    pub fn as_slice(&self) -> &[usize] {
        &self.links[..self.len as usize]
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fdr() -> FabricSpec {
        FabricSpec::fdr_infiniband()
    }

    #[test]
    fn resource_ids_are_disjoint() {
        let net = Network::new(Topology::FatTree { radix: 4, oversub: 2.0 }, 8, &fdr(), 16);
        let mut seen = std::collections::BTreeSet::new();
        for v in 0..8 {
            assert!(seen.insert(net.tx(v)));
            assert!(seen.insert(net.rx(v)));
        }
        for (src, dst) in [(0usize, 5usize), (1, 6), (4, 2), (7, 0)] {
            let (res, _) = net.route(src, dst);
            assert_eq!(res.len(), 4, "cross-leaf route has 4 resources");
            for &r in res.as_slice() {
                assert!(r >= 16 && r < 16 + net.n_resources());
                seen.insert(r);
            }
        }
        // all ids at or above base
        assert!(seen.iter().all(|&r| r >= 16));
    }

    #[test]
    fn intra_leaf_skips_core_channels() {
        let net = Network::new(Topology::FatTree { radix: 4, oversub: 4.0 }, 8, &fdr(), 0);
        let (res, lat) = net.route(0, 3); // same leaf
        assert_eq!(res.len(), 2);
        assert_eq!(lat, 2.0 * net.latency_s);
        let (res, lat) = net.route(0, 4); // cross leaf
        assert_eq!(res.len(), 4);
        assert_eq!(lat, 3.0 * net.latency_s);
    }

    #[test]
    fn oversubscription_reduces_channels() {
        let full = Network::new(Topology::FatTree { radix: 8, oversub: 1.0 }, 16, &fdr(), 0);
        let over = Network::new(Topology::FatTree { radix: 8, oversub: 4.0 }, 16, &fdr(), 0);
        assert_eq!(full.channels_per_leaf, 8);
        assert_eq!(over.channels_per_leaf, 2);
        assert!(over.n_resources() < full.n_resources());
    }

    #[test]
    fn message_duration_matches_alpha_beta() {
        let f = fdr();
        let net = Network::new(Topology::FullySwitched, 4, &f, 0);
        let bytes = 1u64 << 20;
        let (_, dur) = net.message(0, 1, bytes as f64);
        let want = ns(f.latency_s + bytes as f64 / f.effective_bw());
        assert_eq!(dur, want);
    }

    #[test]
    fn switched_is_lower_latency_than_flat() {
        let f = FabricSpec::ethernet_10g();
        let sw = Network::new(Topology::FullySwitched, 4, &f, 0);
        let flat = Network::new(Topology::FlatSwitch, 4, &f, 0);
        assert!(sw.route(0, 1).1 < flat.route(0, 1).1);
    }

    #[test]
    fn leaf_helpers_expose_the_wiring() {
        let f = fdr();
        let net = Network::new(Topology::FatTree { radix: 4, oversub: 2.0 }, 10, &f, 0);
        assert_eq!(net.n_leaves(), 3); // ceil(10/4)
        assert_eq!(net.channels_per_leaf(), 2); // 4/2.0
        assert_eq!(net.leaf_of(0), 0);
        assert_eq!(net.leaf_of(3), 0);
        assert_eq!(net.leaf_of(4), 1);
        assert_eq!(net.leaf_of(9), 2);
        // single-switch fabrics have no leaves and one trivial "leaf"
        let flat = Network::new(Topology::FlatSwitch, 10, &f, 0);
        assert_eq!(flat.n_leaves(), 0);
        assert_eq!(flat.channels_per_leaf(), 0);
        assert_eq!(flat.leaf_of(7), 0);
    }

    #[test]
    fn bisection_is_node_limited_on_non_blocking_fabrics() {
        let f = fdr();
        let want = 4.0 * f.effective_bw(); // 8 nodes -> 4 NICs cross the cut
        for topo in [Topology::FullySwitched, Topology::FlatSwitch] {
            let net = Network::new(topo, 8, &f, 0);
            assert_eq!(net.bisection_bw(), want);
        }
        // a non-blocking fat-tree (oversub = 1) matches: core capacity
        // (1 leaf-pair boundary x 4 channels) equals the NIC side
        let ft = Network::new(Topology::FatTree { radix: 4, oversub: 1.0 }, 8, &f, 0);
        assert_eq!(ft.bisection_bw(), want);
    }

    #[test]
    fn oversubscription_cuts_bisection_proportionally() {
        let f = fdr();
        let full = Network::new(Topology::FatTree { radix: 8, oversub: 1.0 }, 32, &f, 0);
        let over = Network::new(Topology::FatTree { radix: 8, oversub: 4.0 }, 32, &f, 0);
        // 4 leaves: full core = 2 x 8 channels = 16 links, node side = 16
        assert_eq!(full.bisection_bw(), 16.0 * f.effective_bw());
        assert_eq!(over.bisection_bw(), full.bisection_bw() / 4.0);
    }

    #[test]
    fn single_leaf_fat_tree_never_crosses_the_core() {
        let f = fdr();
        let net = Network::new(Topology::FatTree { radix: 8, oversub: 4.0 }, 8, &f, 0);
        assert_eq!(net.n_leaves(), 1);
        assert_eq!(net.bisection_bw(), 4.0 * f.effective_bw());
    }

    #[test]
    fn routes_stay_inside_the_resource_block() {
        // every (src, dst) pair on every topology must route over links
        // the network actually owns — the contract flowsim's fair-share
        // solver relies on when it sizes its capacity vector
        let f = fdr();
        for topo in [
            Topology::FullySwitched,
            Topology::FlatSwitch,
            Topology::FatTree { radix: 4, oversub: 2.0 },
        ] {
            let net = Network::new(topo, 9, &f, 0);
            for src in 0..9 {
                for dst in 0..9 {
                    if src == dst {
                        continue;
                    }
                    let (route, lat) = net.route(src, dst);
                    assert!(lat >= net.latency_s);
                    assert!(!route.is_empty());
                    for &l in route.as_slice() {
                        assert!(l < net.n_resources(), "{topo:?} {src}->{dst} link {l}");
                    }
                }
            }
        }
    }
}
