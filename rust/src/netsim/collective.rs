//! The paper's communication primitives (§3.4), in two fidelities:
//!
//! 1. **α-β cost models** — closed-form seconds for part-reduce
//!    (`MPI_Reduce_scatter`) and part-broadcast (`MPI_Allgather`), used by
//!    the representative-node simulator and as the analytic cross-check
//!    for the full-cluster one.
//! 2. **Schedule builders** — expand the same algorithms into per-message
//!    task DAGs over the simulated links of a [`Network`], so link
//!    contention, stragglers and heterogeneous fleets shape the collective
//!    instead of a single scalar cost.
//!
//! Ring algorithm: N-1 steps of (bytes/N)-sized messages — bandwidth
//! optimal, the standard choice for large gradient tensors. Butterfly
//! (recursive halving/doubling): log2(N) steps — latency optimal for
//! small tensors. `preferred_algorithm` picks the cheaper one, which is
//! what a real MPI would do and what the paper's "optimized MPI-based
//! communications library" implies. Butterfly schedules are built only
//! for power-of-two groups (tuned libraries fall back to ring otherwise);
//! the cost model covers non-powers-of-two with Rabenseifner's extra
//! pre/post round.

use crate::analytic::FabricSpec;

use super::engine::{DepLists, Engine, TaskId};
use super::network::{ns, Network};

/// Largest power of two <= n (n >= 1).
fn prev_pow2(n: u64) -> u64 {
    debug_assert!(n >= 1);
    let mut pow = 1u64;
    while pow * 2 <= n {
        pow *= 2;
    }
    pow
}

/// Seconds for a ring reduce-scatter of `bytes` over `n` nodes.
pub fn ring_reduce_scatter_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let steps = (n - 1) as f64;
    let chunk = bytes as f64 / n as f64;
    fabric.sw_latency_s + steps * (fabric.latency_s + chunk / fabric.effective_bw_n(n))
}

/// Seconds for a ring allgather of `bytes` over `n` nodes.
pub fn ring_allgather_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    ring_reduce_scatter_s(fabric, bytes, n) // symmetric cost
}

/// Seconds for a butterfly (recursive-halving) reduce-scatter.
///
/// For non-powers-of-two, `floor(log2 n)` halving rounds run among the
/// largest power-of-two subset after the `n - 2^m` excess ranks fold
/// their full vector into a partner in one extra pre-round (and pick the
/// results back up in the allgather's mirror post-round) — one extra
/// message latency and one extra full traversal of the vector
/// (Rabenseifner). The previous `ceil(log2 n)` model priced the extra
/// round's latency but missed its full-vector volume.
pub fn butterfly_reduce_scatter_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let pow = prev_pow2(n);
    let mut rounds = pow.trailing_zeros() as f64;
    // halving rounds move bytes * (1 - 1/pow) over the wire
    let mut volume = bytes as f64 * (1.0 - 1.0 / pow as f64);
    if pow != n {
        rounds += 1.0;
        volume += bytes as f64;
    }
    fabric.sw_latency_s + rounds * fabric.latency_s + volume / fabric.effective_bw_n(n)
}

pub fn butterfly_allgather_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    butterfly_reduce_scatter_s(fabric, bytes, n)
}

/// Cheapest reduce-scatter (what the tuned library would pick).
pub fn reduce_scatter_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    ring_reduce_scatter_s(fabric, bytes, n).min(butterfly_reduce_scatter_s(fabric, bytes, n))
}

pub fn allgather_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    reduce_scatter_s(fabric, bytes, n)
}

/// Full gradient exchange for data parallelism: part-reduce of gradients,
/// SGD happens on the owned strip, part-broadcast of updated weights —
/// §3.4's usage of the two primitives.
pub fn gradient_exchange_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    reduce_scatter_s(fabric, bytes, n) + allgather_s(fabric, bytes, n)
}

/// Collective-algorithm selection policy, settable per experiment
/// (`ExperimentSpec.collective`). `Auto` is what a tuned library does —
/// the cheaper algorithm per (bytes, group) point; `Ring`/`Butterfly`
/// pin the algorithm for ablations. Both the α-β cost models and the
/// per-message schedule builders honor the same policy, so the analytic
/// and full-cluster backends stay comparable under any setting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Choice {
    #[default]
    Auto,
    Ring,
    Butterfly,
}

impl Choice {
    pub fn reduce_scatter_s(self, fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
        // priced as what the schedule builder actually runs (including
        // the ring fallback for non-power-of-two groups), so the α-β
        // backend and the per-message backend agree under every policy
        match self.algorithm(fabric, bytes, n) {
            Algorithm::Ring => ring_reduce_scatter_s(fabric, bytes, n),
            Algorithm::Butterfly => butterfly_reduce_scatter_s(fabric, bytes, n),
        }
    }

    pub fn allgather_s(self, fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
        // every algorithm's allgather mirrors its reduce-scatter cost
        self.reduce_scatter_s(fabric, bytes, n)
    }

    pub fn gradient_exchange_s(self, fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
        self.reduce_scatter_s(fabric, bytes, n) + self.allgather_s(fabric, bytes, n)
    }

    /// Schedule-builder algorithm for this policy. Butterfly schedules
    /// only exist for power-of-two groups; like a tuned library, a
    /// pinned butterfly falls back to ring elsewhere.
    pub fn algorithm(self, fabric: &FabricSpec, bytes: u64, n: u64) -> Algorithm {
        match self {
            Choice::Auto => preferred_algorithm(fabric, bytes, n),
            Choice::Ring => Algorithm::Ring,
            Choice::Butterfly => {
                if n.is_power_of_two() {
                    Algorithm::Butterfly
                } else {
                    Algorithm::Ring
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Schedule builders: the same algorithms as per-message task DAGs.
// ---------------------------------------------------------------------

/// Collective algorithm for a schedule build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    Ring,
    Butterfly,
}

/// Which primitive a schedule implements. Ring schedules are identical
/// for both; butterfly halves message sizes for reduce-scatter and
/// doubles them for allgather.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveKind {
    ReduceScatter,
    Allgather,
}

impl CollectiveKind {
    fn tag(self) -> &'static str {
        match self {
            CollectiveKind::ReduceScatter => "rs",
            CollectiveKind::Allgather => "ag",
        }
    }
}

/// Algorithm a tuned library would pick for this (bytes, group) point:
/// the cheaper of ring and butterfly by the α-β model, with ring forced
/// for non-power-of-two groups (the only case the butterfly schedule
/// builder does not cover).
pub fn preferred_algorithm(fabric: &FabricSpec, bytes: u64, n: u64) -> Algorithm {
    if !n.is_power_of_two() {
        return Algorithm::Ring;
    }
    if butterfly_reduce_scatter_s(fabric, bytes, n) < ring_reduce_scatter_s(fabric, bytes, n) {
        Algorithm::Butterfly
    } else {
        Algorithm::Ring
    }
}

/// Result of expanding one collective into tasks.
#[derive(Debug, Clone)]
pub struct BuiltCollective {
    /// Per-member task after which that member's result is final.
    pub done: Vec<TaskId>,
    /// Per-member last task occupying the member's own comm stream (for
    /// FIFO command-queue chaining of subsequent collectives).
    pub last_local: Vec<TaskId>,
}

/// Expand a reduce-scatter or allgather of `bytes` over `group` (global
/// node ids) into per-message tasks on `eng`.
///
/// Each message occupies the sender's comm stream (`comm_res`), its NIC
/// tx port, the receiver's rx port, and any shared fabric channels on the
/// route. `deps.get(j)` gates member `j`'s participation (e.g. its
/// wt-grad task plus the previous collective on its command queue); a
/// per-member setup task charging the fabric's software latency (SWlat)
/// precedes the first message. On a homogeneous contention-free fabric
/// the resulting makespan equals the α-β closed form of the same
/// algorithm.
#[allow(clippy::too_many_arguments)]
pub fn build_collective(
    eng: &mut Engine,
    net: &Network,
    comm_res: &[usize],
    label: &str,
    group: &[usize],
    bytes: u64,
    deps: &DepLists,
    kind: CollectiveKind,
    algo: Algorithm,
) -> BuiltCollective {
    let m = group.len();
    assert_eq!(comm_res.len(), m);
    assert_eq!(deps.len(), m);
    if m <= 1 {
        // no communication: a zero-duration marker keeps the chaining
        // structure uniform for callers
        let id = eng.add(
            &format!("{label}.{}.noop", kind.tag()),
            comm_res[0],
            0,
            deps.get(0),
        );
        return BuiltCollective { done: vec![id], last_local: vec![id] };
    }

    // per-member software setup (SWlat) on the member's comm stream; one
    // interned label shared by the whole group
    let sw_label = format!("{label}.{}.sw", kind.tag());
    let setup: Vec<TaskId> = (0..m)
        .map(|j| eng.add(&sw_label, comm_res[j], ns(net.sw_latency_s), deps.get(j)))
        .collect();

    match algo {
        Algorithm::Ring => build_ring(eng, net, comm_res, label, group, bytes, &setup, kind),
        Algorithm::Butterfly => {
            build_butterfly(eng, net, comm_res, label, group, bytes, &setup, kind)
        }
    }
}

/// Ring: m-1 steps; in step s member j forwards a (bytes/m)-chunk to
/// j+1. Step s of member j depends on its own previous send (command
/// order) and on the chunk it received in step s-1 from member j-1.
#[allow(clippy::too_many_arguments)]
fn build_ring(
    eng: &mut Engine,
    net: &Network,
    comm_res: &[usize],
    label: &str,
    group: &[usize],
    bytes: u64,
    setup: &[TaskId],
    kind: CollectiveKind,
) -> BuiltCollective {
    let m = group.len();
    let chunk = bytes as f64 / m as f64;
    let mut last: Vec<TaskId> = setup.to_vec();
    let mut cur: Vec<TaskId> = Vec::with_capacity(m);
    for s in 0..m - 1 {
        // one interned label per step, shared by all m messages
        let step_label = format!("{label}.{}{s}", kind.tag());
        cur.clear();
        for j in 0..m {
            let dst = (j + 1) % m;
            let prev = (j + m - 1) % m;
            let (route, dur) = net.message(group[j], group[dst], chunk);
            let mut resources = [0usize; 5];
            resources[0] = comm_res[j];
            let links = route.as_slice();
            resources[1..1 + links.len()].copy_from_slice(links);
            let resources = &resources[..1 + links.len()];
            let id = if s == 0 {
                eng.add_multi(&step_label, resources, dur, &[last[j]])
            } else {
                eng.add_multi(&step_label, resources, dur, &[last[j], last[prev]])
            };
            cur.push(id);
        }
        std::mem::swap(&mut last, &mut cur);
    }
    // member j's result is final once the last incoming chunk (sent by
    // j-1 in the final step) lands
    let done: Vec<TaskId> = (0..m).map(|j| last[(j + m - 1) % m]).collect();
    BuiltCollective { done, last_local: last }
}

/// Butterfly (recursive halving/doubling) over a power-of-two group:
/// log2(m) pairwise exchange rounds; reduce-scatter halves message sizes
/// (bytes/2, bytes/4, ...), allgather doubles them (bytes/m, ...,
/// bytes/2). Round k of member j depends on its own round k-1 send and on
/// the round k-1 message it received from its previous partner.
#[allow(clippy::too_many_arguments)]
fn build_butterfly(
    eng: &mut Engine,
    net: &Network,
    comm_res: &[usize],
    label: &str,
    group: &[usize],
    bytes: u64,
    setup: &[TaskId],
    kind: CollectiveKind,
) -> BuiltCollective {
    let m = group.len();
    assert!(m.is_power_of_two(), "butterfly schedule needs a power-of-two group, got {m}");
    let rounds = m.trailing_zeros() as usize;
    let mut last: Vec<TaskId> = setup.to_vec();
    let mut cur: Vec<TaskId> = Vec::with_capacity(m);
    let mut last_partner: Vec<usize> = (0..m).collect(); // self: no round yet
    for k in 0..rounds {
        let (dist, size) = match kind {
            // halving: highest bit first, bytes/2 then bytes/4 ...
            CollectiveKind::ReduceScatter => {
                (m >> (k + 1), bytes as f64 / (1u64 << (k + 1)) as f64)
            }
            // doubling: lowest bit first, bytes/m then 2*bytes/m ...
            CollectiveKind::Allgather => {
                (1usize << k, bytes as f64 * (1u64 << k) as f64 / m as f64)
            }
        };
        // one interned label per round, shared by all m messages
        let round_label = format!("{label}.{}{k}", kind.tag());
        cur.clear();
        for j in 0..m {
            let partner = j ^ dist;
            let (route, dur) = net.message(group[j], group[partner], size);
            let mut resources = [0usize; 5];
            resources[0] = comm_res[j];
            let links = route.as_slice();
            resources[1..1 + links.len()].copy_from_slice(links);
            let resources = &resources[..1 + links.len()];
            let id = if k == 0 {
                eng.add_multi(&round_label, resources, dur, &[last[j]])
            } else {
                // own previous send + the message received last round
                eng.add_multi(&round_label, resources, dur, &[last[j], last[last_partner[j]]])
            };
            cur.push(id);
        }
        for (j, p) in last_partner.iter_mut().enumerate() {
            *p = j ^ dist;
        }
        std::mem::swap(&mut last, &mut cur);
    }
    let done: Vec<TaskId> = (0..m).map(|j| last[last_partner[j]]).collect();
    BuiltCollective { done, last_local: last }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::network::Topology;

    fn fdr() -> FabricSpec {
        FabricSpec::fdr_infiniband()
    }

    #[test]
    fn single_node_is_free() {
        assert_eq!(gradient_exchange_s(&fdr(), 1 << 20, 1), 0.0);
    }

    #[test]
    fn butterfly_wins_small_messages_ring_wins_latency() {
        // tiny tensor, many nodes: butterfly's log2(N) latency beats
        // ring's N-1 latencies.
        let f = fdr();
        let small = 4 * 1024;
        assert!(
            butterfly_reduce_scatter_s(&f, small, 128)
                < ring_reduce_scatter_s(&f, small, 128)
        );
        assert_eq!(preferred_algorithm(&f, small, 128), Algorithm::Butterfly);
    }

    #[test]
    fn cost_scales_with_bytes() {
        let f = fdr();
        let a = gradient_exchange_s(&f, 1 << 20, 16);
        let b = gradient_exchange_s(&f, 1 << 24, 16);
        assert!(b > 8.0 * a, "{b} vs {a}");
    }

    #[test]
    fn volume_term_saturates_with_n() {
        // Bandwidth term approaches 2*bytes/bw as N grows (ring RS+AG).
        let f = fdr();
        let bytes = 64 << 20;
        let t64 = gradient_exchange_s(&f, bytes, 64);
        let t128 = gradient_exchange_s(&f, bytes, 128);
        assert!(t128 < 1.2 * t64, "{t128} vs {t64}");
    }

    #[test]
    fn ethernet_slower_than_fdr() {
        let bytes = 16 << 20;
        let eth = gradient_exchange_s(&FabricSpec::ethernet_10g(), bytes, 16);
        let ib = gradient_exchange_s(&fdr(), bytes, 16);
        assert!(eth > 3.0 * ib);
    }

    #[test]
    fn butterfly_non_pow2_pays_extra_round_and_volume() {
        // regression for the log2(n).ceil() underestimate: n = 3, 6, 12
        // must price floor(log2 n) halving rounds plus one full-vector
        // pre/post round, not just a fractional extra latency.
        let f = fdr();
        let bytes = 8u64 << 20;
        for n in [3u64, 6, 12] {
            let pow = prev_pow2(n);
            let bw = f.effective_bw_n(n);
            let want = f.sw_latency_s
                + (pow.trailing_zeros() as f64 + 1.0) * f.latency_s
                + (bytes as f64 * (1.0 - 1.0 / pow as f64) + bytes as f64) / bw;
            let got = butterfly_reduce_scatter_s(&f, bytes, n);
            assert!((got - want).abs() / want < 1e-12, "n={n}: {got} vs {want}");
            // strictly more than the old ceil(log2 n) model charged
            let old = f.sw_latency_s
                + (n as f64).log2().ceil() * f.latency_s
                + bytes as f64 * (1.0 - 1.0 / n as f64) / bw;
            assert!(got > old, "n={n}: new {got} must exceed old {old}");
            // and the builder never gets asked for a non-pow2 butterfly
            assert_eq!(preferred_algorithm(&f, 4 * 1024, n), Algorithm::Ring);
        }
        // powers of two are unchanged by the fix
        let n = 8u64;
        let want = f.sw_latency_s
            + 3.0 * f.latency_s
            + bytes as f64 * (1.0 - 1.0 / 8.0) / f.effective_bw_n(n);
        let got = butterfly_reduce_scatter_s(&f, bytes, n);
        assert!((got - want).abs() / want < 1e-12, "{got} vs {want}");
    }

    /// Contention-free network + engine harness for schedule builds.
    fn harness(nodes: usize) -> (Engine, Network, Vec<usize>, Vec<usize>, DepLists) {
        let mut f = fdr();
        f.congestion_per_doubling = 0.0;
        let net = Network::new(Topology::FullySwitched, nodes, &f, 2 * nodes);
        let eng = Engine::new();
        let comm: Vec<usize> = (0..nodes).map(|v| 2 * v + 1).collect();
        let group: Vec<usize> = (0..nodes).collect();
        let mut deps = DepLists::new();
        for _ in 0..nodes {
            deps.push_list([]);
        }
        (eng, net, comm, group, deps)
    }

    #[test]
    fn ring_schedule_matches_alpha_beta_on_clean_fabric() {
        for n in [2usize, 3, 5, 8] {
            let (mut eng, net, comm, group, deps) = harness(n);
            let bytes = 16u64 << 20;
            let built = build_collective(
                &mut eng, &net, &comm, "t", &group, bytes, &deps,
                CollectiveKind::ReduceScatter, Algorithm::Ring,
            );
            let sched = eng.run();
            let mut f = fdr();
            f.congestion_per_doubling = 0.0;
            let want = ring_reduce_scatter_s(&f, bytes, n as u64);
            let got = sched.makespan_ns as f64 / 1e9;
            assert!((got - want).abs() / want < 0.01, "n={n}: {got} vs {want}");
            // all members finish simultaneously on a homogeneous fabric
            let ends: Vec<u64> = built.done.iter().map(|&id| sched.end_ns[id]).collect();
            assert!(ends.iter().all(|&e| e == ends[0]), "{ends:?}");
        }
    }

    #[test]
    fn butterfly_schedule_matches_alpha_beta_on_clean_fabric() {
        for n in [2usize, 4, 8, 16] {
            for kind in [CollectiveKind::ReduceScatter, CollectiveKind::Allgather] {
                let (mut eng, net, comm, group, deps) = harness(n);
                let bytes = 4u64 << 20;
                build_collective(
                    &mut eng, &net, &comm, "t", &group, bytes, &deps, kind,
                    Algorithm::Butterfly,
                );
                let sched = eng.run();
                let mut f = fdr();
                f.congestion_per_doubling = 0.0;
                let want = butterfly_reduce_scatter_s(&f, bytes, n as u64);
                let got = sched.makespan_ns as f64 / 1e9;
                assert!(
                    (got - want).abs() / want < 0.01,
                    "n={n} {kind:?}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn straggler_delays_whole_ring() {
        // one late member gates everyone: the DAG expresses what a scalar
        // α-β cost cannot.
        let n = 4usize;
        let (mut eng, net, comm, group, _) = harness(n);
        let bytes = 16u64 << 20;
        let stall = eng.add("stall", 0, ns(0.5), &[]); // 500 ms on node 0's compute
        let mut deps = DepLists::new();
        for j in 0..n {
            if j == 0 {
                deps.push_list([stall]);
            } else {
                deps.push_list([]);
            }
        }
        let built = build_collective(
            &mut eng, &net, &comm, "t", &group, bytes, &deps,
            CollectiveKind::ReduceScatter, Algorithm::Ring,
        );
        let sched = eng.run();
        let finish = built.done.iter().map(|&id| sched.end_ns[id]).max().unwrap();
        assert!(finish >= ns(0.5), "collective cannot finish before the straggler joins");
    }

    #[test]
    fn single_member_collective_is_free() {
        let (mut eng, net, comm, _, _) = harness(2);
        let mut deps = DepLists::new();
        deps.push_list([]);
        let built = build_collective(
            &mut eng, &net, &comm[..1], "t", &[0], 1 << 20, &deps,
            CollectiveKind::Allgather, Algorithm::Ring,
        );
        let sched = eng.run();
        assert_eq!(sched.end_ns[built.done[0]], 0);
    }
}
