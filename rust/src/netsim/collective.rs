//! α-β cost models for the paper's communication primitives (§3.4).
//!
//! * **part-reduce** = reduce-scatter (`MPI_Reduce_scatter`): each node
//!   ends up owning the fully-reduced 1/N strip of the tensor.
//! * **part-broadcast** = allgather (`MPI_Allgather`): each node
//!   broadcasts its owned strip to the group.
//!
//! Ring algorithm: N-1 steps of (bytes/N)-sized messages — bandwidth
//! optimal, the standard choice for large gradient tensors. Butterfly
//! (recursive halving/doubling): log2(N) steps — latency optimal for
//! small tensors. `auto` picks the cheaper one, which is what a real MPI
//! would do and what the paper's "optimized MPI-based communications
//! library" implies.

use crate::analytic::FabricSpec;

/// Seconds for a ring reduce-scatter of `bytes` over `n` nodes.
pub fn ring_reduce_scatter_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let steps = (n - 1) as f64;
    let chunk = bytes as f64 / n as f64;
    fabric.sw_latency_s + steps * (fabric.latency_s + chunk / fabric.effective_bw_n(n))
}

/// Seconds for a ring allgather of `bytes` over `n` nodes.
pub fn ring_allgather_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    ring_reduce_scatter_s(fabric, bytes, n) // symmetric cost
}

/// Seconds for a butterfly (recursive-halving) reduce-scatter.
pub fn butterfly_reduce_scatter_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let rounds = (n as f64).log2().ceil();
    // round k exchanges bytes/2^k; total volume ~ bytes * (1 - 1/N)
    let volume = bytes as f64 * (1.0 - 1.0 / n as f64);
    fabric.sw_latency_s + rounds * fabric.latency_s + volume / fabric.effective_bw_n(n)
}

pub fn butterfly_allgather_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    butterfly_reduce_scatter_s(fabric, bytes, n)
}

/// Cheapest reduce-scatter (what the tuned library would pick).
pub fn reduce_scatter_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    ring_reduce_scatter_s(fabric, bytes, n).min(butterfly_reduce_scatter_s(fabric, bytes, n))
}

pub fn allgather_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    reduce_scatter_s(fabric, bytes, n)
}

/// Full gradient exchange for data parallelism: part-reduce of gradients,
/// SGD happens on the owned strip, part-broadcast of updated weights —
/// §3.4's usage of the two primitives.
pub fn gradient_exchange_s(fabric: &FabricSpec, bytes: u64, n: u64) -> f64 {
    reduce_scatter_s(fabric, bytes, n) + allgather_s(fabric, bytes, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fdr() -> FabricSpec {
        FabricSpec::fdr_infiniband()
    }

    #[test]
    fn single_node_is_free() {
        assert_eq!(gradient_exchange_s(&fdr(), 1 << 20, 1), 0.0);
    }

    #[test]
    fn butterfly_wins_small_messages_ring_wins_latency() {
        // tiny tensor, many nodes: butterfly's log2(N) latency beats
        // ring's N-1 latencies.
        let f = fdr();
        let small = 4 * 1024;
        assert!(
            butterfly_reduce_scatter_s(&f, small, 128)
                < ring_reduce_scatter_s(&f, small, 128)
        );
    }

    #[test]
    fn cost_scales_with_bytes() {
        let f = fdr();
        let a = gradient_exchange_s(&f, 1 << 20, 16);
        let b = gradient_exchange_s(&f, 1 << 24, 16);
        assert!(b > 8.0 * a, "{b} vs {a}");
    }

    #[test]
    fn volume_term_saturates_with_n() {
        // Bandwidth term approaches 2*bytes/bw as N grows (ring RS+AG).
        let f = fdr();
        let bytes = 64 << 20;
        let t64 = gradient_exchange_s(&f, bytes, 64);
        let t128 = gradient_exchange_s(&f, bytes, 128);
        assert!(t128 < 1.2 * t64, "{t128} vs {t64}");
    }

    #[test]
    fn ethernet_slower_than_fdr() {
        let bytes = 16 << 20;
        let eth = gradient_exchange_s(&FabricSpec::ethernet_10g(), bytes, 16);
        let ib = gradient_exchange_s(&fdr(), bytes, 16);
        assert!(eth > 3.0 * ib);
    }
}
