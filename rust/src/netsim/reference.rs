//! Retained reference scheduler — the original O(events × ready-set)
//! dispatch loop, kept as the oracle for the indexed fast path in
//! [`super::engine`].
//!
//! [`run`] executes the exact pre-optimization algorithm over the same
//! CSR task storage: a single global ready set ordered by (ready-time,
//! task-id), rescanned in full at every completion event, starting every
//! task whose whole resource set is idle. The fast path must produce
//! **bit-identical** [`Schedule`]s — `tests/engine_oracle.rs` asserts
//! this over randomized multi-resource DAGs, and `bench_netsim_perf`
//! measures the two against each other on the fig4 fleet DAGs.

use std::collections::{BTreeSet, BinaryHeap};

use super::engine::{Engine, Schedule, TaskId};

/// Run `eng` to completion with the reference full-scan dispatch.
pub fn run(eng: &Engine) -> Schedule {
    let n = eng.len();
    let mut remaining: Vec<usize> = (0..n).map(|id| eng.deps(id).len()).collect();
    let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for id in 0..n {
        for &d in eng.deps(id) {
            dependents[d].push(id);
        }
    }
    let mut busy_until: Vec<u64> = vec![0; eng.n_resources()];
    let mut start = vec![u64::MAX; n];
    let mut end = vec![u64::MAX; n];
    // tasks whose deps are done, ordered by (time they became ready, id)
    let mut ready: BTreeSet<(u64, TaskId)> = BTreeSet::new();
    // min-heap of (completion_time, task_id)
    let mut events: BinaryHeap<std::cmp::Reverse<(u64, TaskId)>> = BinaryHeap::new();

    for id in 0..n {
        if eng.deps(id).is_empty() {
            ready.insert((0, id));
        }
    }

    dispatch(eng, 0, &mut ready, &mut busy_until, &mut start, &mut end, &mut events);

    let mut done = 0usize;
    while let Some(std::cmp::Reverse((t, id))) = events.pop() {
        done += 1;
        for &d in &dependents[id] {
            remaining[d] -= 1;
            if remaining[d] == 0 {
                ready.insert((t, d));
            }
        }
        dispatch(eng, t, &mut ready, &mut busy_until, &mut start, &mut end, &mut events);
    }
    assert_eq!(done, n, "deadlock: {done}/{n} tasks completed (cycle in DAG?)");
    let makespan = end.iter().copied().max().unwrap_or(0);
    Schedule { start_ns: start, end_ns: end, makespan_ns: makespan }
}

/// Start every ready task whose full resource set is idle at `now`,
/// scanning the whole ready set in (ready-time, id) order.
fn dispatch(
    eng: &Engine,
    now: u64,
    ready: &mut BTreeSet<(u64, TaskId)>,
    busy_until: &mut [u64],
    start: &mut [u64],
    end: &mut [u64],
    events: &mut BinaryHeap<std::cmp::Reverse<(u64, TaskId)>>,
) {
    let mut started: Vec<(u64, TaskId)> = Vec::new();
    for &(ready_at, id) in ready.iter() {
        let res = eng.resources(id);
        if res.iter().all(|&r| busy_until[r] <= now) {
            let e = now + eng.duration_ns(id);
            for &r in res {
                busy_until[r] = e;
            }
            start[id] = now;
            end[id] = e;
            events.push(std::cmp::Reverse((e, id)));
            started.push((ready_at, id));
        }
    }
    for key in started {
        ready.remove(&key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_matches_fast_path_on_a_contended_dag() {
        let mut e = Engine::new();
        let a = e.add_multi("m0", &[0, 10, 12], 100, &[]);
        let b = e.add_multi("m1", &[1, 11, 12], 100, &[]);
        let c = e.add("tail", 2, 30, &[a, b]);
        let fast = e.run();
        let oracle = run(&e);
        assert_eq!(fast, oracle);
        assert_eq!(oracle.start_ns[c], 200);
    }
}
