//! Deterministic discrete-event engine over a task DAG with unary
//! resources.
//!
//! A task occupies one or more unary resources **simultaneously** for a
//! fixed duration once all its dependencies have completed and all its
//! resources are free. The first resource of a task is its "home" stream
//! (a node's serial compute pipeline or its dedicated communication
//! thread, the §4 software architecture); additional resources model
//! contended network links (NIC tx/rx, oversubscribed fat-tree uplinks),
//! so a message task holds its sender's injection port and its receiver's
//! ejection port for its whole flight time.
//!
//! Scheduling is work-conserving greedy in (ready-time, task-id) order:
//! when a task's dependencies complete it joins the ready set stamped
//! with that time; at every event the startable ready tasks are scanned
//! in that order and every task whose full resource set is idle starts.
//! Because a task acquires all of its resources atomically (no partial
//! hold-and-wait), the schedule is deadlock-free by construction, and it
//! is bit-identical across runs for a fixed task list — the determinism
//! behind Fig 5's "distributed = serial" equivalence argument.
//!
//! ## Fast path
//!
//! The engine stores the DAG in flat CSR-style arrays — one shared arena
//! for dependencies and one for resource sets, with interned labels
//! instead of a `String` per task — and dispatches through per-resource
//! ready queues instead of rescanning the whole ready set at every
//! completion event:
//!
//! * a ready task that cannot start immediately is parked in the queue of
//!   **every** resource it needs (multi-resource tasks join each queue,
//!   guarded by a started bitmap so a task that starts via one queue is
//!   skipped in the others);
//! * each running task registers a `(end_time, resource)` free event per
//!   resource it holds; a dispatch at time `t` only re-examines the
//!   queues of resources whose free events have matured (`end <= t`),
//!   which is exactly the set of waiters whose blocking state can have
//!   changed — everything else stays parked untouched;
//! * candidates from those queues plus the newly-ready tasks are merged
//!   in global (ready-time, id) order and started greedily against live
//!   `busy_until` state, which reproduces the reference full-scan
//!   semantics bit-for-bit (`super::reference` is the retained oracle;
//!   `tests/engine_oracle.rs` proves the equivalence on randomized
//!   multi-resource DAGs).

use std::collections::{BinaryHeap, HashMap};

pub type TaskId = usize;

/// Simulation output: per-task start/end and the makespan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    pub start_ns: Vec<u64>,
    pub end_ns: Vec<u64>,
    pub makespan_ns: u64,
}

impl Schedule {
    pub fn end_of(&self, id: TaskId) -> u64 {
        self.end_ns[id]
    }
}

/// Reusable per-member dependency lists backed by one shared arena — the
/// DAG builders' replacement for a `Vec<Vec<TaskId>>` per collective
/// (`clear` + refill recycles the allocation across layers/iterations).
#[derive(Debug, Clone)]
pub struct DepLists {
    items: Vec<TaskId>,
    offs: Vec<u32>,
}

impl Default for DepLists {
    fn default() -> Self {
        DepLists::new()
    }
}

impl DepLists {
    pub fn new() -> Self {
        DepLists { items: Vec::new(), offs: vec![0] }
    }

    /// Drop all lists, keeping the allocations.
    pub fn clear(&mut self) {
        self.items.clear();
        self.offs.truncate(1);
    }

    /// Append one dependency to the currently-open list.
    pub fn push(&mut self, dep: TaskId) {
        self.items.push(dep);
    }

    /// Close the currently-open list (it becomes list `len() - 1`).
    pub fn finish_list(&mut self) {
        self.offs.push(self.items.len() as u32);
    }

    /// Append a whole list in one call.
    pub fn push_list(&mut self, deps: impl IntoIterator<Item = TaskId>) {
        self.items.extend(deps);
        self.finish_list();
    }

    /// Number of closed lists.
    pub fn len(&self) -> usize {
        self.offs.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn get(&self, j: usize) -> &[TaskId] {
        &self.items[self.offs[j] as usize..self.offs[j + 1] as usize]
    }
}

/// Word-per-64 bitmap guarding "already started" checks in the
/// per-resource queues.
#[derive(Debug)]
struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    fn with_len(n: usize) -> Self {
        BitSet { words: vec![0; n.div_ceil(64)] }
    }

    #[inline]
    fn get(&self, i: usize) -> bool {
        self.words[i >> 6] & (1u64 << (i & 63)) != 0
    }

    #[inline]
    fn set(&mut self, i: usize) {
        self.words[i >> 6] |= 1u64 << (i & 63);
    }
}

/// Task-graph builder + runner (CSR task storage, see module docs).
#[derive(Debug)]
pub struct Engine {
    n_resources: usize,
    durations: Vec<u64>,
    /// Resource sets, CSR: task `i` holds `res_arena[res_off[i]..res_off[i+1]]`.
    res_off: Vec<u32>,
    res_arena: Vec<usize>,
    /// Dependencies, CSR (same layout).
    dep_off: Vec<u32>,
    dep_arena: Vec<TaskId>,
    /// Interned label per task (labels repeat across iterations/members).
    label_of: Vec<u32>,
    label_pool: Vec<String>,
    label_index: HashMap<String, u32>,
    /// Scratch for deduping large resource sets without allocating.
    dedup_scratch: Vec<usize>,
}

impl Default for Engine {
    fn default() -> Self {
        Engine::new()
    }
}

impl Engine {
    pub fn new() -> Self {
        Engine {
            n_resources: 0,
            durations: Vec::new(),
            res_off: vec![0],
            res_arena: Vec::new(),
            dep_off: vec![0],
            dep_arena: Vec::new(),
            label_of: Vec::new(),
            label_pool: Vec::new(),
            label_index: HashMap::new(),
            dedup_scratch: Vec::new(),
        }
    }

    /// Add a single-resource task; returns its id. Dependencies must
    /// already exist (the DAG is built in topological order).
    pub fn add(&mut self, label: &str, resource: usize, duration_ns: u64,
               deps: &[TaskId]) -> TaskId {
        self.add_multi(label, &[resource], duration_ns, deps)
    }

    /// Add a task occupying every resource in `resources` at once (e.g. a
    /// message holding sender tx + receiver rx + a shared uplink).
    pub fn add_multi(&mut self, label: &str, resources: &[usize], duration_ns: u64,
                     deps: &[TaskId]) -> TaskId {
        let id = self.durations.len();
        for &d in deps {
            assert!(d < id, "dependency {d} of task {id} does not exist yet");
        }
        assert!(!resources.is_empty(), "task {id} needs at least one resource");
        // order-preserving dedup straight into the shared arena: the first
        // entry stays the home resource. Small sets (the 1-3 resource
        // common case) use an in-place window scan — no allocation, no
        // O(k^2) blowup for the rare large set, which goes through a
        // sorted scratch instead.
        let start = self.res_arena.len();
        if resources.len() <= 8 {
            for &r in resources {
                if !self.res_arena[start..].contains(&r) {
                    self.res_arena.push(r);
                }
                self.n_resources = self.n_resources.max(r + 1);
            }
        } else {
            self.dedup_scratch.clear();
            for &r in resources {
                match self.dedup_scratch.binary_search(&r) {
                    Ok(_) => {}
                    Err(pos) => {
                        self.dedup_scratch.insert(pos, r);
                        self.res_arena.push(r);
                    }
                }
                self.n_resources = self.n_resources.max(r + 1);
            }
        }
        self.res_off.push(self.res_arena.len() as u32);
        self.dep_arena.extend_from_slice(deps);
        self.dep_off.push(self.dep_arena.len() as u32);
        let lid = match self.label_index.get(label) {
            Some(&i) => i,
            None => {
                let i = self.label_pool.len() as u32;
                self.label_index.insert(label.to_string(), i);
                self.label_pool.push(label.to_string());
                i
            }
        };
        self.label_of.push(lid);
        self.durations.push(duration_ns);
        id
    }

    pub fn len(&self) -> usize {
        self.durations.len()
    }

    pub fn is_empty(&self) -> bool {
        self.durations.is_empty()
    }

    pub fn n_resources(&self) -> usize {
        self.n_resources
    }

    /// Interned label of a task (not necessarily unique — builders share
    /// labels across iterations and collective members).
    pub fn label(&self, id: TaskId) -> &str {
        &self.label_pool[self.label_of[id] as usize]
    }

    /// Full resource set of a task (home resource first).
    pub fn resources(&self, id: TaskId) -> &[usize] {
        &self.res_arena[self.res_off[id] as usize..self.res_off[id + 1] as usize]
    }

    /// Home resource (first of the resource set).
    pub fn resource(&self, id: TaskId) -> usize {
        self.res_arena[self.res_off[id] as usize]
    }

    pub fn duration_ns(&self, id: TaskId) -> u64 {
        self.durations[id]
    }

    pub fn deps(&self, id: TaskId) -> &[TaskId] {
        &self.dep_arena[self.dep_off[id] as usize..self.dep_off[id + 1] as usize]
    }

    /// Dependents of every task, CSR (built by counting sort so each
    /// task's dependents are sorted ascending — the order the dispatch
    /// tie-break relies on).
    pub(crate) fn dependents(&self) -> (Vec<u32>, Vec<TaskId>) {
        let n = self.len();
        let mut off = vec![0u32; n + 1];
        for &d in &self.dep_arena {
            off[d + 1] += 1;
        }
        for i in 0..n {
            off[i + 1] += off[i];
        }
        let mut cursor: Vec<u32> = off[..n].to_vec();
        let mut arena: Vec<TaskId> = vec![0; self.dep_arena.len()];
        for id in 0..n {
            for &d in self.deps(id) {
                arena[cursor[d] as usize] = id;
                cursor[d] += 1;
            }
        }
        (off, arena)
    }

    /// Run to completion; deterministic for a fixed task list.
    pub fn run(&self) -> Schedule {
        let n = self.len();
        let mut st = RunState::new(self);
        for id in 0..n {
            if self.deps(id).is_empty() {
                st.newly_ready.push(id);
            }
        }
        st.dispatch(self, 0);
        let (dep_off, dependents) = self.dependents();
        let mut remaining: Vec<u32> =
            (0..n).map(|id| self.deps(id).len() as u32).collect();
        let mut done = 0usize;
        while let Some(std::cmp::Reverse((t, id))) = st.events.pop() {
            done += 1;
            let lo = dep_off[id] as usize;
            let hi = dep_off[id + 1] as usize;
            for &d in &dependents[lo..hi] {
                remaining[d] -= 1;
                if remaining[d] == 0 {
                    st.newly_ready.push(d);
                }
            }
            st.dispatch(self, t);
        }
        assert_eq!(done, n, "deadlock: {done}/{n} tasks completed (cycle in DAG?)");
        let makespan = st.end.iter().copied().max().unwrap_or(0);
        Schedule { start_ns: st.start, end_ns: st.end, makespan_ns: makespan }
    }

    /// Replicate the engine's trailing `stride`-task block `copies` times,
    /// shifting every dependency by `stride` per copy — the DAG-template
    /// instancing behind the steady-state fast path. The trailing block is
    /// the template; each copy is a byte-for-byte replica (same labels,
    /// durations and resource sets, deps offset by one block), so the
    /// result is bit-identical to re-emitting the block through the
    /// builders.
    ///
    /// Two invariants are asserted because violating either would produce
    /// a DAG the builders could never have emitted:
    ///
    /// * every template dependency reaches at most one block back
    ///   (`d + stride >= base`), so the shifted copies stay well-formed;
    /// * the block *preceding* the template is structurally identical
    ///   (labels, durations, resource sets — dependency contents may
    ///   differ: the very first block has no previous iteration to gate
    ///   on), evidence that the builder really does emit a fixed-shape
    ///   block per iteration.
    pub fn instance_tail_block(&mut self, stride: usize, copies: usize) {
        let n = self.len();
        assert!(
            stride > 0 && n >= 2 * stride,
            "template instancing needs two fully built blocks ({n} tasks, stride {stride})"
        );
        let base = n - stride;
        for i in 0..stride {
            let (a, b) = (base - stride + i, base + i);
            assert_eq!(
                self.label_of[a], self.label_of[b],
                "block mismatch at offset {i}: label {:?} vs {:?}",
                self.label(a),
                self.label(b)
            );
            assert_eq!(
                self.durations[a], self.durations[b],
                "block mismatch at offset {i} ({}): durations differ",
                self.label(b)
            );
            assert_eq!(
                self.resources(a),
                self.resources(b),
                "block mismatch at offset {i} ({}): resource sets differ",
                self.label(b)
            );
        }
        for id in base..n {
            for &d in self.deps(id) {
                assert!(
                    d + stride >= base,
                    "template task {id} dep {d} reaches more than one block back"
                );
            }
        }
        for _ in 0..copies {
            let tpl = self.len() - stride;
            for i in 0..stride {
                let src = tpl + i;
                let (r_lo, r_hi) = (self.res_off[src] as usize, self.res_off[src + 1] as usize);
                self.res_arena.extend_from_within(r_lo..r_hi);
                self.res_off.push(self.res_arena.len() as u32);
                let (d_lo, d_hi) = (self.dep_off[src] as usize, self.dep_off[src + 1] as usize);
                for k in d_lo..d_hi {
                    let d = self.dep_arena[k] + stride;
                    self.dep_arena.push(d);
                }
                self.dep_off.push(self.dep_arena.len() as u32);
                self.label_of.push(self.label_of[src]);
                self.durations.push(self.durations[src]);
            }
        }
    }

    /// Structural equality of two built DAGs: same tasks, labels,
    /// durations, resource sets and dependency lists. Used by the tests
    /// proving template instancing is bit-identical to the loop build.
    pub fn same_dag(&self, other: &Engine) -> bool {
        self.n_resources == other.n_resources
            && self.durations == other.durations
            && self.res_off == other.res_off
            && self.res_arena == other.res_arena
            && self.dep_off == other.dep_off
            && self.dep_arena == other.dep_arena
            && self.label_of == other.label_of
            && self.label_pool == other.label_pool
    }
}

/// Detect a constant time shift between consecutive `stride`-task blocks
/// of a schedule: returns `Some(period_ns)` iff for every one of the
/// `blocks - 1` adjacent block pairs starting at task `first`, each
/// task's start AND end equal the corresponding task of the previous
/// block plus the same constant. This is the periodic-steady-state
/// detector: a constant shift means every per-resource busy interval
/// repeats with period `period_ns`, so later blocks can be extrapolated
/// in closed form instead of simulated.
pub fn periodic_shift(
    sched: &Schedule,
    first: TaskId,
    stride: usize,
    blocks: usize,
) -> Option<u64> {
    if stride == 0 || blocks < 2 || first + blocks * stride > sched.start_ns.len() {
        return None;
    }
    let shift = sched.start_ns[first + stride].checked_sub(sched.start_ns[first])?;
    for b in 0..blocks - 1 {
        for i in 0..stride {
            let a = first + b * stride + i;
            let c = a + stride;
            if sched.start_ns[c] != sched.start_ns[a].checked_add(shift)?
                || sched.end_ns[c] != sched.end_ns[a].checked_add(shift)?
            {
                return None;
            }
        }
    }
    Some(shift)
}

/// Mutable scheduler state of one `Engine::run` (see module docs for the
/// indexed-dispatch design).
struct RunState {
    busy_until: Vec<u64>,
    start: Vec<u64>,
    end: Vec<u64>,
    started: BitSet,
    queued: BitSet,
    /// Per-resource queues of parked (ready_time, id); entries are
    /// appended in nondecreasing key order, so each queue stays sorted.
    queue: Vec<Vec<(u64, TaskId)>>,
    qhead: Vec<usize>,
    /// Min-heap of task completion events.
    events: BinaryHeap<std::cmp::Reverse<(u64, TaskId)>>,
    /// Min-heap of (time a resource occupation ends, resource): matured
    /// entries name the only queues a dispatch needs to re-examine.
    frees: BinaryHeap<std::cmp::Reverse<(u64, u32)>>,
    // scratch reused across dispatches
    newly_ready: Vec<TaskId>,
    candidates: Vec<(u64, TaskId)>,
    /// Dedup stamp: a task may sit in several examined queues at once.
    stamp: Vec<u32>,
    round: u32,
}

impl RunState {
    fn new(eng: &Engine) -> RunState {
        let n = eng.len();
        RunState {
            busy_until: vec![0; eng.n_resources],
            start: vec![u64::MAX; n],
            end: vec![u64::MAX; n],
            started: BitSet::with_len(n),
            queued: BitSet::with_len(n),
            queue: vec![Vec::new(); eng.n_resources],
            qhead: vec![0; eng.n_resources],
            events: BinaryHeap::new(),
            frees: BinaryHeap::new(),
            newly_ready: Vec::new(),
            candidates: Vec::new(),
            stamp: vec![0; n],
            round: 0,
        }
    }

    /// Start every startable task at `now`: merge the waiters of every
    /// resource freed since the last dispatch with the newly-ready tasks,
    /// in global (ready-time, id) order, against live `busy_until` state.
    fn dispatch(&mut self, eng: &Engine, now: u64) {
        self.round += 1;
        self.candidates.clear();
        while let Some(&std::cmp::Reverse((t, r))) = self.frees.peek() {
            if t > now {
                break;
            }
            self.frees.pop();
            let r = r as usize;
            let q = &self.queue[r];
            let mut h = self.qhead[r];
            while h < q.len() && self.started.get(q[h].1) {
                h += 1;
            }
            self.qhead[r] = h;
            for &(rt, id) in &q[h..] {
                if !self.started.get(id) && self.stamp[id] != self.round {
                    self.stamp[id] = self.round;
                    self.candidates.push((rt, id));
                }
            }
        }
        for &id in &self.newly_ready {
            if self.stamp[id] != self.round {
                self.stamp[id] = self.round;
                self.candidates.push((now, id));
            }
        }
        self.newly_ready.clear();
        self.candidates.sort_unstable();
        for i in 0..self.candidates.len() {
            let id = self.candidates[i].1;
            let res = eng.resources(id);
            if res.iter().all(|&r| self.busy_until[r] <= now) {
                let e = now + eng.durations[id];
                for &r in res {
                    self.busy_until[r] = e;
                    self.frees.push(std::cmp::Reverse((e, r as u32)));
                }
                self.start[id] = now;
                self.end[id] = e;
                self.started.set(id);
                self.events.push(std::cmp::Reverse((e, id)));
            } else if !self.queued.get(id) {
                // blocked for the first time: park in every queue of its
                // resource set (pushes happen in sorted candidate order
                // at time `now`, preserving each queue's order)
                self.queued.set(id);
                for &r in res {
                    self.queue[r].push((now, id));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_chain_sums_durations() {
        let mut e = Engine::new();
        let a = e.add("a", 0, 10, &[]);
        let b = e.add("b", 0, 20, &[a]);
        let c = e.add("c", 0, 30, &[b]);
        let s = e.run();
        assert_eq!(s.end_of(c), 60);
        assert_eq!(s.makespan_ns, 60);
    }

    #[test]
    fn independent_resources_run_in_parallel() {
        let mut e = Engine::new();
        e.add("compute", 0, 100, &[]);
        e.add("comm", 1, 80, &[]);
        let s = e.run();
        assert_eq!(s.makespan_ns, 100); // overlapped, not 180
    }

    #[test]
    fn same_resource_serializes() {
        let mut e = Engine::new();
        e.add("x", 0, 100, &[]);
        e.add("y", 0, 80, &[]);
        let s = e.run();
        assert_eq!(s.makespan_ns, 180);
    }

    #[test]
    fn dependency_across_resources_creates_bubble() {
        // compute 100 -> comm 50 -> compute 10: the second compute waits.
        let mut e = Engine::new();
        let a = e.add("fwd", 0, 100, &[]);
        let c = e.add("xchg", 1, 50, &[a]);
        let b = e.add("next", 0, 10, &[c]);
        let s = e.run();
        assert_eq!(s.start_ns[b], 150);
        assert_eq!(s.makespan_ns, 160);
    }

    #[test]
    fn overlap_hides_comm_when_compute_longer() {
        // comm issued early overlaps long compute: makespan = compute.
        let mut e = Engine::new();
        let g = e.add("wtgrad", 0, 10, &[]);
        e.add("exchange", 1, 50, &[g]);
        e.add("more_compute", 0, 100, &[g]);
        let s = e.run();
        assert_eq!(s.makespan_ns, 110);
    }

    #[test]
    fn fifo_order_is_deterministic() {
        let mut e = Engine::new();
        let ids: Vec<_> = (0..10).map(|i| e.add(&format!("t{i}"), 0, 5, &[])).collect();
        let s = e.run();
        for w in ids.windows(2) {
            assert!(s.start_ns[w[0]] < s.start_ns[w[1]]);
        }
    }

    #[test]
    fn multi_resource_task_serializes_on_shared_link() {
        // two messages from different senders into the same receiver NIC:
        // the shared rx resource serializes them.
        let mut e = Engine::new();
        let a = e.add_multi("msg0->2", &[0, 10, 12], 100, &[]);
        let b = e.add_multi("msg1->2", &[1, 11, 12], 100, &[]);
        let s = e.run();
        assert_eq!(s.start_ns[a], 0);
        assert_eq!(s.start_ns[b], 100); // rx (12) busy until 100
        assert_eq!(s.makespan_ns, 200);
    }

    #[test]
    fn multi_resource_disjoint_links_run_in_parallel() {
        let mut e = Engine::new();
        e.add_multi("msg0->1", &[0, 10, 11], 100, &[]);
        e.add_multi("msg2->3", &[2, 12, 13], 100, &[]);
        let s = e.run();
        assert_eq!(s.makespan_ns, 100);
    }

    #[test]
    fn blocked_task_does_not_stall_other_resources() {
        // t0 holds link L long; t1 (ready first, wants L) waits, but t2 on
        // a different resource set starts immediately — work conserving.
        let mut e = Engine::new();
        let t0 = e.add_multi("hold", &[0, 5], 100, &[]);
        let t1 = e.add_multi("wants_link", &[1, 5], 10, &[]);
        let t2 = e.add("independent", 2, 10, &[]);
        let s = e.run();
        assert_eq!(s.start_ns[t0], 0);
        assert_eq!(s.start_ns[t2], 0);
        assert_eq!(s.start_ns[t1], 100);
    }

    #[test]
    fn duplicate_resources_deduped() {
        let mut e = Engine::new();
        let a = e.add_multi("dup", &[3, 3, 3], 50, &[]);
        let s = e.run();
        assert_eq!(s.end_of(a), 50);
        assert_eq!(e.resources(a), &[3]);
        assert_eq!(e.resource(a), 3);
    }

    #[test]
    fn large_resource_sets_dedup_in_order() {
        // > 8 entries exercises the sorted-scratch path; first occurrence
        // order (home resource first) must be preserved.
        let mut e = Engine::new();
        let a = e.add_multi("wide", &[9, 1, 9, 4, 1, 7, 4, 2, 9, 1, 3], 5, &[]);
        assert_eq!(e.resources(a), &[9, 1, 4, 7, 2, 3]);
        assert_eq!(e.resource(a), 9);
    }

    #[test]
    fn labels_are_interned_and_shared() {
        let mut e = Engine::new();
        let a = e.add("exchange", 0, 1, &[]);
        let b = e.add("exchange", 1, 1, &[]);
        let c = e.add("sgd", 0, 1, &[a]);
        assert_eq!(e.label(a), "exchange");
        assert_eq!(e.label(b), "exchange");
        assert_eq!(e.label(c), "sgd");
    }

    #[test]
    fn zero_duration_tasks_do_not_block_the_stream() {
        let mut e = Engine::new();
        let a = e.add("marker", 0, 0, &[]);
        let b = e.add("work", 0, 10, &[]);
        let s = e.run();
        assert_eq!(s.end_ns[a], 0);
        assert_eq!(s.start_ns[b], 0); // the zero-width marker left res 0 idle
        assert_eq!(s.makespan_ns, 10);
    }

    #[test]
    fn parked_task_resumes_when_last_resource_frees() {
        // t needs both 0 and 1, freed at different times; it must start
        // when the LATER one frees.
        let mut e = Engine::new();
        e.add("hold0", 0, 50, &[]);
        e.add("hold1", 1, 80, &[]);
        let t = e.add_multi("both", &[0, 1], 10, &[]);
        let s = e.run();
        assert_eq!(s.start_ns[t], 80);
        assert_eq!(s.makespan_ns, 90);
    }

    #[test]
    #[should_panic(expected = "does not exist yet")]
    fn forward_dependency_rejected() {
        let mut e = Engine::new();
        e.add("a", 0, 1, &[5]);
    }

    /// One compute + one comm task per block; comm gates the next block's
    /// compute (the fleet DAG's cross-iteration shape in miniature).
    fn two_task_block_engine(blocks: usize, loop_built: bool) -> Engine {
        let mut e = Engine::new();
        let built = if loop_built { blocks } else { 2 };
        let mut prev_x: Option<TaskId> = None;
        for _ in 0..built {
            let deps: Vec<TaskId> = prev_x.into_iter().collect();
            let f = e.add("f", 0, 30, &deps);
            prev_x = Some(e.add("x", 1, 50, &[f]));
        }
        if !loop_built && blocks > 2 {
            e.instance_tail_block(2, blocks - 2);
        }
        e
    }

    #[test]
    fn instanced_blocks_are_bit_identical_to_the_loop_build() {
        let tpl = two_task_block_engine(7, false);
        let full = two_task_block_engine(7, true);
        assert!(tpl.same_dag(&full));
        assert_eq!(tpl.run(), full.run());
    }

    #[test]
    fn instancing_shifts_dependencies_by_one_block_per_copy() {
        let e = two_task_block_engine(5, false);
        assert_eq!(e.len(), 10);
        for b in 1..5 {
            assert_eq!(e.deps(2 * b), &[2 * b - 1], "block {b} compute gate");
            assert_eq!(e.deps(2 * b + 1), &[2 * b], "block {b} comm gate");
        }
    }

    #[test]
    fn same_dag_detects_duration_and_dep_drift() {
        let a = two_task_block_engine(3, true);
        let mut b = two_task_block_engine(3, true);
        assert!(a.same_dag(&b));
        b.durations[4] += 1;
        assert!(!a.same_dag(&b));
        let mut c = Engine::new();
        let f = c.add("f", 0, 30, &[]);
        c.add("x", 1, 50, &[f]);
        assert!(!a.same_dag(&c));
    }

    #[test]
    #[should_panic(expected = "block mismatch")]
    fn instancing_rejects_a_non_repeating_tail() {
        let mut e = Engine::new();
        let a = e.add("f", 0, 30, &[]);
        let b = e.add("x", 1, 50, &[a]);
        let c = e.add("f", 0, 31, &[b]); // drifted duration
        e.add("x", 1, 50, &[c]);
        e.instance_tail_block(2, 1);
    }

    #[test]
    #[should_panic(expected = "more than one block back")]
    fn instancing_rejects_deps_reaching_past_the_previous_block() {
        let mut e = Engine::new();
        let a = e.add("f", 0, 30, &[]); // block 0
        let b = e.add("x", 1, 50, &[a]);
        let c = e.add("f", 0, 30, &[b]); // block 1
        e.add("x", 1, 50, &[c]);
        let d = e.add("f", 0, 30, &[a]); // block 2: dep reaches block 0
        e.add("x", 1, 50, &[d]);
        e.instance_tail_block(2, 1);
    }

    #[test]
    fn periodic_shift_detects_a_steady_schedule() {
        let e = two_task_block_engine(6, false);
        let s = e.run();
        // fully serial chain: every block shifts by f + x = 80ns
        assert_eq!(periodic_shift(&s, 2, 2, 4), Some(80));
        // degenerate requests are rejected, not mis-detected
        assert_eq!(periodic_shift(&s, 2, 2, 1), None);
        assert_eq!(periodic_shift(&s, 2, 0, 2), None);
        assert_eq!(periodic_shift(&s, 10, 2, 2), None); // out of range
    }

    #[test]
    fn periodic_shift_rejects_a_warmup_transient() {
        // a short warm-up task then a steady 40ns cadence: windows that
        // straddle the warm-up boundary are rejected, later ones accepted
        let mut e = Engine::new();
        let mut prev: Option<TaskId> = None;
        for (i, d) in [10u64, 40, 40, 40, 40, 40].iter().enumerate() {
            let deps: Vec<TaskId> = prev.into_iter().collect();
            prev = Some(e.add(&format!("t{i}"), 0, *d, &deps));
        }
        let s = e.run();
        assert_eq!(periodic_shift(&s, 0, 1, 3), None);
        assert_eq!(periodic_shift(&s, 1, 1, 5), Some(40));
    }

    #[test]
    fn dep_lists_recycle() {
        let mut d = DepLists::new();
        d.push_list([1, 2, 3]);
        d.push(7);
        d.finish_list();
        d.push_list([]);
        assert_eq!(d.len(), 3);
        assert_eq!(d.get(0), &[1, 2, 3]);
        assert_eq!(d.get(1), &[7]);
        assert_eq!(d.get(2), &[] as &[TaskId]);
        d.clear();
        assert!(d.is_empty());
        d.push_list([9]);
        assert_eq!(d.get(0), &[9]);
    }
}
