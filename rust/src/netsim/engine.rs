//! Deterministic discrete-event engine over a task DAG with unary
//! resources.
//!
//! Each task occupies exactly one resource (FIFO, in ready order with id
//! tie-break) for a fixed duration once all its dependencies completed.
//! This is sufficient to model the paper's per-node execution: one serial
//! compute stream plus one serial communication stream (the dedicated
//! comm thread of §4), with the command-queue handoff being the
//! compute->comm dependency edge.

use std::collections::{BinaryHeap, VecDeque};

pub type TaskId = usize;

/// A unit of work bound to one resource.
#[derive(Debug, Clone)]
pub struct Task {
    pub name: String,
    /// Index of the unary resource this task runs on.
    pub resource: usize,
    pub duration_ns: u64,
    pub deps: Vec<TaskId>,
}

/// Simulation output: per-task start/end and the makespan.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub start_ns: Vec<u64>,
    pub end_ns: Vec<u64>,
    pub makespan_ns: u64,
}

impl Schedule {
    pub fn end_of(&self, id: TaskId) -> u64 {
        self.end_ns[id]
    }
}

/// Task-graph builder + runner.
#[derive(Debug, Default)]
pub struct Engine {
    tasks: Vec<Task>,
    n_resources: usize,
}

impl Engine {
    pub fn new() -> Self {
        Engine::default()
    }

    /// Add a task; returns its id. Dependencies must already exist
    /// (the DAG is built in topological order by construction).
    pub fn add(&mut self, name: impl Into<String>, resource: usize, duration_ns: u64,
               deps: &[TaskId]) -> TaskId {
        let id = self.tasks.len();
        for &d in deps {
            assert!(d < id, "dependency {d} of task {id} does not exist yet");
        }
        self.n_resources = self.n_resources.max(resource + 1);
        self.tasks.push(Task {
            name: name.into(),
            resource,
            duration_ns,
            deps: deps.to_vec(),
        });
        id
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id]
    }

    /// Run to completion; deterministic for a fixed task list.
    pub fn run(&self) -> Schedule {
        let n = self.tasks.len();
        let mut remaining: Vec<usize> = self.tasks.iter().map(|t| t.deps.len()).collect();
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for (id, t) in self.tasks.iter().enumerate() {
            for &d in &t.deps {
                dependents[d].push(id);
            }
        }
        let mut queues: Vec<VecDeque<TaskId>> = vec![VecDeque::new(); self.n_resources];
        let mut busy_until: Vec<u64> = vec![0; self.n_resources];
        let mut start = vec![u64::MAX; n];
        let mut end = vec![u64::MAX; n];
        // min-heap of (completion_time, task_id)
        let mut events: BinaryHeap<std::cmp::Reverse<(u64, TaskId)>> = BinaryHeap::new();

        for (id, t) in self.tasks.iter().enumerate() {
            if t.deps.is_empty() {
                queues[t.resource].push_back(id);
            }
        }

        let try_start_all = |now: u64,
                                 queues: &mut Vec<VecDeque<TaskId>>,
                                 busy_until: &mut Vec<u64>,
                                 start: &mut Vec<u64>,
                                 end: &mut Vec<u64>,
                                 events: &mut BinaryHeap<std::cmp::Reverse<(u64, TaskId)>>| {
            for r in 0..self.n_resources {
                if busy_until[r] <= now {
                    if let Some(id) = queues[r].pop_front() {
                        let s = now.max(busy_until[r]);
                        let e = s + self.tasks[id].duration_ns;
                        start[id] = s;
                        end[id] = e;
                        busy_until[r] = e;
                        events.push(std::cmp::Reverse((e, id)));
                    }
                }
            }
        };

        try_start_all(0, &mut queues, &mut busy_until, &mut start, &mut end, &mut events);

        let mut done = 0usize;
        while let Some(std::cmp::Reverse((t, id))) = events.pop() {
            done += 1;
            for &d in &dependents[id] {
                remaining[d] -= 1;
                if remaining[d] == 0 {
                    queues[self.tasks[d].resource].push_back(d);
                }
            }
            try_start_all(t, &mut queues, &mut busy_until, &mut start, &mut end, &mut events);
        }
        assert_eq!(done, n, "deadlock: {done}/{n} tasks completed (cycle in DAG?)");
        let makespan = end.iter().copied().max().unwrap_or(0);
        Schedule { start_ns: start, end_ns: end, makespan_ns: makespan }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_chain_sums_durations() {
        let mut e = Engine::new();
        let a = e.add("a", 0, 10, &[]);
        let b = e.add("b", 0, 20, &[a]);
        let c = e.add("c", 0, 30, &[b]);
        let s = e.run();
        assert_eq!(s.end_of(c), 60);
        assert_eq!(s.makespan_ns, 60);
    }

    #[test]
    fn independent_resources_run_in_parallel() {
        let mut e = Engine::new();
        e.add("compute", 0, 100, &[]);
        e.add("comm", 1, 80, &[]);
        let s = e.run();
        assert_eq!(s.makespan_ns, 100); // overlapped, not 180
    }

    #[test]
    fn same_resource_serializes() {
        let mut e = Engine::new();
        e.add("x", 0, 100, &[]);
        e.add("y", 0, 80, &[]);
        let s = e.run();
        assert_eq!(s.makespan_ns, 180);
    }

    #[test]
    fn dependency_across_resources_creates_bubble() {
        // compute 100 -> comm 50 -> compute 10: the second compute waits.
        let mut e = Engine::new();
        let a = e.add("fwd", 0, 100, &[]);
        let c = e.add("xchg", 1, 50, &[a]);
        let b = e.add("next", 0, 10, &[c]);
        let s = e.run();
        assert_eq!(s.start_ns[b], 150);
        assert_eq!(s.makespan_ns, 160);
    }

    #[test]
    fn overlap_hides_comm_when_compute_longer() {
        // comm issued early overlaps long compute: makespan = compute.
        let mut e = Engine::new();
        let g = e.add("wtgrad", 0, 10, &[]);
        e.add("exchange", 1, 50, &[g]);
        e.add("more_compute", 0, 100, &[g]);
        let s = e.run();
        assert_eq!(s.makespan_ns, 110);
    }

    #[test]
    fn fifo_order_is_deterministic() {
        let mut e = Engine::new();
        let ids: Vec<_> = (0..10).map(|i| e.add(format!("t{i}"), 0, 5, &[])).collect();
        let s = e.run();
        for w in ids.windows(2) {
            assert!(s.start_ns[w[0]] < s.start_ns[w[1]]);
        }
    }

    #[test]
    #[should_panic(expected = "does not exist yet")]
    fn forward_dependency_rejected() {
        let mut e = Engine::new();
        e.add("a", 0, 1, &[5]);
    }
}
