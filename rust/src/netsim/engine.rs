//! Deterministic discrete-event engine over a task DAG with unary
//! resources.
//!
//! A task occupies one or more unary resources **simultaneously** for a
//! fixed duration once all its dependencies have completed and all its
//! resources are free. The first resource of a task is its "home" stream
//! (a node's serial compute pipeline or its dedicated communication
//! thread, the §4 software architecture); additional resources model
//! contended network links (NIC tx/rx, oversubscribed fat-tree uplinks),
//! so a message task holds its sender's injection port and its receiver's
//! ejection port for its whole flight time.
//!
//! Scheduling is work-conserving greedy in (ready-time, task-id) order:
//! when a task's dependencies complete it joins the ready set stamped
//! with that time; at every event the ready set is scanned in order and
//! every task whose full resource set is idle starts. For the
//! single-resource task graphs the representative-node simulator builds,
//! this is exactly the per-resource FIFO the previous engine implemented
//! (ready order with id tie-break), so calibrated results are unchanged.
//! Because a task acquires all of its resources atomically (no partial
//! hold-and-wait), the schedule is deadlock-free by construction, and it
//! is bit-identical across runs for a fixed task list — the determinism
//! behind Fig 5's "distributed = serial" equivalence argument.

use std::collections::{BTreeSet, BinaryHeap};

pub type TaskId = usize;

/// A unit of work bound to a set of unary resources.
#[derive(Debug, Clone)]
pub struct Task {
    pub name: String,
    /// Unary resources held simultaneously for the whole duration. The
    /// first entry is the home stream; the rest are links etc.
    pub resources: Vec<usize>,
    pub duration_ns: u64,
    pub deps: Vec<TaskId>,
}

impl Task {
    /// Home resource (first of the resource set).
    pub fn resource(&self) -> usize {
        self.resources[0]
    }
}

/// Simulation output: per-task start/end and the makespan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    pub start_ns: Vec<u64>,
    pub end_ns: Vec<u64>,
    pub makespan_ns: u64,
}

impl Schedule {
    pub fn end_of(&self, id: TaskId) -> u64 {
        self.end_ns[id]
    }
}

/// Task-graph builder + runner.
#[derive(Debug, Default)]
pub struct Engine {
    tasks: Vec<Task>,
    n_resources: usize,
}

impl Engine {
    pub fn new() -> Self {
        Engine::default()
    }

    /// Add a single-resource task; returns its id. Dependencies must
    /// already exist (the DAG is built in topological order).
    pub fn add(&mut self, name: impl Into<String>, resource: usize, duration_ns: u64,
               deps: &[TaskId]) -> TaskId {
        self.add_multi(name, &[resource], duration_ns, deps)
    }

    /// Add a task occupying every resource in `resources` at once (e.g. a
    /// message holding sender tx + receiver rx + a shared uplink).
    pub fn add_multi(&mut self, name: impl Into<String>, resources: &[usize],
                     duration_ns: u64, deps: &[TaskId]) -> TaskId {
        let id = self.tasks.len();
        for &d in deps {
            assert!(d < id, "dependency {d} of task {id} does not exist yet");
        }
        assert!(!resources.is_empty(), "task {id} needs at least one resource");
        // order-preserving dedup: the first entry stays the home resource
        let mut res: Vec<usize> = Vec::with_capacity(resources.len());
        for &r in resources {
            if !res.contains(&r) {
                res.push(r);
            }
            self.n_resources = self.n_resources.max(r + 1);
        }
        self.tasks.push(Task {
            name: name.into(),
            resources: res,
            duration_ns,
            deps: deps.to_vec(),
        });
        id
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn n_resources(&self) -> usize {
        self.n_resources
    }

    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id]
    }

    /// Run to completion; deterministic for a fixed task list.
    pub fn run(&self) -> Schedule {
        let n = self.tasks.len();
        let mut remaining: Vec<usize> = self.tasks.iter().map(|t| t.deps.len()).collect();
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for (id, t) in self.tasks.iter().enumerate() {
            for &d in &t.deps {
                dependents[d].push(id);
            }
        }
        let mut busy_until: Vec<u64> = vec![0; self.n_resources];
        let mut start = vec![u64::MAX; n];
        let mut end = vec![u64::MAX; n];
        // tasks whose deps are done, ordered by (time they became ready, id)
        let mut ready: BTreeSet<(u64, TaskId)> = BTreeSet::new();
        // min-heap of (completion_time, task_id)
        let mut events: BinaryHeap<std::cmp::Reverse<(u64, TaskId)>> = BinaryHeap::new();

        for (id, t) in self.tasks.iter().enumerate() {
            if t.deps.is_empty() {
                ready.insert((0, id));
            }
        }

        dispatch(&self.tasks, 0, &mut ready, &mut busy_until, &mut start, &mut end, &mut events);

        let mut done = 0usize;
        while let Some(std::cmp::Reverse((t, id))) = events.pop() {
            done += 1;
            for &d in &dependents[id] {
                remaining[d] -= 1;
                if remaining[d] == 0 {
                    ready.insert((t, d));
                }
            }
            dispatch(&self.tasks, t, &mut ready, &mut busy_until, &mut start, &mut end,
                     &mut events);
        }
        assert_eq!(done, n, "deadlock: {done}/{n} tasks completed (cycle in DAG?)");
        let makespan = end.iter().copied().max().unwrap_or(0);
        Schedule { start_ns: start, end_ns: end, makespan_ns: makespan }
    }
}

/// Start every ready task whose full resource set is idle at `now`,
/// scanning in (ready-time, id) order.
fn dispatch(
    tasks: &[Task],
    now: u64,
    ready: &mut BTreeSet<(u64, TaskId)>,
    busy_until: &mut [u64],
    start: &mut [u64],
    end: &mut [u64],
    events: &mut BinaryHeap<std::cmp::Reverse<(u64, TaskId)>>,
) {
    let mut started: Vec<(u64, TaskId)> = Vec::new();
    for &(ready_at, id) in ready.iter() {
        let t = &tasks[id];
        if t.resources.iter().all(|&r| busy_until[r] <= now) {
            let e = now + t.duration_ns;
            for &r in &t.resources {
                busy_until[r] = e;
            }
            start[id] = now;
            end[id] = e;
            events.push(std::cmp::Reverse((e, id)));
            started.push((ready_at, id));
        }
    }
    for key in started {
        ready.remove(&key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_chain_sums_durations() {
        let mut e = Engine::new();
        let a = e.add("a", 0, 10, &[]);
        let b = e.add("b", 0, 20, &[a]);
        let c = e.add("c", 0, 30, &[b]);
        let s = e.run();
        assert_eq!(s.end_of(c), 60);
        assert_eq!(s.makespan_ns, 60);
    }

    #[test]
    fn independent_resources_run_in_parallel() {
        let mut e = Engine::new();
        e.add("compute", 0, 100, &[]);
        e.add("comm", 1, 80, &[]);
        let s = e.run();
        assert_eq!(s.makespan_ns, 100); // overlapped, not 180
    }

    #[test]
    fn same_resource_serializes() {
        let mut e = Engine::new();
        e.add("x", 0, 100, &[]);
        e.add("y", 0, 80, &[]);
        let s = e.run();
        assert_eq!(s.makespan_ns, 180);
    }

    #[test]
    fn dependency_across_resources_creates_bubble() {
        // compute 100 -> comm 50 -> compute 10: the second compute waits.
        let mut e = Engine::new();
        let a = e.add("fwd", 0, 100, &[]);
        let c = e.add("xchg", 1, 50, &[a]);
        let b = e.add("next", 0, 10, &[c]);
        let s = e.run();
        assert_eq!(s.start_ns[b], 150);
        assert_eq!(s.makespan_ns, 160);
    }

    #[test]
    fn overlap_hides_comm_when_compute_longer() {
        // comm issued early overlaps long compute: makespan = compute.
        let mut e = Engine::new();
        let g = e.add("wtgrad", 0, 10, &[]);
        e.add("exchange", 1, 50, &[g]);
        e.add("more_compute", 0, 100, &[g]);
        let s = e.run();
        assert_eq!(s.makespan_ns, 110);
    }

    #[test]
    fn fifo_order_is_deterministic() {
        let mut e = Engine::new();
        let ids: Vec<_> = (0..10).map(|i| e.add(format!("t{i}"), 0, 5, &[])).collect();
        let s = e.run();
        for w in ids.windows(2) {
            assert!(s.start_ns[w[0]] < s.start_ns[w[1]]);
        }
    }

    #[test]
    fn multi_resource_task_serializes_on_shared_link() {
        // two messages from different senders into the same receiver NIC:
        // the shared rx resource serializes them.
        let mut e = Engine::new();
        let a = e.add_multi("msg0->2", &[0, 10, 12], 100, &[]);
        let b = e.add_multi("msg1->2", &[1, 11, 12], 100, &[]);
        let s = e.run();
        assert_eq!(s.start_ns[a], 0);
        assert_eq!(s.start_ns[b], 100); // rx (12) busy until 100
        assert_eq!(s.makespan_ns, 200);
    }

    #[test]
    fn multi_resource_disjoint_links_run_in_parallel() {
        let mut e = Engine::new();
        e.add_multi("msg0->1", &[0, 10, 11], 100, &[]);
        e.add_multi("msg2->3", &[2, 12, 13], 100, &[]);
        let s = e.run();
        assert_eq!(s.makespan_ns, 100);
    }

    #[test]
    fn blocked_task_does_not_stall_other_resources() {
        // t0 holds link L long; t1 (ready first, wants L) waits, but t2 on
        // a different resource set starts immediately — work conserving.
        let mut e = Engine::new();
        let t0 = e.add_multi("hold", &[0, 5], 100, &[]);
        let t1 = e.add_multi("wants_link", &[1, 5], 10, &[]);
        let t2 = e.add("independent", 2, 10, &[]);
        let s = e.run();
        assert_eq!(s.start_ns[t0], 0);
        assert_eq!(s.start_ns[t2], 0);
        assert_eq!(s.start_ns[t1], 100);
    }

    #[test]
    fn duplicate_resources_deduped() {
        let mut e = Engine::new();
        let a = e.add_multi("dup", &[3, 3, 3], 50, &[]);
        let s = e.run();
        assert_eq!(s.end_of(a), 50);
        assert_eq!(e.task(a).resources, vec![3]);
        assert_eq!(e.task(a).resource(), 3);
    }

    #[test]
    #[should_panic(expected = "does not exist yet")]
    fn forward_dependency_rejected() {
        let mut e = Engine::new();
        e.add("a", 0, 1, &[5]);
    }
}
