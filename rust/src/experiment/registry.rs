//! Single source of truth for name → constructor lookups.
//!
//! Before the ExperimentSpec refactor, `main.rs`, every `fig*` bench and
//! several examples each carried their own `match name { ... }` blocks
//! for models and platforms. They all route here now; unknown names list
//! what IS available, so a typo in a spec file fails with a useful error.

use anyhow::{anyhow, bail, Result};

use crate::analytic::machine::Platform;
use crate::models::{zoo, NetDescriptor};
use crate::netsim::collective::Choice;
use crate::netsim::{RecoveryPolicy, SyncMode, Topology};

fn gpt_mini() -> NetDescriptor {
    zoo::gpt_descriptor("gpt_mini", 384, 6, 128)
}

fn gpt_large() -> NetDescriptor {
    zoo::gpt_descriptor("gpt_large", 768, 12, 4096)
}

/// Model zoo: the paper's full-size topologies, the runnable tiny
/// variants matching the AOT artifacts, and the transformer configs.
pub const MODELS: &[(&str, fn() -> NetDescriptor)] = &[
    ("vgg_a", zoo::vgg_a),
    ("overfeat_fast", zoo::overfeat_fast),
    ("cddnn_full", zoo::cddnn_full),
    ("vgg_tiny", zoo::vgg_tiny),
    ("overfeat_tiny", zoo::overfeat_tiny),
    ("cddnn_tiny", zoo::cddnn_tiny),
    ("gpt_mini", gpt_mini),
    ("gpt_large", gpt_large),
];

/// The paper's evaluation platforms (§5) plus the two Table 1 columns.
pub const PLATFORMS: &[(&str, fn() -> Platform)] = &[
    ("cori", Platform::cori),
    ("aws", Platform::aws),
    ("endeavor", Platform::endeavor),
    ("table1_ethernet", Platform::table1_ethernet),
    ("table1_fdr", Platform::table1_fdr),
];

pub fn model_names() -> Vec<&'static str> {
    MODELS.iter().map(|(n, _)| *n).collect()
}

pub fn platform_names() -> Vec<&'static str> {
    PLATFORMS.iter().map(|(n, _)| *n).collect()
}

pub fn model(name: &str) -> Result<NetDescriptor> {
    for (n, f) in MODELS {
        if *n == name {
            return Ok(f());
        }
    }
    bail!("unknown model {name:?} (available: {})", model_names().join("|"))
}

pub fn platform(name: &str) -> Result<Platform> {
    for (n, f) in PLATFORMS {
        if *n == name {
            return Ok(f());
        }
    }
    bail!("unknown platform {name:?} (available: {})", platform_names().join("|"))
}

/// Fabric wiring by name; `radix`/`oversub` only matter for `fattree`.
pub fn topology(name: &str, radix: usize, oversub: f64) -> Result<Topology> {
    Ok(match name {
        "switched" | "fully_switched" => Topology::FullySwitched,
        "flat" | "flat_switch" => Topology::FlatSwitch,
        "fattree" | "fat-tree" | "fat_tree" => Topology::FatTree { radix, oversub },
        _ => bail!("unknown topology {name:?} (available: switched|flat|fattree)"),
    })
}

/// Canonical spec-file name of a topology (drops fat-tree parameters —
/// those live in their own spec fields).
pub fn topology_name(t: &Topology) -> &'static str {
    match t {
        Topology::FullySwitched => "switched",
        Topology::FlatSwitch => "flat",
        Topology::FatTree { .. } => "fattree",
    }
}

/// Parallelism-plan derivation modes (`ExperimentSpec.parallelism.mode`):
/// `hybrid` = the paper's fixed recipe, `data` = pure data parallelism,
/// `auto` = the design-point planner (`plan::planner`).
pub const PLAN_MODES: &[&str] = &["hybrid", "data", "auto"];

pub fn plan_mode(name: &str) -> Result<&'static str> {
    PLAN_MODES.iter().find(|m| **m == name).copied().ok_or_else(|| {
        anyhow!("unknown parallelism mode {name:?} (available: {})", PLAN_MODES.join("|"))
    })
}

/// Failure-recovery policies (`ExperimentSpec.cluster.recovery`):
/// `stall` = wait out detection + restart + replay and resume at N,
/// `replan` = drop to N-1 and re-derive the partition plan for the
/// degraded node count, `shrink` = drop to N-1 keeping the original
/// plan re-normalized per the §3.3 degenerate-shape rule.
pub const RECOVERY_POLICIES: &[&str] = &["stall", "replan", "shrink"];

pub fn recovery_policy(name: &str) -> Result<RecoveryPolicy> {
    Ok(match name {
        "stall" => RecoveryPolicy::Stall,
        "replan" => RecoveryPolicy::Replan,
        "shrink" => RecoveryPolicy::Shrink,
        _ => bail!(
            "unknown recovery policy {name:?} (available: {})",
            RECOVERY_POLICIES.join("|")
        ),
    })
}

pub fn recovery_policy_name(p: RecoveryPolicy) -> &'static str {
    match p {
        RecoveryPolicy::Stall => "stall",
        RecoveryPolicy::Replan => "replan",
        RecoveryPolicy::Shrink => "shrink",
    }
}

/// Synchronization modes (`ExperimentSpec.parallelism.sync`): `bsp` =
/// the paper's bulk-synchronous barrier (default, every substrate),
/// `ssp{K}` = stale-synchronous with a bounded staleness window of K
/// iterations (`ssp{0}` normalizes to `bsp` — a zero window *is* the
/// barrier), `async-ps` = fully asynchronous parameter server
/// (unbounded drift). The braces carry the window: `ssp{2}`.
pub const SYNC_MODES: &[&str] = &["bsp", "ssp{staleness}", "async-ps"];

pub fn sync_mode(name: &str) -> Result<SyncMode> {
    match name {
        "bsp" => Ok(SyncMode::Bsp),
        "async-ps" | "async_ps" => Ok(SyncMode::AsyncPs),
        other => {
            if let Some(inner) =
                other.strip_prefix("ssp{").and_then(|s| s.strip_suffix('}'))
            {
                let staleness: usize = inner.trim().parse().map_err(|_| {
                    anyhow!(
                        "sync mode {other:?}: staleness {inner:?} is not an integer \
                         (available: {})",
                        SYNC_MODES.join("|")
                    )
                })?;
                Ok(SyncMode::Ssp { staleness }.normalized())
            } else {
                bail!(
                    "unknown sync mode {name:?} (available: {})",
                    SYNC_MODES.join("|")
                )
            }
        }
    }
}

/// Canonical spec-file name of a sync mode (inverse of [`sync_mode`]).
pub fn sync_mode_name(m: SyncMode) -> String {
    match m {
        SyncMode::Bsp => "bsp".into(),
        SyncMode::Ssp { staleness } => format!("ssp{{{staleness}}}"),
        SyncMode::AsyncPs => "async-ps".into(),
    }
}

pub fn collective(name: &str) -> Result<Choice> {
    Ok(match name {
        "auto" => Choice::Auto,
        "ring" => Choice::Ring,
        "butterfly" => Choice::Butterfly,
        _ => bail!("unknown collective {name:?} (available: auto|ring|butterfly)"),
    })
}

pub fn collective_name(c: Choice) -> &'static str {
    match c {
        Choice::Auto => "auto",
        Choice::Ring => "ring",
        Choice::Butterfly => "butterfly",
    }
}

/// Manifest (runnable-artifact) model standing in for a zoo topology on
/// the PJRT runtime backend: the paper's full-size networks map to their
/// scaled runnable variants; everything else is assumed runnable as-is.
pub fn runtime_model_for(zoo_name: &str) -> &str {
    match zoo_name {
        "vgg_a" => "vgg_tiny",
        "overfeat_fast" => "overfeat_tiny",
        "cddnn_full" => "cddnn_tiny",
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_model_constructs() {
        for name in model_names() {
            let net = model(name).unwrap();
            assert!(!net.layers.is_empty(), "{name}");
        }
    }

    #[test]
    fn every_registered_platform_constructs() {
        for name in platform_names() {
            let p = platform(name).unwrap();
            assert!(p.machine.peak_gflops() > 0.0, "{name}");
        }
    }

    #[test]
    fn unknown_names_list_alternatives() {
        let e = model("vgg_b").unwrap_err().to_string();
        assert!(e.contains("vgg_a") && e.contains("cddnn_full"), "{e}");
        let e = platform("cray").unwrap_err().to_string();
        assert!(e.contains("cori") && e.contains("endeavor"), "{e}");
        let e = topology("torus", 8, 2.0).unwrap_err().to_string();
        assert!(e.contains("fattree"), "{e}");
        let e = collective("allreduce").unwrap_err().to_string();
        assert!(e.contains("butterfly"), "{e}");
    }

    #[test]
    fn topology_names_roundtrip() {
        for name in ["switched", "flat", "fattree"] {
            let t = topology(name, 4, 2.0).unwrap();
            assert_eq!(topology_name(&t), name);
        }
    }

    #[test]
    fn runtime_mapping_targets_runnable_models() {
        assert_eq!(runtime_model_for("vgg_a"), "vgg_tiny");
        assert_eq!(runtime_model_for("gpt_mini"), "gpt_mini");
    }

    #[test]
    fn recovery_policies_resolve_and_roundtrip() {
        for name in RECOVERY_POLICIES {
            let p = recovery_policy(name).unwrap();
            assert_eq!(recovery_policy_name(p), *name);
        }
        let e = recovery_policy("reboot").unwrap_err().to_string();
        assert!(e.contains("stall") && e.contains("replan") && e.contains("shrink"), "{e}");
    }

    #[test]
    fn sync_modes_parse_normalize_and_list_inventory() {
        assert_eq!(sync_mode("bsp").unwrap(), SyncMode::Bsp);
        assert_eq!(sync_mode("async-ps").unwrap(), SyncMode::AsyncPs);
        assert_eq!(sync_mode("ssp{2}").unwrap(), SyncMode::Ssp { staleness: 2 });
        // a zero staleness window IS the barrier — normalized at parse so
        // ssp{0} is bit-identical to bsp on every substrate
        assert_eq!(sync_mode("ssp{0}").unwrap(), SyncMode::Bsp);
        assert_eq!(sync_mode_name(sync_mode("ssp{3}").unwrap()), "ssp{3}");
        assert_eq!(sync_mode_name(SyncMode::Bsp), "bsp");
        assert_eq!(sync_mode_name(SyncMode::AsyncPs), "async-ps");
        for bad in ["gossip", "ssp", "ssp{}", "ssp{two}", "async"] {
            let e = sync_mode(bad).unwrap_err().to_string();
            assert!(
                e.contains("bsp") && e.contains("ssp{staleness}") && e.contains("async-ps"),
                "inventory missing for {bad}: {e}"
            );
        }
    }

    #[test]
    fn plan_modes_resolve_and_list_inventory() {
        for m in PLAN_MODES {
            assert_eq!(plan_mode(m).unwrap(), *m);
        }
        let e = plan_mode("async").unwrap_err().to_string();
        assert!(e.contains("hybrid") && e.contains("auto"), "{e}");
    }
}
