//! Unified experiment API: one declarative [`ExperimentSpec`], three
//! interchangeable substrates behind the [`Backend`] trait.
//!
//! The paper evaluates a *single* synchronous-SGD design across many
//! (model × cluster × fabric) points; this module makes each such point
//! a JSON value instead of hand-wired structs:
//!
//! * [`spec`] — the serde-able experiment description (model, platform,
//!   cluster shape, parallelism mode + explicit plan pins, collective
//!   algorithm, minibatch) with `--set`-style point overrides (flat or
//!   dotted paths). Canonical paper-figure specs live both here
//!   (builders) and committed under `specs/`. The per-layer-group
//!   `PartitionPlan` each spec implies is resolved by
//!   [`backend::partition_plan`] (mode-derived or the `crate::plan`
//!   planner's design-point search) and recorded in every report.
//! * [`registry`] — the single name → constructor table for models,
//!   platforms, topologies and collectives (formerly four copies of
//!   `match name { ... }` across the CLI, benches and examples).
//! * [`backend`] — [`AnalyticBackend`] (balance equations),
//!   [`FlowSimBackend`] (flow-level fair-share simulation, the middle
//!   fidelity tier for 1000s-of-node sweeps), [`FleetSimBackend`]
//!   (full-cluster discrete-event simulation) and [`RuntimeBackend`]
//!   (PJRT execution), all `Backend::run(spec) -> ScalingReport`.
//! * [`report`] — [`ScalingReport`], the common result schema, with a
//!   stable `BENCH_*.json`-shaped serialization pinned by CI.

pub mod backend;
pub mod registry;
pub mod report;
pub mod spec;

pub use backend::{
    backend_by_name, partition_plan, recovery_plans, resolved_platform, run_runtime,
    run_runtime_with, run_sweep, run_sweep_serial, AnalyticBackend, Backend, FleetSimBackend,
    FlowSimBackend, RuntimeBackend, BACKENDS,
};
pub use report::{curve_table, RecoveryReport, ScalingReport};
pub use spec::{
    ClusterSpec, ExecutionSpec, ExperimentSpec, MinibatchSpec, ModelSpec, ParallelismSpec,
};
