//! `ScalingReport` — the common result schema every backend returns.
//!
//! One report = one (spec, backend) run: per-node time breakdown
//! (compute vs exposed communication), throughput, speedup/efficiency
//! against the backend's own 1-node baseline, and utilization spread
//! across the fleet. Serializes to the `BENCH_*.json` object shape
//! (sorted keys, stable formatting — reports are comparable
//! bit-for-bit, which the CLI-alias equivalence test relies on).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Field names of the serialized report, sorted — the schema the CI
/// drift check (`repro schema` vs `specs/report_schema.txt`) pins down.
pub const SCHEMA_KEYS: &[&str] = &[
    "backend",
    "comm_s",
    "compute_s",
    "cycle_tasks",
    "efficiency",
    "iteration_s",
    "mean_compute_utilization",
    "min_compute_utilization",
    "minibatch",
    "model",
    "nodes",
    "overlap_frac",
    "overlap_s",
    "plan",
    "platform",
    "recovery",
    "samples_per_s",
    "sim_path",
    "spec",
    "speedup",
    "tasks",
    "warmup_tasks",
];

#[derive(Debug, Clone)]
pub struct ScalingReport {
    /// `ExperimentSpec.name` this report answers.
    pub spec_name: String,
    /// Producing backend: `analytic` | `netsim` | `runtime`.
    pub backend: String,
    pub model: String,
    pub platform: String,
    pub nodes: u64,
    pub minibatch: u64,
    /// Steady-state synchronous-SGD iteration seconds.
    pub iteration_s: f64,
    pub samples_per_s: f64,
    /// vs the same backend's 1-node run; `None` where a baseline run is
    /// not free (the runtime backend).
    pub speedup: Option<f64>,
    pub efficiency: Option<f64>,
    /// Per-node compute seconds inside one iteration.
    pub compute_s: f64,
    /// Exposed (non-overlapped) communication seconds inside one
    /// iteration — what §3.1's overlap recipe failed to hide.
    pub comm_s: f64,
    pub mean_compute_utilization: f64,
    pub min_compute_utilization: f64,
    /// Communication seconds *hidden* behind compute inside one
    /// iteration — measured comm-thread busy time minus exposed wait on
    /// the runtime backend's streaming exchange; NaN (serialized null)
    /// on backends that do not measure it.
    pub overlap_s: f64,
    /// Fraction of communication hidden behind compute:
    /// `overlap_s / (overlap_s + comm_s)`; NaN where not measured.
    pub overlap_frac: f64,
    /// Discrete-event tasks simulated (0 for closed-form/measured runs).
    /// On the periodic fast path this is the closed-form K-iteration
    /// count the run stands for, not the probe's task count.
    pub tasks: u64,
    /// Which simulation tier/path produced the numbers: `"periodic"`
    /// (netsim steady-state template fast path), `"full"` (netsim
    /// event-by-event), or `"flow"` (flowsim fair-share tier); `None`
    /// for backends without a path choice (analytic, runtime).
    pub sim_path: Option<String>,
    /// Tasks actually scheduled by the discrete-event engine before
    /// extrapolation (the warm-up + probe window on the periodic path,
    /// everything on the full path; 0 where `sim_path` is `None`).
    pub warmup_tasks: u64,
    /// Tasks per steady-state iteration (0 when a failure timeline makes
    /// iterations non-uniform, or where `sim_path` is `None`).
    pub cycle_tasks: u64,
    /// The `PartitionPlan` the run executed (its canonical JSON form),
    /// `null` where no plan applies (e.g. manifest-only runtime models).
    pub plan: Json,
    /// Failure-recovery section ([`RecoveryReport`] JSON) when the spec
    /// carried a failure event; `null` on clean runs. The simulators fill
    /// it with priced/scheduled seconds; the runtime backend fills it
    /// with wall-clock seconds measured through live fault injection —
    /// same schema, so recovery cross-checks three ways.
    pub recovery: Json,
}

fn opt_json(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

fn get_f64(j: &Json, key: &str) -> Result<f64> {
    match j.get(key)? {
        // emitted non-finite values come back as null (see util::json)
        Json::Null => Ok(f64::NAN),
        v => v.as_f64().with_context(|| format!("report field {key:?}")),
    }
}

fn get_opt(j: &Json, key: &str) -> Result<Option<f64>> {
    match j.get(key)? {
        Json::Null => Ok(None),
        v => Ok(Some(v.as_f64().with_context(|| format!("report field {key:?}"))?)),
    }
}

impl ScalingReport {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("spec".to_string(), Json::Str(self.spec_name.clone()));
        m.insert("backend".to_string(), Json::Str(self.backend.clone()));
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("platform".to_string(), Json::Str(self.platform.clone()));
        m.insert("nodes".to_string(), Json::Num(self.nodes as f64));
        m.insert("minibatch".to_string(), Json::Num(self.minibatch as f64));
        m.insert("iteration_s".to_string(), Json::Num(self.iteration_s));
        m.insert("samples_per_s".to_string(), Json::Num(self.samples_per_s));
        m.insert("speedup".to_string(), opt_json(self.speedup));
        m.insert("efficiency".to_string(), opt_json(self.efficiency));
        m.insert("compute_s".to_string(), Json::Num(self.compute_s));
        m.insert("comm_s".to_string(), Json::Num(self.comm_s));
        m.insert(
            "mean_compute_utilization".to_string(),
            Json::Num(self.mean_compute_utilization),
        );
        m.insert(
            "min_compute_utilization".to_string(),
            Json::Num(self.min_compute_utilization),
        );
        m.insert("overlap_s".to_string(), Json::Num(self.overlap_s));
        m.insert("overlap_frac".to_string(), Json::Num(self.overlap_frac));
        m.insert("tasks".to_string(), Json::Num(self.tasks as f64));
        m.insert(
            "sim_path".to_string(),
            match &self.sim_path {
                Some(p) => Json::Str(p.clone()),
                None => Json::Null,
            },
        );
        m.insert("warmup_tasks".to_string(), Json::Num(self.warmup_tasks as f64));
        m.insert("cycle_tasks".to_string(), Json::Num(self.cycle_tasks as f64));
        m.insert("plan".to_string(), self.plan.clone());
        m.insert("recovery".to_string(), self.recovery.clone());
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Self::check_schema(j)?;
        Ok(ScalingReport {
            spec_name: j.get("spec")?.as_str()?.to_string(),
            backend: j.get("backend")?.as_str()?.to_string(),
            model: j.get("model")?.as_str()?.to_string(),
            platform: j.get("platform")?.as_str()?.to_string(),
            nodes: j.get("nodes")?.as_u64()?,
            minibatch: j.get("minibatch")?.as_u64()?,
            iteration_s: get_f64(j, "iteration_s")?,
            samples_per_s: get_f64(j, "samples_per_s")?,
            speedup: get_opt(j, "speedup")?,
            efficiency: get_opt(j, "efficiency")?,
            compute_s: get_f64(j, "compute_s")?,
            comm_s: get_f64(j, "comm_s")?,
            mean_compute_utilization: get_f64(j, "mean_compute_utilization")?,
            min_compute_utilization: get_f64(j, "min_compute_utilization")?,
            overlap_s: get_f64(j, "overlap_s")?,
            overlap_frac: get_f64(j, "overlap_frac")?,
            tasks: j.get("tasks")?.as_u64()?,
            sim_path: match j.get("sim_path")? {
                Json::Null => None,
                v => Some(v.as_str().context("report field \"sim_path\"")?.to_string()),
            },
            warmup_tasks: j.get("warmup_tasks")?.as_u64()?,
            cycle_tasks: j.get("cycle_tasks")?.as_u64()?,
            plan: j.get("plan")?.clone(),
            recovery: j.get("recovery")?.clone(),
        })
    }

    /// Exact key-set check — the CI schema-drift gate.
    pub fn check_schema(j: &Json) -> Result<()> {
        let obj = j.as_obj().context("report must be a JSON object")?;
        let keys: Vec<&str> = obj.keys().map(|k| k.as_str()).collect();
        if keys != SCHEMA_KEYS {
            bail!(
                "report schema drift:\n  expected: {}\n  found:    {}",
                SCHEMA_KEYS.join(","),
                keys.join(",")
            );
        }
        Ok(())
    }

    /// Fraction of the iteration the compute stream is idle waiting on
    /// communication (the overlap shortfall).
    pub fn comm_exposed_frac(&self) -> f64 {
        if self.iteration_s > 0.0 {
            self.comm_s / self.iteration_s
        } else {
            f64::NAN
        }
    }
}

/// The failure-recovery section of a [`ScalingReport`]: what one
/// failure event cost under the spec's `cluster.recovery` policy and
/// what the fleet looked like afterwards. Every failure-capable backend
/// emits it in this shape — the netsim numbers are measured from the
/// executed schedule, the analytic ones are the α-β charges, and the
/// runtime backend's are wall-clock seconds from a live injected worker
/// death — which is what makes recovery a field-by-field three-way
/// cross-check.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryReport {
    /// `stall` | `replan` | `shrink` (registry names).
    pub policy: String,
    pub fail_at: u64,
    pub fail_node: u64,
    pub nodes_before: u64,
    /// Active nodes after the event (N for stall, N-1 otherwise).
    pub nodes_after: u64,
    /// Total disruption seconds attributable to the event (stall's full
    /// recovery window, or detection + replan + redistribution).
    pub stall_s: f64,
    /// Charged replan-coordination seconds (`replan` only; a component
    /// of `stall_s`, itemized).
    pub replan_s: f64,
    /// Charged weight-redistribution seconds (`shrink`/`replan`;
    /// likewise itemized).
    pub redistribution_s: f64,
    /// Samples/step the post-failure respread had to drop because the
    /// ABI-pinned microbatch stopped dividing the global minibatch
    /// (0 = the minibatch hyperparameter survived the event intact;
    /// uneven per-worker assignment absorbs survivor-count changes).
    pub residual_mb: u64,
    /// Post-failure steady-state iteration seconds.
    pub post_iteration_s: f64,
    pub post_samples_per_s: f64,
    /// Post-failure speedup over the backend's 1-node baseline divided
    /// by the *surviving* node count — the policy's tail throughput per
    /// remaining node.
    pub post_efficiency: f64,
    /// `PartitionPlan` JSON before and after the event.
    pub plan_before: Json,
    pub plan_after: Json,
}

/// Field names of the serialized recovery section, sorted.
pub const RECOVERY_KEYS: &[&str] = &[
    "fail_at",
    "fail_node",
    "nodes_after",
    "nodes_before",
    "plan_after",
    "plan_before",
    "policy",
    "post_efficiency",
    "post_iteration_s",
    "post_samples_per_s",
    "redistribution_s",
    "replan_s",
    "residual_mb",
    "stall_s",
];

impl RecoveryReport {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("policy".to_string(), Json::Str(self.policy.clone()));
        m.insert("fail_at".to_string(), Json::Num(self.fail_at as f64));
        m.insert("fail_node".to_string(), Json::Num(self.fail_node as f64));
        m.insert("nodes_before".to_string(), Json::Num(self.nodes_before as f64));
        m.insert("nodes_after".to_string(), Json::Num(self.nodes_after as f64));
        m.insert("stall_s".to_string(), Json::Num(self.stall_s));
        m.insert("replan_s".to_string(), Json::Num(self.replan_s));
        m.insert("redistribution_s".to_string(), Json::Num(self.redistribution_s));
        m.insert("residual_mb".to_string(), Json::Num(self.residual_mb as f64));
        m.insert("post_iteration_s".to_string(), Json::Num(self.post_iteration_s));
        m.insert("post_samples_per_s".to_string(), Json::Num(self.post_samples_per_s));
        m.insert("post_efficiency".to_string(), Json::Num(self.post_efficiency));
        m.insert("plan_before".to_string(), self.plan_before.clone());
        m.insert("plan_after".to_string(), self.plan_after.clone());
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().context("recovery section must be a JSON object")?;
        let keys: Vec<&str> = obj.keys().map(|k| k.as_str()).collect();
        if keys != RECOVERY_KEYS {
            bail!(
                "recovery schema drift:\n  expected: {}\n  found:    {}",
                RECOVERY_KEYS.join(","),
                keys.join(",")
            );
        }
        Ok(RecoveryReport {
            policy: j.get("policy")?.as_str()?.to_string(),
            fail_at: j.get("fail_at")?.as_u64()?,
            fail_node: j.get("fail_node")?.as_u64()?,
            nodes_before: j.get("nodes_before")?.as_u64()?,
            nodes_after: j.get("nodes_after")?.as_u64()?,
            stall_s: get_f64(j, "stall_s")?,
            replan_s: get_f64(j, "replan_s")?,
            redistribution_s: get_f64(j, "redistribution_s")?,
            residual_mb: j.get("residual_mb")?.as_u64()?,
            post_iteration_s: get_f64(j, "post_iteration_s")?,
            post_samples_per_s: get_f64(j, "post_samples_per_s")?,
            post_efficiency: get_f64(j, "post_efficiency")?,
            plan_before: j.get("plan_before")?.clone(),
            plan_after: j.get("plan_after")?.clone(),
        })
    }
}

/// The standard scaling-curve table (nodes, samples/s, speedup,
/// efficiency) — one shared formatter for benches, examples and docs so
/// schema changes propagate from a single place. Absent speedup /
/// efficiency (backends without a free 1-node baseline, e.g. runtime)
/// render as `—`; the JSON form keeps its `null` untouched.
pub fn curve_table(reports: &[ScalingReport]) -> crate::metrics::Table {
    let mut t = crate::metrics::Table::new(&["nodes", "samples/s", "speedup", "efficiency"]);
    for r in reports {
        t.row(vec![
            r.nodes.to_string(),
            format!("{:.0}", r.samples_per_s),
            r.speedup.map(|v| format!("{v:.1}x")).unwrap_or_else(|| "—".into()),
            r.efficiency
                .map(|v| format!("{:.0}%", 100.0 * v))
                .unwrap_or_else(|| "—".into()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ScalingReport {
        ScalingReport {
            spec_name: "fig4".into(),
            backend: "analytic".into(),
            model: "vgg_a".into(),
            platform: "cori".into(),
            nodes: 128,
            minibatch: 512,
            iteration_s: 0.204,
            samples_per_s: 2510.0,
            speedup: Some(90.1),
            efficiency: Some(0.704),
            compute_s: 0.15,
            comm_s: 0.054,
            mean_compute_utilization: 0.73,
            min_compute_utilization: 0.73,
            overlap_s: f64::NAN,
            overlap_frac: f64::NAN,
            tasks: 0,
            sim_path: None,
            warmup_tasks: 0,
            cycle_tasks: 0,
            plan: Json::Null,
            recovery: Json::Null,
        }
    }

    #[test]
    fn roundtrip_is_bit_stable() {
        let r = sample();
        let text = r.to_json().to_string();
        let back = ScalingReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), text);
        assert_eq!(back.nodes, 128);
        assert_eq!(back.speedup, Some(90.1));
    }

    #[test]
    fn optional_fields_serialize_as_null() {
        let mut r = sample();
        r.speedup = None;
        r.efficiency = None;
        let text = r.to_json().to_string();
        assert!(text.contains("\"speedup\":null"));
        let back = ScalingReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.speedup, None);
        assert_eq!(back.efficiency, None);
    }

    #[test]
    fn non_finite_values_survive_the_wire_as_nan() {
        let mut r = sample();
        r.iteration_s = f64::NAN;
        let text = r.to_json().to_string();
        assert!(text.contains("\"iteration_s\":null"), "{text}");
        let back = ScalingReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(back.iteration_s.is_nan());
    }

    #[test]
    fn sim_path_and_task_counts_roundtrip() {
        let mut r = sample();
        r.sim_path = Some("periodic".into());
        r.warmup_tasks = 3208;
        r.cycle_tasks = 802;
        r.tasks = 12832;
        let text = r.to_json().to_string();
        assert!(text.contains("\"sim_path\":\"periodic\""), "{text}");
        let back = ScalingReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.sim_path.as_deref(), Some("periodic"));
        assert_eq!(back.warmup_tasks, 3208);
        assert_eq!(back.cycle_tasks, 802);
        assert_eq!(back.to_json().to_string(), text);
        // backends without a path choice serialize the field as null
        let text = sample().to_json().to_string();
        assert!(text.contains("\"sim_path\":null"), "{text}");
        assert_eq!(ScalingReport::from_json(&Json::parse(&text).unwrap()).unwrap().sim_path, None);
    }

    #[test]
    fn overlap_fields_roundtrip_and_default_to_null() {
        // simulated backends don't measure overlap: NaN -> null
        let text = sample().to_json().to_string();
        assert!(text.contains("\"overlap_s\":null"), "{text}");
        assert!(text.contains("\"overlap_frac\":null"), "{text}");
        // the runtime backend fills measured values; they round-trip
        let mut r = sample();
        r.backend = "runtime".into();
        r.overlap_s = 0.0125;
        r.overlap_frac = 0.82;
        let text = r.to_json().to_string();
        let back = ScalingReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.overlap_s, 0.0125);
        assert_eq!(back.overlap_frac, 0.82);
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn schema_keys_are_sorted_and_match_serialization() {
        let mut sorted = SCHEMA_KEYS.to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, SCHEMA_KEYS, "SCHEMA_KEYS must stay sorted");
        ScalingReport::check_schema(&sample().to_json()).unwrap();
    }

    #[test]
    fn recovery_section_roundtrips_and_pins_its_keys() {
        let mut sorted = RECOVERY_KEYS.to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, RECOVERY_KEYS, "RECOVERY_KEYS must stay sorted");
        let rec = RecoveryReport {
            policy: "replan".into(),
            fail_at: 1,
            fail_node: 2,
            nodes_before: 32,
            nodes_after: 31,
            stall_s: 1.35,
            replan_s: 0.05,
            redistribution_s: 0.3,
            residual_mb: 0,
            post_iteration_s: 0.21,
            post_samples_per_s: 2438.0,
            post_efficiency: 0.72,
            plan_before: Json::Null,
            plan_after: Json::Null,
        };
        let text = rec.to_json().to_string();
        let back = RecoveryReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.to_json().to_string(), text);
        // a drifted key set is rejected, not silently defaulted
        let mut m = match rec.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        m.remove("stall_s");
        assert!(RecoveryReport::from_json(&Json::Obj(m)).is_err());
        // and a report carrying the section round-trips through the wire
        let mut rep = sample();
        rep.recovery = rec.to_json();
        let round = Json::parse(&rep.to_json().to_string()).unwrap();
        ScalingReport::check_schema(&round).unwrap();
        let back = ScalingReport::from_json(&round).unwrap();
        assert_eq!(RecoveryReport::from_json(&back.recovery).unwrap(), rec);
    }

    #[test]
    fn absent_table_values_render_as_dash_not_nan() {
        let mut r = sample();
        r.speedup = None;
        r.efficiency = None;
        let rendered = curve_table(&[sample(), r.clone()]).render();
        assert!(rendered.contains("90.1x") && rendered.contains("70%"), "{rendered}");
        assert!(rendered.contains("—"), "{rendered}");
        assert!(!rendered.contains("NaN"), "{rendered}");
        // the JSON form keeps null, untouched by the table fix
        assert!(r.to_json().to_string().contains("\"efficiency\":null"));
    }

    #[test]
    fn schema_drift_is_detected() {
        let mut j = match sample().to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        j.insert("extra".to_string(), Json::Num(1.0));
        assert!(ScalingReport::check_schema(&Json::Obj(j)).is_err());
    }
}
