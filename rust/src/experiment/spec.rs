//! `ExperimentSpec` — one declarative description of a scaling
//! experiment, runnable on every backend (analytic balance equations,
//! full-cluster discrete-event simulation, PJRT runtime execution).
//!
//! The JSON form is the contract: specs are committed under `specs/`
//! (one per paper figure), passed to `repro run --spec`, and overridden
//! point-wise with `--set key=value,...`. Every field has a default, so
//! a minimal spec is just `{"model": "vgg_a", "platform": "cori"}`.
//! See `DESIGN.md` ("Unified ExperimentSpec API") for the full schema.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::models::{Layer, LayerKind, NetDescriptor};
use crate::plan::PlanPin;
use crate::util::json::Json;

use super::registry;

/// Model selector: a zoo name resolved through the registry, or an
/// inline layer-by-layer `NetDescriptor` for topologies the zoo lacks.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSpec {
    Zoo(String),
    Inline(NetDescriptor),
}

impl ModelSpec {
    pub fn resolve(&self) -> Result<NetDescriptor> {
        match self {
            ModelSpec::Zoo(name) => registry::model(name),
            ModelSpec::Inline(net) => Ok(net.clone()),
        }
    }

    pub fn name(&self) -> &str {
        match self {
            ModelSpec::Zoo(name) => name,
            ModelSpec::Inline(net) => &net.name,
        }
    }
}

/// Cluster shape: size, fabric wiring, and the fleet imperfections the
/// full simulator can express (stragglers, mixed generations, failures).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub nodes: u64,
    /// `switched` | `flat` | `fattree` (registry names).
    pub topology: String,
    /// Fat-tree leaf radix (ignored elsewhere).
    pub radix: usize,
    /// Fat-tree core oversubscription (ignored elsewhere).
    pub oversub: f64,
    /// Linear per-node slowdown ramp, 0 = homogeneous.
    pub straggler_skew: f64,
    /// Odd nodes are a 30% slower older generation.
    pub hetero: bool,
    /// Fail `fail_node` at the start of this iteration (netsim backend).
    pub fail_at: Option<usize>,
    pub fail_node: usize,
    pub recovery_s: f64,
    /// Failure-recovery policy: `stall` (wait out detection + restart +
    /// replay at full N — the classic behavior) | `replan` (drop to N-1
    /// and re-derive the partition plan for the degraded node count) |
    /// `shrink` (drop to N-1 keeping the original plan re-normalized per
    /// the §3.3 degenerate-shape rule). Registry names.
    pub recovery: String,
    /// Override the platform fabric's `congestion_per_doubling` fudge.
    /// `Some(0.0)` = clean fabric, the setting under which the analytic
    /// and netsim backends must agree (cross-backend validation).
    pub congestion: Option<f64>,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            nodes: 1,
            topology: "switched".into(),
            radix: 8,
            oversub: 2.0,
            straggler_skew: 0.0,
            hetero: false,
            fail_at: None,
            fail_node: 0,
            recovery_s: 5.0,
            recovery: "stall".into(),
            congestion: None,
        }
    }
}

/// How the per-layer-group `PartitionPlan` is derived. `hybrid` is the
/// paper's fixed recipe: data parallelism on the conv trunk, per-layer
/// best of data/model/hybrid (§3.3 optimal group shape) on the FC head.
/// `data` forces pure data parallelism; `auto` runs the design-point
/// planner (`plan::planner`). Explicit per-group pins in the spec's
/// `plan` section override the derived plan either way.
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelismSpec {
    /// `hybrid` | `data` | `auto` (registry names).
    pub mode: String,
    /// Send/recv overlap achieved by the comm library (paper assumes 1).
    pub overlap: f64,
    /// Simulated iterations (steady state = last minus previous).
    pub iterations: usize,
    /// Synchronization mode: `bsp` (the paper's barrier, default) |
    /// `ssp{K}` (bounded staleness window of K iterations) | `async-ps`
    /// (fully asynchronous parameter server). Registry names
    /// (`registry::SYNC_MODES`); non-bsp modes require a pure
    /// data-parallel plan and no failure event.
    pub sync: String,
}

impl Default for ParallelismSpec {
    fn default() -> Self {
        ParallelismSpec { mode: "hybrid".into(), overlap: 1.0, iterations: 4, sync: "bsp".into() }
    }
}

/// Minibatch schedule. Today a single global size; the struct is the
/// extension point for warmup/ramp schedules (Goyal et al. 2017).
#[derive(Debug, Clone, PartialEq)]
pub struct MinibatchSpec {
    pub global: u64,
}

impl Default for MinibatchSpec {
    fn default() -> Self {
        MinibatchSpec { global: 256 }
    }
}

/// Execution knobs: the spec's default backend tier plus the fields
/// only the PJRT runtime backend consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionSpec {
    /// Default backend tier for `repro run` when `--backend` is not
    /// given: `analytic` | `flowsim` | `netsim` | `runtime`
    /// (registry names; see `backend::BACKENDS`).
    pub fidelity: String,
    /// Manifest model override (default: `registry::runtime_model_for`
    /// applied to the spec's model name).
    pub model: Option<String>,
    /// Worker count (default: `cluster.nodes`).
    pub workers: Option<usize>,
    pub steps: u64,
    pub lr: f64,
    pub momentum: f64,
    pub seed: u64,
    pub log_every: u64,
    pub eval_every: u64,
    pub optimizer: String,
    /// data-thread prefetch queue depth (microbatches staged ahead of
    /// the coordinator).
    pub prefetch: usize,
    /// async checkpoint interval in steps for the runtime backend
    /// (`None`/null = checkpointing off; `Some(0)` is rejected).
    pub checkpoint: Option<u64>,
    pub artifacts: String,
}

impl Default for ExecutionSpec {
    fn default() -> Self {
        ExecutionSpec {
            fidelity: "analytic".into(),
            model: None,
            workers: None,
            steps: 50,
            lr: 0.01,
            momentum: 0.0,
            seed: 0,
            log_every: 10,
            eval_every: 0,
            optimizer: "sgd".into(),
            prefetch: 8,
            checkpoint: None,
            artifacts: "artifacts".into(),
        }
    }
}

/// The unified experiment description (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentSpec {
    pub name: String,
    pub model: ModelSpec,
    pub platform: String,
    pub cluster: ClusterSpec,
    pub parallelism: ParallelismSpec,
    /// `auto` | `ring` | `butterfly` (registry names).
    pub collective: String,
    pub minibatch: MinibatchSpec,
    /// Explicit partition-plan pins applied on top of the mode-derived
    /// plan: layer-name-prefix -> partial assignment (`plan::PlanPin`).
    /// Empty = fully mode-derived.
    pub plan: BTreeMap<String, PlanPin>,
    pub execution: ExecutionSpec,
}

impl Default for ExperimentSpec {
    fn default() -> Self {
        ExperimentSpec {
            name: "experiment".into(),
            model: ModelSpec::Zoo("vgg_a".into()),
            platform: "cori".into(),
            cluster: ClusterSpec::default(),
            parallelism: ParallelismSpec::default(),
            collective: "auto".into(),
            minibatch: MinibatchSpec::default(),
            plan: BTreeMap::new(),
            execution: ExecutionSpec::default(),
        }
    }
}

// ---------------------------------------------------------------------
// JSON codec
// ---------------------------------------------------------------------

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn opt_num<T: Into<f64>>(v: Option<T>) -> Json {
    match v {
        Some(x) => Json::Num(x.into()),
        None => Json::Null,
    }
}

fn get_f64(obj: &Json, key: &str, default: f64) -> Result<f64> {
    match obj.opt(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_f64().with_context(|| format!("field {key:?}")),
    }
}

fn get_u64(obj: &Json, key: &str, default: u64) -> Result<u64> {
    match obj.opt(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_u64().with_context(|| format!("field {key:?}")),
    }
}

fn get_usize(obj: &Json, key: &str, default: usize) -> Result<usize> {
    match obj.opt(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_usize().with_context(|| format!("field {key:?}")),
    }
}

fn get_bool(obj: &Json, key: &str, default: bool) -> Result<bool> {
    match obj.opt(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_bool().with_context(|| format!("field {key:?}")),
    }
}

fn get_str(obj: &Json, key: &str, default: &str) -> Result<String> {
    match obj.opt(key) {
        None | Some(Json::Null) => Ok(default.to_string()),
        Some(v) => Ok(v.as_str().with_context(|| format!("field {key:?}"))?.to_string()),
    }
}

/// Reject misspelled/unknown keys: a typo must fail loudly, not run a
/// silently different experiment with defaults filled in.
fn check_keys(obj: &Json, allowed: &[&str], what: &str) -> Result<()> {
    if let Json::Obj(m) = obj {
        for k in m.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!("unknown {what} key {k:?} (expected one of: {})", allowed.join(", "));
            }
        }
    }
    Ok(())
}

/// Steady-state timing needs at least two simulated iterations (the
/// last boundary minus the previous one), so reject degenerate counts
/// at spec-build time — both JSON parse and `--set iterations=...` —
/// instead of panicking inside the simulators.
fn validate_iterations(iterations: usize) -> Result<()> {
    if iterations < 2 {
        bail!(
            "parallelism.iterations is {iterations} but must be >= 2: steady-state timing \
             is the last iteration boundary minus the previous one, so at least two \
             iterations must be simulated"
        );
    }
    Ok(())
}

/// The data thread hands microbatches to the coordinator through a
/// bounded queue; depth 0 would mean "no queue at all" and deadlock the
/// first `next()`. Rejected at spec-build time — both JSON parse and
/// `--set execution.prefetch=...` — instead of hanging the runtime.
fn validate_prefetch(prefetch: usize) -> Result<()> {
    if prefetch == 0 {
        bail!(
            "execution.prefetch is 0 but must be >= 1: the data thread stages microbatches \
             through a bounded queue of this depth, and a zero-capacity queue would stall \
             the coordinator's first fetch forever"
        );
    }
    Ok(())
}

/// One rule for the failure-injection window across every backend: the
/// failed iteration plus the recovery iteration must both land before
/// the run ends, and one post-recovery steady-state iteration must
/// remain to measure. The bound differs per backend — the simulators
/// count `parallelism.iterations`, the runtime counts
/// `execution.steps` — so callers name theirs and the error carries it.
pub fn validate_fail_window(fail_at: u64, bound: u64, bound_name: &str) -> Result<()> {
    if fail_at.saturating_add(2) > bound {
        bail!(
            "cluster.fail_at is {fail_at} but {bound_name} is {bound}: the failure needs \
             room for the recovery iteration plus one post-recovery steady-state iteration \
             (fail_at + 2 <= {bound_name}; raise {bound_name} or lower fail_at)"
        );
    }
    Ok(())
}

/// `execution.checkpoint` is an every-N-steps interval; 0 is not a
/// meaningful period ("checkpoint every zero steps") and would divide by
/// zero in the trainer's interval test. Null/absent is the way to turn
/// checkpointing off.
fn validate_checkpoint(checkpoint: Option<u64>) -> Result<()> {
    if checkpoint == Some(0) {
        bail!(
            "execution.checkpoint is 0 but must be >= 1 when set: it is the async \
             checkpoint interval in steps (omit the key or set it to null to disable \
             checkpointing)"
        );
    }
    Ok(())
}

/// A named sub-object of the spec: absent/null means "all defaults",
/// any non-object value is an error (it would otherwise be silently
/// ignored and defaulted — same failure mode as a misspelled key).
fn section<'a>(j: &'a Json, key: &str, empty: &'a Json) -> Result<&'a Json> {
    match j.opt(key) {
        None | Some(Json::Null) => Ok(empty),
        Some(o @ Json::Obj(_)) => Ok(o),
        Some(other) => bail!("\"{key}\" must be an object, got {other:?}"),
    }
}

fn layer_to_json(l: &Layer) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(l.name.clone()));
    match l.kind {
        LayerKind::Conv { ifm, ofm, k, stride, out_h, out_w, in_h, in_w } => {
            m.insert("kind".to_string(), Json::Str("conv".into()));
            m.insert("ifm".to_string(), num(ifm as f64));
            m.insert("ofm".to_string(), num(ofm as f64));
            m.insert("k".to_string(), num(k as f64));
            m.insert("stride".to_string(), num(stride as f64));
            m.insert("out_h".to_string(), num(out_h as f64));
            m.insert("out_w".to_string(), num(out_w as f64));
            m.insert("in_h".to_string(), num(in_h as f64));
            m.insert("in_w".to_string(), num(in_w as f64));
        }
        LayerKind::Fc { in_dim, out_dim } => {
            m.insert("kind".to_string(), Json::Str("fc".into()));
            m.insert("in_dim".to_string(), num(in_dim as f64));
            m.insert("out_dim".to_string(), num(out_dim as f64));
        }
        LayerKind::Pool { ch, out_h, out_w, window } => {
            m.insert("kind".to_string(), Json::Str("pool".into()));
            m.insert("ch".to_string(), num(ch as f64));
            m.insert("out_h".to_string(), num(out_h as f64));
            m.insert("out_w".to_string(), num(out_w as f64));
            m.insert("window".to_string(), num(window as f64));
        }
    }
    Json::Obj(m)
}

fn layer_from_json(j: &Json) -> Result<Layer> {
    let name = get_str(j, "name", "")?;
    if name.is_empty() {
        bail!("layer missing \"name\"");
    }
    let kind = match get_str(j, "kind", "")?.as_str() {
        "conv" => {
            check_keys(
                j,
                &["kind", "name", "ifm", "ofm", "k", "stride", "out_h", "out_w", "in_h", "in_w"],
                "conv layer",
            )?;
            LayerKind::Conv {
                ifm: j.get("ifm")?.as_u64()?,
                ofm: j.get("ofm")?.as_u64()?,
                k: j.get("k")?.as_u64()?,
                stride: get_u64(j, "stride", 1)?,
                out_h: j.get("out_h")?.as_u64()?,
                out_w: j.get("out_w")?.as_u64()?,
                in_h: j.get("in_h")?.as_u64()?,
                in_w: j.get("in_w")?.as_u64()?,
            }
        }
        "fc" => {
            check_keys(j, &["kind", "name", "in_dim", "out_dim"], "fc layer")?;
            LayerKind::Fc {
                in_dim: j.get("in_dim")?.as_u64()?,
                out_dim: j.get("out_dim")?.as_u64()?,
            }
        }
        "pool" => {
            check_keys(j, &["kind", "name", "ch", "out_h", "out_w", "window"], "pool layer")?;
            LayerKind::Pool {
                ch: j.get("ch")?.as_u64()?,
                out_h: j.get("out_h")?.as_u64()?,
                out_w: j.get("out_w")?.as_u64()?,
                window: get_u64(j, "window", 2)?,
            }
        }
        other => bail!("layer {name:?}: unknown kind {other:?} (conv|fc|pool)"),
    };
    Ok(Layer { name, kind })
}

impl ExperimentSpec {
    /// Terse constructor for the common (model, platform, nodes, MB) case.
    pub fn of(name: &str, model: &str, platform: &str, nodes: u64, minibatch: u64) -> Self {
        ExperimentSpec {
            name: name.into(),
            model: ModelSpec::Zoo(model.into()),
            platform: platform.into(),
            cluster: ClusterSpec { nodes, ..Default::default() },
            minibatch: MinibatchSpec { global: minibatch },
            ..Default::default()
        }
    }

    // ---- canonical paper-figure specs ---------------------------------
    // These builders are the single definition of each figure's
    // configuration: the committed `specs/*.json` files serialize them,
    // the CLI aliases (`repro simulate fig4` etc.) build them, and
    // `tests/experiment_api.rs` asserts all three agree bit-for-bit.

    /// Fig 4 headline point: VGG-A on Cori, 128 nodes, MB=512.
    pub fn fig4() -> Self {
        ExperimentSpec::of("fig4", "vgg_a", "cori", 128, 512)
    }

    /// Fig 6, OverFeat-FAST curve endpoint: AWS EC2, 16 nodes, MB=256.
    pub fn fig6_overfeat() -> Self {
        ExperimentSpec::of("fig6_overfeat", "overfeat_fast", "aws", 16, 256)
    }

    /// Fig 6, VGG-A curve endpoint: AWS EC2, 16 nodes, MB=256.
    pub fn fig6_vgg() -> Self {
        ExperimentSpec::of("fig6_vgg", "vgg_a", "aws", 16, 256)
    }

    /// Fig 7: CD-DNN on Endeavor, 16 nodes, MB=1024 frames.
    pub fn fig7() -> Self {
        ExperimentSpec::of("fig7", "cddnn_full", "endeavor", 16, 1024)
    }

    // ---- JSON ---------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut cluster = BTreeMap::new();
        cluster.insert("nodes".to_string(), num(self.cluster.nodes as f64));
        cluster.insert("topology".to_string(), Json::Str(self.cluster.topology.clone()));
        cluster.insert("radix".to_string(), num(self.cluster.radix as f64));
        cluster.insert("oversub".to_string(), num(self.cluster.oversub));
        cluster.insert("straggler_skew".to_string(), num(self.cluster.straggler_skew));
        cluster.insert("hetero".to_string(), Json::Bool(self.cluster.hetero));
        cluster.insert(
            "fail_at".to_string(),
            opt_num(self.cluster.fail_at.map(|v| v as f64)),
        );
        cluster.insert("fail_node".to_string(), num(self.cluster.fail_node as f64));
        cluster.insert("recovery_s".to_string(), num(self.cluster.recovery_s));
        cluster.insert("recovery".to_string(), Json::Str(self.cluster.recovery.clone()));
        cluster.insert("congestion".to_string(), opt_num(self.cluster.congestion));

        let mut par = BTreeMap::new();
        par.insert("mode".to_string(), Json::Str(self.parallelism.mode.clone()));
        par.insert("overlap".to_string(), num(self.parallelism.overlap));
        par.insert("iterations".to_string(), num(self.parallelism.iterations as f64));
        par.insert("sync".to_string(), Json::Str(self.parallelism.sync.clone()));

        let mut mb = BTreeMap::new();
        mb.insert("global".to_string(), num(self.minibatch.global as f64));

        let mut exec = BTreeMap::new();
        exec.insert("fidelity".to_string(), Json::Str(self.execution.fidelity.clone()));
        exec.insert(
            "model".to_string(),
            match &self.execution.model {
                Some(m) => Json::Str(m.clone()),
                None => Json::Null,
            },
        );
        exec.insert("workers".to_string(), opt_num(self.execution.workers.map(|v| v as f64)));
        exec.insert("steps".to_string(), num(self.execution.steps as f64));
        exec.insert("lr".to_string(), num(self.execution.lr));
        exec.insert("momentum".to_string(), num(self.execution.momentum));
        exec.insert("seed".to_string(), num(self.execution.seed as f64));
        exec.insert("log_every".to_string(), num(self.execution.log_every as f64));
        exec.insert("eval_every".to_string(), num(self.execution.eval_every as f64));
        exec.insert("optimizer".to_string(), Json::Str(self.execution.optimizer.clone()));
        exec.insert("prefetch".to_string(), num(self.execution.prefetch as f64));
        exec.insert("checkpoint".to_string(), opt_num(self.execution.checkpoint.map(|v| v as f64)));
        exec.insert("artifacts".to_string(), Json::Str(self.execution.artifacts.clone()));

        let model = match &self.model {
            ModelSpec::Zoo(name) => Json::Str(name.clone()),
            ModelSpec::Inline(net) => {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(net.name.clone()));
                m.insert(
                    "layers".to_string(),
                    Json::Arr(net.layers.iter().map(layer_to_json).collect()),
                );
                Json::Obj(m)
            }
        };

        let plan = if self.plan.is_empty() {
            Json::Null
        } else {
            Json::Obj(self.plan.iter().map(|(k, p)| (k.clone(), p.to_json())).collect())
        };

        let mut root = BTreeMap::new();
        root.insert("name".to_string(), Json::Str(self.name.clone()));
        root.insert("model".to_string(), model);
        root.insert("platform".to_string(), Json::Str(self.platform.clone()));
        root.insert("cluster".to_string(), Json::Obj(cluster));
        root.insert("parallelism".to_string(), Json::Obj(par));
        root.insert("collective".to_string(), Json::Str(self.collective.clone()));
        root.insert("minibatch".to_string(), Json::Obj(mb));
        root.insert("plan".to_string(), plan);
        root.insert("execution".to_string(), Json::Obj(exec));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        j.as_obj().context("spec must be a JSON object")?;
        let d = ExperimentSpec::default();
        check_keys(
            j,
            &[
                "name", "model", "platform", "cluster", "parallelism", "collective",
                "minibatch", "plan", "execution",
            ],
            "spec",
        )?;
        let model = match j.opt("model") {
            None | Some(Json::Null) => d.model.clone(),
            Some(Json::Str(name)) => ModelSpec::Zoo(name.clone()),
            Some(inline @ Json::Obj(_)) => {
                check_keys(inline, &["name", "layers"], "inline model")?;
                let name = get_str(inline, "name", "inline")?;
                let layers: Result<Vec<Layer>> =
                    inline.get("layers")?.as_arr()?.iter().map(layer_from_json).collect();
                let layers = layers.context("inline model layers")?;
                if layers.is_empty() {
                    bail!("inline model {name:?} has no layers");
                }
                ModelSpec::Inline(NetDescriptor { name, layers })
            }
            Some(other) => bail!("\"model\" must be a zoo name or inline object, got {other:?}"),
        };

        let empty = Json::Obj(BTreeMap::new());
        let c = section(j, "cluster", &empty)?;
        check_keys(
            c,
            &[
                "nodes", "topology", "radix", "oversub", "straggler_skew", "hetero",
                "fail_at", "fail_node", "recovery_s", "recovery", "congestion",
            ],
            "cluster",
        )?;
        let cluster = ClusterSpec {
            nodes: get_u64(c, "nodes", d.cluster.nodes)?,
            topology: get_str(c, "topology", &d.cluster.topology)?,
            radix: get_usize(c, "radix", d.cluster.radix)?,
            oversub: get_f64(c, "oversub", d.cluster.oversub)?,
            straggler_skew: get_f64(c, "straggler_skew", d.cluster.straggler_skew)?,
            hetero: get_bool(c, "hetero", d.cluster.hetero)?,
            fail_at: match c.opt("fail_at") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_usize().context("field \"fail_at\"")?),
            },
            fail_node: get_usize(c, "fail_node", d.cluster.fail_node)?,
            recovery_s: get_f64(c, "recovery_s", d.cluster.recovery_s)?,
            recovery: get_str(c, "recovery", &d.cluster.recovery)?,
            congestion: match c.opt("congestion") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().context("field \"congestion\"")?),
            },
        };

        // validate registry names early: a typo'd topology/collective/
        // recovery policy must fail at parse time, not only when the
        // netsim backend first consumes it (the analytic backend's spec
        // job would otherwise let a typo'd committed spec through)
        registry::topology(&cluster.topology, cluster.radix, cluster.oversub)?;
        registry::recovery_policy(&cluster.recovery)?;

        let p = section(j, "parallelism", &empty)?;
        check_keys(p, &["mode", "overlap", "iterations", "sync"], "parallelism")?;
        let parallelism = ParallelismSpec {
            mode: get_str(p, "mode", &d.parallelism.mode)?,
            overlap: get_f64(p, "overlap", d.parallelism.overlap)?,
            iterations: get_usize(p, "iterations", d.parallelism.iterations)?,
            sync: get_str(p, "sync", &d.parallelism.sync)?,
        };
        registry::plan_mode(&parallelism.mode)?; // validate early
        registry::sync_mode(&parallelism.sync)?; // validate early
        validate_iterations(parallelism.iterations)?;

        let minibatch = match j.opt("minibatch") {
            None | Some(Json::Null) => d.minibatch.clone(),
            // shorthand: "minibatch": 512
            Some(n @ Json::Num(_)) => MinibatchSpec { global: n.as_u64()? },
            Some(m @ Json::Obj(_)) => {
                check_keys(m, &["global"], "minibatch")?;
                MinibatchSpec { global: get_u64(m, "global", d.minibatch.global)? }
            }
            Some(other) => bail!("\"minibatch\" must be a number or object, got {other:?}"),
        };

        let e = section(j, "execution", &empty)?;
        check_keys(
            e,
            &[
                "fidelity", "model", "workers", "steps", "lr", "momentum", "seed",
                "log_every", "eval_every", "optimizer", "prefetch", "checkpoint", "artifacts",
            ],
            "execution",
        )?;
        let execution = ExecutionSpec {
            fidelity: get_str(e, "fidelity", &d.execution.fidelity)?,
            model: match e.opt("model") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_str().context("field execution.model")?.to_string()),
            },
            workers: match e.opt("workers") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_usize().context("field execution.workers")?),
            },
            steps: get_u64(e, "steps", d.execution.steps)?,
            lr: get_f64(e, "lr", d.execution.lr)?,
            momentum: get_f64(e, "momentum", d.execution.momentum)?,
            seed: get_u64(e, "seed", d.execution.seed)?,
            log_every: get_u64(e, "log_every", d.execution.log_every)?,
            eval_every: get_u64(e, "eval_every", d.execution.eval_every)?,
            optimizer: get_str(e, "optimizer", &d.execution.optimizer)?,
            prefetch: get_u64(e, "prefetch", d.execution.prefetch as u64)? as usize,
            checkpoint: match e.opt("checkpoint") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_u64().context("field execution.checkpoint")?),
            },
            artifacts: get_str(e, "artifacts", &d.execution.artifacts)?,
        };
        validate_prefetch(execution.prefetch)?;
        validate_checkpoint(execution.checkpoint)?;

        // fidelity is a backend-registry name; validate at parse time
        // like every other registry name
        super::backend::backend_by_name(&execution.fidelity)
            .context("field execution.fidelity")?;

        // one fail-at window rule for every backend, checked against the
        // bound the spec's own fidelity will enforce (the backends
        // re-check through the same helper at run time, since --backend
        // can override the fidelity)
        if let Some(at) = cluster.fail_at {
            let (bound, bound_name) = if execution.fidelity == "runtime" {
                (execution.steps, "execution.steps")
            } else {
                (parallelism.iterations as u64, "parallelism.iterations")
            };
            validate_fail_window(at as u64, bound, bound_name)?;
        }

        let collective = get_str(j, "collective", &d.collective)?;
        registry::collective(&collective)?; // validate early

        // explicit partition-plan pins (strategy/collective names are
        // validated by PlanPin::from_json; prefix matching against the
        // model's layers happens when the plan is resolved)
        let plan = match j.opt("plan") {
            None | Some(Json::Null) => BTreeMap::new(),
            Some(Json::Obj(m)) => {
                let mut out = BTreeMap::new();
                for (k, v) in m {
                    out.insert(
                        k.clone(),
                        PlanPin::from_json(v).with_context(|| format!("plan.{k}"))?,
                    );
                }
                out
            }
            Some(other) => bail!("\"plan\" must be an object of layer-group pins, got {other:?}"),
        };

        Ok(ExperimentSpec {
            name: get_str(j, "name", &d.name)?,
            model,
            platform: get_str(j, "platform", &d.platform)?,
            cluster,
            parallelism,
            collective,
            minibatch,
            plan,
            execution,
        })
    }

    pub fn parse_str(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text).context("spec is not valid JSON")?)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("cannot read spec file {path:?}"))?;
        Self::parse_str(&text).with_context(|| format!("spec file {path:?}"))
    }

    // ---- point overrides ----------------------------------------------

    /// Apply comma-separated `key=value` overrides (the CLI's `--set`).
    /// Keys are flat aliases into the nested spec
    /// (`nodes=64,minibatch=512,topology=fattree`) or dotted paths into
    /// its sections (`cluster.nodes=64`, `parallelism.mode=data`,
    /// `minibatch.global=512`, `execution.steps=100`) including
    /// partition-plan pins (`plan.fc.groups=8`,
    /// `plan.fc8.strategy=data`). Unknown keys and paths fail listing
    /// what IS available.
    pub fn apply_set(&mut self, assignments: &str) -> Result<()> {
        for kv in assignments.split(',').filter(|s| !s.is_empty()) {
            let (key, value) = kv
                .split_once('=')
                .ok_or_else(|| anyhow!("--set entry {kv:?} is not key=value"))?;
            let (key, value) = (key.trim(), value.trim());
            match key.split_once('.') {
                Some((section, rest)) => self.set_path(section, rest, value)?,
                None => self.set_flat(key, value)?,
            }
        }
        Ok(())
    }

    /// Dotted-path `--set`: `<section>.<field>` for the spec sections and
    /// `plan.<group>.<field>` for partition-plan pins.
    fn set_path(&mut self, section: &str, rest: &str, value: &str) -> Result<()> {
        const CLUSTER_KEYS: &[&str] = &[
            "nodes", "topology", "radix", "oversub", "straggler_skew", "hetero", "fail_at",
            "fail_node", "recovery_s", "recovery", "congestion",
        ];
        const PARALLELISM_KEYS: &[&str] = &["mode", "overlap", "iterations", "sync"];
        const EXECUTION_KEYS: &[&str] = &[
            "fidelity", "model", "workers", "steps", "lr", "momentum", "seed", "log_every",
            "eval_every", "optimizer", "prefetch", "checkpoint", "artifacts",
        ];
        match section {
            "cluster" => {
                if !CLUSTER_KEYS.contains(&rest) {
                    bail!(
                        "unknown --set key cluster.{rest} (available: {})",
                        CLUSTER_KEYS.join(", ")
                    );
                }
                self.set_flat(rest, value)
            }
            "parallelism" => {
                if !PARALLELISM_KEYS.contains(&rest) {
                    bail!(
                        "unknown --set key parallelism.{rest} (available: {})",
                        PARALLELISM_KEYS.join(", ")
                    );
                }
                self.set_flat(rest, value)
            }
            "minibatch" => {
                if rest != "global" {
                    bail!("unknown --set key minibatch.{rest} (available: global)");
                }
                self.set_flat("minibatch", value)
            }
            "execution" => {
                if !EXECUTION_KEYS.contains(&rest) {
                    bail!(
                        "unknown --set key execution.{rest} (available: {})",
                        EXECUTION_KEYS.join(", ")
                    );
                }
                if rest == "model" {
                    self.execution.model = Some(value.into());
                    Ok(())
                } else {
                    self.set_flat(rest, value)
                }
            }
            "plan" => {
                let (group, field) = rest.split_once('.').ok_or_else(|| {
                    anyhow!(
                        "--set plan.<group>.<field>=... (fields: {})",
                        crate::plan::PIN_FIELDS.join(", ")
                    )
                })?;
                if group.is_empty() {
                    bail!("--set plan.<group>.<field>: empty group name");
                }
                // mutate a copy and insert only once it validates, so a
                // failed --set cannot leave an invalid or phantom pin
                let mut pin = self.plan.get(group).cloned().unwrap_or_default();
                match field {
                    "strategy" => pin.strategy = Some(value.to_string()),
                    "groups" => {
                        pin.groups = Some(value.parse().map_err(|_| {
                            anyhow!("--set plan.{group}.groups={value}: not an integer")
                        })?)
                    }
                    "collective" => {
                        registry::collective(value)?;
                        pin.collective = Some(value.to_string())
                    }
                    "overlap" => {
                        pin.overlap = Some(value.parse().map_err(|_| {
                            anyhow!("--set plan.{group}.overlap={value}: not a number")
                        })?)
                    }
                    other => bail!(
                        "unknown --set key plan.{group}.{other} (available: {})",
                        crate::plan::PIN_FIELDS.join(", ")
                    ),
                }
                pin.validate()?;
                self.plan.insert(group.to_string(), pin);
                Ok(())
            }
            other => bail!(
                "unknown --set section {other:?} (available: cluster, parallelism, minibatch, \
                 execution, plan — e.g. cluster.nodes=64, plan.fc.groups=8)"
            ),
        }
    }

    /// Flat `--set` aliases into the nested spec.
    fn set_flat(&mut self, key: &str, value: &str) -> Result<()> {
        fn parsed<T: std::str::FromStr>(key: &str, value: &str) -> Result<T> {
            value.parse::<T>().map_err(|_| {
                anyhow!(
                    "--set {key}={value}: cannot parse as {}",
                    std::any::type_name::<T>()
                )
            })
        }
        match key {
                "name" => self.name = value.into(),
                "model" => self.model = ModelSpec::Zoo(value.into()),
                "platform" => self.platform = value.into(),
                "nodes" => self.cluster.nodes = parsed(key, value)?,
                "topology" => {
                    registry::topology(value, self.cluster.radix, self.cluster.oversub)?;
                    self.cluster.topology = value.into()
                }
                "radix" => self.cluster.radix = parsed(key, value)?,
                "oversub" => self.cluster.oversub = parsed(key, value)?,
                "straggler_skew" | "straggler-skew" => {
                    self.cluster.straggler_skew = parsed(key, value)?
                }
                "hetero" => {
                    self.cluster.hetero = match value {
                        "true" | "1" | "yes" => true,
                        "false" | "0" | "no" => false,
                        _ => bail!("--set hetero={value}: expected true|false"),
                    }
                }
                "fail_at" | "fail-at" => {
                    self.cluster.fail_at =
                        if value == "none" { None } else { Some(parsed(key, value)?) }
                }
                "fail_node" | "fail-node" => self.cluster.fail_node = parsed(key, value)?,
                "recovery_s" => self.cluster.recovery_s = parsed(key, value)?,
                "recovery" => {
                    // this key used to alias recovery_s; steer anyone
                    // still passing seconds to the renamed knob
                    registry::recovery_policy(value).map_err(|e| {
                        if value.parse::<f64>().is_ok() {
                            anyhow!(
                                "--set recovery={value}: \"recovery\" is now the policy \
                                 (stall|replan|shrink); use recovery_s={value} for the \
                                 recovery-seconds knob"
                            )
                        } else {
                            e
                        }
                    })?;
                    self.cluster.recovery = value.into()
                }
                "congestion" => {
                    self.cluster.congestion =
                        if value == "none" { None } else { Some(parsed(key, value)?) }
                }
                "mode" => {
                    registry::plan_mode(value)?;
                    self.parallelism.mode = value.into()
                }
                "sync" => {
                    registry::sync_mode(value)?;
                    self.parallelism.sync = value.into()
                }
                "overlap" => self.parallelism.overlap = parsed(key, value)?,
                "iterations" => {
                    let it: usize = parsed(key, value)?;
                    validate_iterations(it)?;
                    self.parallelism.iterations = it
                }
                "collective" => {
                    registry::collective(value)?;
                    self.collective = value.into()
                }
                "minibatch" | "mb" => self.minibatch.global = parsed(key, value)?,
                "fidelity" => {
                    super::backend::backend_by_name(value)?;
                    self.execution.fidelity = value.into()
                }
                "exec_model" => self.execution.model = Some(value.into()),
                "workers" => self.execution.workers = Some(parsed(key, value)?),
                "steps" => self.execution.steps = parsed(key, value)?,
                "lr" => self.execution.lr = parsed(key, value)?,
                "momentum" => self.execution.momentum = parsed(key, value)?,
                "seed" => self.execution.seed = parsed(key, value)?,
                "log_every" => self.execution.log_every = parsed(key, value)?,
                "eval_every" => self.execution.eval_every = parsed(key, value)?,
                "optimizer" => self.execution.optimizer = value.into(),
                "prefetch" => {
                    let p: usize = parsed(key, value)?;
                    validate_prefetch(p)?;
                    self.execution.prefetch = p
                }
                "checkpoint" => {
                    self.execution.checkpoint = if value == "none" || value == "null" {
                        None
                    } else {
                        let c: u64 = parsed(key, value)?;
                        validate_checkpoint(Some(c))?;
                        Some(c)
                    }
                }
                "artifacts" => self.execution.artifacts = value.into(),
                other => bail!(
                    "unknown --set key {other:?} (nodes, minibatch, model, platform, topology, \
                     radix, oversub, straggler_skew, hetero, fail_at, fail_node, recovery_s, \
                     recovery, congestion, mode, sync, overlap, iterations, collective, fidelity, \
                     workers, steps, lr, momentum, seed, log_every, eval_every, optimizer, \
                     prefetch, checkpoint, artifacts, exec_model, name — or a dotted path like \
                     cluster.nodes, parallelism.mode, minibatch.global, execution.fidelity, \
                     execution.steps, plan.<group>.<field>)"
                ),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_preserves_all_fields() {
        let mut s = ExperimentSpec::fig4();
        s.cluster.topology = "fattree".into();
        s.cluster.oversub = 4.0;
        s.cluster.straggler_skew = 0.25;
        s.cluster.hetero = true;
        s.cluster.fail_at = Some(2);
        s.cluster.recovery = "replan".into();
        s.cluster.congestion = Some(0.0);
        s.parallelism.mode = "data".into();
        s.parallelism.sync = "ssp{2}".into();
        s.collective = "ring".into();
        s.execution.workers = Some(4);
        s.execution.model = Some("vgg_tiny".into());
        s.execution.fidelity = "flowsim".into();
        s.execution.checkpoint = Some(3);
        let j = s.to_json();
        let back = ExperimentSpec::from_json(&j).unwrap();
        assert_eq!(s, back);
        // and through text + pretty-printer too
        assert_eq!(ExperimentSpec::parse_str(&j.to_string()).unwrap(), s);
        assert_eq!(ExperimentSpec::parse_str(&j.pretty()).unwrap(), s);
    }

    #[test]
    fn minimal_spec_gets_defaults() {
        let s = ExperimentSpec::parse_str(r#"{"model": "cddnn_full", "platform": "endeavor"}"#)
            .unwrap();
        assert_eq!(s.model, ModelSpec::Zoo("cddnn_full".into()));
        assert_eq!(s.cluster.nodes, 1);
        assert_eq!(s.minibatch.global, 256);
        assert_eq!(s.parallelism.mode, "hybrid");
        assert_eq!(s.collective, "auto");
    }

    #[test]
    fn minibatch_shorthand_number() {
        let s = ExperimentSpec::parse_str(r#"{"minibatch": 512}"#).unwrap();
        assert_eq!(s.minibatch.global, 512);
    }

    #[test]
    fn inline_model_roundtrips_and_resolves() {
        let net = NetDescriptor::new(
            "toy",
            vec![
                Layer::conv("c1", 3, 16, 3, 1, 32, 32),
                Layer::pool("p1", 16, 16),
                Layer::fc("f1", 4096, 10),
            ],
        );
        let s = ExperimentSpec {
            model: ModelSpec::Inline(net.clone()),
            ..Default::default()
        };
        let back = ExperimentSpec::parse_str(&s.to_json().to_string()).unwrap();
        assert_eq!(back.model.resolve().unwrap(), net);
        assert_eq!(back.model.name(), "toy");
    }

    #[test]
    fn apply_set_overrides_nested_fields() {
        let mut s = ExperimentSpec::fig4();
        s.apply_set("nodes=64,minibatch=256,topology=fattree,oversub=4,collective=ring,mode=data")
            .unwrap();
        assert_eq!(s.cluster.nodes, 64);
        assert_eq!(s.minibatch.global, 256);
        assert_eq!(s.cluster.topology, "fattree");
        assert_eq!(s.cluster.oversub, 4.0);
        assert_eq!(s.collective, "ring");
        assert_eq!(s.parallelism.mode, "data");
    }

    #[test]
    fn apply_set_dotted_paths_reach_nested_fields() {
        let mut s = ExperimentSpec::fig4();
        s.apply_set(
            "cluster.nodes=64,parallelism.mode=data,minibatch.global=256,execution.steps=7",
        )
        .unwrap();
        assert_eq!(s.cluster.nodes, 64);
        assert_eq!(s.parallelism.mode, "data");
        assert_eq!(s.minibatch.global, 256);
        assert_eq!(s.execution.steps, 7);
        s.apply_set("cluster.straggler_skew=0.25,execution.model=vgg_tiny").unwrap();
        assert_eq!(s.cluster.straggler_skew, 0.25);
        assert_eq!(s.execution.model.as_deref(), Some("vgg_tiny"));
    }

    #[test]
    fn apply_set_plan_pins_accumulate() {
        let mut s = ExperimentSpec::fig4();
        s.apply_set("plan.fc.groups=8,plan.fc.collective=ring,plan.fc8.strategy=data")
            .unwrap();
        let fc = &s.plan["fc"];
        assert_eq!(fc.groups, Some(8));
        assert_eq!(fc.collective.as_deref(), Some("ring"));
        assert_eq!(s.plan["fc8"].strategy.as_deref(), Some("data"));
        // plan pins survive the JSON round trip
        let back = ExperimentSpec::parse_str(&s.to_json().to_string()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn dotted_inventories_stay_in_sync_with_flat_setters() {
        // every key the dotted-path allowlists advertise must actually be
        // settable — guards the section consts against drifting from the
        // set_flat match arms
        let cases = [
            ("cluster", "nodes", "4"),
            ("cluster", "topology", "flat"),
            ("cluster", "radix", "4"),
            ("cluster", "oversub", "2"),
            ("cluster", "straggler_skew", "0.1"),
            ("cluster", "hetero", "true"),
            ("cluster", "fail_at", "1"),
            ("cluster", "fail_node", "0"),
            ("cluster", "recovery_s", "2.5"),
            ("cluster", "recovery", "shrink"),
            ("cluster", "congestion", "0"),
            ("parallelism", "mode", "data"),
            ("parallelism", "sync", "ssp{2}"),
            ("parallelism", "overlap", "0.5"),
            ("parallelism", "iterations", "3"),
            ("minibatch", "global", "64"),
            ("execution", "fidelity", "flowsim"),
            ("execution", "model", "vgg_tiny"),
            ("execution", "workers", "2"),
            ("execution", "steps", "5"),
            ("execution", "lr", "0.1"),
            ("execution", "momentum", "0.9"),
            ("execution", "seed", "7"),
            ("execution", "log_every", "1"),
            ("execution", "eval_every", "2"),
            ("execution", "optimizer", "adam"),
            ("execution", "prefetch", "4"),
            ("execution", "checkpoint", "3"),
            ("execution", "artifacts", "art"),
        ];
        let mut s = ExperimentSpec::default();
        for (section, key, value) in cases {
            s.apply_set(&format!("{section}.{key}={value}"))
                .unwrap_or_else(|e| panic!("{section}.{key}: {e:#}"));
        }
    }

    #[test]
    fn apply_set_unknown_paths_list_available_keys() {
        let mut s = ExperimentSpec::default();
        let e = format!("{:#}", s.apply_set("cluster.nodez=4").unwrap_err());
        assert!(e.contains("straggler_skew") && e.contains("topology"), "{e}");
        let e = format!("{:#}", s.apply_set("parallelism.modes=data").unwrap_err());
        assert!(e.contains("mode") && e.contains("iterations"), "{e}");
        let e = format!("{:#}", s.apply_set("plan.fc.group=8").unwrap_err());
        assert!(e.contains("groups") && e.contains("strategy"), "{e}");
        let e = format!("{:#}", s.apply_set("orchestra.tempo=4").unwrap_err());
        assert!(e.contains("cluster") && e.contains("plan"), "{e}");
        // a pin missing its field errors too
        assert!(s.apply_set("plan.fc=8").is_err());
        // bad pin values are rejected by the pin's own validation, and a
        // failed --set must not leave an invalid or phantom pin behind
        assert!(s.apply_set("plan.fc.strategy=async").is_err());
        assert!(s.apply_set("plan.fc2.collective=nccl").is_err());
        assert!(s.plan.is_empty(), "failed --set left pins: {:?}", s.plan);
    }

    #[test]
    fn apply_set_rejects_unknown_keys_and_bad_values() {
        let mut s = ExperimentSpec::default();
        assert!(s.apply_set("frobnicate=1").is_err());
        assert!(s.apply_set("nodes=many").is_err());
        assert!(s.apply_set("nodes").is_err());
    }

    #[test]
    fn invalid_mode_is_rejected_at_parse_time() {
        let e = ExperimentSpec::parse_str(r#"{"parallelism": {"mode": "async"}}"#);
        assert!(e.is_err());
    }

    #[test]
    fn sync_mode_validates_at_parse_and_set_time_with_inventory() {
        // absent key defaults to the barrier — the bit-identity contract
        let s = ExperimentSpec::parse_str(r#"{"model": "vgg_a"}"#).unwrap();
        assert_eq!(s.parallelism.sync, "bsp");
        let s =
            ExperimentSpec::parse_str(r#"{"parallelism": {"sync": "async-ps"}}"#).unwrap();
        assert_eq!(s.parallelism.sync, "async-ps");
        // unknown values list the inventory at parse time...
        let e = ExperimentSpec::parse_str(r#"{"parallelism": {"sync": "gossip"}}"#)
            .unwrap_err();
        let msg = format!("{e:#}");
        assert!(
            msg.contains("bsp") && msg.contains("ssp{staleness}") && msg.contains("async-ps"),
            "{msg}"
        );
        // ...and at --set time, via both the flat alias and dotted path
        let mut s = ExperimentSpec::default();
        let e = format!("{:#}", s.apply_set("sync=gossip").unwrap_err());
        assert!(e.contains("ssp{staleness}") && e.contains("async-ps"), "{e}");
        let e = format!("{:#}", s.apply_set("parallelism.sync=ssp{nine}").unwrap_err());
        assert!(e.contains("ssp{staleness}"), "{e}");
        s.apply_set("sync=ssp{3}").unwrap();
        assert_eq!(s.parallelism.sync, "ssp{3}");
        s.apply_set("parallelism.sync=bsp").unwrap();
        assert_eq!(s.parallelism.sync, "bsp");
    }

    #[test]
    fn fail_window_is_one_rule_with_backend_specific_bounds() {
        // simulators: fail_at + 2 <= parallelism.iterations
        let e = ExperimentSpec::parse_str(
            r#"{"cluster": {"fail_at": 3}, "parallelism": {"iterations": 4}}"#,
        )
        .unwrap_err();
        let msg = format!("{e:#}");
        assert!(
            msg.contains("fail_at") && msg.contains("parallelism.iterations"),
            "{msg}"
        );
        assert!(ExperimentSpec::parse_str(
            r#"{"cluster": {"fail_at": 2}, "parallelism": {"iterations": 4}}"#
        )
        .is_ok());
        // runtime fidelity: the bound is execution.steps instead
        let e = ExperimentSpec::parse_str(
            r#"{"cluster": {"fail_at": 9}, "execution": {"fidelity": "runtime", "steps": 10}}"#,
        )
        .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("fail_at") && msg.contains("execution.steps"), "{msg}");
        assert!(ExperimentSpec::parse_str(
            r#"{"cluster": {"fail_at": 8}, "execution": {"fidelity": "runtime", "steps": 10}}"#
        )
        .is_ok());
        // the helper itself is the shared rule
        assert!(validate_fail_window(2, 4, "parallelism.iterations").is_ok());
        let e = validate_fail_window(3, 4, "execution.steps").unwrap_err().to_string();
        assert!(e.contains("execution.steps") && e.contains("fail_at + 2"), "{e}");
    }

    #[test]
    fn degenerate_iteration_counts_fail_at_spec_build_time() {
        // both the JSON parse path and the CLI --set path must reject
        // iterations < 2 with an explanation, not panic downstream
        let e = ExperimentSpec::parse_str(r#"{"parallelism": {"iterations": 1}}"#).unwrap_err();
        assert!(format!("{e:#}").contains("must be >= 2"), "{e:#}");
        let mut s = ExperimentSpec::default();
        let e = s.apply_set("iterations=1").unwrap_err();
        assert!(format!("{e:#}").contains("at least two"), "{e:#}");
        let e = s.apply_set("parallelism.iterations=0").unwrap_err();
        assert!(format!("{e:#}").contains("must be >= 2"), "{e:#}");
        assert!(s.apply_set("iterations=2").is_ok());
    }

    #[test]
    fn degenerate_prefetch_and_checkpoint_fail_at_spec_build_time() {
        // prefetch 0 = zero-capacity queue = deadlocked coordinator;
        // checkpoint 0 = "every zero steps"; both must die with an
        // explanation at parse AND --set time, never downstream
        let e = ExperimentSpec::parse_str(r#"{"execution": {"prefetch": 0}}"#).unwrap_err();
        assert!(format!("{e:#}").contains("prefetch is 0"), "{e:#}");
        let e = ExperimentSpec::parse_str(r#"{"execution": {"checkpoint": 0}}"#).unwrap_err();
        assert!(format!("{e:#}").contains("checkpoint is 0"), "{e:#}");
        let mut s = ExperimentSpec::default();
        let e = s.apply_set("prefetch=0").unwrap_err();
        assert!(format!("{e:#}").contains("bounded queue"), "{e:#}");
        let e = s.apply_set("execution.prefetch=0").unwrap_err();
        assert!(format!("{e:#}").contains("must be >= 1"), "{e:#}");
        let e = s.apply_set("checkpoint=0").unwrap_err();
        assert!(format!("{e:#}").contains("disable"), "{e:#}");
        let e = s.apply_set("execution.checkpoint=0").unwrap_err();
        assert!(format!("{e:#}").contains("interval in steps"), "{e:#}");
        // the happy paths still work, including the explicit off switch
        assert!(s.apply_set("prefetch=2").is_ok());
        assert!(s.apply_set("checkpoint=5").is_ok());
        assert_eq!(s.execution.checkpoint, Some(5));
        assert!(s.apply_set("checkpoint=none").is_ok());
        assert_eq!(s.execution.checkpoint, None);
        // and null round-trips as "off"
        let spec = ExperimentSpec::parse_str(r#"{"execution": {"checkpoint": null}}"#).unwrap();
        assert_eq!(spec.execution.checkpoint, None);
        let spec = ExperimentSpec::parse_str(r#"{"execution": {"checkpoint": 4}}"#).unwrap();
        assert_eq!(spec.execution.checkpoint, Some(4));
    }

    #[test]
    fn unknown_keys_are_rejected_not_defaulted() {
        // a typo must fail loudly instead of running a wrong experiment
        for bad in [
            r#"{"minibtach": 512}"#,
            r#"{"cluster": {"straggler_skw": 0.5}}"#,
            r#"{"parallelism": {"iterations": 4, "overlp": 1}}"#,
            r#"{"execution": {"step": 10}}"#,
            r#"{"minibatch": {"globl": 64}}"#,
        ] {
            let e = ExperimentSpec::parse_str(bad);
            assert!(e.is_err(), "accepted {bad}");
            assert!(
                format!("{:#}", e.unwrap_err()).contains("unknown"),
                "wrong error for {bad}"
            );
        }
    }

    #[test]
    fn mistyped_sections_are_rejected_not_defaulted() {
        // a section of the wrong JSON type must not silently default
        for bad in [
            r#"[]"#,
            r#"{"cluster": 16}"#,
            r#"{"parallelism": "data"}"#,
            r#"{"minibatch": "512"}"#,
            r#"{"execution": true}"#,
        ] {
            assert!(ExperimentSpec::parse_str(bad).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn registry_names_validate_at_parse_time() {
        // the analytic backend never consumes topology/collective, so
        // waiting for the netsim backend to validate them would let a
        // typo'd committed spec pass the analytic-only CI job
        assert!(ExperimentSpec::parse_str(r#"{"cluster": {"topology": "fattre"}}"#).is_err());
        assert!(ExperimentSpec::parse_str(r#"{"collective": "allreduce"}"#).is_err());
        let mut s = ExperimentSpec::default();
        assert!(s.apply_set("topology=torus").is_err());
        assert!(s.apply_set("collective=nccl").is_err());
        // recovery policies are registry names too
        let e = ExperimentSpec::parse_str(r#"{"cluster": {"recovery": "reboot"}}"#)
            .unwrap_err();
        let msg = format!("{e:#}");
        assert!(
            msg.contains("stall") && msg.contains("replan") && msg.contains("shrink"),
            "{msg}"
        );
        assert!(s.apply_set("cluster.recovery=reboot").is_err());
        s.apply_set("recovery=replan").unwrap();
        assert_eq!(s.cluster.recovery, "replan");
        // the seconds knob kept its explicit name
        s.apply_set("recovery_s=7.5").unwrap();
        assert_eq!(s.cluster.recovery_s, 7.5);
    }

    #[test]
    fn fidelity_is_a_backend_registry_name() {
        // execution.fidelity selects the default backend tier; a typo'd
        // tier must fail at parse/--set time listing the registry
        let e = ExperimentSpec::parse_str(r#"{"execution": {"fidelity": "flowsym"}}"#)
            .unwrap_err();
        let msg = format!("{e:#}");
        assert!(
            msg.contains("analytic") && msg.contains("flowsim") && msg.contains("netsim"),
            "{msg}"
        );
        let mut s = ExperimentSpec::default();
        assert_eq!(s.execution.fidelity, "analytic");
        let e = format!("{:#}", s.apply_set("execution.fidelity=packetlevel").unwrap_err());
        assert!(e.contains("flowsim"), "{e}");
        s.apply_set("fidelity=flowsim").unwrap();
        assert_eq!(s.execution.fidelity, "flowsim");
        s.apply_set("execution.fidelity=netsim").unwrap();
        assert_eq!(s.execution.fidelity, "netsim");
    }
}
