//! The three substrates behind `Backend::run`: balance equations,
//! full-cluster discrete-event simulation, and PJRT execution.
//!
//! All three consume the same [`ExperimentSpec`] and produce the same
//! [`ScalingReport`], which is what makes cross-backend validation (the
//! paper's own methodology: model → simulate → measure) a one-liner —
//! see `tests/fleet_sim.rs::cross_backend_consistency_all_models`.

use anyhow::{bail, Context, Result};

use crate::analytic::machine::Platform;
use crate::flowsim;
use crate::models::NetDescriptor;
use crate::netsim::cluster::{self, simulate_training, simulate_training_fleet, SimConfig};
use crate::netsim::{FleetConfig, RecoveryPolicy, SyncMode};
use crate::plan::{self, planner, PartitionPlan, PlanCache};
use crate::runtime::Runtime;
use crate::trainer::{self, TrainConfig, TrainOutcome};
use crate::util::json::Json;

use super::registry;
use super::report::{RecoveryReport, ScalingReport};
use super::spec::{validate_fail_window, ExperimentSpec};

/// A substrate that can answer an [`ExperimentSpec`].
///
/// `Sync` because sweeps fan points out across scoped threads — all
/// backends are stateless unit structs, so this costs nothing.
pub trait Backend: Sync {
    fn name(&self) -> &'static str;
    fn run(&self, spec: &ExperimentSpec) -> Result<ScalingReport>;

    /// Whether concurrent `run` calls are safe AND worthwhile. The pure
    /// simulators are; the runtime backend spawns its own PJRT client +
    /// worker threads per run, so its sweeps stay serial.
    fn parallel_sweep_safe(&self) -> bool {
        true
    }
}

/// Registry names accepted by [`backend_by_name`], in fidelity order:
/// α-β analytic, flow-level, per-message, real execution.
pub const BACKENDS: &[&str] = &["analytic", "flowsim", "netsim", "runtime"];

pub fn backend_by_name(name: &str) -> Result<Box<dyn Backend>> {
    Ok(match name {
        "analytic" => Box::new(AnalyticBackend),
        "flowsim" | "flow" => Box::new(FlowSimBackend),
        "netsim" | "fleet" => Box::new(FleetSimBackend),
        "runtime" | "pjrt" => Box::new(RuntimeBackend),
        _ => bail!("unknown backend {name:?} (available: {})", BACKENDS.join("|")),
    })
}

/// Platform with the spec's fabric overrides applied.
pub fn resolved_platform(spec: &ExperimentSpec) -> Result<Platform> {
    let mut p = registry::platform(&spec.platform)?;
    if let Some(c) = spec.cluster.congestion {
        p.fabric.congestion_per_doubling = c;
    }
    Ok(p)
}

/// The [`PartitionPlan`] a spec implies at `nodes`: derived per
/// `parallelism.mode` (`data` | `hybrid` recipe | `auto` planner search)
/// with the spec's explicit `plan` pins applied on top. Plans are
/// node-count-specific (hybrid group shapes change with N), so sweeps
/// re-derive per point.
pub fn partition_plan(spec: &ExperimentSpec, nodes: u64) -> Result<PartitionPlan> {
    let net = spec.model.resolve()?;
    let platform = resolved_platform(spec)?;
    plan_for(spec, &net, &platform, nodes)
}

fn plan_for(
    spec: &ExperimentSpec,
    net: &NetDescriptor,
    platform: &Platform,
    nodes: u64,
) -> Result<PartitionPlan> {
    let mb = spec.minibatch.global;
    let overlap = spec.parallelism.overlap;
    if nodes <= 1 {
        // nothing is exchanged at one node: skip the planner search (it
        // would price three identical pure-data sims for every baseline)
        // and the pins' group arithmetic (meaningless at N=1) — but still
        // surface typo'd pin keys/names, so a 1-node smoke run catches
        // what would fail every multi-node run
        registry::plan_mode(&spec.parallelism.mode)?;
        plan::check_pins(&spec.plan, net)?;
        return Ok(PartitionPlan::empty(nodes.max(1), mb));
    }
    let base = match registry::plan_mode(&spec.parallelism.mode)? {
        "data" => PartitionPlan::data_parallel(net, nodes, mb),
        "hybrid" => PartitionPlan::paper_recipe(net, nodes, mb, overlap),
        "auto" => {
            planner::plan(&planner::PlannerInput {
                net,
                platform,
                nodes,
                minibatch: mb,
                overlap,
                collective: registry::collective(&spec.collective)?,
                iterations: spec.parallelism.iterations.max(2),
            })
            .plan
        }
        other => bail!("unhandled parallelism mode {other:?}"),
    };
    let resolved = plan::apply_pins(&base, &spec.plan, net)?;
    resolved.validate(net)?;
    Ok(resolved)
}

/// Spec-build-time validation of the failure event: an out-of-range
/// `fail_node` or a `fail_at` past the simulated window would otherwise
/// silently model a no-op failure (the fleet builder clamps/ignores).
fn check_failure_event(spec: &ExperimentSpec) -> Result<()> {
    if let Some(fail_at) = spec.cluster.fail_at {
        let nodes = spec.cluster.nodes;
        if spec.cluster.fail_node as u64 >= nodes {
            bail!(
                "cluster.fail_node ({}) is out of range for the {nodes}-node cluster \
                 (valid: 0..={}) — the failure event would silently be a no-op",
                spec.cluster.fail_node,
                nodes.saturating_sub(1)
            );
        }
        // fail_at == iterations-1 would put the failure iteration inside
        // the steady-state measurement window itself (last minus
        // previous), silently reporting the disruption as throughput —
        // the same window rule the runtime checks against execution.steps
        validate_fail_window(fail_at as u64, spec.parallelism.iterations as u64,
            "parallelism.iterations")?;
        registry::recovery_policy(&spec.cluster.recovery)?;
    }
    Ok(())
}

/// The plan a `replan` recovery re-derives for the degraded node count:
/// mode-respecting (`data`/`hybrid` recipe at N-1; `auto` runs the
/// planner search through the content-addressed cache, keyed by the
/// degraded N — so an auto+replan run touches `artifacts/plans/` as a
/// deliberate side effect, mirroring what a real coordinator would
/// reuse across repeated failures; the pre-failure N-node search stays
/// uncached like every other backend run). Spec-level pins are *not*
/// re-applied — they were authored for the original node count, and
/// hybrid pin shapes are generally invalid at N-1 (the recovery report
/// records both plans).
fn replan_plan(
    spec: &ExperimentSpec,
    net: &NetDescriptor,
    platform: &Platform,
    degraded: u64,
) -> Result<PartitionPlan> {
    let mb = spec.minibatch.global;
    if degraded <= 1 {
        return Ok(PartitionPlan::empty(degraded.max(1), mb));
    }
    let overlap = spec.parallelism.overlap;
    Ok(match registry::plan_mode(&spec.parallelism.mode)? {
        "data" => PartitionPlan::data_parallel(net, degraded, mb),
        "hybrid" => PartitionPlan::paper_recipe(net, degraded, mb, overlap),
        "auto" => {
            let input = planner::PlannerInput {
                net,
                platform,
                nodes: degraded,
                minibatch: mb,
                overlap,
                collective: registry::collective(&spec.collective)?,
                iterations: spec.parallelism.iterations.max(2),
            };
            let cache = PlanCache::new(PlanCache::default_dir());
            cache.plan_cached(spec.model.name(), &input).0.plan
        }
        other => bail!("unhandled parallelism mode {other:?}"),
    })
}

/// The degraded-fleet plan a failure-bearing spec implies under its
/// recovery policy (`None` for stall / 1-node fleets: the plan is
/// unchanged).
fn degraded_plan_for(
    spec: &ExperimentSpec,
    net: &NetDescriptor,
    platform: &Platform,
    plan_before: &PartitionPlan,
    nodes: u64,
) -> Result<Option<PartitionPlan>> {
    if spec.cluster.fail_at.is_none() || nodes <= 1 {
        return Ok(None);
    }
    let degraded = match registry::recovery_policy(&spec.cluster.recovery)? {
        RecoveryPolicy::Stall => return Ok(None),
        RecoveryPolicy::Shrink => plan_before.renormalize_for(nodes - 1),
        RecoveryPolicy::Replan => replan_plan(spec, net, platform, nodes - 1)?,
    };
    degraded.validate(net)?;
    Ok(Some(degraded))
}

/// The (pre-failure, post-failure) partition plans a failure-bearing
/// spec implies — the pair every recovery report records. Errors when
/// the spec carries no failure event.
pub fn recovery_plans(spec: &ExperimentSpec) -> Result<(PartitionPlan, PartitionPlan)> {
    spec.cluster
        .fail_at
        .context("spec has no failure event (cluster.fail_at is null)")?;
    check_failure_event(spec)?;
    let net = spec.model.resolve()?;
    let platform = resolved_platform(spec)?;
    let nodes = spec.cluster.nodes;
    let before = plan_for(spec, &net, &platform, nodes)?;
    let after = degraded_plan_for(spec, &net, &platform, &before, nodes)?
        .unwrap_or_else(|| before.clone());
    Ok((before, after))
}

fn sim_config(
    spec: &ExperimentSpec,
    net: &NetDescriptor,
    platform: &Platform,
    nodes: u64,
) -> Result<SimConfig> {
    if nodes == 0 {
        bail!("cluster.nodes must be >= 1");
    }
    if spec.parallelism.iterations < 2 {
        bail!("parallelism.iterations must be >= 2 (steady state = last minus previous)");
    }
    if spec.minibatch.global < nodes {
        bail!(
            "minibatch.global ({}) must be >= cluster.nodes ({nodes}): every node needs data",
            spec.minibatch.global
        );
    }
    check_failure_event(spec)?;
    let sync = registry::sync_mode(&spec.parallelism.sync)?;
    if !sync.is_bsp() && spec.cluster.fail_at.is_some() {
        bail!(
            "parallelism.sync = {:?} does not model failure recovery: the drift-bounded \
             timeline has no global barrier to anchor the recovery split on (drop \
             cluster.fail_at or set parallelism.sync = \"bsp\")",
            spec.parallelism.sync
        );
    }
    let plan = plan_for(spec, net, platform, nodes)?;
    // the degraded plan applies when this SimConfig runs at the spec's
    // node count — which includes every sweep point (run_sweep rewrites
    // cluster.nodes per point, so each point models its own failure);
    // only the backends' internal 1-node baseline call is exempt
    let degraded_plan = if nodes == spec.cluster.nodes {
        degraded_plan_for(spec, net, platform, &plan, nodes)?
    } else {
        None
    };
    Ok(SimConfig {
        nodes,
        minibatch: spec.minibatch.global,
        iterations: spec.parallelism.iterations,
        plan,
        collective: registry::collective(&spec.collective)?,
        degraded_plan,
        sync,
    })
}

/// `sim_config` for the flow-level tier. Flowsim prices fractional
/// per-node minibatches (paper sweeps reach node counts above the
/// global minibatch, e.g. fig4's MB=512 at 1024 nodes), so the
/// `minibatch >= nodes` floor relaxes to `>= 1`; failure events never
/// reach here because [`FlowSimBackend`] bails on them first.
fn flow_sim_config(
    spec: &ExperimentSpec,
    net: &NetDescriptor,
    platform: &Platform,
    nodes: u64,
) -> Result<SimConfig> {
    if nodes == 0 {
        bail!("cluster.nodes must be >= 1");
    }
    if spec.parallelism.iterations < 2 {
        bail!("parallelism.iterations must be >= 2 (steady state = last minus previous)");
    }
    if spec.minibatch.global < 1 {
        bail!("minibatch.global must be >= 1");
    }
    let plan = plan_for(spec, net, platform, nodes)?;
    Ok(SimConfig {
        nodes,
        minibatch: spec.minibatch.global,
        iterations: spec.parallelism.iterations,
        plan,
        collective: registry::collective(&spec.collective)?,
        degraded_plan: None,
        sync: SyncMode::Bsp,
    })
}

fn base_report(spec: &ExperimentSpec, backend: &'static str) -> ScalingReport {
    ScalingReport {
        spec_name: spec.name.clone(),
        backend: backend.to_string(),
        model: spec.model.name().to_string(),
        platform: spec.platform.clone(),
        nodes: spec.cluster.nodes,
        minibatch: spec.minibatch.global,
        iteration_s: f64::NAN,
        samples_per_s: f64::NAN,
        speedup: None,
        efficiency: None,
        compute_s: f64::NAN,
        comm_s: f64::NAN,
        mean_compute_utilization: f64::NAN,
        min_compute_utilization: f64::NAN,
        overlap_s: f64::NAN,
        overlap_frac: f64::NAN,
        tasks: 0,
        sim_path: None,
        warmup_tasks: 0,
        cycle_tasks: 0,
        plan: Json::Null,
        recovery: Json::Null,
    }
}

/// Representative-node balance equations (paper §2–3): one symmetric
/// node, α-β collective costs over the full node count. Milliseconds to
/// evaluate, so every run also prices its own 1-node baseline.
pub struct AnalyticBackend;

impl Backend for AnalyticBackend {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn run(&self, spec: &ExperimentSpec) -> Result<ScalingReport> {
        let net = spec.model.resolve()?;
        let platform = resolved_platform(spec)?;
        let cfg = sim_config(spec, &net, &platform, spec.cluster.nodes)?;
        let r = simulate_training(&net, &platform, &cfg)?;
        let base = simulate_training(&net, &platform, &sim_config(spec, &net, &platform, 1)?)?;
        let speedup = r.images_per_s / base.images_per_s;
        let mut rep = base_report(spec, "analytic");
        rep.iteration_s = r.iteration_s;
        rep.samples_per_s = r.images_per_s;
        rep.speedup = Some(speedup);
        rep.efficiency = Some(speedup / cfg.nodes as f64);
        rep.compute_s = r.compute_utilization * r.iteration_s;
        rep.comm_s = (1.0 - r.compute_utilization) * r.iteration_s;
        rep.mean_compute_utilization = r.compute_utilization;
        rep.min_compute_utilization = r.compute_utilization;
        rep.plan = cfg.plan.to_json();
        // α-β pricing of the failure event: the same recovery policies
        // the fleet simulator executes, in closed form (the cross-check)
        if spec.cluster.fail_at.is_some() {
            let policy = registry::recovery_policy(&spec.cluster.recovery)?;
            let fabric = &platform.fabric;
            let nodes = cfg.nodes;
            let choice = cfg.collective;
            let (nodes_after, post, plan_after, stall_s, replan_s, redist_s) =
                match (&cfg.degraded_plan, policy) {
                    (Some(degraded), _) => {
                        let post_cfg = SimConfig {
                            nodes: nodes - 1,
                            plan: degraded.clone(),
                            degraded_plan: None,
                            ..cfg.clone()
                        };
                        let post = simulate_training(&net, &platform, &post_cfg)?;
                        let replan_s = if policy == RecoveryPolicy::Replan {
                            cluster::replan_coordination_s(fabric, nodes - 1)
                        } else {
                            0.0
                        };
                        let redist_s =
                            cluster::redistribution_s(fabric, choice, &net, nodes, nodes - 1);
                        let stall_s = cluster::DETECT_FRAC * spec.cluster.recovery_s
                            + replan_s
                            + redist_s;
                        (nodes - 1, post, degraded.to_json(), stall_s, replan_s, redist_s)
                    }
                    // stall (or a 1-node fleet, which cannot shrink):
                    // the node rejoins, the steady state is the main run
                    _ => (
                        nodes,
                        r.clone(),
                        cfg.plan.to_json(),
                        spec.cluster.recovery_s,
                        0.0,
                        0.0,
                    ),
                };
            // report the policy actually modeled: a 1-node fleet cannot
            // shrink, so it degrades to stall exactly like the fleet
            // simulator does (the cross-check is field-by-field)
            let effective_policy = if cfg.degraded_plan.is_some() {
                spec.cluster.recovery.clone()
            } else {
                "stall".to_string()
            };
            rep.recovery = RecoveryReport {
                policy: effective_policy,
                fail_at: spec.cluster.fail_at.unwrap_or(0) as u64,
                fail_node: spec.cluster.fail_node as u64,
                nodes_before: nodes,
                nodes_after,
                stall_s,
                replan_s,
                redistribution_s: redist_s,
                // the simulators respread the minibatch without an
                // ABI-pinned microbatch, so nothing is ever dropped
                residual_mb: 0,
                post_iteration_s: post.iteration_s,
                post_samples_per_s: post.images_per_s,
                post_efficiency: (post.images_per_s / base.images_per_s) / nodes_after as f64,
                plan_before: cfg.plan.to_json(),
                plan_after,
            }
            .to_json();
        }
        Ok(rep)
    }
}

fn fleet_config(spec: &ExperimentSpec) -> Result<FleetConfig> {
    Ok(FleetConfig {
        nodes: spec.cluster.nodes as usize,
        topology: registry::topology(
            &spec.cluster.topology,
            spec.cluster.radix,
            spec.cluster.oversub,
        )?,
        straggler_skew: spec.cluster.straggler_skew,
        hetero: spec.cluster.hetero,
        fail_at: spec.cluster.fail_at,
        fail_node: spec.cluster.fail_node,
        recovery_s: spec.cluster.recovery_s,
        recovery: registry::recovery_policy(&spec.cluster.recovery)?,
    })
}

/// Full-cluster discrete-event simulation: every node, every message,
/// every contended link — the substrate for stragglers, heterogeneous
/// fleets, oversubscribed fabrics and failure/rejoin.
pub struct FleetSimBackend;

impl Backend for FleetSimBackend {
    fn name(&self) -> &'static str {
        "netsim"
    }

    fn run(&self, spec: &ExperimentSpec) -> Result<ScalingReport> {
        let net = spec.model.resolve()?;
        let platform = resolved_platform(spec)?;
        let cfg = sim_config(spec, &net, &platform, spec.cluster.nodes)?;
        let fleet = fleet_config(spec)?;
        let r = simulate_training_fleet(&net, &platform, &cfg, &fleet)?;
        let base = simulate_training_fleet(
            &net,
            &platform,
            &sim_config(spec, &net, &platform, 1)?,
            &FleetConfig::homogeneous(1),
        )?;
        let speedup = r.images_per_s / base.images_per_s;
        let mut rep = base_report(spec, "netsim");
        rep.iteration_s = r.iteration_s;
        rep.samples_per_s = r.images_per_s;
        rep.speedup = Some(speedup);
        rep.efficiency = Some(speedup / cfg.nodes as f64);
        rep.compute_s = r.mean_compute_utilization * r.iteration_s;
        rep.comm_s = (1.0 - r.mean_compute_utilization) * r.iteration_s;
        rep.mean_compute_utilization = r.mean_compute_utilization;
        rep.min_compute_utilization = r.min_compute_utilization;
        rep.tasks = r.tasks as u64;
        rep.sim_path = Some(r.sim_path.name().to_string());
        rep.warmup_tasks = r.warmup_tasks as u64;
        rep.cycle_tasks = r.cycle_tasks as u64;
        rep.plan = cfg.plan.to_json();
        // measured failure recovery: the steady-state window after the
        // split IS the post-failure fleet, so the main run's numbers
        // feed the section directly
        if let Some(out) = &r.recovery {
            rep.recovery = RecoveryReport {
                policy: registry::recovery_policy_name(out.policy).to_string(),
                fail_at: spec.cluster.fail_at.unwrap_or(0) as u64,
                fail_node: spec.cluster.fail_node as u64,
                nodes_before: cfg.nodes,
                nodes_after: out.nodes_after,
                stall_s: out.stall_s,
                replan_s: out.replan_s,
                redistribution_s: out.redistribution_s,
                residual_mb: 0,
                post_iteration_s: r.iteration_s,
                post_samples_per_s: r.images_per_s,
                post_efficiency: (r.images_per_s / base.images_per_s)
                    / out.nodes_after as f64,
                plan_before: cfg.plan.to_json(),
                plan_after: match &out.plan_after {
                    Some(p) => p.to_json(),
                    None => cfg.plan.to_json(),
                },
            }
            .to_json();
        }
        Ok(rep)
    }
}

/// Flow-level simulation: the middle fidelity tier. Collective steps
/// become flows that fair-share link capacity (max-min allocation),
/// so rate changes — not packets or pipelined chunks — drive the event
/// loop. Resolves 1000s-of-node sweeps in seconds while keeping the
/// topology sensitivity the analytic tier lacks. Homogeneous,
/// failure-free fleets only; everything else needs per-message netsim.
pub struct FlowSimBackend;

impl Backend for FlowSimBackend {
    fn name(&self) -> &'static str {
        "flowsim"
    }

    fn run(&self, spec: &ExperimentSpec) -> Result<ScalingReport> {
        if spec.cluster.straggler_skew != 0.0 {
            bail!(
                "flowsim models homogeneous fleets only: cluster.straggler_skew = {} \
                 needs per-message fidelity (--backend netsim)",
                spec.cluster.straggler_skew
            );
        }
        if spec.cluster.hetero {
            bail!(
                "flowsim models homogeneous fleets only: cluster.hetero needs \
                 per-message fidelity (--backend netsim)"
            );
        }
        if spec.cluster.fail_at.is_some() {
            bail!(
                "flowsim models failure-free runs only: cluster.fail_at needs \
                 per-message fidelity (--backend netsim)"
            );
        }
        if !registry::sync_mode(&spec.parallelism.sync)?.is_bsp() {
            bail!(
                "flowsim models bulk-synchronous runs only: parallelism.sync = {:?} \
                 needs per-message fidelity (--backend netsim)",
                spec.parallelism.sync
            );
        }
        let net = spec.model.resolve()?;
        let platform = resolved_platform(spec)?;
        let cfg = flow_sim_config(spec, &net, &platform, spec.cluster.nodes)?;
        let topology = registry::topology(
            &spec.cluster.topology,
            spec.cluster.radix,
            spec.cluster.oversub,
        )?;
        let r = flowsim::simulate_training_flows(&net, &platform, &cfg, topology)?;
        let base = flowsim::simulate_training_flows(
            &net,
            &platform,
            &flow_sim_config(spec, &net, &platform, 1)?,
            topology,
        )?;
        let speedup = r.images_per_s / base.images_per_s;
        let mut rep = base_report(spec, "flowsim");
        rep.iteration_s = r.iteration_s;
        rep.samples_per_s = r.images_per_s;
        rep.speedup = Some(speedup);
        rep.efficiency = Some(speedup / cfg.nodes as f64);
        rep.compute_s = r.mean_compute_utilization * r.iteration_s;
        rep.comm_s = (1.0 - r.mean_compute_utilization) * r.iteration_s;
        rep.mean_compute_utilization = r.mean_compute_utilization;
        rep.min_compute_utilization = r.min_compute_utilization;
        rep.tasks = r.tasks;
        // flowsim builds the full multi-iteration DAG (flows are cheap
        // enough not to need netsim's steady-state templates), so the
        // whole build is the "warmup" and a cycle is one iteration
        rep.sim_path = Some("flow".to_string());
        rep.warmup_tasks = r.tasks;
        rep.cycle_tasks = r.tasks / cfg.iterations.max(1) as u64;
        rep.plan = cfg.plan.to_json();
        Ok(rep)
    }
}

/// PJRT execution of the AOT artifacts through the synchronous-SGD
/// coordinator: `cluster.nodes` shared-memory workers stand in for the
/// paper's MPI ranks. Needs `make artifacts` (with a real `xla`
/// binding); the vendored stub errors cleanly otherwise.
pub struct RuntimeBackend;

impl Backend for RuntimeBackend {
    fn name(&self) -> &'static str {
        "runtime"
    }

    fn run(&self, spec: &ExperimentSpec) -> Result<ScalingReport> {
        Ok(run_runtime(spec)?.0)
    }

    fn parallel_sweep_safe(&self) -> bool {
        // each run spawns a PJRT client and its own worker threads;
        // concurrent instances would thrash the machine and interleave
        // training logs
        false
    }
}

/// The runtime backend's full result: the report plus the training
/// outcome (loss history, final parameters) for callers that need more
/// than scaling numbers (the convergence/e2e examples, `repro train`).
pub fn run_runtime(spec: &ExperimentSpec) -> Result<(ScalingReport, TrainOutcome)> {
    let mut rt = Runtime::new(&spec.execution.artifacts)
        .context("artifacts not built? run `make artifacts`")?;
    run_runtime_with(&mut rt, spec)
}

/// [`run_runtime`] against an existing [`Runtime`], so callers running
/// several specs (e.g. the Fig 5 worker sweep) reuse one PJRT client
/// and its compiled-executable cache instead of recompiling per run.
pub fn run_runtime_with(
    rt: &mut Runtime,
    spec: &ExperimentSpec,
) -> Result<(ScalingReport, TrainOutcome)> {
    let mut cfg = train_config(spec);
    // the runtime executes the spec's plan at worker granularity over the
    // runnable model standing in for the zoo topology (vgg_a -> vgg_tiny
    // etc.); manifest-only models have no descriptor to plan over and run
    // plain data-parallel
    if let Ok(net) = registry::model(&cfg.model) {
        let platform = resolved_platform(spec)?;
        let workers = cfg.workers as u64;
        cfg.plan = match plan_for(spec, &net, &platform, workers) {
            Ok(p) => Some(p),
            // pins are usually authored against the full-size model's
            // layer names; when they don't map onto the substituted
            // runtime model, fall back to the mode-derived plan rather
            // than failing a run the other backends accept
            Err(e) if !spec.plan.is_empty() => {
                eprintln!(
                    "note: spec plan pins do not apply to runtime model {:?} ({e:#}); \
                     using the mode-derived plan",
                    cfg.model
                );
                let mut unpinned = spec.clone();
                unpinned.plan.clear();
                Some(plan_for(&unpinned, &net, &platform, workers)?)
            }
            Err(e) => return Err(e),
        };
    }
    // a replan recovery re-derives the degraded plan on the runnable
    // model at N-1, mirroring the simulators (shrink renormalizes inside
    // the trainer; stall keeps the plan)
    if cfg.fail_at.is_some()
        && cfg.workers >= 2
        && matches!(registry::recovery_policy(&cfg.recovery), Ok(RecoveryPolicy::Replan))
    {
        if let Ok(net) = registry::model(&cfg.model) {
            let platform = resolved_platform(spec)?;
            cfg.recovery_plan =
                Some(replan_plan(spec, &net, &platform, cfg.workers as u64 - 1)?);
        }
    }
    let out = trainer::train(rt, &cfg)?;

    let mut rep = base_report(spec, "runtime");
    rep.plan = match &cfg.plan {
        Some(p) => p.to_json(),
        None => Json::Null,
    };
    rep.model = cfg.model.clone();
    rep.nodes = cfg.workers as u64;
    rep.minibatch = cfg.global_mb as u64;
    let n = out.history.records.len();
    if n > 0 {
        let mean = |f: fn(&crate::metrics::StepRecord) -> f64| {
            out.history.records.iter().map(f).sum::<f64>() / n as f64
        };
        rep.samples_per_s = out.history.mean_throughput();
        rep.iteration_s = if rep.samples_per_s > 0.0 {
            cfg.global_mb as f64 / rep.samples_per_s
        } else {
            f64::NAN
        };
        rep.compute_s = mean(|r| r.compute_s);
        rep.comm_s = mean(|r| r.comm_wait_s);
        // measured overlap from the streaming exchange: comm_s is the
        // *exposed* wait, overlap_s the comm work hidden behind compute
        rep.overlap_s = mean(|r| r.overlap_s);
        let comm_total = rep.overlap_s + rep.comm_s;
        if comm_total > 0.0 {
            rep.overlap_frac = rep.overlap_s / comm_total;
        }
        let busy = rep.compute_s + rep.comm_s;
        if busy > 0.0 {
            rep.mean_compute_utilization = rep.compute_s / busy;
            rep.min_compute_utilization = rep.mean_compute_utilization;
        }
    }
    if let Some(m) = &out.recovery {
        rep.recovery = runtime_recovery_json(m, cfg.plan.as_ref());
    }
    Ok((rep, out))
}

/// Map the trainer's measured [`trainer::fault::RecoveryMeasurement`]
/// onto the shared [`RecoveryReport`] schema — wall-clock seconds in the
/// same fields the simulators price, so recovery cross-checks three
/// ways. `post_efficiency` uses the run's own pre-failure per-node
/// throughput as the baseline (the runtime run carries no 1-node
/// baseline of its own).
pub fn runtime_recovery_json(
    m: &trainer::fault::RecoveryMeasurement,
    plan_before: Option<&PartitionPlan>,
) -> Json {
    let pre_per_node = if m.workers_before > 0 {
        m.pre_samples_per_s / m.workers_before as f64
    } else {
        0.0
    };
    let post_efficiency = if pre_per_node > 0.0 && m.workers_after > 0 {
        (m.post_samples_per_s / pre_per_node) / m.workers_after as f64
    } else {
        0.0
    };
    RecoveryReport {
        policy: registry::recovery_policy_name(m.policy).to_string(),
        fail_at: m.failed_step,
        fail_node: m.dead_worker as u64,
        nodes_before: m.workers_before as u64,
        nodes_after: m.workers_after as u64,
        stall_s: m.stall_s(),
        replan_s: m.replan_s,
        redistribution_s: m.redistribution_s,
        residual_mb: m.residual_mb as u64,
        post_iteration_s: m.post_iteration_s,
        post_samples_per_s: m.post_samples_per_s,
        post_efficiency,
        plan_before: match plan_before {
            Some(p) => p.to_json(),
            None => Json::Null,
        },
        plan_after: match &m.plan_after {
            Some(p) => p.to_json(),
            None => Json::Null,
        },
    }
    .to_json()
}

/// Spec → trainer configuration (public so the CLI's `repro train`
/// alias provably goes through the same translation).
pub fn train_config(spec: &ExperimentSpec) -> TrainConfig {
    TrainConfig {
        model: spec
            .execution
            .model
            .clone()
            .unwrap_or_else(|| registry::runtime_model_for(spec.model.name()).to_string()),
        workers: spec.execution.workers.unwrap_or(spec.cluster.nodes.max(1) as usize),
        global_mb: spec.minibatch.global as usize,
        steps: spec.execution.steps,
        lr: spec.execution.lr as f32,
        momentum: spec.execution.momentum as f32,
        seed: spec.execution.seed,
        log_every: spec.execution.log_every,
        eval_every: spec.execution.eval_every,
        optimizer: spec.execution.optimizer.clone(),
        prefetch: spec.execution.prefetch,
        plan: None,
        checkpoint_every: spec.execution.checkpoint.unwrap_or(0),
        checkpoint_dir: Some(format!("{}/checkpoints", spec.execution.artifacts)),
        fail_at: spec.cluster.fail_at.map(|v| v as u64),
        fail_worker: spec.cluster.fail_node,
        recovery: spec.cluster.recovery.clone(),
        recovery_plan: None,
        sync: spec.parallelism.sync.clone(),
    }
}

/// Run `spec` at each node count (speedup/efficiency stay relative to
/// the backend's 1-node baseline) — the scaling curves of Figs 4/6/7.
///
/// Each point re-prices its own 1-node baseline inside `Backend::run`.
/// That is deliberate: a 1-node simulation has no collectives and costs
/// O(layers) tasks — negligible next to the N-node run — and keeping
/// `run` a pure function of the spec is what makes reports comparable
/// bit-for-bit across call sites (the alias-equivalence guarantee).
///
/// Points are independent pure computations, so simulator backends fan
/// them out across scoped threads (`util::par`; `REPRO_THREADS=1` forces
/// the serial path). Reports come back in input order and are
/// bit-identical to [`run_sweep_serial`].
pub fn run_sweep(
    backend: &dyn Backend,
    spec: &ExperimentSpec,
    nodes: &[u64],
) -> Result<Vec<ScalingReport>> {
    if !backend.parallel_sweep_safe() || nodes.len() <= 1 {
        return run_sweep_serial(backend, spec, nodes);
    }
    crate::util::par::parallel_map(nodes, |&n| {
        let mut s = spec.clone();
        s.cluster.nodes = n;
        backend.run(&s)
    })
    .into_iter()
    .collect()
}

/// [`run_sweep`] pinned to one thread — the timing baseline for the perf
/// harness and the path non-thread-safe backends always take.
pub fn run_sweep_serial(
    backend: &dyn Backend,
    spec: &ExperimentSpec,
    nodes: &[u64],
) -> Result<Vec<ScalingReport>> {
    nodes
        .iter()
        .map(|&n| {
            let mut s = spec.clone();
            s.cluster.nodes = n;
            backend.run(&s)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_and_netsim_run_the_same_spec() {
        let mut spec = ExperimentSpec::of("t", "vgg_a", "cori", 4, 256);
        spec.parallelism.iterations = 3;
        let a = AnalyticBackend.run(&spec).unwrap();
        let f = FleetSimBackend.run(&spec).unwrap();
        assert_eq!(a.backend, "analytic");
        assert_eq!(f.backend, "netsim");
        assert_eq!(a.nodes, 4);
        assert!(a.samples_per_s > 0.0 && f.samples_per_s > 0.0);
        assert!(f.tasks > 0 && a.tasks == 0);
        assert!(a.efficiency.unwrap() > 0.0 && a.efficiency.unwrap() <= 1.01);
    }

    #[test]
    fn sweep_reports_monotone_throughput() {
        let spec = ExperimentSpec::of("t", "vgg_a", "cori", 1, 256);
        let curve = run_sweep(&AnalyticBackend, &spec, &[1, 2, 4, 8]).unwrap();
        assert_eq!(curve.len(), 4);
        assert!((curve[0].speedup.unwrap() - 1.0).abs() < 1e-9);
        for w in curve.windows(2) {
            assert!(w[1].samples_per_s >= w[0].samples_per_s * 0.98);
        }
    }

    #[test]
    fn parallel_sweep_is_bit_identical_to_serial() {
        let mut spec = ExperimentSpec::of("t", "vgg_a", "cori", 1, 256);
        spec.parallelism.iterations = 3;
        let nodes = [1u64, 2, 4, 8, 16];
        let par = run_sweep(&FleetSimBackend, &spec, &nodes).unwrap();
        let ser = run_sweep_serial(&FleetSimBackend, &spec, &nodes).unwrap();
        assert_eq!(par.len(), ser.len());
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        }
    }

    #[test]
    fn failure_events_are_validated_at_spec_build_time() {
        // out-of-range fail_node: today this would silently model a
        // no-op failure; it must fail with a context-rich error instead
        let mut spec = ExperimentSpec::of("t", "vgg_a", "cori", 4, 256);
        spec.cluster.fail_at = Some(1);
        spec.cluster.fail_node = 7;
        for b in [&AnalyticBackend as &dyn Backend, &FleetSimBackend] {
            let e = format!("{:#}", b.run(&spec).unwrap_err());
            assert!(e.contains("fail_node") && e.contains('7') && e.contains('4'), "{e}");
        }
        // fail_at past the simulated window never fires
        let mut spec = ExperimentSpec::of("t", "vgg_a", "cori", 4, 256);
        spec.cluster.fail_at = Some(9);
        let e = format!("{:#}", AnalyticBackend.run(&spec).unwrap_err());
        assert!(e.contains("fail_at") && e.contains("iterations"), "{e}");
        // a clean spec with the same fields unset still runs
        let spec = ExperimentSpec::of("t", "vgg_a", "cori", 4, 256);
        AnalyticBackend.run(&spec).unwrap();
    }

    #[test]
    fn recovery_sections_appear_only_on_failure_specs() {
        use crate::experiment::report::RecoveryReport;
        let mut spec = ExperimentSpec::of("t", "vgg_a", "cori", 4, 256);
        spec.parallelism.iterations = 5;
        let clean = AnalyticBackend.run(&spec).unwrap();
        assert_eq!(clean.recovery, Json::Null);
        spec.cluster.fail_at = Some(1);
        for policy in ["stall", "replan", "shrink"] {
            spec.cluster.recovery = policy.into();
            let rep = AnalyticBackend.run(&spec).unwrap();
            let rec = RecoveryReport::from_json(&rep.recovery).unwrap();
            assert_eq!(rec.policy, policy);
            assert_eq!(rec.nodes_before, 4);
            assert_eq!(rec.nodes_after, if policy == "stall" { 4 } else { 3 });
            assert!(rec.stall_s > 0.0);
            assert!(rec.post_efficiency > 0.0 && rec.post_efficiency <= 1.01);
        }
    }

    #[test]
    fn recovery_plans_pair_is_valid_at_the_degraded_count() {
        let net = registry::model("vgg_a").unwrap();
        let mut spec = ExperimentSpec::of("t", "vgg_a", "cori", 16, 512);
        spec.cluster.fail_at = Some(1);
        spec.cluster.recovery = "replan".into();
        let (before, after) = recovery_plans(&spec).unwrap();
        assert_eq!(before.nodes, 16);
        assert_eq!(after.nodes, 15);
        after.validate(&net).unwrap();
        // stall keeps the plan
        spec.cluster.recovery = "stall".into();
        let (before, after) = recovery_plans(&spec).unwrap();
        assert_eq!(before, after);
        // no failure event -> no plans to pair
        spec.cluster.fail_at = None;
        assert!(recovery_plans(&spec).is_err());
    }

    #[test]
    fn backend_registry_rejects_unknown() {
        let e = backend_by_name("fpga").unwrap_err().to_string();
        // the error is the registry's discoverability surface: it must
        // enumerate every tier, including the flow-level one
        for b in BACKENDS {
            assert!(e.contains(b), "{e}");
        }
        for b in BACKENDS {
            assert_eq!(backend_by_name(b).unwrap().name(), *b);
        }
    }

    #[test]
    fn flowsim_runs_clean_specs_and_tracks_analytic() {
        let mut spec = ExperimentSpec::of("t", "vgg_a", "cori", 8, 256);
        spec.parallelism.iterations = 3;
        spec.cluster.congestion = Some(0.0);
        let a = AnalyticBackend.run(&spec).unwrap();
        let f = FlowSimBackend.run(&spec).unwrap();
        assert_eq!(f.backend, "flowsim");
        assert_eq!(f.sim_path.as_deref(), Some("flow"));
        assert!(f.tasks > 0 && f.cycle_tasks > 0);
        let (ea, ef) = (a.efficiency.unwrap(), f.efficiency.unwrap());
        assert!(
            (ea - ef).abs() / ea < 0.05,
            "flowsim efficiency {ef} drifts from analytic {ea}"
        );
    }

    #[test]
    fn flowsim_prices_nodes_beyond_the_global_minibatch() {
        // fig4's frontier: MB=512 at 1024 nodes. netsim refuses
        // (minibatch >= nodes); the flow tier prices it in seconds.
        let mut spec = ExperimentSpec::of("t", "vgg_a", "cori", 1024, 512);
        spec.parallelism.iterations = 2;
        let rep = FlowSimBackend.run(&spec).unwrap();
        assert_eq!(rep.nodes, 1024);
        assert!(rep.samples_per_s > 0.0 && rep.iteration_s > 0.0);
        assert!(rep.efficiency.unwrap() > 0.0);
    }

    #[test]
    fn flowsim_rejects_out_of_scope_specs_with_netsim_pointer() {
        let cases: [(&str, fn(&mut ExperimentSpec)); 3] = [
            ("straggler_skew", |s| s.cluster.straggler_skew = 0.3),
            ("hetero", |s| s.cluster.hetero = true),
            ("fail_at", |s| s.cluster.fail_at = Some(1)),
        ];
        for (field, apply) in cases {
            let mut spec = ExperimentSpec::of("t", "vgg_a", "cori", 4, 256);
            apply(&mut spec);
            let e = format!("{:#}", FlowSimBackend.run(&spec).unwrap_err());
            assert!(e.contains(field) && e.contains("netsim"), "{field}: {e}");
        }
    }

    #[test]
    fn spec_with_unknown_model_errors_with_inventory() {
        let spec = ExperimentSpec::of("t", "resnet50", "cori", 2, 256);
        let e = AnalyticBackend.run(&spec).unwrap_err().to_string();
        assert!(e.contains("vgg_a"), "{e}");
    }
}
