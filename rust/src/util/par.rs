//! Scoped-thread parallel map — the crate is fully offline (no rayon),
//! so sweep-level parallelism is a small `std::thread::scope` work queue.
//!
//! Sweep points (one `ExperimentSpec` run per node count, one planner
//! search per design point) are independent pure computations, so
//! results are returned in input order and are bit-identical to the
//! serial evaluation. `REPRO_THREADS` caps the worker count (`1` forces
//! serial execution — useful for timing baselines and debugging).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count: `REPRO_THREADS` if set (min 1), else the machine's
/// available parallelism.
pub fn workers() -> usize {
    if let Ok(v) = std::env::var("REPRO_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

/// Apply `f` to every item, fanning out across up to [`workers`] scoped
/// threads. Output order matches input order; with one worker (or one
/// item) this degenerates to a plain serial map, so parallel and serial
/// results are interchangeable.
pub fn parallel_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let threads = workers().min(n);
    if threads <= 1 {
        return items.iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<U>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let u = f(&items[i]);
                out.lock().unwrap()[i] = Some(u);
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|u| u.expect("worker completed every claimed item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_covers_all_items() {
        let items: Vec<u64> = (0..257).collect();
        let out = parallel_map(&items, |&x| x * 2);
        assert_eq!(out, items.iter().map(|&x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single_inputs() {
        let empty: Vec<u64> = Vec::new();
        assert!(parallel_map(&empty, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u64], |&x| x + 1), vec![8]);
    }

    #[test]
    fn matches_serial_map_on_nontrivial_work() {
        let items: Vec<u64> = (0..64).collect();
        let f = |&x: &u64| (0..1000u64).fold(x, |a, b| a.wrapping_mul(31).wrapping_add(b));
        let serial: Vec<u64> = items.iter().map(f).collect();
        assert_eq!(parallel_map(&items, f), serial);
    }

    #[test]
    fn workers_is_at_least_one() {
        assert!(workers() >= 1);
    }
}
