//! Micro-benchmark harness (criterion is unavailable offline). Runs a
//! closure in timed batches with warmup, reports median/mean/p95 and
//! ops/sec. All `cargo bench` targets use this.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }

    pub fn report(&self) {
        println!(
            "  {:<44} {:>12} {:>12} {:>12} {:>12}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            format!("{:.1}/s", self.per_sec()),
        );
    }
}

pub fn header() {
    println!(
        "  {:<44} {:>12} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "median", "p95", "throughput"
    );
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Time `f` adaptively: warm up, pick an iteration count that fills
/// ~`budget`, then sample. Returns stats over per-iteration times.
pub fn bench(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibrate
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_nanos().max(1) as u64;
    let target = budget.as_nanos() as u64;
    let samples: u64 = 16;
    let iters_per_sample = (target / samples / one).clamp(1, 1_000_000);

    let mut per_iter: Vec<f64> = Vec::with_capacity(samples as usize);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        per_iter.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
    }
    per_iter.sort_by(|a, b| a.total_cmp(b));
    let n = per_iter.len();
    BenchResult {
        name: name.to_string(),
        iters: samples * iters_per_sample,
        mean_ns: per_iter.iter().sum::<f64>() / n as f64,
        median_ns: per_iter[n / 2],
        p95_ns: per_iter[(n * 95 / 100).min(n - 1)],
        min_ns: per_iter[0],
    }
}

/// Prevent the optimizer from deleting a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", Duration::from_millis(20), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 0);
        assert!(r.min_ns <= r.median_ns);
        assert!(r.median_ns <= r.p95_ns + 1.0);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn format_ns_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5.0e4).ends_with("us"));
        assert!(fmt_ns(5.0e7).ends_with("ms"));
        assert!(fmt_ns(5.0e9).ends_with('s'));
    }
}
