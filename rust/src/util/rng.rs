//! Deterministic PRNG (SplitMix64) for synthetic data generation and
//! property tests. Every stream is derived from an explicit seed, so all
//! workers and all reruns see identical data — a precondition for the
//! Fig 5 convergence-equivalence experiment.

/// SplitMix64: tiny, fast, passes BigCrush for these purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    /// Derive an independent stream (e.g. per class, per step).
    pub fn fork(&self, salt: u64) -> Rng {
        let mut r = Rng::new(self.state ^ salt.wrapping_mul(0xd1342543de82ef95));
        r.next_u64();
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal (Box-Muller, one value per call).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-7);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out {
            *v = self.normal() * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let a: Vec<u64> = (0..10).map(|_| 0).scan(Rng::new(42), |r, _| Some(r.next_u64())).collect();
        let b: Vec<u64> = (0..10).map(|_| 0).scan(Rng::new(42), |r, _| Some(r.next_u64())).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn forks_are_independent() {
        let base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
        // same salt -> same stream
        let mut c = base.fork(1);
        let mut a2 = base.fork(1);
        assert_eq!(c.next_u64(), a2.next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.next_f32() as f64).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
        // all residues hit
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
