//! Tiny CLI argument parser: `command subcommand --key value --flag`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Opts {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Opts {
    /// Parse from an iterator of args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Opts> {
        let mut o = Opts::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    o.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    o.flags.insert(key.to_string(), it.next().unwrap());
                } else {
                    o.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                o.positional.push(a);
            }
        }
        Ok(o)
    }

    pub fn from_env() -> Result<Opts> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| anyhow!("--{key}: cannot parse {v:?} as {}", std::any::type_name::<T>())),
        }
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.str_opt(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Opts {
        Opts::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let o = parse("train --model vgg_tiny --workers 4 --verbose");
        assert_eq!(o.pos(0), Some("train"));
        assert_eq!(o.str_opt("model"), Some("vgg_tiny"));
        assert_eq!(o.parse_or("workers", 1usize).unwrap(), 4);
        assert!(o.bool_flag("verbose"));
        assert!(!o.bool_flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let o = parse("simulate fig4 --minibatch=512");
        assert_eq!(o.parse_or("minibatch", 0u64).unwrap(), 512);
        assert_eq!(o.pos(1), Some("fig4"));
    }

    #[test]
    fn defaults_apply() {
        let o = parse("x");
        assert_eq!(o.parse_or("lr", 0.1f64).unwrap(), 0.1);
        assert_eq!(o.str_or("model", "vgg_tiny"), "vgg_tiny");
    }

    #[test]
    fn bad_number_is_error() {
        let o = parse("x --n abc");
        assert!(o.parse_or("n", 3usize).is_err());
    }
}
