//! In-tree substrates that would normally be external crates. This
//! workspace builds fully offline (vendor/ holds only `xla` + `anyhow`),
//! so the JSON codec, deterministic PRNG, CLI parser and micro-bench
//! harness are implemented here (see DESIGN.md system inventory).

pub mod bench;
pub mod cli;
pub mod json;
pub mod par;
pub mod rng;
