//! Minimal JSON codec — enough for `artifacts/manifest.json`, the
//! `ExperimentSpec` files under `specs/`, and the `BENCH_*.json` result
//! files. Recursive-descent parser, no external deps.
//!
//! Emission guarantees (what `BENCH_*.json` consumers rely on):
//! * control characters in strings are `\u`-escaped;
//! * non-finite floats (NaN/±inf) serialize as `null` — `{}` formatting
//!   of `f64` would otherwise emit invalid JSON;
//! * object keys are sorted (BTreeMap), so serialization is stable and
//!   reports can be compared bit-for-bit.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors ----
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// `[1,2,3]` -> `vec![1,2,3]` (for shape lists).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Human-readable serialization (2-space indent) for committed files
    /// such as the `specs/` directory. Parses back to the same value.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.pretty_into(&mut s, 0);
        s
    }

    fn pretty_into(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(depth + 1));
                    x.pretty_into(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(depth));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(depth + 1));
                    out.push_str(&format!("{}: ", Json::Str(k.clone())));
                    v.pretty_into(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(depth));
                out.push('}');
            }
            other => out.push_str(&other.to_string()),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // copy raw utf-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len() && self.b[self.i] & 0xc0 == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

/// Serialize (stable key order via BTreeMap).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // NaN/±inf have no JSON representation; `null` keeps
                    // the emitted report parseable (readers map it back
                    // to NaN — see experiment::report).
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{
            "version": 1,
            "artifacts": {
                "m": {"hlo": "m.hlo.txt", "inputs": [{"name": "x", "shape": [2, 3], "dtype": "f32"}]}
            }
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize().unwrap(), 1);
        let inputs = j.get("artifacts").unwrap().get("m").unwrap().get("inputs").unwrap();
        let shape = inputs.as_arr().unwrap()[0].get("shape").unwrap().as_usize_vec().unwrap();
        assert_eq!(shape, vec![2, 3]);
    }

    #[test]
    fn parses_scalars_and_negatives() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64().unwrap(), -1500.0);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = Json::Str("a\"b\\c\nd\té".into());
        let round = Json::parse(&s.to_string()).unwrap();
        assert_eq!(round, s);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn display_roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, {"b": "x"}], "c": null}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn non_finite_floats_emit_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::Num(bad).to_string();
            assert_eq!(s, "null", "{bad} -> {s}");
            // the emitted document must stay parseable
            assert_eq!(Json::parse(&s).unwrap(), Json::Null);
        }
        let mut m = std::collections::BTreeMap::new();
        m.insert("eff".to_string(), Json::Num(f64::NAN));
        m.insert("ok".to_string(), Json::Num(1.5));
        let doc = Json::Obj(m).to_string();
        assert_eq!(doc, r#"{"eff":null,"ok":1.5}"#);
        assert!(Json::parse(&doc).is_ok());
    }

    #[test]
    fn control_characters_escape_and_roundtrip() {
        let s = Json::Str("a\u{1}b\u{7}c\u{1f}\n\t".into());
        let enc = s.to_string();
        assert!(enc.contains("\\u0001") && enc.contains("\\u0007") && enc.contains("\\u001f"));
        // no raw control byte may reach the wire
        assert!(enc.chars().all(|c| c as u32 >= 0x20));
        assert_eq!(Json::parse(&enc).unwrap(), s);
    }

    #[test]
    fn pretty_roundtrips_and_indents() {
        let j = Json::parse(r#"{"a":[1,{"b":"x"},null],"c":{},"d":[]}"#).unwrap();
        let p = j.pretty();
        assert!(p.contains("\n  \"a\": ["));
        assert_eq!(Json::parse(&p).unwrap(), j);
    }

    #[test]
    fn empty_shape_is_scalar() {
        let j = Json::parse(r#"{"shape": []}"#).unwrap();
        assert_eq!(j.get("shape").unwrap().as_usize_vec().unwrap(), Vec::<usize>::new());
    }
}
