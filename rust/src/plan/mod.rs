//! First-class parallelization plans (paper §3.1–3.3).
//!
//! The paper's headline contribution is not one scaling number but a
//! *design point* per network: which layer groups run data-parallel,
//! which run model/hybrid-parallel with what group shape, and which
//! collective serves each exchange. [`PartitionPlan`] makes that decision
//! a serde-able value — a per-layer-group assignment of strategy
//! ([`Strategy`]), collective choice and overlap behavior — consumed
//! unchanged by every backend:
//!
//! * the **analytic** balance equations cost a given plan instead of
//!   re-deriving the recipe inline (`netsim::cluster::simulate_training`);
//! * the **netsim** fleet simulator builds its per-message DAG from the
//!   plan (`simulate_training_fleet`);
//! * the **runtime** trainer executes the plan's shard-owner exchange
//!   over the shared-memory gradient buffers (`trainer`/`coordinator`).
//!
//! Plans come from three places: the fixed paper recipe
//! ([`PartitionPlan::paper_recipe`], §3.1–3.3), pure data parallelism
//! ([`PartitionPlan::data_parallel`], the ablation), or the design-point
//! search in [`planner`] (`repro plan`, `parallelism.mode = "auto"`).
//! Specs may also pin explicit per-group assignments ([`PlanPin`],
//! applied by [`apply_pins`]) on top of any of those.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::analytic::comm_model::{self, Strategy};
use crate::experiment::registry;
use crate::models::NetDescriptor;
use crate::netsim::collective::Choice;
use crate::util::json::Json;

pub mod cache;
pub mod planner;

pub use cache::{CacheOutcome, PlanCache};

/// Registry-style names of the per-layer strategies.
pub const STRATEGIES: &[&str] = &["data", "model", "hybrid"];

/// Canonical name of a strategy (the plan/spec JSON vocabulary).
pub fn strategy_name(s: Strategy) -> &'static str {
    match s {
        Strategy::Data => "data",
        Strategy::Model => "model",
        Strategy::Hybrid { .. } => "hybrid",
    }
}

/// One contiguous run of weighted layers sharing an assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerGroup {
    /// Group label (the first member layer's name) — what reports and
    /// `--set plan.<name>.<field>` refer to.
    pub name: String,
    /// Exact names of the member layers, in network order.
    pub layers: Vec<String>,
    pub strategy: Strategy,
    /// Collective algorithm for this group's exchanges; `None` inherits
    /// the experiment-level choice.
    pub collective: Option<Choice>,
    /// Send/recv overlap assumed when this assignment was derived.
    pub overlap: f64,
}

/// A full parallelization plan for one (network, nodes, minibatch) point.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionPlan {
    /// Provenance: `data` | `recipe` | `auto` | `pinned`.
    pub mode: String,
    pub nodes: u64,
    pub minibatch: u64,
    /// Per-layer-group assignments. Layers not covered — and any plan
    /// with no assignments at all — run data-parallel.
    pub assignments: Vec<LayerGroup>,
}

/// Degenerate hybrid shapes collapse to their named equivalents so that
/// structurally-equal plans compare equal. Only the exact boundary
/// shapes collapse (G = N is data parallelism, G = 1 is model
/// parallelism); out-of-range group counts survive for `validate` to
/// reject instead of being silently rewritten.
fn normalize(s: Strategy, nodes: u64) -> Strategy {
    match s {
        Strategy::Hybrid { groups } if groups == nodes.max(1) => Strategy::Data,
        Strategy::Hybrid { groups: 1 } => Strategy::Model,
        other => other,
    }
}

impl PartitionPlan {
    /// A plan with no assignments: every layer runs data-parallel (the
    /// default for single-node configs, where nothing is exchanged).
    pub fn empty(nodes: u64, minibatch: u64) -> Self {
        PartitionPlan { mode: "data".into(), nodes, minibatch, assignments: Vec::new() }
    }

    /// Pure data parallelism over every weighted layer (the ablation).
    pub fn data_parallel(net: &NetDescriptor, nodes: u64, minibatch: u64) -> Self {
        let per: Vec<_> = net
            .layers
            .iter()
            .filter(|l| l.is_weighted())
            .map(|l| (l.name.clone(), Strategy::Data, None, 1.0))
            .collect();
        Self::from_assignments("data", nodes, minibatch, &per)
    }

    /// The paper's fixed recipe: data parallelism on the conv trunk,
    /// per-layer best of data/model/hybrid (§3.2 rule + §3.3 optimal
    /// group count) on the FC head.
    pub fn paper_recipe(net: &NetDescriptor, nodes: u64, minibatch: u64, overlap: f64) -> Self {
        let per: Vec<_> = net
            .layers
            .iter()
            .filter(|l| l.is_weighted())
            .map(|l| {
                let s = if nodes <= 1 {
                    Strategy::Data
                } else {
                    comm_model::best_strategy(l, minibatch, nodes, overlap)
                };
                (l.name.clone(), s, None, overlap)
            })
            .collect();
        Self::from_assignments("recipe", nodes, minibatch, &per)
    }

    /// Build a plan from a per-layer assignment list, merging contiguous
    /// layers with identical assignments into one group (named after the
    /// group's first layer).
    pub fn from_assignments(
        mode: &str,
        nodes: u64,
        minibatch: u64,
        per_layer: &[(String, Strategy, Option<Choice>, f64)],
    ) -> Self {
        let mut assignments: Vec<LayerGroup> = Vec::new();
        for (layer, strategy, collective, overlap) in per_layer {
            let strategy = normalize(*strategy, nodes);
            match assignments.last_mut() {
                Some(g)
                    if g.strategy == strategy
                        && g.collective == *collective
                        && g.overlap == *overlap =>
                {
                    g.layers.push(layer.clone());
                }
                _ => assignments.push(LayerGroup {
                    name: layer.clone(),
                    layers: vec![layer.clone()],
                    strategy,
                    collective: *collective,
                    overlap: *overlap,
                }),
            }
        }
        PartitionPlan { mode: mode.to_string(), nodes, minibatch, assignments }
    }

    // ---- lookups ------------------------------------------------------

    pub fn assignment_for(&self, layer: &str) -> Option<&LayerGroup> {
        self.assignments.iter().find(|g| g.layers.iter().any(|l| l == layer))
    }

    /// Assignment for a runtime parameter tensor named `<layer>.<suffix>`
    /// (manifest params are `fc0.w` / `fc0.b` for zoo layer `fc0`, and
    /// `b0.qkv.w` for the dotted transformer layer `b0.qkv` — so try the
    /// whole name first, then strip the final segment).
    pub fn assignment_for_param(&self, param: &str) -> Option<&LayerGroup> {
        self.assignment_for(param)
            .or_else(|| param.rsplit_once('.').and_then(|(layer, _)| self.assignment_for(layer)))
    }

    /// Strategy for a layer; uncovered layers run data-parallel.
    pub fn strategy_for(&self, layer: &str) -> Strategy {
        self.assignment_for(layer).map(|g| g.strategy).unwrap_or(Strategy::Data)
    }

    /// Per-group collective override, if pinned.
    pub fn collective_for(&self, layer: &str) -> Option<Choice> {
        self.assignment_for(layer).and_then(|g| g.collective)
    }

    /// True when every assignment (if any) is plain data parallelism.
    pub fn is_pure_data(&self) -> bool {
        self.assignments.iter().all(|g| g.strategy == Strategy::Data)
    }

    /// Check the plan against a network: every named layer must exist,
    /// carry weights, and appear once; hybrid group counts must divide
    /// the node count.
    pub fn validate(&self, net: &NetDescriptor) -> Result<()> {
        let mut seen: Vec<&str> = Vec::new();
        for g in &self.assignments {
            if g.layers.is_empty() {
                bail!("plan group {:?} has no layers", g.name);
            }
            for lname in &g.layers {
                let layer = net.layers.iter().find(|l| &l.name == lname).ok_or_else(|| {
                    anyhow!(
                        "plan group {:?} names unknown layer {lname:?} of {:?} (weighted \
                         layers: {})",
                        g.name,
                        net.name,
                        net.layers
                            .iter()
                            .filter(|l| l.is_weighted())
                            .map(|l| l.name.as_str())
                            .collect::<Vec<_>>()
                            .join(", ")
                    )
                })?;
                if !layer.is_weighted() {
                    bail!("plan group {:?}: layer {lname:?} has no weights to partition", g.name);
                }
                if seen.contains(&lname.as_str()) {
                    bail!("layer {lname:?} appears in more than one plan group");
                }
                seen.push(lname.as_str());
            }
            if let Strategy::Hybrid { groups } = g.strategy {
                if groups == 0 || groups > self.nodes || self.nodes % groups != 0 {
                    bail!(
                        "plan group {:?}: hybrid groups {groups} must divide nodes {}",
                        g.name,
                        self.nodes
                    );
                }
            }
        }
        Ok(())
    }

    /// Re-target this plan at a degraded node count (failure recovery's
    /// `shrink` policy, and the builder-side fallback for `replan`):
    /// hybrid group counts that no longer divide the new N snap to the
    /// nearest divisor (ties toward fewer groups), then the §3.3
    /// degenerate shapes collapse to their named equivalents via the
    /// shared normalization (G = N → data, G = 1 → model). Strategies,
    /// collectives and overlap are otherwise preserved; `minibatch`
    /// stays global (the batch is respread over the survivors).
    pub fn renormalize_for(&self, nodes: u64) -> PartitionPlan {
        if nodes <= 1 {
            return PartitionPlan::empty(nodes.max(1), self.minibatch);
        }
        let nearest_divisor = |g: u64| -> u64 {
            (1..=nodes)
                .filter(|d| nodes % d == 0)
                .min_by_key(|&d| (d.abs_diff(g), d))
                .unwrap_or(1)
        };
        let per: Vec<(String, Strategy, Option<Choice>, f64)> = self
            .assignments
            .iter()
            .flat_map(|g| {
                let strategy = match g.strategy {
                    Strategy::Hybrid { groups } => {
                        Strategy::Hybrid { groups: nearest_divisor(groups) }
                    }
                    other => other,
                };
                g.layers
                    .iter()
                    .map(move |l| (l.clone(), strategy, g.collective, g.overlap))
            })
            .collect();
        PartitionPlan::from_assignments("shrink", nodes, self.minibatch, &per)
    }

    /// The plan as exact-layer spec pins (`ExperimentSpec.plan`), so any
    /// concrete plan can be forced through a spec — e.g. to replay the
    /// planner's choice on the netsim backend.
    pub fn as_pins(&self) -> BTreeMap<String, PlanPin> {
        let mut pins = BTreeMap::new();
        for g in &self.assignments {
            for layer in &g.layers {
                pins.insert(
                    layer.clone(),
                    PlanPin {
                        strategy: Some(strategy_name(g.strategy).to_string()),
                        groups: match g.strategy {
                            Strategy::Hybrid { groups } => Some(groups),
                            _ => None,
                        },
                        collective: g.collective.map(|c| registry::collective_name(c).to_string()),
                        overlap: Some(g.overlap),
                    },
                );
            }
        }
        pins
    }

    /// Human-readable per-group table (the CLI's plan printout).
    pub fn table(&self) -> crate::metrics::Table {
        let mut t = crate::metrics::Table::new(&[
            "group", "layers", "strategy", "G", "collective", "overlap",
        ]);
        for g in &self.assignments {
            let layers = if g.layers.len() <= 3 {
                g.layers.join(",")
            } else {
                format!("{}..{} ({})", g.layers[0], g.layers[g.layers.len() - 1], g.layers.len())
            };
            t.row(vec![
                g.name.clone(),
                layers,
                strategy_name(g.strategy).to_string(),
                match g.strategy {
                    Strategy::Hybrid { groups } => groups.to_string(),
                    _ => "-".into(),
                },
                g.collective
                    .map(|c| registry::collective_name(c).to_string())
                    .unwrap_or_else(|| "inherit".into()),
                format!("{}", g.overlap),
            ]);
        }
        t
    }

    // ---- JSON ---------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "assignments".to_string(),
            Json::Arr(self.assignments.iter().map(group_to_json).collect()),
        );
        m.insert("minibatch".to_string(), Json::Num(self.minibatch as f64));
        m.insert("mode".to_string(), Json::Str(self.mode.clone()));
        m.insert("nodes".to_string(), Json::Num(self.nodes as f64));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        check_keys(j, &["assignments", "minibatch", "mode", "nodes"], "plan")?;
        let mut assignments = Vec::new();
        match j.opt("assignments") {
            None | Some(Json::Null) => {}
            Some(a) => {
                for g in a.as_arr().context("plan \"assignments\"")? {
                    assignments.push(group_from_json(g)?);
                }
            }
        }
        Ok(PartitionPlan {
            mode: j.get("mode")?.as_str()?.to_string(),
            nodes: j.get("nodes")?.as_u64()?,
            minibatch: j.get("minibatch")?.as_u64()?,
            assignments,
        })
    }

    pub fn parse_str(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text).context("plan is not valid JSON")?)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("cannot read plan file {path:?}"))?;
        Self::parse_str(&text).with_context(|| format!("plan file {path:?}"))
    }
}

/// Reject misspelled/unknown keys (same failure contract as spec files).
fn check_keys(obj: &Json, allowed: &[&str], what: &str) -> Result<()> {
    if let Json::Obj(m) = obj {
        for k in m.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!("unknown {what} key {k:?} (expected one of: {})", allowed.join(", "));
            }
        }
        Ok(())
    } else {
        bail!("{what} must be a JSON object, got {obj:?}")
    }
}

fn group_to_json(g: &LayerGroup) -> Json {
    let mut m = BTreeMap::new();
    m.insert(
        "collective".to_string(),
        match g.collective {
            Some(c) => Json::Str(registry::collective_name(c).to_string()),
            None => Json::Null,
        },
    );
    m.insert(
        "groups".to_string(),
        match g.strategy {
            Strategy::Hybrid { groups } => Json::Num(groups as f64),
            _ => Json::Null,
        },
    );
    m.insert(
        "layers".to_string(),
        Json::Arr(g.layers.iter().map(|l| Json::Str(l.clone())).collect()),
    );
    m.insert("name".to_string(), Json::Str(g.name.clone()));
    m.insert("overlap".to_string(), Json::Num(g.overlap));
    m.insert("strategy".to_string(), Json::Str(strategy_name(g.strategy).to_string()));
    Json::Obj(m)
}

fn group_from_json(j: &Json) -> Result<LayerGroup> {
    check_keys(
        j,
        &["collective", "groups", "layers", "name", "overlap", "strategy"],
        "plan group",
    )?;
    let name = j.get("name")?.as_str()?.to_string();
    let mut layers = Vec::new();
    for l in j.get("layers")?.as_arr()? {
        layers.push(l.as_str()?.to_string());
    }
    let strategy = match j.get("strategy")?.as_str()? {
        "data" => Strategy::Data,
        "model" => Strategy::Model,
        "hybrid" => match j.opt("groups") {
            Some(v @ Json::Num(_)) => Strategy::Hybrid { groups: v.as_u64()? },
            _ => bail!("plan group {name:?}: strategy \"hybrid\" requires \"groups\""),
        },
        other => bail!(
            "plan group {name:?}: unknown strategy {other:?} (available: {})",
            STRATEGIES.join("|")
        ),
    };
    if !matches!(strategy, Strategy::Hybrid { .. })
        && matches!(j.opt("groups"), Some(Json::Num(_)))
    {
        bail!("plan group {name:?}: \"groups\" only applies to strategy \"hybrid\"");
    }
    let collective = match j.opt("collective") {
        None | Some(Json::Null) => None,
        Some(v) => Some(registry::collective(
            v.as_str().with_context(|| format!("plan group {name:?} collective"))?,
        )?),
    };
    let overlap = match j.opt("overlap") {
        None | Some(Json::Null) => 1.0,
        Some(v) => v.as_f64().with_context(|| format!("plan group {name:?} overlap"))?,
    };
    Ok(LayerGroup { name, layers, strategy, collective, overlap })
}

// ---------------------------------------------------------------------
// Spec-level pins
// ---------------------------------------------------------------------

/// One spec-level pin: a partial assignment overriding the mode-derived
/// plan for every weighted layer whose name starts with the pin's key
/// (`"fc"` matches `fc6`/`fc7`/`fc8`; more specific keys win).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PlanPin {
    /// `data` | `model` | `hybrid`; `None` keeps the derived strategy
    /// (unless `groups` is set, which implies `hybrid`).
    pub strategy: Option<String>,
    /// Hybrid group count; omitted = the §3.3 optimum for the layer.
    pub groups: Option<u64>,
    /// Collective name (`ring`/`butterfly`/`auto`); `None` inherits.
    pub collective: Option<String>,
    pub overlap: Option<f64>,
}

/// Field names of a pin, sorted (the spec `plan.<group>` sub-schema).
pub const PIN_FIELDS: &[&str] = &["collective", "groups", "overlap", "strategy"];

impl PlanPin {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "collective".to_string(),
            match &self.collective {
                Some(c) => Json::Str(c.clone()),
                None => Json::Null,
            },
        );
        m.insert(
            "groups".to_string(),
            match self.groups {
                Some(g) => Json::Num(g as f64),
                None => Json::Null,
            },
        );
        m.insert(
            "overlap".to_string(),
            match self.overlap {
                Some(o) => Json::Num(o),
                None => Json::Null,
            },
        );
        m.insert(
            "strategy".to_string(),
            match &self.strategy {
                Some(s) => Json::Str(s.clone()),
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        check_keys(j, PIN_FIELDS, "plan pin")?;
        let pin = PlanPin {
            strategy: match j.opt("strategy") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_str().context("pin strategy")?.to_string()),
            },
            groups: match j.opt("groups") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_u64().context("pin groups")?),
            },
            collective: match j.opt("collective") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_str().context("pin collective")?.to_string()),
            },
            overlap: match j.opt("overlap") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().context("pin overlap")?),
            },
        };
        pin.validate()?;
        Ok(pin)
    }

    /// Registry-style early name validation.
    pub fn validate(&self) -> Result<()> {
        if let Some(s) = &self.strategy {
            if !STRATEGIES.contains(&s.as_str()) {
                bail!("unknown plan strategy {s:?} (available: {})", STRATEGIES.join("|"));
            }
            if s != "hybrid" && self.groups.is_some() {
                bail!("plan \"groups\" only applies to strategy \"hybrid\"");
            }
        }
        if let Some(c) = &self.collective {
            registry::collective(c)?;
        }
        if self.groups == Some(0) {
            bail!("plan \"groups\" must be >= 1");
        }
        Ok(())
    }
}

/// Validate pin keys/values against a network without building a plan:
/// every key must match at least one weighted layer and every pin's
/// names must resolve. Used where the plan itself is trivial (1-node
/// baselines) so a typo'd pin still fails loudly.
pub fn check_pins(pins: &BTreeMap<String, PlanPin>, net: &NetDescriptor) -> Result<()> {
    for (key, pin) in pins {
        pin.validate()?;
        let matched = net
            .layers
            .iter()
            .any(|l| l.is_weighted() && l.name.starts_with(key.as_str()));
        if !matched {
            bail!(
                "plan key {key:?} matches no weighted layer of {:?} (weighted layers: {})",
                net.name,
                net.layers
                    .iter()
                    .filter(|l| l.is_weighted())
                    .map(|l| l.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }
    }
    Ok(())
}

/// Apply spec-level pins on top of a mode-derived base plan.
pub fn apply_pins(
    base: &PartitionPlan,
    pins: &BTreeMap<String, PlanPin>,
    net: &NetDescriptor,
) -> Result<PartitionPlan> {
    if pins.is_empty() {
        return Ok(base.clone());
    }
    let (nodes, minibatch) = (base.nodes, base.minibatch);
    let weighted: Vec<&crate::models::Layer> =
        net.layers.iter().filter(|l| l.is_weighted()).collect();
    let mut per: Vec<(String, Strategy, Option<Choice>, f64)> = weighted
        .iter()
        .map(|l| match base.assignment_for(&l.name) {
            Some(g) => (l.name.clone(), g.strategy, g.collective, g.overlap),
            None => (l.name.clone(), Strategy::Data, None, 1.0),
        })
        .collect();
    // least-specific (shortest) keys first, so `plan.fc` then `plan.fc8`
    // leaves fc8 with the more specific assignment
    let mut keys: Vec<&String> = pins.keys().collect();
    keys.sort_by(|a, b| a.len().cmp(&b.len()).then_with(|| a.cmp(b)));
    for key in keys {
        let pin = &pins[key.as_str()];
        pin.validate()?;
        let matched: Vec<usize> = per
            .iter()
            .enumerate()
            .filter(|(_, (name, ..))| name.starts_with(key.as_str()))
            .map(|(i, _)| i)
            .collect();
        if matched.is_empty() {
            bail!(
                "plan key {key:?} matches no weighted layer of {:?} (weighted layers: {})",
                net.name,
                weighted.iter().map(|l| l.name.as_str()).collect::<Vec<_>>().join(", ")
            );
        }
        for i in matched {
            let layer = weighted[i];
            let overlap_now = pin.overlap.unwrap_or(per[i].3);
            let entry = &mut per[i];
            match pin.strategy.as_deref() {
                Some("data") => entry.1 = Strategy::Data,
                Some("model") => entry.1 = Strategy::Model,
                Some("hybrid") => {
                    let g = match pin.groups {
                        Some(g) => g,
                        None => {
                            comm_model::optimal_groups(layer, minibatch, nodes.max(1), overlap_now)
                        }
                    };
                    entry.1 = Strategy::Hybrid { groups: g };
                }
                Some(other) => bail!(
                    "unknown plan strategy {other:?} (available: {})",
                    STRATEGIES.join("|")
                ),
                None => {
                    if let Some(g) = pin.groups {
                        entry.1 = Strategy::Hybrid { groups: g };
                    }
                }
            }
            if let Some(c) = &pin.collective {
                entry.2 = Some(registry::collective(c)?);
            }
            if let Some(o) = pin.overlap {
                entry.3 = o;
            }
        }
    }
    let plan = PartitionPlan::from_assignments("pinned", nodes, minibatch, &per);
    plan.validate(net)?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn recipe_matches_best_strategy_per_layer() {
        let net = zoo::vgg_a();
        let plan = PartitionPlan::paper_recipe(&net, 64, 512, 1.0);
        plan.validate(&net).unwrap();
        for l in net.layers.iter().filter(|l| l.is_weighted()) {
            let want = comm_model::best_strategy(l, 512, 64, 1.0);
            assert_eq!(plan.strategy_for(&l.name), want, "{}", l.name);
        }
        // conv trunk data-parallel, merged into the leading group
        let first = &plan.assignments[0];
        assert_eq!(first.strategy, Strategy::Data);
        assert!(first.layers.iter().all(|n| n.starts_with("conv")));
        // FC head is hybrid/model — not data
        assert_ne!(plan.strategy_for("fc6"), Strategy::Data);
    }

    #[test]
    fn uncovered_layers_default_to_data() {
        let plan = PartitionPlan::empty(8, 256);
        assert_eq!(plan.strategy_for("anything"), Strategy::Data);
        assert!(plan.is_pure_data());
    }

    #[test]
    fn degenerate_hybrids_normalize() {
        let per = vec![
            ("a".to_string(), Strategy::Hybrid { groups: 8 }, None, 1.0),
            ("b".to_string(), Strategy::Hybrid { groups: 1 }, None, 1.0),
        ];
        let plan = PartitionPlan::from_assignments("pinned", 8, 256, &per);
        assert_eq!(plan.strategy_for("a"), Strategy::Data);
        assert_eq!(plan.strategy_for("b"), Strategy::Model);
    }

    #[test]
    fn contiguous_equal_assignments_merge() {
        let net = zoo::cddnn_full();
        let plan = PartitionPlan::data_parallel(&net, 16, 1024);
        assert_eq!(plan.assignments.len(), 1);
        assert_eq!(plan.assignments[0].layers.len(), 8);
        assert_eq!(plan.assignments[0].name, "h0");
    }

    #[test]
    fn json_roundtrip_is_byte_identical() {
        let net = zoo::vgg_a();
        for plan in [
            PartitionPlan::paper_recipe(&net, 64, 512, 1.0),
            PartitionPlan::data_parallel(&net, 8, 256),
            PartitionPlan::empty(1, 256),
        ] {
            let text = plan.to_json().to_string();
            let back = PartitionPlan::parse_str(&text).unwrap();
            assert_eq!(back, plan);
            assert_eq!(back.to_json().to_string(), text);
            // and through the pretty printer (golden-file form)
            let back2 = PartitionPlan::parse_str(&plan.to_json().pretty()).unwrap();
            assert_eq!(back2.to_json().to_string(), text);
        }
    }

    #[test]
    fn plan_json_rejects_bad_shapes() {
        assert!(PartitionPlan::parse_str(r#"{"mode":"data"}"#).is_err()); // missing keys
        assert!(PartitionPlan::parse_str(
            r#"{"assignments":[],"minibatch":256,"mode":"data","nodes":8,"extra":1}"#
        )
        .is_err());
        // hybrid without groups
        let bad = r#"{"assignments":[{"collective":null,"groups":null,"layers":["fc6"],
            "name":"fc6","overlap":1,"strategy":"hybrid"}],
            "minibatch":256,"mode":"pinned","nodes":8}"#;
        assert!(PartitionPlan::parse_str(bad).is_err());
    }

    #[test]
    fn validate_catches_unknown_layers_and_bad_groups() {
        let net = zoo::vgg_a();
        let mut plan = PartitionPlan::paper_recipe(&net, 64, 512, 1.0);
        plan.assignments[0].layers.push("nope".into());
        let e = plan.validate(&net).unwrap_err().to_string();
        assert!(e.contains("fc6"), "{e}"); // inventory listed
        let mut plan = PartitionPlan::paper_recipe(&net, 64, 512, 1.0);
        plan.assignments[1].strategy = Strategy::Hybrid { groups: 7 };
        assert!(plan.validate(&net).is_err());
        // pools carry no weights
        let per = vec![("pool1".to_string(), Strategy::Data, None, 1.0)];
        let plan = PartitionPlan::from_assignments("pinned", 64, 512, &per);
        assert!(plan.validate(&net).is_err());
    }

    #[test]
    fn pins_override_by_prefix_and_specificity() {
        let net = zoo::vgg_a();
        let base = PartitionPlan::paper_recipe(&net, 64, 512, 1.0);
        let mut pins = BTreeMap::new();
        pins.insert(
            "fc".to_string(),
            PlanPin { groups: Some(8), ..Default::default() },
        );
        pins.insert(
            "fc8".to_string(),
            PlanPin { strategy: Some("data".into()), ..Default::default() },
        );
        let plan = apply_pins(&base, &pins, &net).unwrap();
        assert_eq!(plan.strategy_for("fc6"), Strategy::Hybrid { groups: 8 });
        assert_eq!(plan.strategy_for("fc7"), Strategy::Hybrid { groups: 8 });
        assert_eq!(plan.strategy_for("fc8"), Strategy::Data);
        // conv trunk untouched
        assert_eq!(plan.strategy_for("conv1"), Strategy::Data);
        assert_eq!(plan.mode, "pinned");
    }

    #[test]
    fn pins_reject_unknown_keys_groups_and_names() {
        let net = zoo::vgg_a();
        let base = PartitionPlan::paper_recipe(&net, 64, 512, 1.0);
        let mut pins = BTreeMap::new();
        pins.insert("frobnicate".to_string(), PlanPin::default());
        let e = apply_pins(&base, &pins, &net).unwrap_err().to_string();
        assert!(e.contains("conv1") && e.contains("fc8"), "{e}");
        // group count that does not divide the node count
        let mut pins = BTreeMap::new();
        pins.insert("fc6".to_string(), PlanPin { groups: Some(7), ..Default::default() });
        assert!(apply_pins(&base, &pins, &net).is_err());
        // out-of-range group count errors loudly instead of silently
        // collapsing to data parallelism
        let mut pins = BTreeMap::new();
        pins.insert("fc6".to_string(), PlanPin { groups: Some(128), ..Default::default() });
        let e = apply_pins(&base, &pins, &net).unwrap_err().to_string();
        assert!(e.contains("must divide"), "{e}");
        // bad names fail validation
        assert!(PlanPin { strategy: Some("async".into()), ..Default::default() }
            .validate()
            .is_err());
        assert!(PlanPin { collective: Some("nccl".into()), ..Default::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn renormalize_snaps_hybrid_groups_to_degraded_divisors() {
        let net = zoo::vgg_a();
        // every degraded count derived from the paper's node grid must
        // yield a valid plan (the shrink policy's §3.3 guarantee)
        for n in [8u64, 16, 32, 64, 128] {
            let plan = PartitionPlan::paper_recipe(&net, n, 512, 1.0);
            let shrunk = plan.renormalize_for(n - 1);
            assert_eq!(shrunk.nodes, n - 1);
            shrunk.validate(&net).unwrap_or_else(|e| panic!("n={n}: {e:#}"));
            for g in &shrunk.assignments {
                if let Strategy::Hybrid { groups } = g.strategy {
                    assert_eq!((n - 1) % groups, 0, "n={n} group {:?}", g.name);
                }
            }
        }
        // a hybrid shape that still divides is preserved; degenerates
        // collapse through the shared normalization
        let per = vec![
            ("a".to_string(), Strategy::Hybrid { groups: 3 }, None, 1.0),
            ("b".to_string(), Strategy::Hybrid { groups: 5 }, None, 1.0),
        ];
        let plan = PartitionPlan::from_assignments("pinned", 15, 256, &per);
        let shrunk = plan.renormalize_for(6);
        assert_eq!(shrunk.strategy_for("a"), Strategy::Hybrid { groups: 3 });
        // 5 snaps to 6's nearest divisor 6 == N, which normalizes to data
        assert_eq!(shrunk.strategy_for("b"), Strategy::Data);
        // single-survivor fleets have nothing to partition
        assert!(plan.renormalize_for(1).is_pure_data());
    }

    #[test]
    fn renormalize_edge_cases_collapse_to_named_equivalents() {
        // N-1 == 1: a 2-node fleet losing a node collapses to the empty
        // (pure-data) single-node plan, keeping the global minibatch so
        // the trainer can still respread it
        let net = zoo::vgg_a();
        let plan = PartitionPlan::paper_recipe(&net, 2, 512, 1.0);
        let one = plan.renormalize_for(1);
        assert_eq!(one.nodes, 1);
        assert_eq!(one.minibatch, 512);
        assert!(one.is_pure_data());
        // nodes == 0 is clamped rather than building a 0-node plan
        assert_eq!(plan.renormalize_for(0).nodes, 1);

        // hybrid G snapping to the new N collapses to data; snapping to
        // 1 collapses to model — both §3.3 degenerations, post-snap
        let per = vec![
            // 7 is closest to 8's divisor 8 (|8-7| < |4-7|) → data
            ("gn".to_string(), Strategy::Hybrid { groups: 7 }, None, 1.0),
            // 1 divides everything and stays 1 → model
            ("g1".to_string(), Strategy::Hybrid { groups: 1 }, None, 1.0),
            // survivors of an explicit strategy keep it verbatim
            ("keep".to_string(), Strategy::Model, None, 0.5),
        ];
        let plan = PartitionPlan::from_assignments("pinned", 9, 256, &per);
        let shrunk = plan.renormalize_for(8);
        assert_eq!(shrunk.mode, "shrink");
        assert_eq!(shrunk.strategy_for("gn"), Strategy::Data);
        assert_eq!(shrunk.strategy_for("g1"), Strategy::Model);
        assert_eq!(shrunk.strategy_for("keep"), Strategy::Model);
        // overlap riding along unchanged
        let keep = shrunk.assignment_for("keep").expect("keep group survives");
        assert_eq!(keep.overlap, 0.5);
    }

    #[test]
    fn as_pins_roundtrips_through_apply() {
        let net = zoo::vgg_a();
        let plan = PartitionPlan::paper_recipe(&net, 64, 512, 1.0);
        let pins = plan.as_pins();
        let base = PartitionPlan::data_parallel(&net, 64, 512);
        let back = apply_pins(&base, &pins, &net).unwrap();
        assert_eq!(back.assignments, plan.assignments);
    }

    #[test]
    fn param_names_resolve_to_their_layer() {
        let net = zoo::vgg_tiny();
        let plan = PartitionPlan::paper_recipe(&net, 4, 16, 1.0);
        let via_param = plan.assignment_for_param("fc0.w").map(|g| g.strategy);
        let via_layer = plan.assignment_for("fc0").map(|g| g.strategy);
        assert_eq!(via_param, via_layer);
        assert!(via_param.is_some());
        // dotted layer names (transformer zoo: layer "b0.qkv", params
        // "b0.qkv.w"/"b0.qkv.b") must resolve too
        let gpt = zoo::gpt_descriptor("gpt_mini", 384, 2, 128);
        let plan = PartitionPlan::data_parallel(&gpt, 4, 16);
        for p in ["b0.qkv.w", "b0.qkv.b", "b1.mlp2.w", "lm_head.w"] {
            assert!(plan.assignment_for_param(p).is_some(), "{p}");
        }
    }
}
