//! Content-addressed cache for [`PlanSearch`] results under
//! `artifacts/plans/` (the ROADMAP "caching planner results" item).
//!
//! `repro plan`, the fig4/6/7 benches and the CI plan job all re-derive
//! the same design points; the search itself is pure, so its result is a
//! function of exactly: the resolved network, the resolved platform
//! (machine + fabric, including any spec congestion override), the node
//! count, the global minibatch, the assumed send/recv overlap, the
//! collective policy and the pricing iteration count. The cache key is a
//! canonical JSON object over those inputs — the network and platform
//! enter as content fingerprints, not names, so an edited zoo model or a
//! retuned fabric constant misses instead of serving a stale plan — plus
//! a compile-time fingerprint of the planner/cost-model source itself,
//! so a cache directory that survives a code change (CI `restore-keys`,
//! a local checkout after `git pull`) invalidates automatically.
//!
//! Layout: one file per key,
//! `<dir>/<model>_<fabric>_n<nodes>_mb<minibatch>_<hash16>.json`,
//! holding `{ "key": ..., "search": ... }`. A lookup re-checks the full
//! embedded key (not just the filename hash), and any unreadable,
//! unparseable or mismatched file is treated as a miss — corruption
//! recomputes, never crashes.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::analytic::comm_model::Strategy;
use crate::experiment::registry;
use crate::util::json::Json;

use super::planner::{CandidateCost, LayerDecision, PlanSearch, PlannerInput};
use super::{strategy_name, PartitionPlan};

/// FNV-1a 64 over the canonical key bytes (stable, dependency-free).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Compile-time fingerprint of the code that *produces* a `PlanSearch`:
/// the planner itself, the plan construction/normalization logic, the
/// end-to-end pricing simulator and every cost model it consults
/// (compute pass times, α-β collectives, group topology). Embedding the
/// source text means any algorithm change invalidates every cached entry
/// automatically — without it, a cache restored across commits (the CI
/// `plan` job's `restore-keys` fallback, or a local `artifacts/plans/`
/// surviving a `git pull`) would keep serving pre-change searches and
/// mask planner regressions from the golden gate.
fn code_fingerprint() -> u64 {
    fnv1a(
        concat!(
            include_str!("planner.rs"),
            include_str!("mod.rs"),
            include_str!("../netsim/engine.rs"),
            include_str!("../netsim/cluster.rs"),
            include_str!("../netsim/collective.rs"),
            include_str!("../analytic/machine.rs"),
            include_str!("../analytic/comm_model.rs"),
            include_str!("../analytic/compute_model.rs"),
            include_str!("../collectives/topology.rs"),
        )
        .as_bytes(),
    )
}

/// A resolved cache key: the canonical key document plus the file name
/// it addresses.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheKey {
    pub key: Json,
    pub file: String,
}

/// Where a cached search came from (the CLI's hit/miss line).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CacheOutcome {
    Hit(PathBuf),
    /// Computed fresh and written for next time.
    Miss(PathBuf),
    /// Computed fresh but the write failed (read-only checkout, full
    /// disk) — the next invocation will recompute again.
    Unwritable(PathBuf),
}

/// On-disk plan-search cache rooted at one directory.
#[derive(Debug, Clone)]
pub struct PlanCache {
    dir: PathBuf,
}

impl PlanCache {
    pub fn new(dir: impl Into<PathBuf>) -> PlanCache {
        PlanCache { dir: dir.into() }
    }

    /// The conventional location: `<artifacts>/plans/`.
    pub fn default_dir() -> PathBuf {
        crate::runtime::default_artifacts_dir().join("plans")
    }

    /// Canonical content key of one design point. `model` is the zoo (or
    /// inline) model name — display only; the addressed content is the
    /// resolved network and platform, which enter as `Debug`-format
    /// fingerprints (stable for fixed struct definitions, and any field
    /// change is exactly when a recompute is wanted).
    pub fn key(model: &str, input: &PlannerInput) -> CacheKey {
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "code_fingerprint".to_string(),
            Json::Str(format!("{:016x}", code_fingerprint())),
        );
        m.insert("collective".to_string(),
                 Json::Str(registry::collective_name(input.collective).to_string()));
        m.insert("fabric".to_string(), Json::Str(input.platform.fabric.name.clone()));
        m.insert("iterations".to_string(), Json::Num(input.iterations as f64));
        m.insert("minibatch".to_string(), Json::Num(input.minibatch as f64));
        m.insert("model".to_string(), Json::Str(model.to_string()));
        m.insert(
            "net_fingerprint".to_string(),
            Json::Str(format!("{:016x}", fnv1a(format!("{:?}", input.net).as_bytes()))),
        );
        m.insert("nodes".to_string(), Json::Num(input.nodes as f64));
        m.insert("overlap".to_string(), Json::Num(input.overlap));
        m.insert(
            "platform_fingerprint".to_string(),
            Json::Str(format!("{:016x}", fnv1a(format!("{:?}", input.platform).as_bytes()))),
        );
        let key = Json::Obj(m);
        let hash = fnv1a(key.to_string().as_bytes());
        // keep the file name readable and shell-safe: model names may be
        // inline descriptors, fabric names contain spaces
        let tag = |s: &str| -> String {
            s.chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
                .take(24)
                .collect()
        };
        let file = format!(
            "{}_{}_n{}_mb{}_{hash:016x}.json",
            tag(model),
            tag(&input.platform.fabric.name),
            input.nodes,
            input.minibatch
        );
        CacheKey { key, file }
    }

    pub fn path_for(&self, key: &CacheKey) -> PathBuf {
        self.dir.join(&key.file)
    }

    /// Cached search for `key`, or `None` on any miss — absent file,
    /// unparseable JSON, or an embedded key that does not match (hash
    /// collision or stale schema).
    pub fn lookup(&self, key: &CacheKey) -> Option<PlanSearch> {
        let path = self.path_for(key);
        let text = std::fs::read_to_string(&path).ok()?;
        let doc = Json::parse(&text).ok()?;
        if doc.opt("key") != Some(&key.key) {
            return None;
        }
        search_from_json(doc.opt("search")?).ok()
    }

    /// Persist `search` under `key` (creates the cache dir on demand).
    pub fn store(&self, key: &CacheKey, search: &PlanSearch) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("cannot create plan cache dir {:?}", self.dir))?;
        let mut m = std::collections::BTreeMap::new();
        m.insert("key".to_string(), key.key.clone());
        m.insert("search".to_string(), search_to_json(search));
        let path = self.path_for(key);
        std::fs::write(&path, format!("{}\n", Json::Obj(m).pretty()))
            .with_context(|| format!("cannot write plan cache file {path:?}"))?;
        Ok(path)
    }

    /// The planner search through the cache: reuse a stored result when
    /// the content key matches, otherwise run the search and store it.
    /// Store failures degrade to an uncached search (a warning, not an
    /// error — a read-only checkout must still plan).
    pub fn plan_cached(&self, model: &str, input: &PlannerInput) -> (PlanSearch, CacheOutcome) {
        let key = Self::key(model, input);
        if let Some(search) = self.lookup(&key) {
            return (search, CacheOutcome::Hit(self.path_for(&key)));
        }
        let search = super::planner::plan(input);
        let outcome = match self.store(&key, &search) {
            Ok(p) => CacheOutcome::Miss(p),
            Err(e) => {
                eprintln!("note: plan cache write failed ({e:#}); continuing uncached");
                CacheOutcome::Unwritable(self.path_for(&key))
            }
        };
        (search, outcome)
    }
}

impl CacheOutcome {
    /// One-line summary for the CLI (`plan cache: hit <path>`).
    pub fn describe(&self) -> String {
        match self {
            CacheOutcome::Hit(p) => format!("hit {}", display_path(p)),
            CacheOutcome::Miss(p) => format!("miss (wrote {})", display_path(p)),
            CacheOutcome::Unwritable(p) => {
                format!("miss (write failed, not cached: {})", display_path(p))
            }
        }
    }
}

fn display_path(p: &Path) -> String {
    p.to_string_lossy().into_owned()
}

// ---------------------------------------------------------------------
// PlanSearch serialization
// ---------------------------------------------------------------------

fn strategy_to_json(s: Strategy) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "groups".to_string(),
        match s {
            Strategy::Hybrid { groups } => Json::Num(groups as f64),
            _ => Json::Null,
        },
    );
    m.insert("strategy".to_string(), Json::Str(strategy_name(s).to_string()));
    Json::Obj(m)
}

fn strategy_from_json(j: &Json) -> Result<Strategy> {
    Ok(match j.get("strategy")?.as_str()? {
        "data" => Strategy::Data,
        "model" => Strategy::Model,
        "hybrid" => Strategy::Hybrid { groups: j.get("groups")?.as_u64()? },
        other => anyhow::bail!("unknown cached strategy {other:?}"),
    })
}

pub fn search_to_json(s: &PlanSearch) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("chosen_iteration_s".to_string(), Json::Num(s.chosen_iteration_s));
    m.insert("data_iteration_s".to_string(), Json::Num(s.data_iteration_s));
    m.insert(
        "decisions".to_string(),
        Json::Arr(
            s.decisions
                .iter()
                .map(|d| {
                    let mut dm = std::collections::BTreeMap::new();
                    dm.insert(
                        "candidates".to_string(),
                        Json::Arr(
                            d.candidates
                                .iter()
                                .map(|c| {
                                    let mut cm = match strategy_to_json(c.strategy) {
                                        Json::Obj(cm) => cm,
                                        _ => unreachable!("strategy serializes to an object"),
                                    };
                                    cm.insert("comm_s".to_string(), Json::Num(c.comm_s));
                                    Json::Obj(cm)
                                })
                                .collect(),
                        ),
                    );
                    dm.insert("chosen".to_string(), strategy_to_json(d.chosen));
                    dm.insert("layer".to_string(), Json::Str(d.layer.clone()));
                    Json::Obj(dm)
                })
                .collect(),
        ),
    );
    m.insert("plan".to_string(), s.plan.to_json());
    m.insert("recipe_iteration_s".to_string(), Json::Num(s.recipe_iteration_s));
    Json::Obj(m)
}

pub fn search_from_json(j: &Json) -> Result<PlanSearch> {
    let mut decisions = Vec::new();
    for d in j.get("decisions")?.as_arr()? {
        let mut candidates = Vec::new();
        for c in d.get("candidates")?.as_arr()? {
            candidates.push(CandidateCost {
                strategy: strategy_from_json(c)?,
                comm_s: c.get("comm_s")?.as_f64()?,
            });
        }
        decisions.push(LayerDecision {
            layer: d.get("layer")?.as_str()?.to_string(),
            candidates,
            chosen: strategy_from_json(d.get("chosen")?)?,
        });
    }
    Ok(PlanSearch {
        plan: PartitionPlan::from_json(j.get("plan")?)?,
        decisions,
        chosen_iteration_s: j.get("chosen_iteration_s")?.as_f64()?,
        data_iteration_s: j.get("data_iteration_s")?.as_f64()?,
        recipe_iteration_s: j.get("recipe_iteration_s")?.as_f64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::machine::Platform;
    use crate::models::zoo;
    use crate::netsim::collective::Choice;

    fn tmp_dir(salt: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "pcl_dnn_plan_cache_{salt}_{}",
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn input<'a>(net: &'a crate::models::NetDescriptor, p: &'a Platform) -> PlannerInput<'a> {
        PlannerInput {
            net,
            platform: p,
            nodes: 8,
            minibatch: 256,
            overlap: 1.0,
            collective: Choice::Auto,
            iterations: 3,
        }
    }

    #[test]
    fn miss_then_hit_returns_the_same_search() {
        let dir = tmp_dir("roundtrip");
        let cache = PlanCache::new(&dir);
        let net = zoo::vgg_a();
        let p = Platform::cori();
        let inp = input(&net, &p);
        let (first, o1) = cache.plan_cached("vgg_a", &inp);
        assert!(matches!(o1, CacheOutcome::Miss(_)), "{o1:?}");
        let (second, o2) = cache.plan_cached("vgg_a", &inp);
        assert!(matches!(o2, CacheOutcome::Hit(_)), "{o2:?}");
        assert_eq!(first, second);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn key_distinguishes_every_input_dimension() {
        let net = zoo::vgg_a();
        let p = Platform::cori();
        let base = PlanCache::key("vgg_a", &input(&net, &p));
        let mut other = input(&net, &p);
        other.nodes = 16;
        assert_ne!(base, PlanCache::key("vgg_a", &other));
        let mut other = input(&net, &p);
        other.minibatch = 512;
        assert_ne!(base, PlanCache::key("vgg_a", &other));
        let mut other = input(&net, &p);
        other.collective = Choice::Ring;
        assert_ne!(base, PlanCache::key("vgg_a", &other));
        let mut other = input(&net, &p);
        other.overlap = 0.5;
        assert_ne!(base, PlanCache::key("vgg_a", &other));
        // a retuned fabric constant changes the platform fingerprint
        let mut p2 = Platform::cori();
        p2.fabric.latency_s *= 2.0;
        assert_ne!(base, PlanCache::key("vgg_a", &input(&net, &p2)));
        // a different network under the same name misses too
        let of = zoo::overfeat_fast();
        assert_ne!(base, PlanCache::key("vgg_a", &input(&of, &p)));
    }

    #[test]
    fn corrupted_cache_file_recomputes_instead_of_crashing() {
        let dir = tmp_dir("corrupt");
        let cache = PlanCache::new(&dir);
        let net = zoo::vgg_a();
        let p = Platform::cori();
        let inp = input(&net, &p);
        let key = PlanCache::key("vgg_a", &inp);
        for garbage in ["", "not json at all", "{\"key\": 1}", "[1,2,3]"] {
            std::fs::write(cache.path_for(&key), garbage).unwrap();
            assert!(cache.lookup(&key).is_none(), "garbage {garbage:?} must miss");
            let (search, outcome) = cache.plan_cached("vgg_a", &inp);
            assert!(matches!(outcome, CacheOutcome::Miss(_)));
            assert!(!search.plan.mode.is_empty());
            // the recompute repaired the file: next call hits
            let (_, o2) = cache.plan_cached("vgg_a", &inp);
            assert!(matches!(o2, CacheOutcome::Hit(_)));
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn write_failure_reports_unwritable_not_miss() {
        let dir = tmp_dir("unwritable");
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, "not a directory").unwrap();
        // cache dir nested under a regular file: create_dir_all must fail
        // for any user (chmod tricks are a no-op under root)
        let cache = PlanCache::new(blocker.join("plans"));
        let net = zoo::vgg_a();
        let p = Platform::cori();
        let (search, outcome) = cache.plan_cached("vgg_a", &input(&net, &p));
        assert!(matches!(outcome, CacheOutcome::Unwritable(_)), "{outcome:?}");
        assert!(!search.plan.mode.is_empty());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn search_json_roundtrips_exactly() {
        let net = zoo::cddnn_full();
        let p = Platform::endeavor();
        let mut inp = input(&net, &p);
        inp.nodes = 16;
        inp.minibatch = 1024;
        let search = crate::plan::planner::plan(&inp);
        let back = search_from_json(&search_to_json(&search)).unwrap();
        assert_eq!(back, search);
        // byte-stable serialization (BTreeMap keys + shortest-float repr)
        assert_eq!(search_to_json(&back).to_string(), search_to_json(&search).to_string());
    }
}
