//! Design-point planner: derive the optimal [`PartitionPlan`] for a
//! (network, platform, nodes, minibatch) point instead of replaying the
//! paper's fixed recipe.
//!
//! Per weighted layer the planner scores the candidate strategies with
//! the α-β collective models in *seconds* on the actual platform fabric:
//!
//! * **data** — gradient exchange of the full weight tensor over all N
//!   nodes (overlappable against remaining backward compute, §3.1);
//! * **model** — two activation allgathers of the full minibatch across
//!   all N nodes (on the critical path, §3.2) — considered only where
//!   the paper's §3.2 rule says model parallelism can win;
//! * **hybrid G\*** — the §3.3 exchange at the closed-form-scan optimal
//!   group count (`comm_model::optimal_groups`): gradient exchange of
//!   the 1/(N/G) weight shard across replica sets plus per-group
//!   activation allgathers.
//!
//! The per-layer winners form a candidate plan, which is then priced
//! end-to-end with the analytic backend (`simulate_training` — the same
//! §3.1 overlap DAG the netsim backend cross-checks) against the fixed
//! paper recipe and pure data parallelism; the cheapest wins. That final
//! argmin makes the planner *never analytically worse* than either
//! baseline — a property pinned by `tests/plan_tests.rs`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::analytic::comm_model::{self, Strategy};
use crate::analytic::machine::Platform;
use crate::analytic::FabricSpec;
use crate::models::{Layer, NetDescriptor};
use crate::netsim::cluster::{simulate_training, SimConfig};
use crate::netsim::collective::Choice;
use crate::util::json::Json;

use super::PartitionPlan;

/// Everything the search needs about one design point.
#[derive(Debug, Clone, Copy)]
pub struct PlannerInput<'a> {
    pub net: &'a NetDescriptor,
    pub platform: &'a Platform,
    pub nodes: u64,
    pub minibatch: u64,
    /// Send/recv overlap assumed by the §3.2/§3.3 derivations.
    pub overlap: f64,
    /// Collective-algorithm policy pricing the candidates.
    pub collective: Choice,
    /// Iterations for the end-to-end analytic pricing (>= 2).
    pub iterations: usize,
}

/// One scored candidate for one layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CandidateCost {
    pub strategy: Strategy,
    /// α-β communication seconds per iteration attributable to the layer.
    pub comm_s: f64,
}

/// The per-layer design-point row (the `repro plan` table).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerDecision {
    pub layer: String,
    /// Candidates in evaluation order: data, then (where the §3.2 rule
    /// admits them) model and hybrid at the §3.3 optimal group count.
    pub candidates: Vec<CandidateCost>,
    pub chosen: Strategy,
}

impl LayerDecision {
    pub fn cost_of(&self, kind: &str) -> Option<f64> {
        self.candidates
            .iter()
            .find(|c| super::strategy_name(c.strategy) == kind)
            .map(|c| c.comm_s)
    }
}

/// Search output: the chosen plan plus everything needed to report the
/// paper-style design-point table. (Serializable via `plan::cache` —
/// `repro plan`, the benches and CI reuse searches content-addressed
/// under `artifacts/plans/`.)
#[derive(Debug, Clone, PartialEq)]
pub struct PlanSearch {
    /// The winning plan (mode `auto`).
    pub plan: PartitionPlan,
    pub decisions: Vec<LayerDecision>,
    /// Analytic steady-state iteration seconds of the chosen plan.
    pub chosen_iteration_s: f64,
    /// Same spec under pure data parallelism.
    pub data_iteration_s: f64,
    /// Same spec under the fixed paper recipe.
    pub recipe_iteration_s: f64,
}

// ---------------------------------------------------------------------
// Canonical per-strategy α-β exchange costs. These are THE definition of
// what each strategy moves over the wire per iteration — the simulators
// (`netsim::cluster::{grad_exchange_s, act_exchange_s}`) and the
// planner's candidate scorer both call them, so the per-layer candidate
// ranking and the end-to-end pricing can never drift apart.
// ---------------------------------------------------------------------

/// Gradient/weight exchange seconds for one layer under `strategy`
/// (§3.1/§3.3): the full tensor over all N nodes for data parallelism,
/// nothing for model parallelism (weights stay put), the 1/(N/G) shard
/// across the G replica sets for hybrid.
pub fn strategy_grad_s(
    strategy: Strategy,
    layer: &Layer,
    fabric: &FabricSpec,
    choice: Choice,
    nodes: u64,
) -> f64 {
    match strategy {
        Strategy::Data => choice.gradient_exchange_s(fabric, layer.weight_bytes(), nodes),
        Strategy::Model => 0.0, // weights stay put; activations move instead
        Strategy::Hybrid { groups } => {
            let shard = layer.weight_bytes() / (nodes / groups).max(1);
            choice.gradient_exchange_s(fabric, shard, groups)
        }
    }
}

/// Activation allgather seconds for ONE leg (fwd or bwd) of one layer
/// under `strategy` (§3.2/§3.3): the full minibatch across all N nodes
/// for model parallelism, the group minibatch across the N/G-node group
/// for hybrid, nothing for data parallelism.
pub fn strategy_act_leg_s(
    strategy: Strategy,
    layer: &Layer,
    fabric: &FabricSpec,
    choice: Choice,
    nodes: u64,
    minibatch: u64,
) -> f64 {
    match strategy {
        Strategy::Data => 0.0,
        Strategy::Model => {
            choice.allgather_s(fabric, 4 * layer.in_elems() * minibatch, nodes)
        }
        Strategy::Hybrid { groups } => {
            let group_nodes = (nodes / groups).max(1);
            let bytes = 4 * layer.in_elems() * (minibatch / groups);
            choice.allgather_s(fabric, bytes, group_nodes)
        }
    }
}

/// Per-iteration comm seconds attributable to one layer under a
/// candidate strategy: the gradient exchange plus both activation legs.
fn candidate_cost(s: Strategy, l: &Layer, p: &Platform, c: Choice, n: u64, mb: u64) -> f64 {
    strategy_grad_s(s, l, &p.fabric, c, n) + 2.0 * strategy_act_leg_s(s, l, &p.fabric, c, n, mb)
}

/// Analytic price of a concrete plan (the planner's cost model — also
/// what `repro plan --check-golden` uses to detect plan regressions).
pub fn plan_cost_s(input: &PlannerInput, plan: &PartitionPlan) -> f64 {
    let cfg = SimConfig {
        nodes: input.nodes,
        minibatch: input.minibatch,
        iterations: input.iterations.max(2),
        plan: plan.clone(),
        collective: input.collective,
        degraded_plan: None,
        ..Default::default()
    };
    simulate_training(input.net, input.platform, &cfg)
        .expect("plan_cost_s clamps iterations to >= 2")
        .iteration_s
}

/// Exhaustive-over-layer-groups design-point search (see module docs).
pub fn plan(input: &PlannerInput) -> PlanSearch {
    let (n, mb) = (input.nodes, input.minibatch);
    let mut decisions = Vec::new();
    let mut per_layer: Vec<(String, Strategy, Option<Choice>, f64)> = Vec::new();
    for l in input.net.layers.iter().filter(|l| l.is_weighted()) {
        let cost = |s: Strategy| candidate_cost(s, l, input.platform, input.collective, n, mb);
        let mut candidates = vec![CandidateCost {
            strategy: Strategy::Data,
            comm_s: if n > 1 { cost(Strategy::Data) } else { 0.0 },
        }];
        if n > 1 && comm_model::model_beats_data(l, mb, input.overlap) {
            candidates.push(CandidateCost {
                strategy: Strategy::Model,
                comm_s: cost(Strategy::Model),
            });
            let g = comm_model::optimal_groups(l, mb, n, input.overlap);
            if g > 1 && g < n {
                candidates.push(CandidateCost {
                    strategy: Strategy::Hybrid { groups: g },
                    comm_s: cost(Strategy::Hybrid { groups: g }),
                });
            }
        }
        // ties keep the earliest candidate — data parallelism
        let chosen = candidates
            .iter()
            .min_by(|a, b| a.comm_s.total_cmp(&b.comm_s))
            .expect("non-empty candidate set")
            .strategy;
        decisions.push(LayerDecision { layer: l.name.clone(), candidates, chosen });
        per_layer.push((l.name.clone(), chosen, None, input.overlap));
    }
    let searched = PartitionPlan::from_assignments("auto", n, mb, &per_layer);
    let recipe = PartitionPlan::paper_recipe(input.net, n, mb, input.overlap);
    let data = PartitionPlan::data_parallel(input.net, n, mb);

    let searched_s = plan_cost_s(input, &searched);
    let recipe_iteration_s = plan_cost_s(input, &recipe);
    let data_iteration_s = plan_cost_s(input, &data);

    // never-worse guarantee: fall back to whichever baseline prices lower
    let (mut chosen, mut chosen_iteration_s) = (searched, searched_s);
    if recipe_iteration_s < chosen_iteration_s {
        chosen = recipe;
        chosen_iteration_s = recipe_iteration_s;
    }
    if data_iteration_s < chosen_iteration_s {
        chosen = data;
        chosen_iteration_s = data_iteration_s;
    }
    chosen.mode = "auto".into();
    // keep the per-layer decisions consistent with what the returned plan
    // actually executes when a baseline fallback displaced the search
    for d in &mut decisions {
        d.chosen = chosen.strategy_for(&d.layer);
    }
    PlanSearch { plan: chosen, decisions, chosen_iteration_s, data_iteration_s, recipe_iteration_s }
}

// ---------------------------------------------------------------------
// Cross-PR bench trajectory (BENCH_plan.json)
// ---------------------------------------------------------------------

/// One BENCH_plan.json row: planner-chosen vs fixed-recipe vs pure-data
/// efficiency at `nodes` (all relative to the 1-node data-parallel sim).
/// With a [`cache::PlanCache`](super::PlanCache) the search is reused
/// content-addressed from `artifacts/plans/` instead of re-derived per
/// bench invocation.
pub fn bench_row(
    net: &NetDescriptor,
    platform: &Platform,
    minibatch: u64,
    nodes: u64,
    collective: Choice,
    iterations: usize,
    cache: Option<&super::PlanCache>,
) -> Json {
    let input =
        PlannerInput { net, platform, nodes, minibatch, overlap: 1.0, collective, iterations };
    let search = match cache {
        Some(c) => c.plan_cached(&net.name, &input).0,
        None => plan(&input),
    };
    let base = plan_cost_s(
        &PlannerInput { nodes: 1, ..input },
        &PartitionPlan::empty(1, minibatch),
    );
    let eff = |iter_s: f64| base / (iter_s * nodes as f64);
    let mut m = BTreeMap::new();
    m.insert("auto_efficiency".to_string(), Json::Num(eff(search.chosen_iteration_s)));
    m.insert("data_efficiency".to_string(), Json::Num(eff(search.data_iteration_s)));
    m.insert("fixed_efficiency".to_string(), Json::Num(eff(search.recipe_iteration_s)));
    m.insert("minibatch".to_string(), Json::Num(minibatch as f64));
    m.insert("nodes".to_string(), Json::Num(nodes as f64));
    Json::Obj(m)
}

/// Merge one network's design-point rows into an accumulating
/// `BENCH_plan.json`: entries under other keys are preserved, this key's
/// slice is replaced — the fig4/6/7 benches each own one key.
pub fn merge_bench_plan(path: &str, key: &str, rows: Vec<Json>) -> Result<()> {
    let mut root = match std::fs::read_to_string(path) {
        // refuse to clobber sibling benches' rows behind a corrupt file —
        // the whole point of this helper is that entries accumulate
        Ok(text) => Json::parse(&text)
            .with_context(|| format!("existing {path:?} is not valid JSON; not overwriting"))?,
        Err(_) => Json::Obj(BTreeMap::new()),
    };
    match &mut root {
        Json::Obj(m) => {
            m.insert(key.to_string(), Json::Arr(rows));
        }
        other => bail!("existing {path:?} is not a JSON object: {other:?}"),
    }
    std::fs::write(path, format!("{}\n", root.pretty()))
        .with_context(|| format!("cannot write {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn input<'a>(
        net: &'a NetDescriptor,
        platform: &'a Platform,
        nodes: u64,
        mb: u64,
    ) -> PlannerInput<'a> {
        PlannerInput {
            net,
            platform,
            nodes,
            minibatch: mb,
            overlap: 1.0,
            collective: Choice::Auto,
            iterations: 3,
        }
    }

    #[test]
    fn single_node_plans_are_pure_data() {
        let net = zoo::vgg_a();
        let p = Platform::cori();
        let s = plan(&input(&net, &p, 1, 256));
        assert!(s.plan.is_pure_data());
        assert_eq!(s.chosen_iteration_s, s.data_iteration_s);
    }

    #[test]
    fn convs_stay_data_parallel() {
        let net = zoo::vgg_a();
        let p = Platform::cori();
        let s = plan(&input(&net, &p, 64, 512));
        for l in net.layers.iter().filter(|l| l.is_conv()) {
            assert_eq!(s.plan.strategy_for(&l.name), Strategy::Data, "{}", l.name);
        }
    }

    #[test]
    fn fc_head_leaves_data_parallelism_when_it_wins() {
        // CD-DNN on FDR: the §3.3 situation the paper built hybrid for
        let net = zoo::cddnn_full();
        let p = Platform::endeavor();
        let s = plan(&input(&net, &p, 16, 1024));
        let non_data = net
            .layers
            .iter()
            .filter(|l| l.is_fc())
            .filter(|l| s.plan.strategy_for(&l.name) != Strategy::Data)
            .count();
        assert!(non_data > 0, "planner found no model/hybrid FC layers");
        assert!(s.chosen_iteration_s <= s.data_iteration_s * (1.0 + 1e-9));
    }

    #[test]
    fn decisions_cover_every_weighted_layer() {
        let net = zoo::overfeat_fast();
        let p = Platform::aws();
        let s = plan(&input(&net, &p, 16, 256));
        let weighted = net.layers.iter().filter(|l| l.is_weighted()).count();
        assert_eq!(s.decisions.len(), weighted);
        for d in &s.decisions {
            assert!(!d.candidates.is_empty());
            assert!(d.cost_of("data").is_some());
        }
    }

    #[test]
    fn bench_row_has_the_three_efficiencies() {
        let net = zoo::vgg_a();
        let p = Platform::cori();
        let row = bench_row(&net, &p, 256, 8, Choice::Auto, 3, None);
        for k in ["auto_efficiency", "data_efficiency", "fixed_efficiency", "nodes"] {
            assert!(row.get(k).unwrap().as_f64().unwrap() > 0.0, "{k}");
        }
    }
}
