//! Async double-buffered checkpoint writer + bit-exact restore (ISSUE 9).
//!
//! The production shape (Strata's `checkpoint` crate, see SNIPPETS.md):
//! checkpoint write-out runs on its **own thread**, overlapped with
//! training exactly like the comm thread — the leader hands a
//! [`ParamSnapshot`] to a bounded queue and keeps stepping. The queue
//! holds one snapshot while another is being written (double buffering);
//! a third submission before either drains is *dropped*, never blocked
//! on — a skipped interval costs recovery replay, a blocked trainer
//! costs every step.
//!
//! Durability protocol (crash-safe at every point):
//! 1. encode the snapshot to a length-prefixed little-endian byte
//!    payload and FNV-1a-hash it,
//! 2. write `ckpt-<step>.bin.tmp`, then atomically rename to
//!    `ckpt-<step>.bin`,
//! 3. write `MANIFEST.tmp` (JSON: file, step, content hash), rename to
//!    `MANIFEST` — readers only ever trust the manifest, so a crash
//!    mid-write leaves the previous checkpoint fully intact,
//! 4. garbage-collect checkpoint files older than the previous one
//!    (two generations stay on disk, mirroring the in-memory double
//!    buffer).
//!
//! [`restore`] verifies the content hash before decoding and
//! round-trips every f32 bit-for-bit (raw `to_le_bytes`, no text
//! formatting), so `stall` recovery replays the exact trajectory.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::state::ParamSnapshot;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"PCLCKPT1";

/// FNV-1a 64 over the encoded payload (same content-hash idiom as the
/// plan cache).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---------------------------------------------------------------- codec

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_tensors(out: &mut Vec<u8>, ts: &[Vec<f32>]) {
    push_u64(out, ts.len() as u64);
    for t in ts {
        push_u64(out, t.len() as u64);
        for &x in t {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn push_opt_tensors(out: &mut Vec<u8>, ts: &Option<Vec<Vec<f32>>>) {
    match ts {
        None => out.push(0),
        Some(ts) => {
            out.push(1);
            push_tensors(out, ts);
        }
    }
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.bytes.len(), "checkpoint payload truncated");
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn tensors(&mut self) -> Result<Vec<Vec<f32>>> {
        let n = self.u64()? as usize;
        ensure!(n <= 1 << 20, "implausible checkpoint tensor count {n}");
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let len = self.u64()? as usize;
            let raw = self.take(len * 4)?;
            let mut t = Vec::with_capacity(len);
            for c in raw.chunks_exact(4) {
                t.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            out.push(t);
        }
        Ok(out)
    }

    fn opt_tensors(&mut self) -> Result<Option<Vec<Vec<f32>>>> {
        Ok(match self.u8()? {
            0 => None,
            _ => Some(self.tensors()?),
        })
    }
}

fn encode(snap: &ParamSnapshot) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + snap.n_elements() * 4);
    push_u64(&mut out, snap.step);
    push_tensors(&mut out, &snap.tensors);
    push_opt_tensors(&mut out, &snap.velocity);
    push_opt_tensors(&mut out, &snap.adam_m);
    push_opt_tensors(&mut out, &snap.adam_v);
    push_u64(&mut out, snap.tensor_steps.len() as u64);
    for &s in &snap.tensor_steps {
        push_u64(&mut out, s);
    }
    out
}

fn decode(payload: &[u8]) -> Result<ParamSnapshot> {
    let mut r = Reader { bytes: payload, pos: 0 };
    let step = r.u64()?;
    let tensors = r.tensors()?;
    let velocity = r.opt_tensors()?;
    let adam_m = r.opt_tensors()?;
    let adam_v = r.opt_tensors()?;
    let n = r.u64()? as usize;
    let mut tensor_steps = Vec::with_capacity(n);
    for _ in 0..n {
        tensor_steps.push(r.u64()?);
    }
    ensure!(r.pos == payload.len(), "trailing bytes after checkpoint payload");
    Ok(ParamSnapshot { step, tensors, velocity, adam_m, adam_v, tensor_steps })
}

// ------------------------------------------------------------- disk I/O

/// Write one checkpoint durably (tmp-write + rename, then manifest
/// tmp-write + rename). Returns the final checkpoint file path.
pub fn write_snapshot(dir: &Path, snap: &ParamSnapshot) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let payload = encode(snap);
    let hash = fnv1a(&payload);
    let file = format!("ckpt-{:010}.bin", snap.step);
    let path = dir.join(&file);
    let tmp = dir.join(format!("{file}.tmp"));
    let mut bytes = Vec::with_capacity(16 + payload.len());
    bytes.extend_from_slice(MAGIC);
    bytes.extend_from_slice(&hash.to_le_bytes());
    bytes.extend_from_slice(&payload);
    std::fs::write(&tmp, &bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("publishing {}", path.display()))?;
    let mut m = BTreeMap::new();
    m.insert("file".to_string(), Json::Str(file));
    m.insert("step".to_string(), Json::Num(snap.step as f64));
    m.insert("hash".to_string(), Json::Str(format!("{hash:016x}")));
    m.insert("bytes".to_string(), Json::Num(bytes.len() as f64));
    let mtmp = dir.join("MANIFEST.tmp");
    std::fs::write(&mtmp, format!("{}\n", Json::Obj(m).pretty()))
        .with_context(|| format!("writing {}", mtmp.display()))?;
    std::fs::rename(&mtmp, dir.join("MANIFEST")).context("publishing checkpoint MANIFEST")?;
    Ok(path)
}

/// Load the latest durable checkpoint. `Ok(None)` when the directory has
/// no manifest (nothing written yet); corruption — a manifest pointing
/// at a missing file, or a content-hash mismatch — is an *error*, not a
/// silent miss: restoring stale state would break replay determinism.
pub fn restore(dir: &Path) -> Result<Option<ParamSnapshot>> {
    let manifest = dir.join("MANIFEST");
    if !manifest.exists() {
        return Ok(None);
    }
    let text = std::fs::read_to_string(&manifest)
        .with_context(|| format!("reading {}", manifest.display()))?;
    let j = Json::parse(&text).context("parsing checkpoint MANIFEST")?;
    let file = j.get("file")?.as_str()?.to_string();
    let step = j.get("step")?.as_u64()?;
    let want_hash = j.get("hash")?.as_str()?.to_string();
    let path = dir.join(&file);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    ensure!(bytes.len() >= 16 && &bytes[..8] == MAGIC, "{} is not a checkpoint file", file);
    let stored = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let payload = &bytes[16..];
    let actual = fnv1a(payload);
    ensure!(
        actual == stored && format!("{actual:016x}") == want_hash,
        "checkpoint {} is corrupt: content hash {:016x} != recorded {}",
        file,
        actual,
        want_hash
    );
    let snap = decode(payload).with_context(|| format!("decoding checkpoint {file}"))?;
    ensure!(snap.step == step, "checkpoint {} step {} != manifest step {}", file, snap.step, step);
    Ok(Some(snap))
}

// ------------------------------------------------------------ the writer

/// Handle owning the dedicated checkpoint thread (`pcl-dnn-ckpt`).
pub struct CheckpointWriter {
    tx: Option<SyncSender<ParamSnapshot>>,
    handle: Option<JoinHandle<()>>,
    submitted: u64,
    skipped: u64,
    done: Arc<AtomicU64>,
    written: Arc<AtomicU64>,
    errors: Arc<AtomicU64>,
    dir: PathBuf,
}

impl CheckpointWriter {
    /// Spawn the writer thread. The queue holds ONE snapshot while one is
    /// being written — the double buffer; see the module docs.
    pub fn spawn(dir: impl Into<PathBuf>) -> Result<CheckpointWriter> {
        let dir: PathBuf = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let (tx, rx) = sync_channel::<ParamSnapshot>(1);
        let done = Arc::new(AtomicU64::new(0));
        let written = Arc::new(AtomicU64::new(0));
        let errors = Arc::new(AtomicU64::new(0));
        let (d, w, e) = (done.clone(), written.clone(), errors.clone());
        let tdir = dir.clone();
        let handle = std::thread::Builder::new()
            .name("pcl-dnn-ckpt".into())
            .spawn(move || {
                // keep two generations on disk; gc the third as it rotates
                let mut kept: Vec<PathBuf> = Vec::new();
                for snap in rx.iter() {
                    match write_snapshot(&tdir, &snap) {
                        Ok(path) => {
                            w.fetch_add(1, Ordering::Release);
                            kept.push(path);
                            if kept.len() > 2 {
                                let old = kept.remove(0);
                                let _ = std::fs::remove_file(old);
                            }
                        }
                        Err(err) => {
                            // a failed write must not kill training; the
                            // trainer sees it through errors()/flush()
                            eprintln!("checkpoint write failed: {err:#}");
                            e.fetch_add(1, Ordering::Release);
                        }
                    }
                    d.fetch_add(1, Ordering::Release);
                }
            })
            .expect("spawning checkpoint thread");
        Ok(CheckpointWriter {
            tx: Some(tx),
            handle: Some(handle),
            submitted: 0,
            skipped: 0,
            done,
            written,
            errors,
            dir,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Hand a snapshot to the writer. Never blocks: with both buffers
    /// busy the snapshot is dropped (returns `false`) and the interval is
    /// skipped — recovery then replays a little further back.
    pub fn submit(&mut self, snap: ParamSnapshot) -> bool {
        match self.tx.as_ref().expect("writer running").try_send(snap) {
            Ok(()) => {
                self.submitted += 1;
                true
            }
            Err(TrySendError::Full(_)) => {
                self.skipped += 1;
                false
            }
            Err(TrySendError::Disconnected(_)) => false,
        }
    }

    /// Checkpoints durably on disk.
    pub fn written(&self) -> u64 {
        self.written.load(Ordering::Acquire)
    }

    /// Snapshots dropped because both buffers were busy.
    pub fn skipped(&self) -> u64 {
        self.skipped
    }

    /// Block (bounded) until every accepted snapshot is durable — the
    /// recovery path calls this before `restore` so the newest state is
    /// on disk. Errors if the writer hit a write failure or stalls past
    /// `budget`.
    pub fn flush(&self, budget: Duration) -> Result<()> {
        let t0 = Instant::now();
        while self.done.load(Ordering::Acquire) < self.submitted {
            if t0.elapsed() > budget {
                bail!(
                    "checkpoint writer stalled: {}/{} snapshots durable after {:.1}s",
                    self.done.load(Ordering::Acquire),
                    self.submitted,
                    budget.as_secs_f64()
                );
            }
            std::thread::yield_now();
        }
        let errs = self.errors.load(Ordering::Acquire);
        ensure!(errs == 0, "{errs} checkpoint write(s) failed; see stderr");
        Ok(())
    }

    /// Drain the queue and stop the thread; returns checkpoints written.
    pub fn shutdown(mut self) -> u64 {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.written()
    }
}

impl Drop for CheckpointWriter {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::{Optimizer, ParamStore, SgdConfig};

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pcl-dnn-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn adam_snapshot() -> ParamSnapshot {
        let cfg = SgdConfig { lr: 3e-3, optimizer: Optimizer::adam(), ..SgdConfig::default() };
        let mut s = ParamStore::new(vec![vec![0.5f32; 7], vec![-0.25f32; 33]], cfg);
        for k in 0..4 {
            let g: Vec<Vec<f32>> = s
                .tensors
                .iter()
                .map(|t| t.iter().enumerate().map(|(i, _)| (i + k) as f32 * 0.01 - 0.1).collect())
                .collect();
            s.apply_all(&g, 2.0).unwrap();
        }
        s.snapshot()
    }

    #[test]
    fn codec_roundtrips_bit_identically() {
        let snap = adam_snapshot();
        let back = decode(&encode(&snap)).unwrap();
        assert_eq!(snap, back);
        // PartialEq on f32 treats -0.0 == 0.0; pin the raw bits too
        for (a, b) in snap.tensors.iter().flatten().zip(back.tensors.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn write_restore_roundtrip_on_disk() {
        let dir = tmp_dir("roundtrip");
        assert!(restore(&dir).unwrap().is_none(), "empty dir must restore to None");
        let snap = adam_snapshot();
        write_snapshot(&dir, &snap).unwrap();
        let back = restore(&dir).unwrap().expect("manifest written");
        assert_eq!(snap, back);
        assert_eq!(back.step, 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_checkpoint_is_an_error_not_a_miss() {
        let dir = tmp_dir("corrupt");
        let snap = adam_snapshot();
        let path = write_snapshot(&dir, &snap).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let err = restore(&dir).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "unexpected error: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn writer_overlaps_and_keeps_two_generations() {
        let dir = tmp_dir("writer");
        let mut w = CheckpointWriter::spawn(&dir).unwrap();
        let cfg = SgdConfig::default();
        let mut s = ParamStore::new(vec![vec![0.1f32; 64]], cfg);
        let mut accepted = 0u64;
        for _ in 0..6 {
            s.apply_all(&[vec![0.5; 64]], 1.0).unwrap();
            if w.submit(s.snapshot()) {
                accepted += 1;
            }
            // a full queue drops rather than blocks — both outcomes legal
        }
        w.flush(Duration::from_secs(10)).unwrap();
        assert_eq!(w.written(), accepted);
        assert_eq!(accepted + w.skipped(), 6);
        // latest durable checkpoint is the newest accepted snapshot
        let back = restore(&dir).unwrap().expect("restore after writes");
        assert!(back.step >= 1);
        // at most two generations + MANIFEST on disk
        let files: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with("ckpt-"))
            .collect();
        assert!(files.len() <= 2, "gc left {} checkpoint files", files.len());
        assert_eq!(w.shutdown(), accepted);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
