//! Metrics: throughput meters, loss history, and table/CSV emitters used
//! by the CLI, examples and benches to report experiment results.

use std::fmt::Write as _;
use std::time::Instant;

/// One training-step record (the loss-curve row).
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f64,
    pub images_per_s: f64,
    pub compute_s: f64,
    pub comm_wait_s: f64,
    /// comm-thread busy seconds hidden behind compute this step
    /// (StepStats::overlap_s; 0 where the exchange had nothing to hide)
    pub overlap_s: f64,
    /// this step's consumer-side data-thread stall, microseconds
    pub data_stall_us: f64,
}

/// Accumulates a training run's history.
#[derive(Debug, Default, Clone)]
pub struct History {
    pub records: Vec<StepRecord>,
}

impl History {
    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the last `n` records (noise-robust probe).
    pub fn tail_loss(&self, n: usize) -> Option<f64> {
        if self.records.is_empty() {
            return None;
        }
        let k = n.min(self.records.len());
        let s: f64 = self.records[self.records.len() - k..].iter().map(|r| r.loss).sum();
        Some(s / k as f64)
    }

    pub fn mean_throughput(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.images_per_s).sum::<f64>() / self.records.len() as f64
    }

    /// CSV: step,loss,images_per_s,compute_s,comm_wait_s,overlap_s,data_stall_us
    pub fn to_csv(&self) -> String {
        let mut s =
            String::from("step,loss,images_per_s,compute_s,comm_wait_s,overlap_s,data_stall_us\n");
        for r in &self.records {
            let _ = writeln!(
                s,
                "{},{:.6},{:.2},{:.6},{:.6},{:.6},{:.1}",
                r.step,
                r.loss,
                r.images_per_s,
                r.compute_s,
                r.comm_wait_s,
                r.overlap_s,
                r.data_stall_us
            );
        }
        s
    }

    pub fn save_csv(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Wall-clock throughput meter.
pub struct Throughput {
    t0: Instant,
    items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { t0: Instant::now(), items: 0 }
    }

    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn per_sec(&self) -> f64 {
        self.items as f64 / self.t0.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn items(&self) -> u64 {
        self.items
    }
}

/// Fixed-width markdown table printer for experiment reports.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            let _ = write!(out, "|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(out, " {c:>w$} |", w = w);
            }
            let _ = writeln!(out);
        };
        line(&self.headers, &widths, &mut out);
        let _ = write!(out, "|");
        for w in &widths {
            let _ = write!(out, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out);
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_tail_and_csv() {
        let mut h = History::default();
        for i in 0..10 {
            h.push(StepRecord {
                step: i,
                loss: 10.0 - i as f64,
                images_per_s: 100.0,
                compute_s: 0.1,
                comm_wait_s: 0.01,
                overlap_s: 0.005,
                data_stall_us: 2.0,
            });
        }
        assert_eq!(h.final_loss(), Some(1.0));
        assert_eq!(h.tail_loss(2), Some(1.5));
        let csv = h.to_csv();
        assert_eq!(csv.lines().count(), 11);
        assert!(csv.starts_with("step,loss"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["nodes", "img/s"]);
        t.row(vec!["1".into(), "31".into()]);
        t.row(vec!["128".into(), "3367".into()]);
        let r = t.render();
        assert!(r.contains("| nodes |"));
        assert_eq!(r.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
