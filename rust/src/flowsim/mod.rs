//! Flow-level network simulation — the third fidelity tier between the
//! α-β analytic model and the per-message netsim (DESIGN.md "Three-tier
//! fidelity").
//!
//! The per-iteration synchronous-SGD structure is the same one
//! `netsim::cluster::build_fleet_dag` emits (forward with activation
//! allgathers, wt-grad before bprop, RS → strip SGD → AG gradient
//! exchanges overlapped through per-node comm-queue tails), but each
//! collective step becomes *flows* on the [`engine::FlowEngine`] instead
//! of per-message tasks:
//!
//! * **Ring** collectives coarsen to one flow per member: member `j`
//!   streams its (m-1) chunks to `j+1` as a single `(m-1)·bytes/m`
//!   transfer whose latency stage charges the software setup plus all
//!   m-1 per-step α latencies. On a clean fabric this is exactly the
//!   ring α-β closed form, and under contention the flow fair-shares
//!   the same tx/rx/channel links the per-message schedule would occupy.
//! * **Butterfly** collectives keep one flow per member per round (the
//!   pairwise exchange pattern changes links every round, so rounds
//!   cannot be coarsened without losing the contention structure).
//!
//! Scope: flowsim models *homogeneous, failure-free* fleets — the
//! regime where its ≤5% agreement with per-message netsim is validated
//! (`tests/fleet_sim.rs`) and the one that matters for the
//! 1000s-of-node scaling frontier (`benches/flowsim_frontier.rs`).
//! Stragglers, heterogeneous generations and failure/recovery timelines
//! need per-task fidelity and stay on the netsim tier.

pub mod engine;

use anyhow::{bail, Result};

use crate::analytic::comm_model::Strategy;
use crate::analytic::machine::Platform;
use crate::analytic::FabricSpec;
use crate::collectives::GroupTopology;
use crate::models::NetDescriptor;
use crate::netsim::cluster::{self, SimConfig};
use crate::netsim::collective::{self, Algorithm, CollectiveKind};
use crate::netsim::engine::DepLists;
use crate::netsim::network::{Network, Topology};

use engine::{FlowEngine, FlowTaskId};

/// Steady-state summary of one flow-level training simulation (the
/// flowsim analogue of `netsim::cluster::FleetSimResult`).
#[derive(Debug, Clone, PartialEq)]
pub struct FlowSimResult {
    pub iteration_s: f64,
    pub images_per_s: f64,
    pub mean_compute_utilization: f64,
    pub min_compute_utilization: f64,
    /// Tasks (works + flows) pushed through the flow engine.
    pub tasks: u64,
}

/// Per-node FIFO comm-queue tail: the last task on the node's comm
/// stream, plus the completion task of its last collective when that
/// differs (same chaining `netsim::cluster` uses).
#[derive(Debug, Clone, Copy, Default)]
struct Tail {
    a: Option<FlowTaskId>,
    b: Option<FlowTaskId>,
}

impl Tail {
    fn one(t: FlowTaskId) -> Tail {
        Tail { a: Some(t), b: None }
    }
    fn pair(a: FlowTaskId, b: Option<FlowTaskId>) -> Tail {
        Tail { a: Some(a), b }
    }
    fn iter(self) -> impl Iterator<Item = FlowTaskId> {
        self.a.into_iter().chain(self.b)
    }
}

/// Result of emitting one collective as flows.
struct FlowCollective {
    /// Per-member task after which that member's result is final.
    done: Vec<FlowTaskId>,
    /// Per-member last own send (for comm-queue chaining).
    last_local: Vec<FlowTaskId>,
}

/// Ring reduce-scatter/allgather coarsened to one flow per member:
/// member j's m-1 chunk sends to j+1 become a single (m-1)·(bytes/m)
/// flow over the j→j+1 route, with the software setup and the m-1
/// per-step α latencies folded into the latency stage. Member j's
/// result is final when its incoming neighbor's flow lands.
fn emit_ring(
    fe: &mut FlowEngine,
    netw: &Network,
    group: &[usize],
    bytes: u64,
    deps: &DepLists,
) -> FlowCollective {
    let m = group.len();
    let chunk = bytes as f64 / m as f64;
    let steps = (m - 1) as f64;
    let mut flows: Vec<FlowTaskId> = Vec::with_capacity(m);
    for j in 0..m {
        let dst = (j + 1) % m;
        let (route, lat_s) = netw.route(group[j], group[dst]);
        let latency = netw.sw_latency_s + steps * lat_s;
        flows.push(fe.add_flow(route.as_slice(), latency, steps * chunk, deps.get(j)));
    }
    let done: Vec<FlowTaskId> = (0..m).map(|j| flows[(j + m - 1) % m]).collect();
    FlowCollective { done, last_local: flows }
}

/// Butterfly (recursive halving/doubling): one flow per member per
/// round — the same rounds, partners, sizes and dependency structure as
/// `netsim::collective::build_butterfly`, with the per-member software
/// setup folded into round 0's latency stage.
fn emit_butterfly(
    fe: &mut FlowEngine,
    netw: &Network,
    group: &[usize],
    bytes: u64,
    deps: &DepLists,
    kind: CollectiveKind,
) -> FlowCollective {
    let m = group.len();
    assert!(m.is_power_of_two(), "butterfly needs a power-of-two group, got {m}");
    let rounds = m.trailing_zeros() as usize;
    let mut last: Vec<FlowTaskId> = vec![0; m];
    let mut cur: Vec<FlowTaskId> = vec![0; m];
    let mut last_partner: Vec<usize> = (0..m).collect();
    for k in 0..rounds {
        let (dist, size) = match kind {
            CollectiveKind::ReduceScatter => {
                (m >> (k + 1), bytes as f64 / (1u64 << (k + 1)) as f64)
            }
            CollectiveKind::Allgather => {
                (1usize << k, bytes as f64 * (1u64 << k) as f64 / m as f64)
            }
        };
        for j in 0..m {
            let partner = j ^ dist;
            let (route, lat_s) = netw.route(group[j], group[partner]);
            let latency = lat_s + if k == 0 { netw.sw_latency_s } else { 0.0 };
            cur[j] = if k == 0 {
                fe.add_flow(route.as_slice(), latency, size, deps.get(j))
            } else {
                fe.add_flow(
                    route.as_slice(),
                    latency,
                    size,
                    &[last[j], last[last_partner[j]]],
                )
            };
        }
        for (j, p) in last_partner.iter_mut().enumerate() {
            *p = j ^ dist;
        }
        std::mem::swap(&mut last, &mut cur);
    }
    let done: Vec<FlowTaskId> = (0..m).map(|j| last[last_partner[j]]).collect();
    FlowCollective { done, last_local: last }
}

/// Flow-emission analogue of `netsim::cluster::DagBuilder`: the flow
/// engine plus per-node comm-queue tails and the two reusable
/// dependency-list arenas.
struct FlowBuilder<'a> {
    fe: FlowEngine,
    netw: &'a Network,
    fabric: &'a FabricSpec,
    last_comm: Vec<Tail>,
    gates: DepLists,
    deps: DepLists,
}

impl<'a> FlowBuilder<'a> {
    fn gates_single(&mut self, src: &[FlowTaskId]) {
        self.gates.clear();
        for &t in src {
            self.gates.push(t);
            self.gates.finish_list();
        }
    }

    fn run_collective(
        &mut self,
        choice: collective::Choice,
        members: &[usize],
        bytes: u64,
        kind: CollectiveKind,
    ) -> Vec<FlowTaskId> {
        self.deps.clear();
        for &v in members {
            for &d in self.gates.get(v) {
                self.deps.push(d);
            }
            for d in self.last_comm[v].iter() {
                self.deps.push(d);
            }
            self.deps.finish_list();
        }
        let built = if members.len() <= 1 {
            // zero-duration marker on the comm stream, as in netsim
            let id = self.fe.add_work(2 * members[0] + 1, 0.0, self.deps.get(0));
            FlowCollective { done: vec![id], last_local: vec![id] }
        } else {
            match choice.algorithm(self.fabric, bytes, members.len() as u64) {
                Algorithm::Ring => emit_ring(&mut self.fe, self.netw, members, bytes, &self.deps),
                Algorithm::Butterfly => {
                    emit_butterfly(&mut self.fe, self.netw, members, bytes, &self.deps, kind)
                }
            }
        };
        for (j, &v) in members.iter().enumerate() {
            let extra = (built.done[j] != built.last_local[j]).then_some(built.done[j]);
            self.last_comm[v] = Tail::pair(built.last_local[j], extra);
        }
        built.done
    }

    /// RS -> strip SGD -> AG, mirroring `DagBuilder::exchange_update`.
    fn exchange_update(
        &mut self,
        choice: collective::Choice,
        members: &[usize],
        bytes: u64,
        wg: &[FlowTaskId],
        sgd_s: f64,
    ) -> Vec<FlowTaskId> {
        self.gates_single(wg);
        let rs = self.run_collective(choice, members, bytes, CollectiveKind::ReduceScatter);
        let mut sgd_global: Vec<FlowTaskId> = vec![0; self.last_comm.len()];
        for (j, &v) in members.iter().enumerate() {
            let mut d: [FlowTaskId; 3] = [0; 3];
            d[0] = rs[j];
            let mut len = 1;
            for t in self.last_comm[v].iter() {
                d[len] = t;
                len += 1;
            }
            let id = self.fe.add_work(2 * v + 1, sgd_s, &d[..len]);
            self.last_comm[v] = Tail::one(id);
            sgd_global[v] = id;
        }
        self.gates_single(&sgd_global);
        self.run_collective(choice, members, bytes, CollectiveKind::Allgather)
    }
}

/// Simulate `cfg.iterations` of synchronous SGD at flow-level fidelity
/// over a homogeneous, failure-free fleet on `topology`. Steady-state
/// timing is the last iteration boundary minus the previous one, as in
/// `netsim::cluster::summarize_fleet`.
pub fn simulate_training_flows(
    net: &NetDescriptor,
    platform: &Platform,
    cfg: &SimConfig,
    topology: Topology,
) -> Result<FlowSimResult> {
    if cfg.iterations < 2 {
        bail!(
            "SimConfig.iterations is {} but must be >= 2 for flowsim: steady-state \
             timing is the last iteration boundary minus the previous one (set \
             parallelism.iterations >= 2)",
            cfg.iterations
        );
    }
    let n = cfg.nodes as usize;
    if n == 0 {
        bail!("flowsim needs at least one node");
    }
    debug_assert!(
        cfg.plan.assignments.is_empty() || cfg.plan.nodes == cfg.nodes,
        "plan was derived for {} nodes but flowsim runs {}",
        cfg.plan.nodes,
        cfg.nodes
    );
    let m = &platform.machine;
    let fabric = &platform.fabric;
    // link ids start at 0: flowsim streams live in their own id space
    let netw = Network::new(topology, n, fabric, 0);
    let caps = vec![netw.nic_bw; netw.n_resources()];
    let mut b = FlowBuilder {
        fe: FlowEngine::new(2 * n, caps),
        netw: &netw,
        fabric,
        last_comm: vec![Tail::default(); n],
        gates: DepLists::new(),
        deps: DepLists::new(),
    };
    let layers = &net.layers;
    let k = layers.len();
    let active: Vec<usize> = (0..n).collect();
    let n_active = n as u64;
    let mb_active = cfg.minibatch as f64 / n_active as f64;

    let mut prev_update: Vec<Vec<Option<FlowTaskId>>> = vec![vec![None; k]; n];
    let mut iter_ends: Vec<Vec<FlowTaskId>> = Vec::with_capacity(cfg.iterations);
    for _it in 0..cfg.iterations {
        let mut iter_tail: Vec<FlowTaskId> = Vec::new();

        // ---------------- forward ----------------
        let mut last_fwd: Vec<Option<FlowTaskId>> = vec![None; n];
        for (i, l) in layers.iter().enumerate() {
            let strat = cluster::strategy_in(&cfg.plan, l, n_active);
            let choice = cluster::choice_in(&cfg.plan, l, cfg.collective);
            b.gates.clear();
            for v in 0..n {
                if let Some(p) = last_fwd[v] {
                    b.gates.push(p);
                }
                if let Some(u) = prev_update[v][i] {
                    b.gates.push(u);
                }
                b.gates.finish_list();
            }
            // model/hybrid layers gather remote activations before compute
            let fwd_src: Option<Vec<FlowTaskId>> = match strat {
                Strategy::Model if n_active > 1 => {
                    let bytes = 4 * l.in_elems() * cfg.minibatch;
                    Some(b.run_collective(choice, &active, bytes, CollectiveKind::Allgather))
                }
                Strategy::Hybrid { groups } if n_active > 1 => {
                    let topo = GroupTopology::new(n, groups as usize);
                    let bytes = 4 * l.in_elems() * (cfg.minibatch / groups);
                    let mut out: Vec<FlowTaskId> = vec![0; n];
                    for g in 0..topo.groups {
                        let members = topo.group_members(g);
                        let done =
                            b.run_collective(choice, &members, bytes, CollectiveKind::Allgather);
                        for (j, &v) in members.iter().enumerate() {
                            out[v] = done[j];
                        }
                    }
                    Some(out)
                }
                _ => None,
            };
            let base_t = cluster::pass_time_s(l, m, mb_active);
            for v in 0..n {
                let id = match &fwd_src {
                    Some(done) => b.fe.add_work(2 * v, base_t, &[done[v]]),
                    None => b.fe.add_work(2 * v, base_t, b.gates.get(v)),
                };
                last_fwd[v] = Some(id);
            }
        }

        // ---------------- backward (wt-grad before bprop) ----------------
        let mut chain: Vec<FlowTaskId> =
            (0..n).map(|v| last_fwd[v].expect("non-empty net")).collect();
        let mut update_ids: Vec<Vec<Option<FlowTaskId>>> = vec![vec![None; k]; n];
        let first_weighted = layers.iter().position(|l| l.is_weighted()).unwrap_or(0);
        for i in (0..k).rev() {
            let l = &layers[i];
            if !l.is_weighted() {
                continue;
            }
            let strat = cluster::strategy_in(&cfg.plan, l, n_active);
            let choice = cluster::choice_in(&cfg.plan, l, cfg.collective);
            let per_pass = cluster::pass_time_s(l, m, mb_active);
            let mut wg: Vec<FlowTaskId> = vec![0; n];
            for v in 0..n {
                wg[v] = b.fe.add_work(2 * v, per_pass, &[chain[v]]);
            }
            let sgd_s = 2.0 * l.weight_elems() as f64 / (m.peak_gflops() * 1e9);
            let updates: Vec<FlowTaskId> = match strat {
                Strategy::Data if n_active > 1 => {
                    b.exchange_update(choice, &active, l.weight_bytes(), &wg, sgd_s)
                }
                Strategy::Hybrid { groups } if n_active > 1 => {
                    let topo = GroupTopology::new(n, groups as usize);
                    let shard = l.weight_bytes() / topo.group_size() as u64;
                    let mut out: Vec<FlowTaskId> = vec![0; n];
                    for r in 0..topo.group_size() {
                        let members = topo.replica_set(r);
                        let done = b.exchange_update(choice, &members, shard, &wg, sgd_s);
                        for (j, &v) in members.iter().enumerate() {
                            out[v] = done[j];
                        }
                    }
                    out
                }
                _ => {
                    // no weight exchange: local SGD on the comm stream
                    let mut out: Vec<FlowTaskId> = vec![0; n];
                    for v in 0..n {
                        let mut d: [FlowTaskId; 3] = [0; 3];
                        d[0] = wg[v];
                        let mut len = 1;
                        for t in b.last_comm[v].iter() {
                            d[len] = t;
                            len += 1;
                        }
                        let id = b.fe.add_work(2 * v + 1, sgd_s, &d[..len]);
                        b.last_comm[v] = Tail::one(id);
                        out[v] = id;
                    }
                    out
                }
            };
            for v in 0..n {
                update_ids[v][i] = Some(updates[v]);
                iter_tail.push(updates[v]);
            }
            if i != first_weighted {
                let mut bp: Vec<FlowTaskId> = vec![0; n];
                for v in 0..n {
                    bp[v] = b.fe.add_work(2 * v, per_pass, &[wg[v]]);
                }
                chain = match strat {
                    Strategy::Model if n_active > 1 => {
                        let bytes = 4 * l.in_elems() * cfg.minibatch;
                        b.gates_single(&bp);
                        b.run_collective(choice, &active, bytes, CollectiveKind::Allgather)
                    }
                    Strategy::Hybrid { groups } if n_active > 1 => {
                        let topo = GroupTopology::new(n, groups as usize);
                        let bytes = 4 * l.in_elems() * (cfg.minibatch / groups);
                        let mut out: Vec<FlowTaskId> = vec![0; n];
                        b.gates_single(&bp);
                        for g in 0..topo.groups {
                            let members = topo.group_members(g);
                            let done = b.run_collective(
                                choice, &members, bytes, CollectiveKind::Allgather,
                            );
                            for (j, &v) in members.iter().enumerate() {
                                out[v] = done[j];
                            }
                        }
                        out
                    }
                    _ => bp,
                };
            } else {
                chain = wg;
            }
        }
        prev_update = update_ids;
        for v in 0..n {
            iter_tail.push(chain[v]);
        }
        iter_ends.push(iter_tail);
    }

    let tasks = b.fe.len() as u64;
    let sched = b.fe.run()?;

    // steady-state window, mirroring `cluster::summarize_fleet`
    let iter_fin = |it: usize| {
        iter_ends[it].iter().map(|&t| sched.finish_s[t]).fold(0.0f64, f64::max)
    };
    let t_last = iter_fin(cfg.iterations - 1);
    let t_prev = iter_fin(cfg.iterations - 2);
    let iter_s = (t_last - t_prev).max(1e-12);
    let mut busy = vec![0.0f64; n];
    for sp in &sched.spans {
        if sp.stream % 2 == 0 && sp.start_s >= t_prev && sp.end_s <= t_last {
            busy[(sp.stream / 2) as usize] += sp.end_s - sp.start_s;
        }
    }
    let utils: Vec<f64> = busy.iter().map(|&bz| (bz / iter_s).min(1.0)).collect();
    let mean = utils.iter().sum::<f64>() / utils.len().max(1) as f64;
    let min = utils.iter().cloned().fold(f64::INFINITY, f64::min);
    Ok(FlowSimResult {
        iteration_s: iter_s,
        images_per_s: cfg.minibatch as f64 / iter_s,
        mean_compute_utilization: mean,
        min_compute_utilization: min,
        tasks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::netsim::cluster::simulate_training;

    fn clean_cori() -> Platform {
        let mut p = Platform::cori();
        p.fabric.congestion_per_doubling = 0.0;
        p
    }

    #[test]
    fn flowsim_matches_alpha_beta_data_parallel() {
        // The validation chain's first link: on a clean fully-switched
        // fabric, flow-level iteration time within 5% of the
        // representative-node α-β prediction (which netsim also meets).
        let p = clean_cori();
        for nodes in [2u64, 4, 8] {
            let cfg = SimConfig::data_parallel(nodes, 256);
            let rep = simulate_training(&zoo::vgg_a(), &p, &cfg).unwrap();
            let flow = simulate_training_flows(
                &zoo::vgg_a(), &p, &cfg, Topology::FullySwitched,
            )
            .unwrap();
            let rel = (flow.iteration_s - rep.iteration_s).abs() / rep.iteration_s;
            assert!(
                rel < 0.05,
                "nodes={nodes}: flow {} vs analytic {} ({:.1}% off)",
                flow.iteration_s,
                rep.iteration_s,
                100.0 * rel
            );
        }
    }

    #[test]
    fn flowsim_matches_alpha_beta_hybrid() {
        let p = clean_cori();
        let cfg = SimConfig::recipe(&zoo::vgg_a(), 8, 256);
        let rep = simulate_training(&zoo::vgg_a(), &p, &cfg).unwrap();
        let flow =
            simulate_training_flows(&zoo::vgg_a(), &p, &cfg, Topology::FullySwitched).unwrap();
        let rel = (flow.iteration_s - rep.iteration_s).abs() / rep.iteration_s;
        assert!(
            rel < 0.05,
            "flow {} vs analytic {} ({:.1}% off)",
            flow.iteration_s,
            rep.iteration_s,
            100.0 * rel
        );
    }

    #[test]
    fn flow_count_stays_flat_per_member_for_rings() {
        // The point of the tier: ring collectives are one flow per
        // member, not m-1 messages per member — task counts scale like
        // O(nodes · layers), not O(nodes² · layers).
        let p = clean_cori();
        let mk = |nodes: u64| {
            let cfg = SimConfig {
                collective: collective::Choice::Ring,
                ..SimConfig::data_parallel(nodes, 256)
            };
            simulate_training_flows(&zoo::vgg_a(), &p, &cfg, Topology::FullySwitched).unwrap()
        };
        let small = mk(4);
        let big = mk(16);
        // per-message netsim would grow ~16x here (4x members × 4x steps)
        assert!(big.tasks < 6 * small.tasks, "{} vs {}", big.tasks, small.tasks);
    }

    #[test]
    fn oversubscribed_core_slows_flowsim_hybrid() {
        // Contention is modeled: squeezing the fat-tree core must slow
        // the cross-leaf replica-set exchanges of the hybrid recipe.
        let mut p = Platform::aws();
        p.fabric.congestion_per_doubling = 0.0;
        let cfg = SimConfig::recipe(&zoo::cddnn_full(), 8, 1024);
        let flat = simulate_training_flows(
            &zoo::cddnn_full(), &p, &cfg, Topology::FlatSwitch,
        )
        .unwrap();
        let squeezed = simulate_training_flows(
            &zoo::cddnn_full(),
            &p,
            &cfg,
            Topology::FatTree { radix: 4, oversub: 4.0 },
        )
        .unwrap();
        assert!(
            squeezed.iteration_s > flat.iteration_s * 1.02,
            "oversubscribed {} vs flat {}",
            squeezed.iteration_s,
            flat.iteration_s
        );
    }

    #[test]
    fn single_node_runs_without_collectives() {
        let p = clean_cori();
        let cfg = SimConfig::data_parallel(1, 256);
        let r = simulate_training_flows(&zoo::vgg_a(), &p, &cfg, Topology::FullySwitched)
            .unwrap();
        assert!(r.iteration_s > 0.0 && r.tasks > 0);
        assert!(r.mean_compute_utilization > 0.5, "{}", r.mean_compute_utilization);
    }

    #[test]
    fn iterations_under_two_is_an_error() {
        let p = clean_cori();
        let cfg = SimConfig { iterations: 1, ..SimConfig::data_parallel(4, 256) };
        let err = simulate_training_flows(&zoo::vgg_a(), &p, &cfg, Topology::FullySwitched)
            .unwrap_err();
        assert!(err.to_string().contains("iterations"), "{err}");
    }
}
