//! Flow-level discrete-event engine: the substrate of the third fidelity
//! tier (DESIGN.md "Three-tier fidelity").
//!
//! Two task kinds share one dependency graph:
//!
//! * [`Kind::Work`] — occupies a unary FIFO *stream* (a node's compute
//!   pipeline or comm thread) for a fixed duration, exactly like a task
//!   on a `netsim::engine` resource. When a stream frees, the queued
//!   ready task with the smallest (ready time, id) starts — the same
//!   command-queue order the per-message engine produces.
//! * [`Kind::Flow`] — a bulk transfer over up to four network links. A
//!   flow holds no stream; it runs a fixed latency stage (α + software
//!   latency, no bandwidth) and then drains its byte volume at whatever
//!   rate the max-min fair allocation grants it across its links.
//!
//! The event loop re-solves the bandwidth allocation *only when the
//! active flow set changes* (a flow starts or finishes): progressive
//! filling assigns every active flow the largest common rate increment
//! until one of its links saturates, freezes the flows on saturated
//! links, and repeats. Between re-solves, rates are constant, so each
//! flow's finish time is a closed-form prediction; predictions carry the
//! solve epoch and are invalidated wholesale by the next re-solve.
//! Simultaneous events are processed as one batch (one drain + one
//! re-solve), which keeps homogeneous collectives — where all members'
//! flows start and finish at bit-identical times — at O(1) solves per
//! collective round instead of O(members).
//!
//! Time is in f64 seconds; byte volumes and rates in f64 bytes and
//! bytes/s. Determinism follows from the deterministic heaps and the
//! batch processing of equal-time events.

use anyhow::{bail, Result};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Task identifier: insertion order, dense from 0.
pub type FlowTaskId = usize;

const NO_POS: u32 = u32::MAX;
/// Relative slack below which a link counts as saturated.
const SAT_EPS: f64 = 1e-9;

#[derive(Debug, Clone, Copy)]
enum Kind {
    Work { stream: u32, dur_s: f64 },
    Flow { links: [u32; 4], n_links: u8, latency_s: f64, bytes: f64 },
}

/// One `Work` occupancy interval on a stream (for utilization accounting).
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub stream: u32,
    pub start_s: f64,
    pub end_s: f64,
}

/// Executed schedule: per-task finish times plus the stream spans.
#[derive(Debug, Clone)]
pub struct FlowSchedule {
    pub finish_s: Vec<f64>,
    pub spans: Vec<Span>,
    pub makespan_s: f64,
}

/// Flow-level task graph + its link capacities and stream count.
pub struct FlowEngine {
    n_streams: usize,
    /// Per-link capacity in bytes/s.
    caps: Vec<f64>,
    kinds: Vec<Kind>,
    dep_off: Vec<u32>,
    dep_arena: Vec<u32>,
}

// -------------------------------------------------------------------
// Heap entries (min-heaps via Reverse; f64 ordered by total_cmp, which
// is safe because all times are finite and non-negative).
// -------------------------------------------------------------------

/// Work completion or flow latency-stage completion.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Ev {
    t: f64,
    id: u32,
    work: bool,
}
impl Eq for Ev {}
impl Ord for Ev {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&o.t).then(self.id.cmp(&o.id))
    }
}
impl PartialOrd for Ev {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

/// Predicted flow finish, valid only while `epoch` is current.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Fin {
    t: f64,
    id: u32,
    epoch: u32,
}
impl Eq for Fin {}
impl Ord for Fin {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&o.t).then(self.id.cmp(&o.id))
    }
}
impl PartialOrd for Fin {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

/// Queued ready Work waiting for its stream.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Rdy {
    t: f64,
    id: u32,
}
impl Eq for Rdy {}
impl Ord for Rdy {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&o.t).then(self.id.cmp(&o.id))
    }
}
impl PartialOrd for Rdy {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

impl FlowEngine {
    pub fn new(n_streams: usize, link_caps: Vec<f64>) -> FlowEngine {
        debug_assert!(link_caps.iter().all(|&c| c > 0.0), "link capacities must be positive");
        FlowEngine {
            n_streams,
            caps: link_caps,
            kinds: Vec::new(),
            dep_off: vec![0],
            dep_arena: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    fn push_deps(&mut self, deps: &[FlowTaskId]) {
        let next = self.kinds.len();
        for &d in deps {
            debug_assert!(d < next, "dependency {d} of task {next} not yet added");
            self.dep_arena.push(d as u32);
        }
        self.dep_off.push(self.dep_arena.len() as u32);
    }

    /// Add a stream-occupying task (compute pass, local SGD, noop).
    pub fn add_work(&mut self, stream: usize, dur_s: f64, deps: &[FlowTaskId]) -> FlowTaskId {
        debug_assert!(stream < self.n_streams, "stream {stream} out of range");
        debug_assert!(dur_s >= 0.0);
        let id = self.kinds.len();
        self.kinds.push(Kind::Work { stream: stream as u32, dur_s });
        self.push_deps(deps);
        id
    }

    /// Add a flow of `bytes` over `links` after a fixed `latency_s`
    /// stage. Zero-byte flows complete at latency end without entering
    /// the bandwidth allocation.
    pub fn add_flow(
        &mut self,
        links: &[usize],
        latency_s: f64,
        bytes: f64,
        deps: &[FlowTaskId],
    ) -> FlowTaskId {
        debug_assert!(links.len() <= 4, "flows traverse at most 4 links");
        debug_assert!(bytes <= 0.0 || !links.is_empty(), "byte-bearing flow needs links");
        debug_assert!(latency_s >= 0.0 && bytes >= 0.0);
        let mut arr = [0u32; 4];
        for (slot, &l) in arr.iter_mut().zip(links) {
            debug_assert!(l < self.caps.len(), "link {l} out of range");
            *slot = l as u32;
        }
        let id = self.kinds.len();
        self.kinds.push(Kind::Flow {
            links: arr,
            n_links: links.len() as u8,
            latency_s,
            bytes,
        });
        self.push_deps(deps);
        id
    }

    /// Execute the graph; errors if a dependency cycle leaves tasks
    /// unfinished.
    pub fn run(&self) -> Result<FlowSchedule> {
        Runner::new(self).run()
    }
}

struct Runner<'a> {
    eng: &'a FlowEngine,
    succ_off: Vec<u32>,
    succ: Vec<u32>,
    preds_left: Vec<u32>,
    finish_s: Vec<f64>,
    spans: Vec<Span>,
    completed: usize,
    // streams
    stream_busy: Vec<bool>,
    stream_q: Vec<BinaryHeap<Reverse<Rdy>>>,
    kick: Vec<u32>,
    // events
    events: BinaryHeap<Reverse<Ev>>,
    fin: BinaryHeap<Reverse<Fin>>,
    epoch: u32,
    // active flows (struct-of-arrays; `pos` maps task id -> index)
    act_id: Vec<u32>,
    act_rem: Vec<f64>,
    act_rate: Vec<f64>,
    pos: Vec<u32>,
    last_drain: f64,
    // solver scratch (sized to the link count, reset via `touched`)
    cnt: Vec<u32>,
    used: Vec<f64>,
    touched: Vec<u32>,
    frozen: Vec<bool>,
}

impl<'a> Runner<'a> {
    fn new(eng: &'a FlowEngine) -> Runner<'a> {
        let nt = eng.kinds.len();
        // successor CSR from the dependency arena
        let mut succ_off = vec![0u32; nt + 1];
        for &d in &eng.dep_arena {
            succ_off[d as usize + 1] += 1;
        }
        for i in 1..=nt {
            succ_off[i] += succ_off[i - 1];
        }
        let mut cursor = succ_off.clone();
        let mut succ = vec![0u32; eng.dep_arena.len()];
        for t in 0..nt {
            let (d0, d1) = (eng.dep_off[t] as usize, eng.dep_off[t + 1] as usize);
            for &d in &eng.dep_arena[d0..d1] {
                succ[cursor[d as usize] as usize] = t as u32;
                cursor[d as usize] += 1;
            }
        }
        let preds_left: Vec<u32> =
            (0..nt).map(|t| eng.dep_off[t + 1] - eng.dep_off[t]).collect();
        Runner {
            eng,
            succ_off,
            succ,
            preds_left,
            finish_s: vec![f64::NAN; nt],
            spans: Vec::new(),
            completed: 0,
            stream_busy: vec![false; eng.n_streams],
            stream_q: (0..eng.n_streams).map(|_| BinaryHeap::new()).collect(),
            kick: Vec::new(),
            events: BinaryHeap::new(),
            fin: BinaryHeap::new(),
            epoch: 0,
            act_id: Vec::new(),
            act_rem: Vec::new(),
            act_rate: Vec::new(),
            pos: vec![NO_POS; nt],
            last_drain: 0.0,
            cnt: vec![0; eng.caps.len()],
            used: vec![0.0; eng.caps.len()],
            touched: Vec::new(),
            frozen: Vec::new(),
        }
    }

    fn links_of(&self, id: usize) -> ([u32; 4], u8) {
        match self.eng.kinds[id] {
            Kind::Flow { links, n_links, .. } => (links, n_links),
            Kind::Work { .. } => ([0; 4], 0),
        }
    }

    /// All preds done: queue a Work on its stream, or start a flow's
    /// latency stage.
    fn ready(&mut self, id: usize, t: f64) {
        match self.eng.kinds[id] {
            Kind::Work { stream, .. } => {
                self.stream_q[stream as usize].push(Reverse(Rdy { t, id: id as u32 }));
                self.kick.push(stream);
            }
            Kind::Flow { latency_s, .. } => {
                self.events.push(Reverse(Ev { t: t + latency_s, id: id as u32, work: false }));
            }
        }
    }

    fn start_work(&mut self, id: usize, t: f64) {
        let Kind::Work { stream, dur_s } = self.eng.kinds[id] else { unreachable!() };
        self.stream_busy[stream as usize] = true;
        self.spans.push(Span { stream, start_s: t, end_s: t + dur_s });
        self.events.push(Reverse(Ev { t: t + dur_s, id: id as u32, work: true }));
    }

    fn complete(&mut self, id: usize, t: f64) {
        debug_assert!(self.finish_s[id].is_nan(), "task {id} completed twice");
        self.finish_s[id] = t;
        self.completed += 1;
        let (s0, s1) = (self.succ_off[id] as usize, self.succ_off[id + 1] as usize);
        for k in s0..s1 {
            let s = self.succ[k] as usize;
            self.preds_left[s] -= 1;
            if self.preds_left[s] == 0 {
                self.ready(s, t);
            }
        }
    }

    /// Advance all active flows to `t` at their current rates.
    fn drain_to(&mut self, t: f64) {
        let dt = t - self.last_drain;
        if dt > 0.0 {
            for i in 0..self.act_id.len() {
                self.act_rem[i] = (self.act_rem[i] - self.act_rate[i] * dt).max(0.0);
            }
        }
        self.last_drain = t;
    }

    fn join_flow(&mut self, id: usize, bytes: f64) {
        self.pos[id] = self.act_id.len() as u32;
        self.act_id.push(id as u32);
        self.act_rem.push(bytes);
        self.act_rate.push(0.0);
    }

    fn finish_flow(&mut self, id: usize, t: f64) {
        let i = self.pos[id] as usize;
        self.act_id.swap_remove(i);
        self.act_rem.swap_remove(i);
        self.act_rate.swap_remove(i);
        if i < self.act_id.len() {
            self.pos[self.act_id[i] as usize] = i as u32;
        }
        self.pos[id] = NO_POS;
        self.complete(id, t);
    }

    /// Max-min fair allocation by progressive filling, then finish-time
    /// predictions for the new epoch.
    fn resolve(&mut self, t: f64) {
        self.epoch += 1;
        let f_n = self.act_id.len();
        if f_n == 0 {
            return;
        }
        self.touched.clear();
        for i in 0..f_n {
            let (links, nl) = self.links_of(self.act_id[i] as usize);
            for &l in &links[..nl as usize] {
                if self.cnt[l as usize] == 0 {
                    self.touched.push(l);
                    self.used[l as usize] = 0.0;
                }
                self.cnt[l as usize] += 1;
            }
        }
        self.frozen.clear();
        self.frozen.resize(f_n, false);
        for r in self.act_rate.iter_mut() {
            *r = 0.0;
        }
        let mut unfrozen = f_n;
        while unfrozen > 0 {
            let mut inc = f64::INFINITY;
            for &l in &self.touched {
                let l = l as usize;
                if self.cnt[l] > 0 {
                    inc = inc.min((self.caps[l] - self.used[l]) / self.cnt[l] as f64);
                }
            }
            if !inc.is_finite() {
                break; // every remaining flow is link-free (zero-link flows never get here)
            }
            let inc = inc.max(0.0);
            for i in 0..f_n {
                if !self.frozen[i] {
                    self.act_rate[i] += inc;
                }
            }
            for &l in &self.touched {
                let l = l as usize;
                if self.cnt[l] > 0 {
                    self.used[l] += inc * self.cnt[l] as f64;
                }
            }
            let mut froze = 0usize;
            for i in 0..f_n {
                if self.frozen[i] {
                    continue;
                }
                let (links, nl) = self.links_of(self.act_id[i] as usize);
                let saturated = links[..nl as usize]
                    .iter()
                    .any(|&l| {
                        let l = l as usize;
                        self.caps[l] - self.used[l] <= self.caps[l] * SAT_EPS
                    });
                if saturated {
                    self.frozen[i] = true;
                    froze += 1;
                    for &l in &links[..nl as usize] {
                        self.cnt[l as usize] -= 1;
                    }
                }
            }
            if froze == 0 {
                break; // rates already maximal within SAT_EPS
            }
            unfrozen -= froze;
        }
        for &l in &self.touched {
            self.cnt[l as usize] = 0;
        }
        for i in 0..f_n {
            let rate = self.act_rate[i].max(f64::MIN_POSITIVE);
            self.fin.push(Reverse(Fin {
                t: t + self.act_rem[i] / rate,
                id: self.act_id[i],
                epoch: self.epoch,
            }));
        }
    }

    /// Next valid flow-finish time, discarding stale-epoch entries.
    fn peek_fin(&mut self) -> Option<f64> {
        while let Some(&Reverse(f)) = self.fin.peek() {
            if f.epoch != self.epoch || self.pos[f.id as usize] == NO_POS {
                self.fin.pop();
                continue;
            }
            return Some(f.t);
        }
        None
    }

    /// Process everything scheduled at exactly time `t` as one batch;
    /// returns whether the active flow set changed.
    fn batch(&mut self, t: f64) -> bool {
        let mut changed = false;
        loop {
            let mut progressed = false;
            // flow finishes at t (valid epoch only)
            while let Some(tf) = self.peek_fin() {
                if tf > t {
                    break;
                }
                let Reverse(f) = self.fin.pop().expect("peeked");
                self.drain_to(t);
                self.finish_flow(f.id as usize, t);
                changed = true;
                progressed = true;
            }
            // work completions and latency-stage completions at t
            while let Some(&Reverse(e)) = self.events.peek() {
                if e.t > t {
                    break;
                }
                self.events.pop();
                let id = e.id as usize;
                if e.work {
                    self.complete(id, t);
                    let Kind::Work { stream, .. } = self.eng.kinds[id] else { unreachable!() };
                    self.stream_busy[stream as usize] = false;
                    self.kick.push(stream);
                } else {
                    let Kind::Flow { bytes, .. } = self.eng.kinds[id] else { unreachable!() };
                    if bytes <= 0.0 {
                        self.complete(id, t);
                    } else {
                        self.drain_to(t);
                        self.join_flow(id, bytes);
                        changed = true;
                    }
                }
                progressed = true;
            }
            // dispatch freed/kicked streams in (ready time, id) order
            while let Some(s) = self.kick.pop() {
                let s = s as usize;
                if !self.stream_busy[s] {
                    if let Some(Reverse(r)) = self.stream_q[s].pop() {
                        self.start_work(r.id as usize, t);
                        progressed = true;
                    }
                }
            }
            if !progressed {
                return changed;
            }
        }
    }

    fn run(mut self) -> Result<FlowSchedule> {
        let nt = self.eng.kinds.len();
        for id in 0..nt {
            if self.preds_left[id] == 0 {
                self.ready(id, 0.0);
            }
        }
        let mut t = 0.0f64;
        loop {
            if self.batch(t) {
                self.drain_to(t);
                self.resolve(t);
            }
            let te = self.events.peek().map(|&Reverse(e)| e.t);
            let tf = self.peek_fin();
            t = match (te, tf) {
                (None, None) => break,
                (Some(a), None) => a,
                (None, Some(b)) => b,
                (Some(a), Some(b)) => a.min(b),
            };
        }
        if self.completed != nt {
            bail!(
                "flowsim deadlock: {} of {nt} tasks completed (dependency cycle?)",
                self.completed
            );
        }
        let makespan_s = self.finish_s.iter().cloned().fold(0.0, f64::max);
        Ok(FlowSchedule { finish_s: self.finish_s, spans: self.spans, makespan_s })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * b.abs().max(1.0)
    }

    #[test]
    fn lone_flow_runs_at_link_capacity() {
        let mut fe = FlowEngine::new(0, vec![100.0]);
        let f = fe.add_flow(&[0], 0.5, 100.0, &[]);
        let s = fe.run().unwrap();
        assert!(approx(s.finish_s[f], 1.5), "{}", s.finish_s[f]);
    }

    #[test]
    fn two_flows_fair_share_one_link() {
        let mut fe = FlowEngine::new(0, vec![100.0]);
        let a = fe.add_flow(&[0], 0.0, 100.0, &[]);
        let b = fe.add_flow(&[0], 0.0, 100.0, &[]);
        let s = fe.run().unwrap();
        assert!(approx(s.finish_s[a], 2.0) && approx(s.finish_s[b], 2.0));
    }

    #[test]
    fn late_joiner_redistributes_bandwidth() {
        // A alone at 100 B/s until t=0.5, then 50/50 with B until A
        // finishes at 1.5 (50 bytes left at 0.5 -> 1 s at 50 B/s), then
        // B alone at 100 B/s: 50 left at 1.5 -> done at 2.0.
        let mut fe = FlowEngine::new(1, vec![100.0]);
        let a = fe.add_flow(&[0], 0.0, 100.0, &[]);
        let gate = fe.add_work(0, 0.5, &[]);
        let b = fe.add_flow(&[0], 0.0, 100.0, &[gate]);
        let s = fe.run().unwrap();
        assert!(approx(s.finish_s[a], 1.5), "{}", s.finish_s[a]);
        assert!(approx(s.finish_s[b], 2.0), "{}", s.finish_s[b]);
    }

    #[test]
    fn rate_is_min_over_route_links() {
        let mut fe = FlowEngine::new(0, vec![100.0, 40.0]);
        let f = fe.add_flow(&[0, 1], 0.0, 80.0, &[]);
        let s = fe.run().unwrap();
        assert!(approx(s.finish_s[f], 2.0), "{}", s.finish_s[f]);
    }

    #[test]
    fn max_min_gives_unbottlenecked_flow_the_slack() {
        // Flows A and B share link 0 (cap 100); B also crosses link 1
        // (cap 30). Max-min: B is capped at 30, A gets the remaining 70.
        let mut fe = FlowEngine::new(0, vec![100.0, 30.0]);
        let a = fe.add_flow(&[0], 0.0, 70.0, &[]);
        let b = fe.add_flow(&[0, 1], 0.0, 30.0, &[]);
        let s = fe.run().unwrap();
        assert!(approx(s.finish_s[a], 1.0), "{}", s.finish_s[a]);
        assert!(approx(s.finish_s[b], 1.0), "{}", s.finish_s[b]);
    }

    #[test]
    fn streams_are_fifo_and_serial() {
        let mut fe = FlowEngine::new(1, vec![]);
        let a = fe.add_work(0, 1.0, &[]);
        let b = fe.add_work(0, 2.0, &[]);
        let s = fe.run().unwrap();
        assert!(approx(s.finish_s[a], 1.0) && approx(s.finish_s[b], 3.0));
        assert_eq!(s.spans.len(), 2);
        assert!(approx(s.makespan_s, 3.0));
    }

    #[test]
    fn zero_byte_flow_is_latency_only() {
        let mut fe = FlowEngine::new(0, vec![100.0]);
        let f = fe.add_flow(&[0], 0.25, 0.0, &[]);
        let s = fe.run().unwrap();
        assert!(approx(s.finish_s[f], 0.25));
    }

    #[test]
    fn dependencies_chain_across_kinds() {
        let mut fe = FlowEngine::new(1, vec![100.0]);
        let w = fe.add_work(0, 1.0, &[]);
        let f = fe.add_flow(&[0], 0.5, 100.0, &[w]);
        let w2 = fe.add_work(0, 0.5, &[f]);
        let s = fe.run().unwrap();
        assert!(approx(s.finish_s[f], 2.5), "{}", s.finish_s[f]);
        assert!(approx(s.finish_s[w2], 3.0));
    }
}
