//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The workspace builds fully offline (no registry access), so this
//! vendored crate implements exactly the subset of the real `anyhow`
//! API the workspace uses: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Error chains are stored as flat strings — rich downcasting and
//! backtraces are intentionally out of scope.
//!
//! Display mirrors the real crate: `{}` prints the outermost message,
//! `{:#}` prints the whole chain joined with `": "`.

use std::fmt;

/// A string-chain error: outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the chain from outermost to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e).context("opening widget")
    }

    #[test]
    fn display_plain_vs_alternate() {
        let err = fails_io().unwrap_err();
        assert_eq!(format!("{err}"), "opening widget");
        assert_eq!(format!("{err:#}"), "opening widget: gone");
        assert_eq!(err.root_cause(), "gone");
    }

    #[test]
    fn macros_and_option_context() {
        fn inner(x: Option<u32>) -> Result<u32> {
            let v = x.context("missing x")?;
            ensure!(v < 10, "too big: {v}");
            if v == 7 {
                bail!("unlucky {}", v);
            }
            Ok(v)
        }
        assert_eq!(inner(Some(3)).unwrap(), 3);
        assert_eq!(format!("{}", inner(None).unwrap_err()), "missing x");
        assert_eq!(format!("{}", inner(Some(12)).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", inner(Some(7)).unwrap_err()), "unlucky 7");
        let e: Error = anyhow!("basic {}", 1);
        assert_eq!(format!("{e}"), "basic 1");
    }

    #[test]
    fn with_context_lazily_formats() {
        let r: Result<(), Error> = Err(Error::msg("inner")).with_context(|| format!("outer {}", 2));
        assert_eq!(format!("{:#}", r.unwrap_err()), "outer 2: inner");
    }
}
