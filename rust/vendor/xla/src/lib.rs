//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The L3 runtime executes AOT-lowered HLO artifacts through PJRT when a
//! real `xla_extension` install is present. This container ships without
//! it, so this vendored stub keeps the crate building and the non-runtime
//! layers (analytic models, netsim, coordinator logic) fully testable:
//!
//! * [`Literal`] is a **functional** host-side implementation (shape +
//!   typed storage) — tensor round-trip code and its tests work.
//! * PJRT entry points ([`PjRtClient::cpu`], [`HloModuleProto`]) return a
//!   descriptive [`Error`], so `Runtime::new` degrades into a clear
//!   "PJRT unavailable" failure and artifact-dependent tests skip.
//!
//! Swapping the `xla` path dependency in `rust/Cargo.toml` back to the
//! real bindings restores execution with no source changes.

use std::fmt;

/// Stub error type (the real crate's `xla::Error` equivalent).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: built against the offline xla stub (vendor/xla); install xla_extension and \
         point the `xla` dependency at the real bindings to execute artifacts"
    )))
}

/// Element types the artifact ABI uses (plus enough extras that callers'
/// catch-all match arms stay reachable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

/// Shape of a non-tuple literal: dimensions + element type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

mod private {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Host element types a [`Literal`] can carry.
pub trait NativeType: Copy + Sized + private::Sealed {
    #[doc(hidden)]
    fn to_storage(v: &[Self]) -> Storage;
    #[doc(hidden)]
    fn from_storage(s: &Storage) -> Option<&[Self]>;
    #[doc(hidden)]
    fn element_type() -> ElementType;
}

impl NativeType for f32 {
    fn to_storage(v: &[f32]) -> Storage {
        Storage::F32(v.to_vec())
    }

    fn from_storage(s: &Storage) -> Option<&[f32]> {
        match s {
            Storage::F32(d) => Some(d),
            _ => None,
        }
    }

    fn element_type() -> ElementType {
        ElementType::F32
    }
}

impl NativeType for i32 {
    fn to_storage(v: &[i32]) -> Storage {
        Storage::I32(v.to_vec())
    }

    fn from_storage(s: &Storage) -> Option<&[i32]> {
        match s {
            Storage::I32(d) => Some(d),
            _ => None,
        }
    }

    fn element_type() -> ElementType {
        ElementType::S32
    }
}

/// A host-side tensor value: shape + typed storage (or a tuple).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    storage: Storage,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], storage: T::to_storage(v) }
    }

    /// Tuple literal (what `return_tuple=True` artifacts produce).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: Vec::new(), storage: Storage::Tuple(parts) }
    }

    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(d) => d.len(),
            Storage::I32(d) => d.len(),
            Storage::Tuple(t) => t.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if matches!(self.storage, Storage::Tuple(_)) {
            return Err(Error("reshape of a tuple literal".into()));
        }
        if want != have {
            return Err(Error(format!("reshape: {have} elements do not fit {dims:?}")));
        }
        Ok(Literal { dims: dims.to_vec(), storage: self.storage.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.storage {
            Storage::F32(_) => ElementType::F32,
            Storage::I32(_) => ElementType::S32,
            Storage::Tuple(_) => return Err(Error("array_shape of a tuple literal".into())),
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_storage(&self.storage)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error(format!("to_vec: literal is not {:?}", T::element_type())))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.storage {
            Storage::Tuple(v) => Ok(v.clone()),
            _ => Err(Error("to_tuple of a non-tuple literal".into())),
        }
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::from_storage(&self.storage)
            .and_then(|d| d.first().copied())
            .ok_or_else(|| Error("get_first_element: empty or mistyped literal".into()))
    }

    /// Copy the raw elements into a caller-owned buffer of exact length.
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        let src = T::from_storage(&self.storage)
            .ok_or_else(|| Error(format!("copy_raw_to: literal is not {:?}", T::element_type())))?;
        if src.len() != dst.len() {
            return Err(Error(format!(
                "copy_raw_to: {} elements into a buffer of {}",
                src.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(src);
        Ok(())
    }
}

/// Parsed HLO module (stub: parsing requires the real bindings).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client (stub: construction always fails with a clear message).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Argument forms `PjRtLoadedExecutable::execute` accepts.
pub trait ExecuteArg {}

impl ExecuteArg for Literal {}
impl<'a> ExecuteArg for &'a Literal {}

/// A compiled executable (stub: unobtainable, so methods are unreachable
/// in practice but keep callers type-checking).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: ExecuteArg>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]).reshape(&[2, 3]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 3]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn scalar_reshape_to_empty_dims() {
        let l = Literal::vec1(&[4.5f32]).reshape(&[]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[] as &[i64]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 4.5);
    }

    #[test]
    fn tuple_and_copy_raw() {
        let t = Literal::tuple(vec![Literal::vec1(&[1i32, 2]), Literal::vec1(&[3.0f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        let mut buf = [0i32; 2];
        parts[0].copy_raw_to(&mut buf).unwrap();
        assert_eq!(buf, [1, 2]);
        assert!(t.array_shape().is_err());
    }

    #[test]
    fn pjrt_is_cleanly_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("offline xla stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
