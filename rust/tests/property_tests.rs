//! Property-based tests on coordinator invariants (routing/sharding,
//! batching, state management) and the collectives — randomized with the
//! in-tree deterministic PRNG (proptest is unavailable offline; shrinking
//! is traded for printing the failing seed/case).

use pcl_dnn::collectives::{inline, shard_range, threaded, GroupTopology};
use pcl_dnn::coordinator::{CommandQueue, MicrobatchPlan, ParamStore, SgdConfig};
use pcl_dnn::netsim::Engine;
use pcl_dnn::util::json::Json;
use pcl_dnn::util::rng::Rng;

const CASES: u64 = 200;

#[test]
fn prop_shard_ranges_partition() {
    let mut rng = Rng::new(0x5a5a);
    for case in 0..CASES {
        let n = 1 + rng.below(16) as usize;
        let len = rng.below(10_000) as usize;
        let mut covered = 0usize;
        let mut prev_end = 0usize;
        for r in 0..n {
            let s = shard_range(r, n, len);
            assert_eq!(s.start, prev_end, "case {case}: gap at rank {r}");
            covered += s.len();
            prev_end = s.end;
            // balance: sizes differ by at most one
            assert!(s.len() + 1 >= len / n && s.len() <= len / n + 1, "case {case}");
        }
        assert_eq!(covered, len, "case {case}");
    }
}

#[test]
fn prop_microbatch_plan_is_lossless_permutation() {
    let mut rng = Rng::new(0xbeef);
    for case in 0..CASES {
        let workers = 1 + rng.below(8) as usize;
        let micro = 1 + rng.below(8) as usize;
        let per_w = 1 + rng.below(8) as usize;
        let global = workers * micro * per_w;
        let plan = MicrobatchPlan::new(global, workers, micro).unwrap();
        let mut samples: Vec<usize> = plan
            .per_worker
            .iter()
            .flatten()
            .flat_map(|&s| s..s + micro)
            .collect();
        samples.sort_unstable();
        assert_eq!(samples, (0..global).collect::<Vec<_>>(), "case {case}");
    }
}

#[test]
fn prop_plan_worker_invariance_of_sample_set() {
    // the Fig 5 precondition for arbitrary random shapes
    let mut rng = Rng::new(0x41);
    for _ in 0..CASES {
        let micro = 1 + rng.below(4) as usize;
        let base = 1 + rng.below(6) as usize;
        let global = micro * base * 8;
        let mut sets: Vec<Vec<usize>> = Vec::new();
        for workers in [1usize, 2, 4, 8] {
            let plan = MicrobatchPlan::new(global, workers, micro).unwrap();
            let mut v: Vec<usize> =
                plan.per_worker.iter().flatten().flat_map(|&s| s..s + micro).collect();
            v.sort_unstable();
            sets.push(v);
        }
        assert!(sets.windows(2).all(|w| w[0] == w[1]));
    }
}

#[test]
fn prop_inline_threaded_collectives_bitwise_equal() {
    let mut rng = Rng::new(0xc011);
    for case in 0..60 {
        let ranks = 1 + rng.below(9) as usize;
        let len = rng.below(3000) as usize;
        let mut a: Vec<Vec<f32>> = (0..ranks)
            .map(|_| (0..len).map(|_| rng.normal()).collect())
            .collect();
        let mut b = a.clone();
        inline::allreduce(&mut a);
        threaded::allreduce(&mut b);
        assert_eq!(a, b, "case {case} ranks {ranks} len {len}");
    }
}

#[test]
fn prop_allreduce_is_sum_within_fp_tolerance() {
    let mut rng = Rng::new(0xadd);
    for case in 0..60 {
        let ranks = 2 + rng.below(6) as usize;
        let len = 1 + rng.below(500) as usize;
        let bufs: Vec<Vec<f32>> = (0..ranks)
            .map(|_| (0..len).map(|_| rng.normal()).collect())
            .collect();
        let want: Vec<f64> = (0..len)
            .map(|i| bufs.iter().map(|b| b[i] as f64).sum())
            .collect();
        let mut got = bufs.clone();
        inline::allreduce(&mut got);
        for r in 0..ranks {
            for i in 0..len {
                let d = (got[r][i] as f64 - want[i]).abs();
                assert!(d <= 1e-4 * want[i].abs().max(1.0), "case {case} r{r} i{i}");
            }
        }
    }
}

#[test]
fn prop_group_topology_partitions_workers() {
    let mut rng = Rng::new(0x707);
    for _ in 0..CASES {
        let gs = 1 + rng.below(6) as usize;
        let groups = 1 + rng.below(6) as usize;
        let t = GroupTopology::new(gs * groups, groups);
        // every worker in exactly one group; replica sets hit every group
        let mut count = vec![0usize; t.nodes];
        for g in 0..t.groups {
            for w in t.group_members(g) {
                count[w] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1));
        for r in 0..t.group_size() {
            let reps = t.replica_set(r);
            let gset: std::collections::BTreeSet<usize> =
                reps.iter().map(|&w| t.group_of(w)).collect();
            assert_eq!(gset.len(), t.groups);
            assert!(reps.iter().all(|&w| t.rank_in_group(w) == r));
        }
    }
}

#[test]
fn prop_sgd_update_linearity() {
    // applying grads g1 then g2 with lr == applying (g1+g2) with lr when
    // momentum = 0 — the associativity the gradient-accumulation path
    // relies on.
    let mut rng = Rng::new(0x5d5);
    for case in 0..100 {
        let len = 1 + rng.below(64) as usize;
        let init: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let g1: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let g2: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let cfg = SgdConfig { lr: 0.1, ..SgdConfig::default() };
        let mut a = ParamStore::new(vec![init.clone()], cfg);
        a.apply_all(&[g1.clone()], 1.0).unwrap();
        a.apply_all(&[g2.clone()], 1.0).unwrap();
        let sum: Vec<f32> = g1.iter().zip(&g2).map(|(x, y)| x + y).collect();
        let mut b = ParamStore::new(vec![init], cfg);
        b.apply_all(&[sum], 1.0).unwrap();
        for (x, y) in a.tensors[0].iter().zip(&b.tensors[0]) {
            assert!((x - y).abs() < 1e-5, "case {case}");
        }
    }
}

#[test]
fn prop_command_queue_matches_fifo_model_single_thread() {
    let mut rng = Rng::new(0x9);
    for case in 0..CASES {
        let cap = 2 + rng.below(16) as usize;
        let q = CommandQueue::new(cap);
        let mut model = std::collections::VecDeque::new();
        let mut next = 0u64;
        for _ in 0..200 {
            if rng.below(2) == 0 {
                let ok_model = model.len() < q.capacity();
                match q.push(next) {
                    Ok(()) => {
                        assert!(ok_model, "case {case}: queue accepted beyond capacity");
                        model.push_back(next);
                        next += 1;
                    }
                    Err(_) => assert!(!ok_model, "case {case}: queue rejected below capacity"),
                }
            } else {
                assert_eq!(q.pop(), model.pop_front(), "case {case}");
            }
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::new(0x150);
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(2_000_001) as f64 - 1e6) / 8.0),
            3 => {
                let len = rng.below(12) as usize;
                Json::Str(
                    (0..len)
                        .map(|_| char::from_u32(32 + rng.below(95) as u32).unwrap())
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..CASES {
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e} in {text}"));
        assert_eq!(v, back, "case {case}");
    }
}

// ------------------------- discrete-event engine -------------------------

/// Random task DAG: multi-resource tasks, random deps on earlier tasks.
fn random_engine(rng: &mut Rng) -> Engine {
    let mut e = Engine::new();
    let n_tasks = 5 + rng.below(60) as usize;
    let n_res = 1 + rng.below(8) as usize;
    for id in 0..n_tasks {
        let n_own = 1 + rng.below(3) as usize;
        let resources: Vec<usize> =
            (0..n_own).map(|_| rng.below(n_res as u64) as usize).collect();
        let dur = rng.below(50);
        let mut deps: Vec<usize> = Vec::new();
        if id > 0 {
            for _ in 0..rng.below(3) {
                deps.push(rng.below(id as u64) as usize);
            }
            deps.sort_unstable();
            deps.dedup();
        }
        e.add_multi(&format!("t{id}"), &resources, dur, &deps);
    }
    e
}

#[test]
fn prop_engine_task_starts_after_all_deps_end() {
    let mut rng = Rng::new(0xde1);
    for case in 0..CASES {
        let e = random_engine(&mut rng);
        let s = e.run();
        for id in 0..e.len() {
            for &d in e.deps(id) {
                assert!(
                    s.start_ns[id] >= s.end_ns[d],
                    "case {case}: task {id} starts {} before dep {d} ends {}",
                    s.start_ns[id],
                    s.end_ns[d]
                );
            }
        }
    }
}

#[test]
fn prop_engine_no_overlap_on_any_unary_resource() {
    let mut rng = Rng::new(0xde2);
    for case in 0..CASES {
        let e = random_engine(&mut rng);
        let s = e.run();
        let n_res = e.n_resources();
        for r in 0..n_res {
            let mut intervals: Vec<(u64, u64)> = (0..e.len())
                .filter(|&id| e.resources(id).contains(&r))
                .map(|id| (s.start_ns[id], s.end_ns[id]))
                .filter(|&(a, b)| b > a) // zero-width tasks cannot overlap
                .collect();
            intervals.sort_unstable();
            for w in intervals.windows(2) {
                assert!(
                    w[1].0 >= w[0].1,
                    "case {case}: resource {r} double-booked: {:?} then {:?}",
                    w[0],
                    w[1]
                );
            }
        }
    }
}

#[test]
fn prop_engine_schedule_is_bit_identical_across_runs() {
    // determinism is load-bearing for Fig 5's "distributed = serial"
    // equivalence claim
    let mut rng = Rng::new(0xde3);
    for case in 0..CASES {
        let seed = rng.next_u64();
        let a = random_engine(&mut Rng::new(seed)).run();
        let e2 = random_engine(&mut Rng::new(seed));
        let b = e2.run();
        assert_eq!(a, b, "case {case} seed {seed:#x}");
        let c = e2.run(); // same engine re-run
        assert_eq!(a, c, "case {case} re-run");
    }
}

#[test]
fn prop_engine_makespan_bounds() {
    // makespan >= busiest resource's total work and >= any dependency
    // chain; makespan <= total work (single-resource serial worst case)
    let mut rng = Rng::new(0xde4);
    for case in 0..CASES {
        let e = random_engine(&mut rng);
        let s = e.run();
        let mut per_res = vec![0u64; e.n_resources()];
        let mut total = 0u64;
        for id in 0..e.len() {
            for &r in e.resources(id) {
                per_res[r] += e.duration_ns(id);
            }
            total += e.duration_ns(id);
        }
        let busiest = per_res.iter().copied().max().unwrap_or(0);
        assert!(s.makespan_ns >= busiest, "case {case}");
        assert!(s.makespan_ns <= total, "case {case}");
    }
}
