//! Bit-identity suite for the streaming overlapped exchange (ISSUE 8).
//!
//! The streaming pipeline folds worker gradients into a running
//! rank-ordered sum on the comm thread while later workers compute. The
//! paper's equivalence claim (§5, Fig 5) demands the parallel schedule
//! change *nothing* about the arithmetic — so these tests pin the
//! overlapped step to be **bit-identical** (f32 `to_bits`, not
//! approximately equal) to the retained serial reference pipeline,
//! across worker counts, exchange topologies, and optimizers, over
//! multiple steps (so optimizer state — momentum / Adam moments — is
//! covered too).
//!
//! Everything here drives `step_with_compute` with synthetic
//! deterministic gradients: no PJRT artifacts needed, but the real comm
//! thread, command queue, and fold kernels are exercised.

use pcl_dnn::collectives::GroupTopology;
use pcl_dnn::coordinator::state::Optimizer;
use pcl_dnn::coordinator::{MicrobatchPlan, SgdConfig, SyncSgdCoordinator};

/// splitmix64 — deterministic, cheap, avalanche-quality bit mixing so
/// every (seed, step, worker, micro, tensor, element) gets an unrelated
/// gradient value.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Deterministic pseudo-gradient in ~[-0.5, 0.5).
fn grad_val(seed: u64, step: u64, w: u64, m: u64, t: u64, i: u64) -> f32 {
    let e = i.wrapping_mul(0x2545_f491_4f6c_dd1d);
    let h = mix(seed ^ mix(step ^ mix(w ^ mix(m ^ mix(t ^ e)))));
    (h >> 40) as f32 / (1u64 << 24) as f32 - 0.5
}

/// Synthetic per-worker compute: overwrites `acc` on the first
/// microbatch and accumulates afterwards — the same contract the PJRT
/// closure in `SyncSgdCoordinator::step` follows. The step index is
/// recovered from a call counter (both pipelines call the hook once per
/// worker, in rank order).
fn make_compute(
    seed: u64,
    workers: usize,
) -> impl FnMut(usize, &[usize], &mut [Vec<f32>]) -> anyhow::Result<(f64, u64)> {
    let mut calls = 0usize;
    move |w: usize, starts: &[usize], acc: &mut [Vec<f32>]| {
        let step = (calls / workers) as u64;
        assert_eq!(calls % workers, w, "compute hook must be called in rank order");
        calls += 1;
        let mut loss = 0.0f64;
        for (m, _start) in starts.iter().enumerate() {
            for (t, buf) in acc.iter_mut().enumerate() {
                for (i, x) in buf.iter_mut().enumerate() {
                    let g = grad_val(seed, step, w as u64, m as u64, t as u64, i as u64);
                    if m == 0 {
                        *x = g;
                    } else {
                        *x += g;
                    }
                }
            }
            loss += grad_val(seed ^ 0x1055, step, w as u64, m as u64, 0, u64::MAX) as f64;
        }
        Ok((loss.abs() + 0.1, starts.len() as u64))
    }
}

fn init_params(shapes: &[usize], seed: u64) -> Vec<Vec<f32>> {
    shapes
        .iter()
        .enumerate()
        .map(|(t, &n)| {
            (0..n).map(|i| 0.2 * grad_val(seed, 7, 7, 7, t as u64, i as u64)).collect()
        })
        .collect()
}

fn topos_for(kind: &str, workers: usize, n_tensors: usize) -> Vec<Option<GroupTopology>> {
    match kind {
        "none" => vec![None; n_tensors],
        // alternate sharded/replicated tensors so both exchange paths
        // run within a single step
        "model" => (0..n_tensors)
            .map(|t| (t % 2 == 0).then(|| GroupTopology::model_parallel(workers)))
            .collect(),
        "hybrid" => (0..n_tensors)
            .map(|t| (t % 2 == 1).then(|| GroupTopology::new(workers, 2)))
            .collect(),
        other => panic!("unknown topo kind {other}"),
    }
}

fn sgd_for(opt: &str) -> SgdConfig {
    match opt {
        "sgd" => {
            SgdConfig { lr: 0.05, momentum: 0.0, weight_decay: 0.0, optimizer: Optimizer::Sgd }
        }
        "momentum" => {
            SgdConfig { lr: 0.05, momentum: 0.9, weight_decay: 5e-4, optimizer: Optimizer::Sgd }
        }
        "adam" => {
            SgdConfig { lr: 3e-3, momentum: 0.0, weight_decay: 0.0, optimizer: Optimizer::adam() }
        }
        other => panic!("unknown optimizer {other}"),
    }
}

/// Run `steps` steps through a streaming and a reference coordinator
/// built from identical state, asserting bitwise-equal losses and final
/// parameters plus the StepStats invariants. Returns the streaming
/// coordinator for further inspection.
fn run_pair(
    shapes: &[usize],
    workers: usize,
    topo_kind: &str,
    opt: &str,
    steps: usize,
    seed: u64,
) -> SyncSgdCoordinator {
    let params = init_params(shapes, seed);
    let plan = MicrobatchPlan::new(workers * 4, workers, 2).unwrap();
    let topos = topos_for(topo_kind, workers, shapes.len());
    let sgd = sgd_for(opt);
    let mut streaming = SyncSgdCoordinator::with_plan(
        "synthetic",
        params.clone(),
        plan.clone(),
        sgd,
        topos.clone(),
    );
    streaming.set_overlap(true);
    let mut reference = SyncSgdCoordinator::with_plan("synthetic", params, plan, sgd, topos);
    reference.set_overlap(false);
    let mut cs = make_compute(seed, workers);
    let mut cr = make_compute(seed, workers);
    let ctx = format!("workers={workers} topo={topo_kind} opt={opt}");
    for step in 0..steps {
        let ss = streaming.step_with_compute(&mut cs).unwrap();
        let sr = reference.step_with_compute(&mut cr).unwrap();
        assert_eq!(
            ss.loss.to_bits(),
            sr.loss.to_bits(),
            "{ctx} step {step}: loss diverged ({} vs {})",
            ss.loss,
            sr.loss
        );
        assert_eq!(ss.executions, sr.executions, "{ctx} step {step}");
        assert_eq!(ss.plan_sharded, sr.plan_sharded, "{ctx} step {step}");
        for stats in [&ss, &sr] {
            assert!(stats.comm_wait_s >= 0.0, "{ctx} step {step}: negative comm_wait_s");
            assert!(stats.overlap_s >= 0.0, "{ctx} step {step}: negative overlap_s");
            assert!(
                stats.overlap_s <= stats.comm_busy_s + 1e-9,
                "{ctx} step {step}: overlap {} > busy {}",
                stats.overlap_s,
                stats.comm_busy_s
            );
            let f = stats.overlap_frac();
            assert!((0.0..=1.0).contains(&f), "{ctx} step {step}: overlap_frac {f}");
        }
    }
    for (t, (a, b)) in
        streaming.params.tensors.iter().zip(reference.params.tensors.iter()).enumerate()
    {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: tensor {t} elem {i} diverged ({x} vs {y})"
            );
        }
    }
    streaming
}

/// The tentpole acceptance property: overlapped averaged gradients and
/// losses are bit-identical to the serial reference across a randomized
/// grid of worker counts x topologies x optimizers.
#[test]
fn streaming_is_bit_identical_to_reference_across_grid() {
    // odd, non-round tensor shapes so shard boundaries never align
    let shapes = [33usize, 1024, 7, 4093, 257];
    let mut seed = 0x1558_u64;
    for workers in [1usize, 2, 4, 8] {
        for topo_kind in ["none", "model", "hybrid"] {
            if topo_kind == "hybrid" && workers % 2 != 0 {
                continue; // hybrid groups=2 needs an even worker count
            }
            for opt in ["sgd", "momentum", "adam"] {
                seed = mix(seed);
                run_pair(&shapes, workers, topo_kind, opt, 3, seed);
            }
        }
    }
}

/// Tensors past the fold-chunking threshold take the multi-threaded
/// fold path on the comm thread; chunking must not change a single bit
/// (disjoint chunks, same per-element order).
#[test]
fn large_tensor_chunked_fold_stays_bit_identical() {
    let shapes = [(1usize << 19) + 17, 129];
    run_pair(&shapes, 4, "none", "momentum", 2, 0xbeef);
}

/// Same streaming config run twice from scratch must reproduce losses
/// and parameters exactly — thread scheduling can reorder *when* folds
/// run, never *what* they compute.
#[test]
fn repeated_streaming_runs_are_deterministic() {
    let run = |_: usize| -> (Vec<u64>, Vec<Vec<u32>>) {
        let shapes = [311usize, 1021];
        let params = init_params(&shapes, 42);
        let plan = MicrobatchPlan::new(24, 6, 2).unwrap();
        let mut c = SyncSgdCoordinator::with_plan(
            "synthetic",
            params,
            plan,
            sgd_for("momentum"),
            topos_for("model", 6, shapes.len()),
        );
        c.set_overlap(true);
        let mut compute = make_compute(42, 6);
        let losses: Vec<u64> =
            (0..4).map(|_| c.step_with_compute(&mut compute).unwrap().loss.to_bits()).collect();
        let bits = c
            .params
            .tensors
            .iter()
            .map(|t| t.iter().map(|x| x.to_bits()).collect())
            .collect();
        (losses, bits)
    };
    let (la, pa) = run(0);
    let (lb, pb) = run(1);
    assert_eq!(la, lb, "loss sequence not reproducible");
    assert_eq!(pa, pb, "final params not reproducible");
}

/// Peak gradient-buffer memory is constant in the worker count: the
/// streaming pool never allocates more than 3 tensor-aligned sets
/// (running sums + in-flight contribution + the one being computed),
/// whether 4 workers contribute or 16.
#[test]
fn gradient_buffer_memory_constant_in_worker_count() {
    let shapes = [513usize, 65];
    let mut allocated = Vec::new();
    for workers in [4usize, 16] {
        let params = init_params(&shapes, 9);
        let plan = MicrobatchPlan::new(workers * 4, workers, 2).unwrap();
        let mut c = SyncSgdCoordinator::with_plan(
            "synthetic",
            params,
            plan,
            sgd_for("sgd"),
            topos_for("none", workers, shapes.len()),
        );
        c.set_overlap(true);
        let mut compute = make_compute(9, workers);
        for _ in 0..3 {
            c.step_with_compute(&mut compute).unwrap();
        }
        assert!(
            c.grad_sets_allocated() <= 3,
            "workers={workers}: {} gradient sets allocated",
            c.grad_sets_allocated()
        );
        allocated.push(c.grad_sets_allocated());
    }
    assert_eq!(allocated[0], allocated[1], "allocation must not scale with workers");
}

/// A medium-size pair run whose StepStats invariants (checked inside
/// `run_pair`: comm_wait >= 0, 0 <= overlap <= busy, overlap_frac in
/// [0, 1]) exercise the accounting with real fold work on the comm
/// thread. Perf assertions live in benches/runtime_exec.rs.
#[test]
fn accounting_invariants_hold_with_real_fold_work() {
    let shapes = [2048usize, 771];
    let c = run_pair(&shapes, 4, "none", "sgd", 2, 0xabcd);
    // streaming actually cycled buffers through the pool
    assert!(c.grad_sets_allocated() >= 2, "streaming path must use >= 2 buffer sets");
}
