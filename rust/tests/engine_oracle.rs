//! Oracle equivalence for the discrete-event engine's indexed fast path.
//!
//! The engine in `netsim::engine` dispatches through per-resource ready
//! queues; `netsim::reference` retains the original full-ready-set scan.
//! The two must produce **bit-identical** `Schedule`s — same start, same
//! end, same makespan for every task — on any DAG, because the schedule
//! is the measurement instrument behind every netsim figure and the
//! determinism claim behind Fig 5. These property tests drive both over
//! randomized multi-resource DAGs (seeded via `util::rng`) shaped to hit
//! the dispatch corner cases: shared links, zero-duration markers,
//! same-time completions and deep dependency fan-in.

use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::models::zoo;
use pcl_dnn::netsim::cluster::{build_training_fleet, build_training_fleet_full, SimConfig};
use pcl_dnn::netsim::{reference, Engine, FleetConfig, RecoveryPolicy, Topology};
use pcl_dnn::util::rng::Rng;

/// Random task DAG tuned for contention: few resources, many tasks, a
/// mix of multi-resource messages, zero-duration markers and duplicate
/// durations (to force same-time completion events).
fn random_engine(rng: &mut Rng, n_tasks: usize, n_res: usize) -> Engine {
    let mut e = Engine::new();
    for id in 0..n_tasks {
        let n_own = 1 + rng.below(3) as usize;
        let resources: Vec<usize> =
            (0..n_own).map(|_| rng.below(n_res as u64) as usize).collect();
        // durations from a tiny alphabet so completions frequently tie
        let dur = match rng.below(5) {
            0 => 0,
            1 => 10,
            2 => 10,
            3 => 25,
            _ => rng.below(100),
        };
        let mut deps: Vec<usize> = Vec::new();
        if id > 0 {
            for _ in 0..rng.below(4) {
                deps.push(rng.below(id as u64) as usize);
            }
            deps.sort_unstable();
            deps.dedup();
        }
        e.add_multi(&format!("t{id}"), &resources, dur, &deps);
    }
    e
}

#[test]
fn fast_path_is_bit_identical_to_reference_on_random_dags() {
    let mut rng = Rng::new(0x0eac1e);
    for case in 0..300 {
        let n_tasks = 5 + rng.below(120) as usize;
        let n_res = 1 + rng.below(10) as usize;
        let e = random_engine(&mut rng, n_tasks, n_res);
        let fast = e.run();
        let oracle = reference::run(&e);
        assert_eq!(
            fast, oracle,
            "case {case}: fast path diverged from reference ({n_tasks} tasks, {n_res} res)"
        );
    }
}

#[test]
fn fast_path_matches_reference_under_heavy_contention() {
    // one or two resources, long task lists: every dispatch decision is
    // a contended one, so any ordering slip shows up immediately
    let mut rng = Rng::new(0x5eed);
    for case in 0..60 {
        let n_tasks = 50 + rng.below(200) as usize;
        let n_res = 1 + rng.below(2) as usize;
        let e = random_engine(&mut rng, n_tasks, n_res);
        assert_eq!(e.run(), reference::run(&e), "case {case}");
    }
}

#[test]
fn fast_path_matches_reference_on_independent_roots() {
    // no dependencies at all: the initial dispatch must drain the whole
    // ready set in (0, id) order exactly like the reference scan
    let mut rng = Rng::new(0x1005);
    for case in 0..40 {
        let n_res = 1 + rng.below(4) as usize;
        let mut e = Engine::new();
        for id in 0..80 {
            let r = rng.below(n_res as u64) as usize;
            e.add(&format!("r{id}"), r, rng.below(30), &[]);
        }
        assert_eq!(e.run(), reference::run(&e), "case {case}");
    }
}

#[test]
fn failure_bearing_fleet_dags_replay_identically_on_the_reference_engine() {
    // real fleet DAGs with a failure event baked in: the split/resume
    // boundary drops a node's streams mid-DAG and splices in the
    // detect -> (replan) -> redistribute transition — randomized over
    // (policy, fail_at, fail_node, topology), the indexed dispatcher
    // must stay bit-identical to the full-scan reference across it
    let mut rng = Rng::new(0xfa11_0eac);
    let p = Platform::aws();
    let net = zoo::overfeat_fast();
    let policies = [RecoveryPolicy::Stall, RecoveryPolicy::Replan, RecoveryPolicy::Shrink];
    for case in 0..9 {
        let nodes = 3 + rng.below(4) as usize; // 3..=6
        let policy = policies[rng.below(3) as usize];
        let fail_at = 1 + rng.below(2) as usize; // 1..=2
        let fail_node = rng.below(nodes as u64) as usize;
        let topology = match rng.below(3) {
            0 => Topology::FullySwitched,
            1 => Topology::FlatSwitch,
            _ => Topology::FatTree { radix: 2, oversub: 2.0 },
        };
        let cfg = SimConfig {
            iterations: 4,
            ..SimConfig::recipe(&net, nodes as u64, 256)
        };
        let fleet_cfg = FleetConfig {
            nodes,
            topology,
            fail_at: Some(fail_at),
            fail_node,
            recovery_s: 2.0,
            recovery: policy,
            ..Default::default()
        };
        let dag = build_training_fleet(&net, &p, &cfg, &fleet_cfg).unwrap();
        assert_eq!(
            dag.eng.run(),
            reference::run(&dag.eng),
            "case {case}: {policy:?} fail_at={fail_at} fail_node={fail_node} \
             nodes={nodes} {topology:?}"
        );
    }
}

#[test]
fn template_instanced_fleet_dags_are_bit_identical_to_the_loop_build() {
    // The tentpole's structural invariant: building two iterations and
    // stamping out the rest by id-offset copying must reproduce the
    // legacy loop build arena-for-arena — and the resulting schedule
    // must still match the full-scan reference oracle. Straggler skew
    // and hetero generations scale durations uniformly across
    // iterations, so the template applies to them too (only a firing
    // failure event forces the loop).
    let p = Platform::aws();
    let net = zoo::overfeat_fast();
    let fleets = [
        FleetConfig::homogeneous(2),
        FleetConfig::homogeneous(5),
        FleetConfig { nodes: 4, straggler_skew: 0.3, ..Default::default() },
        FleetConfig { nodes: 4, hetero: true, ..Default::default() },
    ];
    for fc in &fleets {
        let cfg = SimConfig {
            iterations: 6,
            ..SimConfig::recipe(&net, fc.nodes as u64, 256)
        };
        let tpl = build_training_fleet(&net, &p, &cfg, fc).unwrap();
        let full = build_training_fleet_full(&net, &p, &cfg, fc).unwrap();
        assert!(
            tpl.eng.same_dag(&full.eng),
            "nodes={} skew={} hetero={}: instanced DAG differs from loop build",
            fc.nodes, fc.straggler_skew, fc.hetero
        );
        assert_eq!(tpl.iter_ends, full.iter_ends, "nodes={}", fc.nodes);
        let sched = tpl.eng.run();
        assert_eq!(sched, full.eng.run(), "nodes={}", fc.nodes);
        assert_eq!(sched, reference::run(&tpl.eng), "nodes={}", fc.nodes);
    }
}

#[test]
fn fast_path_matches_reference_on_fleet_like_shape() {
    // the fleet builder's structure in miniature: per-node compute/comm
    // streams plus shared tx/rx link resources, ring-ish message chains
    let mut rng = Rng::new(0xf1ee7);
    for case in 0..40 {
        let nodes = 2 + rng.below(6) as usize;
        let mut e = Engine::new();
        let mut last: Vec<usize> = (0..nodes)
            .map(|v| e.add(&format!("c{v}"), 2 * v, 50 + rng.below(40), &[]))
            .collect();
        for step in 0..nodes - 1 {
            let mut cur = Vec::with_capacity(nodes);
            for j in 0..nodes {
                let dst = (j + 1) % nodes;
                let prev = (j + nodes - 1) % nodes;
                // comm stream + sender tx + receiver rx
                let res = [2 * j + 1, 2 * nodes + 2 * j, 2 * nodes + 2 * dst + 1];
                let deps: Vec<usize> = if step == 0 {
                    vec![last[j]]
                } else {
                    vec![last[j], last[prev]]
                };
                cur.push(e.add_multi(&format!("m{step}"), &res, 20 + rng.below(10), &deps));
            }
            last = cur;
        }
        assert_eq!(e.run(), reference::run(&e), "case {case} nodes {nodes}");
    }
}
