//! Plan-aware failure recovery: the property suite behind the
//! replan-vs-stall-vs-shrink tradeoff.
//!
//! * **Degraded-plan validity** — for every zoo model at n ∈ {8, 16, 64},
//!   both the replanned and the shrink-renormalized plan are valid at
//!   N-1 (every hybrid group shape divides the survivor count).
//! * **Charged-cost accounting** — analytically, `replan`'s total
//!   disruption never exceeds `stall`'s beyond the itemized replan +
//!   redistribution charges (the policies differ by explicit, reported
//!   costs, not hidden ones).
//! * **Cross-backend agreement** — netsim-measured post-failure
//!   efficiency matches the α-β pricing within 5% on a clean fabric
//!   (the §5–6 model-vs-measurement methodology, extended across the
//!   failure boundary).
//! * **The tradeoff itself** — at n ≥ 32, resuming on a replanned
//!   degraded fleet yields better post-failure efficiency than stalling
//!   the full fleet (the ROADMAP's replan-vs-stall question).

use pcl_dnn::experiment::{
    recovery_plans, registry, AnalyticBackend, Backend, ExperimentSpec, FleetSimBackend,
    RecoveryReport,
};
use pcl_dnn::plan::PartitionPlan;

/// A failure-bearing spec: `model` on `platform`, one node dying at the
/// start of iteration 1, with enough iterations for a clean post-failure
/// steady window.
fn failure_spec(model: &str, platform: &str, nodes: u64, mb: u64, policy: &str) -> ExperimentSpec {
    let mut spec = ExperimentSpec::of(
        &format!("failover_{model}_{nodes}_{policy}"),
        model,
        platform,
        nodes,
        mb,
    );
    spec.cluster.fail_at = Some(1);
    spec.cluster.fail_node = 0;
    spec.cluster.recovery_s = 5.0;
    spec.cluster.recovery = policy.into();
    spec.parallelism.iterations = 5;
    spec
}

fn recovery_of(rep: &pcl_dnn::experiment::ScalingReport) -> RecoveryReport {
    RecoveryReport::from_json(&rep.recovery).expect("failure spec must report recovery")
}

#[test]
fn degraded_plans_are_valid_for_every_zoo_model() {
    // The acceptance property: the replanned degraded-N plan passes the
    // divisibility check for every zoo network — N-1 generally breaks
    // the original hybrid shapes, so this is exactly what recovery must
    // re-establish. The shrink renormalization must hold it too.
    for model in registry::model_names() {
        let net = registry::model(model).unwrap();
        for nodes in [8u64, 16, 64] {
            for policy in ["replan", "shrink"] {
                let spec = failure_spec(model, "cori", nodes, 1024, policy);
                let (before, after) = recovery_plans(&spec)
                    .unwrap_or_else(|e| panic!("{model} x{nodes} {policy}: {e:#}"));
                assert_eq!(before.nodes, nodes);
                assert_eq!(after.nodes, nodes - 1, "{model} x{nodes} {policy}");
                after
                    .validate(&net)
                    .unwrap_or_else(|e| panic!("{model} x{nodes} {policy}: {e:#}"));
                for g in &after.assignments {
                    if let pcl_dnn::analytic::comm_model::Strategy::Hybrid { groups } =
                        g.strategy
                    {
                        assert_eq!(
                            (nodes - 1) % groups,
                            0,
                            "{model} x{nodes} {policy} group {:?}: {groups} !| {}",
                            g.name,
                            nodes - 1
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn replan_disruption_stays_within_the_charged_costs() {
    // `replan` is never analytically worse than `stall` beyond the
    // explicitly charged replan + redistribution seconds: the policies
    // trade the stall's restart/replay window for itemized
    // reconfiguration costs, with nothing hidden.
    for model in registry::model_names() {
        for nodes in [8u64, 16, 64] {
            let stall = recovery_of(
                &AnalyticBackend.run(&failure_spec(model, "cori", nodes, 1024, "stall")).unwrap(),
            );
            let replan = recovery_of(
                &AnalyticBackend.run(&failure_spec(model, "cori", nodes, 1024, "replan")).unwrap(),
            );
            assert!(
                replan.stall_s <= stall.stall_s + replan.replan_s + replan.redistribution_s + 1e-9,
                "{model} x{nodes}: replan disruption {} vs stall {} + charges {} + {}",
                replan.stall_s,
                stall.stall_s,
                replan.replan_s,
                replan.redistribution_s
            );
            // the itemized charges really are components of the total
            assert!(replan.stall_s >= replan.replan_s + replan.redistribution_s - 1e-9);
            assert!(replan.replan_s > 0.0 && replan.redistribution_s > 0.0);
            assert_eq!(stall.replan_s, 0.0);
            assert_eq!(stall.redistribution_s, 0.0);
        }
    }
}

#[test]
fn netsim_post_failure_efficiency_matches_analytic_within_5pct() {
    // clean fabric (congestion override 0, homogeneous switched fleet):
    // the measured post-failure steady state of the degraded fleet must
    // agree with the α-β pricing of the same degraded design point.
    for (model, platform, mb) in [
        ("vgg_a", "cori", 512u64),
        ("overfeat_fast", "aws", 256),
        ("cddnn_full", "endeavor", 1024),
    ] {
        for nodes in [8u64, 16] {
            for policy in ["replan", "shrink", "stall"] {
                let mut spec = failure_spec(model, platform, nodes, mb, policy);
                spec.cluster.congestion = Some(0.0);
                let a = recovery_of(&AnalyticBackend.run(&spec).unwrap());
                let f = recovery_of(&FleetSimBackend.run(&spec).unwrap());
                assert_eq!(a.nodes_after, f.nodes_after, "{model} x{nodes} {policy}");
                let rel = (a.post_efficiency - f.post_efficiency).abs()
                    / a.post_efficiency.max(1e-9);
                assert!(
                    rel < 0.05,
                    "{model} x{nodes} {policy}: analytic post-eff {:.4} vs netsim {:.4} \
                     ({:.1}% apart)",
                    a.post_efficiency,
                    f.post_efficiency,
                    100.0 * rel
                );
                // both record the same degraded plan
                assert_eq!(
                    PartitionPlan::from_json(&a.plan_after).unwrap(),
                    PartitionPlan::from_json(&f.plan_after).unwrap(),
                    "{model} x{nodes} {policy}"
                );
            }
        }
    }
}

#[test]
fn replan_beats_stall_on_post_failure_efficiency_at_scale() {
    // The tradeoff the feature exists to quantify: at n >= 32, dropping
    // to N-1 on a re-derived plan is better *per surviving node* than
    // waiting out the restart and resuming the full fleet — the
    // synchronous step no longer pays the extra member's exchange, and
    // the replanned shapes fit the degraded count. (stall's post-failure
    // efficiency is the clean N-node efficiency by construction.)
    for nodes in [33u64, 65] {
        let stall = recovery_of(
            &AnalyticBackend.run(&failure_spec("vgg_a", "cori", nodes, 512, "stall")).unwrap(),
        );
        let replan = recovery_of(
            &AnalyticBackend.run(&failure_spec("vgg_a", "cori", nodes, 512, "replan")).unwrap(),
        );
        assert_eq!(stall.nodes_after, nodes);
        assert_eq!(replan.nodes_after, nodes - 1);
        assert!(
            replan.post_efficiency > stall.post_efficiency,
            "x{nodes}: replan post-eff {:.4} must beat stall {:.4}",
            replan.post_efficiency,
            stall.post_efficiency
        );
        assert!(stall.post_samples_per_s > 0.0 && replan.post_samples_per_s > 0.0);
    }
    // and the netsim measurement agrees with the winning side at n=33
    let mut spec = failure_spec("vgg_a", "cori", 33, 512, "replan");
    spec.cluster.congestion = Some(0.0);
    let a = recovery_of(&AnalyticBackend.run(&spec).unwrap());
    let f = recovery_of(&FleetSimBackend.run(&spec).unwrap());
    let rel = (a.post_efficiency - f.post_efficiency).abs() / a.post_efficiency.max(1e-9);
    assert!(rel < 0.05, "x33 replan: analytic {} vs netsim {}", a.post_efficiency,
            f.post_efficiency);
}

#[test]
fn recovery_section_travels_through_the_report_wire_format() {
    use pcl_dnn::experiment::ScalingReport;
    use pcl_dnn::util::json::Json;
    let spec = failure_spec("cddnn_full", "endeavor", 8, 1024, "shrink");
    let rep = AnalyticBackend.run(&spec).unwrap();
    let round = Json::parse(&rep.to_json().to_string()).unwrap();
    ScalingReport::check_schema(&round).unwrap();
    let back = ScalingReport::from_json(&round).unwrap();
    assert_eq!(back.to_json().to_string(), rep.to_json().to_string());
    let rec = recovery_of(&back);
    assert_eq!(rec.policy, "shrink");
    assert_eq!(rec.nodes_after, 7);
    // the degraded plan in the report parses as a first-class plan
    let after = PartitionPlan::from_json(&rec.plan_after).unwrap();
    assert_eq!(after.nodes, 7);
}
