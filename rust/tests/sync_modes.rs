//! Sync-mode axis property suite (the `parallelism.sync` contract):
//!
//! * `sync = "bsp"` is bit-identical to a spec with the field absent on
//!   every simulation backend, and the runtime coordinator's staleness
//!   window never changes the math — folds stay rank-ordered, so
//!   parameters match BSP bit-for-bit at every window;
//! * `ssp{0}` normalizes to bsp exactly (not approximately);
//! * relaxed modes strictly beat bsp throughput under straggler skew;
//! * netsim's per-message parameter-server exchange agrees with the
//!   analytic α-β push/pull pricing on a clean fabric (≤ 10%);
//! * the non-bsp fallback matrix rejects unsupported configurations
//!   with actionable errors instead of silently mispricing them.

use pcl_dnn::coordinator::{MicrobatchPlan, SgdConfig, SyncSgdCoordinator};
use pcl_dnn::experiment::{
    registry, AnalyticBackend, Backend, ExperimentSpec, FleetSimBackend, FlowSimBackend,
};
use pcl_dnn::netsim::SyncMode;
use pcl_dnn::util::json::Json;

fn spec_at(nodes: u64) -> ExperimentSpec {
    let mut s = ExperimentSpec::of("sync_modes", "vgg_a", "cori", nodes, 256);
    s.parallelism.iterations = 4;
    s
}

/// Re-parse a spec with `parallelism.sync` dropped from its JSON form —
/// the shape of every committed spec predating the sync axis.
fn without_sync_key(spec: &ExperimentSpec) -> ExperimentSpec {
    let mut j = Json::parse(&spec.to_json().to_string()).unwrap();
    if let Json::Obj(root) = &mut j {
        if let Some(Json::Obj(par)) = root.get_mut("parallelism") {
            assert!(par.remove("sync").is_some(), "spec JSON no longer carries sync");
        }
    }
    ExperimentSpec::parse_str(&j.to_string()).unwrap()
}

#[test]
fn bsp_is_bit_identical_to_an_absent_sync_field_on_all_backends() {
    let backends: &[&dyn Backend] = &[&AnalyticBackend, &FlowSimBackend, &FleetSimBackend];
    for nodes in [2u64, 4, 8] {
        let mut explicit = spec_at(nodes);
        explicit.parallelism.sync = "bsp".into();
        let absent = without_sync_key(&explicit);
        assert_eq!(absent.parallelism.sync, "bsp", "absent key must default to the barrier");
        for b in backends {
            let e = b.run(&explicit).unwrap().to_json().to_string();
            let a = b.run(&absent).unwrap().to_json().to_string();
            assert_eq!(e, a, "{} report diverged at {nodes} nodes", b.name());
        }
    }
}

#[test]
fn coordinator_staleness_windows_keep_updates_bit_identical() {
    let params = vec![vec![0.5f32; 33], vec![-0.25f32; 17]];
    for workers in [2usize, 4, 8] {
        let plan = MicrobatchPlan::new(32, workers, 2).unwrap();
        let mut run = |window: Option<usize>| {
            let mut compute = |w: usize,
                               starts: &[usize],
                               acc: &mut [Vec<f32>]|
             -> anyhow::Result<(f64, u64)> {
                for (t, buf) in acc.iter_mut().enumerate() {
                    for (i, x) in buf.iter_mut().enumerate() {
                        *x = ((w * 31 + t * 7 + i) % 13) as f32 * 0.1 - 0.5;
                    }
                }
                Ok((starts.len() as f64 * 0.25, starts.len() as u64))
            };
            let mut c = SyncSgdCoordinator::new(
                "t",
                params.clone(),
                plan.clone(),
                SgdConfig::default(),
            );
            c.set_overlap(true);
            if let Some(k) = window {
                c.set_staleness(k);
            }
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses.push(c.step_with_compute(&mut compute).unwrap().loss.to_bits());
            }
            (losses, c.params.tensors.clone(), c.grad_sets_allocated())
        };
        // field absent == explicit window 0 == BSP (the regression pin)
        let (l0, p0, s0) = run(None);
        assert!(s0 <= 3, "BSP streaming allocated {s0} gradient sets");
        for window in [0usize, 1, 2, workers] {
            let (l, p, sets) = run(Some(window));
            assert_eq!(l0, l, "losses diverged at window {window} ({workers} workers)");
            for (a, b) in p0.iter().zip(&p) {
                assert!(
                    a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "parameters diverged at window {window} ({workers} workers)"
                );
            }
            // memory stays bounded: the parked backlog adds at most
            // `window` sets on top of the streaming pipeline's 3
            assert!(
                sets <= 3 + window,
                "window {window} allocated {sets} gradient sets ({workers} workers)"
            );
        }
    }
}

#[test]
fn ssp_zero_is_exactly_bsp() {
    assert_eq!(registry::sync_mode("ssp{0}").unwrap(), SyncMode::Bsp);
    assert_eq!(registry::sync_mode("ssp{2}").unwrap(), SyncMode::Ssp { staleness: 2 });
    let mut zero = spec_at(4);
    zero.parallelism.sync = "ssp{0}".into();
    let mut bsp = spec_at(4);
    bsp.parallelism.sync = "bsp".into();
    let rz = FleetSimBackend.run(&zero).unwrap().to_json().to_string();
    let rb = FleetSimBackend.run(&bsp).unwrap().to_json().to_string();
    assert_eq!(rz, rb, "ssp{{0}} must collapse to the barrier bit-for-bit");
}

#[test]
fn relaxed_sync_beats_bsp_under_straggler_skew() {
    // the acceptance frontier: at skew 0.4 and n = 8 the drift-bounded
    // timelines keep fast nodes productive while bsp convoys on the
    // slowest node every iteration
    let mut spec = spec_at(8);
    spec.parallelism.mode = "data".into();
    spec.parallelism.iterations = 6;
    spec.cluster.straggler_skew = 0.4;
    let run = |sync: &str| {
        let mut s = spec.clone();
        s.parallelism.sync = sync.into();
        FleetSimBackend.run(&s).unwrap()
    };
    let bsp = run("bsp");
    let ssp = run("ssp{2}");
    let ps = run("async-ps");
    assert!(
        ssp.samples_per_s > bsp.samples_per_s,
        "ssp{{2}} {:.0} samples/s <= bsp {:.0}",
        ssp.samples_per_s,
        bsp.samples_per_s
    );
    assert!(
        ps.samples_per_s > bsp.samples_per_s,
        "async-ps {:.0} samples/s <= bsp {:.0}",
        ps.samples_per_s,
        bsp.samples_per_s
    );
}

#[test]
fn async_ps_netsim_agrees_with_analytic_alpha_beta_on_clean_fabric() {
    let mut spec = spec_at(8);
    spec.parallelism.mode = "data".into();
    spec.parallelism.sync = "async-ps".into();
    spec.cluster.congestion = Some(0.0);
    let sim = FleetSimBackend.run(&spec).unwrap();
    let ana = AnalyticBackend.run(&spec).unwrap();
    let delta = (sim.iteration_s - ana.iteration_s).abs() / ana.iteration_s;
    assert!(
        delta <= 0.10,
        "netsim {:.4} ms vs analytic {:.4} ms: {:.1}% apart (> 10%)",
        sim.iteration_s * 1e3,
        ana.iteration_s * 1e3,
        100.0 * delta
    );
}

#[test]
fn non_bsp_guards_reject_unsupported_configurations() {
    // flowsim is bulk-synchronous only
    let mut s = spec_at(4);
    s.parallelism.sync = "async-ps".into();
    let e = format!("{:#}", FlowSimBackend.run(&s).unwrap_err());
    assert!(e.contains("flowsim") && e.contains("netsim"), "{e}");
    // failure recovery needs the barrier to anchor the timeline split
    let mut s = spec_at(4);
    s.parallelism.sync = "ssp{2}".into();
    s.parallelism.mode = "data".into();
    s.cluster.fail_at = Some(1);
    let e = format!("{:#}", FleetSimBackend.run(&s).unwrap_err());
    assert!(e.contains("fail_at") && e.contains("bsp"), "{e}");
    // drift-bounded timelines require a pure data-parallel plan
    let mut s = spec_at(8);
    s.parallelism.sync = "async-ps".into();
    s.parallelism.mode = "hybrid".into();
    let e = format!("{:#}", FleetSimBackend.run(&s).unwrap_err());
    assert!(e.contains("data-parallel"), "{e}");
}
