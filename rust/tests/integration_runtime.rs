//! Integration: PJRT runtime x AOT artifacts.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! stays runnable pre-build).

use pcl_dnn::coordinator::{ParamStore, SgdConfig};
use pcl_dnn::runtime::{HostTensor, Runtime};
use pcl_dnn::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

fn rand_tensor(shape: &[usize], seed: u64) -> HostTensor {
    let mut rng = Rng::new(seed);
    let n: usize = shape.iter().product();
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.0);
    HostTensor::f32(shape.to_vec(), v)
}

fn max_abs_diff(a: &HostTensor, b: &HostTensor) -> f32 {
    a.as_f32()
        .unwrap()
        .iter()
        .zip(b.as_f32().unwrap())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[test]
fn matmul_pallas_equals_native() {
    let Some(mut rt) = runtime() else { return };
    let x = rand_tensor(&[256, 512], 1);
    let w = rand_tensor(&[512, 256], 2);
    let a = rt.execute("matmul_native", &[x.clone(), w.clone()]).unwrap();
    let b = rt.execute("matmul_pallas", &[x, w]).unwrap();
    let d = max_abs_diff(&a[0], &b[0]);
    assert!(d < 1e-3, "pallas vs native matmul diff {d}");
}

#[test]
fn conv_layer_pallas_equals_native() {
    let Some(mut rt) = runtime() else { return };
    let x = rand_tensor(&[8, 16, 16, 64], 3);
    let w = rand_tensor(&[3, 3, 64, 128], 4);
    let a = rt.execute("conv_layer_native", &[x.clone(), w.clone()]).unwrap();
    let b = rt.execute("conv_layer_pallas", &[x, w]).unwrap();
    assert_eq!(a[0].shape(), b[0].shape());
    let d = max_abs_diff(&a[0], &b[0]);
    assert!(d < 1e-3, "pallas vs native conv diff {d}");
}

#[test]
fn vgg_forward_pallas_path_matches_native() {
    let Some(mut rt) = runtime() else { return };
    let params = rt.manifest().load_params("vgg_tiny").unwrap();
    let spec = rt.manifest().artifact("vgg_tiny_fwd_pallas").unwrap().clone();
    let b = spec.batch;
    let img = rand_tensor(&[b, 32, 32, 3], 7);
    let pallas = rt
        .execute_with_params("vgg_tiny_fwd_pallas", &params, &[img.clone()])
        .unwrap();
    // native fwd has batch 32; rebuild a matching input by tiling
    let native_spec = rt.manifest().artifact("vgg_tiny_fwd").unwrap().clone();
    let nb = native_spec.batch;
    let mut big = img.as_f32().unwrap().to_vec();
    let one = 32 * 32 * 3;
    while big.len() < nb * one {
        let chunk = big[..b * one].to_vec();
        big.extend_from_slice(&chunk);
    }
    big.truncate(nb * one);
    let native = rt
        .execute_with_params(
            "vgg_tiny_fwd",
            &params,
            &[HostTensor::f32(vec![nb, 32, 32, 3], big)],
        )
        .unwrap();
    // compare the first b rows of logits
    let classes = pallas[0].shape()[1];
    let p = pallas[0].as_f32().unwrap();
    let n = &native[0].as_f32().unwrap()[..b * classes];
    let d = p.iter().zip(n).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(d < 1e-3, "pallas-path logits diff {d}");
}

#[test]
fn train_artifact_abi_loss_plus_grads() {
    let Some(mut rt) = runtime() else { return };
    let params = rt.manifest().load_params("vgg_tiny").unwrap();
    let spec = rt.manifest().artifact("vgg_tiny_train").unwrap().clone();
    let b = spec.batch;
    let img = rand_tensor(&[b, 32, 32, 3], 11);
    let labels = HostTensor::i32(vec![b], (0..b as i32).map(|i| i % 10).collect());
    let out = rt.execute_with_params("vgg_tiny_train", &params, &[img, labels]).unwrap();
    assert_eq!(out.len(), 1 + params.len());
    let loss = out[0].scalar().unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    // grad shapes match param shapes
    for (g, p) in out[1..].iter().zip(&params) {
        assert_eq!(g.len(), p.len());
    }
    // at init, gradients must be non-trivial
    let gnorm: f32 = out[1..]
        .iter()
        .flat_map(|g| g.as_f32().unwrap().iter())
        .map(|&x| x * x)
        .sum::<f32>()
        .sqrt();
    assert!(gnorm > 1e-3, "gradient norm {gnorm}");
}

#[test]
fn in_graph_sgd_matches_param_store() {
    // The vgg_tiny_sgd artifact applies p - lr*g in-graph; ParamStore does
    // it on the host. They must agree bit-for-bit-close.
    let Some(mut rt) = runtime() else { return };
    let params = rt.manifest().load_params("vgg_tiny").unwrap();
    let spec = rt.manifest().artifact("vgg_tiny_sgd").unwrap().clone();
    let n = spec.n_params;
    let mut rng = Rng::new(5);
    let grads: Vec<Vec<f32>> = params
        .iter()
        .map(|p| {
            let mut g = vec![0.0f32; p.len()];
            rng.fill_normal(&mut g, 0.1);
            g
        })
        .collect();
    let lr = 0.05f32;

    // in-graph
    let mut inputs: Vec<HostTensor> = Vec::new();
    for (i, p) in params.iter().enumerate() {
        inputs.push(HostTensor::f32(spec.inputs[i].shape.clone(), p.clone()));
    }
    for (i, g) in grads.iter().enumerate() {
        inputs.push(HostTensor::f32(spec.inputs[n + i].shape.clone(), g.clone()));
    }
    inputs.push(HostTensor::scalar_f32(lr));
    let out = rt.execute("vgg_tiny_sgd", &inputs).unwrap();

    // host
    let mut store = ParamStore::new(
        params.clone(),
        SgdConfig { lr, ..SgdConfig::default() },
    );
    store.apply_all(&grads, 1.0).unwrap();

    for (t, (got, want)) in out.iter().zip(&store.tensors).enumerate() {
        let d = got
            .as_f32()
            .unwrap()
            .iter()
            .zip(want)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(d < 1e-6, "tensor {t} diff {d}");
    }
}

#[test]
fn execute_rejects_bad_shapes_and_dtypes() {
    let Some(mut rt) = runtime() else { return };
    let bad = rt.execute("matmul_native", &[rand_tensor(&[4, 4], 0), rand_tensor(&[512, 256], 1)]);
    assert!(bad.is_err());
    let spec = rt.manifest().artifact("vgg_tiny_train").unwrap().clone();
    let b = spec.batch;
    // labels passed as f32 instead of i32
    let params = rt.manifest().load_params("vgg_tiny").unwrap();
    let img = rand_tensor(&[b, 32, 32, 3], 1);
    let bad_labels = HostTensor::f32(vec![b], vec![0.0; b]);
    assert!(rt.execute_with_params("vgg_tiny_train", &params, &[img, bad_labels]).is_err());
}

#[test]
fn manifest_inventory_is_complete() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest();
    for required in [
        "vgg_tiny_train",
        "vgg_tiny_fwd",
        "vgg_tiny_eval",
        "overfeat_tiny_train",
        "cddnn_tiny_train",
        "gpt_test_train",
        "gpt_mini_train",
        "conv_layer_pallas",
        "matmul_pallas",
    ] {
        assert!(m.artifacts.contains_key(required), "missing {required}");
    }
    for (name, model) in &m.models {
        let params = m.load_params(name).unwrap();
        assert_eq!(params.len(), model.params.len());
        let total: usize = params.iter().map(|p| p.len()).sum();
        assert_eq!(total, model.n_elements);
        assert!(params.iter().flatten().all(|v| v.is_finite()));
    }
}
