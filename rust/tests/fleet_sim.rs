//! Full-cluster simulator validation and scenario coverage.
//!
//! * The full per-message simulator must converge to the representative
//!   α-β prediction on a homogeneous, contention-free fabric (within 5%).
//! * It must also express what the representative model cannot: straggler
//!   skew, oversubscribed-fabric contention, heterogeneous fleets, and
//!   failure/rejoin stalls.

use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::models::zoo;
use pcl_dnn::netsim::cluster::{
    simulate_training, simulate_training_fleet, simulate_training_fleet_full, SimConfig,
    PROBE_ITERATIONS,
};
use pcl_dnn::netsim::{FleetConfig, SimPath, Topology};

/// Cori with the α-β congestion fudge stripped: the full simulator models
/// contention explicitly, so the cross-check must too.
fn contention_free_cori() -> Platform {
    let mut p = Platform::cori();
    p.fabric.congestion_per_doubling = 0.0;
    p
}

#[test]
fn full_cluster_matches_alpha_beta_data_parallel() {
    // The acceptance bar: homogeneous fleet, fully-switched fabric, pure
    // data parallelism — full-cluster iteration time within 5% of the
    // representative-node α-β prediction.
    let p = contention_free_cori();
    for nodes in [2u64, 4, 8] {
        let cfg = SimConfig::data_parallel(nodes, 256);
        let rep = simulate_training(&zoo::vgg_a(), &p, &cfg).unwrap();
        let full = simulate_training_fleet(
            &zoo::vgg_a(),
            &p,
            &cfg,
            &FleetConfig::homogeneous(nodes as usize),
        )
        .unwrap();
        let rel = (full.iteration_s - rep.iteration_s).abs() / rep.iteration_s;
        assert!(
            rel < 0.05,
            "nodes={nodes}: full {} vs analytic {} ({:.1}% off)",
            full.iteration_s,
            rep.iteration_s,
            100.0 * rel
        );
    }
}

#[test]
fn full_cluster_matches_alpha_beta_hybrid() {
    // Same bar with the paper's hybrid-FC recipe active (replica-set
    // exchanges + activation allgathers among model-parallel groups).
    let p = contention_free_cori();
    let cfg = SimConfig::recipe(&zoo::vgg_a(), 8, 256);
    let rep = simulate_training(&zoo::vgg_a(), &p, &cfg).unwrap();
    let full =
        simulate_training_fleet(&zoo::vgg_a(), &p, &cfg, &FleetConfig::homogeneous(8)).unwrap();
    let rel = (full.iteration_s - rep.iteration_s).abs() / rep.iteration_s;
    assert!(
        rel < 0.05,
        "full {} vs analytic {} ({:.1}% off)",
        full.iteration_s,
        rep.iteration_s,
        100.0 * rel
    );
}

#[test]
fn straggler_skew_slows_iterations_monotonically() {
    // Scenario 1 the representative model cannot express: a linear
    // straggler ramp. Synchronous SGD runs at the slowest node's pace, so
    // iteration time must grow with skew and approach the (1 + skew)
    // compute bound.
    let p = contention_free_cori();
    let cfg = SimConfig::data_parallel(8, 256);
    let mut prev = 0.0;
    let base =
        simulate_training_fleet(&zoo::vgg_a(), &p, &cfg, &FleetConfig::homogeneous(8)).unwrap();
    for skew in [0.0, 0.2, 0.5, 1.0] {
        let fc = FleetConfig { nodes: 8, straggler_skew: skew, ..Default::default() };
        let r = simulate_training_fleet(&zoo::vgg_a(), &p, &cfg, &fc).unwrap();
        assert!(
            r.iteration_s >= prev,
            "skew {skew}: {} not monotone (prev {prev})",
            r.iteration_s
        );
        prev = r.iteration_s;
        if skew > 0.0 {
            // slower than homogeneous, no worse than the full slowdown
            // applied to everything
            assert!(r.iteration_s > base.iteration_s, "skew {skew}");
            assert!(
                r.iteration_s <= base.iteration_s * (1.0 + skew) * 1.05,
                "skew {skew}: {} vs bound {}",
                r.iteration_s,
                base.iteration_s * (1.0 + skew)
            );
            // the fast nodes idle while waiting on the straggler
            assert!(
                r.min_compute_utilization < base.min_compute_utilization,
                "skew {skew}"
            );
        }
    }
    // a meaningful skew must cost a meaningful fraction of the compute
    let r = simulate_training_fleet(
        &zoo::vgg_a(),
        &p,
        &cfg,
        &FleetConfig { nodes: 8, straggler_skew: 1.0, ..Default::default() },
    )
    .unwrap();
    assert!(r.iteration_s > base.iteration_s * 1.3, "{} vs {}", r.iteration_s, base.iteration_s);
}

#[test]
fn oversubscribed_ethernet_contention_slows_hybrid_training() {
    // Scenario 2: an oversubscribed fat-tree core on (virtualized) 10
    // GbE. Ring exchanges over consecutive ranks are oversubscription-
    // tolerant (almost all hops stay inside a leaf), but the hybrid
    // recipe's replica-set exchanges stride across leaves — CD-DNN's
    // per-rank gradient flows all cross the core concurrently and
    // serialize on the squeezed uplink channels.
    let mut p = Platform::aws();
    p.fabric.congestion_per_doubling = 0.0;
    let cfg = SimConfig::recipe(&zoo::cddnn_full(), 8, 1024);
    let baseline = simulate_training_fleet(
        &zoo::cddnn_full(),
        &p,
        &cfg,
        &FleetConfig { nodes: 8, topology: Topology::FlatSwitch, ..Default::default() },
    )
    .unwrap();
    let mut prev = 0.0;
    for oversub in [1.0, 2.0, 4.0] {
        let fc = FleetConfig {
            nodes: 8,
            topology: Topology::FatTree { radix: 4, oversub },
            ..Default::default()
        };
        let r = simulate_training_fleet(&zoo::cddnn_full(), &p, &cfg, &fc).unwrap();
        assert!(
            r.iteration_s >= prev * 0.999,
            "oversub {oversub}: {} not monotone (prev {prev})",
            r.iteration_s
        );
        prev = r.iteration_s;
    }
    // a 4:1 core must be measurably slower than the non-blocking switch
    let squeezed = simulate_training_fleet(
        &zoo::cddnn_full(),
        &p,
        &cfg,
        &FleetConfig {
            nodes: 8,
            topology: Topology::FatTree { radix: 4, oversub: 4.0 },
            ..Default::default()
        },
    )
    .unwrap();
    assert!(
        squeezed.iteration_s > baseline.iteration_s * 1.02,
        "oversubscribed {} vs flat {}",
        squeezed.iteration_s,
        baseline.iteration_s
    );
}

#[test]
fn hetero_fleet_runs_at_slow_generation_pace() {
    let p = contention_free_cori();
    let cfg = SimConfig::data_parallel(4, 256);
    let homo =
        simulate_training_fleet(&zoo::vgg_a(), &p, &cfg, &FleetConfig::homogeneous(4)).unwrap();
    let hetero = simulate_training_fleet(
        &zoo::vgg_a(),
        &p,
        &cfg,
        &FleetConfig { nodes: 4, hetero: true, ..Default::default() },
    )
    .unwrap();
    assert!(hetero.iteration_s > homo.iteration_s * 1.1, "{} vs {}", hetero.iteration_s,
            homo.iteration_s);
    assert!(hetero.iteration_s < homo.iteration_s * 1.5);
}

#[test]
fn failure_stalls_one_iteration_then_rejoins() {
    let p = contention_free_cori();
    // iterations: 0 warmup, 1 fails, steady state measured over the last
    // two — so the recovery must NOT pollute the steady-state window...
    let cfg = SimConfig { iterations: 5, ..SimConfig::data_parallel(4, 256) };
    let clean =
        simulate_training_fleet(&zoo::vgg_a(), &p, &cfg, &FleetConfig::homogeneous(4)).unwrap();
    let failed = simulate_training_fleet(
        &zoo::vgg_a(),
        &p,
        &cfg,
        &FleetConfig {
            nodes: 4,
            fail_at: Some(1),
            fail_node: 2,
            recovery_s: 3.0,
            ..Default::default()
        },
    )
    .unwrap();
    // steady state after rejoin matches the clean fleet
    let rel = (failed.iteration_s - clean.iteration_s).abs() / clean.iteration_s;
    assert!(rel < 0.05, "post-rejoin steady state off by {:.1}%", 100.0 * rel);

    // ...but an iteration window containing the failure pays the stall:
    // measure with the failure in the last iteration
    let cfg_tail = SimConfig { iterations: 4, ..cfg.clone() };
    let hit = simulate_training_fleet(
        &zoo::vgg_a(),
        &p,
        &cfg_tail,
        &FleetConfig {
            nodes: 4,
            fail_at: Some(3),
            fail_node: 2,
            recovery_s: 3.0,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(
        hit.iteration_s > clean.iteration_s + 2.5,
        "failed iteration {} must absorb most of the 3 s recovery (clean {})",
        hit.iteration_s,
        clean.iteration_s
    );
}

#[test]
fn fleet_tasks_scale_with_cluster_size() {
    // sanity: the full simulator really is per-node, per-message
    let p = contention_free_cori();
    let mk = |nodes: u64| {
        let cfg = SimConfig { iterations: 3, ..SimConfig::data_parallel(nodes, 256) };
        simulate_training_fleet(&zoo::vgg_a(), &p, &cfg,
                                &FleetConfig::homogeneous(nodes as usize))
        .unwrap()
    };
    let small = mk(2);
    let big = mk(8);
    assert!(big.tasks > 4 * small.tasks, "{} vs {}", big.tasks, small.tasks);
}

#[test]
fn fig4_netsim_smoke_at_128_nodes() {
    // The paper's largest design point, end to end on the full
    // per-message simulator: VGG-A x128 on a clean Cori fabric with the
    // fixed recipe plan — ~100k tasks under auto (butterfly) collectives
    // (the ring-pinned >1M-message ablation of the same point runs in
    // bench_netsim_perf). The bar is loose on purpose — the 5% analytic
    // agreement is asserted at n in {8,32,64} above; here we pin that
    // the 128-node expansion completes and lands in the Fig 4 ballpark
    // (determinism is covered per-engine by the oracle suite).
    let p = contention_free_cori();
    let net = zoo::vgg_a();
    let cfg = SimConfig { iterations: 3, ..SimConfig::recipe(&net, 128, 512) };
    let full = simulate_training_fleet(&net, &p, &cfg, &FleetConfig::homogeneous(128)).unwrap();
    // ~100k tasks under auto (butterfly) collectives; the ring ablation
    // of the same point is the >1M-message case the perf bench times
    assert!(full.tasks > 50_000, "expected a full per-message expansion, got {}", full.tasks);
    let rep = simulate_training(&net, &p, &cfg).unwrap();
    let rel = (full.iteration_s - rep.iteration_s).abs() / rep.iteration_s;
    assert!(
        rel < 0.10,
        "fig4@128: full {} vs analytic {} ({:.1}% off)",
        full.iteration_s,
        rep.iteration_s,
        100.0 * rel
    );
}

#[test]
fn cross_backend_consistency_all_models() {
    // The spec-API form of the validation invariant, extended from the
    // one wired VGG case to every full-size paper network: on a clean
    // (congestion override 0) homogeneous fully-switched fabric, the
    // analytic and netsim backends must report efficiencies within 5%
    // of each other for the SAME ExperimentSpec at n in {8, 32} — the
    // paper's own model-vs-measurement methodology, §5-6.
    use pcl_dnn::experiment::{AnalyticBackend, Backend, ExperimentSpec, FleetSimBackend};

    // n=64 was #[ignore]-tier before the engine's indexed dispatch; it
    // now runs in the default suite alongside 8 and 32
    for (model, platform, mb) in [
        ("vgg_a", "cori", 256u64),
        ("overfeat_fast", "aws", 256),
        ("cddnn_full", "endeavor", 1024),
    ] {
        for nodes in [8u64, 32, 64] {
            let mut spec =
                ExperimentSpec::of(&format!("xcheck_{model}_{nodes}"), model, platform, nodes, mb);
            spec.cluster.congestion = Some(0.0);
            spec.parallelism.iterations = 3;
            let a = AnalyticBackend.run(&spec).unwrap();
            let f = FleetSimBackend.run(&spec).unwrap();
            let (ea, ef) = (a.efficiency.unwrap(), f.efficiency.unwrap());
            let rel = (ea - ef).abs() / ea.max(1e-9);
            assert!(
                rel < 0.05,
                "{model} x{nodes}: analytic eff {ea:.4} vs netsim eff {ef:.4} ({:.1}% apart; \
                 iter {} vs {})",
                100.0 * rel,
                a.iteration_s,
                f.iteration_s
            );
            assert!(f.tasks > 0 && a.tasks == 0);
        }
    }
}

#[test]
fn cross_tier_consistency_flowsim_vs_netsim() {
    // The three-tier fidelity ladder must agree where the tiers'
    // domains overlap: on a clean (congestion override 0) homogeneous
    // fully-switched fabric, the flow-level tier and the per-message
    // tier must report efficiencies within 5% of each other for the
    // SAME ExperimentSpec on every full-size paper network at every
    // node count netsim itself runs in the default suite. This is what
    // licenses flowsim's 1000s-of-node frontier sweeps: the cheap tier
    // is pinned to the expensive one over the entire measurable range.
    use pcl_dnn::experiment::{Backend, ExperimentSpec, FleetSimBackend, FlowSimBackend};

    for (model, platform, mb) in [
        ("vgg_a", "cori", 256u64),
        ("overfeat_fast", "aws", 256),
        ("cddnn_full", "endeavor", 1024),
    ] {
        for nodes in [8u64, 32, 64, 128] {
            let mut spec = ExperimentSpec::of(
                &format!("xtier_{model}_{nodes}"),
                model,
                platform,
                nodes,
                mb,
            );
            spec.cluster.congestion = Some(0.0);
            spec.parallelism.iterations = 3;
            let flow = FlowSimBackend.run(&spec).unwrap();
            let full = FleetSimBackend.run(&spec).unwrap();
            assert_eq!(flow.sim_path.as_deref(), Some("flow"));
            assert!(
                flow.tasks > 0 && flow.tasks < full.tasks,
                "{model} x{nodes}: flow tier should be coarser ({} vs {} tasks)",
                flow.tasks,
                full.tasks
            );
            let (ef, en) = (flow.efficiency.unwrap(), full.efficiency.unwrap());
            let rel = (ef - en).abs() / en.max(1e-9);
            assert!(
                rel < 0.05,
                "{model} x{nodes}: flowsim eff {ef:.4} vs netsim eff {en:.4} ({:.1}% apart; \
                 iter {} vs {})",
                100.0 * rel,
                flow.iteration_s,
                full.iteration_s
            );
        }
    }
}

#[test]
fn periodic_fast_path_is_bit_identical_on_clean_specs() {
    // The tentpole's correctness bar: on every clean-fabric committed
    // spec shape (fig4 VGG-A/Cori, fig6 OverFeat/AWS, fig7 CD-DNN/
    // Endeavor) at n in {8, 32, 64}, the steady-state fast path must
    // report EXACTLY what the full simulation reports — the only fields
    // allowed to differ are the path marker itself and the count of
    // tasks actually pushed through the event loop.
    for (net, platform, mb) in [
        (zoo::vgg_a(), Platform::cori(), 512u64),
        (zoo::overfeat_fast(), Platform::aws(), 256),
        (zoo::cddnn_full(), Platform::endeavor(), 1024),
    ] {
        for nodes in [8u64, 32, 64] {
            let cfg = SimConfig { iterations: 7, ..SimConfig::recipe(&net, nodes, mb) };
            let fc = FleetConfig::homogeneous(nodes as usize);
            let fast = simulate_training_fleet(&net, &platform, &cfg, &fc).unwrap();
            let full = simulate_training_fleet_full(&net, &platform, &cfg, &fc).unwrap();
            assert_eq!(fast.sim_path, SimPath::Periodic, "{} x{nodes}", net.name);
            assert_eq!(full.sim_path, SimPath::Full);
            // the probe simulates PROBE_ITERATIONS cycles, the full run
            // all of them; both extrapolate to the same K-iteration DAG
            assert_eq!(fast.warmup_tasks, fast.cycle_tasks * PROBE_ITERATIONS);
            assert_eq!(full.warmup_tasks, full.cycle_tasks * cfg.iterations);
            let mut fast_norm = fast.clone();
            fast_norm.sim_path = full.sim_path;
            fast_norm.warmup_tasks = full.warmup_tasks;
            assert_eq!(fast_norm, full, "{} x{nodes}: fast path diverged", net.name);
        }
    }
}

#[test]
fn stragglers_hetero_and_failures_take_the_full_path() {
    // The fallback property: any fleet feature that breaks per-iteration
    // uniformity must route to full simulation, and the routed result
    // must be byte-identical to pre-template output (= the forced-full
    // entry point) — every field, no normalization.
    let p = contention_free_cori();
    let cfg = SimConfig { iterations: 6, ..SimConfig::data_parallel(6, 256) };
    let fleets = [
        FleetConfig { nodes: 6, straggler_skew: 0.4, ..Default::default() },
        FleetConfig { nodes: 6, hetero: true, ..Default::default() },
        FleetConfig { nodes: 6, fail_at: Some(2), fail_node: 1, recovery_s: 2.0,
                      ..Default::default() },
    ];
    for fc in &fleets {
        let routed = simulate_training_fleet(&zoo::vgg_a(), &p, &cfg, fc).unwrap();
        let forced = simulate_training_fleet_full(&zoo::vgg_a(), &p, &cfg, fc).unwrap();
        assert_eq!(routed.sim_path, SimPath::Full, "skew={} hetero={} fail_at={:?}",
                   fc.straggler_skew, fc.hetero, fc.fail_at);
        assert_eq!(routed, forced);
    }
    // a fail_at beyond the simulated window never fires, so it stays
    // eligible for the fast path
    let dormant = FleetConfig { nodes: 6, fail_at: Some(99), ..Default::default() };
    let r = simulate_training_fleet(&zoo::vgg_a(), &p, &cfg, &dormant).unwrap();
    assert_eq!(r.sim_path, SimPath::Periodic);
}

#[test]
fn backend_reports_which_sim_path_ran() {
    use pcl_dnn::experiment::{AnalyticBackend, Backend, ExperimentSpec, FleetSimBackend};

    let mut spec = ExperimentSpec::of("path_probe", "vgg_a", "cori", 8, 256);
    spec.parallelism.iterations = 16;
    let rep = FleetSimBackend.run(&spec).unwrap();
    assert_eq!(rep.sim_path.as_deref(), Some("periodic"));
    assert!(rep.cycle_tasks > 0);
    assert_eq!(rep.warmup_tasks, rep.cycle_tasks * PROBE_ITERATIONS as u64);
    assert_eq!(rep.tasks, rep.cycle_tasks * 16);
    // fleet features force the full path, and the report says so
    spec.cluster.straggler_skew = 0.5;
    let rep = FleetSimBackend.run(&spec).unwrap();
    assert_eq!(rep.sim_path.as_deref(), Some("full"));
    assert_eq!(rep.warmup_tasks, rep.tasks);
    // backends without a discrete-event path choice report null
    spec.cluster.straggler_skew = 0.0;
    let rep = AnalyticBackend.run(&spec).unwrap();
    assert_eq!(rep.sim_path, None);
    assert_eq!((rep.warmup_tasks, rep.cycle_tasks), (0, 0));
}
