//! PartitionPlan / design-point planner contract tests:
//!
//! * the acceptance shape — the planner's auto plan for VGG-A at 64
//!   Cori nodes reproduces the paper's recipe (data-parallel conv
//!   trunk, hybrid FC head at the §3.3 optimal group counts);
//! * the never-worse property — on every zoo model and n ∈ {8, 16, 64},
//!   the chosen plan is analytically no worse than pure data
//!   parallelism or the fixed paper recipe;
//! * plan JSON round-trips byte-identically through `util::json`
//!   (randomized plans included);
//! * the chosen plan cross-checks between the analytic and netsim
//!   backends within 5% on a clean fabric;
//! * committed golden plans under `specs/plans/` parse and validate.

use pcl_dnn::analytic::comm_model::{self, Strategy};
use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::experiment::{
    partition_plan, registry, AnalyticBackend, Backend, ExperimentSpec, FleetSimBackend,
};
use pcl_dnn::netsim::collective::Choice;
use pcl_dnn::plan::{planner, PartitionPlan};
use pcl_dnn::util::json::Json;
use pcl_dnn::util::rng::Rng;

fn search(model: &str, platform: &str, nodes: u64, mb: u64) -> planner::PlanSearch {
    let net = registry::model(model).unwrap();
    let plat = registry::platform(platform).unwrap();
    planner::plan(&planner::PlannerInput {
        net: &net,
        platform: &plat,
        nodes,
        minibatch: mb,
        overlap: 1.0,
        collective: Choice::Auto,
        iterations: 3,
    })
}

#[test]
fn auto_plan_matches_paper_recipe_for_vgg_at_64_nodes() {
    // The acceptance criterion: data-parallel conv trunk, hybrid FC head
    // with the §3.3 group count, derived — not hardcoded.
    let net = registry::model("vgg_a").unwrap();
    let s = search("vgg_a", "cori", 64, 512);
    for l in net.layers.iter().filter(|l| l.is_conv()) {
        assert_eq!(s.plan.strategy_for(&l.name), Strategy::Data, "{}", l.name);
    }
    for l in net.layers.iter().filter(|l| l.is_fc()) {
        let recipe = comm_model::best_strategy(l, 512, 64, 1.0);
        assert_eq!(s.plan.strategy_for(&l.name), recipe, "{}", l.name);
        match s.plan.strategy_for(&l.name) {
            Strategy::Hybrid { groups } => {
                assert_eq!(groups, comm_model::optimal_groups(l, 512, 64, 1.0), "{}", l.name)
            }
            Strategy::Model => {}
            Strategy::Data => panic!("{} stayed data-parallel at 64 nodes", l.name),
        }
    }
    // structurally identical to the recipe plan (mode label aside)
    let recipe_plan = PartitionPlan::paper_recipe(&net, 64, 512, 1.0);
    assert_eq!(s.plan.assignments, recipe_plan.assignments);
    assert_eq!(s.plan.mode, "auto");
}

#[test]
fn planner_is_never_analytically_worse_than_data_or_recipe() {
    // Property over the full zoo at three cluster sizes: the final
    // argmin means the chosen plan can never lose to either baseline.
    for model in registry::model_names() {
        for nodes in [8u64, 16, 64] {
            let s = search(model, "cori", nodes, 256);
            assert!(
                s.chosen_iteration_s <= s.data_iteration_s * (1.0 + 1e-9),
                "{model} x{nodes}: chosen {} > data {}",
                s.chosen_iteration_s,
                s.data_iteration_s
            );
            assert!(
                s.chosen_iteration_s <= s.recipe_iteration_s * (1.0 + 1e-9),
                "{model} x{nodes}: chosen {} > recipe {}",
                s.chosen_iteration_s,
                s.recipe_iteration_s
            );
        }
    }
}

#[test]
fn plan_json_roundtrips_byte_identically_randomized() {
    // 100 random plans over zoo nets: parse(to_json) must reproduce the
    // exact value AND the exact bytes (stable BTreeMap serialization).
    let mut rng = Rng::new(0x9a7);
    let nets =
        ["vgg_a", "overfeat_fast", "cddnn_full"].map(|m| registry::model(m).unwrap());
    for case in 0..100 {
        let net = &nets[rng.below(3) as usize];
        let nodes = 1u64 << (1 + rng.below(6)); // 2..64
        let per: Vec<(String, Strategy, Option<Choice>, f64)> = net
            .layers
            .iter()
            .filter(|l| l.is_weighted())
            .map(|l| {
                let strategy = match rng.below(3) {
                    0 => Strategy::Data,
                    1 => Strategy::Model,
                    _ => {
                        // a random divisor of nodes
                        let divs: Vec<u64> = (1..=nodes).filter(|g| nodes % g == 0).collect();
                        Strategy::Hybrid { groups: divs[rng.below(divs.len() as u64) as usize] }
                    }
                };
                let collective = match rng.below(4) {
                    0 => Some(Choice::Ring),
                    1 => Some(Choice::Butterfly),
                    2 => Some(Choice::Auto),
                    _ => None,
                };
                (l.name.clone(), strategy, collective, 1.0)
            })
            .collect();
        let plan = PartitionPlan::from_assignments("pinned", nodes, 256, &per);
        let text = plan.to_json().to_string();
        let back = PartitionPlan::parse_str(&text)
            .unwrap_or_else(|e| panic!("case {case}: {e:#} in {text}"));
        assert_eq!(back, plan, "case {case}");
        assert_eq!(back.to_json().to_string(), text, "case {case}: bytes differ");
    }
}

#[test]
fn chosen_plan_validates_on_netsim_within_5_percent() {
    // The planner's chosen plan (mode=auto), replayed on the fleet
    // simulator over a clean fabric, must agree with the analytic cost —
    // the same bar the fixed recipe meets in tests/fleet_sim.rs.
    for nodes in [8u64, 32] {
        let mut spec = ExperimentSpec::of("autocheck", "vgg_a", "cori", nodes, 512);
        spec.parallelism.mode = "auto".into();
        spec.parallelism.iterations = 3;
        spec.cluster.congestion = Some(0.0);
        let a = AnalyticBackend.run(&spec).unwrap();
        let f = FleetSimBackend.run(&spec).unwrap();
        let (ea, ef) = (a.efficiency.unwrap(), f.efficiency.unwrap());
        let rel = (ea - ef).abs() / ea.max(1e-9);
        assert!(
            rel < 0.05,
            "x{nodes}: analytic eff {ea:.4} vs netsim eff {ef:.4} ({:.1}% apart)",
            100.0 * rel
        );
        // both backends report the same chosen plan
        assert_eq!(a.plan.to_string(), f.plan.to_string());
        let plan = PartitionPlan::from_json(&a.plan).unwrap();
        assert_eq!(plan.mode, "auto");
        assert_eq!(plan.nodes, nodes);
    }
}

#[test]
fn chosen_plan_validates_on_netsim_at_64_nodes() {
    // previously #[ignore]d as minutes-long: the engine's indexed
    // dispatch makes the full 64-node expansion run in seconds

    let mut spec = ExperimentSpec::of("autocheck64", "vgg_a", "cori", 64, 512);
    spec.parallelism.mode = "auto".into();
    spec.parallelism.iterations = 3;
    spec.cluster.congestion = Some(0.0);
    let a = AnalyticBackend.run(&spec).unwrap();
    let f = FleetSimBackend.run(&spec).unwrap();
    let rel = (a.iteration_s - f.iteration_s).abs() / a.iteration_s;
    assert!(rel < 0.05, "{:.1}% apart", 100.0 * rel);
}

#[test]
fn spec_pins_override_the_derived_plan_end_to_end() {
    // --set plan.fc.groups=8 through the spec machinery: every FC layer
    // lands in an 8-group hybrid, the conv trunk stays data-parallel,
    // and the backend report records the pinned plan.
    let mut spec = ExperimentSpec::of("pinned", "vgg_a", "cori", 64, 512);
    spec.parallelism.iterations = 3;
    spec.apply_set("plan.fc.strategy=hybrid,plan.fc.groups=8").unwrap();
    let plan = partition_plan(&spec, 64).unwrap();
    for fc in ["fc6", "fc7", "fc8"] {
        assert_eq!(plan.strategy_for(fc), Strategy::Hybrid { groups: 8 }, "{fc}");
    }
    assert_eq!(plan.strategy_for("conv1"), Strategy::Data);
    let rep = AnalyticBackend.run(&spec).unwrap();
    let reported = PartitionPlan::from_json(&rep.plan).unwrap();
    assert_eq!(reported.assignments, plan.assignments);
}

#[test]
fn sweeps_re_derive_the_plan_per_node_count() {
    // hybrid group shapes are node-count-specific: the same spec at
    // different n must not reuse one plan
    let spec = ExperimentSpec::of("sweep", "cddnn_full", "endeavor", 16, 1024);
    let p16 = partition_plan(&spec, 16).unwrap();
    let p4 = partition_plan(&spec, 4).unwrap();
    assert_eq!(p16.nodes, 16);
    assert_eq!(p4.nodes, 4);
    assert!(p16.assignments != p4.assignments || p16.nodes != p4.nodes);
}

#[test]
fn committed_golden_plans_parse_and_validate() {
    for (file, model, nodes) in [
        ("fig4.json", "vgg_a", 128u64),
        ("fig6_overfeat.json", "overfeat_fast", 16),
        ("fig6_vgg.json", "vgg_a", 16),
        ("fig7.json", "cddnn_full", 16),
    ] {
        let path = format!("{}/specs/plans/{file}", env!("CARGO_MANIFEST_DIR"));
        let golden = PartitionPlan::load(&path).unwrap();
        let net = registry::model(model).unwrap();
        golden.validate(&net).unwrap();
        assert_eq!(golden.nodes, nodes, "{file}");
        assert!(!golden.is_pure_data(), "{file}: golden plan should use the FC head");
    }
}

#[test]
fn runtime_train_config_carries_the_plan() {
    // The runtime backend derives its plan over the runnable tiny model
    // at worker granularity; without artifacts the run fails cleanly
    // AFTER the plan resolution (vendored xla stub), so assert the
    // translation directly.
    let net = registry::model("vgg_tiny").unwrap();
    let plan = PartitionPlan::paper_recipe(&net, 4, 16, 1.0);
    plan.validate(&net).unwrap();
    // manifest params are `<layer>.<suffix>`; the plan resolves them
    for p in ["conv0.w", "conv0.b", "fc0.w", "head.b"] {
        assert!(plan.assignment_for_param(p).is_some(), "{p}");
    }
}

#[test]
fn bench_plan_rows_merge_by_key() {
    let dir = std::env::temp_dir().join(format!(
        "pcl_dnn_bench_plan_{}",
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_plan.json");
    let path = path.to_str().unwrap();
    let net = registry::model("vgg_a").unwrap();
    let plat = Platform::cori();
    let rows =
        vec![planner::bench_row(&net, &plat, 256, 4, Choice::Auto, 3, None)];
    planner::merge_bench_plan(path, "fig4_vgg_a", rows.clone()).unwrap();
    planner::merge_bench_plan(path, "fig7_cddnn", rows).unwrap();
    let doc = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    assert!(doc.get("fig4_vgg_a").is_ok() && doc.get("fig7_cddnn").is_ok());
    std::fs::remove_dir_all(dir).ok();
}
